"""AOT export pipeline: corpus → train → quantize → HLO-text artifacts.

Runs once at ``make artifacts``; the Rust serving binary is self-contained
afterwards. Exports **HLO text** (not serialized HloModuleProto): the
image's xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction
ids), while the text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Artifact grid (DESIGN.md §2): for each model and precision, a prefill
program per batch size, a ragged verification ``decode`` program per
(batch, Q) bucket for the main model, and a fused ``draft`` program per
(batch, K) bucket for draft models. Buckets keep the artifact count finite;
the Rust engine rounds Algorithm-1 draft lengths to the nearest bucket.

Usage: ``cd python && python -m compile.aot --out ../artifacts [--quick]``
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import bwt
from compile.corpus import build_corpus, write_tasks
from compile.model import (CONFIGS, ModelConfig, decode, decode_packed,
                           draft_loop, draft_packed, kv_row_copy, prefill,
                           prefill_scatter)
from compile.quant import quantize_params
from compile.train import TrainConfig, held_out_loss, train_model

# ---------------------------------------------------------------------------
# Export grid
# ---------------------------------------------------------------------------

BATCHES = [1, 2, 4, 8, 16]
DRAFT_K_BUCKETS = [1, 2, 3, 4, 5, 6, 8, 10, 12, 16]   # Algorithm-1 range
SMALL_K_BUCKETS = [2, 4, 6, 8]                         # draft_b / draft_c
# Packed verification capacity ladder: a decode_packed artifact at (b, q')
# carries C = b·q' packed tokens. Reusing {k + 1} keeps q_launch = max_i q_i
# a ladder member, so the packed capacity bucket never exceeds PAD's
# rectangle for the same batch (Σq_i ≤ b·q_launch rounds to q' ≤ q_launch).
PACKED_Q_BUCKETS = sorted({k + 1 for k in DRAFT_K_BUCKETS})
# Prompt capacity: must fit the longest task prompt (synth_xsum articles
# run ~110 bytes); prompt + generation must stay within the *trained*
# position range (TrainConfig.seq = 192).
PREFILL_P = 112
MAIN = "main"
DRAFTS = ["draft_a", "draft_b", "draft_c"]
PRECISIONS = {"main": ["f32", "int8"], "draft_a": ["f32", "int8"],
              "draft_b": ["f32"], "draft_c": ["f32"]}
# Pallas parity subset: proves the explicitly-tiled kernel path composes
# end-to-end through PJRT (the rest of the grid uses the XLA-fused "dense"
# realization of BASS-PAD, which is numerically identical — see
# tests/test_model.py and DESIGN.md §6).
PALLAS_SUBSET = [("main", "decode", 1, 5), ("main", "decode", 8, 5),
                 ("draft_a", "draft", 8, 4)]


def grid(quick: bool = False):
    """Yield (model, precision, phase, batch, q, attn) artifact specs."""
    batches = [1, 2] if quick else BATCHES
    main_q = [1] + [k + 1 for k in DRAFT_K_BUCKETS]
    if quick:
        main_q, draft_k, small_k = [1, 5], [4], [4]
        packed_q, drafts = [5], ["draft_a"]
    else:
        draft_k, small_k, drafts = DRAFT_K_BUCKETS, SMALL_K_BUCKETS, DRAFTS
        packed_q = PACKED_Q_BUCKETS
    for b in batches:
        # Per-row prefill-scatter: PAD mid-flight admission re-primes one
        # row of a running fused batch. Bucket 1 is skipped — a one-row
        # batch auto-resets the moment its only sequence retires, so no
        # reusable (husk/shadow) row ever exists to scatter into.
        scatter = b > 1
        for prec in PRECISIONS[MAIN]:
            yield (MAIN, prec, "prefill", b, PREFILL_P, "dense")
            if scatter:
                yield (MAIN, prec, "prefill_scatter", b, PREFILL_P,
                       "dense")
                # Row-copy shares prefill_scatter's reachability: only a
                # multi-row fused store has a donor row to copy from.
                yield (MAIN, prec, "kv_row_copy", b, 0, "dense")
            for q in main_q:
                yield (MAIN, prec, "decode", b, q, "dense")
            for q in packed_q:
                yield (MAIN, prec, "decode_packed", b, q, "dense")
        for d in drafts:
            ks = draft_k if d == "draft_a" else small_k
            for prec in PRECISIONS[d]:
                yield (d, prec, "prefill", b, PREFILL_P, "dense")
                if scatter:
                    yield (d, prec, "prefill_scatter", b, PREFILL_P,
                           "dense")
                    yield (d, prec, "kv_row_copy", b, 0, "dense")
                for k in ks:
                    yield (d, prec, "draft", b, k, "dense")
                    yield (d, prec, "draft_packed", b, k, "dense")
    if not quick:
        for (m, phase, b, q) in PALLAS_SUBSET:
            yield (m, "f32", phase, b, q, "pallas")


def artifact_name(model, prec, phase, batch, q, attn):
    suffix = "_pallas" if attn == "pallas" else ""
    return f"{model}_{prec}_{phase}{q}_b{batch}{suffix}"


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _flat_weights(params):
    """Flatten params; returns (leaves, treedef, names, shape_dtype_specs)."""
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in paths]
    leaves, treedef = jax.tree_util.tree_flatten(params)
    specs = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
    return leaves, treedef, names, specs


def _cache_specs(cfg: ModelConfig, batch):
    shape = (batch, cfg.n_head, cfg.s_max, cfg.d_head)
    return [jax.ShapeDtypeStruct(shape, jnp.float32)] * (2 * cfg.n_layer)


def lower_artifact(cfg: ModelConfig, params, phase, batch, q, attn):
    """Lower one artifact; returns HLO text.

    Input order  : weights..., host tensors..., caches...
    Output order : head outputs..., caches...
    (cache buffers stay device-resident across steps in the Rust runtime).
    """
    _, treedef, _, wspecs = _flat_weights(params)
    i32, f32 = jnp.int32, jnp.float32

    if phase == "prefill":
        def fn(flat_w, tokens, prompt_lens):
            p = jax.tree_util.tree_unflatten(treedef, flat_w)
            return prefill(p, tokens, prompt_lens, cfg, attn)
        args = (wspecs, jax.ShapeDtypeStruct((batch, q), i32),
                jax.ShapeDtypeStruct((batch,), i32))
        jitted = jax.jit(fn)
    elif phase == "prefill_scatter":
        def fn(flat_w, tokens, prompt_lens, row, caches):
            p = jax.tree_util.tree_unflatten(treedef, flat_w)
            last, new_caches = prefill_scatter(p, tokens, prompt_lens, row,
                                               caches, cfg, attn)
            return (last, *new_caches)
        # One [1, P] prompt scattered into row `row` of a running fused
        # cache: the donated caches are (batch,)-shaped, everything else
        # is B=1 (the new sequence alone).
        args = (wspecs, jax.ShapeDtypeStruct((1, q), i32),
                jax.ShapeDtypeStruct((1,), i32),
                jax.ShapeDtypeStruct((1,), i32),
                _cache_specs(cfg, batch))
        jitted = jax.jit(fn, donate_argnums=(4,))
    elif phase == "kv_row_copy":
        # Weightless: a pure per-buffer slice + scatter over the donated
        # fused cache. src/dst are s32[1] batch rows; q is unused (0).
        def fn(src, dst, caches):
            return tuple(kv_row_copy(caches, src, dst))
        args = (jax.ShapeDtypeStruct((1,), i32),
                jax.ShapeDtypeStruct((1,), i32),
                _cache_specs(cfg, batch))
        jitted = jax.jit(fn, donate_argnums=(2,))
    elif phase == "decode":
        def fn(flat_w, tokens, seq_lens, caches):
            p = jax.tree_util.tree_unflatten(treedef, flat_w)
            return decode(p, tokens, seq_lens, caches, cfg, attn)
        args = (wspecs, jax.ShapeDtypeStruct((batch, q), i32),
                jax.ShapeDtypeStruct((batch,), i32),
                _cache_specs(cfg, batch))
        jitted = jax.jit(fn, donate_argnums=(3,))
    elif phase == "decode_packed":
        # One packed [1, C] token stream (C = batch·q capacity) addressed
        # by cumulative segment offsets; caches stay [B]-fused/donated.
        c_tok = batch * q

        def fn(flat_w, tokens, qoffs, seq_lens, caches):
            p = jax.tree_util.tree_unflatten(treedef, flat_w)
            return decode_packed(p, tokens, qoffs, seq_lens, caches, cfg,
                                 attn)
        args = (wspecs, jax.ShapeDtypeStruct((1, c_tok), i32),
                jax.ShapeDtypeStruct((batch + 1,), i32),
                jax.ShapeDtypeStruct((batch,), i32),
                _cache_specs(cfg, batch))
        jitted = jax.jit(fn, donate_argnums=(4,))
    elif phase == "draft":
        def fn(flat_w, tokens_in, n_in, seq_lens, uniforms, temp, top_p,
               caches):
            p = jax.tree_util.tree_unflatten(treedef, flat_w)
            toks, qdists, caches = draft_loop(
                p, tokens_in, n_in, seq_lens, caches, uniforms, temp, top_p,
                cfg, attn)
            return (toks, qdists, *caches)
        # temp/top_p are [B] per-row vectors: co-batched sequences from
        # different requests keep their own sampling params (the Rust
        # engine fills one entry per slot).
        args = (wspecs, jax.ShapeDtypeStruct((batch, 2), i32),
                jax.ShapeDtypeStruct((batch,), i32),
                jax.ShapeDtypeStruct((batch,), i32),
                jax.ShapeDtypeStruct((batch, q), f32),
                jax.ShapeDtypeStruct((batch,), f32),
                jax.ShapeDtypeStruct((batch,), f32),
                _cache_specs(cfg, batch))
        jitted = jax.jit(fn, donate_argnums=(7,))
    elif phase == "draft_packed":
        # Offset-addressed draft ABI: uniforms and outputs live in a
        # packed-prefix [B·K] layout indexed by koffs (see model.py).
        cu = batch * q

        def fn(flat_w, tokens_in, n_in, seq_lens, koffs, uniforms, temp,
               top_p, caches):
            p = jax.tree_util.tree_unflatten(treedef, flat_w)
            toks, qdists, caches = draft_packed(
                p, tokens_in, n_in, seq_lens, caches, koffs, uniforms,
                temp, top_p, q, cfg, attn)
            return (toks, qdists, *caches)
        args = (wspecs, jax.ShapeDtypeStruct((batch, 2), i32),
                jax.ShapeDtypeStruct((batch,), i32),
                jax.ShapeDtypeStruct((batch,), i32),
                jax.ShapeDtypeStruct((batch + 1,), i32),
                jax.ShapeDtypeStruct((cu,), f32),
                jax.ShapeDtypeStruct((batch,), f32),
                jax.ShapeDtypeStruct((batch,), f32),
                _cache_specs(cfg, batch))
        jitted = jax.jit(fn, donate_argnums=(8,))
    else:
        raise ValueError(phase)
    return to_hlo_text(jitted.lower(*args))


def lower_gemm_calib(n: int = 768) -> str:
    """A big square matmul used by the Rust runtime to calibrate peak
    FLOP/s for the Fig-1 utilization metric."""
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return to_hlo_text(jax.jit(lambda a, b: a @ b).lower(spec, spec))


# ---------------------------------------------------------------------------
# Weight I/O
# ---------------------------------------------------------------------------

def save_weights(out_dir, model_name, prec, params):
    leaves, _, names, _ = _flat_weights(params)
    tensors = [(n, np.asarray(l)) for n, l in zip(names, leaves)]
    path = os.path.join(out_dir, "weights", f"{model_name}_{prec}.bwt")
    bwt.write_bwt(path, tensors)
    return [{"name": n, "dtype": str(a.dtype), "shape": list(a.shape)}
            for n, a in tensors]


def params_to_npz(path, params):
    leaves, _, names, _ = _flat_weights(params)
    np.savez(path, **{n: np.asarray(l) for n, l in zip(names, leaves)})


def params_from_npz(path, cfg: ModelConfig, prec="f32"):
    """Rebuild the pytree from an npz (names encode the paths)."""
    from compile.model import init_params
    base = init_params(jax.random.PRNGKey(0), cfg)
    if prec == "int8":
        base = quantize_params(base)
    leaves, treedef = jax.tree_util.tree_flatten(base)
    _, _, names, _ = _flat_weights(base)
    data = np.load(path)
    new = [jnp.asarray(data[n]) for n in names]
    return jax.tree_util.tree_unflatten(treedef, new)


# ---------------------------------------------------------------------------
# Main driver
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny grid + tiny training, for CI/tests")
    ap.add_argument("--steps-main", type=int, default=350)
    ap.add_argument("--steps-draft", type=int, default=300)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out = args.out
    for sub in ["hlo", "weights", "tasks", "results"]:
        os.makedirs(os.path.join(out, sub), exist_ok=True)

    t_start = time.time()
    corpus, code_p, summ_p = build_corpus()
    write_tasks(os.path.join(out, "tasks"), code_p, summ_p)
    with open(os.path.join(out, "tasks", "corpus_stats.json"), "w") as f:
        json.dump({"bytes": len(corpus), "code_tasks": len(code_p),
                   "summ_tasks": len(summ_p)}, f)

    # ---- train (or reuse) --------------------------------------------------
    train_log = {}
    model_names = [MAIN] + (["draft_a"] if args.quick else DRAFTS)
    params_by_model = {}
    for name in model_names:
        cfg = CONFIGS[name]
        npz = os.path.join(out, "weights", f"{name}.npz")
        if os.path.exists(npz) and not args.force:
            print(f"[aot] reusing trained weights for {name}")
            params_by_model[name] = params_from_npz(npz, cfg)
            continue
        steps = args.steps_main if name == MAIN else args.steps_draft
        if args.quick:
            steps = 5
        tc = TrainConfig(steps=steps)
        params, hist = train_model(cfg, corpus, tc)
        params_by_model[name] = params
        train_log[name] = {
            "steps": steps, "history": hist,
            "held_out_loss": held_out_loss(params, cfg, corpus, tc),
            "params": cfg.param_count(params),
        }
        params_to_npz(npz, params)
    if train_log:
        with open(os.path.join(out, "weights", "train_log.json"), "w") as f:
            json.dump(train_log, f, indent=1)

    # ---- weights (.bwt per precision) --------------------------------------
    weight_manifest = {}
    for name, params in params_by_model.items():
        weight_manifest[name] = {}
        for prec in PRECISIONS[name]:
            p = params if prec == "f32" else quantize_params(params)
            weight_manifest[name][prec] = save_weights(out, name, prec, p)

    # ---- HLO artifacts ------------------------------------------------------
    artifacts = []
    n_done = 0
    for (model, prec, phase, b, q, attn) in grid(args.quick):
        name = artifact_name(model, prec, phase, b, q, attn)
        path = os.path.join(out, "hlo", name + ".hlo.txt")
        rec = {"file": f"hlo/{name}.hlo.txt", "model": model,
               "precision": prec, "phase": phase, "batch": b, "q": q,
               "attn": attn}
        artifacts.append(rec)
        if os.path.exists(path) and not args.force:
            continue
        cfg = CONFIGS[model]
        params = params_by_model[model]
        p = params if prec == "f32" else quantize_params(params)
        t0 = time.time()
        text = lower_artifact(cfg, p, phase, b, q, attn)
        with open(path, "w") as f:
            f.write(text)
        n_done += 1
        print(f"[aot] {name}: {len(text) / 1e6:.2f} MB in "
              f"{time.time() - t0:.1f}s")

    calib_path = os.path.join(out, "hlo", "gemm_calib.hlo.txt")
    calib_n = 768
    if not os.path.exists(calib_path) or args.force:
        with open(calib_path, "w") as f:
            f.write(lower_gemm_calib(calib_n))

    # ---- manifest -----------------------------------------------------------
    manifest = {
        # v5: adds per-bucket kv_row_copy artifacts (prompt-prefix KV
        # reuse: fan-out prefill sharing + the coordinator prefix cache);
        # v4 added packed-segment decode_packed / draft_packed artifacts
        # (ExecMode::Packed, offset-addressed ragged ABI); v3 added
        # per-row prefill_scatter (PAD mid-flight admission); v2 made
        # draft temperature/top_p [B] per-row vectors.
        # Must match rust/src/runtime/manifest.rs::MANIFEST_VERSION.
        "version": 5,
        "vocab": 256,
        "eos": 0,
        "prefill_p": PREFILL_P,
        "draft_k_buckets": DRAFT_K_BUCKETS,
        "small_k_buckets": SMALL_K_BUCKETS,
        "batches": BATCHES if not args.quick else [1, 2],
        "models": {
            name: {
                "n_layer": CONFIGS[name].n_layer,
                "n_head": CONFIGS[name].n_head,
                "d_model": CONFIGS[name].d_model,
                "d_ff": CONFIGS[name].d_ff,
                "s_max": CONFIGS[name].s_max,
                "d_head": CONFIGS[name].d_head,
                "param_count": CONFIGS[name].param_count(
                    params_by_model[name]),
                "weights": {prec: f"weights/{name}_{prec}.bwt"
                            for prec in PRECISIONS[name]},
                "weight_tensors": weight_manifest[name],
            } for name in params_by_model
        },
        "artifacts": artifacts,
        "calib": {"file": "hlo/gemm_calib.hlo.txt", "n": calib_n,
                  "flops": 2 * calib_n ** 3},
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] exported {n_done} new artifacts "
          f"({len(artifacts)} total) in {time.time() - t_start:.0f}s")


if __name__ == "__main__":
    main()
