"""Layer-1 Pallas kernels: BASS ragged-attention.

The paper's kernel contribution (§3.2, Figure 4) is attention over *ragged*
K/V/P tensors: after batched speculative verification, every sequence in the
batch has its own length, so Q·Kᵀ, softmax and P·V cannot assume one
rectangular sequence dimension. BASS-PAD pads K/V/P to the batch max and
zeroes the probabilities of pad tokens; BASS-SPLIT launches per-sequence
kernels.

TPU/Pallas adaptation (DESIGN.md §6): the CUDA per-(batch,head) threadblock
becomes a Pallas grid cell ``(b, h)``; the sequence dimension is streamed
through VMEM in ``S_BLK``-sized tiles with a flash-attention running
max/denominator, and raggedness is enforced with in-register iota masks —
BASS-PAD's "zero probability for padded tokens" costs masked vector lanes,
not extra HBM traffic. The QKᵀ and PV contractions are MXU-shaped
``jnp.dot`` calls with f32 accumulation.

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO so the same
module is loadable by the Rust runtime. Real-TPU resource estimates live in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sequence tile streamed through VMEM per grid cell. 128 lanes matches the
# TPU vector-register width; with Dh ≤ 64 a (S_BLK, Dh) f32 tile is ≤ 32 KiB.
DEFAULT_S_BLK = 128

NEG_INF = -1e30


def _attention_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, s_blk: int,
                      scale: float):
    """One (batch, head) grid cell of BASS-PAD ragged attention.

    Block shapes (leading singleton dims dropped by BlockSpec):
      len_ref: ()        int32   — tokens already in the cache for this seq
      q_ref:   (Q, Dh)           — the Q new (draft/verify) token queries
      k_ref:   (S, Dh)           — padded key cache (S = batch max capacity)
      v_ref:   (S, Dh)           — padded value cache
      o_ref:   (Q, Dh)           — attention output

    Query row j may attend cache positions < len + j + 1 (its own K/V has
    already been appended at position len + j). Positions ≥ len + Q are pad:
    they receive zero probability, exactly the BASS-PAD contract.
    """
    q_len, d_head = q_ref.shape
    s_max = k_ref.shape[0]
    n_blocks = s_max // s_blk

    seq_len = len_ref[0]
    q = q_ref[...].astype(jnp.float32) * scale
    # Row j attends strictly below this bound.
    row_bound = seq_len + 1 + jax.lax.broadcasted_iota(jnp.int32, (q_len, 1), 0)

    def body(blk, carry):
        m_prev, l_prev, acc_prev = carry
        start = blk * s_blk
        k_blk = k_ref[pl.dslice(start, s_blk), :].astype(jnp.float32)
        v_blk = v_ref[pl.dslice(start, s_blk), :].astype(jnp.float32)
        # (Q, S_BLK) MXU contraction.
        scores = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        col = start + jax.lax.broadcasted_iota(jnp.int32, (1, s_blk), 1)
        scores = jnp.where(col < row_bound, scores, NEG_INF)
        # Flash-style running softmax.
        m_cur = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
        correction = jnp.exp(m_prev - m_cur)
        p = jnp.exp(scores - m_cur)
        l_cur = l_prev * correction + jnp.sum(p, axis=1, keepdims=True)
        acc_cur = acc_prev * correction + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_cur, l_cur, acc_cur

    m0 = jnp.full((q_len, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_len, 1), jnp.float32)
    acc0 = jnp.zeros((q_len, d_head), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("s_blk",))
def ragged_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            seq_lens: jax.Array,
                            s_blk: int = DEFAULT_S_BLK) -> jax.Array:
    """BASS-PAD ragged attention over a padded KV cache.

    Args:
      q: ``(B, H, Q, Dh)`` queries for the Q newly appended tokens.
      k: ``(B, H, S, Dh)`` padded key cache; positions ``seq_lens[b] + j``
        hold the new tokens' keys.
      v: ``(B, H, S, Dh)`` padded value cache.
      seq_lens: ``(B,)`` int32 — per-sequence token counts *before* the Q
        new tokens were appended (the ragged lengths).
      s_blk: VMEM tile along the sequence dimension; must divide S.

    Returns:
      ``(B, H, Q, Dh)`` attention outputs, same dtype as ``q``.
    """
    b, h, q_len, d_head = q.shape
    s_max = k.shape[2]
    if s_max % s_blk != 0:
        raise ValueError(f"S={s_max} not divisible by s_blk={s_blk}")
    if k.shape != (b, h, s_max, d_head) or v.shape != k.shape:
        raise ValueError(f"bad kv shapes {k.shape} {v.shape}")
    scale = 1.0 / (d_head ** 0.5)
    kernel = functools.partial(_attention_kernel, s_blk=s_blk, scale=scale)
    grid = (b, h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((None, None, q_len, d_head), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s_max, d_head), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s_max, d_head), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, q_len, d_head),
                               lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, q_len, d_head), q.dtype),
        interpret=True,
    )(seq_lens, q, k, v)


def ragged_prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             s_blk: int = DEFAULT_S_BLK) -> jax.Array:
    """Causal self-attention for the prefill phase.

    Prefill is the ``seq_lens = 0`` special case of the decode kernel: query
    row j attends cache positions ``0..j``. Pad rows beyond a sequence's
    prompt length produce garbage that the model discards (their K/V slots
    are overwritten as generation appends real tokens).
    """
    b = q.shape[0]
    zeros = jnp.zeros((b,), jnp.int32)
    return ragged_decode_attention(q, k, v, zeros, s_blk=s_blk)


def packed_segment_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             seq_lens: jax.Array, qoffs: jax.Array,
                             window: int, attn=None) -> jax.Array:
    """BASS-packed ragged attention over a packed query stream.

    The packed exec mode lays the batch's ragged rows back-to-back in one
    ``C``-token stream (row i occupies ``qoffs[i]:qoffs[i+1]``; the tail
    beyond ``qoffs[B]`` is filler). Attention still needs per-row query
    blocks against per-row cache rows, so each segment is realized as a
    fixed window of the stream: ``seg_q[i, :, j] = q[:, qoffs[i] + j]``
    for ``j < window``; window positions past a row's real length are
    garbage and are discarded on scatter-back. ``window`` is a static
    *global* per-row length bound (max draft bucket + 1), NOT the batch
    max — the packed dense stream, not this gather, is where the
    pad-FLOP saving lives; the gather merely lets the existing ragged
    kernel run completely unchanged.

    Per-query flash accumulation never mixes query rows, so every valid
    packed position is bitwise-identical to the same query under the
    rectangular BASS-PAD launch.

    Args:
      q: ``(H, C, Dh)`` packed queries.
      k, v: ``(B, H, S, Dh)`` caches (new tokens already appended).
      seq_lens: ``(B,)`` pre-append lengths.
      qoffs: ``(B+1,)`` cumulative segment offsets, ``qoffs[0] = 0``.
      window: static per-row length bound (must be ``>= max_i q_i``).
      attn: ``(q, k, v, seq_lens) -> out`` callable; defaults to the
        Pallas kernel.

    Returns:
      ``(H, C, Dh)``: ``out[:, t]`` is the attention output for packed
      token t; filler positions hold garbage.
    """
    if attn is None:
        attn = ragged_decode_attention
    h, c, d_head = q.shape
    b = seq_lens.shape[0]
    w = min(window, c)
    gather = jnp.clip(qoffs[:-1, None] + jnp.arange(w)[None, :], 0, c - 1)
    seg_q = jnp.take(q, gather.reshape(-1), axis=1)
    seg_q = seg_q.reshape(h, b, w, d_head).transpose(1, 0, 2, 3)
    seg_out = attn(seg_q, k, v, seq_lens)                 # (B, H, W, Dh)
    t_idx = jnp.arange(c)
    rid = jnp.sum((t_idx[:, None] >= qoffs[None, 1:]).astype(jnp.int32),
                  axis=1)
    rid_c = jnp.clip(rid, 0, b - 1)
    pos = jnp.clip(t_idx - qoffs[rid_c], 0, w - 1)
    out = seg_out[rid_c, :, pos, :]                       # (C, H, Dh)
    return out.transpose(1, 0, 2)


def split_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           seq_lens: jax.Array,
                           s_blk: int = DEFAULT_S_BLK) -> jax.Array:
    """BASS-SPLIT ragged attention: one kernel launch per sequence.

    Mirrors Figure 4(c): the batch dimension is peeled and each sequence
    gets its own ``pallas_call`` (B=1), so no pad lanes are computed at the
    cost of B kernel launches. On the serving path the Rust coordinator
    realizes SPLIT as per-sequence *executables* dispatched concurrently
    (DESIGN.md §6); this in-graph variant exists for kernel-level parity
    tests and the Table 6 microbenchmarks.
    """
    b = q.shape[0]
    outs = [
        ragged_decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                seq_lens[i:i + 1], s_blk=s_blk)
        for i in range(b)
    ]
    return jnp.concatenate(outs, axis=0)
