"""Layer-1 Pallas kernels for BASS ragged attention."""

from compile.kernels.ragged_attention import (  # noqa: F401
    packed_segment_attention,
    ragged_decode_attention,
    ragged_prefill_attention,
    split_decode_attention,
)
from compile.kernels.ref import (  # noqa: F401
    ragged_decode_attention_ref,
    ragged_prefill_attention_ref,
)
