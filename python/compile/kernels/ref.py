"""Pure-jnp oracle for the BASS ragged-attention kernels.

This is the correctness contract for Layer 1: an explicit-mask softmax
attention with no tiling, no running statistics and no Pallas. pytest
(``python/tests/test_kernel.py``) asserts the Pallas kernels match this
oracle across shapes, dtypes and ragged length patterns.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ragged_decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                                seq_lens: jax.Array) -> jax.Array:
    """Reference BASS-PAD attention. Shapes as in the Pallas kernel.

    Query row j of sequence b attends cache positions < seq_lens[b] + j + 1;
    everything else (the pad region) gets exactly zero probability.
    """
    b, h, q_len, d_head = q.shape
    s_max = k.shape[2]
    scale = 1.0 / (d_head ** 0.5)
    scores = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    row = jnp.arange(q_len)[None, :, None]            # (1, Q, 1)
    col = jnp.arange(s_max)[None, None, :]            # (1, 1, S)
    bound = seq_lens[:, None, None] + row + 1         # (B, Q, 1)
    mask = col < bound                                # (B, Q, S)
    scores = jnp.where(mask[:, None, :, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask[:, None, :, :], p, 0.0)        # exact-zero pad prob
    out = jnp.einsum("bhqs,bhsd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ragged_prefill_attention_ref(q: jax.Array, k: jax.Array,
                                 v: jax.Array) -> jax.Array:
    """Causal prefill reference: the seq_lens = 0 case."""
    zeros = jnp.zeros((q.shape[0],), jnp.int32)
    return ragged_decode_attention_ref(q, k, v, zeros)
