"""INT8 weight-only quantization (paper Appendix A.1 analog).

The paper fuses CUTLASS INT8 GEMMs with dequantize epilogues; the XLA
analog is storing weights as ``int8`` plus per-output-channel f32 scales and
dequantizing *in-graph* — XLA fuses the ``convert × scale`` into the
consuming GEMM, so the weight memory traffic (the decode bottleneck, §2.1)
halves while logits move only slightly. Granularity matches the paper: the
quantization range is per inner-product dimension (per output channel).

A quantized leaf is a dict ``{"q": int8[..., n], "s": f32[n]}``; model code
calls :func:`maybe_dequant` at every weight use so the same forward works
for both precisions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_tensor(w: jax.Array) -> dict:
    """Symmetric per-output-channel int8 quantization of a [..., n] weight."""
    amax = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def maybe_dequant(p) -> jax.Array:
    """Return the f32 view of a (possibly quantized) weight leaf."""
    if isinstance(p, dict) and "q" in p:
        return p["q"].astype(jnp.float32) * p["s"]
    return p


def quantize_params(params) -> dict:
    """Quantize every 2-D weight matrix; keep vectors/norms in f32.

    Embedding and position tables stay f32 as well (they are gathers, not
    GEMMs — quantizing them saves little and hurts the tied LM head).
    """
    def walk(p, path=()):
        if isinstance(p, dict):
            return {k: walk(v, path + (k,)) for k, v in p.items()}
        if isinstance(p, list):
            return [walk(v, path + (str(i),)) for i, v in enumerate(p)]
        if p.ndim == 2 and path[-1] == "w":
            return quantize_tensor(p)
        return p

    return walk(params)


def dequantize_params(qparams):
    """Materialize an f32 pytree (for tests / accuracy deltas)."""
    def walk(p):
        if isinstance(p, dict) and "q" in p:
            return maybe_dequant(p)
        if isinstance(p, dict):
            return {k: walk(v) for k, v in p.items()}
        if isinstance(p, list):
            return [walk(v) for v in p]
        return p

    return walk(qparams)
