"""Layer-2 JAX model: decoder-only transformer with a ragged KV cache.

This is the compute graph that BASS coordinates. Two inference entry points
are AOT-exported per model (see ``aot.py``):

  * ``prefill``  — context encoding of the prompt batch (paper §2.1 phase a);
  * ``decode``   — incremental/speculative step over ``Q`` new tokens per
    sequence at per-sequence offsets ``seq_lens`` (phase b; for the main
    model ``Q = draft_len + 1`` verification, for drafts ``Q = 1``
    auto-regressive drafting). Raggedness is carried by ``seq_lens`` and
    resolved inside the Layer-1 Pallas attention kernel (BASS-PAD).

The cache is one tensor ``f32[L, 2, B, H, S, Dh]`` so the Rust runtime can
keep it as a single device-resident PJRT buffer fed back step to step.

Training uses a dense-attention path (``lm_loss``) — Pallas interpret mode
is needless overhead under autodiff; pytest asserts the dense and Pallas
paths agree (``test_model.py``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict

import jax
import jax.numpy as jnp

from compile.kernels import (packed_segment_attention,
                             ragged_decode_attention)
from compile.kernels.ref import ragged_decode_attention_ref
from compile.quant import maybe_dequant

VOCAB = 256  # byte-level


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (paper Table 4 analog grid)."""
    name: str
    n_layer: int
    n_head: int
    d_model: int
    d_ff: int
    s_max: int = 256      # padded KV capacity (BASS-PAD max length)
    p_max: int = 64       # prefill prompt capacity
    vocab: int = VOCAB

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    def cache_shape(self, batch: int) -> tuple:
        return (self.n_layer, 2, batch, self.n_head, self.s_max, self.d_head)

    def param_count(self, params=None) -> int:
        if params is None:
            params = init_params(jax.random.PRNGKey(0), self)
        return sum(int(jnp.size(x)) for x in jax.tree_util.tree_leaves(params))


# The model zoo: one "main" model and the three draft variants of Table 4
# (A shallow-wide, B deeper, C wider) at ~1:7 / 1:4 / 1:2 parameter ratios.
CONFIGS: Dict[str, ModelConfig] = {
    "main": ModelConfig("main", n_layer=4, n_head=8, d_model=256, d_ff=1024),
    "draft_a": ModelConfig("draft_a", n_layer=2, n_head=4, d_model=128,
                           d_ff=512),
    "draft_b": ModelConfig("draft_b", n_layer=4, n_head=4, d_model=128,
                           d_ff=512),
    "draft_c": ModelConfig("draft_c", n_layer=2, n_head=8, d_model=256,
                           d_ff=1024),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    """GPT-2-style init. LM head is tied to the token embedding."""
    d, ff = cfg.d_model, cfg.d_ff
    std = 0.02

    def dense(k, n_in, n_out):
        return {"w": jax.random.normal(k, (n_in, n_out), jnp.float32) * std,
                "b": jnp.zeros((n_out,), jnp.float32)}

    keys = jax.random.split(key, 2 + 4 * cfg.n_layer)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, d), jnp.float32) * std,
        "pos": jax.random.normal(keys[1], (cfg.s_max, d), jnp.float32) * std,
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "blocks": [],
    }
    for l in range(cfg.n_layer):
        k0, k1, k2, k3 = keys[2 + 4 * l: 6 + 4 * l]
        params["blocks"].append({
            "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "qkv": dense(k0, d, 3 * d),
            "proj": dense(k1, d, d),
            "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "fc": dense(k2, d, ff),
            "out": dense(k3, ff, d),
        })
    return params


def _ln(x, p):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * p["g"] + p["b"]


def _dense(x, p):
    return x @ maybe_dequant(p["w"]) + p["b"]


def _split_heads(x, n_head):  # [B,T,D] -> [B,H,T,Dh]
    b, t, d = x.shape
    return x.reshape(b, t, n_head, d // n_head).transpose(0, 2, 1, 3)


def _merge_heads(x):  # [B,H,T,Dh] -> [B,T,D]
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


# ---------------------------------------------------------------------------
# Ragged cache write (the "incremental context encoding" of §2.2)
# ---------------------------------------------------------------------------
#
# The KV cache is a flat list ``[k_0, v_0, k_1, v_1, ...]`` of per-layer
# ``f32[B, H, S, Dh]`` tensors rather than one stacked tensor: each tensor is
# then an independent PJRT buffer that the Rust runtime feeds back
# device-resident between steps, and XLA can update each in place under
# donation (jnp.stack would force a full-cache copy every step — measured 2-5×
# slower on the steady-state path; see EXPERIMENTS.md §Perf).

def cache_spec(cfg: "ModelConfig", batch: int):
    """Shapes of the per-layer cache buffers, in artifact I/O order."""
    shape = (batch, cfg.n_head, cfg.s_max, cfg.d_head)
    return [shape] * (2 * cfg.n_layer)


def init_cache(cfg: "ModelConfig", batch: int):
    return [jnp.zeros(s, jnp.float32) for s in cache_spec(cfg, batch)]


def _append_kv(cache_k, cache_v, k_new, v_new, seq_lens):
    """Write K/V for Q new tokens at per-sequence offsets.

    cache_k/v: [B,H,S,Dh]; k_new/v_new: [B,H,Q,Dh]; seq_lens: [B].
    A vmap'd dynamic_update_slice lowers to a batched scatter — the XLA
    analog of the per-sequence pointer arithmetic in the paper's CUDA cache
    append.
    """
    def upd(c, n, start):
        return jax.lax.dynamic_update_slice(c, n, (0, start, 0))
    ck = jax.vmap(upd)(cache_k, k_new, seq_lens)
    cv = jax.vmap(upd)(cache_v, v_new, seq_lens)
    return ck, cv


def _dense_ragged_attention(q, k, v, seq_lens):
    """jnp BASS-PAD attention (same contract as the Pallas kernel).

    Used by the training path and as the ``dense`` attention variant of the
    exported artifacts (DESIGN.md §6: BASS-PAD *is* pad+mask; this is the
    XLA-fused realization, the Pallas kernel is the explicitly-tiled one).
    """
    return ragged_decode_attention_ref(q, k, v, seq_lens)


ATTN_IMPLS = {
    "pallas": ragged_decode_attention,
    "dense": _dense_ragged_attention,
}


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def decode(params, tokens, seq_lens, caches, cfg: ModelConfig,
           attn_impl: str = "pallas"):
    """Process Q new tokens per sequence against a ragged cache.

    Args:
      tokens: int32[B, Q] — for the main model, ``[last_accepted, d_1..d_k]``
        (verification); for drafts, the resync/draft tokens.
      seq_lens: int32[B] — tokens already in each sequence's cache.
      caches: list ``[k_0, v_0, ...]`` of f32[B, H, S, Dh].

    Returns:
      (logits f32[B, Q, V], new_caches). ``logits[:, j]`` is the next-token
      distribution after consuming ``tokens[:, j]``.
    """
    attn = ATTN_IMPLS[attn_impl]
    b, q_len = tokens.shape
    pos_ids = seq_lens[:, None] + jnp.arange(q_len)[None, :]      # [B,Q]
    x = maybe_dequant(params["embed"])[tokens] + \
        maybe_dequant(params["pos"])[pos_ids]

    new_caches = []
    for l, blk in enumerate(params["blocks"]):
        h = _ln(x, blk["ln1"])
        qkv = _dense(h, blk["qkv"])
        qh, kh, vh = jnp.split(qkv, 3, axis=-1)
        qh = _split_heads(qh, cfg.n_head)
        kh = _split_heads(kh, cfg.n_head)
        vh = _split_heads(vh, cfg.n_head)
        ck, cv = _append_kv(caches[2 * l], caches[2 * l + 1], kh, vh,
                            seq_lens)
        attn_out = attn(qh, ck, cv, seq_lens)
        x = x + _dense(_merge_heads(attn_out), blk["proj"])
        h2 = _ln(x, blk["ln2"])
        x = x + _dense(jax.nn.gelu(_dense(h2, blk["fc"])), blk["out"])
        new_caches += [ck, cv]

    x = _ln(x, params["ln_f"])
    logits = x @ maybe_dequant(params["embed"]).T                  # tied head
    return logits, new_caches


# Static global per-row length bound of the packed exec mode: the largest
# draft bucket (aot.DRAFT_K_BUCKETS) plus the bonus-token query. Every
# packed segment is at most this long, so the attention gather window is
# independent of the batch's max row — the raggedness lives entirely in
# the packed token stream.
PACKED_WINDOW = 17


def decode_packed(params, tokens, qoffs, seq_lens, caches, cfg: ModelConfig,
                  attn_impl: str = "pallas"):
    """Process a packed ragged batch of Σq_i new tokens in one launch.

    BASS-packed exec mode: instead of PAD's rectangular ``[B, Q_launch]``
    token block, the batch's variable-length rows are laid back-to-back in
    one ``[1, C]`` token stream (``C`` = capacity bucket ≥ Σq_i; the tail
    beyond ``qoffs[B]`` is filler). All dense work — embeddings, layer
    norms, GEMMs, the LM head — runs on the packed stream, i.e. over C
    tokens instead of B·Q_launch, which is where the pad-FLOP saving
    physically lives. Attention realizes each segment as a window of the
    stream (``packed_segment_attention``) and reuses the unchanged ragged
    kernel.

    Args:
      tokens: int32[1, C] — row i occupies ``tokens[0, qoffs[i]:qoffs[i+1]]``.
      qoffs: int32[B+1] cumulative offsets (``qoffs[0] = 0``, monotone,
        ``qoffs[B] = Σq_i ≤ C``).
      seq_lens: int32[B]; caches: ``[k_0, v_0, ...]`` of f32[B, H, S, Dh] —
        same contracts as ``decode``.

    Returns:
      (logits f32[1, C, V], new_caches). ``logits[0, qoffs[i] + j]`` is
      row i's next-token distribution after consuming its token j; filler
      positions hold garbage. Valid positions are bitwise-identical to
      ``decode``'s: per-token dense ops and per-query flash accumulation
      are independent of the batch reshape, and each row's K/V land at
      the same cache coordinates (PAD additionally writes garbage beyond
      a row's real length — positions the attention bound never reads
      and the next step overwrites).
    """
    attn = ATTN_IMPLS[attn_impl]
    _, c_tok = tokens.shape
    b = seq_lens.shape[0]
    t_idx = jnp.arange(c_tok)
    # rid[t] = segment owning packed position t; filler tokens get B.
    rid = jnp.sum((t_idx[:, None] >= qoffs[None, 1:]).astype(jnp.int32),
                  axis=1)
    real = rid < b
    rid_c = jnp.clip(rid, 0, b - 1)
    pos_in_seg = t_idx - qoffs[rid_c]
    pos_ids = jnp.where(real, seq_lens[rid_c] + pos_in_seg, 0)
    x = maybe_dequant(params["embed"])[tokens] + \
        maybe_dequant(params["pos"])[pos_ids][None]

    # Scatter coordinates for the per-token KV append; filler tokens
    # target the out-of-bounds batch row B and are dropped.
    rid_w = jnp.where(real, rid_c, b)
    pos_w = jnp.where(real, seq_lens[rid_c] + pos_in_seg, 0)
    head_ids = jnp.arange(cfg.n_head)

    new_caches = []
    for l, blk in enumerate(params["blocks"]):
        h = _ln(x, blk["ln1"])
        qkv = _dense(h, blk["qkv"])
        qh, kh, vh = jnp.split(qkv, 3, axis=-1)
        qh = _split_heads(qh, cfg.n_head)                 # [1, H, C, Dh]
        kh = _split_heads(kh, cfg.n_head)
        vh = _split_heads(vh, cfg.n_head)
        k_tok = kh[0].transpose(1, 0, 2)                  # [C, H, Dh]
        v_tok = vh[0].transpose(1, 0, 2)
        ck = caches[2 * l].at[
            rid_w[:, None], head_ids[None, :], pos_w[:, None]].set(
            k_tok, mode="drop")
        cv = caches[2 * l + 1].at[
            rid_w[:, None], head_ids[None, :], pos_w[:, None]].set(
            v_tok, mode="drop")
        seg = packed_segment_attention(qh[0], ck, cv, seq_lens, qoffs,
                                       min(PACKED_WINDOW, c_tok), attn=attn)
        attn_tok = seg.transpose(1, 0, 2).reshape(c_tok, -1)
        x = x + _dense(attn_tok[None], blk["proj"])
        h2 = _ln(x, blk["ln2"])
        x = x + _dense(jax.nn.gelu(_dense(h2, blk["fc"])), blk["out"])
        new_caches += [ck, cv]

    x = _ln(x, params["ln_f"])
    logits = x @ maybe_dequant(params["embed"]).T                  # tied head
    return logits, new_caches


def prefill(params, tokens, prompt_lens, cfg: ModelConfig,
            attn_impl: str = "pallas"):
    """Context-encode a prompt batch into a fresh ragged cache.

    tokens: int32[B, P] right-padded prompts; prompt_lens: int32[B].
    Returns (last_logits f32[B, V], caches). ``last_logits[b]`` is the
    distribution after the final real prompt token of sequence b.

    Convention (see rust/src/spec/engine.rs): the engine sets the post-
    prefill cache length to ``prompt_len - 1`` and carries the final prompt
    token as the pending input ``t0`` of the first speculative step — its
    K/V is simply rewritten with identical values, which keeps every step's
    "one pending token" invariant uniform.
    """
    b, p_len = tokens.shape
    caches = init_cache(cfg, b)
    zeros = jnp.zeros((b,), jnp.int32)
    logits, caches = decode(params, tokens, zeros, caches, cfg, attn_impl)
    idx = jnp.clip(prompt_lens - 1, 0, p_len - 1)
    last = logits[jnp.arange(b), idx]
    return last, caches


def prefill_scatter(params, tokens, prompt_lens, row, caches,
                    cfg: ModelConfig, attn_impl: str = "pallas"):
    """Prefill ONE sequence and scatter its KV into row ``row`` of an
    existing fused cache, leaving every other row untouched.

    This is the per-row prefill that lets BASS-PAD admit a request
    mid-flight: a retired (husk) or padding (shadow) row of a *running*
    fused batch is re-primed with a fresh prompt without draining the
    batch — the continuous-batching move SPLIT mode always had via its
    per-slot B=1 prefill.

    Args:
      tokens: int32[1, P] right-padded prompt; prompt_lens: int32[1].
      row: int32[1] — the batch row of ``caches`` to overwrite.
      caches: fused cache list ``[k_0, v_0, ...]`` of f32[B, H, S, Dh]
        (donated in the exported artifact, like ``decode``).

    Returns (last_logits f32[1, V], new_caches). The entire [H, S, Dh]
    row is replaced — fresh KV through the prompt, zeros beyond — so no
    stale entries from the row's previous occupant survive; all other
    rows are element-identical to their inputs. The row's first decode
    step then rewrites the final prompt token's KV in place, identically,
    per the ``prefill`` pending-token convention.
    """
    last, fresh = prefill(params, tokens, prompt_lens, cfg, attn_impl)
    r = row[0]
    new_caches = [jax.lax.dynamic_update_slice(c, f, (r, 0, 0, 0))
                  for c, f in zip(caches, fresh)]
    return last, new_caches


def kv_row_copy(caches, src, dst):
    """Copy one row's full ``[H, S, Dh]`` KV slab onto another row of the
    same fused cache, leaving every other row untouched.

    Strictly simpler than ``prefill_scatter``: no weights, no forward
    pass — a pure slice + scatter per cache buffer. This is the device
    primitive behind prompt-prefix KV reuse (fan-out prefill sharing and
    the coordinator's prefix cache): because KV at position ``i`` is a
    pure function of tokens ``0..i`` (the recompute-resume soundness
    argument, ``test_resume_recompute_*``), a copied row is bitwise what
    a fresh prefill of the same context would have produced — including
    the zero tail when the donor row is itself freshly prefilled.

    Args:
      caches: fused cache list ``[k_0, v_0, ...]`` of f32[B, H, S, Dh]
        (donated in the exported artifact, like ``prefill_scatter``).
      src, dst: int32[1] batch rows (donor, destination).

    Returns new_caches with row ``dst`` of every buffer replaced by row
    ``src``; all other rows element-identical to their inputs. ``src ==
    dst`` is the identity.
    """
    s, d = src[0], dst[0]
    new_caches = []
    for c in caches:
        slab = jax.lax.dynamic_slice(c, (s, 0, 0, 0), (1,) + c.shape[1:])
        new_caches.append(
            jax.lax.dynamic_update_slice(c, slab, (d, 0, 0, 0)))
    return new_caches


# ---------------------------------------------------------------------------
# In-graph nucleus sampling + the fused draft loop
# ---------------------------------------------------------------------------

def sample_top_p(logits, u, temperature, top_p):
    """Temperature + nucleus warp, then CDF-inversion sampling.

    Args:
      logits: f32[B, V]; u: f32[B] uniforms in [0,1);
      temperature, top_p: f32[B] **per-row** sampling params (scalars are
        broadcast) — co-batched sequences from different serving requests
        keep their own knobs inside one fused draft call.

    Returns (tokens i32[B], warped f32[B, V]) where ``warped`` is the
    renormalized post-top-p distribution — the q(x) the speculative
    accept/reject rule needs (rust/src/sampling.rs implements the identical
    warp for the main model so the composed distribution is exact).

    The nucleus is defined *value-wise*: token i is kept iff the total mass
    of strictly-more-probable tokens is < top_p (ties are all kept). This
    avoids ``lax.top_k``, whose modern ``topk(..., largest=true)`` HLO the
    image's XLA 0.5.1 text parser cannot read, and is O(V²) with V = 256 —
    negligible. The top-1 token is always kept.
    """
    b, v = logits.shape
    t = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (b,))
    tp = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (b,))
    probs = jax.nn.softmax(logits / jnp.maximum(t, 1e-4)[:, None], axis=-1)
    # mass_before[b, i] = sum of probs strictly greater than probs[b, i].
    # Deliberately O(V²): at V = 256 the vectorized compare+sum beats a
    # sort-based O(V log V) cutoff on CPU XLA by ~12% per draft step
    # (measured; EXPERIMENTS.md §Perf #5), and `lax.top_k` is unusable —
    # its modern `topk(..., largest=true)` HLO breaks the runtime's
    # XLA 0.5.1 text parser.
    gt = probs[:, None, :] > probs[:, :, None]                    # [B, i, j]
    mass_before = jnp.sum(jnp.where(gt, probs[:, None, :], 0.0), axis=-1)
    keep = mass_before < tp[:, None]
    filt = jnp.where(keep, probs, 0.0)
    warped = filt / jnp.sum(filt, -1, keepdims=True)
    cdf = jnp.cumsum(warped, axis=-1)
    # First index with cdf > u (u scaled down a hair to dodge the fp edge).
    u = (u * (1.0 - 1e-6))[:, None]
    tokens = jnp.argmax(cdf > u, axis=-1).astype(jnp.int32)
    return tokens, warped


def draft_loop(params, tokens_in, n_in, seq_lens, caches, uniforms,
               temperature, top_p, cfg: ModelConfig,
               attn_impl: str = "pallas"):
    """One fused drafting call: resync + K auto-regressive draft steps.

    This is the testbed analog of the paper's cheap draft phase: running the
    whole draft loop inside one XLA program amortizes the per-launch cost
    exactly the way GPU speculative decoding amortizes weight I/O (DESIGN.md
    §1). Sampling (temperature + top-p) happens in-graph from host-supplied
    uniforms, so Python stays off the request path and Rust stays in charge
    of randomness.

    Args:
      tokens_in: i32[B, 2] — the 1 or 2 tokens the draft must ingest to
        catch up with the verified stream (last accepted/corrected token;
        two when the previous step accepted the whole draft and added a
        bonus token). Slot 1 is ignored where ``n_in == 1``.
      n_in: i32[B] in {1, 2}.
      seq_lens: i32[B] — valid draft-cache lengths (ragged).
      uniforms: f32[B, K] — one uniform per drafted token.
      temperature, top_p: f32[B] — per-row sampling params (one pair per
        co-batched sequence; the serving layer fills each row from its
        request's overrides).

    Returns (draft_tokens i32[B, K], qdists f32[B, K, V], new_caches).
    qdists[b, j] is the warped draft distribution d_{j} was sampled from.
    """
    b, k_draft = uniforms.shape
    # Resync: ingest up to two catch-up tokens at ragged offsets.
    logits2, caches = decode(params, tokens_in, seq_lens, caches, cfg,
                             attn_impl)
    first_logits = logits2[jnp.arange(b), n_in - 1]               # [B, V]
    d0, q0 = sample_top_p(first_logits, uniforms[:, 0], temperature, top_p)
    lens = seq_lens + n_in

    # The K-1 remaining steps are unrolled: lax.scan would stack the
    # per-layer cache buffers into one carry tensor, defeating per-buffer
    # donation. K is small (≤16) and bucketed, so unrolling is cheap.
    toks, qs = [d0], [q0]
    tok, cur = d0, lens
    for j in range(1, k_draft):
        logits, caches = decode(params, tok[:, None], cur, caches, cfg,
                                attn_impl)
        tok, q = sample_top_p(logits[:, 0], uniforms[:, j], temperature,
                              top_p)
        cur = cur + 1
        toks.append(tok)
        qs.append(q)
    draft_tokens = jnp.stack(toks, axis=1)                        # [B, K]
    qdists = jnp.stack(qs, axis=1)                                # [B, K, V]
    return draft_tokens, qdists, caches


def draft_packed(params, tokens_in, n_in, seq_lens, caches, koffs, uniforms,
                 temperature, top_p, k_draft: int, cfg: ModelConfig,
                 attn_impl: str = "pallas"):
    """Packed-ABI drafting: ``draft_loop`` with offset-addressed I/O.

    The packed exec mode addresses every per-row buffer by cumulative
    offsets instead of a rectangular ``[B, K]`` layout. Drafting is
    auto-regressive — every step is a genuine B×1 decode, so there is no
    *column* pad waste to reclaim (rows whose ``k_i`` is below the launch
    bucket still step forward producing garbage the orchestrator ignores,
    exactly as in the PAD draft program) — but the host-facing ABI packs:

      * ``koffs``: int32[B+1] cumulative draft-length offsets;
        ``k_i = koffs[i+1] - koffs[i]`` (``<= k_draft``).
      * ``uniforms``: f32[Cu] (``Cu = B·k_draft`` capacity), row i's
        ``k_i`` uniforms at ``koffs[i]:koffs[i+1]``; the tail is unused.
      * returns ``(toks f32→i32[Cu], qdists f32[Cu, V], caches)`` in the
        same packed-prefix layout; positions past ``koffs[B]`` are zero.

    Step j of row i consumes ``uniforms[koffs[i] + j]`` when ``j < k_i``
    and the PAD filler 0.0 otherwise, so tokens, q-distributions and
    caches are bitwise-identical to ``draft_loop`` fed the equivalent
    rectangular uniforms.
    """
    b = seq_lens.shape[0]
    cu = uniforms.shape[0]
    klens = koffs[1:] - koffs[:-1]                                # [B]

    def u_at(j):
        idx = jnp.clip(koffs[:-1] + j, 0, cu - 1)
        return jnp.where(j < klens, uniforms[idx], 0.0)

    logits2, caches = decode(params, tokens_in, seq_lens, caches, cfg,
                             attn_impl)
    first_logits = logits2[jnp.arange(b), n_in - 1]               # [B, V]
    d0, q0 = sample_top_p(first_logits, u_at(0), temperature, top_p)
    lens = seq_lens + n_in

    toks, qs = [d0], [q0]
    tok, cur = d0, lens
    for j in range(1, k_draft):
        logits, caches = decode(params, tok[:, None], cur, caches, cfg,
                                attn_impl)
        tok, q = sample_top_p(logits[:, 0], u_at(j), temperature, top_p)
        cur = cur + 1
        toks.append(tok)
        qs.append(q)
    draft_tokens = jnp.stack(toks, axis=1)                        # [B, K]
    qdists = jnp.stack(qs, axis=1)                                # [B, K, V]

    # Scatter into the packed-prefix layout; steps beyond a row's k_i
    # target the out-of-bounds index Cu and are dropped.
    j_idx = jnp.arange(k_draft)[None, :]
    out_idx = jnp.where(j_idx < klens[:, None],
                        koffs[:-1, None] + j_idx, cu)             # [B, K]
    flat = out_idx.reshape(-1)
    toks_packed = jnp.zeros((cu,), jnp.int32).at[flat].set(
        draft_tokens.reshape(-1), mode="drop")
    qdists_packed = jnp.zeros((cu, cfg.vocab), jnp.float32).at[flat].set(
        qdists.reshape(-1, cfg.vocab), mode="drop")
    return toks_packed, qdists_packed, caches


# ---------------------------------------------------------------------------
# Training path (dense attention, no cache)
# ---------------------------------------------------------------------------

def lm_logits(params, tokens, cfg: ModelConfig):
    """Full causal forward over [B, T] for training/eval."""
    b, t = tokens.shape
    x = params["embed"][tokens] + params["pos"][:t][None]
    mask = jnp.tril(jnp.ones((t, t), bool))
    for blk in params["blocks"]:
        h = _ln(x, blk["ln1"])
        qkv = h @ blk["qkv"]["w"] + blk["qkv"]["b"]
        qh, kh, vh = jnp.split(qkv, 3, axis=-1)
        qh = _split_heads(qh, cfg.n_head)
        kh = _split_heads(kh, cfg.n_head)
        vh = _split_heads(vh, cfg.n_head)
        scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / (cfg.d_head ** 0.5)
        scores = jnp.where(mask[None, None], scores, -1e30)
        attn_out = jnp.einsum("bhqk,bhkd->bhqd",
                              jax.nn.softmax(scores, -1), vh)
        x = x + _merge_heads(attn_out) @ blk["proj"]["w"] + blk["proj"]["b"]
        h2 = _ln(x, blk["ln2"])
        x = x + jax.nn.gelu(h2 @ blk["fc"]["w"] + blk["fc"]["b"]) \
            @ blk["out"]["w"] + blk["out"]["b"]
    x = _ln(x, params["ln_f"])
    return x @ params["embed"].T


def lm_loss(params, tokens, cfg: ModelConfig):
    """Next-byte cross-entropy over a [B, T] batch."""
    logits = lm_logits(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, targets[..., None], -1)[..., 0]
    return jnp.mean(nll)
