"""Build-time trainer for the main model and the draft zoo.

Reproduces the draft-training recipe of Appendix A.2 at testbed scale:
AdamW (β1=0.9, β2=0.95, ε=1e-8), warmup → cosine decay to 10% of peak LR,
global-norm gradient clipping at 1.0, all models trained on the same corpus.
Hand-rolled optimizer (optax is not available in this image).

Runs once from ``aot.py`` during ``make artifacts``; never on the request
path.
"""

from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import ModelConfig, init_params, lm_loss


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 600
    batch: int = 12
    # Train positions 0..seq-1; must cover prompt + generation (the padded
    # KV capacity is 256 but only trained positions produce sane logits).
    seq: int = 192
    lr: float = 3e-3
    warmup: int = 60
    min_lr_frac: float = 0.1
    weight_decay: float = 0.01
    clip: float = 1.0
    seed: int = 0
    eval_every: int = 50
    eval_batches: int = 4


def _lr_at(step, tc: TrainConfig):
    warm = jnp.minimum(1.0, (step + 1) / tc.warmup)
    prog = jnp.clip((step - tc.warmup) / max(1, tc.steps - tc.warmup), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = tc.min_lr_frac + (1 - tc.min_lr_frac) * cos
    return tc.lr * warm * frac


def _adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


@partial(jax.jit, static_argnames=("cfg", "tc"), donate_argnums=(0, 1))
def _update(params, opt, tokens, cfg: ModelConfig, tc: TrainConfig):
    loss, grads = jax.value_and_grad(lm_loss)(params, tokens, cfg)
    # Global-norm clip.
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, tc.clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    t = opt["t"] + 1
    lr = _lr_at(t, tc)
    b1, b2, eps = 0.9, 0.95, 1e-8

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + tc.weight_decay * p)
        return p, m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt["m"])
    flat_v = jax.tree_util.tree_leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    opt = {"m": jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]),
           "v": jax.tree_util.tree_unflatten(tdef, [o[2] for o in out]),
           "t": t}
    return params, opt, loss


def _batches(data: np.ndarray, rng: np.random.Generator, batch, seq):
    idx = rng.integers(0, len(data) - seq - 1, size=batch)
    return np.stack([data[i:i + seq + 1] for i in idx]).astype(np.int32)


def train_model(cfg: ModelConfig, corpus: bytes, tc: TrainConfig,
                log=print):
    """Train one model; returns (params, history list of (step, loss))."""
    data = np.frombuffer(corpus, np.uint8)
    name_salt = zlib.crc32(cfg.name.encode()) % 1000   # stable across runs
    rng = np.random.default_rng(tc.seed + name_salt)
    params = init_params(jax.random.PRNGKey(tc.seed), cfg)
    opt = _adamw_init(params)
    history = []
    t0 = time.time()
    for step in range(tc.steps):
        tokens = jnp.asarray(_batches(data, rng, tc.batch, tc.seq))
        params, opt, loss = _update(params, opt, tokens, cfg, tc)
        if step % tc.eval_every == 0 or step == tc.steps - 1:
            l = float(loss)
            history.append((step, l))
            log(f"[train {cfg.name}] step {step:5d} loss {l:.4f} "
                f"({time.time() - t0:.0f}s)")
    return params, history


def held_out_loss(params, cfg: ModelConfig, corpus: bytes, tc: TrainConfig):
    """Loss on deterministic windows from the corpus tail."""
    data = np.frombuffer(corpus, np.uint8)
    rng = np.random.default_rng(9999)
    losses = []
    for _ in range(tc.eval_batches):
        tokens = jnp.asarray(_batches(data, rng, tc.batch, tc.seq))
        losses.append(float(lm_loss(params, tokens, cfg)))
    return float(np.mean(losses))
