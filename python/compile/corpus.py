"""Deterministic synthetic corpus + evaluation tasks.

The paper evaluates on XSum (summarization, OPT models) and HumanEval (code
generation, CodeGen / a 7.8B code model). Neither a 13B model nor the real
datasets fit this testbed, so we build the closest synthetic equivalent
(DESIGN.md §1): a templated byte-level corpus with two registers —

  * prose: entity/fact sentences, and Article→Summary pairs whose summary is
    derivable from the article (gives ROUGE-2 a real signal);
  * code: small python-like functions drawn from parameterized families
    (arith ops, clamps, predicates, accumulators) with canonical one-line
    bodies (gives Pass@K a programmatic checker).

Main and draft models are trained on the *same* corpus (as in the paper,
App. A.2), which is what produces realistic draft-token acceptance rates.
Everything is seeded: the corpus, the train/test task splits and the task
JSON files are bit-reproducible.
"""

from __future__ import annotations

import json
import random
from dataclasses import dataclass

NAMES = ["alice", "bob", "carol", "dave", "erin", "frank", "grace", "heidi",
         "ivan", "judy", "karl", "lena", "mike", "nora", "oscar", "peggy"]
CITIES = ["paris", "tokyo", "berlin", "cairo", "oslo", "lima", "quito",
          "seoul", "dakar", "milan", "delhi", "hanoi"]
TOPICS = ["rivers", "bridges", "markets", "gardens", "museums", "harbors",
          "stadiums", "forests", "castles", "libraries"]
VERBS = ["studies", "maps", "paints", "records", "restores", "describes"]

EOS = "\x00"  # byte-level end-of-sequence marker


# ---------------------------------------------------------------------------
# Code register (HumanEval analog)
# ---------------------------------------------------------------------------

@dataclass
class CodeProblem:
    task_id: str
    prompt: str      # up to and including "    return"
    canonical: str   # the canonical completion line, e.g. " x + 7"
    family: str


def _code_families(rng: random.Random):
    """Parameterized function families with canonical single-line bodies."""
    fams = []
    for k in range(2, 30):
        fams.append(("add", f"add_{k}", f"adds {k} to x", f" x + {k}"))
        fams.append(("mul", f"mul_{k}", f"multiplies x by {k}", f" x * {k}"))
        fams.append(("sub", f"sub_{k}", f"subtracts {k} from x", f" x - {k}"))
    for k in range(2, 16):
        fams.append(("gt", f"gt_{k}", f"checks if x exceeds {k}", f" x > {k}"))
        fams.append(("mod", f"mod_{k}", f"takes x modulo {k}", f" x % {k}"))
        fams.append(("clamp", f"clamp_{k}",
                     f"clamps x to at most {k}", f" min(x, {k})"))
    rng.shuffle(fams)
    return fams


def make_code_problem(fam) -> CodeProblem:
    _, name, desc, body = fam
    prompt = (f"def {name}(x):\n"
              f"    # {desc}\n"
              f"    return")
    return CodeProblem(task_id=name, prompt=prompt, canonical=body,
                       family=fam[0])


def code_sample_text(p: CodeProblem) -> str:
    return p.prompt + p.canonical + "\n" + EOS


# ---------------------------------------------------------------------------
# Prose register (XSum analog)
# ---------------------------------------------------------------------------

@dataclass
class SummProblem:
    task_id: str
    prompt: str      # "article: ...\nsummary:"
    reference: str   # the derivable summary


def make_summ_problem(rng: random.Random, idx: int) -> SummProblem:
    name = rng.choice(NAMES)
    city = rng.choice(CITIES)
    topic = rng.choice(TOPICS)
    verb = rng.choice(VERBS)
    other = rng.choice([t for t in TOPICS if t != topic])
    year = rng.randint(1950, 2020)
    # Kept short enough that prompt + summary fits the trained context
    # (TrainConfig.seq) with headroom; the summary is the first fact.
    art = (f"article: {name} {verb} the {topic} of {city}. "
           f"the work began in {year}. "
           f"the {other} are nearby.\n")
    summary = f" {name} {verb} the {topic} of {city}."
    return SummProblem(task_id=f"summ_{idx}", prompt=art + "summary:",
                       reference=summary)


def summ_sample_text(p: SummProblem) -> str:
    return p.prompt + p.reference + "\n" + EOS


# ---------------------------------------------------------------------------
# Corpus assembly
# ---------------------------------------------------------------------------

def build_corpus(seed: int = 1234, n_code: int = 4000,
                 n_summ: int = 3000) -> tuple[bytes, list, list]:
    """Returns (corpus_bytes, held_out_code_problems, held_out_summ_problems).

    Held-out problems use parameter combinations excluded from the training
    text (same template distribution, unseen instances for prose; for code,
    families repeat but each function appears in both — memorization is the
    point: the tiny main model plays the "competent big model" role and the
    drafts approximate it, reproducing the paper's alignment regime).
    """
    rng = random.Random(seed)
    fams = _code_families(rng)
    test_fams = fams[:48]
    train_fams = fams  # code problems seen in training (memorization regime)

    pieces: list[str] = []
    for i in range(n_summ):
        pieces.append(summ_sample_text(make_summ_problem(rng, i)))
    for i in range(n_code):
        fam = train_fams[rng.randrange(len(train_fams))]
        pieces.append(code_sample_text(make_code_problem(fam)))
    rng.shuffle(pieces)
    text = "".join(pieces)

    test_rng = random.Random(seed + 1)
    code_problems = [make_code_problem(f) for f in test_fams]
    summ_problems = [make_summ_problem(test_rng, 10000 + i) for i in range(48)]
    return text.encode("latin-1"), code_problems, summ_problems


def write_tasks(out_dir: str, code_problems, summ_problems) -> None:
    """Emit the task JSONs consumed by the Rust eval harness."""
    import os
    os.makedirs(out_dir, exist_ok=True)
    code = [{
        "task_id": p.task_id,
        "prompt": p.prompt,
        "checker": {"type": "line_equals", "expected": p.canonical.strip()},
    } for p in code_problems]
    summ = [{
        "task_id": p.task_id,
        "prompt": p.prompt,
        "reference": p.reference.strip(),
    } for p in summ_problems]
    with open(f"{out_dir}/synth_humaneval.json", "w") as f:
        json.dump(code, f, indent=1)
    with open(f"{out_dir}/synth_xsum.json", "w") as f:
        json.dump(summ, f, indent=1)
