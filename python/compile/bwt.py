"""BWT — the flat binary weight format shared by python (writer) and the
Rust runtime (reader, ``rust/src/runtime/weights.rs``).

Layout (all little-endian):

    magic   4 bytes  b"BWT1"
    count   u32      number of tensors
    per tensor:
      name_len u16, name utf-8 bytes
      dtype    u8   (0 = f32, 1 = i8, 2 = i32)
      ndim     u8
      dims     u32 × ndim
      data     raw bytes (row-major)

Tensor order is the artifact *input order* (flattened-pytree order), so the
Rust side can upload buffers positionally without re-deriving the pytree.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"BWT1"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1, np.dtype(np.int32): 2}
DTYPES_INV = {0: np.float32, 1: np.int8, 2: np.int32}


def write_bwt(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_bwt(path: str) -> list[tuple[str, np.ndarray]]:
    """Python-side reader (round-trip tests; Rust has its own)."""
    out = []
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError("bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            dtype = np.dtype(DTYPES_INV[dt])
            n = int(np.prod(dims)) if ndim else 1
            arr = np.frombuffer(f.read(n * dtype.itemsize), dtype).reshape(dims)
            out.append((name, arr))
    return out
