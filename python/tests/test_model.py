"""Layer-2 model correctness: KV-cache consistency, raggedness, and the
pallas/dense attention parity inside the full transformer."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import (CONFIGS, ModelConfig, decode, draft_loop,
                           init_cache, init_params, lm_logits, prefill,
                           sample_top_p)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig("tiny", n_layer=2, n_head=2, d_model=32, d_ff=64)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)


def rand_tokens(seed, shape):
    return jnp.asarray(
        np.random.RandomState(seed).randint(1, 256, shape), jnp.int32)


# ---------------------------------------------------------------------------
# Cache consistency: incremental decode == full forward
# ---------------------------------------------------------------------------

def test_prefill_matches_full_forward():
    toks = rand_tokens(0, (2, 10))
    plens = jnp.array([6, 10], jnp.int32)
    last, _ = prefill(PARAMS, toks, plens, CFG, attn_impl="dense")
    full = lm_logits(PARAMS, toks, CFG)
    np.testing.assert_allclose(last[0], full[0, 5], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(last[1], full[1, 9], atol=1e-4, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    p1=st.integers(2, 10),
    p2=st.integers(2, 10),
    q=st.integers(1, 5),
)
def test_ragged_decode_matches_full_forward(seed, p1, p2, q):
    """Two sequences at *different* lengths decode Q tokens each; logits
    must equal the full forward over each concatenated stream — the core
    ragged-batch property of BASS."""
    p_max = 10
    toks = rand_tokens(seed, (2, p_max))
    plens = jnp.array([p1, p2], jnp.int32)
    _, caches = prefill(PARAMS, toks, plens, CFG, attn_impl="dense")
    new = rand_tokens(seed + 1, (2, q))
    logits, _ = decode(PARAMS, new, plens, caches, CFG, attn_impl="dense")
    for b, p in enumerate([p1, p2]):
        stream = jnp.concatenate([toks[b, :p], new[b]])[None]
        full = lm_logits(PARAMS, stream, CFG)
        np.testing.assert_allclose(logits[b], full[0, p:p + q],
                                   atol=2e-4, rtol=2e-4)


def test_decode_pallas_matches_dense_in_model():
    toks = rand_tokens(2, (2, 8))
    plens = jnp.array([5, 8], jnp.int32)
    _, caches = prefill(PARAMS, toks, plens, CFG, attn_impl="dense")
    new = rand_tokens(3, (2, 3))
    ld, _ = decode(PARAMS, new, plens, caches, CFG, attn_impl="dense")
    lp, _ = decode(PARAMS, new, plens, caches, CFG, attn_impl="pallas")
    np.testing.assert_allclose(ld, lp, atol=1e-4, rtol=1e-4)


def test_stale_cache_tail_is_invisible():
    """Rollback = length truncation: poisoned entries beyond seq_lens must
    not affect decode (the paper's rejection-rollback scheme)."""
    toks = rand_tokens(4, (1, 8))
    plens = jnp.array([8], jnp.int32)
    _, caches = prefill(PARAMS, toks, plens, CFG, attn_impl="dense")
    new = rand_tokens(5, (1, 2))
    base, _ = decode(PARAMS, new, plens, caches, CFG, attn_impl="dense")
    poisoned = [c.at[:, :, 12:, :].set(1e3) for c in caches]
    pois, _ = decode(PARAMS, new, plens, poisoned, CFG, attn_impl="dense")
    np.testing.assert_allclose(base, pois, atol=1e-5)


def test_cache_write_positions_are_ragged():
    """Decode must write K/V at each sequence's own offset."""
    toks = rand_tokens(6, (2, 8))
    plens = jnp.array([3, 7], jnp.int32)
    _, caches = prefill(PARAMS, toks, plens, CFG, attn_impl="dense")
    new = rand_tokens(7, (2, 2))
    _, newc = decode(PARAMS, new, plens, caches, CFG, attn_impl="dense")
    k_old, k_new = np.asarray(caches[0]), np.asarray(newc[0])
    # Row 0: positions 3,4 changed; row 1: positions 7,8 changed.
    assert not np.allclose(k_old[0, :, 3:5], k_new[0, :, 3:5])
    np.testing.assert_allclose(k_old[0, :, 5:], k_new[0, :, 5:])
    assert not np.allclose(k_old[1, :, 7:9], k_new[1, :, 7:9])
    np.testing.assert_allclose(k_old[1, :, 0:7], k_new[1, :, 0:7])


# ---------------------------------------------------------------------------
# Draft loop
# ---------------------------------------------------------------------------

def test_draft_loop_resync_two_tokens():
    """n_in=2 must condition the first draft on both catch-up tokens."""
    toks = rand_tokens(8, (1, 8))
    plens = jnp.array([6], jnp.int32)
    _, caches = prefill(PARAMS, toks, plens, CFG, attn_impl="dense")
    extra = rand_tokens(9, (1, 2))
    u = jnp.full((1, 3), 0.31, jnp.float32)
    t, tp = jnp.float32(0.01), jnp.float32(0.95)
    d2, _, _ = draft_loop(PARAMS, extra, jnp.array([2], jnp.int32),
                          plens - 1, caches, u, t, tp, CFG,
                          attn_impl="dense")
    # Reference: full forward over prompt[:5] + last_prompt? Use stream:
    # prefill covers toks[:6]; pending convention starts at len-1 = 5 with
    # inputs extra[0], extra[1].
    stream = jnp.concatenate([toks[0, :5], extra[0]])[None]
    full = lm_logits(PARAMS, stream, CFG)
    expected = int(jnp.argmax(full[0, -1]))
    assert int(d2[0, 0]) == expected


def test_draft_loop_k_tokens_advance():
    toks = rand_tokens(10, (2, 8))
    plens = jnp.array([4, 8], jnp.int32)
    _, caches = prefill(PARAMS, toks, plens, CFG, attn_impl="dense")
    t0 = jnp.stack([toks[jnp.arange(2), plens - 1],
                    jnp.zeros(2, jnp.int32)], axis=1)
    u = jnp.full((2, 5), 0.5, jnp.float32)
    dt, qd, newc = draft_loop(PARAMS, t0, jnp.array([1, 1], jnp.int32),
                              plens - 1, caches, u, jnp.float32(0.2),
                              jnp.float32(0.95), CFG, attn_impl="dense")
    assert dt.shape == (2, 5)
    assert qd.shape == (2, 5, 256)
    np.testing.assert_allclose(np.asarray(qd).sum(-1), 1.0, atol=1e-5)
    assert all(c.shape == caches[i].shape for i, c in enumerate(newc))


# ---------------------------------------------------------------------------
# In-graph sampler
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       temp=st.floats(0.05, 2.0),
       top_p=st.floats(0.1, 1.0))
def test_sample_top_p_valid_distribution(seed, temp, top_p):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (3, 64)) * 3
    u = jax.random.uniform(jax.random.PRNGKey(seed + 1), (3,))
    tok, warped = sample_top_p(logits, u, jnp.float32(temp),
                               jnp.float32(top_p))
    w = np.asarray(warped)
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    assert (w >= 0).all()
    # The sampled token must have non-zero warped probability.
    for b in range(3):
        assert w[b, int(tok[b])] > 0


def test_sample_top_p_per_row_params():
    """Per-row [B] temperature/top_p vectors: a near-greedy row and a hot
    row warp independently inside one call (the per-request sampling
    contract of the serving layer)."""
    logits = jnp.array([[0.0, 3.0, 1.0, -2.0]] * 2)
    u = jnp.array([0.7, 0.7])
    tok, w = sample_top_p(logits, u, jnp.array([0.01, 2.0], jnp.float32),
                          jnp.array([0.9, 1.0], jnp.float32))
    w = np.asarray(w)
    assert int(tok[0]) == 1 and w[0, 1] > 0.999   # greedy row collapses
    assert (w[1] > 0.01).all()                     # hot row keeps everything
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)


def test_sample_top_p_greedy_limit():
    logits = jnp.array([[0.0, 3.0, 1.0, -2.0]])
    tok, w = sample_top_p(logits, jnp.array([0.7]), jnp.float32(0.01),
                          jnp.float32(0.9))
    assert int(tok[0]) == 1
    assert float(w[0, 1]) > 0.999


def test_config_registry():
    assert set(CONFIGS) == {"main", "draft_a", "draft_b", "draft_c"}
    for cfg in CONFIGS.values():
        assert cfg.d_model % cfg.n_head == 0
        assert cfg.d_head == cfg.d_model // cfg.n_head
    assert len(init_cache(CFG, 3)) == 2 * CFG.n_layer
