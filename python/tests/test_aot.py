"""AOT export pipeline: HLO-text validity (parseable by the runtime's XLA
generation), grid coverage, donation aliasing, and weight-manifest order."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile.aot import (artifact_name, grid, lower_artifact,
                         lower_gemm_calib, _flat_weights, PREFILL_P)
from compile.model import ModelConfig, init_params
from compile.quant import quantize_params

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig("tiny", n_layer=1, n_head=2, d_model=32, d_ff=64)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)


def test_grid_covers_phases_and_buckets():
    specs = list(grid(quick=False))
    phases = {(m, ph) for (m, _, ph, _, _, _) in specs}
    assert ("main", "decode") in phases
    assert ("draft_a", "draft") in phases
    assert ("draft_b", "draft") in phases
    # Every draft K bucket has a matching main verify bucket (Q = K + 1).
    draft_ks = {q for (m, _, ph, _, q, _) in specs
                if m == "draft_a" and ph == "draft"}
    main_qs = {q for (m, _, ph, _, q, _) in specs
               if m == "main" and ph == "decode"}
    assert {k + 1 for k in draft_ks} <= main_qs
    assert 1 in main_qs  # RD
    # Pallas parity subset present.
    assert any(attn == "pallas" for (_, _, _, _, _, attn) in specs)


def test_artifact_name_stable():
    assert artifact_name("main", "f32", "decode", 2, 5, "dense") == \
        "main_f32_decode5_b2"
    assert artifact_name("main", "f32", "decode", 2, 5, "pallas") == \
        "main_f32_decode5_b2_pallas"


def _parses_as_hlo(text: str) -> bool:
    """The acceptance criterion: the *old* text parser (what the Rust side
    uses) must accept the module. jax's own parser is newer, so we check
    the known-poisonous constructs instead of round-tripping."""
    assert text.startswith("HloModule")
    for forbidden in ["topk(", "largest=true"]:
        if forbidden in text:
            return False
    return True


def test_decode_artifact_text_and_donation():
    text = lower_artifact(CFG, PARAMS, "decode", 2, 3, "dense")
    assert _parses_as_hlo(text)
    # Cache donation must survive to HLO (input_output_alias header).
    assert "input_output_alias" in text.splitlines()[0]


def test_prefill_artifact_text():
    text = lower_artifact(CFG, PARAMS, "prefill", 1, 8, "dense")
    assert _parses_as_hlo(text)


def test_draft_artifact_avoids_topk():
    text = lower_artifact(CFG, PARAMS, "draft", 1, 2, "dense")
    assert _parses_as_hlo(text), "draft artifact uses parser-hostile ops"


def test_draft_artifact_takes_per_row_sampling_params():
    """The draft ABI carries temperature/top_p as [B] vectors (per-request
    sampling params), not scalars: at B=2 the entry computation must take
    f32[2] parameters alongside the f32[2,3] uniforms."""
    text = lower_artifact(CFG, PARAMS, "draft", 2, 3, "dense")
    assert _parses_as_hlo(text)
    entry = text.splitlines()[0]
    assert "f32[2]" in entry, "temp/top_p are not [B]-shaped in the ABI"
    assert "f32[]" not in entry, "scalar sampling param survived in the ABI"


def test_int8_artifact_has_s8_params():
    qp = quantize_params(PARAMS)
    text = lower_artifact(CFG, qp, "decode", 1, 1, "dense")
    assert "s8[" in text
    assert _parses_as_hlo(text)


def test_gemm_calib_is_a_dot():
    text = lower_gemm_calib(64)
    assert "dot(" in text


def test_flat_weights_order_is_deterministic():
    leaves1, _, names1, _ = _flat_weights(PARAMS)
    leaves2, _, names2, _ = _flat_weights(
        init_params(jax.random.PRNGKey(0), CFG))
    assert names1 == names2
    assert names1[0].startswith("blocks/0/")
    assert len(leaves1) == len(leaves2)


@pytest.mark.skipif(not os.path.exists("../artifacts/manifest.json"),
                    reason="artifacts not built")
def test_built_manifest_consistent():
    import json
    with open("../artifacts/manifest.json") as f:
        man = json.load(f)
    assert man["prefill_p"] == PREFILL_P
    for a in man["artifacts"]:
        assert os.path.exists(os.path.join("../artifacts", a["file"])), \
            a["file"]
    for m in man["models"].values():
        for rel in m["weights"].values():
            assert os.path.exists(os.path.join("../artifacts", rel))
