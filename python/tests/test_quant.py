"""INT8 weight-only quantization: round-trip error bounds and model-level
logit drift (the Tables 1–3 precision axis)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import ModelConfig, init_params, lm_logits
from compile.quant import (dequantize_params, maybe_dequant,
                           quantize_params, quantize_tensor)

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       rows=st.integers(1, 64),
       cols=st.integers(1, 64),
       scale=st.floats(0.01, 100.0))
def test_quantize_tensor_error_bound(seed, rows, cols, scale):
    w = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)) * scale
    q = quantize_tensor(w)
    assert q["q"].dtype == jnp.int8
    assert q["s"].shape == (cols,)
    deq = maybe_dequant(q)
    # Per-channel symmetric int8: |err| <= scale/2 per element, where
    # scale = amax / 127.
    amax = np.abs(np.asarray(w)).max(axis=0)
    bound = amax / 127.0 * 0.5 + 1e-7
    err = np.abs(np.asarray(deq) - np.asarray(w))
    assert (err <= bound[None, :] + 1e-6).all()


def test_quantize_params_structure():
    cfg = ModelConfig("tiny", n_layer=2, n_head=2, d_model=32, d_ff=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params)
    # Embedding stays f32; block weight matrices become {"q","s"} dicts.
    assert isinstance(qp["embed"], jnp.ndarray)
    assert "q" in qp["blocks"][0]["qkv"]["w"]
    assert isinstance(qp["blocks"][0]["qkv"]["b"], jnp.ndarray)
    # Leaf count grows by one scale per quantized matrix (qkv, proj, fc,
    # out = 4 per block).
    n_f32 = len(jax.tree_util.tree_leaves(params))
    n_q = len(jax.tree_util.tree_leaves(qp))
    assert n_q == n_f32 + 4 * cfg.n_layer


def test_model_level_logit_drift_small():
    cfg = ModelConfig("tiny", n_layer=2, n_head=2, d_model=32, d_ff=64)
    params = init_params(jax.random.PRNGKey(1), cfg)
    toks = jnp.asarray(
        np.random.RandomState(0).randint(1, 256, (2, 12)), jnp.int32)
    full = lm_logits(params, toks, cfg)
    deq = lm_logits(dequantize_params(quantize_params(params)), toks, cfg)
    # Quantization perturbs logits slightly; ranking of the argmax should
    # mostly survive and the numeric drift stays bounded.
    drift = np.abs(np.asarray(full) - np.asarray(deq)).max()
    assert drift < 0.5, f"excessive int8 drift {drift}"
    agree = (np.argmax(np.asarray(full), -1)
             == np.argmax(np.asarray(deq), -1)).mean()
    assert agree > 0.8


def test_maybe_dequant_passthrough():
    x = jnp.ones((3, 3))
    assert maybe_dequant(x) is x
