"""Layer-1 correctness: Pallas ragged-attention kernels vs the jnp oracle.

This is the CORE kernel correctness signal: hypothesis sweeps shapes, dtypes
and ragged length patterns and asserts allclose against ``kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    ragged_decode_attention,
    ragged_decode_attention_ref,
    ragged_prefill_attention,
    ragged_prefill_attention_ref,
    split_decode_attention,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _make_inputs(seed, b, h, q, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        _rand(ks[0], (b, h, q, d), dtype),
        _rand(ks[1], (b, h, s, d), dtype),
        _rand(ks[2], (b, h, s, d), dtype),
    )


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=3e-5, rtol=3e-5)


def _check(out, ref, dtype):
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# Hypothesis sweep: shapes / dtypes / ragged lengths
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    q=st.integers(1, 9),
    s=st.sampled_from([128, 256]),
    d=st.sampled_from([16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    data=st.data(),
)
def test_decode_matches_ref_hypothesis(seed, b, h, q, s, d, dtype, data):
    qx, kx, vx = _make_inputs(seed, b, h, q, s, d, dtype)
    max_len = s - q
    lens = jnp.array(
        data.draw(st.lists(st.integers(0, max_len), min_size=b, max_size=b)),
        jnp.int32)
    out = ragged_decode_attention(qx, kx, vx, lens)
    ref = ragged_decode_attention_ref(qx, kx, vx, lens)
    _check(out, ref, dtype)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 3),
    q=st.integers(1, 6),
    data=st.data(),
)
def test_split_matches_pad(seed, b, q, data):
    """BASS-SPLIT and BASS-PAD compute identical attention (Fig 4b vs 4c)."""
    h, s, d = 2, 128, 16
    qx, kx, vx = _make_inputs(seed, b, h, q, s, d, jnp.float32)
    lens = jnp.array(
        data.draw(st.lists(st.integers(0, s - q), min_size=b, max_size=b)),
        jnp.int32)
    pad = ragged_decode_attention(qx, kx, vx, lens)
    split = split_decode_attention(qx, kx, vx, lens)
    _check(split, pad, jnp.float32)


# ---------------------------------------------------------------------------
# Directed edge cases
# ---------------------------------------------------------------------------

def test_single_token_single_seq():
    q, k, v = _make_inputs(0, 1, 1, 1, 128, 16, jnp.float32)
    lens = jnp.array([0], jnp.int32)
    out = ragged_decode_attention(q, k, v, lens)
    ref = ragged_decode_attention_ref(q, k, v, lens)
    _check(out, ref, jnp.float32)


def test_zero_length_attends_only_self():
    """len=0, Q=1: the token can only attend itself -> out == its own value."""
    q, k, v = _make_inputs(1, 1, 2, 1, 128, 16, jnp.float32)
    lens = jnp.array([0], jnp.int32)
    out = ragged_decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[:, :, :1]),
                               atol=1e-6)


def test_full_cache_boundary():
    """Lengths at the very end of the padded cache (len + Q == S)."""
    b, h, qn, s, d = 2, 2, 4, 128, 16
    q, k, v = _make_inputs(2, b, h, qn, s, d, jnp.float32)
    lens = jnp.array([s - qn, s - qn], jnp.int32)
    out = ragged_decode_attention(q, k, v, lens)
    ref = ragged_decode_attention_ref(q, k, v, lens)
    _check(out, ref, jnp.float32)


def test_pad_region_is_ignored():
    """Garbage beyond seq_len must not change the output (BASS-PAD contract)."""
    b, h, qn, s, d = 2, 2, 3, 256, 32
    q, k, v = _make_inputs(3, b, h, qn, s, d, jnp.float32)
    lens = jnp.array([10, 50], jnp.int32)
    out1 = ragged_decode_attention(q, k, v, lens)
    # Poison the pad region with huge values.
    k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
    for i, l in enumerate([10, 50]):
        k2[i, :, l + qn:] = 1e4
        v2[i, :, l + qn:] = -1e4
    out2 = ragged_decode_attention(q, jnp.asarray(k2), jnp.asarray(v2), lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_causality_within_draft_block():
    """Row j must not see the keys of rows > j (future draft tokens)."""
    b, h, qn, s, d = 1, 1, 4, 128, 16
    q, k, v = _make_inputs(4, b, h, qn, s, d, jnp.float32)
    lens = jnp.array([7], jnp.int32)
    out1 = ragged_decode_attention(q, k, v, lens)
    # Mutate the last draft token's K/V (position lens + qn - 1): rows < qn-1
    # must be unchanged.
    k2, v2 = np.asarray(k).copy(), np.asarray(v).copy()
    k2[0, :, 7 + qn - 1] = 123.0
    v2[0, :, 7 + qn - 1] = -77.0
    out2 = ragged_decode_attention(q, jnp.asarray(k2), jnp.asarray(v2), lens)
    np.testing.assert_allclose(np.asarray(out1)[:, :, :qn - 1],
                               np.asarray(out2)[:, :, :qn - 1], atol=1e-6)
    assert not np.allclose(np.asarray(out1)[:, :, qn - 1],
                           np.asarray(out2)[:, :, qn - 1])


def test_prefill_is_causal():
    b, h, p, d = 2, 2, 64, 16
    q, k, v = _make_inputs(5, b, h, p, 128, d, jnp.float32)
    out = ragged_prefill_attention(q, k, v)
    ref = ragged_prefill_attention_ref(q, k, v)
    _check(out, ref, jnp.float32)


def test_s_blk_variants_agree():
    """Tiling must be value-invariant: different S_BLK, same result."""
    q, k, v = _make_inputs(6, 2, 2, 5, 256, 32, jnp.float32)
    lens = jnp.array([30, 200], jnp.int32)
    a = ragged_decode_attention(q, k, v, lens, s_blk=64)
    b_ = ragged_decode_attention(q, k, v, lens, s_blk=128)
    c = ragged_decode_attention(q, k, v, lens, s_blk=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5)


def test_bad_shapes_raise():
    q, k, v = _make_inputs(7, 1, 1, 1, 128, 16, jnp.float32)
    with pytest.raises(ValueError):
        ragged_decode_attention(q, k, v, jnp.zeros((1,), jnp.int32), s_blk=100)
    with pytest.raises(ValueError):
        ragged_decode_attention(q, k[:, :, :, :8], v, jnp.zeros((1,), jnp.int32))
