"""Corpus determinism, task-file integrity, trainer sanity, and the BWT
weight-format round trip."""

import json
import os

import jax
import numpy as np
import pytest

from compile import bwt
from compile.corpus import build_corpus, make_code_problem, write_tasks
from compile.model import ModelConfig
from compile.train import TrainConfig, held_out_loss, train_model

jax.config.update("jax_platform_name", "cpu")


def test_corpus_is_deterministic():
    c1, code1, summ1 = build_corpus(seed=5, n_code=50, n_summ=50)
    c2, code2, summ2 = build_corpus(seed=5, n_code=50, n_summ=50)
    assert c1 == c2
    assert [p.prompt for p in code1] == [p.prompt for p in code2]
    assert [p.reference for p in summ1] == [p.reference for p in summ2]
    c3, _, _ = build_corpus(seed=6, n_code=50, n_summ=50)
    assert c1 != c3


def test_corpus_contains_both_registers():
    c, code, summ = build_corpus(n_code=100, n_summ=100)
    text = c.decode("latin-1")
    assert "def " in text and "article: " in text and "summary:" in text
    assert len(code) == 48 and len(summ) == 48
    # Prompts must fit the AOT prompt capacity.
    from compile.aot import PREFILL_P
    assert all(len(p.prompt) <= PREFILL_P for p in code)
    assert all(len(p.prompt) <= PREFILL_P for p in summ)


def test_code_problem_checker_matches_sample():
    p = make_code_problem(("add", "add_5", "adds 5 to x", " x + 5"))
    assert p.prompt.endswith("return")
    assert p.canonical == " x + 5"


def test_write_tasks_json(tmp_path):
    _, code, summ = build_corpus(n_code=20, n_summ=20)
    write_tasks(str(tmp_path), code, summ)
    with open(tmp_path / "synth_humaneval.json") as f:
        data = json.load(f)
    assert data[0]["checker"]["type"] == "line_equals"
    with open(tmp_path / "synth_xsum.json") as f:
        data = json.load(f)
    assert "summary:" in data[0]["prompt"]


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = ModelConfig("tiny", n_layer=1, n_head=2, d_model=32, d_ff=64)
    corpus, _, _ = build_corpus(n_code=200, n_summ=200)
    tc = TrainConfig(steps=30, batch=4, seq=64, eval_every=29, warmup=5)
    params, hist = train_model(cfg, corpus, tc, log=lambda *_: None)
    assert hist[0][1] > hist[-1][1] * 1.2, f"loss did not drop: {hist}"
    h = held_out_loss(params, cfg, corpus, tc)
    assert h < hist[0][1]


def test_bwt_roundtrip(tmp_path):
    tensors = [
        ("a/w", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("a/q", np.array([-3, 0, 7], dtype=np.int8)),
        ("scalar", np.array(5, dtype=np.int32)),
    ]
    path = str(tmp_path / "t.bwt")
    bwt.write_bwt(path, tensors)
    back = bwt.read_bwt(path)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, a), (_, b) in zip(tensors, back):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)


def test_bwt_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(ValueError):
        bwt.write_bwt(str(tmp_path / "bad.bwt"),
                      [("x", np.zeros(3, np.float64))])
