"""Cross-language sampler parity: the in-graph nucleus warp
(`model.sample_top_p`) and the Rust host warp (`rust/src/sampling.rs`)
implement the same value-wise rule. This test pins the *python* side's
semantics with directed cases whose expected outputs were computed by hand;
the Rust unit tests pin the same cases, so both sides are anchored to the
same contract (exactness of speculative sampling depends on it)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import sample_top_p

jax.config.update("jax_platform_name", "cpu")


def warp_reference(logits, temperature, top_p):
    """Straight-line NumPy restatement of the contract."""
    x = np.asarray(logits, np.float64) / max(temperature, 1e-4)
    p = np.exp(x - x.max())
    p /= p.sum()
    keep = np.zeros_like(p, bool)
    for i in range(len(p)):
        mass_before = p[p > p[i]].sum()
        keep[i] = mass_before < top_p
    f = np.where(keep, p, 0.0)
    return f / f.sum()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 100_000),
       temp=st.floats(0.05, 2.0),
       top_p=st.floats(0.05, 1.0))
def test_warp_matches_reference(seed, temp, top_p):
    logits = np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed), (16,)) * 2.5)
    _, warped = sample_top_p(jnp.asarray(logits)[None],
                             jnp.array([0.5]), jnp.float32(temp),
                             jnp.float32(top_p))
    ref = warp_reference(logits, temp, top_p)
    np.testing.assert_allclose(np.asarray(warped[0]), ref, atol=2e-4)


def test_warp_directed_case():
    """Pinned case shared with rust/src/sampling.rs::warp_matches_python."""
    logits = jnp.array([[0.0, 1.0, 2.0, -1.0]])
    _, w = sample_top_p(logits, jnp.array([0.5]), jnp.float32(1.0),
                        jnp.float32(0.8))
    # softmax(0,1,2,-1) = [0.0871, 0.2369, 0.6439, 0.0321]
    # mass_before: t2 -> 0 (<0.8 keep), t1 -> .6439 (<0.8 keep),
    # t0 -> .8808 (drop), t3 -> .9679 (drop); renorm over {t1, t2}.
    w = np.asarray(w[0])
    np.testing.assert_allclose(w[2], 0.6439 / 0.8808, atol=2e-3)
    np.testing.assert_allclose(w[1], 0.2369 / 0.8808, atol=2e-3)
    assert w[0] == 0.0 and w[3] == 0.0


def test_per_row_params_directed():
    """Per-row (temperature, top_p) vectors over one logits row: row 0 is
    the shared pinned case at (1.0, 0.8); row 1 the same logits at
    (0.5, 1.0), i.e. softmax(0, 2, 4, -2) with nothing filtered. Pinned in
    rust/src/sampling.rs::warp_per_row_params_matches_python — the Rust
    verify-side warp runs per row with each slot's own params, so both
    sides must agree row-wise."""
    logits = jnp.array([[0.0, 1.0, 2.0, -1.0]] * 2)
    _, w = sample_top_p(logits, jnp.array([0.5, 0.5]),
                        jnp.array([1.0, 0.5], jnp.float32),
                        jnp.array([0.8, 1.0], jnp.float32))
    w = np.asarray(w)
    np.testing.assert_allclose(w[0, 2], 0.6439 / 0.8808, atol=2e-3)
    np.testing.assert_allclose(w[0, 1], 0.2369 / 0.8808, atol=2e-3)
    assert w[0, 0] == 0.0 and w[0, 3] == 0.0
    np.testing.assert_allclose(w[1, 2], 0.86495, atol=2e-3)
    np.testing.assert_allclose(w[1, 1], 0.11706, atol=2e-3)
    np.testing.assert_allclose(w[1, 0], 0.01584, atol=2e-3)
    assert w[1, 3] > 0.0  # top_p = 1 keeps everything


def test_cdf_inversion_directed():
    """Token selection = first index with cdf > u, in index order."""
    logits = jnp.log(jnp.array([[0.25, 0.25, 0.25, 0.25]]))
    for u, want in [(0.05, 0), (0.3, 1), (0.55, 2), (0.9, 3)]:
        tok, _ = sample_top_p(logits, jnp.array([u]), jnp.float32(1.0),
                              jnp.float32(1.0))
        assert int(tok[0]) == want, (u, int(tok[0]))
