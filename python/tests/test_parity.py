"""Cross-language parity tests.

Sampler: the in-graph nucleus warp (`model.sample_top_p`) and the Rust
host warp (`rust/src/sampling.rs`) implement the same value-wise rule.
Directed cases pin the python side's semantics (expected outputs computed
by hand); the Rust unit tests pin the same cases, so both sides are
anchored to the same contract (exactness of speculative sampling depends
on it).

Prefill-scatter: the per-row `prefill_scatter` artifact (PAD mid-flight
admission, `rust/src/runtime/engine.rs::prefill_into_slot`) must equal a
full fused prefill row-for-row — elementwise-exact, across batch buckets —
and must leave non-target rows untouched.

Recompute-resume: preemption (`SpecBatch::suspend`/`resume`) rebuilds a
suspended sequence's KV row by prefilling `prompt ‖ generated` instead of
snapshotting device memory. That is only sound if a prefill-recomputed row
is **bitwise identical** to one built incrementally by decode calls of
assorted Q shapes (with speculative-rollback stale tails in between) —
the property `test_resume_recompute_*` pins here, on the real model graph,
for both attention impls, eager and jitted.

Ragged co-batch: per-sequence draft lengths launch decode at the batch's
max `k_i + 1` with per-row filler beyond each row's real tokens —
`test_ragged_cobatch_decode_matches_solo` pins that a short-draft row's
real-position logits are bitwise those of its solo run."""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal images; CI installs hypothesis
    given = None

import pytest

from compile.model import (ModelConfig, decode, decode_packed, draft_loop,
                           draft_packed, init_params, kv_row_copy, prefill,
                           prefill_scatter, sample_top_p)

jax.config.update("jax_platform_name", "cpu")


def warp_reference(logits, temperature, top_p):
    """Straight-line NumPy restatement of the contract."""
    x = np.asarray(logits, np.float64) / max(temperature, 1e-4)
    p = np.exp(x - x.max())
    p /= p.sum()
    keep = np.zeros_like(p, bool)
    for i in range(len(p)):
        mass_before = p[p > p[i]].sum()
        keep[i] = mass_before < top_p
    f = np.where(keep, p, 0.0)
    return f / f.sum()


if given is not None:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 100_000),
           temp=st.floats(0.05, 2.0),
           top_p=st.floats(0.05, 1.0))
    def test_warp_matches_reference(seed, temp, top_p):
        logits = np.asarray(
            jax.random.normal(jax.random.PRNGKey(seed), (16,)) * 2.5)
        _, warped = sample_top_p(jnp.asarray(logits)[None],
                                 jnp.array([0.5]), jnp.float32(temp),
                                 jnp.float32(top_p))
        ref = warp_reference(logits, temp, top_p)
        np.testing.assert_allclose(np.asarray(warped[0]), ref, atol=2e-4)


def test_warp_directed_case():
    """Pinned case shared with rust/src/sampling.rs::warp_matches_python."""
    logits = jnp.array([[0.0, 1.0, 2.0, -1.0]])
    _, w = sample_top_p(logits, jnp.array([0.5]), jnp.float32(1.0),
                        jnp.float32(0.8))
    # softmax(0,1,2,-1) = [0.0871, 0.2369, 0.6439, 0.0321]
    # mass_before: t2 -> 0 (<0.8 keep), t1 -> .6439 (<0.8 keep),
    # t0 -> .8808 (drop), t3 -> .9679 (drop); renorm over {t1, t2}.
    w = np.asarray(w[0])
    np.testing.assert_allclose(w[2], 0.6439 / 0.8808, atol=2e-3)
    np.testing.assert_allclose(w[1], 0.2369 / 0.8808, atol=2e-3)
    assert w[0] == 0.0 and w[3] == 0.0


def test_per_row_params_directed():
    """Per-row (temperature, top_p) vectors over one logits row: row 0 is
    the shared pinned case at (1.0, 0.8); row 1 the same logits at
    (0.5, 1.0), i.e. softmax(0, 2, 4, -2) with nothing filtered. Pinned in
    rust/src/sampling.rs::warp_per_row_params_matches_python — the Rust
    verify-side warp runs per row with each slot's own params, so both
    sides must agree row-wise."""
    logits = jnp.array([[0.0, 1.0, 2.0, -1.0]] * 2)
    _, w = sample_top_p(logits, jnp.array([0.5, 0.5]),
                        jnp.array([1.0, 0.5], jnp.float32),
                        jnp.array([0.8, 1.0], jnp.float32))
    w = np.asarray(w)
    np.testing.assert_allclose(w[0, 2], 0.6439 / 0.8808, atol=2e-3)
    np.testing.assert_allclose(w[0, 1], 0.2369 / 0.8808, atol=2e-3)
    assert w[0, 0] == 0.0 and w[0, 3] == 0.0
    np.testing.assert_allclose(w[1, 2], 0.86495, atol=2e-3)
    np.testing.assert_allclose(w[1, 1], 0.11706, atol=2e-3)
    np.testing.assert_allclose(w[1, 0], 0.01584, atol=2e-3)
    assert w[1, 3] > 0.0  # top_p = 1 keeps everything


def test_cdf_inversion_directed():
    """Token selection = first index with cdf > u, in index order."""
    logits = jnp.log(jnp.array([[0.25, 0.25, 0.25, 0.25]]))
    for u, want in [(0.05, 0), (0.3, 1), (0.55, 2), (0.9, 3)]:
        tok, _ = sample_top_p(logits, jnp.array([u]), jnp.float32(1.0),
                              jnp.float32(1.0))
        assert int(tok[0]) == want, (u, int(tok[0]))


# ---------------------------------------------------------------------------
# Prefill-scatter vs fused prefill (PAD mid-flight admission)
# ---------------------------------------------------------------------------

_SCATTER_CFG = ModelConfig("tiny", n_layer=2, n_head=2, d_model=32, d_ff=64)
_SCATTER_PARAMS = init_params(jax.random.PRNGKey(7), _SCATTER_CFG)
_P = 12


def _prompts(batch, seed):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(1, 256, size=(batch, _P)).astype(np.int32)
    plens = rng.integers(1, _P + 1, size=(batch,)).astype(np.int32)
    return jnp.asarray(tokens), jnp.asarray(plens)


def _garbage_cache(cfg, batch):
    """Stand-in for a running fused cache full of previous occupants."""
    return [jnp.full((batch, cfg.n_head, cfg.s_max, cfg.d_head), 7.5,
                     jnp.float32) for _ in range(2 * cfg.n_layer)]


def test_scatter_prefill_matches_fused_prefill_across_buckets():
    """Scatter-prefilling every row of a garbage-initialized fused cache
    must equal one fused prefill of the same batch, **elementwise-exact**
    (caches and last-token logits) — the property that makes a PAD row
    admitted mid-flight byte-identical to a solo run. Exactness matters:
    the Rust equivalence harness (`rust/tests/admission_interleaving.rs`)
    compares generated bytes, which ride on these values bit-for-bit."""
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    for batch in [1, 2, 4]:
        tokens, plens = _prompts(batch, seed=batch)
        last_full, caches_full = prefill(params, tokens, plens, cfg,
                                         "dense")
        caches = _garbage_cache(cfg, batch)
        for r in range(batch):
            last, caches = prefill_scatter(
                params, tokens[r:r + 1], plens[r:r + 1],
                jnp.asarray([r], jnp.int32), caches, cfg, "dense")
            np.testing.assert_array_equal(
                np.asarray(last[0]), np.asarray(last_full[r]),
                err_msg=f"b={batch} row {r}: scatter logits != fused")
        for i, (cf, cs) in enumerate(zip(caches_full, caches)):
            np.testing.assert_array_equal(
                np.asarray(cs), np.asarray(cf),
                err_msg=f"b={batch} cache buffer {i}: scatter != fused")


def test_scatter_prefill_leaves_other_rows_untouched():
    """Only the target row changes; every other row of every cache buffer
    is element-identical to its input (a running batch's live rows must
    not see the admission)."""
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    batch, target = 4, 2
    tokens, plens = _prompts(1, seed=9)
    before = _garbage_cache(cfg, batch)
    _, after = prefill_scatter(params, tokens, plens,
                               jnp.asarray([target], jnp.int32),
                               before, cfg, "dense")
    for i, (b, a) in enumerate(zip(before, after)):
        for r in range(batch):
            if r == target:
                assert not np.array_equal(np.asarray(a[r]),
                                          np.asarray(b[r])), \
                    f"buffer {i}: target row {target} was not rewritten"
            else:
                np.testing.assert_array_equal(
                    np.asarray(a[r]), np.asarray(b[r]),
                    err_msg=f"buffer {i}: row {r} changed")


# ---------------------------------------------------------------------------
# KV row-copy vs fresh prefill (fan-out sharing / prefix cache)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("attn", ["dense", "pallas"])
def test_kv_row_copy_matches_fresh_prefill_bitwise(attn):
    """Row-copying a freshly-prefilled donor row must equal a fresh
    prefill of the same prompt into the destination row **bit for bit**
    (the entire [H, S, Dh] slab, zero tail included) — the soundness
    argument for fan-out prefill sharing: KV at position i is a pure
    function of tokens 0..i, so a copy of the donor's slab IS the
    destination's fresh prefill, and the generated bytes that ride on it
    (`rust/tests/step_equivalence.rs` solo-vs-shared) stay identical."""
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    batch = 3
    tokens, plens = _prompts(batch, seed=21)
    # Reference: row 2 freshly prefilled with row 0's prompt.
    want_tokens = np.asarray(tokens).copy()
    want_tokens[2] = np.asarray(tokens)[0]
    want_plens = np.asarray(plens).copy()
    want_plens[2] = np.asarray(plens)[0]
    last_want, caches_want = prefill(params, jnp.asarray(want_tokens),
                                     jnp.asarray(want_plens), cfg, attn)
    # Shared path: prefill the original batch (row 2 holds an unrelated
    # prompt — the previous occupant), then row-copy 0 -> 2.
    _, caches = prefill(params, tokens, plens, cfg, attn)
    copied = kv_row_copy(caches, jnp.asarray([0], jnp.int32),
                         jnp.asarray([2], jnp.int32))
    for i, (cw, cc) in enumerate(zip(caches_want, copied)):
        np.testing.assert_array_equal(
            np.asarray(cc)[2], np.asarray(cw)[2],
            err_msg=f"buffer {i}: copied row != fresh prefill "
                    f"(attn={attn})")
    # And the copied row's next decode emits bitwise the logits of the
    # freshly-prefilled row (what sampling actually consumes).
    nxt = jnp.asarray([[int(np.asarray(tokens)[0, plens[0] - 1]), 17, 42]],
                      jnp.int32)
    lens = jnp.asarray([int(plens[0]) - 1], np.int32)
    solo_w = [c[2:3] for c in caches_want]
    solo_c = [c[2:3] for c in copied]
    l_w, _ = decode(params, nxt, lens, solo_w, cfg, attn)
    l_c, _ = decode(params, nxt, lens, solo_c, cfg, attn)
    np.testing.assert_array_equal(
        np.asarray(l_c), np.asarray(l_w),
        err_msg=f"next-step logits differ after row copy (attn={attn})")


def test_kv_row_copy_leaves_other_rows_untouched():
    """Only the destination row changes; src == dst is the identity."""
    cfg = _SCATTER_CFG
    before = _garbage_cache(cfg, 4)
    # Make the donor row distinguishable from the 7.5 fill.
    before = [c.at[1].set(float(i + 1)) for i, c in enumerate(before)]
    after = kv_row_copy(before, jnp.asarray([1], jnp.int32),
                        jnp.asarray([3], jnp.int32))
    for i, (b, a) in enumerate(zip(before, after)):
        np.testing.assert_array_equal(
            np.asarray(a)[3], np.asarray(b)[1],
            err_msg=f"buffer {i}: dst row != src row")
        for r in (0, 1, 2):
            np.testing.assert_array_equal(
                np.asarray(a)[r], np.asarray(b)[r],
                err_msg=f"buffer {i}: row {r} changed")
    ident = kv_row_copy(before, jnp.asarray([2], jnp.int32),
                        jnp.asarray([2], jnp.int32))
    for i, (b, a) in enumerate(zip(before, ident)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"buffer {i}: src == dst is not the identity")


def test_kv_row_copy_artifact_lowers_weightless_with_donation():
    """The aot grid entry: `kv_row_copy` lowers weightless — two s32[1]
    row indices plus the donated (batch,)-fused caches, nothing else —
    and mirrors prefill_scatter's b>1 reachability (a one-row store has
    no donor row)."""
    from compile.aot import grid, lower_artifact
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    batch = 4
    text = lower_artifact(cfg, params, "kv_row_copy", batch, 0, "dense")
    assert text.startswith("HloModule")
    entry = text.splitlines()[0]
    assert "input_output_alias" in entry, "cache donation lost"
    assert "s32[1]" in entry, "src/dst rows are not s32[1]"
    cache = (f"f32[{batch},{cfg.n_head},{cfg.s_max},"
             f"{cfg.d_model // cfg.n_head}]")
    assert cache in entry, f"caches are not (batch,)-shaped: want {cache}"
    # Weightless: the only f32 inputs are the 2·n_layer cache buffers.
    assert entry.count(cache) >= 2 * cfg.n_layer
    assert "f32[256," not in entry, "embedding weights leaked into the ABI"

    specs = list(grid(quick=False))
    scatters = {(m, prec, b) for (m, prec, ph, b, _, _) in specs
                if ph == "prefill_scatter"}
    copies = {(m, prec, b, q) for (m, prec, ph, b, q, _) in specs
              if ph == "kv_row_copy"}
    assert {(m, prec, b) for (m, prec, b, _) in copies} == scatters, \
        "kv_row_copy grid does not mirror the prefill_scatter grid"
    assert all(q == 0 for (_, _, _, q) in copies)
    assert all(b > 1 for (_, _, b, _) in copies), \
        "unreachable b=1 kv_row_copy artifact exported"


# ---------------------------------------------------------------------------
# Recompute-resume vs incremental KV (preemption's suspend/resume)
# ---------------------------------------------------------------------------

_RESUME_P = 24


def _incremental_session(attn, use_jit, seed=0):
    """Mirror the Rust engine's incremental flow: prefill a prompt (valid
    length = plen - 1, last token pending), then a few speculative-shaped
    decode rounds — Q = k+1 with partial accepts, so rejected drafts leave
    stale tail KV exactly like rejection rollback. Returns the verified
    byte stream, its caches and the valid length."""
    pf = jax.jit(prefill, static_argnums=(3, 4)) if use_jit else prefill
    dc = jax.jit(decode, static_argnums=(4, 5)) if use_jit else decode
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    rng = np.random.default_rng(seed)

    prompt = rng.integers(1, 256, size=(7,)).astype(np.int32).tolist()
    toks = np.zeros((1, _RESUME_P), np.int32)
    toks[0, : len(prompt)] = prompt
    _, caches = pf(params, jnp.asarray(toks),
                   jnp.asarray([len(prompt)], np.int32), cfg, attn)
    seq_len = len(prompt) - 1
    stream = list(prompt)

    # (k, accepted): full accept, partial, zero-accept, and a Q=2 round —
    # the draft resync shape — so several distinct decode programs write
    # the KV this session later recomputes with one prefill program.
    for k, acc in [(4, 4), (2, 1), (1, 0), (3, 2)]:
        pending = stream[seq_len]
        drafts = rng.integers(1, 256, size=(k,)).astype(np.int32).tolist()
        q_toks = jnp.asarray([[pending] + drafts], jnp.int32)
        _, caches = dc(params, q_toks, jnp.asarray([seq_len], np.int32),
                       caches, cfg, attn)
        corrected = int(rng.integers(1, 256))
        stream = stream[: seq_len + 1] + drafts[:acc] + [corrected]
        seq_len += 1 + acc
    assert seq_len == len(stream) - 1 and len(stream) <= _RESUME_P
    return pf, dc, stream, caches, seq_len


@pytest.mark.parametrize("attn", ["dense", "pallas"])
@pytest.mark.parametrize("use_jit", [False, True])
def test_resume_recompute_is_bitwise_identical(attn, use_jit):
    """prefill(prompt ‖ generated) must reproduce the incrementally built
    KV **bit for bit** over the valid region (positions 0..L-2; position
    L-1 is the pending byte both runs re-ingest next step), and the next
    decode from either cache must emit bitwise-equal logits. This is the
    whole soundness argument for suspend/resume-by-recompute: per-query
    masking is exact-zero outside the valid prefix, KV at position i is a
    pure function of tokens 0..i, and the reduction order per output
    element does not depend on the program's Q shape. Tolerance-based
    closeness would NOT be enough — the Rust identity harness compares
    generated bytes, which ride on these values bit-for-bit."""
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    pf, dc, stream, caches, seq_len = _incremental_session(attn, use_jit)
    L = len(stream)

    toks = np.zeros((1, _RESUME_P), np.int32)
    toks[0, :L] = stream
    _, recomputed = pf(params, jnp.asarray(toks),
                       jnp.asarray([L], np.int32), cfg, attn)

    for i, (ci, cr) in enumerate(zip(caches, recomputed)):
        np.testing.assert_array_equal(
            np.asarray(ci)[0, :, : L - 1], np.asarray(cr)[0, :, : L - 1],
            err_msg=f"cache buffer {i}: recompute != incremental "
                    f"(attn={attn}, jit={use_jit})")

    nxt = jnp.asarray([[stream[-1], 17, 42]], jnp.int32)
    lens = jnp.asarray([seq_len], np.int32)
    l_inc, _ = dc(params, nxt, lens, [jnp.array(c) for c in caches], cfg,
                  attn)
    l_rec, _ = dc(params, nxt, lens, [jnp.array(c) for c in recomputed],
                  cfg, attn)
    np.testing.assert_array_equal(
        np.asarray(l_inc), np.asarray(l_rec),
        err_msg=f"next-step logits differ (attn={attn}, jit={use_jit})")


def test_resume_recompute_scatter_into_running_batch():
    """The PAD mid-flight resume path: scattering the recomputed context
    into a husk row of a running fused cache equals the incremental row
    bitwise, and leaves the co-resident rows untouched."""
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    _, _, stream, caches, _ = _incremental_session("dense", False)
    L = len(stream)

    # A running bucket of 3: garbage occupants, the target row is 1.
    fused = _garbage_cache(cfg, 3)
    toks = np.zeros((1, _RESUME_P), np.int32)
    toks[0, :L] = stream
    _, fused = prefill_scatter(params, jnp.asarray(toks),
                               jnp.asarray([L], np.int32),
                               jnp.asarray([1], jnp.int32), fused, cfg,
                               "dense")
    for i, (ci, cf) in enumerate(zip(caches, fused)):
        np.testing.assert_array_equal(
            np.asarray(ci)[0, :, : L - 1], np.asarray(cf)[1, :, : L - 1],
            err_msg=f"buffer {i}: scatter-resume row != incremental")
        for row in (0, 2):
            np.testing.assert_array_equal(
                np.asarray(cf)[row], 7.5 * np.ones_like(np.asarray(cf)[row]),
                err_msg=f"buffer {i}: co-resident row {row} touched")


# ---------------------------------------------------------------------------
# Ragged co-batched decode (per-sequence draft lengths)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("attn", ["dense", "pallas"])
def test_ragged_cobatch_decode_matches_solo(attn):
    """Per-sequence draft lengths make verify launches RAGGED: a row
    drafting k_i rides a program sized by the batch max, its Q axis
    carrying k_i+1 real tokens and junk filler after them. Soundness
    rests on two exact properties, both pinned here **bitwise**: (1) a
    row's logits at its real q positions are unaffected by the trailing
    filler (causal masking — a later position cannot feed an earlier
    output), and (2) they are unaffected by the co-batched row entirely
    (row independence). The Rust engine's per-row `k_i` loop
    (`DraftIo::klens` / `VerifyIo::qlens`, rust/src/spec/backend.rs)
    samples from these logits byte-for-byte, so tolerance-based
    closeness would not be enough."""
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    rng = np.random.default_rng(3)

    ctx_a = rng.integers(1, 256, size=(5,)).astype(np.int32).tolist()
    ctx_b = rng.integers(1, 256, size=(9,)).astype(np.int32).tolist()
    k_short, k_long = 1, 4          # row A drafts 1, row B drafts 4
    q = k_long + 1                  # launch width = batch max k + 1
    qa = [ctx_a[-1]] + rng.integers(
        1, 256, size=(k_short,)).astype(np.int32).tolist()
    qb = [ctx_b[-1]] + rng.integers(
        1, 256, size=(k_long,)).astype(np.int32).tolist()

    def solo(ctx, q_toks):
        """The row alone, decoded at exactly its own q length."""
        toks = np.zeros((1, _P), np.int32)
        toks[0, : len(ctx)] = ctx
        _, caches = prefill(params, jnp.asarray(toks),
                            jnp.asarray([len(ctx)], np.int32), cfg, attn)
        logits, _ = decode(params, jnp.asarray([q_toks], jnp.int32),
                           jnp.asarray([len(ctx) - 1], np.int32),
                           caches, cfg, attn)
        return np.asarray(logits[0])

    want_a = solo(ctx_a, qa)        # a Q = k_short+1 program
    want_b = solo(ctx_b, qb)        # a Q = k_long+1 program

    # Co-batched: one fused prefill, one decode at the launch width. Row
    # A's q is padded past its k_short+1 real tokens with a deliberately
    # nonzero filler byte a correct mask must render inert.
    toks = np.zeros((2, _P), np.int32)
    toks[0, : len(ctx_a)] = ctx_a
    toks[1, : len(ctx_b)] = ctx_b
    plens = jnp.asarray([len(ctx_a), len(ctx_b)], np.int32)
    _, caches = prefill(params, jnp.asarray(toks), plens, cfg, attn)
    q_toks = np.full((2, q), 213, np.int32)
    q_toks[0, : k_short + 1] = qa
    q_toks[1] = qb
    seq_lens = jnp.asarray([len(ctx_a) - 1, len(ctx_b) - 1], np.int32)
    logits, _ = decode(params, jnp.asarray(q_toks), seq_lens, caches,
                       cfg, attn)
    got = np.asarray(logits)

    np.testing.assert_array_equal(
        got[0, : k_short + 1], want_a,
        err_msg=f"short row's real-position logits != solo (attn={attn})")
    np.testing.assert_array_equal(
        got[1], want_b,
        err_msg=f"long row's logits != solo (attn={attn})")


# ---------------------------------------------------------------------------
# Packed segment layout vs BASS-PAD (ExecMode::Packed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("attn", ["dense", "pallas"])
def test_packed_decode_matches_pad_bitwise(attn):
    """`decode_packed` lays the ragged rows back-to-back in one [1, C]
    stream; every real packed position must be **bitwise** the logits the
    rectangular PAD launch produces for the same (row, q) — and the KV a
    row appends must land byte-identical over its valid region. This is
    the soundness contract of ExecMode::Packed: the Rust engine samples
    from the unpacked logits byte-for-byte, so a packed co-batched run
    stays byte-identical to PAD/solo (rust/tests/step_equivalence.rs)."""
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    rng = np.random.default_rng(11)
    ctx_lens = [5, 9, 7]
    qlens = [2, 5, 3]               # ragged: k_i + 1 for k = 1, 4, 2
    q_launch = max(qlens)
    b = len(ctx_lens)

    toks = np.zeros((b, _P), np.int32)
    ctxs = []
    for i, n in enumerate(ctx_lens):
        ctx = rng.integers(1, 256, size=(n,)).astype(np.int32)
        toks[i, :n] = ctx
        ctxs.append(ctx)
    plens = jnp.asarray(ctx_lens, np.int32)
    _, caches = prefill(params, jnp.asarray(toks), plens, cfg, attn)
    seq_lens = jnp.asarray([n - 1 for n in ctx_lens], np.int32)

    q_rows = [np.concatenate([[ctxs[i][-1]],
                              rng.integers(1, 256, size=(qlens[i] - 1,))])
              .astype(np.int32) for i in range(b)]

    # PAD: rectangular launch at the batch max, nonzero junk filler.
    q_pad = np.full((b, q_launch), 213, np.int32)
    for i in range(b):
        q_pad[i, : qlens[i]] = q_rows[i]
    logits_pad, caches_pad = decode(params, jnp.asarray(q_pad), seq_lens,
                                    caches, cfg, attn)
    logits_pad = np.asarray(logits_pad)

    # Packed: C = b·q' capacity with a filler tail past qoffs[B].
    c_tok = b * q_launch
    qoffs = np.concatenate([[0], np.cumsum(qlens)]).astype(np.int32)
    packed = np.full((1, c_tok), 213, np.int32)
    for i in range(b):
        packed[0, qoffs[i]: qoffs[i + 1]] = q_rows[i]
    logits_pk, caches_pk = decode_packed(params, jnp.asarray(packed),
                                         jnp.asarray(qoffs), seq_lens,
                                         caches, cfg, attn)
    logits_pk = np.asarray(logits_pk)

    for i in range(b):
        np.testing.assert_array_equal(
            logits_pk[0, qoffs[i]: qoffs[i + 1]],
            logits_pad[i, : qlens[i]],
            err_msg=f"row {i}: packed logits != PAD (attn={attn})")
    for bi, (cp, ck) in enumerate(zip(caches_pad, caches_pk)):
        cp, ck = np.asarray(cp), np.asarray(ck)
        for i in range(b):
            valid = ctx_lens[i] - 1 + qlens[i]
            np.testing.assert_array_equal(
                ck[i, :, :valid], cp[i, :, :valid],
                err_msg=f"buffer {bi} row {i}: packed KV != PAD "
                        f"(attn={attn})")


def test_draft_packed_matches_draft_loop_bitwise():
    """`draft_packed` must be the identity reshape of `draft_loop`: same
    tokens, q-distributions and caches when the packed-prefix uniforms
    spell the same per-(row, step) values, with the packed tail unused
    and out-of-range steps consuming the PAD filler 0.0."""
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    rng = np.random.default_rng(13)
    b, k_draft = 2, 4
    klens = [2, 4]

    toks = np.zeros((b, _P), np.int32)
    ctx_lens = [6, 4]
    for i, n in enumerate(ctx_lens):
        toks[i, :n] = rng.integers(1, 256, size=(n,))
    _, caches = prefill(params, jnp.asarray(toks),
                        jnp.asarray(ctx_lens, np.int32), cfg, "dense")
    seq_lens = jnp.asarray([n - 1 for n in ctx_lens], np.int32)
    tokens_in = jnp.asarray([[toks[i, ctx_lens[i] - 1], 0]
                             for i in range(b)], jnp.int32)
    n_in = jnp.ones((b,), jnp.int32)
    temps = jnp.asarray([0.7, 1.1], jnp.float32)
    tps = jnp.asarray([0.9, 0.95], jnp.float32)

    rect = np.zeros((b, k_draft), np.float32)
    for i in range(b):
        rect[i, : klens[i]] = rng.random(klens[i], np.float32)
    koffs = np.concatenate([[0], np.cumsum(klens)]).astype(np.int32)
    packed_u = np.zeros((b * k_draft,), np.float32)
    for i in range(b):
        packed_u[koffs[i]: koffs[i + 1]] = rect[i, : klens[i]]

    want_t, want_q, want_c = draft_loop(
        params, tokens_in, n_in, seq_lens, caches, jnp.asarray(rect),
        temps, tps, cfg, "dense")
    got_t, got_q, got_c = draft_packed(
        params, tokens_in, n_in, seq_lens, caches, jnp.asarray(koffs),
        jnp.asarray(packed_u), temps, tps, k_draft, cfg, "dense")
    got_t, got_q = np.asarray(got_t), np.asarray(got_q)
    want_t, want_q = np.asarray(want_t), np.asarray(want_q)

    for i in range(b):
        for j in range(klens[i]):
            assert got_t[koffs[i] + j] == want_t[i, j], (i, j)
            np.testing.assert_array_equal(
                got_q[koffs[i] + j], want_q[i, j],
                err_msg=f"row {i} step {j}: packed qdist != rectangular")
    assert np.all(got_t[koffs[-1]:] == 0), "packed tail not zeroed"
    for bi, (cw, cg) in enumerate(zip(want_c, got_c)):
        np.testing.assert_array_equal(
            np.asarray(cg), np.asarray(cw),
            err_msg=f"buffer {bi}: packed draft caches != rectangular")


def test_packed_artifact_lowers_with_offset_specs():
    """The aot grid entries: `decode_packed` lowers with a [1, C] packed
    token stream, s32[B+1] offsets and donated (batch,)-fused caches;
    `draft_packed` with s32[B+1] koffs and a flat f32[B·K] uniform
    buffer — the offset ABI `Engine::decode_packed`/`draft_packed`
    feeds. Grid coverage: one packed artifact per (batch, bucket) pair
    mirroring the rectangular grids."""
    from compile.aot import PACKED_Q_BUCKETS, grid, lower_artifact
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    batch, q = 2, 3
    text = lower_artifact(cfg, params, "decode_packed", batch, q, "dense")
    assert text.startswith("HloModule")
    entry = text.splitlines()[0]
    assert "input_output_alias" in entry, "cache donation lost"
    assert f"s32[1,{batch * q}]" in entry, "tokens are not packed [1, C]"
    assert f"s32[{batch + 1}]" in entry, "qoffs are not s32[B+1]"

    text = lower_artifact(cfg, params, "draft_packed", batch, q, "dense")
    entry = text.splitlines()[0]
    assert "input_output_alias" in entry, "cache donation lost"
    assert f"s32[{batch + 1}]" in entry, "koffs are not s32[B+1]"
    assert f"f32[{batch * q}]" in entry, "uniforms are not flat [B*K]"

    specs = list(grid(quick=False))
    decodes = {(prec, bb) for (m, prec, ph, bb, _, _) in specs
               if ph == "decode"}
    packs = {(prec, bb, qq) for (m, prec, ph, bb, qq, _) in specs
             if ph == "decode_packed"}
    assert {(p, bb) for (p, bb, _) in packs} == decodes
    assert {qq for (_, _, qq) in packs} == set(PACKED_Q_BUCKETS)
    drafts = {(m, prec, bb, kk) for (m, prec, ph, bb, kk, _) in specs
              if ph == "draft"}
    dpacks = {(m, prec, bb, kk) for (m, prec, ph, bb, kk, _) in specs
              if ph == "draft_packed"}
    assert dpacks == drafts, "draft_packed grid does not mirror draft"


def test_scatter_prefill_artifact_lowers_with_batch_correct_specs():
    """The aot grid entry: `prefill_scatter` lowers with (batch,)-shaped
    donated caches, B=1 prompt inputs, an s32[1] row index, and cache
    donation surviving to the HLO entry (input_output_alias) — the ABI
    `Engine::prefill_into_slot` feeds."""
    from compile.aot import grid, lower_artifact
    cfg, params = _SCATTER_CFG, _SCATTER_PARAMS
    batch = 2
    text = lower_artifact(cfg, params, "prefill_scatter", batch, _P,
                          "dense")
    assert text.startswith("HloModule")
    assert "topk(" not in text and "largest=true" not in text
    entry = text.splitlines()[0]
    assert "input_output_alias" in entry, "cache donation lost"
    assert f"s32[1,{_P}]" in entry, "prompt tokens are not [1, P]"
    assert "s32[1]" in entry, "prompt_len/row are not s32[1]"
    cache = (f"f32[{batch},{cfg.n_head},{cfg.s_max},"
             f"{cfg.d_model // cfg.n_head}]")
    assert cache in entry, f"caches are not (batch,)-shaped: want {cache}"

    # Grid coverage: one scatter artifact per (model, precision, batch)
    # at prefill capacity, for every exported bucket EXCEPT 1 — a one-row
    # PAD batch auto-resets when its only sequence retires, so a b=1
    # scatter program could never be invoked.
    specs = list(grid(quick=False))
    prefills = {(m, prec, b) for (m, prec, ph, b, _, _) in specs
                if ph == "prefill" and b > 1}
    scatters = {(m, prec, b, q) for (m, prec, ph, b, q, _) in specs
                if ph == "prefill_scatter"}
    assert {(m, prec, b) for (m, prec, b, _) in scatters} == prefills, \
        "prefill_scatter grid does not mirror the b>1 prefill grid"
    assert all(b > 1 for (_, _, b, _) in scatters), \
        "unreachable b=1 scatter artifact exported"
    from compile.aot import PREFILL_P
    assert all(q == PREFILL_P for (_, _, _, q) in scatters)
