#!/usr/bin/env python3
"""CI gate for BENCH_serving.json (schema bass-serving-bench/v2).

v2 = v1 + the per-scenario "draft" section (draft_len / acceptance_rate
distributions across requests), added when the engine switched to one
adaptive draft-length controller per sequence. Draft stats are
wall-clock-independent but policy-dependent, so they are schema-checked
(present; numeric or explicit null for an empty sample set; p50 <= p99
when both are numbers) yet never counter-gated. Bare NaN/Infinity
tokens — or any non-finite number smuggled in elsewhere — are hard
failures: the emitter must write null, never NaN.

The per-scenario "observability" section (span summary + trace file
pointer from `serving --trace-out`) is schema-additive: ignored here
beyond the global finiteness walk, validated by scripts/check_trace.py.

The per-scenario "flops" section (launch / padded_launch step-FLOP
totals from the exec backends' launch accounting) is additive to v2:
optional to have — older reports predate it — but hard-checked when
present (numeric, 0 <= launch <= padded_launch; the packed backend's
zero-pad claim is exactly that gap).

The per-scenario "prefix_cache" section (prompt-prefix KV reuse:
cache lookups/hits/misses/evictions, executed KV row copies, prefill
FLOPs saved) follows the same additive pattern: optional to have,
hard-checked when present — every field numeric and non-negative, and
hits + misses == lookups (the counters are monotone engine-lifetime
echoes aggregated by max, which preserves the identity).

Three modes:

  diff_bench_serving.py CHECK run.json
      Schema/invariant checks on a single report (always hard).

  diff_bench_serving.py --determinism a.json b.json
      The perf-regression gate's deterministic half: the CI job runs the
      gate scenarios twice on the same machine and the two reports'
      `counters` blocks must match **bit for bit** (the gate workload
      pins fan-out to 1, so counters are a function of the scenario seed
      alone — any drift is a real behavior change, not timing noise).
      Hard failure on any difference.

  diff_bench_serving.py --baseline BENCH_serving.json run.json [--update]
      Compare a fresh run against the committed baseline. `counters`
      must match exactly; wall-clock sections (latency/goodput/overhead)
      are reported but never gated (machine-dependent). While the
      baseline is marked `"generated_by": "bootstrap-estimate"` the
      counters comparison is *advisory* (the baseline was hand-estimated
      before a toolchain could run the harness); regenerate it with

          cargo run --release -- serving --deterministic --arrival both \
              --requests 96 --rate 400 --seed 7 --out run.json
          python3 scripts/diff_bench_serving.py \
              --baseline BENCH_serving.json run.json --update

      after which the gate is hard. `--update` rewrites the baseline
      from the run (clearing the bootstrap marker).

Exit status: 0 clean/advisory, 1 hard failure.
"""

import argparse
import json
import math
import sys

SCHEMA = "bass-serving-bench/v2"
BOOTSTRAP = "bootstrap-estimate"
LATENCY_METRICS = ("ttft_ms", "tpot_ms", "e2e_ms", "queue_ms")
DRAFT_METRICS = ("draft_len", "acceptance_rate")
STATS = ("mean", "p50", "p99")
COUNTER_KEYS = ("n_requests", "n_seqs_requested", "total_tokens",
                "all_finished")


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def _reject_constant(token):
    # json.load() happily parses bare NaN/Infinity (invalid JSON that
    # a buggy emitter writes unquoted); the report contract is finite
    # numbers or explicit null, so these are hard failures.
    raise ValueError(f"non-finite JSON token {token!r}")


def _assert_finite(node, path, where="$"):
    """Recursively reject non-finite numbers anywhere in the report."""
    if isinstance(node, float) and not math.isfinite(node):
        fail(f"{path}: non-finite number at {where}")
    elif isinstance(node, dict):
        for key, value in node.items():
            _assert_finite(value, path, f"{where}.{key}")
    elif isinstance(node, list):
        for i, value in enumerate(node):
            _assert_finite(value, path, f"{where}[{i}]")


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f, parse_constant=_reject_constant)
    except (OSError, ValueError) as e:
        fail(f"{path}: {e}")
    _assert_finite(doc, path)
    return doc


def check_report(doc, path):
    """Hard schema + internal-consistency invariants for one report."""
    if doc.get("schema") != SCHEMA:
        fail(f"{path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    for key in ("generated_by", "driver", "mode", "scenarios"):
        if key not in doc:
            fail(f"{path}: missing top-level key {key!r}")
    if not doc["scenarios"]:
        fail(f"{path}: empty scenarios")
    for s in doc["scenarios"]:
        name = s.get("name", "<unnamed>")
        for section in ("arrival", "workload", "latency", "goodput",
                        "overhead", "draft", "counters"):
            if section not in s:
                fail(f"{path}:{name}: missing section {section!r}")
        for section, metrics in (("latency", LATENCY_METRICS),
                                 ("draft", DRAFT_METRICS)):
            for metric in metrics:
                m = s[section].get(metric)
                if m is None:
                    fail(f"{path}:{name}: {section} missing {metric!r}")
                for stat in STATS:
                    if stat not in m:
                        fail(f"{path}:{name}: {metric} missing {stat!r}")
                    # Explicit null = empty sample set (e.g. every
                    # request expired unserved) — allowed; anything
                    # else must be a number.
                    if m[stat] is not None and not isinstance(
                            m[stat], (int, float)):
                        fail(f"{path}:{name}: {metric}.{stat} "
                             f"not a number")
                if (isinstance(m["p50"], (int, float))
                        and isinstance(m["p99"], (int, float))
                        and m["p50"] > m["p99"]):
                    fail(f"{path}:{name}: {metric} p50 {m['p50']} > "
                         f"p99 {m['p99']}")
        g, c = s["goodput"], s["counters"]
        for key in COUNTER_KEYS:
            if key not in c:
                fail(f"{path}:{name}: counters missing {key!r}")
        if not (0 <= g["within_slo"] <= g["served"] <= c["n_requests"]):
            fail(f"{path}:{name}: within_slo {g['within_slo']} <= served "
                 f"{g['served']} <= n_requests {c['n_requests']} violated")
        if c["n_seqs_requested"] < c["n_requests"]:
            fail(f"{path}:{name}: n_seqs_requested {c['n_seqs_requested']}"
                 f" < n_requests {c['n_requests']}")
        if c["all_finished"] and c["total_tokens"] <= 0:
            fail(f"{path}:{name}: all_finished with zero total_tokens")
        # "flops" is additive (reports written before the packed backend
        # lack it): optional to *have*, hard to get *wrong*. The packed
        # backend's whole claim is launch <= padded_launch.
        fl = s.get("flops")
        if fl is not None:
            for key in ("launch", "padded_launch"):
                if not isinstance(fl.get(key), (int, float)):
                    fail(f"{path}:{name}: flops.{key} not a number")
            if fl["launch"] < 0 or fl["launch"] > fl["padded_launch"]:
                fail(f"{path}:{name}: flops.launch {fl['launch']} "
                     f"outside [0, padded_launch "
                     f"{fl['padded_launch']}]")
        # "prefix_cache" is additive like "flops": optional to *have*,
        # hard to get *wrong*. The load-bearing identity is
        # hits + misses == lookups (every probe is exactly one of the
        # two), which max-of-monotone-echo aggregation must preserve.
        pc = s.get("prefix_cache")
        if pc is not None:
            for key in ("lookups", "hits", "misses", "evictions",
                        "row_copies", "saved_flops"):
                v = pc.get(key)
                if not isinstance(v, (int, float)) or v < 0:
                    fail(f"{path}:{name}: prefix_cache.{key} "
                         f"not a non-negative number: {v!r}")
            if pc["hits"] + pc["misses"] != pc["lookups"]:
                fail(f"{path}:{name}: prefix_cache tally broken: "
                     f"hits {pc['hits']} + misses {pc['misses']} "
                     f"!= lookups {pc['lookups']}")
    print(f"ok: {path} passes {SCHEMA} invariants "
          f"({len(doc['scenarios'])} scenario(s))")


def counters_by_name(doc):
    return {s["name"]: s["counters"] for s in doc["scenarios"]}


def diff_counters(a, b, a_path, b_path):
    """Return a list of human-readable counter differences."""
    diffs = []
    ca, cb = counters_by_name(a), counters_by_name(b)
    for name in sorted(set(ca) | set(cb)):
        if name not in ca:
            diffs.append(f"scenario {name!r} only in {b_path}")
            continue
        if name not in cb:
            diffs.append(f"scenario {name!r} only in {a_path}")
            continue
        for key in sorted(set(ca[name]) | set(cb[name])):
            va, vb = ca[name].get(key), cb[name].get(key)
            if va != vb:
                diffs.append(f"{name}.counters.{key}: "
                             f"{va!r} ({a_path}) != {vb!r} ({b_path})")
    return diffs


def show_advisory(base, run):
    """Print wall-clock section movement — never gated."""
    by_name = {s["name"]: s for s in base["scenarios"]}
    for s in run["scenarios"]:
        b = by_name.get(s["name"])
        if b is None:
            continue
        for metric in LATENCY_METRICS:
            cur = s["latency"][metric]["p99"]
            ref = b["latency"][metric]["p99"]
            if cur is None or ref is None:
                # Empty sample set on either side: no movement to show.
                continue
            delta = cur - ref
            print(f"  {s['name']}.{metric}.p99: {ref:.3g} -> {cur:.3g} "
                  f"({delta:+.3g} ms, advisory)")
        cur = s["goodput"]["goodput_rps"]
        ref = b["goodput"]["goodput_rps"]
        print(f"  {s['name']}.goodput_rps: {ref:.4g} -> {cur:.4g} "
              f"(advisory)")


def main():
    ap = argparse.ArgumentParser(
        description="BENCH_serving.json invariant/diff gate")
    ap.add_argument("--determinism", nargs=2, metavar=("A", "B"),
                    help="hard bit-for-bit counters diff of two runs")
    ap.add_argument("--baseline", nargs=2, metavar=("BASELINE", "RUN"),
                    help="compare RUN's counters against BASELINE")
    ap.add_argument("--update", action="store_true",
                    help="with --baseline: rewrite BASELINE from RUN")
    ap.add_argument("report", nargs="?",
                    help="single report to invariant-check")
    args = ap.parse_args()

    if args.determinism:
        a_path, b_path = args.determinism
        a, b = load(a_path), load(b_path)
        check_report(a, a_path)
        check_report(b, b_path)
        diffs = diff_counters(a, b, a_path, b_path)
        if diffs:
            for d in diffs:
                print(f"  {d}", file=sys.stderr)
            fail("counters differ between identical-seed runs — "
                 "the deterministic gate workload drifted")
        print("ok: deterministic counters identical across runs")
        return

    if args.baseline:
        base_path, run_path = args.baseline
        base, run = load(base_path), load(run_path)
        check_report(base, base_path)
        check_report(run, run_path)
        if args.update:
            run = dict(run)
            run["generated_by"] = (
                f"scripts/diff_bench_serving.py --update "
                f"(from {run.get('generated_by', '?')})")
            with open(base_path, "w") as f:
                json.dump(run, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"ok: {base_path} updated from {run_path}")
            return
        diffs = diff_counters(base, run, base_path, run_path)
        advisory = base.get("generated_by") == BOOTSTRAP
        show_advisory(base, run)
        if diffs:
            for d in diffs:
                print(f"  {d}", file=sys.stderr)
            if advisory:
                print("warn: counters differ from the bootstrap-estimate "
                      "baseline (advisory until regenerated with "
                      "--update)")
            else:
                fail("counters regressed against the committed baseline")
        else:
            print("ok: counters match the committed baseline")
        return

    if not args.report:
        ap.error("give a report path, or --determinism / --baseline")
    check_report(load(args.report), args.report)


if __name__ == "__main__":
    main()
