#!/usr/bin/env python3
"""Validate a Chrome trace exported by `bass serving --trace-out`.

The exporter (rust/src/obs/trace.rs) writes one JSON object per
scenario: {"traceEvents": [...], "displayTimeUnit": "ms",
"otherData": {"dropped_spans": N}}. Events use pid 1 and tid = the
owning request id (lane 0 is the engine-wide lane); duration spans are
complete `X` events, lifecycle markers are thread-scoped `i` instants,
and each lane leads with a `thread_name` `M` metadata record. Data
events are sorted by start timestamp, so `ts` must be non-decreasing
in file order.

Checks (all hard, exit 1 on the first failure):

  * top-level shape: non-empty traceEvents list, numeric
    otherData.dropped_spans >= 0;
  * per event: ph in {X, B, E, i, M}; non-metadata events carry name,
    cat, pid, tid and a finite ts >= 0; X events a finite dur >= 0;
  * no bare NaN/Infinity tokens anywhere (they are invalid JSON that
    Python's json module would otherwise accept silently);
  * ts non-decreasing across data events in file order;
  * B/E begin/end events (not currently emitted, but legal Chrome
    trace) balance per tid.

With --report BENCH.json --scenario NAME the trace is cross-checked
against the serving report: the set of distinct request lanes that
received an `admit` instant must have exactly counters.n_requests
members, and every non-zero lane appearing anywhere in the trace must
be one of those admitted lanes (no orphan swimlanes).

Usage:
  check_trace.py TRACE.json [--report BENCH.json --scenario NAME]
"""

import argparse
import json
import math
import sys

VALID_PH = {"X", "B", "E", "i", "M"}


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def _reject_constant(token):
    raise ValueError(f"non-finite JSON token {token!r}")


def load(path):
    try:
        with open(path) as f:
            return json.load(f, parse_constant=_reject_constant)
    except (OSError, ValueError) as e:
        fail(f"{path}: {e}")


def _finite_number(v):
    return (isinstance(v, (int, float)) and not isinstance(v, bool)
            and math.isfinite(v))


def check_trace(doc, path):
    if not isinstance(doc, dict):
        fail(f"{path}: top level is not an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")
    dropped = doc.get("otherData", {}).get("dropped_spans")
    if not _finite_number(dropped) or dropped < 0:
        fail(f"{path}: otherData.dropped_spans missing or negative")
    if dropped > 0:
        print(f"warn: {path}: {int(dropped)} span(s) dropped "
              f"(ring capacity exceeded)", file=sys.stderr)

    last_ts = None
    open_begins = {}  # tid -> depth of unmatched B events
    counts = {"X": 0, "i": 0, "M": 0, "B": 0, "E": 0}
    for i, ev in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(ev, dict):
            fail(f"{where}: not an object")
        ph = ev.get("ph")
        if ph not in VALID_PH:
            fail(f"{where}: ph {ph!r} not in {sorted(VALID_PH)}")
        counts[ph] += 1
        if not _finite_number(ev.get("tid")):
            fail(f"{where}: tid missing or non-numeric")
        if ev.get("pid") != 1:
            fail(f"{where}: pid {ev.get('pid')!r} != 1")
        if ph == "M":
            continue
        for key in ("name", "cat"):
            if not isinstance(ev.get(key), str) or not ev[key]:
                fail(f"{where}: missing {key!r}")
        ts = ev.get("ts")
        if not _finite_number(ts) or ts < 0:
            fail(f"{where}: ts {ts!r} not a finite number >= 0")
        if last_ts is not None and ts < last_ts:
            fail(f"{where}: ts {ts} < previous {last_ts} "
                 f"(file order must be non-decreasing)")
        last_ts = ts
        if ph == "X":
            dur = ev.get("dur")
            if not _finite_number(dur) or dur < 0:
                fail(f"{where}: X event dur {dur!r} not a finite "
                     f"number >= 0")
        elif ph == "B":
            open_begins[ev["tid"]] = open_begins.get(ev["tid"], 0) + 1
        elif ph == "E":
            depth = open_begins.get(ev["tid"], 0)
            if depth == 0:
                fail(f"{where}: E without matching B on tid "
                     f"{ev['tid']}")
            open_begins[ev["tid"]] = depth - 1
    unbalanced = {t: d for t, d in open_begins.items() if d}
    if unbalanced:
        fail(f"{path}: unmatched B events on tids {sorted(unbalanced)}")
    if counts["X"] == 0:
        fail(f"{path}: no complete (X) spans recorded")
    print(f"ok: {path} is a valid Chrome trace "
          f"({counts['X']} spans, {counts['i']} instants, "
          f"{counts['M']} lanes)")
    return events


def cross_check(events, report_path, scenario):
    doc = load(report_path)
    by_name = {s.get("name"): s for s in doc.get("scenarios", [])}
    s = by_name.get(scenario)
    if s is None:
        fail(f"{report_path}: no scenario named {scenario!r} "
             f"(have {sorted(by_name)})")
    n_requests = s["counters"]["n_requests"]

    admitted = set()
    lanes = set()
    for ev in events:
        if ev.get("ph") == "M":
            continue
        lanes.add(ev["tid"])
        if ev.get("name") == "admit":
            admitted.add(ev["tid"])
    if len(admitted) != n_requests:
        fail(f"trace has {len(admitted)} admitted request lane(s) but "
             f"{report_path}:{scenario} counters.n_requests = "
             f"{n_requests}")
    orphans = {t for t in lanes if t != 0} - admitted
    if orphans:
        fail(f"trace lanes {sorted(orphans)} carry events but were "
             f"never admitted")
    print(f"ok: trace lanes match {report_path}:{scenario} "
          f"({n_requests} admitted requests, no orphan lanes)")


def main():
    ap = argparse.ArgumentParser(
        description="Chrome trace validator for bass --trace-out")
    ap.add_argument("trace", help="trace JSON file to validate")
    ap.add_argument("--report",
                    help="BENCH_serving.json to cross-check against")
    ap.add_argument("--scenario",
                    help="scenario name within --report")
    args = ap.parse_args()
    if bool(args.report) != bool(args.scenario):
        ap.error("--report and --scenario go together")

    events = check_trace(load(args.trace), args.trace)
    if args.report:
        cross_check(events, args.report, args.scenario)


if __name__ == "__main__":
    main()
