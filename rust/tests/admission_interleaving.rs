//! The pin behind PAD mid-flight admission **and preemption**: under
//! randomized admit/step/**suspend/resume**/retire schedules — mixed
//! fan-out, per-sequence sampling params and generation budgets, delayed
//! retirement, slot/row reuse, random mid-generation preemptions with
//! recompute-resume — every sequence must be **byte-identical** (and
//! logP-identical) to its solo one-shot run, in PAD, SPLIT and PACKED
//! execution modes.
//!
//! `step_equivalence.rs` pins a handful of hand-picked interleavings;
//! this harness replays hundreds of seeded PCG32-driven schedules so the
//! row-lifecycle edges (scatter-prefill into Husk vs Shadow rows, drain
//! auto-reset, delayed retirement, fan-out streams, suspension husks,
//! resumes into running buckets *and* into fresh ones, shared
//! admissions/resumes that row-copy a donor row's KV instead of
//! prefilling) are all crossed many times. Each admission pins its RNG stream and — since draft
//! lengths went per-sequence — BOTH policies keep a row's draft-length
//! trajectory batch-independent: `Policy::Fixed` trivially, and
//! `Policy::Heuristic` because every row runs its own Algorithm-1
//! controller fed only by its own acceptance and consumes exactly its
//! own `k_i` uniforms per step. So under either policy a sequence's
//! output is a pure function of (prompt, seed, stream, sampling params,
//! budget) — the invariant that makes continuous batching *and
//! preemptive scheduling* invisible to clients; the sweep runs once per
//! policy per mode.

use std::collections::HashMap;

use bass::bench_util::{artifacts_available, artifacts_root};
use bass::kv::{FinishReason, SeqState};
use bass::runtime::Engine;
use bass::sampling::Pcg32;
use bass::spec::{AdmitOpts, ExecMode, Policy, SeqId, SpecBatch, SpecConfig};
use bass::tokenizer;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

const PROMPTS: [&str; 3] = [
    "def add_7(x):\n    # adds 7 to x\n    return",
    "def mul_3(x):\n    return",
    "article: alice went to the market. summary:",
];
const PARAMS: [(f32, f32); 3] = [(0.2, 0.95), (0.8, 0.9), (1.5, 1.0)];
const BUDGETS: [usize; 4] = [4, 6, 9, 12];
const SEEDS: [u64; 4] = [3, 11, 42, 99];
const K: usize = 4;
const CAPACITY: usize = 4;
const SCHEDULES: u64 = 200;

/// Identity of one admission, drawn from small pools so solo reference
/// runs can be cached across schedules. `stream` is the pinned fan-out
/// index (requests with fan-out admit one plan per stream).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct Plan {
    prompt: usize,
    params: usize,
    budget: usize,
    seed_idx: usize,
    stream: u64,
}

fn base_cfg(mode: ExecMode, policy: Policy) -> SpecConfig {
    SpecConfig {
        max_new_tokens: 8,
        policy,
        mode,
        seed: 0,
        // Batch defaults deliberately unlike any plan's overrides, so an
        // override that fails to stick shows up as a byte divergence.
        temperature: 0.7,
        top_p: 0.85,
        ..SpecConfig::default()
    }
}

fn plan_inputs(p: Plan) -> (Vec<u8>, u64, AdmitOpts) {
    let (temperature, top_p) = PARAMS[p.params];
    (
        tokenizer::encode(PROMPTS[p.prompt]),
        SEEDS[p.seed_idx],
        AdmitOpts {
            max_new_tokens: Some(BUDGETS[p.budget]),
            stream: Some(p.stream),
            temperature: Some(temperature),
            top_p: Some(top_p),
        },
    )
}

/// The reference: the same admission alone in a one-slot batch, stepped
/// to completion with nothing else ever co-resident.
fn solo_run(e: &Engine, mode: ExecMode, policy: Policy, p: Plan)
            -> SeqState {
    let (prompt, seed, opts) = plan_inputs(p);
    let mut batch = SpecBatch::new(e, base_cfg(mode, policy), 1).unwrap();
    let id = batch.admit_opts(&prompt, seed, opts).unwrap();
    let mut guard = 0;
    while batch.has_active() {
        batch.step().unwrap();
        guard += 1;
        assert!(guard < 500, "runaway solo run");
    }
    batch.retire(id).unwrap()
}

/// Per-schedule outcome counters (what the harness must exercise).
#[derive(Default)]
struct ScheduleOutcome {
    /// Sequences completed and checked against their solo runs.
    checked: usize,
    /// Admissions that landed in a *running* batch (no drain between).
    midflight: usize,
    /// Mid-generation suspensions (preemptions).
    suspensions: usize,
    /// Resumes into a batch that was running at the time.
    resumes_midflight: usize,
    /// Live re-buckets that grew the running fused bucket (PAD only).
    grows: usize,
    /// Live re-buckets that shrank it (PAD only).
    shrinks: usize,
    /// Admissions that shared a resident row's prompt KV by row copy
    /// (`admit_shared_opts`) instead of prefilling their own.
    shared: usize,
    /// Resumes that rebuilt KV by row copy off a covering donor row
    /// (`resume_shared`) instead of recompute.
    resumes_shared: usize,
}

/// Replay one random schedule with random admissions, retirements AND
/// preemptions (suspend/resume-by-recompute).
fn run_schedule(e: &Engine, mode: ExecMode, policy: Policy,
                schedule: u64, solo: &mut HashMap<Plan, SeqState>)
                -> ScheduleOutcome {
    let mut rng = Pcg32::new(0xBA55_0000 + schedule, 1);
    let mut batch =
        SpecBatch::new(e, base_cfg(mode, policy), CAPACITY).unwrap();

    // Draw the admission list: 3..=6 requests, fan-out 1..=2 each.
    let mut pending: Vec<Plan> = Vec::new();
    let n_requests = 3 + (rng.next_u32() % 4) as usize;
    for _ in 0..n_requests {
        let prompt = (rng.next_u32() as usize) % PROMPTS.len();
        let params = (rng.next_u32() as usize) % PARAMS.len();
        let budget = (rng.next_u32() as usize) % BUDGETS.len();
        let seed_idx = (rng.next_u32() as usize) % SEEDS.len();
        let fanout = 1 + (rng.next_u32() % 2) as u64;
        for stream in 0..fanout {
            pending.push(Plan { prompt, params, budget, seed_idx, stream });
        }
    }

    let mut owners: HashMap<SeqId, Plan> = HashMap::new();
    let mut unretired: Vec<SeqId> = Vec::new();
    let mut parked: Vec<(Plan, bass::spec::SuspendedSeq)> = Vec::new();
    let mut done: Vec<(Plan, SeqState)> = Vec::new();
    let mut out = ScheduleOutcome::default();
    let mut stepped_since_empty = false;
    let mut guard = 0;
    loop {
        guard += 1;
        assert!(guard < 4000, "schedule {schedule} did not converge");

        // Delayed retirement: each finished sequence leaves with p=0.7
        // per boundary, so Husk rows and finished-but-unretired slots
        // both occur.
        let mut still = Vec::new();
        for id in unretired.drain(..) {
            if rng.next_f32() < 0.7 {
                let st = batch.retire(id).unwrap();
                done.push((owners.remove(&id).unwrap(), st));
            } else {
                still.push(id);
            }
        }
        unretired = still;

        // Random preemption: any still-suspendable live sequence may be
        // yanked to the host (p=0.15 per boundary). The snapshot parks
        // in the harness and competes with fresh admissions for slots —
        // exactly what the coordinator's scheduler does.
        let live_ids: Vec<SeqId> = owners.keys().copied().collect();
        for id in live_ids {
            if batch.can_suspend(id) && rng.next_f32() < 0.15 {
                let snap = batch.suspend(id).unwrap();
                parked.push((owners.remove(&id).unwrap(), snap));
                out.suspensions += 1;
            }
        }
        if batch.occupied() == 0 {
            stepped_since_empty = false; // drained (PAD auto-reset point)
        }

        // Random live re-bucketing (p=0.5 per eligible boundary): GROW
        // when waiting work cannot be placed (the bucket is fully live —
        // no husk/shadow row left), SHRINK when nothing is waiting and
        // the occupancy fits a smaller bucket — the same two triggers
        // the coordinator's scheduler uses. Every carried sequence rides
        // the recompute primitive, so the byte-identity checks below pin
        // re-bucketing exactly like admission and preemption. SPLIT has
        // no fused bucket: `rebucket` probes to `None` and the counters
        // must stay at zero.
        if stepped_since_empty && batch.occupied() > 0
            && rng.next_f32() < 0.5
        {
            let waiting = pending.len() + parked.len();
            if waiting > 0 && batch.free_slots() == 0 {
                if let Some(r) = batch
                    .rebucket(batch.occupied() + waiting)
                    .unwrap()
                {
                    assert!(r.to > r.from,
                            "demand against a full bucket must grow");
                    out.grows += 1;
                }
            } else if waiting == 0 {
                if let Some(r) = batch.rebucket(batch.occupied()).unwrap()
                {
                    assert!(r.to < r.from,
                            "idle re-bucket must shrink");
                    assert!(r.to >= batch.occupied());
                    out.shrinks += 1;
                }
            }
        }

        // Random resume of parked sequences (p=0.5 each boundary, slots
        // permitting): into a running bucket (scatter recompute) or a
        // fresh one (fused-prefill recompute) — whichever the schedule
        // happens to present.
        while !parked.is_empty() && batch.can_admit()
            && rng.next_f32() < 0.5
        {
            let (plan, snap) = parked.pop().unwrap();
            if stepped_since_empty && batch.occupied() > 0 {
                out.resumes_midflight += 1;
            }
            // Resume-by-row-copy when a resident row already covers the
            // suspended context (an identical-plan sibling at equal or
            // later progress) — the cheap-resume path the coordinator's
            // prefix cache feeds. `can_suspend` gated the snapshot at
            // ctx <= prefill_p, so `resume_shared` never rejects on
            // length. Rare (needs a duplicate Plan co-resident), so
            // counted but not floored.
            let id = match batch.donor_row_for(&snap.context()) {
                Some(d) if rng.next_f32() < 0.9 => {
                    out.resumes_shared += 1;
                    batch.resume_shared(d, snap).unwrap()
                }
                _ => batch.resume(snap).unwrap(),
            };
            owners.insert(id, plan);
        }

        // Random admission into whatever slots/rows are free right now.
        while !pending.is_empty() && batch.can_admit()
            && rng.next_f32() < 0.6
        {
            let p = pending.pop().unwrap();
            if stepped_since_empty && batch.occupied() > 0 {
                out.midflight += 1; // landed in a running batch (no drain)
            }
            let (prompt, seed, opts) = plan_inputs(p);
            // Fan-out prefill sharing: when some resident row (live Seq
            // or Husk) already encodes this prompt, admit by KV row
            // copy off it (p=0.9) instead of prefilling. The solo
            // checks below are what pin the copy as byte-invisible.
            let id = match batch.donor_row_for(&prompt) {
                Some(d) if rng.next_f32() < 0.9 => {
                    out.shared += 1;
                    batch.admit_shared_opts(d, &prompt, seed, opts)
                        .unwrap()
                }
                _ => batch.admit_opts(&prompt, seed, opts).unwrap(),
            };
            owners.insert(id, p);
        }

        if batch.has_active() {
            let report = batch.step().unwrap();
            // StepReport.k is the LAUNCH width (max over live rows'
            // k_i): constant under Fixed, adaptive under Heuristic.
            if matches!(policy, Policy::Fixed(_)) {
                assert_eq!(report.k, K, "Fixed({K}) must hold every step");
            } else {
                assert!(report.k >= 1, "launch width must stay positive");
            }
            stepped_since_empty = true;
            unretired.extend(report.finished);
        } else if pending.is_empty() && unretired.is_empty()
            && owners.is_empty() && parked.is_empty()
        {
            break;
        }
    }

    // Every completed sequence must reproduce its solo one-shot run —
    // however many times it was preempted and recomputed along the way.
    out.checked = done.len();
    for (plan, st) in done {
        let want = solo
            .entry(plan)
            .or_insert_with(|| solo_run(e, mode, policy, plan));
        assert_ne!(st.finish, FinishReason::Running);
        assert_eq!(st.generated, want.generated,
                   "{mode:?} schedule {schedule}: interleaved bytes \
                    diverge from the solo run");
        assert_eq!(st.finish, want.finish,
                   "{mode:?} schedule {schedule}: finish reason");
        assert!((st.mean_logp() - want.mean_logp()).abs() < 1e-12,
                "{mode:?} schedule {schedule}: mean_logp {} vs {}",
                st.mean_logp(), want.mean_logp());
    }
    out
}

fn run_mode(mode: ExecMode, policy: Policy) {
    let e = Engine::load(&artifacts_root()).expect("engine load");
    // The solo-reference cache is policy-scoped: a Heuristic solo run
    // draws different draft lengths (hence different RNG positions)
    // than a Fixed one for the same Plan.
    let mut solo: HashMap<Plan, SeqState> = HashMap::new();
    let mut total = ScheduleOutcome::default();
    for schedule in 0..SCHEDULES {
        let o = run_schedule(&e, mode, policy, schedule, &mut solo);
        total.checked += o.checked;
        total.midflight += o.midflight;
        total.suspensions += o.suspensions;
        total.resumes_midflight += o.resumes_midflight;
        total.grows += o.grows;
        total.shrinks += o.shrinks;
        total.shared += o.shared;
        total.resumes_shared += o.resumes_shared;
    }
    assert!(total.checked >= 600,
            "{mode:?}: only {} sequences checked — schedules degenerate",
            total.checked);
    // The whole point: a healthy share of admissions landed in a batch
    // that had already started (no drain in between). Busy periods that
    // bucketed at 1 can never take one, so the floor is well below the
    // admission count, but it must stay far from zero.
    assert!(total.midflight >= 30,
            "{mode:?}: only {} mid-flight admissions across {SCHEDULES} \
             schedules — the harness is not exercising running-batch \
             admission", total.midflight);
    // And the preemption edges must actually be crossed: plenty of
    // mid-generation suspensions, including resumes into still-running
    // batches (the scatter-recompute path in PAD; slot reuse in SPLIT).
    assert!(total.suspensions >= 50,
            "{mode:?}: only {} suspensions across {SCHEDULES} schedules \
             — the harness is not exercising preemption",
            total.suspensions);
    assert!(total.resumes_midflight >= 10,
            "{mode:?}: only {} mid-flight resumes across {SCHEDULES} \
             schedules — resumes never hit a running batch",
            total.resumes_midflight);
    // Fan-out prefill sharing must be crossed many times per mode: with
    // 3 prompts in the pool, a mid-flight admission usually finds a
    // co-resident (or husked) row of the same prompt, and the harness
    // takes the row-copy path at p=0.9 whenever one exists. Every one
    // of those admissions is still held to the solo byte/logP identity
    // above — that is the shared-prefill pin at scale.
    assert!(total.shared >= 30,
            "{mode:?}: only {} shared (row-copy) admissions across \
             {SCHEDULES} schedules — donor rows never found",
            total.shared);
    // Live re-bucketing floors: PAD schedules must actually grow and
    // shrink running buckets many times (the recompute-carry path the
    // identity checks pin); SPLIT has no fused bucket and every rebucket
    // call must have declined as a no-op.
    match mode {
        // PACKED follows the PAD fused-bucket lifecycle (same
        // grow/shrink triggers over the same row states), so it shares
        // PAD's re-bucketing floors.
        ExecMode::Pad | ExecMode::Packed => {
            assert!(total.grows >= 10,
                    "{mode:?}: only {} live grows across {SCHEDULES} \
                     schedules — the harness is not exercising \
                     re-bucketing", total.grows);
            assert!(total.shrinks >= 5,
                    "{mode:?}: only {} live shrinks across {SCHEDULES} \
                     schedules — the harness is not exercising \
                     re-bucketing", total.shrinks);
        }
        ExecMode::Split => {
            assert_eq!((total.grows, total.shrinks), (0, 0),
                       "SPLIT has no fused bucket to re-shape");
        }
        ExecMode::Stub => unreachable!("run_mode drives device modes"),
    }
}

#[test]
fn interleaved_admission_matches_solo_pad() {
    require_artifacts!();
    run_mode(ExecMode::Pad, Policy::Fixed(K));
}

#[test]
fn interleaved_admission_matches_solo_split() {
    require_artifacts!();
    run_mode(ExecMode::Split, Policy::Fixed(K));
}

// The same 200-schedule sweep under the ADAPTIVE policy — the
// per-sequence-draft-length pin at scale. Before draft lengths went
// per-row this sweep could only run under Fixed (the batch-global
// Algorithm-1 state made every sequence's k depend on its co-batch);
// now a Heuristic row's trajectory is its own, so the exact same
// solo-identity checks must hold across admission, preemption, resume
// and live re-bucketing.

#[test]
fn interleaved_admission_matches_solo_heuristic_pad() {
    require_artifacts!();
    run_mode(ExecMode::Pad, Policy::Heuristic);
}

#[test]
fn interleaved_admission_matches_solo_heuristic_split() {
    require_artifacts!();
    run_mode(ExecMode::Split, Policy::Heuristic);
}

// PACKED under the same sweep: every admission/preemption/re-bucket
// edge now also crosses the segment-packing round trip (qoffs/koffs
// construction, filler rows for Husk/Shadow slots, unpack back to
// launch-width layout) — under both policies, since Heuristic is what
// makes the packed stream genuinely ragged.

#[test]
fn interleaved_admission_matches_solo_packed() {
    require_artifacts!();
    run_mode(ExecMode::Packed, Policy::Fixed(K));
}

#[test]
fn interleaved_admission_matches_solo_heuristic_packed() {
    require_artifacts!();
    run_mode(ExecMode::Packed, Policy::Heuristic);
}
