//! Coordinator + TCP server integration tests: continuous batching
//! (mid-flight admission, immediate retirement), preemptive priority
//! scheduling (suspend/resume-by-recompute), queueing, fan-out slicing,
//! streaming and the line protocol, over real artifacts.
//!
//! Tests prefixed `stub_` run the same coordinator stack on the
//! host-only [`ExecMode::Stub`] backend — no artifacts, no device — so
//! they execute on every machine (they are what the CI serving gate
//! leans on).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use bass::bench_util::{artifacts_available, artifacts_root};
use bass::coordinator::batcher::BatcherConfig;
use bass::coordinator::{server, Coordinator, CoordinatorConfig, Reply,
                        Request};
use bass::runtime::json::Json;
use bass::runtime::Engine;
use bass::spec::{ExecMode, Policy, SpecConfig, SpecEngine};
use bass::tokenizer;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn config_with(spec: SpecConfig, max_batch: usize, window_ms: u64)
               -> CoordinatorConfig {
    // Built via `new()` + field mutations, so config growth cannot
    // break this helper (a struct literal here has to chase every new
    // field).
    let mut cfg = CoordinatorConfig::new(
        artifacts_root(),
        spec,
        BatcherConfig {
            max_batch,
            window: Duration::from_millis(window_ms),
        },
    );
    cfg.prewarm = false; // keep tests fast; lazy compiles are fine here
    cfg
}

fn coordinator_with(spec: SpecConfig, max_batch: usize, window_ms: u64)
                    -> Coordinator {
    Coordinator::start(config_with(spec, max_batch, window_ms))
        .expect("coordinator start")
}

fn coordinator(max_batch: usize, window_ms: u64) -> Coordinator {
    coordinator_with(
        SpecConfig { max_new_tokens: 12, ..SpecConfig::default() },
        max_batch, window_ms)
}

fn request(prompt: &str, n: usize, max_new: usize, stream: bool)
           -> Request {
    Request {
        prompt: tokenizer::encode(prompt),
        n_seqs: n,
        max_new_tokens: Some(max_new),
        temperature: None,
        top_p: None,
        seed: None,
        priority: None,
        deadline_ms: None,
        stream,
    }
}

fn code_request(n: usize) -> Request {
    request("def add_7(x):\n    # adds 7 to x\n    return", n, 12, false)
}

#[test]
fn single_request_roundtrip() {
    require_artifacts!();
    let coord = coordinator(4, 1);
    let resp = coord.generate(code_request(2)).unwrap();
    assert_eq!(resp.seqs.len(), 2);
    assert!(resp.seqs[0].n_tokens > 0);
    assert!(resp.batch_secs > 0.0);
}

#[test]
fn concurrent_requests_are_cobatched() {
    require_artifacts!();
    let coord = Arc::new(coordinator(8, 30));
    // Warm the engine so the co-batch window isn't dwarfed by compiles.
    let _ = coord.generate(code_request(1));
    let rx1 = coord.submit(code_request(2));
    let rx2 = coord.submit(code_request(2));
    let r1 = Coordinator::wait(rx1).unwrap();
    let r2 = Coordinator::wait(rx2).unwrap();
    assert_eq!(r1.seqs.len(), 2);
    assert_eq!(r2.seqs.len(), 2);
    // Both rode the same engine batch (2 + 2 sequences co-resident).
    assert_eq!(r1.batch_size, 4);
    assert_eq!(r2.batch_size, 4);
}

#[test]
fn fanout_clamped_to_max_batch() {
    require_artifacts!();
    let coord = coordinator(4, 1);
    let resp = coord.generate(code_request(9)).unwrap();
    assert_eq!(resp.seqs.len(), 4);
    // The clamp is no longer silent: the response reports the asked-for
    // fan-out so the client can see 4 < 9.
    assert_eq!(resp.n_requested, 9);
}

#[test]
fn unclamped_fanout_reports_requested_n() {
    require_artifacts!();
    let coord = coordinator(4, 1);
    let resp = coord.generate(code_request(2)).unwrap();
    assert_eq!(resp.seqs.len(), 2);
    assert_eq!(resp.n_requested, 2);
}

/// The per-request sampling acceptance test: a request carrying its own
/// temperature/top_p (and a pinned seed) must reproduce a solo
/// `SpecEngine::generate` run with those params byte-for-byte — even
/// while co-batched with traffic running the server's (very different)
/// defaults. `Policy::Fixed` pins per-step draft lengths; the pinned seed
/// pins the RNG streams. Covers both PAD and SPLIT execution.
#[test]
fn per_request_sampling_params_match_solo_engine_run() {
    require_artifacts!();
    let prompt = "def add_7(x):\n    # adds 7 to x\n    return";
    let (temp, top_p, seed) = (0.3f32, 0.9f32, 7u64);
    for mode in [ExecMode::Pad, ExecMode::Split] {
        let server_cfg = SpecConfig {
            max_new_tokens: 12,
            policy: Policy::Fixed(4),
            mode,
            seed: 0,
            temperature: 2.0, // server defaults far from the request's
            top_p: 1.0,
            ..SpecConfig::default()
        };

        // Solo reference (engine dropped before the coordinator spawns
        // its own PJRT client).
        let want = {
            let engine = Engine::load(&artifacts_root()).unwrap();
            let solo_cfg = SpecConfig {
                temperature: temp,
                top_p,
                seed,
                ..server_cfg.clone()
            };
            let solo = SpecEngine::new(&engine, solo_cfg)
                .generate(&[tokenizer::encode(prompt)])
                .unwrap();
            tokenizer::decode(&solo.seqs[0].generated)
        };
        assert!(!want.is_empty());

        let coord = Arc::new(coordinator_with(server_cfg, 4, 30));
        // Default-params traffic to co-batch with.
        let rx_hot = coord.submit(code_request(2));
        let rx_target = coord.submit(Request {
            prompt: tokenizer::encode(prompt),
            n_seqs: 1,
            max_new_tokens: Some(12),
            temperature: Some(temp),
            top_p: Some(top_p),
            seed: Some(seed),
            priority: None,
            deadline_ms: None,
            stream: false,
        });
        let target = Coordinator::wait(rx_target).unwrap();
        let hot = Coordinator::wait(rx_hot).unwrap();
        assert!(target.batch_size > 1,
                "{mode:?}: request was not co-batched (batch_size {})",
                target.batch_size);
        assert_eq!(target.seqs[0].text, want,
                   "{mode:?}: per-request params did not reproduce the \
                    solo run");
        // The co-batched default-params traffic really ran hotter config:
        // it must not have inherited the target's overrides.
        assert_eq!(hot.seqs.len(), 2);
    }
}

/// The continuous-batching acceptance test: a short request submitted
/// after a long one has *started* must be admitted into the running
/// batch (SPLIT mode), finish first, and report a queue wait that is the
/// admission wait — not the long request's full runtime.
#[test]
fn midflight_admission_into_running_batch() {
    require_artifacts!();
    let coord = Arc::new(coordinator_with(
        SpecConfig {
            max_new_tokens: 96,
            mode: ExecMode::Split,
            temperature: 2.0, // keep the long request rambling (no EOS)
            ..SpecConfig::default()
        },
        4, 1));
    // Warm up so step timing is not dominated by lazy compiles.
    let _ = coord.generate(request("def f(x):\n    return", 1, 4, false));

    // Long request, streaming so we *know* when its batch has started.
    let rx_long = coord.submit(
        request("def add_7(x):\n    # adds 7 to x\n    return", 1, 96,
                true));
    match rx_long.recv().expect("long request alive") {
        Reply::Step(_) => {} // first step done => batch started
        Reply::Done(r) => panic!("long request finished instantly: {r:?}"),
    }

    // Short request arrives mid-flight.
    let t_submit = std::time::Instant::now();
    let short = coord
        .generate(request("def mul_3(x):\n    return", 1, 2, false))
        .unwrap();
    let short_wall = t_submit.elapsed().as_secs_f64();

    // Admitted into the running batch: co-resident with the long seq,
    // even though it arrived after that batch started.
    assert!(short.batch_size > short.seqs.len(),
            "batch_size {} not > own seqs {} — no mid-flight admission",
            short.batch_size, short.seqs.len());
    assert_eq!(short.seqs.len(), 1);
    assert!(short.seqs[0].n_tokens > 0);

    // The long request must still be running when the short one answered.
    let mut long_done_early = false;
    loop {
        match rx_long.try_recv() {
            Ok(Reply::Step(_)) => continue,
            Ok(Reply::Done(_)) => {
                long_done_early = true;
                break;
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => break,
            Err(e) => panic!("long request channel died: {e}"),
        }
    }
    assert!(!long_done_early,
            "short request did not overtake the long one");

    let long = Coordinator::wait(rx_long).unwrap();
    assert!(long.seqs[0].n_tokens >= short.seqs[0].n_tokens);
    // queue_secs is the admission wait, not the long batch's runtime.
    assert!(short.queue_secs <= short_wall,
            "queue {:.3}s exceeds the request's own wall {:.3}s",
            short.queue_secs, short_wall);
    assert!(short.queue_secs < long.batch_secs * 0.5,
            "queue {:.3}s looks like full-batch wait ({:.3}s batch)",
            short.queue_secs, long.batch_secs);
}

/// PAD mid-flight admission (the tentpole of the prefill-scatter
/// artifact): a request arriving after a PAD batch *started* is
/// scatter-prefilled into a freed row of the running fused cache — no
/// drain — and answered independently while the co-resident long request
/// keeps running. The freed row comes from a short co-batched request
/// retiring early (a Husk row).
#[test]
fn pad_midflight_admission_into_running_batch() {
    require_artifacts!();
    let coord = Arc::new(coordinator_with(
        SpecConfig {
            max_new_tokens: 96,
            mode: ExecMode::Pad,
            temperature: 2.0, // keep the long request rambling (no EOS)
            ..SpecConfig::default()
        },
        4, 30));
    // Warm up so step timing is not dominated by lazy compiles.
    let _ = coord.generate(request("def f(x):\n    return", 1, 4, false));

    // A long and a short request ride one fused bucket (the 30ms window
    // co-batches them). The short one retires early, husking its row.
    let rx_long = coord.submit(
        request("def add_7(x):\n    # adds 7 to x\n    return", 1, 96,
                true));
    let rx_short = coord.submit(request("def mul_3(x):\n    return", 1, 2,
                                        false));
    let early = Coordinator::wait(rx_short).unwrap();
    assert!(early.batch_size >= 2,
            "setup failed: short request was not co-batched (batch_size \
             {})", early.batch_size);

    // Late arrival, after the batch started: must be admitted into the
    // running fused batch via scatter-prefill, not wait for the drain.
    let late = coord
        .generate(request("def mul_3(x):\n    return", 1, 2, false))
        .unwrap();
    assert!(late.batch_size > late.seqs.len(),
            "batch_size {} not > own seqs {} — no PAD mid-flight \
             admission", late.batch_size, late.seqs.len());
    assert_eq!(late.seqs.len(), 1);
    assert!(late.seqs[0].n_tokens > 0);

    // The long request must still be running when the late one answered
    // (i.e. the batch never drained).
    let mut long_done_early = false;
    loop {
        match rx_long.try_recv() {
            Ok(Reply::Step(_)) => continue,
            Ok(Reply::Done(_)) => {
                long_done_early = true;
                break;
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => break,
            Err(e) => panic!("long request channel died: {e}"),
        }
    }
    assert!(!long_done_early,
            "late request did not overtake the long one — PAD admission \
             waited for the drain");
    let long = Coordinator::wait(rx_long).unwrap();
    assert!(long.seqs[0].n_tokens >= late.seqs[0].n_tokens);
}

/// The live-grow acceptance test (this PR's tentpole): a PAD batch
/// running at bucket b admits a burst of b+k sequences **without
/// draining** — the scheduler grows the live fused bucket by recompute
/// (there is no husk/shadow row to scatter into, and equal priorities
/// never preempt), the burst is answered while the original request
/// keeps generating, and the response's `"rebuckets"` counter reports
/// the grow. Byte-identity of grow/shrink carries is pinned separately
/// in `step_equivalence.rs` / `admission_interleaving.rs`.
#[test]
fn pad_burst_beyond_bucket_grows_without_drain() {
    require_artifacts!();
    let coord = Arc::new(coordinator_with(
        SpecConfig {
            max_new_tokens: 96,
            mode: ExecMode::Pad,
            temperature: 2.0, // keep the long request rambling (no EOS)
            ..SpecConfig::default()
        },
        4, 1));
    // Warm up so step timing is not dominated by lazy compiles.
    let _ = coord.generate(request("def f(x):\n    return", 1, 4, false));

    // Long request alone: the lazy start buckets TIGHT (bucket 1, no
    // headroom), so the running bucket has zero reusable rows. The
    // short prompt keeps its context recomputable for many steps.
    let rx_long = coord.submit(
        request("def f(x):\n    return", 1, 96, true));
    match rx_long.recv().expect("long request alive") {
        Reply::Step(_) => {} // first step done => batch started
        Reply::Done(r) => panic!("long request finished instantly: {r:?}"),
    }

    // Burst beyond the bucket: serving it requires growing the live
    // batch — pre-grow there is nowhere to scatter-admit.
    let rx_a = coord.submit(request("def mul_3(x):\n    return", 1, 2,
                                    false));
    let rx_b = coord.submit(
        request("article: alice went to the market. summary:", 1, 2,
                false));
    let a = Coordinator::wait(rx_a).unwrap();
    let b = Coordinator::wait(rx_b).unwrap();
    for (name, r) in [("a", &a), ("b", &b)] {
        assert_eq!(r.seqs.len(), 1);
        assert!(r.seqs[0].n_tokens > 0,
                "burst {name} generated nothing");
        assert!(r.batch_size > 1,
                "burst {name} was not co-resident with the long request \
                 (batch_size {}) — no live grow happened",
                r.batch_size);
        assert!(r.rebuckets >= 1,
                "burst {name} answered without a grow (rebuckets {})",
                r.rebuckets);
        assert_eq!(r.preempted, 0,
                   "equal priorities must grow, not preempt");
    }

    // The long request must still be running when the burst answered —
    // the bucket was re-shaped, never drained.
    let mut long_done_early = false;
    loop {
        match rx_long.try_recv() {
            Ok(Reply::Step(_)) => continue,
            Ok(Reply::Done(_)) => {
                long_done_early = true;
                break;
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => break,
            Err(e) => panic!("long request channel died: {e}"),
        }
    }
    assert!(!long_done_early,
            "burst did not overtake the long request — the bucket \
             drained instead of growing");
    let long = Coordinator::wait(rx_long).unwrap();
    assert_eq!(long.seqs.len(), 1);
    assert!(long.seqs[0].n_tokens >= a.seqs[0].n_tokens,
            "the grown-over request lost output");
}

/// The preemptive-scheduler acceptance test: with a single engine slot, a
/// high-priority late arrival can only run by **suspending** the running
/// low-priority sequence. It must answer first; the preempted request
/// must then resume by recompute and still deliver its complete output,
/// reporting how often it was preempted. Covers both execution modes
/// (SPLIT per-slot recompute; PAD husk-row + fresh-bucket recompute).
#[test]
fn high_priority_preempts_and_answers_first() {
    require_artifacts!();
    for mode in [ExecMode::Split, ExecMode::Pad] {
        let coord = Arc::new(coordinator_with(
            SpecConfig {
                max_new_tokens: 96,
                mode,
                temperature: 2.0, // keep the low-pri request rambling
                ..SpecConfig::default()
            },
            1, 1));
        // Warm up so step timing is not dominated by lazy compiles.
        let _ = coord.generate(
            request("def f(x):\n    return", 1, 4, false));

        // Low-priority long request; short prompt so its context stays
        // under the prefill capacity (= suspendable) for many steps.
        // Streaming tells us when its batch has started.
        let rx_low = coord.submit(
            request("def f(x):\n    return", 1, 96, true));
        match rx_low.recv().expect("low-priority request alive") {
            Reply::Step(_) => {} // first step done => batch started
            Reply::Done(r) => {
                panic!("{mode:?}: long request finished instantly: {r:?}")
            }
        }

        // High-priority late arrival. Capacity is 1, so FIFO would have
        // made it wait out all 96 tokens; preemption must run it now.
        let hi = coord
            .generate(Request {
                priority: Some(5),
                ..request("def mul_3(x):\n    return", 1, 3, false)
            })
            .unwrap();
        assert_eq!(hi.seqs.len(), 1);
        assert!(hi.seqs[0].n_tokens > 0);
        assert_eq!(hi.preempted, 0,
                   "{mode:?}: the high-priority request itself must not \
                    be preempted");

        // The low-priority request must still be running when the
        // high-priority one answered (i.e. it really was overtaken).
        let mut low_done_early = false;
        loop {
            match rx_low.try_recv() {
                Ok(Reply::Step(_)) => continue,
                Ok(Reply::Done(_)) => {
                    low_done_early = true;
                    break;
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(e) => panic!("low-priority channel died: {e}"),
            }
        }
        assert!(!low_done_early,
                "{mode:?}: high-priority request did not overtake");

        // The preempted request completes — full budget, correct
        // preemption count (suspended at least once; possibly more if
        // other boundaries raced).
        let low = Coordinator::wait(rx_low).unwrap();
        assert_eq!(low.seqs.len(), 1);
        assert!(low.preempted >= 1,
                "{mode:?}: low-priority request was never preempted \
                 (preempted = {})", low.preempted);
        assert!(low.seqs[0].finished,
                "{mode:?}: preempted request did not run to completion");
        assert!(low.seqs[0].n_tokens >= hi.seqs[0].n_tokens,
                "{mode:?}: preempted request lost output ({} tokens)",
                low.seqs[0].n_tokens);
    }
}

#[test]
fn streaming_deltas_reassemble_final_text() {
    require_artifacts!();
    let coord = coordinator(4, 1);
    let rx = coord.submit(
        request("def add_7(x):\n    # adds 7 to x\n    return", 1, 12,
                true));
    let mut assembled = String::new();
    let mut events = 0usize;
    let resp = loop {
        match rx.recv().expect("worker alive") {
            Reply::Step(ev) => {
                assert_eq!(ev.seq, 0);
                assembled.push_str(&ev.text_delta);
                events += 1;
            }
            Reply::Done(r) => break r.unwrap(),
        }
    };
    assert!(events > 0, "streaming request produced no step events");
    assert_eq!(assembled, resp.seqs[0].text,
               "streamed deltas disagree with the final text");
}

#[test]
fn tcp_server_line_protocol() {
    require_artifacts!();
    let coord = Arc::new(coordinator(4, 1));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv_coord = coord.clone();
    std::thread::spawn(move || {
        let _ = server::serve(srv_coord, "127.0.0.1:0", move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(
            b"{\"prompt\": \"def add_7(x):\\n    # adds 7 to x\\n    \
              return\", \"n\": 2, \"max_new_tokens\": 8}\n")
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
    assert_eq!(j.get("seqs").unwrap().as_arr().unwrap().len(), 2);

    // Malformed request gets a structured error, connection stays open.
    stream.write_all(b"not json\n").unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    let j2 = Json::parse(&line2).unwrap();
    assert_eq!(j2.get("ok").unwrap(), &Json::Bool(false));

    // Streaming: event lines first, then the final ok line; the deltas
    // reassemble the final text.
    stream
        .write_all(
            b"{\"prompt\": \"def mul_3(x):\\n    return\", \
              \"max_new_tokens\": 6, \"stream\": true}\n")
        .unwrap();
    let mut assembled = String::new();
    let mut saw_event = false;
    loop {
        let mut l = String::new();
        reader.read_line(&mut l).unwrap();
        let j = Json::parse(&l).unwrap();
        if j.opt("event").is_some() {
            saw_event = true;
            assembled.push_str(j.get("delta").unwrap().as_str().unwrap());
            continue;
        }
        assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
        let text = j.get("seqs").unwrap().as_arr().unwrap()[0]
            .get("text").unwrap().as_str().unwrap().to_string();
        assert_eq!(assembled, text);
        break;
    }
    assert!(saw_event, "no event lines before the final response");
}

// ---------------------------------------------------------------------------
// Stub-backend tests — ExecMode::Stub needs no artifacts and no device,
// so everything below runs on any machine (including CI). They pin the
// latency-accounting and serving-path behavior the load harness
// (`bass serving`) depends on.
// ---------------------------------------------------------------------------

fn stub_spec() -> SpecConfig {
    SpecConfig {
        mode: ExecMode::Stub,
        policy: Policy::Fixed(4),
        max_new_tokens: 16,
        ..SpecConfig::default()
    }
}

#[test]
fn stub_roundtrip_counts_tokens_and_records_ttft() {
    let coord = coordinator_with(stub_spec(), 4, 1);
    let t0 = std::time::Instant::now();
    let resp = coord.generate(request("hello stub", 2, 10, false))
        .unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(resp.seqs.len(), 2);
    for s in &resp.seqs {
        // The stub backend accepts every drafted token, so the length
        // cap is hit exactly: deterministic counters for the CI gate.
        assert_eq!(s.n_tokens, 10);
        assert!(s.finished);
    }
    let ttft = resp.ttft_secs.expect("bytes were emitted → TTFT set");
    assert!(ttft >= 0.0 && ttft <= wall,
            "ttft {ttft}s outside [0, {wall}s]");
}

/// TTFT is pinned at the *first* emitted byte and never moved by later
/// steps: the server-side value must not exceed the client-observed
/// elapsed time at the first streaming event (submission happens-before
/// enqueue; recording happens-before the event is received — so any
/// later overwrite would violate this bound).
#[test]
fn stub_ttft_is_recorded_once_at_the_first_byte() {
    let coord = coordinator_with(stub_spec(), 4, 1);
    let t0 = std::time::Instant::now();
    let rx = coord.submit(request("stream me", 1, 24, true));
    let mut first_evt_secs = None;
    let resp = loop {
        match rx.recv().expect("worker alive") {
            Reply::Step(ev) => {
                if first_evt_secs.is_none() && !ev.text_delta.is_empty() {
                    first_evt_secs = Some(t0.elapsed().as_secs_f64());
                }
            }
            Reply::Done(r) => break r.unwrap(),
        }
    };
    let first_evt = first_evt_secs.expect("saw a non-empty delta");
    let ttft = resp.ttft_secs.expect("TTFT set on a streamed request");
    assert!(ttft > 0.0, "ttft must be positive, got {ttft}");
    assert!(ttft <= first_evt,
            "ttft {ttft}s was re-recorded after the first byte \
             (client saw the first delta at {first_evt}s)");
}

/// Wedge guard for the queued-budget-expiry fix: with a zero budget and
/// a single slot, *every* request — admitted or still queued — must be
/// answered (empty, unfinished, no TTFT) instead of the queued one
/// waiting forever on a batch that never runs. Assertions hold for
/// either drain ordering, so the test is race-free.
#[test]
fn stub_zero_budget_answers_queued_requests_too() {
    let coord = coordinator_with(
        SpecConfig { time_budget_secs: Some(0.0), ..stub_spec() }, 1, 1);
    let rx1 = coord.submit(request("first", 1, 32, false));
    let rx2 = coord.submit(request("second", 1, 32, false));
    for (name, rx) in [("first", rx1), ("second", rx2)] {
        let resp = loop {
            match rx.recv_timeout(Duration::from_secs(10)) {
                Ok(Reply::Step(_)) => continue,
                Ok(Reply::Done(r)) => break r.unwrap(),
                Err(e) => panic!("{name} request wedged: {e}"),
            }
        };
        assert_eq!(resp.seqs.len(), 1, "{name}");
        assert_eq!(resp.seqs[0].n_tokens, 0,
                   "{name}: budget 0 must yield no tokens");
        assert!(!resp.seqs[0].finished,
                "{name}: expiry leaves sequences unfinished");
        assert!(resp.ttft_secs.is_none(),
                "{name}: no byte emitted → ttft_ms must be null");
    }
}

/// ISSUE-10 acceptance: a preempted request resumes through the
/// prompt-prefix cache — KV row-copied off a still-resident
/// same-trajectory sibling instead of recomputed — and still finishes
/// **byte-identical** to an uninterrupted solo run. The stub backend's
/// output is a pure function of (prompt, seed, stream), so two fan-out-1
/// requests with the same prompt and seed walk identical byte
/// trajectories: the earlier-admitted sibling's row always covers the
/// later one's suspended context and can donate its KV on resume.
///
/// Stub steps run in microseconds, so catching the target mid-flight
/// from another thread is inherently racy; the test retries fresh
/// coordinators until one attempt observes the preemption. The
/// byte-identity assertions run on EVERY attempt — retries only chase
/// the scheduling interleaving, never the bytes.
#[test]
fn stub_preempted_request_resumes_via_prefix_cache_hit() {
    let prompt = "sharedpfx"; // 9 bytes: ctx stays far under prefill_p,
                              // so the target is suspendable all run
    let solo_text = |budget: usize| {
        let coord = coordinator_with(stub_spec(), 2, 1);
        let resp = coord
            .generate(Request {
                seed: Some(7),
                ..request(prompt, 1, budget, false)
            })
            .unwrap();
        assert!(resp.seqs[0].finished);
        assert_eq!(resp.seqs[0].n_tokens, budget);
        resp.seqs[0].text.clone()
    };
    let want_t = solo_text(40);
    let want_l1 = solo_text(48);

    let mut witnessed = false;
    for _attempt in 0..40 {
        let coord = Arc::new(coordinator_with(stub_spec(), 2, 1));
        // The donor sibling: same (prompt, seed, stream) as the target,
        // admitted first and given the larger budget, so its progress
        // always covers the target's suspended context. Streaming tells
        // us when its batch has started.
        let rx_l1 = coord.submit(Request {
            seed: Some(7),
            priority: Some(3),
            ..request(prompt, 1, 48, true)
        });
        match rx_l1.recv().expect("sibling alive") {
            Reply::Step(_) => {} // first step done => batch started
            Reply::Done(r) => panic!("sibling finished instantly: {r:?}"),
        }
        // The target: low priority, so the preemptor's victim search
        // (lowest priority first, deadlineless before deadlined) always
        // picks it — never the pri-3 sibling.
        let rx_t = coord.submit(Request {
            seed: Some(7),
            priority: Some(0),
            ..request(prompt, 1, 40, true)
        });
        let mut t_done = None;
        match rx_t.recv().expect("target alive") {
            Reply::Step(_) => {} // target admitted and stepping
            Reply::Done(r) => t_done = Some(r.unwrap()),
        }
        // Preemptor: max_batch is 2 and both rows are live, so admitting
        // it needs exactly one victim slot.
        let hi = coord
            .generate(Request {
                priority: Some(5),
                ..request("urgent", 1, 2, false)
            })
            .unwrap();
        assert_eq!(hi.seqs[0].n_tokens, 2);
        assert_eq!(hi.preempted, 0,
                   "the preemptor itself must not be preempted");
        let t = match t_done {
            Some(r) => r,
            None => Coordinator::wait(rx_t).unwrap(),
        };
        let l1 = Coordinator::wait(rx_l1).unwrap();

        // Byte-identity holds on every attempt, preempted or not.
        assert_eq!(t.seqs.len(), 1);
        assert!(t.seqs[0].finished, "target did not run to completion");
        assert_eq!(t.seqs[0].n_tokens, 40);
        assert_eq!(t.seqs[0].text, want_t,
                   "preemption/resume changed the target's bytes");
        assert_eq!(l1.seqs.len(), 1);
        assert!(l1.seqs[0].finished, "sibling did not run to completion");
        assert_eq!(l1.seqs[0].n_tokens, 48);
        assert_eq!(l1.seqs[0].text, want_l1,
                   "the donor sibling's bytes drifted");

        if t.preempted >= 1 {
            // The prefix machinery must have fired: the target's own
            // admission shared the sibling's prompt row, and its resume
            // probed the cache again — so by its finish the engine-
            // lifetime echo reports hits, executed row copies and a
            // positive prefill-FLOP saving.
            assert!(t.prefix.hits >= 1,
                    "preempted run reported no prefix-cache hit: {:?}",
                    t.prefix);
            assert!(t.prefix.row_copies >= 1,
                    "prefix hits never materialized as row copies: {:?}",
                    t.prefix);
            assert!(t.prefix.saved_flops > 0.0,
                    "row copies saved no prefill FLOPs: {:?}", t.prefix);
            witnessed = true;
            break;
        }
    }
    assert!(witnessed,
            "no attempt observed a preemption in 40 tries — the stub \
             scheduling interleaving never yanked the target; the \
             byte-identity checks all passed, but the resume-via-cache \
             path went unexercised");
}

/// Pipelining over one TCP connection: tagged requests answered
/// out-of-order-safe, every reply carrying its client `"id"` verbatim —
/// including structured errors for tagged-but-bad requests — and the
/// final lines reporting `"ttft_ms"`.
#[test]
fn stub_tcp_pipelining_correlates_replies_by_id() {
    let coord = Arc::new(coordinator_with(stub_spec(), 4, 1));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv_coord = coord.clone();
    std::thread::spawn(move || {
        let _ = server::serve(srv_coord, "127.0.0.1:0", move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    let mut stream = TcpStream::connect(addr).unwrap();
    // Three lines back-to-back, no reads in between: a long request, a
    // short one, and a tagged-but-malformed one (no prompt).
    stream.write_all(
        b"{\"id\": 7, \"prompt\": \"abc\", \"max_new_tokens\": 30}\n\
          {\"id\": 9, \"prompt\": \"xyz\", \"max_new_tokens\": 4}\n\
          {\"id\": \"bad\", \"n\": 2}\n").unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut by_id = std::collections::HashMap::new();
    while by_id.len() < 3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        if j.opt("event").is_some() {
            continue; // streaming deltas (none expected here)
        }
        let id = match j.get("id").expect("every reply is tagged") {
            Json::Num(n) => format!("{n}"),
            Json::Str(s) => s.clone(),
            other => panic!("unexpected id shape: {other:?}"),
        };
        by_id.insert(id, j);
    }

    let ok7 = &by_id["7"];
    assert_eq!(ok7.get("ok").unwrap(), &Json::Bool(true));
    assert_eq!(ok7.get("seqs").unwrap().as_arr().unwrap()[0]
               .get("n_tokens").unwrap().as_usize().unwrap(), 30);
    assert!(ok7.get("ttft_ms").unwrap().as_f64().unwrap() >= 0.0);

    let ok9 = &by_id["9"];
    assert_eq!(ok9.get("ok").unwrap(), &Json::Bool(true));
    assert_eq!(ok9.get("seqs").unwrap().as_arr().unwrap()[0]
               .get("n_tokens").unwrap().as_usize().unwrap(), 4);

    let bad = &by_id["bad"];
    assert_eq!(bad.get("ok").unwrap(), &Json::Bool(false),
               "malformed tagged request must error, with the id echoed");
}

/// Tentpole acceptance (satellite 3b): a traced stub-coordinator run
/// exports a Chrome trace whose request swimlanes are exactly the
/// submitted requests — every `admit`/`retire` lane is a real request
/// id, every request got both, and the export parses as valid JSON
/// with non-decreasing timestamps.
#[test]
fn stub_trace_export_matches_submitted_requests() {
    use bass::obs::{SpanKind, Tracer};
    let tracer = Tracer::wall(4096);
    let mut cfg = config_with(stub_spec(), 4, 1);
    cfg.tracer = tracer.clone();
    let coord = Coordinator::start(cfg).expect("coordinator start");
    let rxs: Vec<_> = (0..3)
        .map(|i| coord.submit(request(&format!("req {i}"), 1, 8, false)))
        .collect();
    for rx in rxs {
        let resp = Coordinator::wait(rx).unwrap();
        assert_eq!(resp.seqs[0].n_tokens, 8);
    }
    coord.shutdown();

    let events = tracer.snapshot();
    assert_eq!(tracer.dropped(), 0, "ring overflowed a tiny run");
    // Worker request ids start at 1; three submissions → lanes {1,2,3}.
    let admits: std::collections::HashSet<u64> = events
        .iter()
        .filter(|e| e.kind == SpanKind::Admit)
        .map(|e| e.request)
        .collect();
    assert_eq!(admits, (1..=3).collect(),
               "admit lanes must be exactly the submitted requests");
    let retires: std::collections::HashSet<u64> = events
        .iter()
        .filter(|e| e.kind == SpanKind::Retire)
        .map(|e| e.request)
        .collect();
    assert_eq!(retires, admits, "every admitted request must retire");
    for e in &events {
        assert!(e.request == 0 || admits.contains(&e.request),
                "{:?} on unknown lane {}", e.kind, e.request);
    }
    // The step phases really recorded as duration spans on the engine
    // lane, with the launch geometry in their meta.
    let draft = events
        .iter()
        .find(|e| e.kind == SpanKind::Draft)
        .expect("no draft span recorded");
    assert_eq!(draft.request, 0);
    assert_eq!(draft.mode, "stub");
    assert!(draft.meta.iter().any(|&(k, v)| k == "k" && v > 0.0),
            "draft span lost its launch width: {:?}", draft.meta);
    assert!(events.iter().any(|e| e.kind == SpanKind::Verify));
    assert!(events.iter().any(|e| e.kind == SpanKind::SeqStep));

    // Chrome export: parses, timestamps non-decreasing in file order,
    // phases restricted to complete/instant/metadata.
    let text = tracer.chrome_trace().to_string_pretty();
    let back = Json::parse(&text).expect("trace must be valid JSON");
    let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(evs.len() >= events.len());
    let mut last_ts = 0.0f64;
    for e in evs {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        assert!(matches!(ph, "X" | "i" | "M"), "unexpected phase {ph}");
        if ph == "M" {
            continue;
        }
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        assert!(ts >= last_ts, "timestamps regressed: {ts} < {last_ts}");
        last_ts = ts;
    }
}

/// The `stats` admin path: an on-demand registry snapshot over the API
/// and the wire, served without perturbing generation. With tracing
/// enabled the snapshot grows the `spans` section (schema-additive).
#[test]
fn stub_stats_snapshot_on_demand_and_over_tcp() {
    use bass::obs::Tracer;
    let mut cfg = config_with(stub_spec(), 4, 1);
    cfg.tracer = Tracer::wall(4096);
    let coord = Arc::new(Coordinator::start(cfg).expect("start"));
    let resp = coord.generate(request("warm", 1, 8, false)).unwrap();
    assert_eq!(resp.seqs[0].n_tokens, 8);

    // Direct API.
    let snap = coord.stats().expect("stats snapshot");
    let sched = snap.get("sched").expect("sched section");
    assert!(sched.get("queue_depth").unwrap().as_usize().is_ok());
    let spans = snap.get("spans").expect("spans section (tracing on)");
    let counts = spans.get("span_counts").unwrap();
    assert!(counts.get("admit").unwrap().as_usize().unwrap() >= 1);
    assert!(counts.get("retire").unwrap().as_usize().unwrap() >= 1);

    // Wire admin command, pipelined with an id tag.
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv_coord = coord.clone();
    std::thread::spawn(move || {
        let _ = server::serve(srv_coord, "127.0.0.1:0", move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).unwrap();
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(b"{\"cmd\": \"stats\", \"id\": 3}\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
    assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 3);
    let stats = j.get("stats").unwrap();
    assert!(stats.get("sched").is_ok());
    assert!(stats.get("spans").is_ok(), "spans section missing on wire");
}
