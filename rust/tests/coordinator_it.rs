//! Coordinator + TCP server integration tests: request queueing, dynamic
//! co-batching, fan-out slicing and the line protocol, over real artifacts.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use bass::bench_util::{artifacts_available, artifacts_root};
use bass::coordinator::batcher::BatcherConfig;
use bass::coordinator::{server, Coordinator, CoordinatorConfig, Request};
use bass::runtime::json::Json;
use bass::spec::SpecConfig;
use bass::tokenizer;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn coordinator(max_batch: usize, window_ms: u64) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        artifacts_root: artifacts_root(),
        spec: SpecConfig { max_new_tokens: 12, ..SpecConfig::default() },
        batcher: BatcherConfig {
            max_batch,
            window: Duration::from_millis(window_ms),
        },
        prewarm: false, // keep tests fast; lazy compiles are fine here
    })
    .expect("coordinator start")
}

fn code_request(n: usize) -> Request {
    Request {
        prompt: tokenizer::encode(
            "def add_7(x):\n    # adds 7 to x\n    return"),
        n_seqs: n,
        max_new_tokens: Some(12),
        temperature: None,
        top_p: None,
    }
}

#[test]
fn single_request_roundtrip() {
    require_artifacts!();
    let coord = coordinator(4, 1);
    let resp = coord.generate(code_request(2)).unwrap();
    assert_eq!(resp.seqs.len(), 2);
    assert!(resp.seqs[0].n_tokens > 0);
    assert!(resp.batch_secs > 0.0);
}

#[test]
fn concurrent_requests_are_cobatched() {
    require_artifacts!();
    let coord = Arc::new(coordinator(8, 30));
    // Warm the engine so the co-batch window isn't dwarfed by compiles.
    let _ = coord.generate(code_request(1));
    let rx1 = coord.submit(code_request(2));
    let rx2 = coord.submit(code_request(2));
    let r1 = rx1.recv().unwrap().unwrap();
    let r2 = rx2.recv().unwrap().unwrap();
    assert_eq!(r1.seqs.len(), 2);
    assert_eq!(r2.seqs.len(), 2);
    // Both rode the same engine batch (2 + 2 sequences).
    assert_eq!(r1.batch_size, 4);
    assert_eq!(r2.batch_size, 4);
}

#[test]
fn fanout_clamped_to_max_batch() {
    require_artifacts!();
    let coord = coordinator(4, 1);
    let resp = coord.generate(code_request(9)).unwrap();
    assert_eq!(resp.seqs.len(), 4);
}

#[test]
fn tcp_server_line_protocol() {
    require_artifacts!();
    let coord = Arc::new(coordinator(4, 1));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv_coord = coord.clone();
    std::thread::spawn(move || {
        let _ = server::serve(srv_coord, "127.0.0.1:0", move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(
            b"{\"prompt\": \"def add_7(x):\\n    # adds 7 to x\\n    \
              return\", \"n\": 2, \"max_new_tokens\": 8}\n")
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
    assert_eq!(j.get("seqs").unwrap().as_arr().unwrap().len(), 2);

    // Malformed request gets a structured error, connection stays open.
    stream.write_all(b"not json\n").unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    let j2 = Json::parse(&line2).unwrap();
    assert_eq!(j2.get("ok").unwrap(), &Json::Bool(false));
}
