//! The archetype invariant behind continuous batching: driving the
//! resumable `SpecBatch` API step by step must reproduce the one-shot
//! `SpecEngine::generate` **byte for byte** (and logP for logP) — in
//! PAD, SPLIT and PACKED execution modes. If this holds, the
//! coordinator may interleave admission/retirement at any step boundary
//! without changing any sequence's output, because each sequence's
//! randomness and cache state are functions of (prompt, seed, admission
//! index) alone. For PACKED the same assertions additionally pin the
//! segment-packing round trip: offsets, filler rows and the unpack back
//! to launch-width layout must be invisible next to a solo run.

use bass::bench_util::{artifacts_available, artifacts_root};
use bass::kv::FinishReason;
use bass::runtime::Engine;
use bass::spec::{AdmitOpts, ExecMode, Policy, SpecBatch, SpecConfig,
                 SpecEngine};
use bass::tokenizer;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn engine() -> Engine {
    Engine::load(&artifacts_root()).expect("engine load")
}

fn prompts() -> Vec<Vec<u8>> {
    vec![
        tokenizer::encode("def add_7(x):\n    # adds 7 to x\n    return"),
        tokenizer::encode("def mul_3(x):\n    return"),
        tokenizer::encode("article: alice went to the market. summary:"),
    ]
}

fn cfg(mode: ExecMode) -> SpecConfig {
    SpecConfig {
        max_new_tokens: 20,
        policy: Policy::Fixed(4),
        mode,
        seed: 42,
        ..SpecConfig::default()
    }
}

/// Drive a SpecBatch manually to completion and return the final states
/// in admission order.
fn run_stepwise(e: &Engine, cfg: &SpecConfig, prompts: &[Vec<u8>])
                -> Vec<bass::kv::SeqState> {
    let mut batch = SpecBatch::new(e, cfg.clone(), prompts.len()).unwrap();
    let mut ids = Vec::new();
    for p in prompts {
        ids.push(batch.admit(p, cfg.seed).unwrap());
    }
    let mut guard = 0;
    while batch.has_active() {
        let report = batch.step().unwrap();
        assert_eq!(report.k, 4, "Fixed(4) must hold every step");
        // Events cover exactly the sequences that were active.
        assert!(!report.events.is_empty());
        guard += 1;
        assert!(guard < 1000, "runaway stepwise loop");
    }
    ids.into_iter().map(|id| batch.retire(id).unwrap()).collect()
}

fn assert_equivalent(mode: ExecMode) {
    let e = engine();
    let cfg = cfg(mode);
    let prompts = prompts();

    let oneshot = SpecEngine::new(&e, cfg.clone())
        .generate(&prompts)
        .unwrap();
    let stepwise = run_stepwise(&e, &cfg, &prompts);

    assert_eq!(oneshot.seqs.len(), stepwise.len());
    for (i, (a, b)) in oneshot.seqs.iter().zip(&stepwise).enumerate() {
        assert_eq!(a.generated, b.generated,
                   "{mode:?} seq {i}: stepwise bytes diverge from one-shot");
        assert_eq!(a.finish, b.finish, "{mode:?} seq {i}: finish reason");
        assert!((a.mean_logp() - b.mean_logp()).abs() < 1e-12,
                "{mode:?} seq {i}: mean_logp {} vs {}", a.mean_logp(),
                b.mean_logp());
        assert_ne!(a.finish, FinishReason::Running);
    }
}

#[test]
fn stepwise_equals_oneshot_pad() {
    require_artifacts!();
    assert_equivalent(ExecMode::Pad);
}

#[test]
fn stepwise_equals_oneshot_split() {
    require_artifacts!();
    assert_equivalent(ExecMode::Split);
}

#[test]
fn stepwise_equals_oneshot_packed() {
    require_artifacts!();
    assert_equivalent(ExecMode::Packed);
}

#[test]
fn stepwise_equals_oneshot_heuristic_policy() {
    require_artifacts!();
    // The adaptive policy observes per-step accept counts; stepwise
    // driving must feed it identically.
    let e = engine();
    let cfg = SpecConfig {
        max_new_tokens: 24,
        seed: 7,
        ..SpecConfig::default()
    };
    let prompts = prompts();
    let oneshot = SpecEngine::new(&e, cfg.clone())
        .generate(&prompts)
        .unwrap();
    let stepwise = run_stepwise_lenient(&e, &cfg, &prompts);
    for (a, b) in oneshot.seqs.iter().zip(&stepwise) {
        assert_eq!(a.generated, b.generated);
    }
}

/// Like `run_stepwise` but without Fixed(4)-specific assertions.
fn run_stepwise_lenient(e: &Engine, cfg: &SpecConfig, prompts: &[Vec<u8>])
                        -> Vec<bass::kv::SeqState> {
    let mut batch = SpecBatch::new(e, cfg.clone(), prompts.len()).unwrap();
    let mut ids = Vec::new();
    for p in prompts {
        ids.push(batch.admit(p, cfg.seed).unwrap());
    }
    while batch.has_active() {
        batch.step().unwrap();
    }
    ids.into_iter().map(|id| batch.retire(id).unwrap()).collect()
}

/// Per-request sampling params: each request run SOLO with its own
/// (temperature, top_p, seed) must byte-match the same request co-batched
/// with differently-parameterized traffic. Streams are pinned to 0 — the
/// admission index each solo `generate` run uses — so the randomness is a
/// pure function of the request's seed, and `Policy::Fixed` keeps draft
/// lengths batch-independent. This is the invariant that lets the
/// coordinator thread `Request::temperature`/`top_p` through `admit_opts`
/// without changing any co-batched request's output.
fn assert_mixed_params_equivalent(mode: ExecMode) {
    let e = engine();
    let base = cfg(mode);
    let prompts = prompts();
    let params = [(0.8f32, 0.9f32), (0.2, 0.95), (1.5, 1.0)];
    let seeds = [11u64, 42, 99];

    // Solo reference runs: one request per engine batch, its own params.
    let mut solo = Vec::new();
    for i in 0..prompts.len() {
        let cfg_i = SpecConfig {
            temperature: params[i].0,
            top_p: params[i].1,
            seed: seeds[i],
            ..base.clone()
        };
        let r = SpecEngine::new(&e, cfg_i)
            .generate(&[prompts[i].clone()])
            .unwrap();
        solo.push(r.seqs.into_iter().next().unwrap());
    }

    // Co-batched run: all three requests share one batch, each admitted
    // with its own per-sequence sampling overrides.
    let mut batch =
        SpecBatch::new(&e, base.clone(), prompts.len()).unwrap();
    let mut ids = Vec::new();
    for i in 0..prompts.len() {
        let id = batch
            .admit_opts(&prompts[i], seeds[i], AdmitOpts {
                stream: Some(0),
                temperature: Some(params[i].0),
                top_p: Some(params[i].1),
                ..AdmitOpts::default()
            })
            .unwrap();
        ids.push(id);
    }
    let mut guard = 0;
    while batch.has_active() {
        batch.step().unwrap();
        guard += 1;
        assert!(guard < 1000, "runaway mixed-params loop");
    }
    for (i, id) in ids.into_iter().enumerate() {
        let st = batch.retire(id).unwrap();
        assert_eq!(solo[i].generated, st.generated,
                   "{mode:?} req {i}: co-batched bytes diverge from the \
                    solo run with its own sampling params");
        assert_eq!(solo[i].finish, st.finish,
                   "{mode:?} req {i}: finish reason");
        assert!((solo[i].mean_logp() - st.mean_logp()).abs() < 1e-12,
                "{mode:?} req {i}: mean_logp {} vs {}",
                solo[i].mean_logp(), st.mean_logp());
        assert_ne!(st.finish, FinishReason::Running);
    }
}

#[test]
fn mixed_params_cobatch_equals_solo_pad() {
    require_artifacts!();
    assert_mixed_params_equivalent(ExecMode::Pad);
}

#[test]
fn mixed_params_cobatch_equals_solo_split() {
    require_artifacts!();
    assert_mixed_params_equivalent(ExecMode::Split);
}

/// Packed-vs-solo byte exactness under `Policy::Fixed`: ragged qlens
/// (each row accepts differently) exercise the packed verify stream
/// with real filler slack, and every request must still match its solo
/// run exactly.
#[test]
fn mixed_params_cobatch_equals_solo_packed() {
    require_artifacts!();
    assert_mixed_params_equivalent(ExecMode::Packed);
}

/// The per-sequence-draft-length tentpole invariant: under the
/// **adaptive** policy a request's output is a pure function of
/// (prompt, seed, stream). Each row runs its own Algorithm-1 controller
/// fed only by its own acceptance, and consumes exactly its own `k_i`
/// draft uniforms per step — so co-batched traffic can bend neither its
/// draft-length trajectory (the old batch-global Algorithm-1 state) nor
/// its RNG stream positions (the old launch-width uniform draw). Before
/// this refactor the equivalent assertion only held under
/// `Policy::Fixed` (see `assert_mixed_params_equivalent`).
fn assert_heuristic_cobatch_equals_solo(mode: ExecMode) {
    let e = engine();
    let base = SpecConfig {
        max_new_tokens: 24,
        policy: Policy::Heuristic,
        mode,
        seed: 7,
        temperature: 2.0, // high entropy: acceptance differs per row,
        top_p: 1.0,       // so per-row controllers genuinely diverge
        ..SpecConfig::default()
    };
    let prompts = prompts();
    let seeds = [7u64, 11, 99];

    let solo: Vec<_> = (0..prompts.len())
        .map(|i| solo_pinned(&e, &base, &prompts[i], seeds[i]))
        .collect();

    let mut batch =
        SpecBatch::new(&e, base.clone(), prompts.len()).unwrap();
    let ids: Vec<_> = (0..prompts.len())
        .map(|i| {
            batch
                .admit_opts(&prompts[i], seeds[i], AdmitOpts {
                    stream: Some(0),
                    ..AdmitOpts::default()
                })
                .unwrap()
        })
        .collect();
    let mut guard = 0;
    while batch.has_active() {
        batch.step().unwrap();
        guard += 1;
        assert!(guard < 1000, "runaway heuristic co-batch loop");
    }
    for (i, id) in ids.into_iter().enumerate() {
        let st = batch.retire(id).unwrap();
        assert_eq!(solo[i].generated, st.generated,
                   "{mode:?} req {i}: adaptive-policy co-batched bytes \
                    diverge from the solo run");
        assert_eq!(solo[i].finish, st.finish,
                   "{mode:?} req {i}: finish reason");
        assert!((solo[i].mean_logp() - st.mean_logp()).abs() < 1e-12,
                "{mode:?} req {i}: mean_logp {} vs {}",
                solo[i].mean_logp(), st.mean_logp());
        assert_ne!(st.finish, FinishReason::Running);
    }
}

#[test]
fn heuristic_cobatch_equals_solo_pad() {
    require_artifacts!();
    assert_heuristic_cobatch_equals_solo(ExecMode::Pad);
}

#[test]
fn heuristic_cobatch_equals_solo_split() {
    require_artifacts!();
    assert_heuristic_cobatch_equals_solo(ExecMode::Split);
}

/// Packed-vs-solo byte exactness under the **adaptive** policy: per-row
/// controllers diverge, so the packed draft sees genuinely ragged k_i
/// (packed-prefix uniforms) while verify sees ragged q_i — the full
/// zero-pad layout, pinned bitwise against solo runs.
#[test]
fn heuristic_cobatch_equals_solo_packed() {
    require_artifacts!();
    assert_heuristic_cobatch_equals_solo(ExecMode::Packed);
}

/// The preemption invariant (acceptance criterion of the scheduler PR):
/// suspend → resume-by-recompute must be **invisible** to the sequence.
/// The interrupted run goes through two full preemption cycles — suspend
/// mid-generation, let an unrelated interloper run (and retire) in the
/// freed slot, resume, generate one more step, suspend *again* (now from
/// the n_pending=1 restart state), resume again — and must still produce
/// bytes, finish reason and logP identical to the uninterrupted solo run.
/// High temperature keeps the reference long enough to bisect twice. No
/// artifact/manifest change is involved: resume recomputes the KV row
/// with the existing prefill (SPLIT) / prefill_scatter (PAD) programs.
fn assert_suspend_resume_identity(mode: ExecMode) {
    let e = engine();
    let cfg = SpecConfig {
        temperature: 2.0, // ramble: no early EOS, reference hits Length
        top_p: 1.0,
        ..cfg(mode)
    };
    let prompt = &prompts()[0];

    // Uninterrupted reference.
    let mut refb = SpecBatch::new(&e, cfg.clone(), 1).unwrap();
    let rid = refb.admit(prompt, cfg.seed).unwrap();
    let mut guard = 0;
    while refb.has_active() {
        refb.step().unwrap();
        guard += 1;
        assert!(guard < 200, "runaway reference run");
    }
    let want = refb.retire(rid).unwrap();
    // Two single-step preemption cycles emit at most 2 * (k + 1) = 10
    // bytes; the reference must outlive them so every suspend really
    // bisects a still-running sequence.
    assert!(want.tokens_generated() >= 12,
            "{mode:?}: reference too short ({} tokens) to bisect twice",
            want.tokens_generated());

    let mut batch = SpecBatch::new(&e, cfg.clone(), 1).unwrap();
    let mut cur = batch.admit(prompt, cfg.seed).unwrap();
    for cycle in 0..2u64 {
        batch.step().unwrap();
        assert!(batch.can_suspend(cur),
                "{mode:?} cycle {cycle}: sequence not suspendable");
        let snap = batch.suspend(cur).unwrap();
        assert_eq!(batch.occupied(), 0,
                   "{mode:?} cycle {cycle}: suspend must free the slot");
        if cycle > 0 {
            assert!(snap.tokens_generated() > 0, "progress carried over");
        }
        // Interloper: unrelated traffic occupies (and perturbs) the freed
        // slot, then retires — the resumed KV row is rebuilt from scratch
        // either way.
        let other = batch.admit(&prompts()[1], 99 + cycle).unwrap();
        let mut g = 0;
        while batch.has_active() {
            batch.step().unwrap();
            g += 1;
            assert!(g < 200, "runaway interloper");
        }
        batch.retire(other).unwrap();
        let resumed = batch.resume(snap).unwrap();
        assert_ne!(resumed, cur, "SeqIds are never reused across resume");
        cur = resumed;
    }
    let mut g = 0;
    while batch.has_active() {
        batch.step().unwrap();
        g += 1;
        assert!(g < 200, "runaway resumed run");
    }
    let got = batch.retire(cur).unwrap();

    assert_eq!(want.generated, got.generated,
               "{mode:?}: preempted run bytes diverge from the \
                uninterrupted run");
    assert_eq!(want.finish, got.finish, "{mode:?}: finish reason");
    assert!((want.mean_logp() - got.mean_logp()).abs() < 1e-12,
            "{mode:?}: mean_logp {} vs {}", want.mean_logp(),
            got.mean_logp());
    assert_ne!(got.finish, FinishReason::Running);
    let s_max = e.manifest.model("main").unwrap().s_max as i32;
    got.check_invariants(s_max).unwrap();
}

#[test]
fn suspend_resume_is_invisible_pad() {
    require_artifacts!();
    assert_suspend_resume_identity(ExecMode::Pad);
}

#[test]
fn suspend_resume_is_invisible_split() {
    require_artifacts!();
    assert_suspend_resume_identity(ExecMode::Split);
}

/// PACKED reuses the PAD fused-bucket lifecycle (suspend leaves a Husk
/// row, resume scatter-prefills over it), so the preemption-invisibility
/// contract must hold unchanged.
#[test]
fn suspend_resume_is_invisible_packed() {
    require_artifacts!();
    assert_suspend_resume_identity(ExecMode::Packed);
}

/// Resume must also be exact into a *running* PAD bucket: the suspended
/// sequence scatter-prefills over the Husk row its own suspension left
/// while a co-resident sequence keeps stepping — the mid-flight-resume
/// edge the capacity-1 test above cannot reach.
#[test]
fn suspend_resume_into_running_pad_bucket() {
    require_artifacts!();
    let e = engine();
    let cfg = SpecConfig {
        temperature: 2.0,
        top_p: 1.0,
        max_new_tokens: 24,
        ..cfg(ExecMode::Pad)
    };
    let prompt = &prompts()[0];

    // Reference: the target co-resident with the long companion from
    // step 0, never interrupted. Streams are pinned so identity is a
    // function of (prompt, seed, stream) in both runs.
    fn admit_pinned(batch: &mut SpecBatch, p: &[u8], seed: u64)
                    -> bass::spec::SeqId {
        batch.admit_opts(p, seed, AdmitOpts {
            stream: Some(0),
            ..AdmitOpts::default()
        }).unwrap()
    }
    let mut refb = SpecBatch::new(&e, cfg.clone(), 2).unwrap();
    let target_ref = admit_pinned(&mut refb, prompt, 7);
    let _company = admit_pinned(&mut refb, &prompts()[2], 13);
    let mut guard = 0;
    while refb.has_active() {
        refb.step().unwrap();
        guard += 1;
        assert!(guard < 200);
    }
    let want = refb.retire(target_ref).unwrap();
    assert!(want.tokens_generated() >= 8, "reference too short");

    // Interrupted: same pair, but the target is suspended after one step
    // and resumed two steps later into the STILL-RUNNING bucket (the
    // companion keeps it alive, so the resume goes through the
    // prefill_scatter path, not a fresh fused prefill).
    let mut batch = SpecBatch::new(&e, cfg.clone(), 2).unwrap();
    let target = admit_pinned(&mut batch, prompt, 7);
    let company = admit_pinned(&mut batch, &prompts()[2], 13);
    batch.step().unwrap();
    let snap = batch.suspend(target).unwrap();
    assert_eq!(batch.occupied(), 1, "companion keeps the bucket running");
    batch.step().unwrap();
    batch.step().unwrap();
    assert!(batch.has_active(),
            "companion must still be running for a mid-flight resume \
             (raise its budget if this fires)");
    let resumed = batch.resume(snap).unwrap();
    let mut guard = 0;
    while batch.has_active() {
        batch.step().unwrap();
        guard += 1;
        assert!(guard < 200);
    }
    let got = batch.retire(resumed).unwrap();
    let _ = batch.retire(company);

    assert_eq!(want.generated, got.generated,
               "mid-flight PAD resume diverged from the co-resident \
                reference");
    assert_eq!(want.finish, got.finish);
    assert!((want.mean_logp() - got.mean_logp()).abs() < 1e-12);
}

/// Run a one-slot reference batch for `prompt` with a pinned stream and
/// return its final state (the solo run every re-bucket pin compares
/// against).
fn solo_pinned(e: &Engine, cfg: &SpecConfig, prompt: &[u8], seed: u64)
               -> bass::kv::SeqState {
    let mut refb = SpecBatch::new(e, cfg.clone(), 1).unwrap();
    let id = refb
        .admit_opts(prompt, seed, AdmitOpts {
            stream: Some(0),
            ..AdmitOpts::default()
        })
        .unwrap();
    let mut guard = 0;
    while refb.has_active() {
        refb.step().unwrap();
        guard += 1;
        assert!(guard < 200, "runaway reference run");
    }
    refb.retire(id).unwrap()
}

/// Live re-bucketing identity, GROW: a PAD batch running at bucket 1
/// grows mid-generation (the carried row is rebuilt by the same bitwise
/// recompute as resume), a late burst scatter-admits into the fresh
/// Shadow rows with no drain, and the carried sequence still reproduces
/// its solo run byte-for-byte (and logP-for-logP) under `Policy::Fixed`.
/// No artifact/manifest change is involved: the grow is one fused
/// prefill with the existing per-bucket programs.
#[test]
fn rebucket_grow_mid_generation_is_invisible_pad() {
    require_artifacts!();
    let e = engine();
    let cfg = SpecConfig {
        temperature: 2.0, // ramble: the target outlives the whole dance
        top_p: 1.0,
        ..cfg(ExecMode::Pad)
    };
    let prompt = &prompts()[0];
    let want = solo_pinned(&e, &cfg, prompt, 7);
    assert!(want.tokens_generated() >= 10,
            "reference too short ({} tokens) to bisect with a grow",
            want.tokens_generated());

    // Interrupted: the same admission at capacity 4 — the lazy start
    // still buckets TIGHT at 1, so the running bucket has zero reusable
    // rows and a burst can only be served by growing it live.
    let mut batch = SpecBatch::new(&e, cfg.clone(), 4).unwrap();
    let target = batch
        .admit_opts(prompt, 7, AdmitOpts {
            stream: Some(0),
            ..AdmitOpts::default()
        })
        .unwrap();
    batch.step().unwrap();
    assert_eq!(batch.bucket_rows(), Some(1), "tight bucket to start");
    assert!(!batch.can_admit(), "bucket of 1 fully live");
    let r = batch
        .rebucket(3)
        .unwrap()
        .expect("grow must execute on a fully-live bucket");
    assert_eq!((r.from, r.migrated), (1, 1));
    assert!(r.to >= 3, "bucket must cover the demand (got {})", r.to);
    assert_eq!(batch.bucket_rows(), Some(r.to));
    // The burst lands in the grown bucket's fresh rows while the target
    // keeps generating — scatter admission, no drain in between.
    let a = batch.admit(&prompts()[1], 11).unwrap();
    let b = batch.admit(&prompts()[2], 13).unwrap();
    assert!(batch.occupied() >= 3);
    let mut guard = 0;
    while batch.has_active() {
        batch.step().unwrap();
        guard += 1;
        assert!(guard < 200, "runaway grown run");
    }
    let got = batch.retire(target).unwrap();
    let _ = batch.retire(a);
    let _ = batch.retire(b);

    assert_eq!(want.generated, got.generated,
               "grow-carried bytes diverge from the solo run");
    assert_eq!(want.finish, got.finish, "finish reason");
    assert!((want.mean_logp() - got.mean_logp()).abs() < 1e-12,
            "mean_logp {} vs {}", want.mean_logp(), got.mean_logp());
    assert_ne!(got.finish, FinishReason::Running);
}

/// Live re-bucketing identity, RESUME FOLD: a suspended sequence rides
/// the grow's single fused prefill (`SpecBatch::rebucket_resume`)
/// instead of a separate scatter prefill afterwards, and both the
/// carried row and the folded rider still reproduce the co-resident
/// reference byte-for-byte. This pins the one-launch resume path the
/// coordinator prefers when a re-bucket and parked resumes land on the
/// same tick.
#[test]
fn rebucket_resume_folds_rider_bitwise_pad() {
    require_artifacts!();
    let e = engine();
    let cfg = SpecConfig {
        temperature: 2.0,
        top_p: 1.0,
        max_new_tokens: 24,
        ..cfg(ExecMode::Pad)
    };
    let p_target = &prompts()[0];
    let p_rider = &prompts()[2];
    fn admit_pinned(batch: &mut SpecBatch, p: &[u8], seed: u64)
                    -> bass::spec::SeqId {
        batch.admit_opts(p, seed, AdmitOpts {
            stream: Some(0),
            ..AdmitOpts::default()
        }).unwrap()
    }

    // Reference: both sequences co-resident from step 0, uninterrupted.
    // Streams are pinned, so each row's identity is a function of
    // (prompt, seed, stream) regardless of bucket geometry.
    let mut refb = SpecBatch::new(&e, cfg.clone(), 2).unwrap();
    let t_ref = admit_pinned(&mut refb, p_target, 7);
    let r_ref = admit_pinned(&mut refb, p_rider, 13);
    let mut guard = 0;
    while refb.has_active() {
        refb.step().unwrap();
        guard += 1;
        assert!(guard < 200);
    }
    let want_t = refb.retire(t_ref).unwrap();
    let want_r = refb.retire(r_ref).unwrap();
    assert!(want_t.tokens_generated() >= 8
                && want_r.tokens_generated() >= 8,
            "references too short to bisect with a suspend + fold");

    // Interrupted: suspend the rider after one step, let the target run
    // on, then grow the live bucket with the rider folded into the SAME
    // fused prefill (one launch re-encodes the carried target and
    // prefills the rider's context).
    let mut batch = SpecBatch::new(&e, cfg.clone(), 4).unwrap();
    let target = admit_pinned(&mut batch, p_target, 7);
    let rider = admit_pinned(&mut batch, p_rider, 13);
    batch.step().unwrap();
    assert_eq!(batch.bucket_rows(), Some(2), "tight bucket to start");
    let snap = batch.suspend(rider).unwrap();
    batch.step().unwrap();
    assert!(batch.has_active(),
            "target must still be running when the fold lands");
    assert!(batch.rebucket_target_with(3, 1).is_some(),
            "a larger bucket must exist for the fold to target");
    let (r, ids) = batch.rebucket_resume(3, vec![snap]).unwrap();
    assert!(r.to >= 3, "bucket must cover the demand (got {})", r.to);
    // `migrated` counts every row the fused prefill re-encoded: the
    // carried target plus the folded rider.
    assert_eq!(r.migrated, 2, "carried target + folded rider re-encode");
    assert_eq!(ids.len(), 1, "one rider resumed by the fold");
    let rider = ids[0];
    assert_eq!(batch.occupied(), 2);
    let mut guard = 0;
    while batch.has_active() {
        batch.step().unwrap();
        guard += 1;
        assert!(guard < 200, "runaway folded run");
    }
    let got_t = batch.retire(target).unwrap();
    let got_r = batch.retire(rider).unwrap();

    assert_eq!(want_t.generated, got_t.generated,
               "fold-carried bytes diverge from the co-resident \
                reference");
    assert_eq!(want_r.generated, got_r.generated,
               "folded-rider bytes diverge from the co-resident \
                reference");
    assert_eq!(want_t.finish, got_t.finish);
    assert_eq!(want_r.finish, got_r.finish);
    assert!((want_t.mean_logp() - got_t.mean_logp()).abs() < 1e-12);
    assert!((want_r.mean_logp() - got_r.mean_logp()).abs() < 1e-12);
}

/// Live re-bucketing identity, SHRINK: three sequences start at bucket
/// 4; after the two short companions retire, the bucket shrinks to 1
/// mid-generation (dropping their husk rows) and the survivor still
/// matches its solo run byte-for-byte.
#[test]
fn rebucket_shrink_after_retire_is_invisible_pad() {
    require_artifacts!();
    let e = engine();
    let cfg = SpecConfig {
        temperature: 2.0,
        top_p: 1.0,
        ..cfg(ExecMode::Pad)
    };
    let prompt = &prompts()[0];
    let want = solo_pinned(&e, &cfg, prompt, 7);
    assert!(want.tokens_generated() >= 10, "reference too short");

    let mut batch = SpecBatch::new(&e, cfg.clone(), 4).unwrap();
    let target = batch
        .admit_opts(prompt, 7, AdmitOpts {
            stream: Some(0),
            ..AdmitOpts::default()
        })
        .unwrap();
    let short = |batch: &mut SpecBatch, p: &[u8], seed: u64| {
        batch
            .admit_opts(p, seed, AdmitOpts {
                max_new_tokens: Some(2), // one step and out
                ..AdmitOpts::default()
            })
            .unwrap()
    };
    let c1 = short(&mut batch, &prompts()[1], 11);
    let c2 = short(&mut batch, &prompts()[2], 13);
    batch.step().unwrap();
    assert_eq!(batch.bucket_rows(), Some(4), "3 admits bucket at 4");
    batch.retire(c1).unwrap();
    batch.retire(c2).unwrap();
    assert_eq!(batch.occupied(), 1, "companions must have retired");
    assert!(batch.has_active(), "target must still be generating");
    let r = batch
        .rebucket(batch.occupied())
        .unwrap()
        .expect("shrink must execute on a mostly-empty bucket");
    assert_eq!((r.from, r.to, r.migrated), (4, 1, 1));
    assert_eq!(batch.bucket_rows(), Some(1));
    let mut guard = 0;
    while batch.has_active() {
        batch.step().unwrap();
        guard += 1;
        assert!(guard < 200, "runaway shrunk run");
    }
    let got = batch.retire(target).unwrap();

    assert_eq!(want.generated, got.generated,
               "shrink-carried bytes diverge from the solo run");
    assert_eq!(want.finish, got.finish, "finish reason");
    assert!((want.mean_logp() - got.mean_logp()).abs() < 1e-12,
            "mean_logp {} vs {}", want.mean_logp(), got.mean_logp());
    assert_ne!(got.finish, FinishReason::Running);
}

#[test]
fn split_slot_reuse_is_isolated() {
    require_artifacts!();
    // A sequence's output must be a function of (prompt, seed, admission
    // index) only. Reference: p_long and p_new co-resident from step 0
    // (admission indices 0 and 1). Continuous run: p_long alone, retired,
    // then p_new admitted into the *reused* slot (still admission index
    // 1). The bytes must match exactly — the slot's previous occupant and
    // the changed batch composition must not leak into p_new.
    let e = engine();
    let cfg = SpecConfig {
        max_new_tokens: 12,
        policy: Policy::Fixed(4), // stateless policy: k identical in both
        mode: ExecMode::Split,
        seed: 5,
        ..SpecConfig::default()
    };
    let p_long = tokenizer::encode(
        "def add_7(x):\n    # adds 7 to x\n    return");
    let p_new = tokenizer::encode("def mul_3(x):\n    return");

    // Reference: both sequences from step 0 in a 2-slot batch.
    let mut refb = SpecBatch::new(&e, cfg.clone(), 2).unwrap();
    refb.admit(&p_long, cfg.seed).unwrap();
    let ref_new = refb.admit(&p_new, 99).unwrap();
    while refb.has_active() {
        refb.step().unwrap();
    }
    let ref_state = refb.retire(ref_new).unwrap();

    // Continuous: single slot, serial occupancy.
    let mut batch = SpecBatch::new(&e, cfg.clone(), 1).unwrap();
    let long_id = batch.admit(&p_long, cfg.seed).unwrap();
    while batch.has_active() {
        batch.step().unwrap();
    }
    batch.retire(long_id).unwrap();
    assert!(batch.can_admit(), "retire must free the SPLIT slot");
    let new_id = batch.admit(&p_new, 99).unwrap();
    assert_ne!(new_id, long_id, "SeqIds are never reused");
    while batch.has_active() {
        batch.step().unwrap();
    }
    let new_state = batch.retire(new_id).unwrap();

    assert_eq!(ref_state.generated, new_state.generated,
               "slot reuse leaked state into the new sequence");
    assert!((ref_state.mean_logp() - new_state.mean_logp()).abs() < 1e-12);
    assert_ne!(new_state.finish, FinishReason::Running);
    let s_max = e.manifest.model("main").unwrap().s_max as i32;
    new_state.check_invariants(s_max).unwrap();
}

/// Fan-out prefill sharing (ISSUE 10 tentpole): siblings admitted by
/// `admit_shared_opts` — one KV row copy off a live donor row instead
/// of their own prompt prefill — must be byte-identical (and
/// logP-identical) to solo runs of the same (prompt, seed, stream).
/// The donor's KV for the shared prompt positions IS the prefill the
/// sibling would have computed, so the copy is bitwise invisible; this
/// is what lets the coordinator admit a fan-out-n request with exactly
/// one prefill + (n-1) row copies. Runs per exec backend: the fused
/// modes copy through the device `kv_row_copy` program (PAD slab copy
/// / packed offset-addressed), SPLIT copies its per-slot cache, and
/// the stub copies host-side.
fn assert_shared_fanout_equals_solo(e: &Engine, mode: ExecMode) {
    let cfg = SpecConfig {
        temperature: 2.0,
        top_p: 1.0,
        ..cfg(mode)
    };
    let prompt = &prompts()[0];
    let solo_stream = |stream: u64| {
        let mut refb = SpecBatch::new(e, cfg.clone(), 1).unwrap();
        let id = refb
            .admit_opts(prompt, 7, AdmitOpts {
                stream: Some(stream),
                ..AdmitOpts::default()
            })
            .unwrap();
        let mut guard = 0;
        while refb.has_active() {
            refb.step().unwrap();
            guard += 1;
            assert!(guard < 200, "runaway solo sibling run");
        }
        refb.retire(id).unwrap()
    };
    let solo: Vec<_> = (0..3u64).map(solo_stream).collect();

    // Shared run: the target prefills once (stream 0); two bystanders
    // fill the bucket, step once (the fused modes only have donor rows
    // in a STARTED bucket), then retire to free rows for the siblings.
    let mut batch = SpecBatch::new(e, cfg.clone(), 4).unwrap();
    assert!(batch.donor_row_for(prompt).is_none(),
            "{mode:?}: no donor row before anything is resident");
    let first = batch
        .admit_opts(prompt, 7, AdmitOpts {
            stream: Some(0),
            ..AdmitOpts::default()
        })
        .unwrap();
    let b1 = batch.admit(&prompts()[1], 11).unwrap();
    let b2 = batch.admit(&prompts()[2], 13).unwrap();
    batch.step().unwrap();
    batch.retire(b1).unwrap();
    batch.retire(b2).unwrap();
    let mut ids = vec![first];
    for stream in 1..3u64 {
        let donor = batch
            .donor_row_for(prompt)
            .expect("a resident row encoding the prompt must donate");
        let id = batch
            .admit_shared_opts(donor, prompt, 7, AdmitOpts {
                stream: Some(stream),
                ..AdmitOpts::default()
            })
            .unwrap();
        ids.push(id);
    }
    let mut guard = 0;
    while batch.has_active() {
        batch.step().unwrap();
        guard += 1;
        assert!(guard < 200, "runaway shared-fanout run");
    }
    for (i, id) in ids.into_iter().enumerate() {
        let got = batch.retire(id).unwrap();
        assert_eq!(solo[i].generated, got.generated,
                   "{mode:?} sibling {i} (stream {i}): row-copy admission \
                    diverged from the solo prefill run");
        assert_eq!(solo[i].finish, got.finish,
                   "{mode:?} sibling {i}: finish reason");
        assert!((solo[i].mean_logp() - got.mean_logp()).abs() < 1e-12,
                "{mode:?} sibling {i}: mean_logp {} vs {}",
                solo[i].mean_logp(), got.mean_logp());
        assert_ne!(got.finish, FinishReason::Running);
    }
}

#[test]
fn shared_fanout_equals_solo_pad() {
    require_artifacts!();
    assert_shared_fanout_equals_solo(&engine(), ExecMode::Pad);
}

#[test]
fn shared_fanout_equals_solo_split() {
    require_artifacts!();
    assert_shared_fanout_equals_solo(&engine(), ExecMode::Split);
}

#[test]
fn shared_fanout_equals_solo_packed() {
    require_artifacts!();
    assert_shared_fanout_equals_solo(&engine(), ExecMode::Packed);
}

/// Same contract on the host-only stub backend — no artifact gate, so
/// CI always exercises the shared-admission path end to end.
#[test]
fn shared_fanout_equals_solo_stub() {
    assert_shared_fanout_equals_solo(&Engine::stub(), ExecMode::Stub);
}

/// Satellite 3c — the disabled-is-free / tracing-is-invisible contract,
/// on the stub backend so it runs everywhere (no artifact gate): the
/// same workload driven with tracing OFF and with tracing ON must
/// produce byte-identical outputs AND bit-identical FLOP counters. The
/// tracer only *reads* (its manual clock is its own state), so enabling
/// it can never perturb the deterministic counters the CI gate diffs.
#[test]
fn stub_counters_identical_with_tracing_on_and_off() {
    use bass::obs::Tracer;
    let e = Engine::stub();
    let cfg = SpecConfig {
        max_new_tokens: 20,
        policy: Policy::Heuristic,
        mode: ExecMode::Stub,
        seed: 42,
        ..SpecConfig::default()
    };
    let prompts = prompts();

    let run = |tracer: Option<Tracer>| {
        let mut batch =
            SpecBatch::new(&e, cfg.clone(), prompts.len()).unwrap();
        if let Some(t) = tracer {
            batch.set_tracer(t);
        }
        let ids: Vec<_> = prompts
            .iter()
            .map(|p| batch.admit(p, cfg.seed).unwrap())
            .collect();
        let mut guard = 0;
        while batch.has_active() {
            batch.step().unwrap();
            guard += 1;
            assert!(guard < 1000, "runaway traced-equivalence loop");
        }
        let flops = (batch.flops.launch.to_bits(),
                     batch.flops.padded_launch.to_bits(),
                     batch.flops.total.to_bits());
        let states: Vec<_> = ids
            .into_iter()
            .map(|id| batch.retire(id).unwrap())
            .collect();
        (states, flops)
    };

    let tracer = Tracer::manual(4096);
    let (off, flops_off) = run(None);
    let (on, flops_on) = run(Some(tracer.clone()));

    assert_eq!(flops_off, flops_on,
               "tracing perturbed the FLOP counters (bitwise)");
    for (i, (a, b)) in off.iter().zip(&on).enumerate() {
        assert_eq!(a.generated, b.generated,
                   "seq {i}: bytes diverge with tracing on");
        assert_eq!(a.finish, b.finish, "seq {i}: finish reason");
        assert!((a.mean_logp() - b.mean_logp()).abs() == 0.0,
                "seq {i}: mean_logp drifted under tracing");
    }
    // And the tracer really saw the run: draft+verify spans per step.
    assert!(tracer.recorded() > 0, "enabled tracer recorded nothing");
}
