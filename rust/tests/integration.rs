//! Integration tests over real artifacts (require `make artifacts`).
//!
//! These exercise the full stack: HLO-text load → PJRT compile → device-
//! resident cache feedback → speculative loop → metrics. They are skipped
//! (with a loud message) when artifacts are absent so `cargo test` stays
//! runnable on a fresh checkout.

use bass::baseline::{RdConfig, RegularDecoder};
use bass::bench_util::{artifacts_available, artifacts_root};
use bass::kv::FinishReason;
use bass::runtime::{Attn, Engine, Precision};
use bass::spec::{ExecMode, Policy, SpecConfig, SpecEngine};
use bass::tokenizer;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn engine() -> Engine {
    Engine::load(&artifacts_root()).expect("engine load")
}

fn code_prompt() -> Vec<u8> {
    tokenizer::encode("def add_7(x):\n    # adds 7 to x\n    return")
}

fn small_cfg() -> SpecConfig {
    SpecConfig { max_new_tokens: 16, ..SpecConfig::default() }
}

#[test]
fn engine_loads_manifest_and_weights() {
    require_artifacts!();
    let e = engine();
    assert_eq!(e.manifest.vocab, 256);
    assert!(e.manifest.models.contains_key("main"));
    assert!(e.manifest.models.contains_key("draft_a"));
    let w = e.weights("main", Precision::F32).unwrap();
    assert_eq!(w.len(), 52); // 4 blocks × 12 + embed/pos + ln_f g/b
    let w8 = e.weights("main", Precision::Int8).unwrap();
    assert!(w8.len() > w.len()); // quantized leaves carry scales
}

#[test]
fn prefill_logits_are_finite_and_prompt_dependent() {
    require_artifacts!();
    let e = engine();
    let p = e.manifest.prefill_p;
    let mk = |text: &str| {
        let enc = tokenizer::encode(text);
        let mut toks = vec![0i32; p];
        for (i, &b) in enc.iter().enumerate() {
            toks[i] = b as i32;
        }
        (toks, enc.len() as i32)
    };
    let (t1, l1) = mk("def add_7(x):");
    let (t2, l2) = mk("article: alice");
    let o1 = e.prefill("main", Precision::F32, Attn::Dense, 1, &t1, &[l1])
        .unwrap();
    let o2 = e.prefill("main", Precision::F32, Attn::Dense, 1, &t2, &[l2])
        .unwrap();
    assert_eq!(o1.logits.len(), 256);
    assert!(o1.logits.iter().all(|x| x.is_finite()));
    assert_ne!(o1.logits, o2.logits);
}

#[test]
fn decode_cache_feedback_changes_distribution() {
    require_artifacts!();
    let e = engine();
    let p = e.manifest.prefill_p;
    let mut toks = vec![0i32; p];
    for (i, &b) in code_prompt().iter().enumerate() {
        toks[i] = b as i32;
    }
    let plen = code_prompt().len() as i32;
    let out = e.prefill("main", Precision::F32, Attn::Dense, 1, &toks,
                        &[plen]).unwrap();
    // Step twice with the same input token at advancing offsets; the
    // logits must differ because the cache grew.
    let s1 = e.decode("main", Precision::F32, Attn::Dense, 1, 1, &[32],
                      &[plen - 1], out.caches).unwrap();
    let s2 = e.decode("main", Precision::F32, Attn::Dense, 1, 1, &[32],
                      &[plen], s1.caches).unwrap();
    assert_ne!(s1.logits, s2.logits);
}

#[test]
fn pallas_and_dense_artifacts_agree() {
    require_artifacts!();
    let e = engine();
    let p = e.manifest.prefill_p;
    let mut toks = vec![0i32; p];
    for (i, &b) in code_prompt().iter().enumerate() {
        toks[i] = b as i32;
    }
    let plen = code_prompt().len() as i32;
    // Fresh prefill per variant (decode donates its caches).
    let run = |attn: Attn| {
        let pre = e.prefill("main", Precision::F32, Attn::Dense, 1, &toks,
                            &[plen]).unwrap();
        let tokens = [32i32, 97, 98, 99, 100];
        e.decode("main", Precision::F32, attn, 1, 5, &tokens, &[plen - 1],
                 pre.caches).unwrap().logits
    };
    let dense = run(Attn::Dense);
    let pallas = run(Attn::Pallas);
    assert_eq!(dense.len(), pallas.len());
    for (a, b) in dense.iter().zip(&pallas) {
        assert!((a - b).abs() < 1e-3, "pallas/dense divergence: {a} vs {b}");
    }
}

#[test]
fn spec_generates_and_accepts_in_distribution() {
    require_artifacts!();
    let e = engine();
    let prompts = vec![code_prompt(); 2];
    let res = SpecEngine::new(&e, small_cfg()).generate(&prompts).unwrap();
    assert_eq!(res.seqs.len(), 2);
    for s in &res.seqs {
        assert!(s.tokens_generated() > 0);
        assert_ne!(s.finish, FinishReason::Running);
    }
    // In-distribution prompts must get a healthy acceptance rate — this is
    // the paper's core operating regime (~78-88%).
    assert!(res.metrics.acceptance_rate > 0.5,
            "acceptance {:.2} too low", res.metrics.acceptance_rate);
    assert!(res.metrics.tokens_per_step > 1.0);
    assert!(res.drafted >= res.accepted);
}

#[test]
fn spec_is_deterministic_for_fixed_seed() {
    require_artifacts!();
    let e = engine();
    let prompts = vec![code_prompt(); 2];
    let r1 = SpecEngine::new(&e, small_cfg()).generate(&prompts).unwrap();
    let r2 = SpecEngine::new(&e, small_cfg()).generate(&prompts).unwrap();
    for (a, b) in r1.seqs.iter().zip(&r2.seqs) {
        assert_eq!(a.generated, b.generated);
    }
    let r3 = SpecEngine::new(&e, SpecConfig { seed: 7, ..small_cfg() })
        .generate(&prompts).unwrap();
    // Different seed should (overwhelmingly) change at least one output.
    assert!(r1.seqs.iter().zip(&r3.seqs)
            .any(|(a, b)| a.generated != b.generated));
}

#[test]
fn pad_and_split_produce_identical_streams() {
    require_artifacts!();
    // PAD and SPLIT are different *executions* of the same math with the
    // same RNG streams: outputs must match exactly (Fig 4b ≡ 4c).
    let e = engine();
    let prompts = vec![code_prompt(); 2];
    let pad = SpecEngine::new(&e, small_cfg()).generate(&prompts).unwrap();
    let split = SpecEngine::new(&e, SpecConfig {
        mode: ExecMode::Split,
        ..small_cfg()
    }).generate(&prompts).unwrap();
    for (a, b) in pad.seqs.iter().zip(&split.seqs) {
        assert_eq!(a.generated, b.generated,
                   "PAD vs SPLIT divergence");
    }
}

#[test]
fn batch_padding_rows_do_not_affect_real_rows() {
    require_artifacts!();
    // 3 prompts ride in the B=4 bucket; results must equal the same
    // prompts in a B=4 batch position-for-position (independence across
    // the batch — the paper's §3 claim).
    let e = engine();
    let p = code_prompt();
    let r3 = SpecEngine::new(&e, small_cfg())
        .generate(&[p.clone(), p.clone(), p.clone()]).unwrap();
    let r4 = SpecEngine::new(&e, small_cfg())
        .generate(&[p.clone(), p.clone(), p.clone(), p.clone()]).unwrap();
    for i in 0..3 {
        assert_eq!(r3.seqs[i].generated, r4.seqs[i].generated);
    }
}

#[test]
fn int8_runs_and_roughly_tracks_f32() {
    require_artifacts!();
    let e = engine();
    let prompts = vec![code_prompt(); 2];
    let res = SpecEngine::new(&e, SpecConfig {
        precision: Precision::Int8,
        ..small_cfg()
    }).generate(&prompts).unwrap();
    assert!(res.seqs[0].tokens_generated() > 0);
    assert!(res.metrics.acceptance_rate > 0.3);
}

#[test]
fn fixed_draft_policy_uses_constant_length() {
    require_artifacts!();
    let e = engine();
    let res = SpecEngine::new(&e, SpecConfig {
        policy: Policy::Fixed(4),
        ..small_cfg()
    }).generate(&[code_prompt()]).unwrap();
    assert!(res.step_log.iter().all(|(k, _)| *k == 4));
}

#[test]
fn heuristic_draft_length_adapts() {
    require_artifacts!();
    let e = engine();
    let res = SpecEngine::new(&e, SpecConfig {
        max_new_tokens: 48,
        ..SpecConfig::default()
    }).generate(&[code_prompt()]).unwrap();
    let lens: Vec<usize> = res.step_log.iter().map(|(k, _)| *k).collect();
    assert!(!lens.is_empty());
    // Algorithm 1 must stay within the exported bucket range.
    assert!(lens.iter().all(|&k| (1..=16).contains(&k)));
}

#[test]
fn rd_baseline_generates() {
    require_artifacts!();
    let e = engine();
    let rd = RegularDecoder::new(&e, RdConfig {
        max_new_tokens: 12,
        ..RdConfig::default()
    });
    let res = rd.generate(&[code_prompt(), code_prompt()]).unwrap();
    assert_eq!(res.seqs.len(), 2);
    assert!(res.seqs[0].tokens_generated() > 0);
    assert!(res.metrics.ptl_mean > 0.0);
    assert!(res.metrics.ptl_first <= res.metrics.ptl_last);
}

#[test]
fn time_budget_stops_generation() {
    require_artifacts!();
    let e = engine();
    // Warm the executables so the budget measures steady state.
    let _ = SpecEngine::new(&e, small_cfg()).generate(&[code_prompt()]);
    let res = SpecEngine::new(&e, SpecConfig {
        max_new_tokens: 100_000,
        time_budget_secs: Some(0.25),
        temperature: 2.0, // keep it rambling (avoid instant EOS)
        ..SpecConfig::default()
    }).generate(&[code_prompt()]).unwrap();
    // The budget is checked at step granularity; the first run may also
    // lazily compile larger-K artifacts mid-loop, so allow generous slack —
    // the point is that generation stops long before 100k tokens would.
    assert!(res.metrics.wall_secs < 30.0,
            "budget ignored: ran {:.1}s", res.metrics.wall_secs);
    assert!(res.seqs[0].tokens_generated() < 10_000);
}

#[test]
fn capacity_limit_finishes_sequences() {
    require_artifacts!();
    let e = engine();
    let res = SpecEngine::new(&e, SpecConfig {
        max_new_tokens: 100_000,
        temperature: 3.0,
        top_p: 1.0,
        ..SpecConfig::default()
    }).generate(&[tokenizer::encode("article: ")]).unwrap();
    let s = &res.seqs[0];
    assert_ne!(s.finish, FinishReason::Running);
    // Either it rambled to capacity or found an EOS byte; both are valid,
    // but the state must still satisfy the invariants.
    s.check_invariants(e.manifest.model("main").unwrap().s_max as i32)
        .unwrap();
}

#[test]
fn eval_tasks_load_and_check() {
    require_artifacts!();
    let root = artifacts_root();
    let code = bass::eval::load_code_tasks(&root).unwrap();
    assert!(code.len() >= 32);
    assert!(code[0].prompt.contains("def "));
    let summ = bass::eval::load_summ_tasks(&root).unwrap();
    assert!(summ.len() >= 32);
    assert!(summ[0].prompt.contains("summary:"));
}

#[test]
fn calibration_returns_plausible_flops() {
    require_artifacts!();
    let e = engine();
    let peak = e.calibrate_peak_flops(3).unwrap();
    assert!(peak > 1e9, "peak {peak:.2e} implausibly low");
    assert!(peak < 1e13, "peak {peak:.2e} implausibly high");
}
