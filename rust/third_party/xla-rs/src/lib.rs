//! API-compatible **stub** of the crate-local patched `xla-rs` PJRT
//! binding (see README.md). Exposes exactly the surface
//! `bass::runtime::engine` consumes; every device entry point returns
//! [`Error::StubRuntime`]. `PjRtClient::cpu()` fails first, so callers
//! get one clear error instead of deep failures.

use std::fmt;

/// Error type matching the real binding's stringly-typed PJRT errors.
#[derive(Debug)]
pub enum Error {
    /// The stub was invoked where the real PJRT binding is required.
    StubRuntime,
    /// Generic wrapped error (file IO, parse, ...).
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::StubRuntime => write!(
                f,
                "xla stub: the patched PJRT binding is not vendored in \
                 this checkout (see rust/third_party/xla-rs/README.md)"
            ),
            Error::Msg(m) => write!(f, "xla stub: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the engine uploads (matches the real binding's names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S8,
    S32,
}

/// Host value types that can cross the host<->device boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i8 {}
impl NativeType for u8 {}

/// Parsed HLO module (text artifact).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::StubRuntime)
    }
}

/// A computation handed to `PjRtClient::compile`.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::StubRuntime)
    }
}

/// Host-side literal downloaded from a buffer.
pub struct Literal(());

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::StubRuntime)
    }
}

/// A device (placement argument of the upload calls).
pub struct PjRtDevice(());

/// Compiled executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with borrowed inputs; one `Vec<PjRtBuffer>` per device.
    pub fn execute_b(&self, _args: &[&PjRtBuffer])
                     -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::StubRuntime)
    }
}

/// PJRT client over one platform.
pub struct PjRtClient(());

impl PjRtClient {
    /// The stub fails here — the earliest, clearest choke point.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::StubRuntime)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(Error::StubRuntime)
    }

    pub fn buffer_from_host_raw_bytes(
        &self, _ty: ElementType, _bytes: &[u8], _dims: &[usize],
        _device: Option<&PjRtDevice>) -> Result<PjRtBuffer> {
        Err(Error::StubRuntime)
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self, _data: &[T], _dims: &[usize],
        _device: Option<&PjRtDevice>) -> Result<PjRtBuffer> {
        Err(Error::StubRuntime)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_at_client_construction() {
        let e = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(e.to_string().contains("stub"));
    }
}
