//! Byte-level tokenizer (vocab = 256, EOS = 0x00) — matching the corpus
//! and models trained by `python/compile/`.

/// Encode text to token bytes (latin-1 semantics: non-latin1 chars are
/// replaced by '?', matching the corpus generator's charset).
pub fn encode(text: &str) -> Vec<u8> {
    text.chars()
        .map(|c| if (c as u32) < 256 { c as u8 } else { b'?' })
        .collect()
}

/// Decode token bytes back to text (latin-1).
pub fn decode(tokens: &[u8]) -> String {
    tokens.iter().map(|&b| b as char).collect()
}

/// The end-of-sequence byte the corpus uses between samples.
pub const EOS: u8 = 0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "def add_7(x):\n    return x + 7\n";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn latin1_roundtrip() {
        let s = "café";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn non_latin1_replaced() {
        assert_eq!(decode(&encode("a☃b")), "a?b");
    }
}
