//! The open-loop runner: renders a [`Scenario`] into timed submissions
//! against a live [`Coordinator`] — directly (in-process mpsc) or over
//! the TCP line protocol — and collects per-request latency outcomes.
//!
//! Submission is open-loop: each request is fired at its scheduled
//! offset whether or not earlier ones have answered, so server-side
//! queueing shows up as measured latency. Requests are collected by a
//! bounded pool of polling workers (direct path) or correlated by their
//! echoed `"id"` tags (TCP path, one pipelined connection), so a slow
//! request never skews a fast one's end-to-end clock.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::{Coordinator, PrefixEcho, Reply, Response};
use crate::runtime::json::Json;

use super::arrival::Arrival;
use super::workload::{LoadRequest, Workload};

/// One load scenario: an arrival process driving a workload mix.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub seed: u64,
    pub n_requests: usize,
    pub arrival: Arrival,
    pub workload: Workload,
    /// End-to-end SLO for the goodput metric, milliseconds.
    pub slo_ms: f64,
}

impl Scenario {
    /// Render the concrete run: arrival offsets and sampled requests.
    /// The workload stream is decorrelated from the arrival stream by a
    /// seed twist so "same gap" never implies "same request shape".
    pub fn requests(&self) -> (Vec<f64>, Vec<LoadRequest>) {
        let offsets = self.arrival.schedule(self.n_requests, self.seed);
        let reqs = self
            .workload
            .sample(self.n_requests, self.seed ^ 0x9E37_79B9_7F4A_7C15);
        (offsets, reqs)
    }
}

/// What one request observed, client side plus server echoes.
#[derive(Debug, Clone)]
pub struct Outcome {
    /// The request was answered (not a transport/engine error).
    pub ok: bool,
    /// Server-measured time to first token, ms (`None`: no byte ever).
    pub ttft_ms: Option<f64>,
    /// Client-measured submission → final answer, ms.
    pub e2e_ms: f64,
    /// Time per output token after the first, ms.
    pub tpot_ms: Option<f64>,
    /// Server-reported admission wait, ms.
    pub queue_ms: f64,
    pub n_seqs_requested: usize,
    pub n_seqs_returned: usize,
    /// Generated tokens summed over the fan-out.
    pub n_tokens: usize,
    /// Every returned sequence ran to its own finish.
    pub all_finished: bool,
    /// Came back empty and unfinished: a time budget expired before
    /// the request produced anything (possibly while still queued).
    pub expired_unserved: bool,
    pub preempted: usize,
    pub rebuckets: u64,
    pub queue_depth: usize,
    /// Server-reported mean per-row draft length over this request's
    /// (sequence, step) observations — the adaptive controller's
    /// realized γ for this request's own traffic.
    pub draft_len_mean: f64,
    /// Server-reported accepted/proposed draft-token ratio of this
    /// request's sequences.
    pub acceptance_rate: f64,
    /// Engine-lifetime step FLOPs actually launched when this request
    /// finished — what the backend really dispatched (packed: the Σq_i
    /// stream; PAD/stub: the rectangle). 0 for never-admitted answers.
    pub launch_flops: f64,
    /// Same steps priced as rectangular PAD launches — the baseline the
    /// packed saving is measured against (`launch ≤ padded` always).
    pub padded_launch_flops: f64,
    /// Engine-lifetime prompt-prefix KV reuse counters when this
    /// request finished (monotone echo, same convention as
    /// `launch_flops`). Zeroed for error outcomes.
    pub prefix: PrefixEcho,
}

impl Outcome {
    fn error(e2e_ms: f64) -> Outcome {
        Outcome {
            ok: false,
            ttft_ms: None,
            e2e_ms,
            tpot_ms: None,
            queue_ms: 0.0,
            n_seqs_requested: 0,
            n_seqs_returned: 0,
            n_tokens: 0,
            all_finished: false,
            expired_unserved: false,
            preempted: 0,
            rebuckets: 0,
            queue_depth: 0,
            draft_len_mean: 0.0,
            acceptance_rate: 0.0,
            launch_flops: 0.0,
            padded_launch_flops: 0.0,
            prefix: PrefixEcho::default(),
        }
    }

    fn from_response(resp: &Response, e2e_ms: f64) -> Outcome {
        let n_tokens: usize = resp.seqs.iter().map(|s| s.n_tokens).sum();
        let ttft_ms = resp.ttft_secs.map(|s| s * 1e3);
        Outcome {
            ok: true,
            ttft_ms,
            e2e_ms,
            tpot_ms: tpot(ttft_ms, e2e_ms, n_tokens),
            queue_ms: resp.queue_secs * 1e3,
            n_seqs_requested: resp.n_requested,
            n_seqs_returned: resp.seqs.len(),
            n_tokens,
            all_finished: !resp.seqs.is_empty()
                && resp.seqs.iter().all(|s| s.finished),
            expired_unserved: n_tokens == 0
                && resp.seqs.iter().all(|s| !s.finished),
            preempted: resp.preempted,
            rebuckets: resp.rebuckets,
            queue_depth: resp.queue_depth,
            draft_len_mean: resp.draft_len_mean,
            acceptance_rate: resp.acceptance_rate,
            launch_flops: resp.launch_flops,
            padded_launch_flops: resp.padded_launch_flops,
            prefix: resp.prefix,
        }
    }
}

fn tpot(ttft_ms: Option<f64>, e2e_ms: f64, n_tokens: usize)
        -> Option<f64> {
    match ttft_ms {
        Some(t) if n_tokens >= 2 => {
            Some(((e2e_ms - t) / (n_tokens - 1) as f64).max(0.0))
        }
        _ => None,
    }
}

/// Sleep until `offset` seconds past `t0` (no-op when already late —
/// open loop means late submissions fire immediately, they never
/// stretch the schedule).
fn pace(t0: Instant, offset: f64) {
    let target = Duration::from_secs_f64(offset.max(0.0));
    if let Some(wait) = target.checked_sub(t0.elapsed()) {
        std::thread::sleep(wait);
    }
}

/// Drive the coordinator directly over its mpsc submission API.
/// Returns per-request outcomes (in request order) and the makespan,
/// seconds from first submission tick to last answer.
///
/// Collection runs on a **bounded worker pool**, not a thread per
/// request: the old shape spawned one OS thread per submission just to
/// block on its reply channel, so a 10k-request scenario meant 10k
/// threads — most asleep, all paying stack + scheduler cost, and the
/// harness hit thread limits long before the engine was the
/// bottleneck. Each pool worker owns the receivers of the requests it
/// accepted and *polls* them (`try_recv`, short idle sleep) rather
/// than blocking on one: replies are observed within a poll tick of
/// arriving regardless of completion order, so the e2e clock never
/// inflates behind a slow co-pending request. A worker with nothing
/// accepted does **not** poll — it blocks on the intake queue
/// (`recv_timeout`), so an idle pool costs no CPU. Submission stays on
/// the caller's thread — the open-loop pacing contract is untouched.
pub fn run_direct(coord: &Coordinator, sc: &Scenario)
                  -> (Vec<Outcome>, f64) {
    let (offsets, reqs) = sc.requests();
    let n = reqs.len();
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .clamp(2, 16)
        .min(n.max(1));
    let (work_tx, work_rx) =
        channel::<(usize, Instant, Receiver<Reply>)>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    let out: Arc<Mutex<Vec<Option<Outcome>>>> =
        Arc::new(Mutex::new(vec![None; n]));
    let pool: Vec<_> = (0..workers)
        .map(|_| {
            let work_rx = Arc::clone(&work_rx);
            let out = Arc::clone(&out);
            std::thread::spawn(move || collect_replies(&work_rx, &out))
        })
        .collect();

    let t0 = Instant::now();
    for (i, (offset, lr)) in offsets.iter().zip(&reqs).enumerate() {
        pace(t0, *offset);
        let submitted = Instant::now();
        let rx = coord.submit(lr.to_request(false));
        let _ = work_tx.send((i, submitted, rx));
    }
    drop(work_tx); // pool drains what's pending, then exits
    for h in pool {
        h.join().expect("collector worker panicked");
    }
    let makespan = t0.elapsed().as_secs_f64();
    let outcomes = Arc::try_unwrap(out)
        .expect("pool exited")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every request collected"))
        .collect();
    (outcomes, makespan)
}

/// One pool worker: accept submitted requests from the shared queue,
/// poll the accepted reply channels round-robin, record each outcome at
/// the moment its `Done` is observed. Workers with nothing accepted
/// park in a blocking intake recv rather than polling. Exits when the
/// submission side hung up and every accepted request has answered.
fn collect_replies(
    work_rx: &Mutex<Receiver<(usize, Instant, Receiver<Reply>)>>,
    out: &Mutex<Vec<Option<Outcome>>>,
) {
    use std::sync::mpsc::{RecvTimeoutError, TryRecvError};
    let mut mine: Vec<(usize, Instant, Receiver<Reply>)> = Vec::new();
    let mut open = true;
    while open || !mine.is_empty() {
        let mut progressed = false;
        if mine.is_empty() {
            // Idle worker: **block** on the shared intake queue. The
            // old shape spun on `try_recv` + a 100µs sleep even with
            // nothing accepted — ~10k wakeups/s per idle worker for the
            // length of the run. Holding the lock across the blocking
            // recv is safe precisely here: an idle worker has no reply
            // channels to poll, the blocked holder observes a new job
            // with zero latency, and busy siblings fall through their
            // `try_lock` intake below instead of queueing behind us.
            let rx = work_rx.lock().unwrap();
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(job) => {
                    mine.push(job);
                    progressed = true;
                }
                // Re-check the exit condition on a timeout tick (a
                // sibling may have drained the queue to disconnection).
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
        } else if let Ok(rx) = work_rx.try_lock() {
            // Busy worker: non-blocking intake, and only when no idle
            // sibling is already camped on the queue — never hold the
            // lock across a blocking recv while replies are pending
            // (std mpsc has no multi-channel select, so the pending
            // reply channels below can only be *polled*).
            loop {
                match rx.try_recv() {
                    Ok(job) => {
                        mine.push(job);
                        progressed = true;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        mine.retain_mut(|(idx, submitted, rx)| {
            let oc = loop {
                match rx.try_recv() {
                    // Direct collection discards step events (the
                    // harness submits stream=false; defensive anyway).
                    Ok(Reply::Step(_)) => continue,
                    Ok(Reply::Done(Ok(resp))) => {
                        break Some(Outcome::from_response(
                            &resp,
                            submitted.elapsed().as_secs_f64() * 1e3,
                        ))
                    }
                    Ok(Reply::Done(Err(_))) | Err(TryRecvError::Disconnected) => {
                        break Some(Outcome::error(
                            submitted.elapsed().as_secs_f64() * 1e3,
                        ))
                    }
                    Err(TryRecvError::Empty) => break None,
                }
            };
            match oc {
                Some(oc) => {
                    out.lock().unwrap()[*idx] = Some(oc);
                    progressed = true;
                    false
                }
                None => true,
            }
        });
        if !progressed && !mine.is_empty() {
            // Replies pending but nothing moved this cycle: idle
            // briefly instead of spinning. The tick bounds
            // reply-observation skew (and thus e2e inflation) to
            // ~0.1 ms. (An *empty* `mine` never reaches this sleep —
            // it parks in the blocking intake above.)
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

/// Drive the coordinator through the TCP server over **one pipelined
/// connection**: every request line carries an `"id"` tag and replies
/// are correlated by the echoed tag (the head-of-line-blocking bugfix
/// is load-bearing here — before it, one connection serialized the
/// whole open loop).
pub fn run_tcp(addr: &str, sc: &Scenario) -> Result<(Vec<Outcome>, f64)> {
    let (offsets, reqs) = sc.requests();
    let n = reqs.len();
    let mut wstream = TcpStream::connect(addr)?;
    let rstream = wstream.try_clone()?;
    let submits: Arc<Mutex<Vec<Option<Instant>>>> =
        Arc::new(Mutex::new(vec![None; n]));

    let reader_submits = Arc::clone(&submits);
    let reader = std::thread::spawn(move || -> Result<Vec<Outcome>> {
        let mut out: Vec<Option<Outcome>> = vec![None; n];
        let mut done = 0usize;
        let mut lines = BufReader::new(rstream).lines();
        while done < n {
            let line = lines
                .next()
                .ok_or_else(|| anyhow!("server closed the connection"))??;
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(&line)?;
            if j.opt("event").is_some() {
                continue; // streaming step line of some request
            }
            let idx = j.get("id")?.as_usize()?;
            if idx >= n {
                anyhow::bail!("reply for unknown id {idx}");
            }
            let submitted = reader_submits.lock().unwrap()[idx]
                .ok_or_else(|| anyhow!("reply before submission"))?;
            let e2e_ms = submitted.elapsed().as_secs_f64() * 1e3;
            let oc = if j.get("ok")? == &Json::Bool(true) {
                outcome_from_wire(&j, e2e_ms)?
            } else {
                Outcome::error(e2e_ms)
            };
            if out[idx].replace(oc).is_none() {
                done += 1;
            }
        }
        Ok(out.into_iter().flatten().collect())
    });

    let t0 = Instant::now();
    for (i, (offset, lr)) in offsets.iter().zip(&reqs).enumerate() {
        pace(t0, *offset);
        submits.lock().unwrap()[i] = Some(Instant::now());
        let line = lr.to_wire_json(i).to_string_pretty()
            .replace('\n', " ");
        wstream.write_all(line.as_bytes())?;
        wstream.write_all(b"\n")?;
    }
    wstream.flush()?;
    let outcomes = reader
        .join()
        .map_err(|_| anyhow!("reader thread panicked"))??;
    Ok((outcomes, t0.elapsed().as_secs_f64()))
}

/// Rebuild an [`Outcome`] from a server response line (the fields
/// `coordinator::server::response_json` emits).
fn outcome_from_wire(j: &Json, e2e_ms: f64) -> Result<Outcome> {
    let seqs = j.get("seqs")?.as_arr()?;
    let mut n_tokens = 0usize;
    let mut all_finished = !seqs.is_empty();
    let mut any_finished = false;
    for s in seqs {
        n_tokens += s.get("n_tokens")?.as_usize()?;
        let fin = s.get("finished")? == &Json::Bool(true);
        all_finished &= fin;
        any_finished |= fin;
    }
    let ttft_ms = match j.get("ttft_ms")? {
        Json::Null => None,
        v => Some(v.as_f64()?),
    };
    Ok(Outcome {
        ok: true,
        ttft_ms,
        e2e_ms,
        tpot_ms: tpot(ttft_ms, e2e_ms, n_tokens),
        queue_ms: j.get("queue_ms")?.as_f64()?,
        n_seqs_requested: j.get("n_requested")?.as_usize()?,
        n_seqs_returned: seqs.len(),
        n_tokens,
        all_finished,
        expired_unserved: n_tokens == 0 && !any_finished
            && !seqs.is_empty(),
        preempted: j.get("preempted")?.as_usize()?,
        rebuckets: j.get("rebuckets")?.as_usize()? as u64,
        queue_depth: j.get("queue_depth")?.as_usize()?,
        draft_len_mean: j.get("draft_len_mean")?.as_f64()?,
        acceptance_rate: j.get("acceptance_rate")?.as_f64()?,
        launch_flops: j.get("launch_flops")?.as_f64()?,
        padded_launch_flops: j.get("padded_launch_flops")?.as_f64()?,
        prefix: prefix_from_wire(j)?,
    })
}

/// Parse the response line's `prefix_cache` object back into the
/// counter echo. Tolerates its absence (all-zero) so the harness can
/// still drive a pre-ISSUE-10 server binary.
fn prefix_from_wire(j: &Json) -> Result<PrefixEcho> {
    let Some(pc) = j.opt("prefix_cache") else {
        return Ok(PrefixEcho::default());
    };
    let count = |k: &str| -> Result<u64> {
        Ok(pc.get(k)?.as_usize()? as u64)
    };
    Ok(PrefixEcho {
        lookups: count("lookups")?,
        hits: count("hits")?,
        misses: count("misses")?,
        evictions: count("evictions")?,
        row_copies: count("row_copies")?,
        saved_flops: pc.get("saved_flops")?.as_f64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_util::artifacts_root;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::CoordinatorConfig;
    use crate::spec::{ExecMode, Policy, SpecConfig};

    fn stub_coordinator(max_batch: usize) -> Coordinator {
        Coordinator::start(CoordinatorConfig::new(
            artifacts_root(),
            SpecConfig {
                mode: ExecMode::Stub,
                policy: Policy::Fixed(4),
                ..SpecConfig::default()
            },
            BatcherConfig {
                max_batch,
                window: Duration::from_millis(1),
            },
        ))
        .expect("stub coordinator")
    }

    /// The harness-determinism pin: on the stub backend with the gate
    /// mix (fan-out 1, no budget), `total_tokens` equals Σ max_new of
    /// the sampled requests **independent of scheduling order** — the
    /// invariant the CI perf gate diffs across runs.
    #[test]
    fn direct_open_loop_counters_match_the_sampled_workload() {
        let sc = Scenario {
            name: "unit-gate".into(),
            seed: 23,
            n_requests: 12,
            arrival: Arrival::Poisson { rate_rps: 2000.0 },
            workload: Workload::gate(),
            slo_ms: 1000.0,
        };
        let coord = stub_coordinator(4);
        let (outcomes, makespan) = run_direct(&coord, &sc);
        assert_eq!(outcomes.len(), 12);
        assert!(makespan > 0.0);
        let (_, reqs) = sc.requests();
        let want: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
        let got: usize = outcomes.iter().map(|o| o.n_tokens).sum();
        assert_eq!(got, want,
                   "stub gate runs must generate exactly Σ max_new");
        for o in &outcomes {
            assert!(o.ok);
            assert!(o.all_finished);
            assert_eq!(o.n_seqs_returned, 1);
            let ttft = o.ttft_ms.expect("every request emitted bytes");
            assert!(ttft >= 0.0 && ttft <= o.e2e_ms,
                    "ttft {ttft}ms outside e2e {}ms", o.e2e_ms);
            assert!(o.tpot_ms.is_some(), "max_new >= 8 implies a tpot");
        }
    }

    fn canned_response(n_tokens: usize) -> Response {
        Response {
            seqs: vec![crate::coordinator::GenSeq {
                text: "x".repeat(n_tokens),
                finished: true,
                mean_logp: 0.0,
                n_tokens,
            }],
            n_requested: 1,
            batch_secs: 0.01,
            batch_size: 1,
            queue_secs: 0.0,
            preempted: 0,
            queue_depth: 0,
            rebuckets: 0,
            launch_flops: 3.0e6,
            padded_launch_flops: 4.0e6,
            prefix: PrefixEcho {
                lookups: 3,
                hits: 2,
                misses: 1,
                evictions: 0,
                row_copies: 2,
                saved_flops: 1.5e5,
            },
            ttft_secs: Some(0.001),
            draft_len_mean: 4.0,
            acceptance_rate: 0.5,
        }
    }

    /// The idle/ordering pin for the pool collector: workers that have
    /// accepted nothing **block** on intake (the pre-fix shape
    /// busy-polled `try_recv` with a 100µs sleep), and replies resolved
    /// in any order land at their submitting request's own index —
    /// never shifted onto a neighbour's slot.
    #[test]
    fn idle_collectors_block_and_replies_land_at_their_own_index() {
        let (work_tx, work_rx) =
            channel::<(usize, Instant, Receiver<Reply>)>();
        let work_rx = Arc::new(Mutex::new(work_rx));
        let out: Arc<Mutex<Vec<Option<Outcome>>>> =
            Arc::new(Mutex::new(vec![None; 3]));
        let pool: Vec<_> = (0..2)
            .map(|_| {
                let work_rx = Arc::clone(&work_rx);
                let out = Arc::clone(&out);
                std::thread::spawn(move || collect_replies(&work_rx,
                                                           &out))
            })
            .collect();

        // Let the fully idle pool park on intake before any job
        // exists; it must consume nothing and record nothing.
        std::thread::sleep(Duration::from_millis(15));
        assert!(out.lock().unwrap().iter().all(Option::is_none));

        let mut replies = Vec::new();
        for i in 0..3 {
            let (tx, rx) = channel::<Reply>();
            replies.push(tx);
            work_tx.send((i, Instant::now(), rx)).unwrap();
        }
        // Resolve strictly out of submission order: 2 answers first
        // (after a stray step event), then 1, then 0's channel drops
        // without a Done (an engine-side failure).
        replies[2].send(Reply::Done(Ok(canned_response(7)))).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        replies[1]
            .send(Reply::Step(crate::coordinator::StepEvent {
                seq: 0,
                text_delta: String::new(),
                done: false,
            }))
            .unwrap();
        replies[1].send(Reply::Done(Ok(canned_response(2)))).unwrap();
        std::thread::sleep(Duration::from_millis(3));
        drop(replies); // request 0: disconnected, never answered
        drop(work_tx); // pool drains and exits
        for h in pool {
            h.join().expect("collector worker panicked");
        }
        let out = Arc::try_unwrap(out)
            .expect("pool exited")
            .into_inner()
            .unwrap();
        let o2 = out[2].as_ref().expect("request 2 collected");
        assert!(o2.ok);
        assert_eq!(o2.n_tokens, 7, "reply 2 must land at index 2");
        assert!((o2.launch_flops - 3.0e6).abs() < 1.0);
        assert!((o2.padded_launch_flops - 4.0e6).abs() < 1.0);
        assert_eq!(o2.prefix.hits + o2.prefix.misses, o2.prefix.lookups,
                   "the echoed prefix tally must stay internally consistent");
        assert_eq!(o2.prefix.row_copies, 2);
        let o1 = out[1].as_ref().expect("request 1 collected");
        assert!(o1.ok);
        assert_eq!(o1.n_tokens, 2, "reply 1 must land at index 1");
        let o0 = out[0].as_ref().expect("request 0 collected");
        assert!(!o0.ok, "a dropped reply channel is an error outcome");
    }

    #[test]
    fn tpot_needs_a_first_token_and_a_second() {
        assert_eq!(tpot(None, 50.0, 10), None);
        assert_eq!(tpot(Some(10.0), 50.0, 1), None);
        let t = tpot(Some(10.0), 50.0, 5).unwrap();
        assert!((t - 10.0).abs() < 1e-9, "(50-10)/(5-1) = 10, got {t}");
        // A clock-skew artifact (ttft past e2e) clamps to zero rather
        // than reporting negative time.
        assert_eq!(tpot(Some(60.0), 50.0, 5), Some(0.0));
    }
}
