//! The open-loop runner: renders a [`Scenario`] into timed submissions
//! against a live [`Coordinator`] — directly (in-process mpsc) or over
//! the TCP line protocol — and collects per-request latency outcomes.
//!
//! Submission is open-loop: each request is fired at its scheduled
//! offset whether or not earlier ones have answered, so server-side
//! queueing shows up as measured latency. Every request is collected on
//! its own thread (direct path) or correlated by its echoed `"id"` tag
//! (TCP path, one pipelined connection), so a slow request never skews
//! a fast one's end-to-end clock.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::{Coordinator, Response};
use crate::runtime::json::Json;

use super::arrival::Arrival;
use super::workload::{LoadRequest, Workload};

/// One load scenario: an arrival process driving a workload mix.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub seed: u64,
    pub n_requests: usize,
    pub arrival: Arrival,
    pub workload: Workload,
    /// End-to-end SLO for the goodput metric, milliseconds.
    pub slo_ms: f64,
}

impl Scenario {
    /// Render the concrete run: arrival offsets and sampled requests.
    /// The workload stream is decorrelated from the arrival stream by a
    /// seed twist so "same gap" never implies "same request shape".
    pub fn requests(&self) -> (Vec<f64>, Vec<LoadRequest>) {
        let offsets = self.arrival.schedule(self.n_requests, self.seed);
        let reqs = self
            .workload
            .sample(self.n_requests, self.seed ^ 0x9E37_79B9_7F4A_7C15);
        (offsets, reqs)
    }
}

/// What one request observed, client side plus server echoes.
#[derive(Debug, Clone)]
pub struct Outcome {
    /// The request was answered (not a transport/engine error).
    pub ok: bool,
    /// Server-measured time to first token, ms (`None`: no byte ever).
    pub ttft_ms: Option<f64>,
    /// Client-measured submission → final answer, ms.
    pub e2e_ms: f64,
    /// Time per output token after the first, ms.
    pub tpot_ms: Option<f64>,
    /// Server-reported admission wait, ms.
    pub queue_ms: f64,
    pub n_seqs_requested: usize,
    pub n_seqs_returned: usize,
    /// Generated tokens summed over the fan-out.
    pub n_tokens: usize,
    /// Every returned sequence ran to its own finish.
    pub all_finished: bool,
    /// Came back empty and unfinished: a time budget expired before
    /// the request produced anything (possibly while still queued).
    pub expired_unserved: bool,
    pub preempted: usize,
    pub rebuckets: u64,
    pub queue_depth: usize,
}

impl Outcome {
    fn error(e2e_ms: f64) -> Outcome {
        Outcome {
            ok: false,
            ttft_ms: None,
            e2e_ms,
            tpot_ms: None,
            queue_ms: 0.0,
            n_seqs_requested: 0,
            n_seqs_returned: 0,
            n_tokens: 0,
            all_finished: false,
            expired_unserved: false,
            preempted: 0,
            rebuckets: 0,
            queue_depth: 0,
        }
    }

    fn from_response(resp: &Response, e2e_ms: f64) -> Outcome {
        let n_tokens: usize = resp.seqs.iter().map(|s| s.n_tokens).sum();
        let ttft_ms = resp.ttft_secs.map(|s| s * 1e3);
        Outcome {
            ok: true,
            ttft_ms,
            e2e_ms,
            tpot_ms: tpot(ttft_ms, e2e_ms, n_tokens),
            queue_ms: resp.queue_secs * 1e3,
            n_seqs_requested: resp.n_requested,
            n_seqs_returned: resp.seqs.len(),
            n_tokens,
            all_finished: !resp.seqs.is_empty()
                && resp.seqs.iter().all(|s| s.finished),
            expired_unserved: n_tokens == 0
                && resp.seqs.iter().all(|s| !s.finished),
            preempted: resp.preempted,
            rebuckets: resp.rebuckets,
            queue_depth: resp.queue_depth,
        }
    }
}

fn tpot(ttft_ms: Option<f64>, e2e_ms: f64, n_tokens: usize)
        -> Option<f64> {
    match ttft_ms {
        Some(t) if n_tokens >= 2 => {
            Some(((e2e_ms - t) / (n_tokens - 1) as f64).max(0.0))
        }
        _ => None,
    }
}

/// Sleep until `offset` seconds past `t0` (no-op when already late —
/// open loop means late submissions fire immediately, they never
/// stretch the schedule).
fn pace(t0: Instant, offset: f64) {
    let target = Duration::from_secs_f64(offset.max(0.0));
    if let Some(wait) = target.checked_sub(t0.elapsed()) {
        std::thread::sleep(wait);
    }
}

/// Drive the coordinator directly over its mpsc submission API.
/// Returns per-request outcomes (in request order) and the makespan,
/// seconds from first submission tick to last answer.
pub fn run_direct(coord: &Coordinator, sc: &Scenario)
                  -> (Vec<Outcome>, f64) {
    let (offsets, reqs) = sc.requests();
    let t0 = Instant::now();
    let mut collectors = Vec::with_capacity(reqs.len());
    for (offset, lr) in offsets.iter().zip(&reqs) {
        pace(t0, *offset);
        let submitted = Instant::now();
        let rx = coord.submit(lr.to_request(false));
        collectors.push(std::thread::spawn(move || {
            match Coordinator::wait(rx) {
                Ok(resp) => Outcome::from_response(
                    &resp, submitted.elapsed().as_secs_f64() * 1e3),
                Err(_) => Outcome::error(
                    submitted.elapsed().as_secs_f64() * 1e3),
            }
        }));
    }
    let outcomes: Vec<Outcome> = collectors
        .into_iter()
        .map(|h| h.join().expect("collector thread panicked"))
        .collect();
    (outcomes, t0.elapsed().as_secs_f64())
}

/// Drive the coordinator through the TCP server over **one pipelined
/// connection**: every request line carries an `"id"` tag and replies
/// are correlated by the echoed tag (the head-of-line-blocking bugfix
/// is load-bearing here — before it, one connection serialized the
/// whole open loop).
pub fn run_tcp(addr: &str, sc: &Scenario) -> Result<(Vec<Outcome>, f64)> {
    let (offsets, reqs) = sc.requests();
    let n = reqs.len();
    let mut wstream = TcpStream::connect(addr)?;
    let rstream = wstream.try_clone()?;
    let submits: Arc<Mutex<Vec<Option<Instant>>>> =
        Arc::new(Mutex::new(vec![None; n]));

    let reader_submits = Arc::clone(&submits);
    let reader = std::thread::spawn(move || -> Result<Vec<Outcome>> {
        let mut out: Vec<Option<Outcome>> = vec![None; n];
        let mut done = 0usize;
        let mut lines = BufReader::new(rstream).lines();
        while done < n {
            let line = lines
                .next()
                .ok_or_else(|| anyhow!("server closed the connection"))??;
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(&line)?;
            if j.opt("event").is_some() {
                continue; // streaming step line of some request
            }
            let idx = j.get("id")?.as_usize()?;
            if idx >= n {
                anyhow::bail!("reply for unknown id {idx}");
            }
            let submitted = reader_submits.lock().unwrap()[idx]
                .ok_or_else(|| anyhow!("reply before submission"))?;
            let e2e_ms = submitted.elapsed().as_secs_f64() * 1e3;
            let oc = if j.get("ok")? == &Json::Bool(true) {
                outcome_from_wire(&j, e2e_ms)?
            } else {
                Outcome::error(e2e_ms)
            };
            if out[idx].replace(oc).is_none() {
                done += 1;
            }
        }
        Ok(out.into_iter().flatten().collect())
    });

    let t0 = Instant::now();
    for (i, (offset, lr)) in offsets.iter().zip(&reqs).enumerate() {
        pace(t0, *offset);
        submits.lock().unwrap()[i] = Some(Instant::now());
        let line = lr.to_wire_json(i).to_string_pretty()
            .replace('\n', " ");
        wstream.write_all(line.as_bytes())?;
        wstream.write_all(b"\n")?;
    }
    wstream.flush()?;
    let outcomes = reader
        .join()
        .map_err(|_| anyhow!("reader thread panicked"))??;
    Ok((outcomes, t0.elapsed().as_secs_f64()))
}

/// Rebuild an [`Outcome`] from a server response line (the fields
/// `coordinator::server::response_json` emits).
fn outcome_from_wire(j: &Json, e2e_ms: f64) -> Result<Outcome> {
    let seqs = j.get("seqs")?.as_arr()?;
    let mut n_tokens = 0usize;
    let mut all_finished = !seqs.is_empty();
    let mut any_finished = false;
    for s in seqs {
        n_tokens += s.get("n_tokens")?.as_usize()?;
        let fin = s.get("finished")? == &Json::Bool(true);
        all_finished &= fin;
        any_finished |= fin;
    }
    let ttft_ms = match j.get("ttft_ms")? {
        Json::Null => None,
        v => Some(v.as_f64()?),
    };
    Ok(Outcome {
        ok: true,
        ttft_ms,
        e2e_ms,
        tpot_ms: tpot(ttft_ms, e2e_ms, n_tokens),
        queue_ms: j.get("queue_ms")?.as_f64()?,
        n_seqs_requested: j.get("n_requested")?.as_usize()?,
        n_seqs_returned: seqs.len(),
        n_tokens,
        all_finished,
        expired_unserved: n_tokens == 0 && !any_finished
            && !seqs.is_empty(),
        preempted: j.get("preempted")?.as_usize()?,
        rebuckets: j.get("rebuckets")?.as_usize()? as u64,
        queue_depth: j.get("queue_depth")?.as_usize()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_util::artifacts_root;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::CoordinatorConfig;
    use crate::spec::{ExecMode, Policy, SpecConfig};

    fn stub_coordinator(max_batch: usize) -> Coordinator {
        Coordinator::start(CoordinatorConfig::new(
            artifacts_root(),
            SpecConfig {
                mode: ExecMode::Stub,
                policy: Policy::Fixed(4),
                ..SpecConfig::default()
            },
            BatcherConfig {
                max_batch,
                window: Duration::from_millis(1),
            },
        ))
        .expect("stub coordinator")
    }

    /// The harness-determinism pin: on the stub backend with the gate
    /// mix (fan-out 1, no budget), `total_tokens` equals Σ max_new of
    /// the sampled requests **independent of scheduling order** — the
    /// invariant the CI perf gate diffs across runs.
    #[test]
    fn direct_open_loop_counters_match_the_sampled_workload() {
        let sc = Scenario {
            name: "unit-gate".into(),
            seed: 23,
            n_requests: 12,
            arrival: Arrival::Poisson { rate_rps: 2000.0 },
            workload: Workload::gate(),
            slo_ms: 1000.0,
        };
        let coord = stub_coordinator(4);
        let (outcomes, makespan) = run_direct(&coord, &sc);
        assert_eq!(outcomes.len(), 12);
        assert!(makespan > 0.0);
        let (_, reqs) = sc.requests();
        let want: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
        let got: usize = outcomes.iter().map(|o| o.n_tokens).sum();
        assert_eq!(got, want,
                   "stub gate runs must generate exactly Σ max_new");
        for o in &outcomes {
            assert!(o.ok);
            assert!(o.all_finished);
            assert_eq!(o.n_seqs_returned, 1);
            let ttft = o.ttft_ms.expect("every request emitted bytes");
            assert!(ttft >= 0.0 && ttft <= o.e2e_ms,
                    "ttft {ttft}ms outside e2e {}ms", o.e2e_ms);
            assert!(o.tpot_ms.is_some(), "max_new >= 8 implies a tpot");
        }
    }

    #[test]
    fn tpot_needs_a_first_token_and_a_second() {
        assert_eq!(tpot(None, 50.0, 10), None);
        assert_eq!(tpot(Some(10.0), 50.0, 1), None);
        let t = tpot(Some(10.0), 50.0, 5).unwrap();
        assert!((t - 10.0).abs() < 1e-9, "(50-10)/(5-1) = 10, got {t}");
        // A clock-skew artifact (ttft past e2e) clamps to zero rather
        // than reporting negative time.
        assert_eq!(tpot(Some(60.0), 50.0, 5), Some(0.0));
    }
}
