//! Seeded request-mix sampling for the serving load harness.
//!
//! A [`Workload`] describes the request population — prompt lengths,
//! fan-outs, priorities, deadlines, generation budgets — and `sample`
//! renders `n` concrete requests from it deterministically, so a
//! scenario seed pins the exact byte-for-byte request stream.

use crate::coordinator::Request;
use crate::runtime::json::Json;
use crate::sampling::Pcg32;

/// RNG stream id for workload sampling (distinct from the arrival
/// process's stream; see `arrival::ARRIVAL_STREAM`).
const WORKLOAD_STREAM: u64 = 0xB10C;

/// The request-mix distribution. Ranges are inclusive; `Vec` fields are
/// uniform choice sets.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    /// Prompt length range, bytes (the tokenizer is byte-level).
    pub prompt_len: (usize, usize),
    /// Per-request `max_new_tokens` range.
    pub max_new: (usize, usize),
    /// Fan-out choices (`Request::n_seqs`).
    pub fanout: Vec<usize>,
    /// Priority choices (wire `"priority"`).
    pub priorities: Vec<i32>,
    /// Deadline choices (wire `"deadline_ms"`; `None` = undeadlined).
    pub deadlines_ms: Vec<Option<u64>>,
    /// Shared system-prompt population (`None` = every prompt fully
    /// random, the pre-prefix-cache stream byte for byte).
    pub prefix_pool: Option<PrefixPool>,
}

/// A seeded shared-prefix population: `n_prompts` fixed "system
/// prompts" (drawn once per `sample` call from the same seeded stream)
/// that a sampled request reuses with probability
/// `reuse_permille`/1000. A reusing request keeps its sampled length —
/// the pool prompt overwrites the leading `min(prefix_len, len)` bytes
/// — so the length distribution is untouched and repeat-prefix traffic
/// becomes common, which is what exercises the coordinator's
/// prompt-prefix KV cache. Permille (not a float) keeps the reuse coin
/// integer-exact and the scenario JSON round-trippable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixPool {
    /// Number of distinct shared system prompts.
    pub n_prompts: usize,
    /// Bytes of each pool prompt (clamped to the sampled prompt length;
    /// sized to span several cache key blocks).
    pub prefix_len: usize,
    /// Reuse probability in permille (0..=1000).
    pub reuse_permille: u32,
}

/// One concrete sampled request, ready to submit.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadRequest {
    pub prompt: Vec<u8>,
    pub n_seqs: usize,
    pub max_new_tokens: usize,
    pub priority: i32,
    pub deadline_ms: Option<u64>,
}

impl Workload {
    /// The CI-gate mix: fan-out pinned to 1 and every request run to
    /// completion, which makes `total_tokens = Σ max_new` exact and
    /// **timing-independent** — admission order may vary run to run,
    /// but each request always generates exactly its budget on the
    /// stub backend. The deterministic-counters contract of
    /// `BENCH_serving.json` rests on this mix.
    pub fn gate() -> Workload {
        Workload {
            prompt_len: (16, 96),
            max_new: (8, 48),
            fanout: vec![1],
            priorities: vec![-1, 0, 0, 0, 5],
            deadlines_ms: vec![None, Some(50), Some(250)],
            // No shared prefixes: the gate stream predates the prefix
            // cache and must stay byte-identical (prefix_pool = None
            // draws nothing from the RNG, so the stream is untouched).
            prefix_pool: None,
        }
    }

    /// The serving mix: mixed fan-outs, priorities and deadlines —
    /// the paper-style heterogeneous open-loop population. Fan-out > 1
    /// makes `n_seqs_returned` admission-timing dependent (the engine
    /// clamps fan-out to free slots), so this mix reports its counters
    /// as observed, not as a determinism gate.
    pub fn mixed() -> Workload {
        Workload {
            prompt_len: (16, 192),
            max_new: (8, 64),
            fanout: vec![1, 1, 1, 2, 2, 4],
            priorities: vec![-1, 0, 0, 0, 0, 3, 5],
            deadlines_ms: vec![None, None, Some(50), Some(150), Some(400)],
            // Realistic serving traffic repeats system prompts: four
            // shared prefixes, reused by ~60% of requests, each long
            // enough (48 bytes = 3 cache key blocks) that the prefix
            // cache and fan-out sharing actually fire.
            prefix_pool: Some(PrefixPool {
                n_prompts: 4,
                prefix_len: 48,
                reuse_permille: 600,
            }),
        }
    }

    /// Render `n` concrete requests. Same `(workload, n, seed)` —
    /// same requests, byte for byte. With no `prefix_pool` the RNG
    /// draw sequence is exactly the pre-pool one, so legacy mixes
    /// (the gate) replay their historical streams unchanged.
    pub fn sample(&self, n: usize, seed: u64) -> Vec<LoadRequest> {
        let mut rng = Pcg32::new(seed, WORKLOAD_STREAM);
        // Shared system prompts come off the same seeded stream, ahead
        // of the per-request draws, so the whole population is pinned
        // by (workload, seed) alone.
        let pool: Vec<Vec<u8>> = match &self.prefix_pool {
            Some(pp) => (0..pp.n_prompts)
                .map(|_| (0..pp.prefix_len)
                    .map(|_| b'a' + (rng.next_u32() % 26) as u8)
                    .collect())
                .collect(),
            None => Vec::new(),
        };
        (0..n)
            .map(|_| {
                let len = range(&mut rng, self.prompt_len);
                let mut prompt: Vec<u8> = (0..len)
                    .map(|_| b'a' + (rng.next_u32() % 26) as u8)
                    .collect();
                if let Some(pp) = &self.prefix_pool {
                    // Reuse coin, then pool pick. Overwriting (never
                    // prepending) the leading bytes keeps the sampled
                    // length — the prompt_len distribution is the same
                    // with and without the pool.
                    if !pool.is_empty()
                        && rng.next_u32() % 1000 < pp.reuse_permille
                    {
                        let sys = pick(&mut rng, &pool);
                        let k = pp.prefix_len.min(prompt.len());
                        prompt[..k].copy_from_slice(&sys[..k]);
                    }
                }
                LoadRequest {
                    prompt,
                    max_new_tokens: range(&mut rng, self.max_new),
                    n_seqs: *pick(&mut rng, &self.fanout),
                    priority: *pick(&mut rng, &self.priorities),
                    deadline_ms: *pick(&mut rng, &self.deadlines_ms),
                }
            })
            .collect()
    }

    /// Scenario-config JSON (embedded in `BENCH_serving.json`).
    /// `prefix_pool` is emitted only when set — schema-additive, so
    /// pool-free reports are byte-identical to pre-pool ones.
    pub fn to_json(&self) -> Json {
        let pair = |(lo, hi): (usize, usize)| {
            Json::Arr(vec![lo.into(), hi.into()])
        };
        let mut pairs = vec![
            ("prompt_len", pair(self.prompt_len)),
            ("max_new", pair(self.max_new)),
            ("fanout",
             Json::Arr(self.fanout.iter().map(|&f| f.into()).collect())),
            ("priorities",
             Json::Arr(self.priorities.iter()
                 .map(|&p| (p as f64).into()).collect())),
            ("deadlines_ms",
             Json::Arr(self.deadlines_ms.iter()
                 .map(|d| match d {
                     Some(ms) => (*ms as usize).into(),
                     None => Json::Null,
                 })
                 .collect())),
        ];
        if let Some(pp) = &self.prefix_pool {
            pairs.push(("prefix_pool", Json::obj(vec![
                ("n_prompts", pp.n_prompts.into()),
                ("prefix_len", pp.prefix_len.into()),
                ("reuse_permille", (pp.reuse_permille as usize).into()),
            ])));
        }
        Json::obj(pairs)
    }
}

impl LoadRequest {
    /// The coordinator-level request this sample denotes.
    pub fn to_request(&self, stream: bool) -> Request {
        Request {
            prompt: self.prompt.clone(),
            n_seqs: self.n_seqs,
            max_new_tokens: Some(self.max_new_tokens),
            temperature: None,
            top_p: None,
            seed: None,
            priority: Some(self.priority),
            deadline_ms: self.deadline_ms,
            stream,
        }
    }

    /// The wire-protocol request line (tagged with `"id"` so replies
    /// can pipeline on one connection; see `coordinator::server`).
    pub fn to_wire_json(&self, id: usize) -> Json {
        let mut pairs = vec![
            ("id", id.into()),
            ("prompt",
             String::from_utf8(self.prompt.clone())
                 .expect("sampled prompts are ASCII")
                 .into()),
            ("n", self.n_seqs.into()),
            ("max_new_tokens", self.max_new_tokens.into()),
            ("priority", (self.priority as f64).into()),
        ];
        if let Some(ms) = self.deadline_ms {
            pairs.push(("deadline_ms", (ms as usize).into()));
        }
        Json::obj(pairs)
    }
}

fn range(rng: &mut Pcg32, (lo, hi): (usize, usize)) -> usize {
    debug_assert!(lo <= hi);
    lo + (rng.next_u32() as usize) % (hi - lo + 1)
}

fn pick<'a, T>(rng: &mut Pcg32, xs: &'a [T]) -> &'a T {
    &xs[(rng.next_u32() as usize) % xs.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_seed_deterministic() {
        let w = Workload::mixed();
        let a = w.sample(40, 9);
        let b = w.sample(40, 9);
        assert_eq!(a, b, "same seed must replay the identical stream");
        let c = w.sample(40, 10);
        assert_ne!(a, c, "a different seed must change the stream");
    }

    #[test]
    fn samples_respect_the_distribution() {
        let w = Workload::mixed();
        for lr in w.sample(200, 4) {
            assert!(lr.prompt.len() >= w.prompt_len.0
                    && lr.prompt.len() <= w.prompt_len.1);
            assert!(lr.prompt.iter().all(u8::is_ascii_lowercase),
                    "prompts must stay JSON-safe ASCII");
            assert!(lr.max_new_tokens >= w.max_new.0
                    && lr.max_new_tokens <= w.max_new.1);
            assert!(w.fanout.contains(&lr.n_seqs));
            assert!(w.priorities.contains(&lr.priority));
            assert!(w.deadlines_ms.contains(&lr.deadline_ms));
        }
    }

    #[test]
    fn gate_mix_pins_fanout_to_one() {
        // The deterministic-counters contract: every gate request is a
        // single sequence run to completion, so total_tokens is exactly
        // Σ max_new regardless of scheduling order.
        assert!(Workload::gate().sample(64, 1).iter()
                .all(|lr| lr.n_seqs == 1));
        // And no prefix pool: the gate's historical byte stream (and
        // its no-KV-reuse counters) must survive the pool feature.
        assert!(Workload::gate().prefix_pool.is_none());
    }

    #[test]
    fn prefix_pool_shares_whole_prefixes() {
        let w = Workload::mixed();
        let pp = w.prefix_pool.expect("mixed carries a pool");
        let reqs = w.sample(300, 6);
        // Group by the leading pool-length (clamped) prefix; reused
        // prompts collapse onto n_prompts groups, so with ~60% reuse
        // the most popular prefixes must repeat many times.
        let mut counts: std::collections::HashMap<&[u8], usize> =
            std::collections::HashMap::new();
        for lr in &reqs {
            let k = pp.prefix_len.min(lr.prompt.len());
            *counts.entry(&lr.prompt[..k]).or_default() += 1;
        }
        let repeated: usize = counts.values()
            .filter(|&&c| c > 1).sum();
        assert!(repeated >= reqs.len() / 4,
                "shared prefixes too rare: {repeated}/{}", reqs.len());
        // Overlay preserves the sampled-distribution invariants.
        for lr in &reqs {
            assert!(lr.prompt.len() >= w.prompt_len.0
                    && lr.prompt.len() <= w.prompt_len.1);
            assert!(lr.prompt.iter().all(u8::is_ascii_lowercase));
        }
        // And it is seed-deterministic like everything else here.
        assert_eq!(reqs, w.sample(300, 6));
    }

    #[test]
    fn wire_line_carries_the_id_tag() {
        let lr = &Workload::gate().sample(1, 2)[0];
        let j = lr.to_wire_json(17);
        assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 17);
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), lr.n_seqs);
        assert_eq!(j.get("prompt").unwrap().as_str().unwrap().len(),
                   lr.prompt.len());
        // One line on the wire: the compact form must hold no newlines
        // once flattened the way the server writes lines.
        assert!(!j.to_string_pretty().replace('\n', " ").contains('\n'));
    }
}
