//! Open-loop arrival processes for the serving load harness.
//!
//! Open-loop means arrival times are fixed **before** the run: a slow
//! server does not slow the generator down, so queueing delay shows up
//! in the measurements instead of being hidden by client back-pressure
//! (the closed-loop fallacy). All processes are seeded [`Pcg32`] draws —
//! the same `(process, n, seed)` triple always produces the identical
//! schedule, which is what lets the CI gate re-run a scenario and diff
//! its counters bit-for-bit.

use crate::sampling::Pcg32;

/// RNG stream id for arrival schedules (distinct from the workload
/// sampler's so the same scenario seed drives both independently).
const ARRIVAL_STREAM: u64 = 0xA221;

/// An open-loop arrival process. `schedule` renders it into concrete
/// request offsets (seconds from the run's t0), sorted non-decreasing.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Homogeneous Poisson process: exponential inter-arrival gaps at
    /// `rate_rps` requests/second — the standard serving-benchmark
    /// arrival model.
    Poisson { rate_rps: f64 },
    /// Bursty traffic: piecewise-exponential gaps whose rate alternates
    /// between `burst_rps` (for the first `duty` fraction of every
    /// `period_secs` window) and `base_rps` (the rest). An
    /// approximation of a modulated Poisson process — each gap is drawn
    /// at the rate in force when it starts — which is enough to slam
    /// the scheduler with admission bursts and let it drain between
    /// them.
    Bursty {
        base_rps: f64,
        burst_rps: f64,
        period_secs: f64,
        duty: f64,
    },
    /// Trace replay: explicit offsets (seconds from t0). Asking for
    /// more requests than the trace holds replays it cyclically, each
    /// pass shifted by the trace's span plus one mean gap.
    Trace { offsets_secs: Vec<f64> },
}

impl Arrival {
    /// Render the first `n` arrival offsets of this process.
    pub fn schedule(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg32::new(seed, ARRIVAL_STREAM);
        match self {
            Arrival::Poisson { rate_rps } => {
                let mut t = 0.0f64;
                (0..n)
                    .map(|_| {
                        t += exp_gap(&mut rng, *rate_rps);
                        t
                    })
                    .collect()
            }
            Arrival::Bursty { base_rps, burst_rps, period_secs, duty } => {
                let period = period_secs.max(1e-6);
                let mut t = 0.0f64;
                (0..n)
                    .map(|_| {
                        let phase = (t / period).fract();
                        let rate = if phase < duty.clamp(0.0, 1.0) {
                            *burst_rps
                        } else {
                            *base_rps
                        };
                        t += exp_gap(&mut rng, rate);
                        t
                    })
                    .collect()
            }
            Arrival::Trace { offsets_secs } => {
                let mut offs = offsets_secs.clone();
                offs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                if offs.is_empty() {
                    return vec![0.0; n];
                }
                let last = *offs.last().unwrap();
                let span = last + last / offs.len() as f64;
                (0..n)
                    .map(|i| {
                        offs[i % offs.len()]
                            + span * (i / offs.len()) as f64
                    })
                    .collect()
            }
        }
    }

    /// Scenario-config JSON (embedded in `BENCH_serving.json` so a
    /// report names the process that produced it).
    pub fn to_json(&self) -> crate::runtime::json::Json {
        use crate::runtime::json::Json;
        match self {
            Arrival::Poisson { rate_rps } => Json::obj(vec![
                ("kind", "poisson".into()),
                ("rate_rps", (*rate_rps).into()),
            ]),
            Arrival::Bursty { base_rps, burst_rps, period_secs, duty } => {
                Json::obj(vec![
                    ("kind", "bursty".into()),
                    ("base_rps", (*base_rps).into()),
                    ("burst_rps", (*burst_rps).into()),
                    ("period_secs", (*period_secs).into()),
                    ("duty", (*duty).into()),
                ])
            }
            Arrival::Trace { offsets_secs } => Json::obj(vec![
                ("kind", "trace".into()),
                ("n_offsets", offsets_secs.len().into()),
            ]),
        }
    }
}

/// One exponential inter-arrival gap by inverse CDF. `next_f32` is in
/// [0, 1), so `1 - u` is in (0, 1] and the log never sees zero.
fn exp_gap(rng: &mut Pcg32, rate_rps: f64) -> f64 {
    let u = rng.next_f32() as f64;
    -(1.0 - u).ln() / rate_rps.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_schedule_is_seed_deterministic() {
        let a = Arrival::Poisson { rate_rps: 100.0 };
        let s1 = a.schedule(64, 7);
        let s2 = a.schedule(64, 7);
        assert_eq!(s1, s2, "same seed must replay bit-identically");
        let s3 = a.schedule(64, 8);
        assert_ne!(s1, s3, "a different seed must move the arrivals");
        assert!(s1.windows(2).all(|w| w[0] <= w[1]), "sorted offsets");
        assert!(s1.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn poisson_mean_gap_tracks_the_rate() {
        let a = Arrival::Poisson { rate_rps: 200.0 };
        let s = a.schedule(4000, 3);
        let mean_gap = s.last().unwrap() / s.len() as f64;
        // Exponential(200) has mean 5ms; 4000 samples put the empirical
        // mean within a few percent.
        assert!((mean_gap - 0.005).abs() < 0.0005,
                "mean gap {mean_gap} is far from 1/rate");
    }

    #[test]
    fn bursty_concentrates_arrivals_in_the_burst_window() {
        let a = Arrival::Bursty {
            base_rps: 20.0,
            burst_rps: 400.0,
            period_secs: 1.0,
            duty: 0.2,
        };
        let s = a.schedule(600, 11);
        let in_burst = s.iter().filter(|t| t.fract() < 0.2).count();
        // 20% of the time carries the large majority of arrivals.
        assert!(in_burst * 2 > s.len(),
                "only {in_burst}/{} arrivals in the burst window",
                s.len());
    }

    #[test]
    fn trace_replays_cyclically_and_stays_sorted() {
        let a = Arrival::Trace { offsets_secs: vec![0.3, 0.1, 0.2] };
        let s = a.schedule(7, 0);
        assert_eq!(s.len(), 7);
        assert!((s[0] - 0.1).abs() < 1e-12);
        assert!((s[2] - 0.3).abs() < 1e-12);
        // Second pass: shifted by span = 0.3 + 0.3/3 = 0.4.
        assert!((s[3] - 0.5).abs() < 1e-12);
        assert!(s.windows(2).all(|w| w[0] <= w[1]));
    }
}
