//! `BENCH_serving.json` — the schema-stable serving benchmark record.
//!
//! Schema `bass-serving-bench/v2` (v1 + the `draft` section: per-request
//! mean draft lengths and acceptance rates, reported since the engine
//! runs one adaptive draft-length controller per sequence):
//!
//! ```text
//! {
//!   "schema": "bass-serving-bench/v2",
//!   "generated_by": <tool/provenance string>,
//!   "driver": "direct" | "tcp",
//!   "mode": "stub" | "pad" | "split" | "packed",
//!   "scenarios": [{
//!     "name", "seed", "n_requests",
//!     "arrival":  {"kind", ...process params},
//!     "workload": {"prompt_len", "max_new", "fanout", "priorities",
//!                  "deadlines_ms"},
//!     "slo_ms",
//!     "latency":  {"ttft_ms" | "tpot_ms" | "e2e_ms" | "queue_ms":
//!                  {"mean", "p50", "p99"}},
//!     "goodput":  {"slo_ms", "served", "within_slo", "goodput_rps",
//!                  "offered_rps"},
//!     "overhead": {"preemptions", "rebuckets", "max_queue_depth",
//!                  "expired_unserved", "errors"},
//!     "draft":    {"draft_len": {"mean", "p50", "p99"},
//!                  "acceptance_rate": {"mean", "p50", "p99"}},
//!     "flops":    {"launch", "padded_launch"},
//!     "prefix_cache": {"lookups", "hits", "misses", "evictions",
//!                      "row_copies", "saved_flops"},
//!     "counters": {"n_requests", "n_seqs_requested", "total_tokens",
//!                  "all_finished"},
//!     "observability": {...}   // additive; only with --trace-out
//!   }, ...]
//! }
//! ```
//!
//! Distribution stats (`mean`/`p50`/`p99`) over an **empty** sample set
//! — e.g. `ttft_ms` when every request expired unserved — are emitted
//! as `null`, never a fake `0.0` and never a bare `NaN` (which the
//! hand-rolled writer would emit unquoted). The optional
//! `observability` section ([`attach_observability`]) carries the span
//! summary, the trace-file pointer and the live-registry snapshot for
//! runs traced with `--trace-out`; it is advisory and excluded from
//! the deterministic-counters contract.
//!
//! `flops` reports the scenario's engine-lifetime step-FLOP totals:
//! `launch` is what the backend actually dispatched, `padded_launch`
//! the rectangular-PAD price of the same steps — the gap is the
//! packed backend's zero-pad saving. Responses echo a monotone
//! engine-lifetime counter, so the scenario total is the max across
//! outcomes (same convention as `overhead.rebuckets`). The section is
//! additive to v2 and the baseline diff treats it as optional.
//!
//! `prefix_cache` reports the scenario's prompt-prefix KV reuse
//! (ISSUE 10): cache lookups/hits/misses/evictions, the KV row copies
//! executed (cache hits **and** fan-out sibling shares), and the
//! prefill FLOPs that reuse avoided. Like `flops`, each response
//! echoes monotone engine-lifetime counters and the scenario value is
//! the max across outcomes — every counter is non-decreasing in time,
//! so each max is attained at the chronologically last snapshot and
//! `hits + misses == lookups` survives the aggregation (the diff
//! script hard-checks it). Additive to v2; optional in the diff.
//!
//! `draft` distributions are **across requests** (each sample is one
//! request's server-reported `draft_len_mean` / `acceptance_rate`, over
//! requests that actually ran a speculative step), so a single
//! long-running request cannot drown out the tail the way a
//! per-step-weighted aggregate would.
//!
//! The split matters: `latency`/`goodput`/`overhead` are wall-clock
//! observations (machine- and load-dependent — the CI gate treats them
//! as advisory), while `counters` is the **deterministic** subset: under
//! the gate workload (fan-out 1, no budget) on the stub backend these
//! are functions of the scenario seed alone, so the CI job re-runs the
//! scenario and diffs them bit-for-bit.

use crate::metrics::Summary;
use crate::runtime::json::Json;

use super::run::{Outcome, Scenario};

pub const SCHEMA: &str = "bass-serving-bench/v2";

/// Aggregate one scenario's outcomes into its report entry.
pub fn scenario_report(sc: &Scenario, outcomes: &[Outcome],
                       makespan_secs: f64) -> Json {
    let dist = |xs: &mut dyn Iterator<Item = f64>| {
        let mut s = Summary::default();
        for x in xs {
            s.add(x);
        }
        // An empty sample set has no distribution: its stats are
        // explicitly `null`, never a fake 0.0 — and never a bare NaN,
        // which the hand-rolled writer would emit unquoted (invalid
        // JSON that `json.load` still accepts silently; the baseline
        // diff rejects non-finite numbers outright).
        let stat = |v: f64| -> Json {
            if s.n() == 0 || !v.is_finite() {
                Json::Null
            } else {
                v.into()
            }
        };
        Json::obj(vec![
            ("mean", stat(s.mean())),
            ("p50", stat(s.percentile(0.50))),
            ("p99", stat(s.percentile(0.99))),
        ])
    };
    let served = outcomes.iter().filter(|o| o.ok).count();
    let within_slo = outcomes
        .iter()
        .filter(|o| o.ok && o.all_finished && o.e2e_ms <= sc.slo_ms)
        .count();
    let span = makespan_secs.max(1e-9);
    let latency = Json::obj(vec![
        ("ttft_ms",
         dist(&mut outcomes.iter().filter_map(|o| o.ttft_ms))),
        ("tpot_ms",
         dist(&mut outcomes.iter().filter_map(|o| o.tpot_ms))),
        ("e2e_ms",
         dist(&mut outcomes.iter().filter(|o| o.ok)
              .map(|o| o.e2e_ms))),
        ("queue_ms",
         dist(&mut outcomes.iter().filter(|o| o.ok)
              .map(|o| o.queue_ms))),
    ]);
    let goodput = Json::obj(vec![
        ("slo_ms", sc.slo_ms.into()),
        ("served", served.into()),
        ("within_slo", within_slo.into()),
        // Goodput counts only SLO-met completed requests; offered load
        // is what the open loop actually pushed.
        ("goodput_rps", (within_slo as f64 / span).into()),
        ("offered_rps", (outcomes.len() as f64 / span).into()),
    ]);
    let overhead = Json::obj(vec![
        ("preemptions",
         outcomes.iter().map(|o| o.preempted).sum::<usize>().into()),
        // The response echoes a monotone engine-lifetime counter; the
        // max across responses is the scenario's total.
        ("rebuckets",
         (outcomes.iter().map(|o| o.rebuckets).max().unwrap_or(0)
          as usize).into()),
        ("max_queue_depth",
         outcomes.iter().map(|o| o.queue_depth).max().unwrap_or(0)
             .into()),
        ("expired_unserved",
         outcomes.iter().filter(|o| o.expired_unserved).count().into()),
        ("errors",
         outcomes.iter().filter(|o| !o.ok).count().into()),
    ]);
    // Per-request draft economy (v2): samples are requests whose
    // server-reported draft_len_mean is positive — i.e. that ran at
    // least one speculative step (expired-unserved requests carry no
    // draft signal).
    let draft = Json::obj(vec![
        ("draft_len",
         dist(&mut outcomes.iter()
              .filter(|o| o.ok && o.draft_len_mean > 0.0)
              .map(|o| o.draft_len_mean))),
        ("acceptance_rate",
         dist(&mut outcomes.iter()
              .filter(|o| o.ok && o.draft_len_mean > 0.0)
              .map(|o| o.acceptance_rate))),
    ]);
    // Engine-lifetime launch-FLOP totals echoed on each response; max
    // across outcomes = the scenario total (monotone counter, same
    // convention as overhead.rebuckets).
    let flops = Json::obj(vec![
        ("launch",
         outcomes.iter().map(|o| o.launch_flops)
             .fold(0.0_f64, f64::max).into()),
        ("padded_launch",
         outcomes.iter().map(|o| o.padded_launch_flops)
             .fold(0.0_f64, f64::max).into()),
    ]);
    // Prompt-prefix KV reuse tally, aggregated exactly like `flops`:
    // monotone engine-lifetime echoes, max across outcomes. Taking
    // each field's max independently is sound for the same reason —
    // all counters are non-decreasing, so every max comes from the
    // last snapshot and the hits+misses==lookups identity is
    // preserved.
    let max_u64 = |f: &dyn Fn(&Outcome) -> u64| -> Json {
        (outcomes.iter().map(f).max().unwrap_or(0) as usize).into()
    };
    let prefix_cache = Json::obj(vec![
        ("lookups", max_u64(&|o| o.prefix.lookups)),
        ("hits", max_u64(&|o| o.prefix.hits)),
        ("misses", max_u64(&|o| o.prefix.misses)),
        ("evictions", max_u64(&|o| o.prefix.evictions)),
        ("row_copies", max_u64(&|o| o.prefix.row_copies)),
        ("saved_flops",
         outcomes.iter().map(|o| o.prefix.saved_flops)
             .fold(0.0_f64, f64::max).into()),
    ]);
    let counters = Json::obj(vec![
        ("n_requests", outcomes.len().into()),
        ("n_seqs_requested",
         outcomes.iter().map(|o| o.n_seqs_requested.max(1))
             .sum::<usize>().into()),
        ("total_tokens",
         outcomes.iter().map(|o| o.n_tokens).sum::<usize>().into()),
        ("all_finished",
         outcomes.iter().all(|o| o.ok && o.all_finished).into()),
    ]);
    Json::obj(vec![
        ("name", sc.name.as_str().into()),
        ("seed", (sc.seed as usize).into()),
        ("n_requests", sc.n_requests.into()),
        ("arrival", sc.arrival.to_json()),
        ("workload", sc.workload.to_json()),
        ("slo_ms", sc.slo_ms.into()),
        ("latency", latency),
        ("goodput", goodput),
        ("overhead", overhead),
        ("draft", draft),
        ("flops", flops),
        ("prefix_cache", prefix_cache),
        ("counters", counters),
    ])
}

/// Attach the schema-additive per-scenario `observability` section
/// (span summary, per-phase time shares, trace-file pointer, registry
/// snapshot — see [`crate::obs`]). Additive on top of v2: the baseline
/// diff ignores it, and reports written with tracing off omit it
/// entirely, so the deterministic `counters` comparison is unaffected.
pub fn attach_observability(entry: &mut Json, obs: Json) {
    if let Json::Obj(map) = entry {
        map.insert("observability".to_string(), obs);
    }
}

/// Assemble the whole `BENCH_serving.json` document.
pub fn bench_report(scenarios: Vec<Json>, generated_by: &str,
                    driver: &str, mode: &str) -> Json {
    Json::obj(vec![
        ("schema", SCHEMA.into()),
        ("generated_by", generated_by.into()),
        ("driver", driver.into()),
        ("mode", mode.into()),
        ("scenarios", Json::Arr(scenarios)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loadgen::{Arrival, Workload};

    fn outcome(e2e: f64, tokens: usize, finished: bool) -> Outcome {
        Outcome {
            ok: true,
            ttft_ms: Some(e2e * 0.2),
            e2e_ms: e2e,
            tpot_ms: Some(1.5),
            queue_ms: e2e * 0.1,
            n_seqs_requested: 1,
            n_seqs_returned: 1,
            n_tokens: tokens,
            all_finished: finished,
            expired_unserved: tokens == 0 && !finished,
            preempted: 1,
            rebuckets: 3,
            queue_depth: 2,
            draft_len_mean: if tokens > 0 { 3.0 } else { 0.0 },
            acceptance_rate: if tokens > 0 { 0.6 } else { 0.0 },
            // Monotone engine-lifetime echo: scale with e2e so later
            // outcomes carry larger totals (the report takes the max).
            launch_flops: e2e * 1.0e6,
            padded_launch_flops: e2e * 1.5e6,
            prefix: crate::coordinator::PrefixEcho {
                lookups: 3,
                hits: 2,
                misses: 1,
                evictions: 1,
                row_copies: 2,
                saved_flops: e2e * 1.0e4,
            },
        }
    }

    fn scenario() -> Scenario {
        Scenario {
            name: "t".into(),
            seed: 1,
            n_requests: 4,
            arrival: Arrival::Poisson { rate_rps: 50.0 },
            workload: Workload::gate(),
            slo_ms: 100.0,
        }
    }

    #[test]
    fn goodput_counts_only_slo_met_completions() {
        let outcomes = vec![
            outcome(40.0, 16, true),   // within SLO
            outcome(90.0, 16, true),   // within SLO
            outcome(150.0, 16, true),  // late
            outcome(30.0, 0, false),   // fast but expired-unserved
        ];
        let j = scenario_report(&scenario(), &outcomes, 2.0);
        let g = j.get("goodput").unwrap();
        assert_eq!(g.get("served").unwrap().as_usize().unwrap(), 4);
        assert_eq!(g.get("within_slo").unwrap().as_usize().unwrap(), 2);
        assert!((g.get("goodput_rps").unwrap().as_f64().unwrap() - 1.0)
                .abs() < 1e-9);
        let o = j.get("overhead").unwrap();
        assert_eq!(o.get("expired_unserved").unwrap().as_usize().unwrap(),
                   1);
        assert_eq!(o.get("preemptions").unwrap().as_usize().unwrap(), 4);
        assert_eq!(o.get("rebuckets").unwrap().as_usize().unwrap(), 3);
        let c = j.get("counters").unwrap();
        assert_eq!(c.get("total_tokens").unwrap().as_usize().unwrap(), 48);
        assert_eq!(c.get("all_finished").unwrap(), &Json::Bool(false));
    }

    /// The schema-stability pin: a report round-trips through the
    /// hand-rolled JSON layer losslessly and carries every v2 key.
    #[test]
    fn report_round_trips_and_is_schema_complete() {
        let outcomes: Vec<Outcome> =
            (0..5).map(|i| outcome(20.0 + i as f64, 8, true)).collect();
        let sc = scenario();
        let doc = bench_report(
            vec![scenario_report(&sc, &outcomes, 0.5)],
            "unit-test", "direct", "stub");
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc, "pretty-print → parse must be lossless");
        assert_eq!(back.get("schema").unwrap().as_str().unwrap(), SCHEMA);
        let s = &back.get("scenarios").unwrap().as_arr().unwrap()[0];
        for section in ["arrival", "workload", "latency", "goodput",
                        "overhead", "draft", "flops", "prefix_cache",
                        "counters"] {
            assert!(s.opt(section).is_some(), "missing {section}");
        }
        for metric in ["ttft_ms", "tpot_ms", "e2e_ms", "queue_ms"] {
            let m = s.get("latency").unwrap().get(metric).unwrap();
            for stat in ["mean", "p50", "p99"] {
                assert!(m.opt(stat).is_some(), "{metric} missing {stat}");
            }
            let p50 = m.get("p50").unwrap().as_f64().unwrap();
            let p99 = m.get("p99").unwrap().as_f64().unwrap();
            assert!(p50 <= p99, "{metric}: p50 {p50} > p99 {p99}");
        }
        for metric in ["draft_len", "acceptance_rate"] {
            let m = s.get("draft").unwrap().get(metric).unwrap();
            for stat in ["mean", "p50", "p99"] {
                assert!(m.opt(stat).is_some(), "{metric} missing {stat}");
            }
        }
        for key in ["n_requests", "n_seqs_requested", "total_tokens",
                    "all_finished"] {
            assert!(s.get("counters").unwrap().opt(key).is_some(),
                    "counters missing {key}");
        }
        // v2 draft samples: every test outcome drafted at mean 3.0 with
        // 60% acceptance.
        let d = s.get("draft").unwrap();
        let dl = d.get("draft_len").unwrap()
            .get("mean").unwrap().as_f64().unwrap();
        assert!((dl - 3.0).abs() < 1e-9);
        let ar = d.get("acceptance_rate").unwrap()
            .get("p50").unwrap().as_f64().unwrap();
        assert!((ar - 0.6).abs() < 1e-9);
        // flops: max over the monotone per-outcome echoes (last e2e is
        // 24.0), and launch never exceeds its own padded baseline.
        let f = s.get("flops").unwrap();
        let launch = f.get("launch").unwrap().as_f64().unwrap();
        let padded = f.get("padded_launch").unwrap().as_f64().unwrap();
        assert!((launch - 24.0e6).abs() < 1.0, "got launch {launch}");
        assert!(launch <= padded, "launch {launch} > padded {padded}");
        // prefix_cache: monotone-echo max aggregation must preserve the
        // hits+misses==lookups identity the diff script hard-checks.
        let pc = s.get("prefix_cache").unwrap();
        let v = |k: &str| pc.get(k).unwrap().as_usize().unwrap();
        assert_eq!(v("hits") + v("misses"), v("lookups"));
        assert_eq!(v("row_copies"), 2);
        let saved = pc.get("saved_flops").unwrap().as_f64().unwrap();
        assert!((saved - 24.0e4).abs() < 1.0, "got saved {saved}");
    }

    /// Satellite regression: a scenario where nothing was ever served
    /// (every request expired unserved) has **no** TTFT/TPOT samples —
    /// the stats must come out `null`, not 0.0 and not an unquoted NaN
    /// that would corrupt the JSON document.
    #[test]
    fn empty_sample_sets_emit_null_stats_not_nan() {
        let outcomes = vec![outcome(30.0, 0, false)];
        let j = scenario_report(&scenario(), &outcomes, 1.0);
        let text = j.to_string_pretty();
        assert!(!text.contains("NaN") && !text.contains("inf"),
                "non-finite leaked into JSON: {text}");
        let back = Json::parse(&text).unwrap();
        let lat = back.get("latency").unwrap();
        // ttft_ms has one sample (the expired outcome still carries a
        // Some(ttft) in this fixture) but the draft section is sampled
        // only from requests that drafted — zero of them here.
        let d = back.get("draft").unwrap().get("draft_len").unwrap();
        for stat in ["mean", "p50", "p99"] {
            assert_eq!(d.get(stat).unwrap(), &Json::Null,
                       "draft_len.{stat} should be null");
        }
        // And a fully empty iterator: e2e over zero ok-outcomes.
        let none = scenario_report(&scenario(), &[], 1.0);
        let e2e = none.get("latency").unwrap().get("e2e_ms").unwrap();
        assert_eq!(e2e.get("mean").unwrap(), &Json::Null);
        assert_eq!(e2e.get("p99").unwrap(), &Json::Null);
        // Single-outcome sets still emit real numbers.
        let q = lat.get("queue_ms").unwrap();
        assert!(q.get("p50").unwrap().as_f64().is_ok());
    }

    #[test]
    fn observability_section_is_additive() {
        let outcomes = vec![outcome(20.0, 8, true)];
        let sc = scenario();
        let mut entry = scenario_report(&sc, &outcomes, 1.0);
        attach_observability(&mut entry, Json::obj(vec![
            ("trace_file", "trace.t.json".into()),
        ]));
        let obs = entry.get("observability").unwrap();
        assert_eq!(obs.get("trace_file").unwrap().as_str().unwrap(),
                   "trace.t.json");
        // Everything the v2 schema promises is still there.
        for section in ["latency", "goodput", "overhead", "draft",
                        "flops", "counters"] {
            assert!(entry.opt(section).is_some(), "missing {section}");
        }
    }
}
