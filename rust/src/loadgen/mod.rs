//! The serving load harness: open-loop load generation against the
//! [`crate::coordinator::Coordinator`], measuring what the paper-table
//! benches cannot — serving behavior under concurrent traffic.
//!
//! Layers:
//! * [`arrival`] — seeded open-loop arrival processes (Poisson, bursty,
//!   trace replay).
//! * [`workload`] — seeded request-mix sampling (prompt length,
//!   fan-out, priority, deadline, generation budget).
//! * [`run`] — the runner: timed submissions driving the coordinator
//!   directly or through the TCP line protocol (one pipelined
//!   connection, replies correlated by `"id"`), per-request TTFT /
//!   TPOT / e2e / queue-wait outcomes.
//! * [`report`] — the schema-stable `BENCH_serving.json` record:
//!   latency distributions (mean/p50/p99), goodput under an SLO,
//!   preemption/re-bucket overhead, and a deterministic counter
//!   subset the CI perf gate diffs bit-for-bit.
//!
//! The harness runs end to end on the host-only stub backend
//! ([`crate::spec::ExecMode::Stub`]): no artifacts, no device, full
//! scheduler stack — which is exactly what a CI machine has.

pub mod arrival;
pub mod report;
pub mod run;
pub mod workload;

pub use arrival::Arrival;
pub use run::{run_direct, run_tcp, Outcome, Scenario};
pub use workload::{LoadRequest, PrefixPool, Workload};

use anyhow::{bail, Result};

/// Build the named scenario set. `deterministic` selects the CI-gate
/// workload (fan-out 1 → timing-independent counters); otherwise the
/// mixed serving population runs. A `prefix_pool` override replaces
/// the mix's default shared-prefix population (`Some` on the mixed
/// mix, `None` on the gate) — CI uses it to run a gate-deterministic
/// scenario that still hammers the prompt-prefix cache.
pub fn scenarios(arrival: &str, deterministic: bool, n_requests: usize,
                 rate_rps: f64, seed: u64, slo_ms: f64,
                 prefix_pool: Option<Option<PrefixPool>>)
                 -> Result<Vec<Scenario>> {
    let mut workload = if deterministic {
        Workload::gate()
    } else {
        Workload::mixed()
    };
    if let Some(pool) = prefix_pool {
        workload.prefix_pool = pool;
    }
    let poisson = Scenario {
        name: if deterministic {
            "poisson-gate".into()
        } else {
            "poisson".into()
        },
        seed,
        n_requests,
        arrival: Arrival::Poisson { rate_rps },
        workload: workload.clone(),
        slo_ms,
    };
    // The burst alternates 4x the offered rate (one fifth of the time)
    // with a light trough — the admission-spike shape that exercises
    // live re-bucketing and preemption.
    let bursty = Scenario {
        name: if deterministic {
            "bursty-gate".into()
        } else {
            "bursty".into()
        },
        seed: seed.wrapping_add(1),
        n_requests,
        arrival: Arrival::Bursty {
            base_rps: rate_rps * 0.25,
            burst_rps: rate_rps * 4.0,
            period_secs: 1.0,
            duty: 0.2,
        },
        workload,
        slo_ms,
    };
    Ok(match arrival {
        "poisson" => vec![poisson],
        "bursty" => vec![bursty],
        "both" => vec![poisson, bursty],
        other => bail!("unknown arrival '{other}' \
                        (try: poisson|bursty|both)"),
    })
}
