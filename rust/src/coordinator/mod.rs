//! Layer-3 serving coordinator: request queue → dynamic batcher → the
//! speculative engine on a dedicated worker thread → responses.
//!
//! The engine (PJRT handles) is **not** `Send`, so it is constructed inside
//! the worker thread and owns the device for the process lifetime — the
//! same single-engine-loop architecture vLLM's scheduler uses. Requests and
//! responses cross threads over mpsc channels; the TCP front-end
//! ([`server`]) is just a thin line-protocol adapter.

pub mod batcher;
pub mod server;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::runtime::Engine;
use crate::spec::{SpecConfig, SpecEngine};
use batcher::{plan_batch, should_flush, BatcherConfig, Pending};

/// One generation request.
#[derive(Debug)]
pub struct Request {
    pub prompt: Vec<u8>,
    /// Fan-out: number of sequences to sample for this prompt.
    pub n_seqs: usize,
    pub max_new_tokens: Option<usize>,
    pub temperature: Option<f32>,
    pub top_p: Option<f32>,
}

/// One generated sequence.
#[derive(Debug, Clone)]
pub struct GenSeq {
    pub text: String,
    pub finished: bool,
    pub mean_logp: f64,
    pub n_tokens: usize,
}

/// Response to one request.
#[derive(Debug)]
pub struct Response {
    pub seqs: Vec<GenSeq>,
    /// Engine wall seconds spent on the batch this request rode in.
    pub batch_secs: f64,
    /// Sequences in that engine batch (yours + co-batched).
    pub batch_size: usize,
    /// Queue wait before the batch started.
    pub queue_secs: f64,
}

enum Msg {
    Job(Request, Sender<Result<Response>>),
    Shutdown,
}

/// Handle to the serving worker.
pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_root: std::path::PathBuf,
    pub spec: SpecConfig,
    pub batcher: BatcherConfig,
    /// Compile all needed executables at startup (slower start, no
    /// lazy-compile spikes on the request path). Default true.
    pub prewarm: bool,
}

impl CoordinatorConfig {
    pub fn new(artifacts_root: std::path::PathBuf, spec: SpecConfig,
               batcher: BatcherConfig) -> Self {
        CoordinatorConfig { artifacts_root, spec, batcher, prewarm: true }
    }
}

impl Coordinator {
    /// Spawn the worker (builds the PJRT engine inside the thread).
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("bass-engine".into())
            .spawn(move || worker(cfg, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Coordinator { tx, handle: Some(handle) })
    }

    /// Submit a request; the receiver yields the response when its batch
    /// completes.
    pub fn submit(&self, req: Request) -> Receiver<Result<Response>> {
        let (tx, rx) = channel();
        // A send error means the worker is gone; the receiver will report
        // a disconnect to the caller.
        let _ = self.tx.send(Msg::Job(req, tx));
        rx
    }

    /// Convenience: submit and block for the response.
    pub fn generate(&self, req: Request) -> Result<Response> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("engine thread terminated"))?
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct QueuedJob {
    req: Request,
    reply: Sender<Result<Response>>,
    pending: Pending,
}

fn worker(cfg: CoordinatorConfig, rx: Receiver<Msg>,
          ready: Sender<Result<()>>) {
    let engine = match Engine::load(&cfg.artifacts_root) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    if cfg.prewarm {
        let batches: Vec<usize> = engine.manifest.batches.iter().copied()
            .filter(|&b| b <= cfg.batcher.max_batch)
            .collect();
        for b in batches {
            for model in [&cfg.spec.main_model, &cfg.spec.draft_model] {
                if let Err(e) = engine.prewarm(model, cfg.spec.precision, b) {
                    let _ = ready.send(Err(e));
                    return;
                }
            }
        }
    }
    let _ = ready.send(Ok(()));
    let mut queue: Vec<QueuedJob> = Vec::new();
    let mut next_id = 0u64;
    let mut open = true;

    while open || !queue.is_empty() {
        // Pull messages; block only when the queue is empty.
        loop {
            let msg = if queue.is_empty() && open {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        open = false;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            };
            match msg {
                Msg::Shutdown => {
                    open = false;
                    break;
                }
                Msg::Job(req, reply) => {
                    next_id += 1;
                    let pending = Pending {
                        request_id: next_id,
                        n_seqs: req.n_seqs.max(1),
                        enqueued: Instant::now(),
                    };
                    queue.push(QueuedJob { req, reply, pending });
                }
            }
        }
        if queue.is_empty() {
            continue;
        }
        let pendings: Vec<Pending> =
            queue.iter().map(|j| j.pending.clone()).collect();
        if open && !should_flush(&pendings, &cfg.batcher, Instant::now()) {
            std::thread::sleep(std::time::Duration::from_micros(200));
            continue;
        }
        let (n_take, _) = plan_batch(&pendings, &cfg.batcher);
        let jobs: Vec<QueuedJob> = queue.drain(..n_take).collect();
        run_batch(&engine, &cfg, jobs);
    }
}

fn run_batch(engine: &Engine, cfg: &CoordinatorConfig,
             jobs: Vec<QueuedJob>) {
    // Expand fan-outs into a flat prompt batch.
    let mut prompts: Vec<Vec<u8>> = Vec::new();
    let mut slices: Vec<(usize, usize)> = Vec::new();
    let cap = cfg.batcher.max_batch;
    for j in &jobs {
        let n = j.req.n_seqs.max(1).min(cap - prompts.len().min(cap - 1));
        let start = prompts.len();
        for _ in 0..n {
            prompts.push(j.req.prompt.clone());
        }
        slices.push((start, n));
    }

    // Per-batch overrides come from the first request (co-batched requests
    // share sampling params; the server groups compatible requests).
    let mut spec = cfg.spec.clone();
    if let Some(t) = jobs[0].req.temperature {
        spec.temperature = t;
    }
    if let Some(p) = jobs[0].req.top_p {
        spec.top_p = p;
    }
    if let Some(m) = jobs[0].req.max_new_tokens {
        spec.max_new_tokens = m;
    }

    let t0 = Instant::now();
    let result = SpecEngine::new(engine, spec).generate(&prompts);
    let batch_secs = t0.elapsed().as_secs_f64();

    match result {
        Ok(res) => {
            for (j, (start, n)) in jobs.into_iter().zip(slices) {
                let seqs = res.seqs[start..start + n]
                    .iter()
                    .map(|s| GenSeq {
                        text: crate::tokenizer::decode(&s.generated),
                        finished: s.finish
                            != crate::kv::FinishReason::Running,
                        mean_logp: s.mean_logp(),
                        n_tokens: s.tokens_generated(),
                    })
                    .collect();
                let queue_secs =
                    t0.duration_since(j.pending.enqueued).as_secs_f64();
                let _ = j.reply.send(Ok(Response {
                    seqs,
                    batch_secs,
                    batch_size: prompts.len(),
                    queue_secs,
                }));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for j in jobs {
                let _ = j.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
