//! Layer-3 serving coordinator: request queue → free-slot batcher → a
//! **continuously batched** speculative engine on a dedicated worker
//! thread → responses.
//!
//! The worker owns one long-lived [`SpecBatch`] and drives it step by
//! step. At every step boundary it (a) admits queued requests into free
//! batch slots ([`batcher::plan_batch`] plans against *free slots*, not an
//! empty batch) and (b) retires sequences the moment they finish,
//! answering each request as soon as *its* sequences are done — no
//! head-of-line blocking behind co-batched long requests. **Both
//! execution modes admit mid-flight**: SPLIT prefills a per-slot B=1
//! cache; PAD scatter-prefills the new sequence into a freed row of the
//! running fused cache (the per-row `prefill_scatter` artifact), so the
//! paper's primary mode keeps its batch continuously utilized under load
//! instead of waiting for a drain. A running PAD batch's *bucket* still
//! cannot grow — free slots there are retired/padding rows — so a burst
//! larger than the current bucket waits for the drain-and-re-bucket.
//!
//! The engine (PJRT handles) is **not** `Send`, so it is constructed
//! inside the worker thread and owns the device for the process lifetime —
//! the same single-engine-loop architecture vLLM's scheduler uses.
//! Requests and responses cross threads over mpsc channels; the TCP
//! front-end ([`server`]) is just a thin line-protocol adapter that can
//! also relay per-step [`StepEvent`]s as a streaming response.
//!
//! Sampling parameters (temperature / top-p) are **per request**, like
//! `max_new_tokens` and `seed`: sequences from many requests share fused
//! device calls, but the draft artifact takes `[B]` per-row param vectors
//! and the verify-side warp is per-slot host code, so each admitted
//! sequence keeps its own request's knobs ([`crate::spec::AdmitOpts`]).
//! The server's [`SpecConfig`] values are only the defaults for requests
//! that leave them unset.

pub mod batcher;
pub mod server;

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::kv::FinishReason;
use crate::runtime::Engine;
use crate::spec::{AdmitOpts, SeqId, SpecBatch, SpecConfig};
use batcher::{plan_batch, should_flush, BatcherConfig, Pending};

/// One generation request.
#[derive(Debug)]
pub struct Request {
    pub prompt: Vec<u8>,
    /// Fan-out: number of sequences to sample for this prompt.
    pub n_seqs: usize,
    pub max_new_tokens: Option<usize>,
    /// Per-request sampling temperature; every sequence of this request's
    /// fan-out uses it in the fused draft call and the verify-side warp.
    /// Defaults to the server's [`SpecConfig::temperature`].
    pub temperature: Option<f32>,
    /// Per-request nucleus threshold (same scope as `temperature`).
    pub top_p: Option<f32>,
    /// Per-request RNG seed. When set, each fan-out sequence's RNG
    /// stream is pinned to its fan-out index, so {prompt, seed}
    /// reproduces the same output regardless of server traffic history —
    /// provided the per-step draft lengths match, i.e. the server runs
    /// `Policy::Fixed` (under the adaptive heuristic, k is batch-global
    /// Algorithm-1 state fed by co-batched traffic). Defaults to the
    /// server's spec seed with traffic-dependent streams.
    pub seed: Option<u64>,
    /// Relay per-step [`StepEvent`]s before the final response.
    pub stream: bool,
}

/// One generated sequence.
#[derive(Debug, Clone)]
pub struct GenSeq {
    pub text: String,
    pub finished: bool,
    pub mean_logp: f64,
    pub n_tokens: usize,
}

/// Response to one request.
#[derive(Debug)]
pub struct Response {
    pub seqs: Vec<GenSeq>,
    /// Fan-out the request asked for. `seqs.len() < n_requested` means the
    /// engine clamped the fan-out to its batch capacity — previously a
    /// silent truncation the client could not distinguish from a typo'd
    /// `n`.
    pub n_requested: usize,
    /// Wall seconds from this request's admission into the engine batch
    /// to its last sequence retiring.
    pub batch_secs: f64,
    /// Most sequences that shared the engine batch with this request at
    /// any step (yours + co-batched).
    pub batch_size: usize,
    /// Queue wait before admission (not before the whole batch finished).
    pub queue_secs: f64,
}

/// One per-step progress notification for a streaming request.
#[derive(Debug, Clone)]
pub struct StepEvent {
    /// Index of the sequence within the request's fan-out.
    pub seq: usize,
    /// Text decoded from the bytes this sequence emitted this step.
    pub text_delta: String,
    /// This sequence finished on this step.
    pub done: bool,
}

/// What a submitted request's receiver yields: zero or more step events
/// (streaming requests only), then exactly one `Done`.
#[derive(Debug)]
pub enum Reply {
    Step(StepEvent),
    Done(Result<Response>),
}

enum Msg {
    Job(Request, Sender<Reply>),
    Shutdown,
}

/// Handle to the serving worker.
pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_root: std::path::PathBuf,
    pub spec: SpecConfig,
    pub batcher: BatcherConfig,
    /// Compile all needed executables at startup (slower start, no
    /// lazy-compile spikes on the request path). Default true.
    pub prewarm: bool,
}

impl CoordinatorConfig {
    pub fn new(artifacts_root: std::path::PathBuf, spec: SpecConfig,
               batcher: BatcherConfig) -> Self {
        CoordinatorConfig { artifacts_root, spec, batcher, prewarm: true }
    }
}

impl Coordinator {
    /// Spawn the worker (builds the PJRT engine inside the thread).
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("bass-engine".into())
            .spawn(move || worker(cfg, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Coordinator { tx, handle: Some(handle) })
    }

    /// Submit a request; the receiver yields step events (if requested)
    /// and then `Reply::Done` as soon as *this* request's sequences
    /// retire — co-batched requests keep running.
    pub fn submit(&self, req: Request) -> Receiver<Reply> {
        let (tx, rx) = channel();
        // A send error means the worker is gone; the receiver will report
        // a disconnect to the caller.
        let _ = self.tx.send(Msg::Job(req, tx));
        rx
    }

    /// Drain a submission's receiver to its final response, discarding
    /// any step events.
    pub fn wait(rx: Receiver<Reply>) -> Result<Response> {
        loop {
            match rx.recv() {
                Ok(Reply::Step(_)) => continue,
                Ok(Reply::Done(r)) => return r,
                Err(_) => return Err(anyhow!("engine thread terminated")),
            }
        }
    }

    /// Convenience: submit and block for the response.
    pub fn generate(&self, req: Request) -> Result<Response> {
        Self::wait(self.submit(req))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct QueuedJob {
    id: u64,
    req: Request,
    reply: Sender<Reply>,
    pending: Pending,
}

/// A request whose sequences are (partly) in the engine batch.
struct InFlight {
    reply: Sender<Reply>,
    stream: bool,
    /// seq id -> index within this request's fan-out.
    seq_index: HashMap<SeqId, usize>,
    done: Vec<Option<GenSeq>>,
    remaining: usize,
    /// Fan-out asked for (before any capacity clamp).
    n_requested: usize,
    admitted: Instant,
    queue_secs: f64,
    /// Max co-resident sequences observed while this request was in the
    /// batch (reported as `Response::batch_size`).
    batch_size: usize,
}

impl InFlight {
    fn finish(self) {
        let seqs = self
            .done
            .into_iter()
            .map(|s| s.expect("all sequences retired"))
            .collect();
        let _ = self.reply.send(Reply::Done(Ok(Response {
            seqs,
            n_requested: self.n_requested,
            batch_secs: self.admitted.elapsed().as_secs_f64(),
            batch_size: self.batch_size,
            queue_secs: self.queue_secs,
        })));
    }
}

fn worker(cfg: CoordinatorConfig, rx: Receiver<Msg>,
          ready: Sender<Result<()>>) {
    let engine = match Engine::load(&cfg.artifacts_root) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    if cfg.prewarm {
        let batches: Vec<usize> = engine.manifest.batches.iter().copied()
            .filter(|&b| b <= cfg.batcher.max_batch)
            .collect();
        for b in batches {
            for model in [&cfg.spec.main_model, &cfg.spec.draft_model] {
                if let Err(e) = engine.prewarm(model, cfg.spec.precision, b) {
                    let _ = ready.send(Err(e));
                    return;
                }
            }
        }
    }
    let capacity = cfg.batcher.max_batch.max(1);
    let mut batch = match SpecBatch::new(&engine, cfg.spec.clone(), capacity)
    {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = ready.send(Ok(()));

    let mut queue: Vec<QueuedJob> = Vec::new();
    let mut inflight: HashMap<u64, InFlight> = HashMap::new();
    // seq id -> owning request id (live sequences only).
    let mut seq_owner: HashMap<SeqId, u64> = HashMap::new();
    let mut next_id = 0u64;
    let mut open = true;

    while open || !queue.is_empty() || !inflight.is_empty() {
        // -- pull messages; block only when fully idle ---------------------
        loop {
            let idle =
                queue.is_empty() && inflight.is_empty() && open;
            let msg = if idle {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        open = false;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            };
            match msg {
                Msg::Shutdown => {
                    open = false;
                    break;
                }
                Msg::Job(req, reply) => {
                    next_id += 1;
                    let pending = Pending {
                        request_id: next_id,
                        n_seqs: req.n_seqs.max(1),
                        enqueued: Instant::now(),
                    };
                    queue.push(QueuedJob { id: next_id, req, reply,
                                           pending });
                }
            }
        }

        // -- admission at the step boundary --------------------------------
        admit_jobs(&mut batch, &mut queue, &mut inflight, &mut seq_owner,
                   &cfg.batcher);

        // Per-request time budget (Fig-5 semantics): a request whose age
        // since *its own admission* exceeds the budget is answered as-is,
        // possibly unfinished. Measured per request, not per busy period,
        // so late joiners of a long-running SPLIT batch get a full budget.
        if let Some(budget) = cfg.spec.time_budget_secs {
            let expired: Vec<SeqId> = seq_owner
                .iter()
                .filter(|(_, owner)| {
                    inflight.get(owner).is_some_and(|j| {
                        j.admitted.elapsed().as_secs_f64() >= budget
                    })
                })
                .map(|(&id, _)| id)
                .collect();
            for id in expired {
                retire_seq(&mut batch, id, &mut inflight, &mut seq_owner);
            }
        }

        if !batch.has_active() {
            if batch.occupied() > 0 {
                // Defensive: sequences stalled in any other way are
                // returned rather than wedging their requests forever.
                let ids: Vec<SeqId> = seq_owner.keys().copied().collect();
                for id in ids {
                    retire_seq(&mut batch, id, &mut inflight,
                               &mut seq_owner);
                }
            } else if !queue.is_empty() {
                // Waiting out the co-batching window.
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            continue;
        }

        // -- one speculative step ------------------------------------------
        let occupied = batch.occupied();
        for job in inflight.values_mut() {
            job.batch_size = job.batch_size.max(occupied);
        }
        let report = match batch.step() {
            Ok(r) => r,
            Err(e) => {
                // The device state is suspect: fail everything in flight
                // and start over with a fresh batch.
                let msg = format!("{e:#}");
                for (_, job) in inflight.drain() {
                    let _ = job.reply
                        .send(Reply::Done(Err(anyhow!("{msg}"))));
                }
                seq_owner.clear();
                match SpecBatch::new(&engine, cfg.spec.clone(), capacity) {
                    Ok(b) => batch = b,
                    Err(e2) => {
                        for j in queue.drain(..) {
                            let _ = j.reply
                                .send(Reply::Done(Err(anyhow!("{e2:#}"))));
                        }
                        return;
                    }
                }
                continue;
            }
        };

        // -- relay streaming events ----------------------------------------
        for ev in &report.events {
            let Some(&owner) = seq_owner.get(&ev.id) else { continue };
            let Some(job) = inflight.get(&owner) else { continue };
            if job.stream && (!ev.new_bytes.is_empty() || ev.done) {
                let _ = job.reply.send(Reply::Step(StepEvent {
                    seq: job.seq_index[&ev.id],
                    text_delta: crate::tokenizer::decode(&ev.new_bytes),
                    done: ev.done,
                }));
            }
        }

        // -- retire finished sequences immediately -------------------------
        for id in report.finished {
            retire_seq(&mut batch, id, &mut inflight, &mut seq_owner);
        }
    }
}

/// Admit queued requests into free slots — mid-flight in both modes
/// (SPLIT: per-slot prefill; PAD: scatter-prefill into freed rows of the
/// running bucket) — respecting the co-batching window.
fn admit_jobs(batch: &mut SpecBatch, queue: &mut Vec<QueuedJob>,
              inflight: &mut HashMap<u64, InFlight>,
              seq_owner: &mut HashMap<SeqId, u64>, bcfg: &BatcherConfig) {
    let default_seed = batch.config().seed;
    while batch.can_admit() && !queue.is_empty() {
        let free = batch.free_slots();
        let pendings: Vec<Pending> =
            queue.iter().map(|j| j.pending.clone()).collect();
        if !should_flush(&pendings, free, bcfg, Instant::now()) {
            return;
        }
        let (n_take, _) = plan_batch(&pendings, free, bcfg);
        if n_take == 0 {
            return;
        }
        for job in queue.drain(..n_take) {
            let n_requested = job.pending.n_seqs.max(1);
            let n = n_requested.min(batch.free_slots().max(1));
            let admitted = Instant::now();
            let queue_secs =
                admitted.duration_since(job.pending.enqueued).as_secs_f64();
            let seed = job.req.seed.unwrap_or(default_seed);
            let mut fl = InFlight {
                reply: job.reply,
                stream: job.req.stream,
                seq_index: HashMap::new(),
                done: (0..n).map(|_| None).collect(),
                remaining: n,
                n_requested,
                admitted,
                queue_secs,
                batch_size: n,
            };
            let mut failed = None;
            for i in 0..n {
                // A pinned per-request seed also pins the RNG stream to
                // the fan-out index, so {prompt, seed} reproduces the
                // same output regardless of prior traffic (exact under
                // Policy::Fixed; see Request::seed).
                let stream = job.req.seed.map(|_| i as u64);
                match batch.admit_opts(&job.req.prompt, seed, AdmitOpts {
                    max_new_tokens: job.req.max_new_tokens,
                    stream,
                    temperature: job.req.temperature,
                    top_p: job.req.top_p,
                }) {
                    Ok(id) => {
                        fl.seq_index.insert(id, i);
                        seq_owner.insert(id, job.id);
                    }
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            if let Some(e) = failed {
                // Roll back this job's partial admissions and fail it.
                for &id in fl.seq_index.keys() {
                    let _ = batch.retire(id);
                    seq_owner.remove(&id);
                }
                let _ = fl.reply.send(Reply::Done(Err(e)));
                continue;
            }
            inflight.insert(job.id, fl);
        }
    }
}

/// Move one finished (or budget-stalled) sequence out of the batch and
/// into its request's response; answer the request when it was the last.
fn retire_seq(batch: &mut SpecBatch, id: SeqId,
              inflight: &mut HashMap<u64, InFlight>,
              seq_owner: &mut HashMap<SeqId, u64>) {
    let Some(owner) = seq_owner.remove(&id) else { return };
    let state = match batch.retire(id) {
        Ok(s) => s,
        Err(_) => return,
    };
    let Some(job) = inflight.get_mut(&owner) else { return };
    let idx = job.seq_index[&id];
    job.done[idx] = Some(GenSeq {
        text: crate::tokenizer::decode(&state.generated),
        finished: state.finish != FinishReason::Running,
        mean_logp: state.mean_logp(),
        n_tokens: state.tokens_generated(),
    });
    job.remaining -= 1;
    if job.remaining == 0 {
        let job = inflight.remove(&owner).expect("job present");
        job.finish();
    }
}
