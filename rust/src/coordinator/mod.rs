//! Layer-3 serving coordinator: request queue → **preemptive priority
//! scheduler** → a continuously batched speculative engine on a dedicated
//! worker thread → responses.
//!
//! The worker owns one long-lived [`SpecBatch`] and drives it step by
//! step. At every step boundary it asks the [`scheduler`] for a plan over
//! {queued, running, suspended} work and executes it:
//!
//! * **Preempt** — a strictly-higher-priority arrival may suspend a
//!   low-priority running sequence ([`SpecBatch::suspend`]): the
//!   sequence's host-side identity (bytes, RNG streams, params, budget)
//!   is parked in the scheduler, its device KV dropped, its slot freed.
//!   Weakest victims go first; equal priority never preempts; sequences
//!   whose context outgrew the prefill capacity are pinned (see
//!   [`SpecBatch::can_suspend`]).
//! * **Resume** — parked sequences re-enter free slots by **recompute**
//!   ([`SpecBatch::resume`]): one prefill over `prompt ‖ generated`
//!   (SPLIT per-slot, PAD scatter into a reusable row of the running
//!   bucket) rebuilds the KV row bitwise with the existing artifacts, so
//!   a preempted request's output is byte-identical to an uninterrupted
//!   run under `Policy::Fixed`. The cost model: suspension holds a few
//!   hundred host bytes; resumption costs one prompt-length prefill.
//!   Because the suspended set lives on the host, admitted work may
//!   exceed the engine's device slots — `max_batch` bounds *running*
//!   work only.
//! * **Admit** — queued requests enter through the rank-ordered FIFO
//!   policy ([`batcher::plan_batch`] / [`batcher::should_flush`], now
//!   consulted solely by the scheduler with a single wall-clock read per
//!   round). **Both execution modes admit mid-flight**: SPLIT prefills a
//!   per-slot B=1 cache; PAD scatter-prefills into a freed row of the
//!   running fused cache.
//! * **Re-bucket** — a running PAD bucket **grows live** when a burst
//!   exceeds its reusable rows, and shrinks when it runs mostly empty
//!   ([`SpecBatch::rebucket`], planned by the scheduler's cost model):
//!   every carried sequence rides the same bitwise recompute primitive
//!   as resume — one fused prefill at the new bucket — keeping its
//!   SeqId, RNG streams, params and clock, so a late burst of `b + k`
//!   sequences is served while the original `b` keep generating,
//!   byte-identically, with no drain and no artifact rebuild.
//!   `--pad-headroom` still pre-provisions grow-room rows (cheaper than
//!   a re-prefill) and is re-applied on every re-bucket; free headroom
//!   rows are always consumed before a grow is considered.
//!
//! Sequences retire the moment they finish and each request is answered
//! as soon as *its* sequences are done — no head-of-line blocking behind
//! co-batched long requests. The engine (PJRT handles) is **not** `Send`,
//! so it is constructed inside the worker thread and owns the device for
//! the process lifetime — the same single-engine-loop architecture vLLM's
//! scheduler uses. Requests and responses cross threads over mpsc
//! channels; the TCP front-end ([`server`]) is a thin line-protocol
//! adapter that can also relay per-step [`StepEvent`]s.
//!
//! **Prompt-prefix KV reuse** (ISSUE 10) removes the redundant prefills
//! the bullets above imply:
//!
//! * **Fan-out sharing** — a fan-out-`n` admission prefills the prompt
//!   **once**: sibling 2..n get their KV by a device row copy from the
//!   first sibling's row ([`SpecBatch::admit_shared_opts`] →
//!   `Backend::copy_row`), charged as copies, not prefills.
//! * **The prefix cache** ([`prefix_cache::PrefixCache`]) is a
//!   host-side *index* of recently-resident prefix contexts, keyed by
//!   prompt bytes truncated to block granularity and evicted LRU over a
//!   **logical tick** (one per cache operation — never wall-clock, so
//!   identical traffic replays identical evictions). The KV itself
//!   stays on the device: a lookup hit is only served after
//!   [`SpecBatch::donor_row_for`] re-validates a live donor row (a
//!   running sequence or a frozen Husk row covering the context), so a
//!   stale entry costs one probe, never stale KV. Hits turn
//!   repeat-prefix admissions and recompute-resumes into `row_copy`
//!   instead of a full prompt prefill. Reuse is **bitwise invisible**:
//!   a copied row is byte-identical to a freshly prefilled one, so
//!   cache on/off cannot perturb the deterministic counters.
//! * **Scheduler cost model** — when the engine runs a started fused
//!   bucket and the cache is on, a preempted sequence's row survives as
//!   its own Husk donor, so resume is a cheap row copy instead of a
//!   prompt-length recompute. The worker reports that via
//!   [`scheduler::BatchView::cheap_resume`], and the scheduler is then
//!   *more willing* to preempt: a **deadlined** waiter may suspend an
//!   equal-priority **undeadlined** victim (the relation is asymmetric,
//!   so cheap preemption cannot ping-pong; without `cheap_resume`,
//!   equal priority still never preempts).
//!
//! Sampling parameters (temperature / top-p) are **per request**, like
//! `max_new_tokens`, `seed`, `priority` and `deadline_ms`: sequences from
//! many requests share fused device calls, but the draft artifact takes
//! `[B]` per-row param vectors and the verify-side warp is per-slot host
//! code ([`crate::spec::AdmitOpts`]). The server's [`SpecConfig`] values
//! are only the defaults for requests that leave them unset.

pub mod batcher;
pub mod prefix_cache;
pub mod scheduler;
pub mod server;

use std::collections::{HashMap, HashSet};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::kv::FinishReason;
use crate::obs::{registry, SpanKind, Tracer};
use crate::runtime::json::Json;
use crate::runtime::Engine;
use crate::spec::{AdmitOpts, ExecMode, SeqId, SpecBatch, SpecConfig,
                  SuspendedSeq};
use crate::metrics::SchedStats;
use batcher::BatcherConfig;
use prefix_cache::PrefixCache;
use scheduler::{ParkedSeq, RunningSeq, Scheduler, SchedulerConfig,
                Urgency};

/// One generation request.
#[derive(Debug)]
pub struct Request {
    pub prompt: Vec<u8>,
    /// Fan-out: number of sequences to sample for this prompt.
    pub n_seqs: usize,
    pub max_new_tokens: Option<usize>,
    /// Per-request sampling temperature; every sequence of this request's
    /// fan-out uses it in the fused draft call and the verify-side warp.
    /// Defaults to the server's [`SpecConfig::temperature`].
    pub temperature: Option<f32>,
    /// Per-request nucleus threshold (same scope as `temperature`).
    pub top_p: Option<f32>,
    /// Per-request RNG seed. When set, each fan-out sequence's RNG
    /// stream is pinned to its fan-out index, so {prompt, seed}
    /// reproduces the same output regardless of server traffic history
    /// — under **both** draft-length policies: each sequence runs its
    /// own Algorithm-1 controller fed only by its own acceptance, and
    /// consumes exactly `k_i` draft uniforms per step, so its draft
    /// lengths and RNG positions are a pure function of {prompt, seed},
    /// never of co-batched traffic. Preemption does not break this
    /// either: a suspended sequence resumes with its exact RNG stream
    /// positions *and* its learned controller state. Defaults to the
    /// server's spec seed with traffic-dependent streams.
    pub seed: Option<u64>,
    /// Scheduling priority: higher runs first and may **preempt**
    /// strictly-lower-priority running work (suspend-to-host +
    /// recompute-resume). Equal priorities never preempt each other.
    /// Default 0.
    pub priority: Option<i32>,
    /// Soft deadline, milliseconds from submission: orders work *within*
    /// a priority class (earliest first; deadlined before undeadlined).
    /// An ordering hint, not a guarantee — priority always dominates.
    pub deadline_ms: Option<u64>,
    /// Relay per-step [`StepEvent`]s before the final response.
    pub stream: bool,
}

/// One generated sequence.
#[derive(Debug, Clone)]
pub struct GenSeq {
    pub text: String,
    pub finished: bool,
    pub mean_logp: f64,
    pub n_tokens: usize,
}

/// Response to one request.
#[derive(Debug)]
pub struct Response {
    pub seqs: Vec<GenSeq>,
    /// Fan-out the request asked for. `seqs.len() < n_requested` means the
    /// engine clamped the fan-out to its batch capacity — previously a
    /// silent truncation the client could not distinguish from a typo'd
    /// `n`.
    pub n_requested: usize,
    /// Wall seconds from this request's admission into the engine batch
    /// to its last sequence retiring (time spent suspended counts — the
    /// request was admitted and preemption is a serving decision the
    /// client should be able to see in its latency).
    pub batch_secs: f64,
    /// Most sequences that shared the engine batch with this request at
    /// any step while it had live sequences (yours + co-batched).
    pub batch_size: usize,
    /// Queue wait before first admission (not before the whole batch
    /// finished).
    pub queue_secs: f64,
    /// Times this request's sequences were preempted (suspended to host
    /// for higher-priority work and later resumed by recompute).
    pub preempted: usize,
    /// Requests still waiting in the scheduler queue when this response
    /// was finalized — a server-load signal for clients.
    pub queue_depth: usize,
    /// Live PAD re-buckets (grow + shrink) the serving engine had
    /// executed when this response was finalized — like `queue_depth`,
    /// a load/behavior signal: a rising count under bursty traffic
    /// means the fused bucket is being re-shaped instead of draining.
    pub rebuckets: u64,
    /// Step FLOPs the engine had actually launched (engine-lifetime
    /// total) when this response was finalized: each backend accrues
    /// what it really dispatched per draft/verify call — packed counts
    /// the Σq_i token stream, PAD/stub the full rectangle (see
    /// `spec::backend`'s launch accounting). 0.0 for never-admitted
    /// answers (budget-expired while queued).
    pub launch_flops: f64,
    /// What a rectangular PAD launch of the same steps would have cost
    /// — the baseline `launch_flops` is measured against. The gap is
    /// the pad-FLOP saving the serving report surfaces.
    pub padded_launch_flops: f64,
    /// Time to first token: wall seconds from submission to the first
    /// step on which any of this request's sequences emitted bytes.
    /// Recorded once per request — preemption/resume cannot reset it —
    /// and `None` when no byte was ever emitted (e.g. a time budget
    /// expired before the first step, or the request expired while
    /// still queued).
    pub ttft_secs: Option<f64>,
    /// Prefix-cache / fan-out-sharing economy when this response was
    /// finalized — engine-lifetime totals like `launch_flops`, so a
    /// client (or the load harness) folding responses with `max` sees
    /// the serving period's final tally. All-zero for never-admitted
    /// answers and on servers running `--prefix-cache 0` with no
    /// fan-out sharing.
    pub prefix: PrefixEcho,
    /// Mean per-row draft length over this request's (sequence, step)
    /// observations — under the adaptive policy each sequence runs its
    /// own Algorithm-1 controller, so this is the request's realized γ,
    /// not a batch-global setting. 0 when no speculative step ran.
    pub draft_len_mean: f64,
    /// Draft tokens accepted over draft tokens proposed across this
    /// request's sequences (0 when nothing was drafted).
    pub acceptance_rate: f64,
}

/// Engine-lifetime prefix-reuse counters echoed on every response,
/// read from [`crate::metrics::SchedStats`] at finalize time (the same
/// monotone-echo convention as `Response::rebuckets` /
/// `Response::launch_flops`). `hits + misses == lookups` by
/// construction — the invariant the bench diff hard-checks.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefixEcho {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// KV row copies executed (fan-out sibling shares + cache hits).
    pub row_copies: u64,
    /// Device-equivalent prefill FLOPs the reuse avoided.
    pub saved_flops: f64,
}

impl PrefixEcho {
    fn from_stats(stats: &SchedStats) -> PrefixEcho {
        PrefixEcho {
            lookups: stats.prefix_lookups(),
            hits: stats.prefix_hits,
            misses: stats.prefix_misses,
            evictions: stats.prefix_evictions,
            row_copies: stats.row_copies,
            saved_flops: stats.prefix_saved_flops,
        }
    }
}

/// One per-step progress notification for a streaming request.
#[derive(Debug, Clone)]
pub struct StepEvent {
    /// Index of the sequence within the request's fan-out.
    pub seq: usize,
    /// Text decoded from the bytes this sequence emitted this step.
    pub text_delta: String,
    /// This sequence finished on this step.
    pub done: bool,
}

/// What a submitted request's receiver yields: zero or more step events
/// (streaming requests only), then exactly one `Done`.
#[derive(Debug)]
pub enum Reply {
    Step(StepEvent),
    Done(Result<Response>),
}

enum Msg {
    Job(Request, Sender<Reply>),
    /// On-demand metrics snapshot (`{"cmd":"stats"}` on the wire or
    /// [`Coordinator::stats`]): the worker answers at the next message
    /// drain with [`registry::snapshot`] — the same registry behind the
    /// exit summary line, so the two can never drift.
    Stats(Sender<Json>),
    Shutdown,
}

/// Handle to the serving worker.
pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifacts_root: std::path::PathBuf,
    pub spec: SpecConfig,
    pub batcher: BatcherConfig,
    /// Allow the scheduler to suspend running sequences for
    /// strictly-higher-priority arrivals (`--no-preempt` clears it).
    /// Off, priorities still order the queue but running work always
    /// drains naturally.
    pub preempt: bool,
    /// Compile all needed executables at startup (slower start, no
    /// lazy-compile spikes on the request path). Default true.
    pub prewarm: bool,
    /// Force the host-only stub engine regardless of mode
    /// (`--stub-engine`). Only meaningful for modes with a host-only
    /// execution path — `Stub` (implied) and `Packed` (stub-identical
    /// host compute in the packed layout) — so CI can exercise the
    /// packed serving path on machines without the PJRT binding;
    /// startup rejects other modes, whose device calls could only fail
    /// later and more confusingly. Default false.
    pub stub_engine: bool,
    /// Span recorder shared with the engine batch ([`crate::obs`]).
    /// Disabled by default — recording is then a no-op and the
    /// deterministic-counters contract is untouched. The handle is a
    /// shared ring: clone it before `start()` to export the trace after
    /// shutdown.
    pub tracer: Tracer,
    /// Emit a one-line registry snapshot to stderr every this many
    /// seconds (`--stats-every`). None (default) disables the feed.
    pub stats_every_secs: Option<f64>,
    /// Prompt-prefix cache capacity in entries (`--prefix-cache`).
    /// 0 disables all prefix reuse — no cache lookups, no fan-out
    /// sharing, no cheap-resume preemption bias — restoring the
    /// prefill-everything behavior byte-for-byte (the CI on/off
    /// determinism pin). Default 64.
    pub prefix_cache: usize,
}

/// Block granularity of the prefix-cache keys (bytes): prompts agreeing
/// on every whole 16-byte block share an index entry; correctness stays
/// exact because a hit is only served after full-context donor
/// validation (see [`prefix_cache`]).
pub const PREFIX_BLOCK: usize = 16;

impl CoordinatorConfig {
    pub fn new(artifacts_root: std::path::PathBuf, spec: SpecConfig,
               batcher: BatcherConfig) -> Self {
        CoordinatorConfig {
            artifacts_root,
            spec,
            batcher,
            preempt: true,
            prewarm: true,
            stub_engine: false,
            tracer: Tracer::disabled(),
            stats_every_secs: None,
            prefix_cache: 64,
        }
    }
}

impl Coordinator {
    /// Spawn the worker (builds the PJRT engine inside the thread).
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("bass-engine".into())
            .spawn(move || worker(cfg, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Coordinator { tx, handle: Some(handle) })
    }

    /// Submit a request; the receiver yields step events (if requested)
    /// and then `Reply::Done` as soon as *this* request's sequences
    /// retire — co-batched requests keep running.
    pub fn submit(&self, req: Request) -> Receiver<Reply> {
        let (tx, rx) = channel();
        // A send error means the worker is gone; the receiver will report
        // a disconnect to the caller.
        let _ = self.tx.send(Msg::Job(req, tx));
        rx
    }

    /// Drain a submission's receiver to its final response, discarding
    /// any step events.
    pub fn wait(rx: Receiver<Reply>) -> Result<Response> {
        loop {
            match rx.recv() {
                Ok(Reply::Step(_)) => continue,
                Ok(Reply::Done(r)) => return r,
                Err(_) => return Err(anyhow!("engine thread terminated")),
            }
        }
    }

    /// Convenience: submit and block for the response.
    pub fn generate(&self, req: Request) -> Result<Response> {
        Self::wait(self.submit(req))
    }

    /// On-demand live metrics snapshot ([`registry::snapshot`]): the
    /// scheduler counters/gauges/series plus, when tracing is enabled,
    /// the span summary. Answered at the worker's next message drain —
    /// an idle worker wakes for it immediately.
    pub fn stats(&self) -> Result<Json> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Stats(tx))
            .map_err(|_| anyhow!("engine thread terminated"))?;
        rx.recv().map_err(|_| anyhow!("engine thread terminated"))
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A queued request's payload while the scheduler owns its ordering.
struct PendingJob {
    req: Request,
    reply: Sender<Reply>,
    enqueued: Instant,
    urgency: Urgency,
}

/// A request whose sequences are in the engine batch and/or parked.
struct InFlight {
    reply: Sender<Reply>,
    stream: bool,
    /// live seq id -> index within this request's fan-out (suspended
    /// sequences are keyed by fanout index inside their `ParkedSeq`).
    seq_index: HashMap<SeqId, usize>,
    done: Vec<Option<GenSeq>>,
    remaining: usize,
    /// Fan-out asked for (before any capacity clamp).
    n_requested: usize,
    admitted: Instant,
    queue_secs: f64,
    /// Max co-resident sequences observed while this request had live
    /// sequences in the batch (reported as `Response::batch_size`).
    batch_size: usize,
    urgency: Urgency,
    enqueued: Instant,
    /// Preemption events suffered (reported as `Response::preempted`).
    preempted: usize,
    /// Seconds from submission to the request's first emitted byte, set
    /// exactly once in the event-relay loop. Lives here (not on any
    /// sequence) because the `InFlight` record survives preemption and
    /// resume — the TTFT of a preempted request is still its first
    /// token, not its first token after the resume.
    ttft_secs: Option<f64>,
    /// Draft tokens proposed across this request's sequences, summed in
    /// the event-relay loop from each step's [`crate::spec::SeqEvent`]
    /// (per-row `draft_len`, so co-batched traffic never pollutes it).
    drafted: u64,
    /// Draft tokens accepted across this request's sequences.
    accepted: u64,
    /// (sequence, step) observations behind `drafted` — the divisor for
    /// `Response::draft_len_mean`.
    draft_steps: u64,
}

impl InFlight {
    fn finish(self, queue_depth: usize, rebuckets: u64,
              launch_flops: f64, padded_launch_flops: f64,
              prefix: PrefixEcho) {
        let seqs = self
            .done
            .into_iter()
            .map(|s| s.expect("all sequences retired"))
            .collect();
        let _ = self.reply.send(Reply::Done(Ok(Response {
            seqs,
            n_requested: self.n_requested,
            batch_secs: self.admitted.elapsed().as_secs_f64(),
            batch_size: self.batch_size,
            queue_secs: self.queue_secs,
            preempted: self.preempted,
            queue_depth,
            rebuckets,
            launch_flops,
            padded_launch_flops,
            prefix,
            ttft_secs: self.ttft_secs,
            draft_len_mean: if self.draft_steps > 0 {
                self.drafted as f64 / self.draft_steps as f64
            } else {
                0.0
            },
            acceptance_rate: if self.drafted > 0 {
                self.accepted as f64 / self.drafted as f64
            } else {
                0.0
            },
        })));
    }
}

fn worker(cfg: CoordinatorConfig, rx: Receiver<Msg>,
          ready: Sender<Result<()>>) {
    // A stub-mode coordinator serves without a device: the host-only
    // backend needs no artifacts and nothing to prewarm, so the whole
    // scheduler stack — admission, preemption, re-bucketing, budgets —
    // runs on machines without the PJRT binding (the serving load
    // harness and the CI perf gate drive this path). `--stub-engine`
    // extends the same no-device serving to `Packed`, whose backend
    // has a stub-identical host path.
    if cfg.stub_engine
        && !matches!(cfg.spec.mode, ExecMode::Stub | ExecMode::Packed)
    {
        let _ = ready.send(Err(anyhow!(
            "--stub-engine requires a mode with a host-only execution \
             path (stub or packed); this mode's device calls would only \
             fail mid-serving")));
        return;
    }
    let engine = if cfg.spec.mode == ExecMode::Stub || cfg.stub_engine {
        Engine::stub()
    } else {
        match Engine::load(&cfg.artifacts_root) {
            Ok(e) => e,
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        }
    };
    if cfg.prewarm && !engine.is_stub() {
        let batches: Vec<usize> = engine.manifest.batches.iter().copied()
            .filter(|&b| b <= cfg.batcher.max_batch)
            .collect();
        for b in batches {
            for model in [&cfg.spec.main_model, &cfg.spec.draft_model] {
                if let Err(e) = engine.prewarm(model, cfg.spec.precision, b) {
                    let _ = ready.send(Err(e));
                    return;
                }
            }
        }
    }
    let capacity = cfg.batcher.max_batch.max(1);
    let mut batch = match SpecBatch::new(&engine, cfg.spec.clone(), capacity)
    {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    batch.set_tracer(cfg.tracer.clone());
    let tracer = cfg.tracer.clone();
    let mode = cfg.spec.mode.as_str();
    let _ = ready.send(Ok(()));

    let mut sched = Scheduler::new(SchedulerConfig {
        batcher: cfg.batcher.clone(),
        preempt: cfg.preempt,
        ..SchedulerConfig::default()
    });
    // The prompt-prefix index (see the module docs): populated on
    // admission and suspension, probed before prompt prefills and
    // recompute-resumes. Capacity 0 disables every reuse path.
    let mut pcache = PrefixCache::new(cfg.prefix_cache, PREFIX_BLOCK);
    // Queued payloads (the scheduler owns their ordering) and admitted
    // requests.
    let mut jobs: HashMap<u64, PendingJob> = HashMap::new();
    let mut inflight: HashMap<u64, InFlight> = HashMap::new();
    // live seq id -> owning request id.
    let mut seq_owner: HashMap<SeqId, u64> = HashMap::new();
    let mut next_id = 0u64;
    let mut open = true;
    let mut last_emit = Instant::now();

    while open || !jobs.is_empty() || !inflight.is_empty() {
        // -- pull messages; block only when fully idle ---------------------
        loop {
            let idle = jobs.is_empty() && inflight.is_empty() && open;
            let msg = if idle {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        open = false;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(std::sync::mpsc::TryRecvError::Empty) => break,
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            };
            match msg {
                Msg::Shutdown => {
                    open = false;
                    break;
                }
                Msg::Job(req, reply) => {
                    next_id += 1;
                    let enqueued = Instant::now();
                    let urgency = Urgency {
                        priority: req.priority.unwrap_or(0),
                        deadline: req.deadline_ms.map(|ms| {
                            enqueued + Duration::from_millis(ms)
                        }),
                    };
                    sched.submit(next_id, req.n_seqs.max(1), urgency,
                                 enqueued);
                    jobs.insert(next_id, PendingJob {
                        req,
                        reply,
                        enqueued,
                        urgency,
                    });
                }
                Msg::Stats(tx) => {
                    // Advisory read of the live registry; never touches
                    // the batch, so it cannot perturb the deterministic
                    // counters.
                    let _ = tx.send(registry::snapshot(&sched.stats,
                                                       &tracer));
                }
            }
        }

        // -- periodic stderr snapshot (--stats-every) ----------------------
        if let Some(every) = cfg.stats_every_secs {
            if last_emit.elapsed().as_secs_f64() >= every {
                last_emit = Instant::now();
                let snap = registry::snapshot(&sched.stats, &tracer);
                eprintln!("[bass-engine] stats: {}",
                          snap.to_string_pretty().replace('\n', " "));
            }
        }

        // -- scheduling at the step boundary -------------------------------
        //
        // One wall-clock read drives the whole round: the scheduler's
        // window checks, queue waits and admission timestamps all see the
        // same `now` (the old admit loop re-read the clock per iteration,
        // letting the flush window drift from the plan it gated).
        let now = Instant::now();
        let view: Vec<RunningSeq> = seq_owner
            .iter()
            .map(|(&id, owner)| {
                let urgency = inflight
                    .get(owner)
                    .map(|j| j.urgency)
                    .unwrap_or_default();
                RunningSeq {
                    id,
                    priority: urgency.priority,
                    has_deadline: urgency.deadline.is_some(),
                    preemptible: batch.can_suspend(id),
                }
            })
            .collect();
        let plan = {
            let probe = |desired: usize| batch.rebucket_target(desired);
            let bview = scheduler::BatchView {
                free: batch.free_slots(),
                occupied: batch.occupied(),
                bucket_rows: batch.bucket_rows(),
                rebucket_target: Some(&probe),
                // A started fused bucket keeps a suspended row resident
                // as its own Husk donor, so (cache on) a resume is a
                // row copy, not a prompt recompute — the scheduler may
                // preempt more willingly.
                cheap_resume: pcache.enabled()
                    && batch.bucket_rows().is_some(),
            };
            sched.plan(&bview, &view, now)
        };

        for id in plan.preempt {
            let Some(&owner) = seq_owner.get(&id) else { continue };
            let snap = match batch.suspend(id) {
                Ok(s) => s,
                // can_suspend was checked when the view was built and no
                // step ran since; defensively leave the sequence running.
                Err(_) => continue,
            };
            seq_owner.remove(&id);
            let Some(job) = inflight.get_mut(&owner) else { continue };
            job.preempted += 1;
            // Index the suspended context: in a started fused bucket the
            // freed row survives as a Husk still encoding it, so the
            // resume below can find itself and row-copy instead of
            // recomputing (the index never asserts residency — the
            // lookup re-validates against the live row table).
            if pcache.enabled() {
                sched.stats.prefix_evictions +=
                    pcache.insert(&snap.context()) as u64;
            }
            tracer.instant(SpanKind::Suspend, owner, Some(id), mode, &[]);
            let fanout_index = job.seq_index.remove(&id).unwrap_or(0);
            sched.park(ParkedSeq {
                snapshot: snap,
                owner,
                fanout_index,
                urgency: job.urgency,
                enqueued: job.enqueued,
            });
        }

        let mut resumes = plan.resume;
        if let Some(target) = plan.rebucket {
            // Grow for waiting demand / shrink to the occupancy —
            // executed after preemptions (the victims' husk rows are
            // dropped by the move) and before scatter-resumes and
            // admissions, which land in the new bucket's fresh rows.
            //
            // Resumes planned for the same round **ride the re-bucket**:
            // their contexts are folded into the move's fused prefill
            // ([`SpecBatch::rebucket_resume`]) instead of paying one
            // scatter prefill each right after the bucket was already
            // re-encoded. A rider is taken only while a target bucket
            // provably covers it (`rebucket_target_with` re-probed per
            // rider); the rest fall through to the scatter loop below.
            // Orphans (owner already failed) are left for that loop's
            // own drop-guard.
            let mut riders: Vec<ParkedSeq> = Vec::new();
            let mut rest: Vec<ParkedSeq> = Vec::new();
            for parked in resumes {
                if inflight.contains_key(&parked.owner)
                    && batch
                        .rebucket_target_with(target, riders.len() + 1)
                        .is_some()
                {
                    riders.push(parked);
                } else {
                    rest.push(parked);
                }
            }
            resumes = rest;
            if riders.is_empty() {
                match batch.rebucket(target) {
                    Ok(Some(r)) => {
                        sched.stats.note_rebucket(r.to > r.from,
                                                  r.migrated);
                    }
                    Ok(None) => {} // raced to a no-op; work keeps waiting
                    Err(e) => {
                        // The old bucket survives a failed re-prefill
                        // (the caches are swapped only on success), so
                        // keep serving from it; any resume/admission
                        // this round truly had no row for fails its
                        // request loudly below.
                        eprintln!("[bass-engine] live re-bucket failed; \
                                   keeping the current bucket: {e:#}");
                    }
                }
            } else {
                let metas: Vec<(u64, usize)> = riders
                    .iter()
                    .map(|p| (p.owner, p.fanout_index))
                    .collect();
                let snaps: Vec<SuspendedSeq> =
                    riders.into_iter().map(|p| p.snapshot).collect();
                match batch.rebucket_resume(target, snaps) {
                    Ok((r, ids)) => {
                        sched.stats.note_rebucket(r.to > r.from,
                                                  r.migrated);
                        sched.stats.resumes += metas.len() as u64;
                        for (id, (owner, fanout_index)) in
                            ids.into_iter().zip(metas)
                        {
                            seq_owner.insert(id, owner);
                            tracer.instant(SpanKind::Resume, owner,
                                           Some(id), mode,
                                           &[("rebucket_rider", 1.0)]);
                            if let Some(job) = inflight.get_mut(&owner) {
                                job.seq_index.insert(id, fanout_index);
                            }
                        }
                    }
                    Err(e) => {
                        // The rider snapshots are consumed and their
                        // requests cannot be made whole — fail each
                        // owner loudly (same contract as a scatter
                        // resume failing below). The old bucket
                        // survives (caches swap only on success), so
                        // keep serving everyone else from it.
                        eprintln!("[bass-engine] live re-bucket with {} \
                                   folded resumes failed; keeping the \
                                   current bucket: {e:#}",
                                  metas.len());
                        let owners: HashSet<u64> =
                            metas.iter().map(|&(o, _)| o).collect();
                        for owner in owners {
                            fail_request(&mut batch, owner, &e,
                                         &mut inflight, &mut seq_owner,
                                         &mut sched);
                        }
                    }
                }
            }
        }

        for parked in resumes {
            let owner = parked.owner;
            // A resume failure earlier in this round may have failed the
            // owner already; its remaining snapshots are dead — dropping
            // them here prevents orphan sequences from occupying device
            // slots with nobody waiting on their output.
            if !inflight.contains_key(&owner) {
                continue;
            }
            // Planned against rows that never materialized (the grow
            // failed and the old bucket is still serving): the snapshot
            // is intact — `SpecBatch::resume` never saw it — so re-park
            // it to re-rank next round instead of consuming it against
            // a guaranteed "no row" failure that would kill the request.
            if !batch.can_admit() {
                sched.repark(parked);
                continue;
            }
            let fanout_index = parked.fanout_index;
            // Prefix-cache probe before the recompute: a hit (index
            // entry + live donor row — typically this sequence's own
            // Husk) turns the prompt-length resume prefill into one KV
            // row copy. Miss or cache-off falls through to the bitwise
            // recompute path; either way the resumed bytes are
            // identical, so the choice is invisible to outputs.
            let donor = if pcache.enabled() {
                let ctx = parked.snapshot.context();
                let warm = pcache.lookup(&ctx);
                let d = if warm { batch.donor_row_for(&ctx) } else { None };
                sched.stats.note_prefix_lookup(d.is_some());
                d
            } else {
                None
            };
            let resumed = match donor {
                Some(d) => {
                    let saving = batch.shared_bind_saving();
                    let r = batch.resume_shared(d, parked.snapshot);
                    if r.is_ok() {
                        sched.stats.note_row_copy(saving);
                    }
                    r
                }
                None => batch.resume(parked.snapshot),
            };
            match resumed {
                Ok(id) => {
                    sched.stats.resumes += 1;
                    seq_owner.insert(id, owner);
                    tracer.instant(SpanKind::Resume, owner, Some(id),
                                   mode, &[]);
                    if let Some(job) = inflight.get_mut(&owner) {
                        job.seq_index.insert(id, fanout_index);
                    }
                }
                Err(e) => {
                    // The snapshot is consumed; the request cannot be
                    // made whole — fail it loudly (and abandon its other
                    // sequences) rather than silently dropping output.
                    fail_request(&mut batch, owner, &e, &mut inflight,
                                 &mut seq_owner, &mut sched);
                }
            }
        }

        for rid in plan.admit {
            // Same phantom-row guard as resumes: a request admitted
            // against a grow that failed to execute goes back in the
            // queue (its payload never left `jobs`) rather than
            // hard-failing on "no reusable PAD row". Its queue wait is
            // re-observed on the eventual admission — acceptable drift
            // on a failure path.
            if batch.free_slots() == 0 {
                if let Some(job) = jobs.get(&rid) {
                    sched.submit(rid, job.req.n_seqs.max(1), job.urgency,
                                 job.enqueued);
                }
                continue;
            }
            let Some(job) = jobs.remove(&rid) else { continue };
            if let Some(job) = admit_request(&mut batch, rid, job,
                                             &mut inflight,
                                             &mut seq_owner, now,
                                             &mut pcache,
                                             &mut sched.stats) {
                // Zero free rows by the time the admission executed
                // (e.g. a race with this round's resumes): same
                // phantom-row treatment — back in the queue, payload
                // retained, queue wait re-observed on the eventual
                // admission.
                sched.submit(rid, job.req.n_seqs.max(1), job.urgency,
                             job.enqueued);
                jobs.insert(rid, job);
            } else if let Some(fl) = inflight.get(&rid) {
                // Admitted (a `None` with no inflight entry was a
                // failed admission, already answered).
                tracer.instant(SpanKind::Admit, rid, None, mode, &[
                    ("n_seqs", fl.remaining as f64),
                    ("queue_ms", fl.queue_secs * 1e3),
                ]);
            }
        }
        // Bucket-occupancy gauge: live rows of the fused bucket only —
        // SPLIT and an idle/not-started engine report (0, 0) as the
        // SchedStats contract promises.
        match batch.bucket_rows() {
            Some(rows) => sched.stats.note_bucket(batch.active(), rows),
            None => sched.stats.note_bucket(0, 0),
        }

        // Per-request time budget (Fig-5 semantics): a request whose age
        // since *its own admission* exceeds the budget is answered as-is,
        // possibly unfinished — including any sequences currently parked
        // (their snapshots are reported without resuming; suspended time
        // counts against the budget, matching `Response::batch_secs`).
        if let Some(budget) = cfg.spec.time_budget_secs {
            let expired: Vec<u64> = inflight
                .iter()
                .filter(|(_, j)| {
                    j.admitted.elapsed().as_secs_f64() >= budget
                })
                .map(|(&id, _)| id)
                .collect();
            for owner in expired {
                tracer.instant(SpanKind::Expire, owner, None, mode, &[]);
                let queue_depth = sched.queue_depth();
                let rebuckets = sched.stats.rebuckets();
                let flops = (batch.flops.launch,
                             batch.flops.padded_launch);
                let prefix = PrefixEcho::from_stats(&sched.stats);
                let ids: Vec<SeqId> = seq_owner
                    .iter()
                    .filter(|(_, &o)| o == owner)
                    .map(|(&id, _)| id)
                    .collect();
                for id in ids {
                    retire_seq(&mut batch, id, &mut inflight,
                               &mut seq_owner, queue_depth, rebuckets,
                               flops, prefix, &tracer, mode);
                }
                for parked in sched.take_parked_of(owner) {
                    deliver_parked(parked, &mut inflight, queue_depth,
                                   rebuckets, flops, prefix);
                }
            }
            expire_queued_jobs(budget, &mut jobs, &mut sched, &tracer,
                               mode);
        }

        if !batch.has_active() {
            if batch.occupied() > 0 {
                // Defensive: sequences stalled in any other way are
                // returned rather than wedging their requests forever.
                let queue_depth = sched.queue_depth();
                let rebuckets = sched.stats.rebuckets();
                let flops = (batch.flops.launch,
                             batch.flops.padded_launch);
                let prefix = PrefixEcho::from_stats(&sched.stats);
                let ids: Vec<SeqId> = seq_owner.keys().copied().collect();
                for id in ids {
                    retire_seq(&mut batch, id, &mut inflight,
                               &mut seq_owner, queue_depth, rebuckets,
                               flops, prefix, &tracer, mode);
                }
            } else if sched.has_queued() || sched.parked_count() > 0 {
                // Waiting out the co-batching window (or a transiently
                // unplaceable parked set).
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            continue;
        }

        // -- one speculative step ------------------------------------------
        let occupied = batch.occupied();
        let live_owners: HashSet<u64> = seq_owner.values().copied().collect();
        for (id, job) in inflight.iter_mut() {
            // Only requests with live sequences observe the co-residency;
            // a fully parked request is not sharing the batch right now.
            if live_owners.contains(id) {
                job.batch_size = job.batch_size.max(occupied);
            }
        }
        let report = match batch.step() {
            Ok(r) => r,
            Err(e) => {
                // The device state is suspect: fail everything in flight
                // (parked snapshots included — their owners are gone) and
                // start over with a fresh batch.
                let msg = format!("{e:#}");
                for (_, job) in inflight.drain() {
                    let _ = job.reply
                        .send(Reply::Done(Err(anyhow!("{msg}"))));
                }
                seq_owner.clear();
                sched.clear_parked();
                match SpecBatch::new(&engine, cfg.spec.clone(), capacity) {
                    Ok(b) => batch = b,
                    Err(e2) => {
                        for rid in sched.drain_queued() {
                            if let Some(j) = jobs.remove(&rid) {
                                let _ = j.reply.send(
                                    Reply::Done(Err(anyhow!("{e2:#}"))));
                            }
                        }
                        return;
                    }
                }
                continue;
            }
        };

        // -- record TTFT, draft economy, and streaming events --------------
        for ev in &report.events {
            // Engine-wide draft-length economy (per-row: each event
            // carries its own sequence's k_i and accepted count).
            sched.stats.observe_draft(ev.draft_len, ev.accepted);
            let Some(&owner) = seq_owner.get(&ev.id) else { continue };
            let Some(job) = inflight.get_mut(&owner) else { continue };
            job.drafted += ev.draft_len as u64;
            job.accepted += ev.accepted as u64;
            job.draft_steps += 1;
            // Per-sequence step marker on the owning request's trace
            // lane: this row's own draft length and acceptance, never
            // the batch-global launch width.
            tracer.instant(SpanKind::SeqStep, owner, Some(ev.id), mode,
                           &[("k_i", ev.draft_len as f64),
                             ("accepted", ev.accepted as f64)]);
            if !ev.new_bytes.is_empty() && job.ttft_secs.is_none() {
                // First emitted byte of the whole request (any fan-out
                // sequence), measured from submission. Set once: later
                // events — including post-resume ones — cannot move it.
                job.ttft_secs =
                    Some(job.enqueued.elapsed().as_secs_f64());
            }
            if job.stream && (!ev.new_bytes.is_empty() || ev.done) {
                let _ = job.reply.send(Reply::Step(StepEvent {
                    seq: job.seq_index[&ev.id],
                    text_delta: crate::tokenizer::decode(&ev.new_bytes),
                    done: ev.done,
                }));
            }
        }

        // -- retire finished sequences immediately -------------------------
        let queue_depth = sched.queue_depth();
        let rebuckets = sched.stats.rebuckets();
        let flops = (batch.flops.launch, batch.flops.padded_launch);
        let prefix = PrefixEcho::from_stats(&sched.stats);
        for id in report.finished {
            retire_seq(&mut batch, id, &mut inflight, &mut seq_owner,
                       queue_depth, rebuckets, flops, prefix, &tracer,
                       mode);
        }
    }

    // Serving-period scheduler summary: one stderr line at worker exit,
    // next to the server's other diagnostics — preemption/resume volume
    // and per-priority queue waits are fleet-tuning signals (window,
    // max_batch, pad_headroom). The line is a formatted *view* of the
    // same [`crate::metrics::SchedStats`] registry the `stats` command
    // snapshots, so the two can never drift.
    if let Some(line) = sched.stats.summary_line() {
        eprintln!("[bass-engine] scheduler: {line}");
    }
}

/// Admit one planned request: fan-out into free slots (clamped to the
/// batch capacity), per-sequence overrides threaded through
/// [`AdmitOpts`]. A partial admission failure rolls the request back and
/// fails it. Zero free slots hands the payload back (`Some`) for the
/// caller to re-queue — admitting a fan-out "clamped to 1" against a
/// full batch could only fail the whole request on a row that was never
/// there.
///
/// Prefix reuse: the prompt runs **at most one** prefill. The first
/// sequence binds by row copy when the prefix cache validates a
/// resident donor (a counted hit), by prefill otherwise; every later
/// fan-out sibling then row-copies from the donor the first one
/// established ([`SpecBatch::donor_row_for`] — in a started batch that
/// is at worst the first sibling's own row; in a not-yet-started fused
/// batch the probe stays `None` and the lazy fused start encodes all
/// rows in its single rectangle prefill anyway). Each executed copy is
/// counted and credited with the sibling prefill it replaced.
#[allow(clippy::too_many_arguments)]
fn admit_request(batch: &mut SpecBatch, rid: u64, job: PendingJob,
                 inflight: &mut HashMap<u64, InFlight>,
                 seq_owner: &mut HashMap<SeqId, u64>, now: Instant,
                 pcache: &mut PrefixCache, stats: &mut SchedStats)
                 -> Option<PendingJob> {
    let default_seed = batch.config().seed;
    let n_requested = job.req.n_seqs.max(1);
    let free = batch.free_slots();
    if free == 0 {
        return Some(job);
    }
    let n = n_requested.min(free);
    let queue_secs = now.duration_since(job.enqueued).as_secs_f64();
    let seed = job.req.seed.unwrap_or(default_seed);
    let mut fl = InFlight {
        reply: job.reply,
        stream: job.req.stream,
        seq_index: HashMap::new(),
        done: (0..n).map(|_| None).collect(),
        remaining: n,
        n_requested,
        admitted: now,
        queue_secs,
        batch_size: n,
        urgency: job.urgency,
        enqueued: job.enqueued,
        preempted: 0,
        ttft_secs: None,
        drafted: 0,
        accepted: 0,
        draft_steps: 0,
    };
    // One counted cache probe per request (the fan-out shares one
    // prompt): a hit means a resident donor row validated against the
    // full context, so even the *first* sequence binds by row copy.
    let mut donor = if pcache.enabled() {
        let warm = pcache.lookup(&job.req.prompt);
        let d = if warm {
            batch.donor_row_for(&job.req.prompt)
        } else {
            None
        };
        stats.note_prefix_lookup(d.is_some());
        d
    } else {
        None
    };
    let mut failed = None;
    for i in 0..n {
        // A pinned per-request seed also pins the RNG stream to the
        // fan-out index, so {prompt, seed} reproduces the same output
        // regardless of prior traffic (exact under Policy::Fixed; see
        // Request::seed).
        let stream = job.req.seed.map(|_| i as u64);
        let opts = AdmitOpts {
            max_new_tokens: job.req.max_new_tokens,
            stream,
            temperature: job.req.temperature,
            top_p: job.req.top_p,
        };
        let admitted = match donor {
            Some(d) => {
                let saving = batch.shared_bind_saving();
                let r = batch.admit_shared_opts(d, &job.req.prompt, seed,
                                                opts);
                if r.is_ok() {
                    stats.note_row_copy(saving);
                }
                r
            }
            None => batch.admit_opts(&job.req.prompt, seed, opts),
        };
        match admitted {
            Ok(id) => {
                fl.seq_index.insert(id, i);
                seq_owner.insert(id, rid);
                if pcache.enabled() && donor.is_none() {
                    // Fan-out sharing: once the first sibling has a
                    // row, the rest copy from it (the probe is `None`
                    // in a not-yet-started fused batch — there the lazy
                    // fused start covers every row at once).
                    donor = batch.donor_row_for(&job.req.prompt);
                }
            }
            Err(e) => {
                failed = Some(e);
                break;
            }
        }
    }
    if let Some(e) = failed {
        // Roll back this job's partial admissions and fail it.
        for &id in fl.seq_index.keys() {
            let _ = batch.retire(id);
            seq_owner.remove(&id);
        }
        let _ = fl.reply.send(Reply::Done(Err(e)));
        return None;
    }
    if pcache.enabled() {
        // Index the admitted prompt for later repeat-prefix arrivals
        // (their lookups re-validate a live donor before trusting it).
        stats.prefix_evictions += pcache.insert(&job.req.prompt) as u64;
    }
    inflight.insert(rid, fl);
    None
}

/// A budgeted request can expire while **still queued** (open-loop
/// overload): it was never admitted, so the inflight budget sweep cannot
/// see it, and before this sweep existed it would wedge in the queue
/// until capacity freed — long after its budget made the answer useless
/// — and then burn a full generation's compute on output nobody was
/// waiting for. Answer it as-is at the step boundary: the full requested
/// fan-out of empty, unfinished sequences — the same "budget ran out"
/// shape an admitted-but-unfinished request reports. Its age runs from
/// submission (there is no admission timestamp).
fn expire_queued_jobs(budget: f64, jobs: &mut HashMap<u64, PendingJob>,
                      sched: &mut Scheduler, tracer: &Tracer,
                      mode: &'static str) {
    let expired_queued: Vec<u64> = jobs
        .iter()
        .filter(|(_, j)| j.enqueued.elapsed().as_secs_f64() >= budget)
        .map(|(&id, _)| id)
        .collect();
    for rid in expired_queued {
        if !sched.remove_queued(rid) {
            // Not in the queue: planned/admitted this round. The
            // inflight sweep answers it at the next boundary.
            continue;
        }
        let Some(job) = jobs.remove(&rid) else { continue };
        tracer.instant(SpanKind::Expire, rid, None, mode,
                       &[("queued", 1.0)]);
        let n = job.req.n_seqs.max(1);
        let _ = job.reply.send(Reply::Done(Ok(Response {
            seqs: (0..n)
                .map(|_| GenSeq {
                    text: String::new(),
                    finished: false,
                    // 0.0, not mean_logp()'s -inf for an empty
                    // sequence: -inf does not survive the JSON wire
                    // format.
                    mean_logp: 0.0,
                    n_tokens: 0,
                })
                .collect(),
            n_requested: n,
            batch_secs: 0.0,
            batch_size: 0,
            queue_secs: job.enqueued.elapsed().as_secs_f64(),
            preempted: 0,
            queue_depth: sched.queue_depth(),
            rebuckets: sched.stats.rebuckets(),
            // Never admitted: this request drove no launches.
            launch_flops: 0.0,
            padded_launch_flops: 0.0,
            // Engine-lifetime echo like `rebuckets`, so even a
            // queue-expired answer carries the serving period's tally.
            prefix: PrefixEcho::from_stats(&sched.stats),
            ttft_secs: None,
            draft_len_mean: 0.0,
            acceptance_rate: 0.0,
        })));
    }
}

/// Move one finished (or budget-stalled) sequence out of the batch and
/// into its request's response; answer the request when it was the last.
/// `flops` is the engine-lifetime (launch, padded_launch) pair read at
/// the step boundary.
#[allow(clippy::too_many_arguments)]
fn retire_seq(batch: &mut SpecBatch, id: SeqId,
              inflight: &mut HashMap<u64, InFlight>,
              seq_owner: &mut HashMap<SeqId, u64>, queue_depth: usize,
              rebuckets: u64, flops: (f64, f64), prefix: PrefixEcho,
              tracer: &Tracer, mode: &'static str) {
    let Some(owner) = seq_owner.remove(&id) else { return };
    let state = match batch.retire(id) {
        Ok(s) => s,
        Err(_) => return,
    };
    tracer.instant(SpanKind::Retire, owner, Some(id), mode, &[]);
    let Some(job) = inflight.get_mut(&owner) else { return };
    let idx = job.seq_index[&id];
    job.done[idx] = Some(GenSeq {
        text: crate::tokenizer::decode(&state.generated),
        finished: state.finish != FinishReason::Running,
        mean_logp: state.mean_logp(),
        n_tokens: state.tokens_generated(),
    });
    job.remaining -= 1;
    if job.remaining == 0 {
        let job = inflight.remove(&owner).expect("job present");
        job.finish(queue_depth, rebuckets, flops.0, flops.1, prefix);
    }
}

/// Answer one parked (still suspended) sequence as-is from its snapshot —
/// the time-budget path for preempted work that never got to resume.
fn deliver_parked(parked: ParkedSeq,
                  inflight: &mut HashMap<u64, InFlight>,
                  queue_depth: usize, rebuckets: u64,
                  flops: (f64, f64), prefix: PrefixEcho) {
    let owner = parked.owner;
    let Some(job) = inflight.get_mut(&owner) else { return };
    let state = parked.snapshot.into_state();
    job.done[parked.fanout_index] = Some(GenSeq {
        text: crate::tokenizer::decode(&state.generated),
        finished: false, // suspended mid-generation by definition
        mean_logp: state.mean_logp(),
        n_tokens: state.tokens_generated(),
    });
    job.remaining -= 1;
    if job.remaining == 0 {
        let job = inflight.remove(&owner).expect("job present");
        job.finish(queue_depth, rebuckets, flops.0, flops.1, prefix);
    }
}

/// Fail one in-flight request outright: abandon its live sequences, drop
/// its parked snapshots, send the error.
fn fail_request(batch: &mut SpecBatch, owner: u64, err: &anyhow::Error,
                inflight: &mut HashMap<u64, InFlight>,
                seq_owner: &mut HashMap<SeqId, u64>,
                sched: &mut Scheduler) {
    let Some(job) = inflight.remove(&owner) else { return };
    let ids: Vec<SeqId> = job.seq_index.keys().copied().collect();
    for id in ids {
        let _ = batch.retire(id);
        seq_owner.remove(&id);
    }
    let _ = sched.take_parked_of(owner);
    let _ = job.reply.send(Reply::Done(Err(anyhow!("{err:#}"))));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::Policy;

    #[test]
    fn zero_slot_admission_hands_the_job_back_for_requeue() {
        let engine = Engine::stub();
        let spec = SpecConfig {
            mode: ExecMode::Stub,
            policy: Policy::Fixed(2),
            max_new_tokens: 64,
            ..SpecConfig::default()
        };
        let mut batch = SpecBatch::new(&engine, spec, 1).unwrap();
        batch.admit(b"occupy", 1).unwrap();
        batch.step().unwrap(); // lazy start: a bucket of 1, fully live
        assert_eq!(batch.free_slots(), 0);
        let (tx, rx) = channel::<Reply>();
        let now = Instant::now();
        let job = PendingJob {
            req: Request {
                prompt: b"queued".to_vec(),
                n_seqs: 2,
                max_new_tokens: None,
                temperature: None,
                top_p: None,
                seed: None,
                priority: None,
                deadline_ms: None,
                stream: false,
            },
            reply: tx,
            enqueued: now,
            urgency: Urgency { priority: 0, deadline: None },
        };
        let mut inflight = HashMap::new();
        let mut seq_owner = HashMap::new();
        let mut pcache = PrefixCache::new(0, PREFIX_BLOCK);
        let mut stats = SchedStats::default();
        let back = admit_request(&mut batch, 7, job, &mut inflight,
                                 &mut seq_owner, now, &mut pcache,
                                 &mut stats);
        // The old clamp `free_slots().max(1)` admitted one sequence
        // against the full batch, which failed the whole request on a
        // row that was never there; the payload must instead come back
        // intact for the caller to re-queue.
        assert!(back.is_some(), "zero slots: hand the job back");
        assert!(inflight.is_empty());
        assert!(seq_owner.is_empty());
        assert!(rx.try_recv().is_err(), "no answer, no error: re-queued");
    }

    fn queued_job(n_seqs: usize, enqueued: Instant)
                  -> (PendingJob, Receiver<Reply>) {
        let (tx, rx) = channel::<Reply>();
        (PendingJob {
            req: Request {
                prompt: b"overload".to_vec(),
                n_seqs,
                max_new_tokens: None,
                temperature: None,
                top_p: None,
                seed: None,
                priority: None,
                deadline_ms: None,
                stream: false,
            },
            reply: tx,
            enqueued,
            urgency: Urgency { priority: 0, deadline: None },
        }, rx)
    }

    /// The budget-expiry bugfix: the sweep used to scan only `inflight`,
    /// so a request whose budget ran out while it was **still queued**
    /// was admitted anyway once capacity freed and burned a full
    /// generation on an answer nobody could use. Expired queued jobs
    /// must instead be answered as-is from the queue: the full
    /// requested fan-out of empty unfinished sequences, no TTFT, never
    /// admitted.
    #[test]
    fn expired_queued_jobs_are_answered_without_admission() {
        let mut sched = Scheduler::new(SchedulerConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                window: Duration::from_millis(0),
            },
            ..SchedulerConfig::default()
        });
        let now = Instant::now();
        let stale = now - Duration::from_secs(1);
        let (expired, rx_expired) = queued_job(3, stale);
        let (fresh, rx_fresh) = queued_job(1, now);
        let mut jobs = HashMap::new();
        sched.submit(1, 3, expired.urgency, stale);
        jobs.insert(1u64, expired);
        sched.submit(2, 1, fresh.urgency, now);
        jobs.insert(2u64, fresh);

        expire_queued_jobs(0.5, &mut jobs, &mut sched,
                           &Tracer::disabled(), "stub");

        // The stale job is gone from both the payload map and the
        // scheduler queue, and answered with its full fan-out of empty
        // unfinished sequences.
        assert!(!jobs.contains_key(&1));
        assert!(jobs.contains_key(&2), "fresh job must stay queued");
        match rx_expired.try_recv() {
            Ok(Reply::Done(Ok(resp))) => {
                assert_eq!(resp.seqs.len(), 3);
                assert_eq!(resp.n_requested, 3);
                assert!(resp.seqs.iter().all(|s| {
                    !s.finished && s.n_tokens == 0 && s.text.is_empty()
                }));
                assert_eq!(resp.batch_size, 0, "never admitted");
                assert!(resp.ttft_secs.is_none(), "no byte was emitted");
                assert!(resp.queue_secs >= 0.5, "aged in the queue");
            }
            other => panic!("expected an empty response, got {other:?}"),
        }
        assert!(rx_fresh.try_recv().is_err(),
                "the unexpired job must not be answered");
        // The scheduler still ranks exactly the fresh job.
        assert_eq!(sched.queue_depth(), 1);
    }

    /// Admission-side prefix reuse on the stub backend: a cache-warm
    /// prompt with a resident Husk donor admits its whole fan-out by
    /// row copies — zero prompt prefills — and the stats ledger shows
    /// one counted hit, one copy per admitted sequence, and positive
    /// saved FLOPs. With the cache disabled the same admission runs
    /// the plain prefill path and touches no prefix counter.
    #[test]
    fn warm_prompt_fanout_admits_by_row_copies() {
        let engine = Engine::stub();
        let spec = SpecConfig {
            mode: ExecMode::Stub,
            policy: Policy::Fixed(2),
            max_new_tokens: 64,
            ..SpecConfig::default()
        };
        let mut batch = SpecBatch::new(&engine, spec, 4).unwrap();
        // Start a fused bucket with the shared prompt resident, then
        // retire it: its row freezes into a Husk still encoding the
        // context — the residency the cache trades on.
        let warm = batch.admit(b"shared system prompt", 7).unwrap();
        let bystander = batch.admit(b"bystander A", 8).unwrap();
        batch.admit(b"bystander B", 9).unwrap();
        batch.step().unwrap(); // lazy start: bucket of 4, one Shadow
        batch.retire(warm).unwrap();

        let (tx, _rx) = channel::<Reply>();
        let now = Instant::now();
        let job = PendingJob {
            req: Request {
                prompt: b"shared system prompt".to_vec(),
                n_seqs: 2,
                max_new_tokens: None,
                temperature: None,
                top_p: None,
                seed: None,
                priority: None,
                deadline_ms: None,
                stream: false,
            },
            reply: tx,
            enqueued: now,
            urgency: Urgency { priority: 0, deadline: None },
        };
        let mut inflight = HashMap::new();
        let mut seq_owner = HashMap::new();
        let mut pcache = PrefixCache::new(8, PREFIX_BLOCK);
        pcache.insert(b"shared system prompt"); // warmed by earlier admit
        let mut stats = SchedStats::default();
        let back = admit_request(&mut batch, 42, job, &mut inflight,
                                 &mut seq_owner, now, &mut pcache,
                                 &mut stats);
        assert!(back.is_none(), "admitted");
        assert_eq!(seq_owner.len(), 2, "full fan-out placed");
        assert_eq!(stats.prefix_hits, 1, "one counted probe per request");
        assert_eq!(stats.prefix_misses, 0);
        assert_eq!(stats.row_copies, 2, "every sibling bound by copy");
        assert!(stats.prefix_saved_flops > 0.0);
        // The engine charged copies, never a scatter prefill, for this
        // admission: both siblings' share is exactly 2 copies per model.
        let copy = crate::flops::row_copy_flops(
            engine.manifest.model("main").unwrap());
        assert!(copy > 0.0);

        // Cold path (cache off): same shape, no prefix bookkeeping.
        batch.retire(bystander).unwrap(); // free one Husk row
        let (tx2, _rx2) = channel::<Reply>();
        let job2 = PendingJob {
            req: Request {
                prompt: b"shared system prompt".to_vec(),
                n_seqs: 1,
                max_new_tokens: None,
                temperature: None,
                top_p: None,
                seed: None,
                priority: None,
                deadline_ms: None,
                stream: false,
            },
            reply: tx2,
            enqueued: now,
            urgency: Urgency { priority: 0, deadline: None },
        };
        let mut off = PrefixCache::new(0, PREFIX_BLOCK);
        let mut stats_off = SchedStats::default();
        let back2 = admit_request(&mut batch, 43, job2, &mut inflight,
                                  &mut seq_owner, now, &mut off,
                                  &mut stats_off);
        assert!(back2.is_none(), "admitted on the plain path");
        assert_eq!(stats_off.prefix_lookups(), 0);
        assert_eq!(stats_off.row_copies, 0);
        assert_eq!(stats_off.prefix_saved_flops, 0.0);
    }
}
