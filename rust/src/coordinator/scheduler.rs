//! Preemptive priority scheduler — the coordinator's admission brain.
//!
//! The FIFO free-slot batcher ([`super::batcher`]) decided *how many*
//! queued requests fit; under load that let a long-running batch starve a
//! latency-sensitive arrival until something retired ("The Synergy of
//! Speculative Decoding and Batching", arXiv:2310.18813, shows the
//! speculative-batching sweet spot shifts with load — batch composition
//! must be the server's decision; MagicDec, arXiv:2408.11049, frames the
//! per-request latency/throughput tradeoff that motivates priorities).
//! This module ranks **all** waiting work — queued requests *and*
//! suspended sequences — and may **preempt** running work to serve it:
//!
//! * Every request carries an [`Urgency`]: a wire `priority` (higher
//!   runs first; default 0) and an optional `deadline` that orders work
//!   *within* a priority class (earliest first; no-deadline work sorts
//!   after deadlined work of the same class). Ties fall back to FIFO by
//!   enqueue time, which also makes a resumed sequence naturally outrank
//!   later arrivals of its own class.
//! * At each step boundary the coordinator calls [`Scheduler::plan`]
//!   with the batch's free slots and a view of the running sequences.
//!   The plan may (a) **preempt** running sequences — only for
//!   *strictly* higher-priority waiting work, lowest-priority victims
//!   first, and only victims `SpecBatch::can_suspend` accepts — then
//!   (b) **resume** parked sequences, and (c) **admit** queued requests.
//!   Preemption is progressive: when the top waiting item needs more
//!   slots than eligible victims can free, the freed slots are held for
//!   it (head-of-line in rank order) and the batch drains toward it.
//! * The FIFO batcher survives as the *policy the scheduler consults*
//!   for (c): [`plan_batch`] keeps the atomic-fan-out and
//!   oversized-head clamp semantics over the **rank-ordered** queue, and
//!   [`should_flush`] keeps the co-batching window — evaluated exactly
//!   once per round against a single `now`, so the window check cannot
//!   drift between call sites. A round that already preempted or
//!   resumed skips the window (work is flowing; holding the head back
//!   would buy no batching).
//!
//! Suspended sequences live on the **host** (a [`SuspendedSeq`] is a few
//! hundred bytes; resume recomputes the KV row), so the scheduler may
//! hold arbitrarily more admitted work than the engine has device slots
//! — the `capacity = max_batch` bound applies to *running* work only.
//!
//! Starvation: a preempted sequence resumes as soon as rank order allows
//! (its original enqueue time keeps its FIFO position within its class);
//! under sustained strictly-higher-priority load it waits indefinitely —
//! there is deliberately no aging in this version. Running work is never
//! preempted by *equal*-priority arrivals, so default-priority traffic
//! cannot thrash.

use std::time::Instant;

use crate::metrics::SchedStats;
use crate::spec::{SeqId, SuspendedSeq};

use super::batcher::{plan_batch, should_flush, BatcherConfig, Pending};

/// Scheduling class of one request: wire `priority` (higher runs first)
/// plus an optional soft deadline ordering work within the class.
#[derive(Debug, Clone, Copy, Default)]
pub struct Urgency {
    pub priority: i32,
    pub deadline: Option<Instant>,
}

/// Rank order: priority descending, then deadline ascending (deadlined
/// work before undeadlined within a class), then FIFO by enqueue time.
/// `Less` means "runs first".
fn rank(a: (&Urgency, Instant), b: (&Urgency, Instant))
        -> std::cmp::Ordering {
    use std::cmp::Ordering;
    b.0.priority
        .cmp(&a.0.priority)
        .then_with(|| match (a.0.deadline, b.0.deadline) {
            (Some(x), Some(y)) => x.cmp(&y),
            (Some(_), None) => Ordering::Less,
            (None, Some(_)) => Ordering::Greater,
            (None, None) => Ordering::Equal,
        })
        .then_with(|| a.1.cmp(&b.1))
}

/// One queued (not yet admitted) request.
#[derive(Debug)]
struct QueuedReq {
    id: u64,
    n_seqs: usize,
    urgency: Urgency,
    enqueued: Instant,
}

/// A preempted sequence parked host-side, with everything the
/// coordinator needs to re-wire it on resume.
#[derive(Debug)]
pub struct ParkedSeq {
    /// The engine snapshot `SpecBatch::resume` consumes.
    pub snapshot: SuspendedSeq,
    /// Owning request id.
    pub owner: u64,
    /// Index within the owner's fan-out (step events / response slot).
    pub fanout_index: usize,
    pub urgency: Urgency,
    /// The owner's original enqueue time — the FIFO tie-break that makes
    /// resumed work outrank later arrivals of the same class.
    pub enqueued: Instant,
}

/// The scheduler's read-only view of one running sequence.
#[derive(Debug, Clone, Copy)]
pub struct RunningSeq {
    pub id: SeqId,
    /// The owning request's priority.
    pub priority: i32,
    /// `SpecBatch::can_suspend(id)` — live, generating, and exactly
    /// resumable (context still fits the prefill capacity).
    pub preemptible: bool,
}

/// One admission/preemption decision round, in execution order.
#[derive(Debug, Default)]
pub struct SchedPlan {
    /// Running sequences to `SpecBatch::suspend`, weakest victims first.
    pub preempt: Vec<SeqId>,
    /// Parked sequences to `SpecBatch::resume`, rank order.
    pub resume: Vec<ParkedSeq>,
    /// Queued request ids to admit, rank order.
    pub admit: Vec<u64>,
}

impl SchedPlan {
    pub fn is_empty(&self) -> bool {
        self.preempt.is_empty() && self.resume.is_empty()
            && self.admit.is_empty()
    }
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// The FIFO batching policy the scheduler consults for queued
    /// admissions (atomic fan-out, oversized-head clamp, co-batch
    /// window).
    pub batcher: BatcherConfig,
    /// Allow suspending running sequences for strictly-higher-priority
    /// arrivals. Off, the scheduler still ranks the queue but running
    /// work always drains naturally.
    pub preempt: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { batcher: BatcherConfig::default(), preempt: true }
    }
}

/// The scheduler: owns the waiting sets (queued requests, parked
/// sequences) and the serving counters; the coordinator owns request
/// payloads and executes the plans.
pub struct Scheduler {
    cfg: SchedulerConfig,
    queue: Vec<QueuedReq>,
    parked: Vec<ParkedSeq>,
    pub stats: SchedStats,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            cfg,
            queue: Vec::new(),
            parked: Vec::new(),
            stats: SchedStats::default(),
        }
    }

    /// Enqueue a request (the coordinator keeps its payload).
    pub fn submit(&mut self, id: u64, n_seqs: usize, urgency: Urgency,
                  enqueued: Instant) {
        self.queue.push(QueuedReq {
            id,
            n_seqs: n_seqs.max(1),
            urgency,
            enqueued,
        });
        let depth = self.queue.len();
        self.stats.note_depth(depth);
    }

    /// Park a suspended sequence (after a successful
    /// `SpecBatch::suspend`).
    pub fn park(&mut self, seq: ParkedSeq) {
        self.stats.preemptions += 1;
        self.parked.push(seq);
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn has_queued(&self) -> bool {
        !self.queue.is_empty()
    }

    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }

    /// Remove and return every parked sequence of one request (budget
    /// expiry or request failure: the owner is answered/failed as-is).
    pub fn take_parked_of(&mut self, owner: u64) -> Vec<ParkedSeq> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.parked.len() {
            if self.parked[i].owner == owner {
                out.push(self.parked.remove(i));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Drop every parked sequence (batch-fatal error recovery: their
    /// owners have already been failed).
    pub fn clear_parked(&mut self) {
        self.parked.clear();
    }

    /// Drain the queue, returning the ids (shutdown-with-error path).
    pub fn drain_queued(&mut self) -> Vec<u64> {
        let ids = self.queue.iter().map(|q| q.id).collect();
        self.queue.clear();
        self.stats.note_depth(0);
        ids
    }

    fn sort(&mut self) {
        self.queue.sort_by(
            |a, b| rank((&a.urgency, a.enqueued), (&b.urgency, b.enqueued)));
        self.parked.sort_by(
            |a, b| rank((&a.urgency, a.enqueued), (&b.urgency, b.enqueued)));
    }

    /// Merged (priority, slots-needed) of all waiting work, best rank
    /// first — the preemption planner's view of demand.
    fn waiting_in_rank_order(&self) -> Vec<(i32, usize)> {
        let mut items: Vec<(Urgency, Instant, usize)> = self
            .parked
            .iter()
            .map(|p| (p.urgency, p.enqueued, 1))
            .chain(self.queue.iter().map(|q| (q.urgency, q.enqueued,
                                              q.n_seqs)))
            .collect();
        items.sort_by(|a, b| rank((&a.0, a.1), (&b.0, b.1)));
        items.into_iter().map(|(u, _, n)| (u.priority, n)).collect()
    }

    /// One decision round at a step boundary. `free` is the batch's free
    /// slots, `running` the live sequences. `now` is read **once** by
    /// the caller and threaded through every window check, so the
    /// head-of-line co-batching window cannot be re-evaluated against a
    /// drifting wall clock within one round (it used to be read in two
    /// places per admission loop).
    pub fn plan(&mut self, free: usize, running: &[RunningSeq],
                now: Instant) -> SchedPlan {
        self.sort();
        let mut plan = SchedPlan::default();
        let max_batch = self.cfg.batcher.max_batch.max(1);
        let mut avail = free;

        // -- preemption: free slots for strictly-higher-priority work ------
        if self.cfg.preempt
            && !(self.queue.is_empty() && self.parked.is_empty())
        {
            let mut victims: Vec<(SeqId, i32)> = running
                .iter()
                .filter(|r| r.preemptible)
                .map(|r| (r.id, r.priority))
                .collect();
            victims.sort_by_key(|&(_, p)| p); // weakest first
            let mut vi = 0;
            let mut ahead = avail;
            for (pri, need) in self.waiting_in_rank_order() {
                let need = need.min(max_batch);
                while ahead < need
                    && vi < victims.len()
                    && victims[vi].1 < pri
                {
                    plan.preempt.push(victims[vi].0);
                    vi += 1;
                    ahead += 1;
                }
                if ahead >= need {
                    ahead -= need;
                } else {
                    break; // head-of-line in rank order: hold freed slots
                }
            }
            avail += plan.preempt.len();
        }

        // -- resume parked work, unless the queue head outranks it ---------
        while avail > 0 {
            let Some(p) = self.parked.first() else { break };
            if let Some(q) = self.queue.first() {
                if rank((&q.urgency, q.enqueued), (&p.urgency, p.enqueued))
                    .is_lt()
                {
                    break; // a queued request runs first; re-rank next round
                }
            }
            let p = self.parked.remove(0);
            // `stats.resumes` is NOT bumped here: the executor counts a
            // resume only after `SpecBatch::resume` succeeds (mirroring
            // `park`, which counts after a successful suspend), so the
            // counters never drift from what actually ran.
            plan.resume.push(p);
            avail -= 1;
        }

        // -- queued admission through the batcher policy -------------------
        let pendings: Vec<Pending> = self
            .queue
            .iter()
            .map(|q| Pending {
                request_id: q.id,
                n_seqs: q.n_seqs,
                enqueued: q.enqueued,
            })
            .collect();
        let flush = !plan.preempt.is_empty() || !plan.resume.is_empty()
            || should_flush(&pendings, avail, &self.cfg.batcher, now);
        if flush {
            let (n_take, _) = plan_batch(&pendings, avail, &self.cfg.batcher);
            for q in self.queue.drain(..n_take) {
                self.stats.observe_wait(
                    q.urgency.priority,
                    now.duration_since(q.enqueued).as_secs_f64());
                plan.admit.push(q.id);
            }
        }
        let depth = self.queue.len();
        self.stats.note_depth(depth);
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::spec::{AdmitOpts, SpecConfig};

    fn sched(max_batch: usize, window_ms: u64, preempt: bool) -> Scheduler {
        Scheduler::new(SchedulerConfig {
            batcher: BatcherConfig {
                max_batch,
                window: Duration::from_millis(window_ms),
            },
            preempt,
        })
    }

    fn urgency(priority: i32) -> Urgency {
        Urgency { priority, deadline: None }
    }

    fn parked(owner: u64, priority: i32, enqueued: Instant) -> ParkedSeq {
        ParkedSeq {
            snapshot: SuspendedSeq::fresh(b"xy", 0, &AdmitOpts::default(),
                                          &SpecConfig::default()),
            owner,
            fanout_index: 0,
            urgency: urgency(priority),
            enqueued,
        }
    }

    fn running(id: SeqId, priority: i32) -> RunningSeq {
        RunningSeq { id, priority, preemptible: true }
    }

    /// A `now` far past the co-batch window for `enqueued` at `t0`.
    fn late(t0: Instant) -> Instant {
        t0 + Duration::from_secs(1)
    }

    #[test]
    fn fifo_within_a_priority_class() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(1, 1, urgency(0), t0);
        s.submit(2, 1, urgency(0), t0 + Duration::from_millis(1));
        let plan = s.plan(4, &[], late(t0));
        assert_eq!(plan.admit, vec![1, 2]);
        assert!(plan.preempt.is_empty() && plan.resume.is_empty());
    }

    #[test]
    fn higher_priority_jumps_the_queue() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(1, 2, urgency(0), t0);
        s.submit(2, 1, urgency(5), t0 + Duration::from_millis(1));
        // One free slot: only the high-priority request fits — and it
        // must be taken first despite arriving later (retiring FIFO-only
        // admission).
        let plan = s.plan(1, &[], late(t0));
        assert_eq!(plan.admit, vec![2]);
        assert_eq!(s.queue_depth(), 1);
    }

    #[test]
    fn deadline_orders_within_a_class() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        let d_near = Some(t0 + Duration::from_millis(50));
        let d_far = Some(t0 + Duration::from_millis(500));
        s.submit(1, 1, Urgency { priority: 0, deadline: None }, t0);
        s.submit(2, 1, Urgency { priority: 0, deadline: d_far },
                 t0 + Duration::from_millis(1));
        s.submit(3, 1, Urgency { priority: 0, deadline: d_near },
                 t0 + Duration::from_millis(2));
        let plan = s.plan(4, &[], late(t0));
        // Deadlined work first (earliest first), then undeadlined FIFO —
        // but priority still dominates deadline across classes.
        assert_eq!(plan.admit, vec![3, 2, 1]);
    }

    #[test]
    fn preempts_weakest_victim_for_strictly_higher_priority() {
        let t0 = Instant::now();
        let mut s = sched(2, 1, true);
        s.submit(9, 1, urgency(5), t0);
        // Batch full: two running seqs at priorities 0 and 3.
        let run = [running(10, 3), running(11, 0)];
        let plan = s.plan(0, &run, late(t0));
        assert_eq!(plan.preempt, vec![11], "weakest victim first");
        assert_eq!(plan.admit, vec![9]);
    }

    #[test]
    fn equal_priority_never_preempts() {
        let t0 = Instant::now();
        let mut s = sched(1, 1, true);
        s.submit(9, 1, urgency(0), t0);
        let plan = s.plan(0, &[running(10, 0)], late(t0));
        assert!(plan.preempt.is_empty(), "no equal-priority thrash");
        assert!(plan.admit.is_empty());
    }

    #[test]
    fn preemption_respects_non_preemptible_victims() {
        // A sequence `can_suspend` rejects (e.g. context past the prefill
        // capacity) is pinned; the scheduler must pick another victim or
        // none at all.
        let t0 = Instant::now();
        let mut s = sched(2, 1, true);
        s.submit(9, 1, urgency(5), t0);
        let run = [
            RunningSeq { id: 10, priority: 0, preemptible: false },
            running(11, 1),
        ];
        let plan = s.plan(0, &run, late(t0));
        assert_eq!(plan.preempt, vec![11]);
    }

    #[test]
    fn preempt_disabled_ranks_but_never_suspends() {
        let t0 = Instant::now();
        let mut s = sched(1, 1, false);
        s.submit(9, 1, urgency(9), t0);
        let plan = s.plan(0, &[running(10, 0)], late(t0));
        assert!(plan.preempt.is_empty());
        assert!(plan.admit.is_empty());
        // Once the slot frees naturally, the ranked head admits.
        let plan = s.plan(1, &[], late(t0));
        assert_eq!(plan.admit, vec![9]);
    }

    #[test]
    fn progressive_preemption_holds_freed_slots_for_the_head() {
        // The top waiting item needs 3 slots; only two lower-priority
        // victims exist. Both are preempted (draining toward the
        // reservation) but nothing lower-ranked may take the freed slots.
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(9, 3, urgency(5), t0);
        s.submit(8, 1, urgency(0), t0);
        let run = [running(10, 0), running(11, 1),
                   RunningSeq { id: 12, priority: 0, preemptible: false }];
        let plan = s.plan(0, &run, late(t0));
        assert_eq!(plan.preempt, vec![10, 11]);
        assert!(plan.admit.is_empty(),
                "freed slots are reserved for the oversized head");
        assert_eq!(s.queue_depth(), 2);
    }

    #[test]
    fn resumes_park_order_and_beats_later_arrivals_of_its_class() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.park(parked(1, 0, t0));
        s.submit(2, 1, urgency(0), t0 + Duration::from_millis(2));
        let plan = s.plan(1, &[], late(t0));
        // One slot: the parked sequence (earlier enqueue, same class)
        // resumes; the queued request waits.
        assert_eq!(plan.resume.len(), 1);
        assert_eq!(plan.resume[0].owner, 1);
        assert!(plan.admit.is_empty());
        // Counted by the executor on a successful `SpecBatch::resume`,
        // never at plan time (a planned resume can still be dropped).
        assert_eq!(s.stats.resumes, 0);
    }

    #[test]
    fn queued_higher_priority_outranks_parked_lower() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.park(parked(1, 0, t0));
        s.submit(2, 1, urgency(5), t0 + Duration::from_millis(2));
        let plan = s.plan(1, &[], late(t0));
        assert_eq!(plan.admit, vec![2]);
        assert!(plan.resume.is_empty());
        assert_eq!(s.parked_count(), 1);
    }

    #[test]
    fn parked_high_priority_preempts_running_low() {
        // Parked work participates in preemption demand: a high-priority
        // suspended sequence evicts low-priority work that was admitted
        // while it was parked.
        let t0 = Instant::now();
        let mut s = sched(1, 1, true);
        s.park(parked(1, 5, t0));
        let plan = s.plan(0, &[running(10, 0)], late(t0));
        assert_eq!(plan.preempt, vec![10]);
        assert_eq!(plan.resume.len(), 1);
        assert_eq!(plan.resume[0].owner, 1);
    }

    #[test]
    fn preemption_skips_the_cobatch_window() {
        // A round that preempted admits immediately — holding the head
        // for the window after evicting a victim would be pure waste.
        let t0 = Instant::now();
        let mut s = sched(2, 50, true);
        s.submit(9, 1, urgency(5), t0);
        let plan = s.plan(0, &[running(10, 0)], t0); // window NOT expired
        assert_eq!(plan.preempt, vec![10]);
        assert_eq!(plan.admit, vec![9]);
    }

    #[test]
    fn window_still_gates_plain_admission() {
        // No preemption, no resume: the batcher's co-batch window governs
        // exactly as before (both sides, same single `now`).
        let t0 = Instant::now();
        let mut s = sched(4, 50, true);
        s.submit(1, 1, urgency(0), t0);
        let plan = s.plan(4, &[], t0 + Duration::from_millis(1));
        assert!(plan.is_empty(), "young head must wait out the window");
        let plan = s.plan(4, &[], t0 + Duration::from_millis(60));
        assert_eq!(plan.admit, vec![1]);
    }

    #[test]
    fn fresh_high_priority_head_does_not_rearm_the_window() {
        // Rank order puts a fresh urgent arrival at the head; the
        // co-batch window must still expire on the OLDEST waiter's
        // clock, or a sub-window trickle of urgent arrivals would
        // starve older lower-priority work indefinitely.
        let t0 = Instant::now();
        let mut s = sched(8, 50, true);
        s.submit(1, 1, urgency(0), t0);
        s.submit(2, 1, urgency(5), t0 + Duration::from_millis(49));
        let plan = s.plan(8, &[], t0 + Duration::from_millis(51));
        assert_eq!(plan.admit, vec![2, 1],
                   "oldest waiter's window expired: admit in rank order");
    }

    #[test]
    fn oversized_head_clamp_survives_the_scheduler() {
        // plan_batch's empty-batch clamp-admit is consulted unchanged:
        // fan-out 9 > max_batch 4 admits (clamped by the coordinator)
        // only against a fully-free batch.
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(1, 9, urgency(0), t0);
        let plan = s.plan(3, &[running(10, 0)], late(t0));
        assert!(plan.admit.is_empty(), "partial batch: head waits");
        let plan = s.plan(4, &[], late(t0));
        assert_eq!(plan.admit, vec![1]);
    }

    #[test]
    fn budget_sweep_takes_a_requests_parked_seqs() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.park(parked(1, 0, t0));
        s.park(parked(2, 0, t0));
        s.park(parked(1, 0, t0));
        let taken = s.take_parked_of(1);
        assert_eq!(taken.len(), 2);
        assert_eq!(s.parked_count(), 1);
    }

    #[test]
    fn stats_observe_admission_waits_per_class() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(1, 1, urgency(0), t0);
        s.submit(2, 1, urgency(7), t0);
        assert_eq!(s.stats.max_queue_depth, 2);
        let plan = s.plan(4, &[], t0 + Duration::from_millis(100));
        assert_eq!(plan.admit.len(), 2);
        assert_eq!(s.stats.queue_depth, 0);
        assert!(s.stats.mean_wait_secs(0) >= 0.1);
        assert!(s.stats.mean_wait_secs(7) >= 0.1);
    }
}
