//! Preemptive priority scheduler — the coordinator's admission brain.
//!
//! The FIFO free-slot batcher ([`super::batcher`]) decided *how many*
//! queued requests fit; under load that let a long-running batch starve a
//! latency-sensitive arrival until something retired ("The Synergy of
//! Speculative Decoding and Batching", arXiv:2310.18813, shows the
//! speculative-batching sweet spot shifts with load — batch composition
//! must be the server's decision; MagicDec, arXiv:2408.11049, frames the
//! per-request latency/throughput tradeoff that motivates priorities).
//! This module ranks **all** waiting work — queued requests *and*
//! suspended sequences — and may **preempt** running work to serve it:
//!
//! * Every request carries an [`Urgency`]: a wire `priority` (higher
//!   runs first; default 0) and an optional `deadline` that orders work
//!   *within* a priority class (earliest first; no-deadline work sorts
//!   after deadlined work of the same class). Ties fall back to FIFO by
//!   enqueue time, which also makes a resumed sequence naturally outrank
//!   later arrivals of its own class.
//! * At each step boundary the coordinator calls [`Scheduler::plan`]
//!   with the batch's free slots and a view of the running sequences.
//!   The plan may (a) **preempt** running sequences — only for
//!   *strictly* higher-priority waiting work, lowest-priority victims
//!   first, and only victims `SpecBatch::can_suspend` accepts — then
//!   (b) **resume** parked sequences, and (c) **admit** queued requests.
//!   Preemption is progressive: when the top waiting item needs more
//!   slots than eligible victims can free, the freed slots are held for
//!   it (head-of-line in rank order) and the batch drains toward it.
//! * The FIFO batcher survives as the *policy the scheduler consults*
//!   for (c): [`plan_batch`] keeps the atomic-fan-out and
//!   oversized-head clamp semantics over the **rank-ordered** queue, and
//!   [`should_flush`] keeps the co-batching window — evaluated exactly
//!   once per round against a single `now`, so the window check cannot
//!   drift between call sites. A round that already preempted or
//!   resumed skips the window (work is flowing; holding the head back
//!   would buy no batching).
//!
//! Suspended sequences live on the **host** (a [`SuspendedSeq`] is a few
//! hundred bytes; resume recomputes the KV row), so the scheduler may
//! hold arbitrarily more admitted work than the engine has device slots
//! — the `capacity = max_batch` bound applies to *running* work only.
//!
//! **Live re-bucketing** (PAD): the plan may also ask the engine to
//! re-shape its running fused bucket ([`SchedPlan::rebucket`], executed
//! via `SpecBatch::rebucket` before resumes/admissions). The decision is
//! a cost model over one fused prefill at the new bucket `b'` (≈ `b'`
//! row-prefills over the prompt capacity):
//!
//! * **Grow** when the **ranked head cannot be placed** in the free
//!   rows — either none are left, or the head's atomic fan-out exceeds
//!   them (it would otherwise hold the queue until the bucket drained).
//!   The prefill buys rows *now*, versus queued work waiting unboundedly
//!   for a retirement or the drain, and it beats preemption (same
//!   recompute cost, nobody evicted). Free rows — `--pad-headroom`
//!   grow-room, retired husks — are consumed first whenever they can
//!   place the head: growing then would re-prefill the whole bucket for
//!   nothing, so such a grow is rejected. (A grow that exists to serve
//!   *parked* work pays extra: the fused prefill fills the new rows with
//!   shadow padding and each resume then scatter-prefills over it —
//!   folding the round's resume contexts into the re-bucket prefill
//!   itself is an open micro-optimization, see ROADMAP.)
//! * **Shrink** when the waiting sets have stayed empty for
//!   [`SchedulerConfig::shrink_delay`] (hysteresis: a shrink destroys
//!   reusable husk rows, so intermittent traffic must not thrash the
//!   bucket with grow/shrink prefill pairs) and a smaller bucket
//!   (headroom re-applied) covers the occupancy — the same one-prefill
//!   cost removes `b - b'` dead rows from every subsequent fused step,
//!   which pays for itself after roughly `prefill_p / (k+1)` steps of
//!   the surviving sequences.
//!
//! A planned grow can still fail at execution (device prefill failure —
//! the old bucket keeps serving). The coordinator then **re-queues** the
//! admissions and **re-parks** the resumes planned against the phantom
//! rows ([`Scheduler::repark`]) instead of hard-failing them.
//!
//! The engine's [`BatchView::rebucket_target`] probe is the single
//! validation path (`SPLIT` and pinned-context rows simply probe to
//! `None`), so the plan cannot drift from what the batch will execute.
//!
//! **Cheap-resume preemption** (prefix-KV reuse): when the engine
//! reports [`BatchView::cheap_resume`] — a started fused bucket with
//! the prefix cache on, so a suspended row survives as its own Husk
//! donor and resumes by one KV row copy instead of a prompt-length
//! recompute — the preemption threshold relaxes: a **deadlined** waiter
//! may also suspend an *equal*-priority **undeadlined** victim. The
//! rule is asymmetric by construction (undeadlined work never preempts
//! a deadlined runner of the same class), so it cannot thrash; with
//! `cheap_resume` false the old strictly-higher-priority rule applies
//! unchanged.
//!
//! Starvation: a preempted sequence resumes as soon as rank order allows
//! (its original enqueue time keeps its FIFO position within its class);
//! under sustained strictly-higher-priority load it waits indefinitely —
//! there is deliberately no aging in this version. Running work is never
//! preempted by *equal*-priority arrivals, so default-priority traffic
//! cannot thrash.

use std::time::Instant;

use crate::metrics::SchedStats;
use crate::spec::{SeqId, SuspendedSeq};

use super::batcher::{plan_batch, should_flush, BatcherConfig, Pending};

/// Scheduling class of one request: wire `priority` (higher runs first)
/// plus an optional soft deadline ordering work within the class.
#[derive(Debug, Clone, Copy, Default)]
pub struct Urgency {
    pub priority: i32,
    pub deadline: Option<Instant>,
}

/// Rank order: priority descending, then deadline ascending (deadlined
/// work before undeadlined within a class), then FIFO by enqueue time.
/// `Less` means "runs first".
fn rank(a: (&Urgency, Instant), b: (&Urgency, Instant))
        -> std::cmp::Ordering {
    use std::cmp::Ordering;
    b.0.priority
        .cmp(&a.0.priority)
        .then_with(|| match (a.0.deadline, b.0.deadline) {
            (Some(x), Some(y)) => x.cmp(&y),
            (Some(_), None) => Ordering::Less,
            (None, Some(_)) => Ordering::Greater,
            (None, None) => Ordering::Equal,
        })
        .then_with(|| a.1.cmp(&b.1))
}

/// One queued (not yet admitted) request.
#[derive(Debug)]
struct QueuedReq {
    id: u64,
    n_seqs: usize,
    urgency: Urgency,
    enqueued: Instant,
}

/// A preempted sequence parked host-side, with everything the
/// coordinator needs to re-wire it on resume.
#[derive(Debug)]
pub struct ParkedSeq {
    /// The engine snapshot `SpecBatch::resume` consumes.
    pub snapshot: SuspendedSeq,
    /// Owning request id.
    pub owner: u64,
    /// Index within the owner's fan-out (step events / response slot).
    pub fanout_index: usize,
    pub urgency: Urgency,
    /// The owner's original enqueue time — the FIFO tie-break that makes
    /// resumed work outrank later arrivals of the same class.
    pub enqueued: Instant,
}

/// The scheduler's read-only view of one running sequence.
#[derive(Debug, Clone, Copy)]
pub struct RunningSeq {
    pub id: SeqId,
    /// The owning request's priority.
    pub priority: i32,
    /// The owning request carries a deadline. Undeadlined sequences are
    /// the only eligible *equal*-priority victims of the cheap-resume
    /// preemption rule ([`BatchView::cheap_resume`]).
    pub has_deadline: bool,
    /// `SpecBatch::can_suspend(id)` — live, generating, and exactly
    /// resumable (context still fits the prefill capacity).
    pub preemptible: bool,
}

/// The scheduler's read-only view of the engine batch at one step
/// boundary (built by the coordinator from `SpecBatch` introspection).
pub struct BatchView<'a> {
    /// Rows an admission/resume could bind right now
    /// (`SpecBatch::free_slots`).
    pub free: usize,
    /// Real sequences occupying slots (`SpecBatch::occupied`).
    pub occupied: usize,
    /// Rows of the live fused bucket (`SpecBatch::bucket_rows`) — `None`
    /// for SPLIT or a batch that has not started.
    pub bucket_rows: Option<usize>,
    /// `SpecBatch::rebucket_target`: the bucket a live re-bucket toward
    /// a desired total row count would land on (headroom re-applied),
    /// `None` when impossible or a no-op. `None` here disables
    /// re-bucket planning entirely.
    pub rebucket_target: Option<&'a dyn Fn(usize) -> Option<usize>>,
    /// Resuming a preempted sequence would be a KV **row copy** rather
    /// than a prompt-length recompute: the engine runs a started fused
    /// bucket (a suspended row survives as its own Husk donor) and the
    /// prefix cache is on. The cost model is then *more willing* to
    /// preempt — a **deadlined** waiter may suspend an equal-priority
    /// **undeadlined** victim. The relation is strictly asymmetric
    /// (the evicted undeadlined sequence can never preempt a deadlined
    /// one back), so cheap preemption cannot ping-pong; with this
    /// false, equal priority never preempts, exactly as before.
    pub cheap_resume: bool,
}

/// One admission/preemption decision round, in execution order.
#[derive(Debug, Default)]
pub struct SchedPlan {
    /// Running sequences to `SpecBatch::suspend`, weakest victims first.
    pub preempt: Vec<SeqId>,
    /// Desired total rows of a live PAD re-bucket
    /// (`SpecBatch::rebucket`), executed after preemptions and before
    /// resumes/admissions: grow when waiting work has no reusable row,
    /// shrink when idle occupancy fits a smaller bucket.
    pub rebucket: Option<usize>,
    /// Parked sequences to `SpecBatch::resume`, rank order.
    pub resume: Vec<ParkedSeq>,
    /// Queued request ids to admit, rank order.
    pub admit: Vec<u64>,
}

impl SchedPlan {
    pub fn is_empty(&self) -> bool {
        self.preempt.is_empty() && self.rebucket.is_none()
            && self.resume.is_empty() && self.admit.is_empty()
    }
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// The FIFO batching policy the scheduler consults for queued
    /// admissions (atomic fan-out, oversized-head clamp, co-batch
    /// window).
    pub batcher: BatcherConfig,
    /// Allow suspending running sequences for strictly-higher-priority
    /// arrivals. Off, the scheduler still ranks the queue but running
    /// work always drains naturally.
    pub preempt: bool,
    /// How long the waiting sets must stay empty before a **shrink** is
    /// planned — hysteresis against bucket thrash: each grow/shrink
    /// costs a whole-bucket re-prefill, and a shrink destroys reusable
    /// husk rows an intermittent arrival could have scatter-admitted
    /// into for one cheap row prefill. The default means "no arrival
    /// for several co-batch windows". Grows are never delayed (waiting
    /// work is the trigger).
    pub shrink_delay: std::time::Duration,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            batcher: BatcherConfig::default(),
            preempt: true,
            shrink_delay: std::time::Duration::from_millis(50),
        }
    }
}

/// The scheduler: owns the waiting sets (queued requests, parked
/// sequences) and the serving counters; the coordinator owns request
/// payloads and executes the plans.
pub struct Scheduler {
    cfg: SchedulerConfig,
    queue: Vec<QueuedReq>,
    parked: Vec<ParkedSeq>,
    /// Start of the current no-waiting-work stretch (None while
    /// anything is queued or parked) — the shrink-hysteresis clock.
    idle_since: Option<Instant>,
    pub stats: SchedStats,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            cfg,
            queue: Vec::new(),
            parked: Vec::new(),
            idle_since: None,
            stats: SchedStats::default(),
        }
    }

    /// Enqueue a request (the coordinator keeps its payload).
    pub fn submit(&mut self, id: u64, n_seqs: usize, urgency: Urgency,
                  enqueued: Instant) {
        self.queue.push(QueuedReq {
            id,
            n_seqs: n_seqs.max(1),
            urgency,
            enqueued,
        });
        let depth = self.queue.len();
        self.stats.note_depth(depth);
    }

    /// Park a suspended sequence (after a successful
    /// `SpecBatch::suspend`).
    pub fn park(&mut self, seq: ParkedSeq) {
        self.stats.preemptions += 1;
        self.parked.push(seq);
    }

    /// Put a **planned resume back** without counting a new preemption:
    /// the executor found no row for it (a planned grow failed to
    /// materialize), so the snapshot returns to the parked set — intact,
    /// `SpecBatch::resume` never consumed it — and re-ranks next round.
    pub fn repark(&mut self, seq: ParkedSeq) {
        self.parked.push(seq);
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Remove one queued request by id — the queued-budget-expiry path:
    /// a time-budgeted request that expired before ever being admitted
    /// is answered as-is by the coordinator and must leave the queue,
    /// or it would wedge there under open-loop overload. Returns
    /// whether an entry was removed (false: the request was already
    /// planned out of the queue this round).
    pub fn remove_queued(&mut self, id: u64) -> bool {
        let before = self.queue.len();
        self.queue.retain(|q| q.id != id);
        let removed = self.queue.len() != before;
        if removed {
            self.stats.note_depth(self.queue.len());
        }
        removed
    }

    pub fn has_queued(&self) -> bool {
        !self.queue.is_empty()
    }

    pub fn parked_count(&self) -> usize {
        self.parked.len()
    }

    /// Remove and return every parked sequence of one request (budget
    /// expiry or request failure: the owner is answered/failed as-is).
    pub fn take_parked_of(&mut self, owner: u64) -> Vec<ParkedSeq> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.parked.len() {
            if self.parked[i].owner == owner {
                out.push(self.parked.remove(i));
            } else {
                i += 1;
            }
        }
        out
    }

    /// Drop every parked sequence (batch-fatal error recovery: their
    /// owners have already been failed).
    pub fn clear_parked(&mut self) {
        self.parked.clear();
    }

    /// Drain the queue, returning the ids (shutdown-with-error path).
    pub fn drain_queued(&mut self) -> Vec<u64> {
        let ids = self.queue.iter().map(|q| q.id).collect();
        self.queue.clear();
        self.stats.note_depth(0);
        ids
    }

    fn sort(&mut self) {
        self.queue.sort_by(
            |a, b| rank((&a.urgency, a.enqueued), (&b.urgency, b.enqueued)));
        self.parked.sort_by(
            |a, b| rank((&a.urgency, a.enqueued), (&b.urgency, b.enqueued)));
    }

    /// Merged (priority, has-deadline, slots-needed) of all waiting
    /// work, best rank first — the preemption planner's view of demand.
    fn waiting_in_rank_order(&self) -> Vec<(i32, bool, usize)> {
        let mut items: Vec<(Urgency, Instant, usize)> = self
            .parked
            .iter()
            .map(|p| (p.urgency, p.enqueued, 1))
            .chain(self.queue.iter().map(|q| (q.urgency, q.enqueued,
                                              q.n_seqs)))
            .collect();
        items.sort_by(|a, b| rank((&a.0, a.1), (&b.0, b.1)));
        items
            .into_iter()
            .map(|(u, _, n)| (u.priority, u.deadline.is_some(), n))
            .collect()
    }

    /// One decision round at a step boundary. `batch` is the engine
    /// batch's introspection view, `running` the live sequences. `now`
    /// is read **once** by the caller and threaded through every window
    /// check, so the head-of-line co-batching window cannot be
    /// re-evaluated against a drifting wall clock within one round (it
    /// used to be read in two places per admission loop).
    pub fn plan(&mut self, batch: &BatchView, running: &[RunningSeq],
                now: Instant) -> SchedPlan {
        self.sort();
        let mut plan = SchedPlan::default();
        let max_batch = self.cfg.batcher.max_batch.max(1);
        let mut avail = batch.free;

        // -- live PAD re-bucketing (see the module docs' cost model) -------
        {
            let demand: usize = self.parked.len()
                + self
                    .queue
                    .iter()
                    .map(|q| q.n_seqs.min(max_batch))
                    .sum::<usize>();
            // The shrink-hysteresis clock runs regardless of the probe,
            // so a batch that becomes shrinkable later (e.g. a pinned
            // row finishing) sees the full idle stretch.
            if demand > 0 {
                self.idle_since = None;
            } else if self.idle_since.is_none() {
                self.idle_since = Some(now);
            }
            if let Some(probe) = batch.rebucket_target {
                self.plan_rebucket(batch, probe, demand, &mut avail,
                                   &mut plan, now);
            }
        }

        // -- preemption: free slots for higher-ranked work -----------------
        //
        // The base rule frees slots only for *strictly* higher-priority
        // waiting work. When resume is cheap (`BatchView::cheap_resume`
        // — the victim's row stays resident as a Husk donor and comes
        // back by row copy, not a prompt recompute), the cost model
        // also lets a **deadlined** waiter suspend an equal-priority
        // **undeadlined** victim: the preemption buys latency for the
        // deadline at near-zero recompute cost, and the relation cannot
        // ping-pong (the evicted undeadlined sequence never outranks a
        // deadlined runner back).
        if self.cfg.preempt
            && !(self.queue.is_empty() && self.parked.is_empty())
        {
            let mut victims: Vec<(SeqId, i32, bool)> = running
                .iter()
                .filter(|r| r.preemptible)
                .map(|r| (r.id, r.priority, r.has_deadline))
                .collect();
            // Weakest first; within a priority, undeadlined before
            // deadlined — they are the only eligible equal-priority
            // victims, so they must be in front of the cursor.
            victims.sort_by_key(|&(_, p, d)| (p, d));
            let mut vi = 0;
            let mut ahead = avail;
            for (pri, deadlined, need) in self.waiting_in_rank_order() {
                let need = need.min(max_batch);
                while ahead < need && vi < victims.len() {
                    let (id, vpri, vdead) = victims[vi];
                    let eligible = vpri < pri
                        || (batch.cheap_resume && deadlined && !vdead
                            && vpri == pri);
                    if !eligible {
                        break;
                    }
                    plan.preempt.push(id);
                    vi += 1;
                    ahead += 1;
                }
                if ahead >= need {
                    ahead -= need;
                } else {
                    break; // head-of-line in rank order: hold freed slots
                }
            }
            avail += plan.preempt.len();
        }

        // -- resume parked work, unless the queue head outranks it ---------
        while avail > 0 {
            let Some(p) = self.parked.first() else { break };
            if let Some(q) = self.queue.first() {
                if rank((&q.urgency, q.enqueued), (&p.urgency, p.enqueued))
                    .is_lt()
                {
                    break; // a queued request runs first; re-rank next round
                }
            }
            let p = self.parked.remove(0);
            // `stats.resumes` is NOT bumped here: the executor counts a
            // resume only after `SpecBatch::resume` succeeds (mirroring
            // `park`, which counts after a successful suspend), so the
            // counters never drift from what actually ran.
            plan.resume.push(p);
            avail -= 1;
        }

        // -- queued admission through the batcher policy -------------------
        let pendings: Vec<Pending> = self
            .queue
            .iter()
            .map(|q| Pending {
                request_id: q.id,
                n_seqs: q.n_seqs,
                enqueued: q.enqueued,
            })
            .collect();
        // A round that preempted, re-bucketed or resumed skips the
        // co-batch window: work is already flowing (and a grow was
        // *caused* by the waiting work — holding it back after paying
        // the re-prefill would be pure waste).
        let flush = !plan.preempt.is_empty() || plan.rebucket.is_some()
            || !plan.resume.is_empty()
            || should_flush(&pendings, avail, &self.cfg.batcher, now);
        if flush {
            let (n_take, _) = plan_batch(&pendings, avail, &self.cfg.batcher);
            for q in self.queue.drain(..n_take) {
                self.stats.observe_wait(
                    q.urgency.priority,
                    now.duration_since(q.enqueued).as_secs_f64());
                plan.admit.push(q.id);
            }
        }
        let depth = self.queue.len();
        self.stats.note_depth(depth);
        plan
    }

    /// The grow/shrink decision of one round (see the module docs' cost
    /// model). Grow: waiting demand and no reusable row left — free
    /// rows (headroom, husks) must be consumed first, so a grow while
    /// rows are still free is rejected by construction. Shrink: the
    /// waiting sets have been empty for at least
    /// [`SchedulerConfig::shrink_delay`] (hysteresis — a shrink
    /// destroys reusable husk rows and an immediate re-grow would pay
    /// two whole-bucket prefills for one intermittent arrival) and a
    /// smaller bucket covers the occupancy.
    fn plan_rebucket(&self, batch: &BatchView,
                     probe: &dyn Fn(usize) -> Option<usize>,
                     demand: usize, avail: &mut usize,
                     plan: &mut SchedPlan, now: Instant) {
        let Some(cur) = batch.bucket_rows else { return };
        let max_batch = self.cfg.batcher.max_batch.max(1);
        if demand > 0 {
            // Grow when the *ranked head* cannot be placed in the free
            // rows. Free rows that can place the head are consumed
            // first (no grow for demand the headroom absorbs); but a
            // head whose atomic fan-out exceeds the remaining free rows
            // must grow NOW — plan_batch would otherwise hold it (and
            // everything behind it) until enough of the bucket drained,
            // the exact wait this mechanism removes.
            let head_need = self
                .waiting_in_rank_order()
                .first()
                .map_or(0, |&(_, _, n)| n.min(max_batch));
            if *avail < head_need {
                let desired = (batch.occupied + demand).min(max_batch);
                if let Some(to) = probe(desired) {
                    if to > cur {
                        plan.rebucket = Some(desired);
                        // The grown bucket's fresh Shadow rows are
                        // admissible this same round (the old husks are
                        // dropped by the move, so free = to - occupied).
                        *avail = to - batch.occupied;
                    }
                }
            }
        } else if batch.occupied > 0 {
            let idle_long_enough = self
                .idle_since
                .is_some_and(|t| now.duration_since(t)
                    >= self.cfg.shrink_delay);
            if !idle_long_enough {
                return;
            }
            // `to < cur` also rejects the degenerate "grow to restore
            // headroom" a fuller probe could suggest.
            if let Some(to) = probe(batch.occupied) {
                if to < cur {
                    plan.rebucket = Some(batch.occupied);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::spec::{AdmitOpts, SpecConfig};

    fn sched(max_batch: usize, window_ms: u64, preempt: bool) -> Scheduler {
        Scheduler::new(SchedulerConfig {
            batcher: BatcherConfig {
                max_batch,
                window: Duration::from_millis(window_ms),
            },
            preempt,
            // Most tests exercise the shrink *decision*, not the
            // hysteresis clock — zero delay shrinks on the first idle
            // round. `shrink_waits_out_the_idle_hysteresis` covers the
            // clock itself.
            shrink_delay: Duration::ZERO,
        })
    }

    fn urgency(priority: i32) -> Urgency {
        Urgency { priority, deadline: None }
    }

    #[test]
    fn remove_queued_drops_exactly_the_named_entry() {
        let mut s = sched(4, 0, false);
        let now = Instant::now();
        s.submit(1, 1, urgency(0), now);
        s.submit(2, 1, urgency(0), now);
        assert_eq!(s.queue_depth(), 2);
        assert!(s.remove_queued(1), "present: removed");
        assert!(!s.remove_queued(1), "already gone");
        assert_eq!(s.queue_depth(), 1);
        assert_eq!(s.stats.queue_depth, 1, "depth gauge re-observed");
        assert!(s.remove_queued(2));
        assert!(!s.has_queued());
    }

    /// A batch view with `free` slots and no re-bucketing capability
    /// (SPLIT-like) — what most scheduling tests need.
    fn view(free: usize) -> BatchView<'static> {
        BatchView {
            free,
            occupied: 0,
            bucket_rows: None,
            rebucket_target: None,
            cheap_resume: false,
        }
    }

    /// A running-PAD view: `occupied` live rows of a `bucket`-row fused
    /// bucket, probing re-buckets against the given bucket ladder
    /// (smallest ladder entry >= desired, headroom 0, like
    /// `SpecBatch::rebucket_target` with the exported buckets).
    fn pad_view(occupied: usize, bucket: usize,
                probe: &dyn Fn(usize) -> Option<usize>) -> BatchView<'_> {
        BatchView {
            free: bucket - occupied,
            occupied,
            bucket_rows: Some(bucket),
            rebucket_target: Some(probe),
            cheap_resume: false,
        }
    }

    /// Probe emulating a [1, 2, 4, 8] bucket ladder at `cur` rows.
    fn ladder_probe(cur: usize) -> impl Fn(usize) -> Option<usize> {
        move |want: usize| {
            let b = [1usize, 2, 4, 8].into_iter().find(|&b| b >= want)?;
            (b != cur).then_some(b)
        }
    }

    fn parked(owner: u64, priority: i32, enqueued: Instant) -> ParkedSeq {
        ParkedSeq {
            snapshot: SuspendedSeq::fresh(b"xy", 0, &AdmitOpts::default(),
                                          &SpecConfig::default()),
            owner,
            fanout_index: 0,
            urgency: urgency(priority),
            enqueued,
        }
    }

    fn running(id: SeqId, priority: i32) -> RunningSeq {
        RunningSeq {
            id,
            priority,
            has_deadline: false,
            preemptible: true,
        }
    }

    /// A `now` far past the co-batch window for `enqueued` at `t0`.
    fn late(t0: Instant) -> Instant {
        t0 + Duration::from_secs(1)
    }

    #[test]
    fn fifo_within_a_priority_class() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(1, 1, urgency(0), t0);
        s.submit(2, 1, urgency(0), t0 + Duration::from_millis(1));
        let plan = s.plan(&view(4), &[], late(t0));
        assert_eq!(plan.admit, vec![1, 2]);
        assert!(plan.preempt.is_empty() && plan.resume.is_empty());
    }

    #[test]
    fn higher_priority_jumps_the_queue() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(1, 2, urgency(0), t0);
        s.submit(2, 1, urgency(5), t0 + Duration::from_millis(1));
        // One free slot: only the high-priority request fits — and it
        // must be taken first despite arriving later (retiring FIFO-only
        // admission).
        let plan = s.plan(&view(1), &[], late(t0));
        assert_eq!(plan.admit, vec![2]);
        assert_eq!(s.queue_depth(), 1);
    }

    #[test]
    fn deadline_orders_within_a_class() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        let d_near = Some(t0 + Duration::from_millis(50));
        let d_far = Some(t0 + Duration::from_millis(500));
        s.submit(1, 1, Urgency { priority: 0, deadline: None }, t0);
        s.submit(2, 1, Urgency { priority: 0, deadline: d_far },
                 t0 + Duration::from_millis(1));
        s.submit(3, 1, Urgency { priority: 0, deadline: d_near },
                 t0 + Duration::from_millis(2));
        let plan = s.plan(&view(4), &[], late(t0));
        // Deadlined work first (earliest first), then undeadlined FIFO —
        // but priority still dominates deadline across classes.
        assert_eq!(plan.admit, vec![3, 2, 1]);
    }

    #[test]
    fn preempts_weakest_victim_for_strictly_higher_priority() {
        let t0 = Instant::now();
        let mut s = sched(2, 1, true);
        s.submit(9, 1, urgency(5), t0);
        // Batch full: two running seqs at priorities 0 and 3.
        let run = [running(10, 3), running(11, 0)];
        let plan = s.plan(&view(0), &run, late(t0));
        assert_eq!(plan.preempt, vec![11], "weakest victim first");
        assert_eq!(plan.admit, vec![9]);
    }

    #[test]
    fn equal_priority_never_preempts() {
        let t0 = Instant::now();
        let mut s = sched(1, 1, true);
        s.submit(9, 1, urgency(0), t0);
        let plan = s.plan(&view(0), &[running(10, 0)], late(t0));
        assert!(plan.preempt.is_empty(), "no equal-priority thrash");
        assert!(plan.admit.is_empty());
    }

    #[test]
    fn preemption_respects_non_preemptible_victims() {
        // A sequence `can_suspend` rejects (e.g. context past the prefill
        // capacity) is pinned; the scheduler must pick another victim or
        // none at all.
        let t0 = Instant::now();
        let mut s = sched(2, 1, true);
        s.submit(9, 1, urgency(5), t0);
        let run = [
            RunningSeq { has_deadline: false, preemptible: false,
                         ..running(10, 0) },
            running(11, 1),
        ];
        let plan = s.plan(&view(0), &run, late(t0));
        assert_eq!(plan.preempt, vec![11]);
    }

    /// A SPLIT-like view whose resumes would be row copies (started
    /// fused bucket + prefix cache on, as the coordinator reports it).
    fn cheap_view(free: usize) -> BatchView<'static> {
        BatchView { cheap_resume: true, ..view(free) }
    }

    fn deadlined(priority: i32, at: Instant) -> Urgency {
        Urgency { priority, deadline: Some(at) }
    }

    #[test]
    fn cheap_resume_lets_deadlined_work_preempt_equal_priority() {
        let t0 = Instant::now();
        let mut s = sched(1, 1, true);
        s.submit(9, 1, deadlined(0, t0 + Duration::from_millis(50)), t0);
        let run = [running(10, 0)]; // equal priority, no deadline
        // Base cost model (resume = full prompt recompute): equal
        // priority never preempts, deadline or not.
        let plan = s.plan(&view(0), &run, late(t0));
        assert!(plan.preempt.is_empty(), "expensive resume: no preempt");
        assert!(plan.admit.is_empty());
        // Cheap resume (the victim's row survives as its own Husk donor
        // and comes back by one row copy): the deadline is worth it.
        let plan = s.plan(&cheap_view(0), &run, late(t0));
        assert_eq!(plan.preempt, vec![10]);
        assert_eq!(plan.admit, vec![9]);
    }

    #[test]
    fn cheap_resume_keeps_the_no_thrash_asymmetry() {
        let t0 = Instant::now();
        // An undeadlined waiter must not evict anyone of its own class,
        // however cheap the resume...
        let mut s = sched(1, 1, true);
        s.submit(9, 1, urgency(0), t0);
        let plan = s.plan(&cheap_view(0), &[running(10, 0)], late(t0));
        assert!(plan.preempt.is_empty(), "undeadlined waiter: no eviction");
        // ...and a deadlined *victim* is never evicted by its own class
        // — the asymmetry that makes ping-pong impossible (the evicted
        // sequence could otherwise turn around and preempt its evictor).
        let mut s = sched(1, 1, true);
        s.submit(9, 1, deadlined(0, t0 + Duration::from_millis(50)), t0);
        let run = [RunningSeq { has_deadline: true, ..running(10, 0) }];
        let plan = s.plan(&cheap_view(0), &run, late(t0));
        assert!(plan.preempt.is_empty(), "deadlined victim is protected");
    }

    #[test]
    fn cheap_resume_prefers_undeadlined_victims_first() {
        let t0 = Instant::now();
        let mut s = sched(2, 1, true);
        // Strictly-higher-priority waiter needing one slot: victim
        // order must still put the undeadlined equal-weakest first.
        s.submit(9, 1, deadlined(5, t0 + Duration::from_millis(50)), t0);
        let run = [RunningSeq { has_deadline: true, ..running(10, 0) },
                   running(11, 0)];
        let plan = s.plan(&cheap_view(0), &run, late(t0));
        assert_eq!(plan.preempt, vec![11],
                   "undeadlined victim evicted before the deadlined one");
    }

    #[test]
    fn preempt_disabled_ranks_but_never_suspends() {
        let t0 = Instant::now();
        let mut s = sched(1, 1, false);
        s.submit(9, 1, urgency(9), t0);
        let plan = s.plan(&view(0), &[running(10, 0)], late(t0));
        assert!(plan.preempt.is_empty());
        assert!(plan.admit.is_empty());
        // Once the slot frees naturally, the ranked head admits.
        let plan = s.plan(&view(1), &[], late(t0));
        assert_eq!(plan.admit, vec![9]);
    }

    #[test]
    fn progressive_preemption_holds_freed_slots_for_the_head() {
        // The top waiting item needs 3 slots; only two lower-priority
        // victims exist. Both are preempted (draining toward the
        // reservation) but nothing lower-ranked may take the freed slots.
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(9, 3, urgency(5), t0);
        s.submit(8, 1, urgency(0), t0);
        let run = [running(10, 0), running(11, 1),
                   RunningSeq { preemptible: false, ..running(12, 0) }];
        let plan = s.plan(&view(0), &run, late(t0));
        assert_eq!(plan.preempt, vec![10, 11]);
        assert!(plan.admit.is_empty(),
                "freed slots are reserved for the oversized head");
        assert_eq!(s.queue_depth(), 2);
    }

    #[test]
    fn resumes_park_order_and_beats_later_arrivals_of_its_class() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.park(parked(1, 0, t0));
        s.submit(2, 1, urgency(0), t0 + Duration::from_millis(2));
        let plan = s.plan(&view(1), &[], late(t0));
        // One slot: the parked sequence (earlier enqueue, same class)
        // resumes; the queued request waits.
        assert_eq!(plan.resume.len(), 1);
        assert_eq!(plan.resume[0].owner, 1);
        assert!(plan.admit.is_empty());
        // Counted by the executor on a successful `SpecBatch::resume`,
        // never at plan time (a planned resume can still be dropped).
        assert_eq!(s.stats.resumes, 0);
    }

    #[test]
    fn queued_higher_priority_outranks_parked_lower() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.park(parked(1, 0, t0));
        s.submit(2, 1, urgency(5), t0 + Duration::from_millis(2));
        let plan = s.plan(&view(1), &[], late(t0));
        assert_eq!(plan.admit, vec![2]);
        assert!(plan.resume.is_empty());
        assert_eq!(s.parked_count(), 1);
    }

    #[test]
    fn parked_high_priority_preempts_running_low() {
        // Parked work participates in preemption demand: a high-priority
        // suspended sequence evicts low-priority work that was admitted
        // while it was parked.
        let t0 = Instant::now();
        let mut s = sched(1, 1, true);
        s.park(parked(1, 5, t0));
        let plan = s.plan(&view(0), &[running(10, 0)], late(t0));
        assert_eq!(plan.preempt, vec![10]);
        assert_eq!(plan.resume.len(), 1);
        assert_eq!(plan.resume[0].owner, 1);
    }

    #[test]
    fn preemption_skips_the_cobatch_window() {
        // A round that preempted admits immediately — holding the head
        // for the window after evicting a victim would be pure waste.
        let t0 = Instant::now();
        let mut s = sched(2, 50, true);
        s.submit(9, 1, urgency(5), t0);
        let plan = s.plan(&view(0), &[running(10, 0)], t0); // window NOT expired
        assert_eq!(plan.preempt, vec![10]);
        assert_eq!(plan.admit, vec![9]);
    }

    #[test]
    fn window_still_gates_plain_admission() {
        // No preemption, no resume: the batcher's co-batch window governs
        // exactly as before (both sides, same single `now`).
        let t0 = Instant::now();
        let mut s = sched(4, 50, true);
        s.submit(1, 1, urgency(0), t0);
        let plan = s.plan(&view(4), &[], t0 + Duration::from_millis(1));
        assert!(plan.is_empty(), "young head must wait out the window");
        let plan = s.plan(&view(4), &[], t0 + Duration::from_millis(60));
        assert_eq!(plan.admit, vec![1]);
    }

    #[test]
    fn fresh_high_priority_head_does_not_rearm_the_window() {
        // Rank order puts a fresh urgent arrival at the head; the
        // co-batch window must still expire on the OLDEST waiter's
        // clock, or a sub-window trickle of urgent arrivals would
        // starve older lower-priority work indefinitely.
        let t0 = Instant::now();
        let mut s = sched(8, 50, true);
        s.submit(1, 1, urgency(0), t0);
        s.submit(2, 1, urgency(5), t0 + Duration::from_millis(49));
        let plan = s.plan(&view(8), &[], t0 + Duration::from_millis(51));
        assert_eq!(plan.admit, vec![2, 1],
                   "oldest waiter's window expired: admit in rank order");
    }

    #[test]
    fn oversized_head_clamp_survives_the_scheduler() {
        // plan_batch's empty-batch clamp-admit is consulted unchanged:
        // fan-out 9 > max_batch 4 admits (clamped by the coordinator)
        // only against a fully-free batch.
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(1, 9, urgency(0), t0);
        let plan = s.plan(&view(3), &[running(10, 0)], late(t0));
        assert!(plan.admit.is_empty(), "partial batch: head waits");
        let plan = s.plan(&view(4), &[], late(t0));
        assert_eq!(plan.admit, vec![1]);
    }

    #[test]
    fn grow_proposed_when_rows_exhausted() {
        // Bucket of 4 fully live, two queued singles: the plan grows the
        // bucket (desired = occupied + demand = 6 -> ladder 8) and
        // admits into the fresh rows in the same round — no window wait,
        // no drain, no preemption.
        let t0 = Instant::now();
        let mut s = sched(8, 50, true);
        s.submit(1, 1, urgency(0), t0);
        s.submit(2, 1, urgency(0), t0 + Duration::from_millis(1));
        let probe = ladder_probe(4);
        let plan = s.plan(&pad_view(4, 4, &probe), &[], t0); // window young
        assert_eq!(plan.rebucket, Some(6));
        assert_eq!(plan.admit, vec![1, 2],
                   "grown rows admit immediately (no window re-wait)");
        assert!(plan.preempt.is_empty(),
                "growing beats evicting equal-priority work");
    }

    #[test]
    fn grow_rejected_while_headroom_rows_free() {
        // The same demand against a bucket that still has reusable rows
        // (--pad-headroom grow-room or husks): no grow — the free rows
        // must be consumed first (they admit the head right now).
        let t0 = Instant::now();
        let mut s = sched(8, 1, true);
        s.submit(1, 1, urgency(0), t0);
        s.submit(2, 1, urgency(0), t0);
        s.submit(3, 1, urgency(0), t0);
        let probe = ladder_probe(4);
        // 3 live of 4: one headroom row free, demand 3 > free 1.
        let plan = s.plan(&pad_view(3, 4, &probe), &[], late(t0));
        assert_eq!(plan.rebucket, None,
                   "free headroom row must be consumed before growing");
        assert_eq!(plan.admit, vec![1], "the free row still admits");
        assert_eq!(s.queue_depth(), 2);
    }

    #[test]
    fn grow_when_fanout_head_exceeds_free_rows() {
        // One husk row free, but the ranked head is an atomic fan-out of
        // 4: plan_batch would hold it (and everything behind it) until
        // the bucket drained. The head's need, not bare row exhaustion,
        // drives the grow — and the burst admits in the same round.
        let t0 = Instant::now();
        let mut s = sched(8, 1, true);
        s.submit(1, 4, urgency(0), t0);
        let probe = ladder_probe(4);
        let plan = s.plan(&pad_view(3, 4, &probe), &[], late(t0));
        assert_eq!(plan.rebucket, Some(7), "occupied 3 + demand 4");
        assert_eq!(plan.admit, vec![1],
                   "the fan-out head admits into the grown rows");
        // The flip side: a head the free row CAN place never grows.
        let mut s = sched(8, 1, true);
        s.submit(1, 1, urgency(0), t0);
        let plan = s.plan(&pad_view(3, 4, &probe), &[], late(t0));
        assert_eq!(plan.rebucket, None);
        assert_eq!(plan.admit, vec![1]);
    }

    #[test]
    fn grow_capped_by_max_batch_and_ladder() {
        // Demand far beyond the serving cap: desired clamps to
        // max_batch; an unsatisfiable probe (ladder exhausted) plans no
        // grow at all.
        let t0 = Instant::now();
        let mut s = sched(8, 1, true);
        s.submit(1, 40, urgency(0), t0);
        let probe = ladder_probe(4);
        let plan = s.plan(&pad_view(4, 4, &probe), &[], late(t0));
        assert_eq!(plan.rebucket, Some(8), "desired = occupied+demand cap");
        // Already at the largest bucket: probe declines, nothing planned.
        let probe8 = ladder_probe(8);
        let mut s = sched(8, 1, true);
        s.submit(1, 40, urgency(0), t0);
        let plan = s.plan(&pad_view(8, 8, &probe8), &[], late(t0));
        assert_eq!(plan.rebucket, None);
    }

    #[test]
    fn shrink_when_idle_occupancy_fits_smaller_bucket() {
        // Nothing waiting, one live row of an 8-row bucket: shrink to
        // the occupancy (the engine maps it to a bucket, headroom
        // re-applied). No admissions are planned — there is nothing to
        // admit.
        let t0 = Instant::now();
        let mut s = sched(8, 1, true);
        let probe = ladder_probe(8);
        let plan = s.plan(&pad_view(1, 8, &probe), &[], late(t0));
        assert_eq!(plan.rebucket, Some(1));
        assert!(plan.admit.is_empty() && plan.resume.is_empty());
    }

    #[test]
    fn no_shrink_with_waiting_or_full_occupancy() {
        let t0 = Instant::now();
        // Waiting work: the round is a grow/admission round, never a
        // shrink (here the parked seq fits the free rows -> no rebucket
        // at all).
        let mut s = sched(8, 1, true);
        s.park(parked(1, 0, t0));
        let probe = ladder_probe(8);
        let plan = s.plan(&pad_view(1, 8, &probe), &[], late(t0));
        assert_eq!(plan.rebucket, None);
        assert_eq!(plan.resume.len(), 1);
        // Occupancy matching the bucket: probe returns the same bucket,
        // nothing planned.
        let mut s = sched(8, 1, true);
        let probe4 = ladder_probe(4);
        let plan = s.plan(&pad_view(4, 4, &probe4), &[], late(t0));
        assert_eq!(plan.rebucket, None);
    }

    #[test]
    fn shrink_waits_out_the_idle_hysteresis() {
        // A shrink only fires after the waiting sets have been empty
        // for `shrink_delay` — an intermittent arrival inside the
        // window resets the clock, so bursty traffic cannot thrash the
        // bucket with grow/shrink re-prefill pairs.
        let t0 = Instant::now();
        let mut s = Scheduler::new(SchedulerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                window: Duration::from_millis(1),
            },
            preempt: true,
            shrink_delay: Duration::from_millis(100),
        });
        let probe = ladder_probe(8);
        // First idle round arms the clock; no shrink yet.
        let plan = s.plan(&pad_view(1, 8, &probe), &[], t0);
        assert_eq!(plan.rebucket, None, "idle clock just armed");
        // Still inside the window: no shrink.
        let plan = s.plan(&pad_view(1, 8, &probe),
                          &[], t0 + Duration::from_millis(50));
        assert_eq!(plan.rebucket, None);
        // An arrival resets the clock (and is admitted into free rows
        // once its co-batch window expires).
        s.submit(1, 1, urgency(0), t0 + Duration::from_millis(60));
        let plan = s.plan(&pad_view(1, 8, &probe),
                          &[], t0 + Duration::from_millis(62));
        assert_eq!(plan.admit, vec![1]);
        assert_eq!(plan.rebucket, None);
        // The next idle round re-arms the clock at 70ms; 50ms later is
        // still inside the window, 105ms later shrinks.
        let plan = s.plan(&pad_view(2, 8, &probe),
                          &[], t0 + Duration::from_millis(70));
        assert_eq!(plan.rebucket, None, "clock re-armed, not expired");
        let plan = s.plan(&pad_view(2, 8, &probe),
                          &[], t0 + Duration::from_millis(120));
        assert_eq!(plan.rebucket, None, "50ms since re-arm < 100ms");
        let plan = s.plan(&pad_view(2, 8, &probe),
                          &[], t0 + Duration::from_millis(175));
        assert_eq!(plan.rebucket, Some(2));
    }

    #[test]
    fn rebucket_never_planned_without_a_probe() {
        // SPLIT (or a not-yet-started PAD batch) exposes no probe: the
        // exhausted-batch round degrades to plain waiting exactly as
        // before re-bucketing existed.
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(1, 1, urgency(0), t0);
        let plan = s.plan(&view(0), &[], late(t0));
        assert!(plan.rebucket.is_none() && plan.admit.is_empty());
    }

    #[test]
    fn grow_spares_equal_priority_running_work_from_preemption() {
        // Preemption requires strictly-higher priority; a grow serves
        // the high-priority arrival without evicting anyone when the
        // ladder still has room — the freed rows cover the head, so the
        // victim loop never fires.
        let t0 = Instant::now();
        let mut s = sched(8, 1, true);
        s.submit(9, 1, urgency(5), t0);
        let probe = ladder_probe(2);
        let plan = s.plan(&pad_view(2, 2, &probe),
                          &[running(10, 0), running(11, 0)], late(t0));
        assert_eq!(plan.rebucket, Some(3));
        assert_eq!(plan.admit, vec![9]);
        assert!(plan.preempt.is_empty(),
                "grown rows make the eviction unnecessary");
    }

    #[test]
    fn budget_sweep_takes_a_requests_parked_seqs() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.park(parked(1, 0, t0));
        s.park(parked(2, 0, t0));
        s.park(parked(1, 0, t0));
        let taken = s.take_parked_of(1);
        assert_eq!(taken.len(), 2);
        assert_eq!(s.parked_count(), 1);
    }

    #[test]
    fn stats_observe_admission_waits_per_class() {
        let t0 = Instant::now();
        let mut s = sched(4, 1, true);
        s.submit(1, 1, urgency(0), t0);
        s.submit(2, 1, urgency(7), t0);
        assert_eq!(s.stats.max_queue_depth, 2);
        let plan = s.plan(&view(4), &[], t0 + Duration::from_millis(100));
        assert_eq!(plan.admit.len(), 2);
        assert_eq!(s.stats.queue_depth, 0);
        assert!(s.stats.mean_wait_secs(0) >= 0.1);
        assert!(s.stats.mean_wait_secs(7) >= 0.1);
    }
}
