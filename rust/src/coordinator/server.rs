//! TCP line-protocol front-end over the [`Coordinator`].
//!
//! One JSON object per line in, one (or more) per line out:
//!
//! ```text
//! -> {"prompt": "def add_7(x):\n    return", "n": 4, "max_new_tokens": 32,
//!     "temperature": 0.7, "top_p": 0.9, "priority": 5,
//!     "deadline_ms": 250}
//! <- {"ok": true, "seqs": [{"text": " x + 7", "finished": true, ...}],
//!     "n_requested": 4, "batch_size": 4, "batch_ms": 120.5,
//!     "queue_ms": 0.8, "ttft_ms": 14.2, "preempted": 0,
//!     "queue_depth": 3}
//! ```
//!
//! `"ttft_ms"` is the request's time to first token — submission to the
//! first step that emitted a byte of any of its sequences, recorded once
//! (preemption/resume cannot reset it) — or `null` when nothing was ever
//! emitted (e.g. the time budget expired first).
//!
//! With `"stream": true` the server relays one event line per speculative
//! step a sequence advanced, before the final `"ok"` line:
//!
//! ```text
//! -> {"prompt": "def add_7(x):\n    return", "stream": true}
//! <- {"event": "step", "seq": 0, "delta": " x", "done": false}
//! <- {"event": "step", "seq": 0, "delta": " + 7", "done": true}
//! <- {"ok": true, "seqs": [...], ...}
//! ```
//!
//! Requests **pipeline** on one connection: every line is submitted the
//! moment it parses — the server never waits for an earlier request's
//! response before reading the next line — and reply lines of
//! concurrent requests may interleave (whole lines, never bytes). A
//! pipelining client tags each request with an `"id"` (any JSON value);
//! the server echoes it verbatim on every event/response/error line of
//! that request, which is how interleaved replies are correlated.
//! Untagged requests get untagged replies, and a client that sends one
//! request at a time observes the old strictly-ordered behavior.
//!
//! A thread per connection forwards requests to the engine worker. The
//! coordinator schedules concurrent connections **preemptively**: work is
//! ranked by the wire `"priority"` (higher first; default 0), ordered
//! within a class by `"deadline_ms"` (a soft hint, milliseconds from
//! submission; earliest first), FIFO on ties. A strictly-higher-priority
//! arrival may *suspend* a running lower-priority sequence: its device KV
//! row is dropped and later rebuilt bitwise by re-prefilling
//! `prompt ‖ generated` (recompute-resume), so the preempted request
//! still returns exactly the output it would have produced uninterrupted
//! (byte-exact under both draft policies — the per-sequence controller
//! state rides the snapshot); it just returns later, and its
//! `"preempted"` count says so. The cost model: a suspension holds a few
//! hundred host bytes; each resume costs one prompt-length prefill —
//! cheap next to the latency a blocked high-priority request would eat.
//! Equal priorities never preempt each other, so default-priority
//! traffic behaves exactly like the old FIFO server. `"queue_depth"` in
//! the response is the scheduler's queue when the reply was finalized —
//! a load signal.
//!
//! Admission stays continuous in **both** execution modes: PAD (the
//! default, the paper's fused-batch headline path) scatter-prefills late
//! arrivals into freed rows of the running fused cache, SPLIT prefills
//! per-slot caches; neither waits for a drain (PAD needs v3 artifacts —
//! rebuild with `make artifacts` if the manifest version check rejects
//! yours; `--pad-headroom` starts PAD buckets with grow-room rows). A
//! burst larger than the running PAD bucket no longer waits either: the
//! scheduler **re-buckets the live batch** — grows the fused bucket by
//! recompute (and shrinks it when mostly empty) with no drain and no
//! artifact rebuild; the response's `"rebuckets"` counter echoes how
//! often the serving engine has done so.
//! Sampling parameters (temperature / top-p) are honored **per request**
//! even across co-batched traffic — the engine threads them per-row
//! through the fused draft call and the verify-side warp; the server's
//! `SpecConfig` only supplies defaults. A fan-out `"n"` larger than the
//! engine's batch capacity is clamped; the response's `"n_requested"`
//! echoes the asked-for value so clients can detect the clamp
//! (`seqs.len() < n_requested`). Out-of-range sampling params (`top_p`
//! outside (0, 1], non-finite or negative temperature) fail that request
//! with `{"ok": false, ...}` at admission.
//!
//! **Admin command**: a line of `{"cmd": "stats"}` (instead of a
//! request) answers with a one-line snapshot of the live metrics
//! registry — the scheduler counters/gauges/series and, when tracing
//! is enabled, the span summary ([`crate::obs::registry::snapshot`]):
//!
//! ```text
//! -> {"cmd": "stats", "id": 7}
//! <- {"ok": true, "id": 7, "stats": {"sched": {...}, "spans": {...}}}
//! ```
//!
//! It pipelines like any request (the optional `"id"` is echoed) and
//! reads the registry without touching the engine batch, so polling it
//! never perturbs generation or the deterministic counters.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::{Coordinator, Reply, Request, StepEvent};
use crate::runtime::json::Json;

/// Serve until the listener errors (bind to port 0 for an ephemeral port;
/// the bound address is passed to `on_ready`).
pub fn serve(coord: Arc<Coordinator>, addr: &str,
             on_ready: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_ready(listener.local_addr()?);
    for stream in listener.incoming() {
        let stream = stream?;
        let coord = coord.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().ok();
            if let Err(e) = handle_conn(&coord, stream) {
                eprintln!("[server] connection {peer:?} error: {e:#}");
            }
        });
    }
    Ok(())
}

fn write_line(w: &mut impl Write, j: &Json) -> Result<()> {
    w.write_all(j.to_string_pretty().replace('\n', " ").as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    Ok(())
}

/// Per-connection loop. Requests **pipeline**: each parsed line is
/// submitted to the coordinator immediately — the reader never blocks
/// on an earlier request's reply — and a relay thread per request
/// streams its event/response lines back as they arrive, so one socket
/// can carry many in-flight requests (the open-loop load harness
/// drives exactly this). Reply lines of concurrent requests
/// interleave; a pipelining client tags each request with an `"id"`
/// and correlates replies by the echoed tag. One-request-at-a-time
/// clients see the old behavior unchanged.
fn handle_conn(coord: &Coordinator, stream: TcpStream) -> Result<()> {
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    let mut relays = Vec::new();
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        // Admin lines short-circuit before request parsing: `{"cmd":
        // "stats"}` is answered inline from the live registry (the
        // worker replies at its next message drain, immediately when
        // idle) and never enters the scheduler queue.
        if let Ok(j) = Json::parse(&line) {
            if j.opt("cmd").and_then(|c| c.as_str().ok())
                == Some("stats")
            {
                let id = j.opt("id").cloned();
                let reply = match coord.stats() {
                    Ok(stats) => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("stats", stats),
                    ]),
                    Err(e) => error_json(&format!("{e:#}")),
                };
                let Ok(mut w) = writer.lock() else { break };
                write_line(&mut *w, &with_id(reply, &id))?;
                continue;
            }
        }
        let (id, parsed) = parse_line(&line);
        match parsed {
            Ok(req) => {
                let rx = coord.submit(req);
                let w = Arc::clone(&writer);
                relays.push(std::thread::spawn(move || {
                    relay_replies(&rx, &w, &id);
                }));
            }
            Err(e) => {
                let Ok(mut w) = writer.lock() else { break };
                write_line(&mut *w, &with_id(
                    error_json(&format!("bad request: {e:#}")), &id))?;
            }
        }
    }
    // The client closed its write side; finish relaying the in-flight
    // replies before dropping the connection.
    for h in relays {
        let _ = h.join();
    }
    Ok(())
}

/// Relay one request's replies onto the shared connection writer,
/// tagging every line with the client's echoed `"id"` (if any). Each
/// line is written under the writer lock, so concurrent relays
/// interleave whole lines, never bytes. A dead writer ends the relay;
/// the coordinator-side receiver is simply dropped.
fn relay_replies(rx: &std::sync::mpsc::Receiver<Reply>,
                 writer: &Mutex<TcpStream>, id: &Option<Json>) {
    loop {
        let (line, done) = match rx.recv() {
            Ok(Reply::Step(ev)) => (event_json(&ev), false),
            Ok(Reply::Done(Ok(resp))) => (response_json(&resp), true),
            Ok(Reply::Done(Err(e))) => {
                (error_json(&format!("{e:#}")), true)
            }
            Err(_) => (error_json("engine thread terminated"), true),
        };
        let Ok(mut w) = writer.lock() else { return };
        if write_line(&mut *w, &with_id(line, id)).is_err() {
            return;
        }
        if done {
            return;
        }
    }
}

/// Echo the client's request tag onto a reply line: pipelined clients
/// correlate interleaved replies by it. Untagged requests keep
/// untagged replies.
fn with_id(mut j: Json, id: &Option<Json>) -> Json {
    if let (Json::Obj(map), Some(tag)) = (&mut j, id) {
        map.insert("id".to_string(), tag.clone());
    }
    j
}

/// Split one wire line into its optional client `"id"` tag and the
/// parsed request. The tag comes back even when the request is
/// invalid, so the error line still correlates (it is `None` only when
/// the line is not JSON at all).
fn parse_line(line: &str) -> (Option<Json>, Result<Request>) {
    match Json::parse(line) {
        Ok(j) => (j.opt("id").cloned(), request_from(&j)),
        Err(e) => (None, Err(e)),
    }
}

pub fn parse_request(line: &str) -> Result<Request> {
    request_from(&Json::parse(line)?)
}

fn request_from(j: &Json) -> Result<Request> {
    Ok(Request {
        prompt: crate::tokenizer::encode(j.get("prompt")?.as_str()?),
        n_seqs: j.opt("n").map(|v| v.as_usize()).transpose()?.unwrap_or(1),
        max_new_tokens: j
            .opt("max_new_tokens")
            .map(|v| v.as_usize())
            .transpose()?,
        temperature: j
            .opt("temperature")
            .map(|v| v.as_f64().map(|x| x as f32))
            .transpose()?,
        top_p: j
            .opt("top_p")
            .map(|v| v.as_f64().map(|x| x as f32))
            .transpose()?,
        seed: j
            .opt("seed")
            .map(|v| v.as_usize().map(|x| x as u64))
            .transpose()?,
        priority: j
            .opt("priority")
            .map(|v| {
                // Range-checked like the sampling params (PR 2): a
                // wrapped `as i32` would silently turn a huge priority
                // into a *negative* one — a preemption victim instead of
                // a preemptor.
                let p = v.as_i64()?;
                i32::try_from(p).map_err(|_| {
                    anyhow!("priority {p} out of range (i32)")
                })
            })
            .transpose()?,
        deadline_ms: j
            .opt("deadline_ms")
            .map(|v| v.as_usize().map(|x| x as u64))
            .transpose()?,
        stream: j
            .opt("stream")
            .map(|v| v == &Json::Bool(true))
            .unwrap_or(false),
    })
}

pub fn event_json(ev: &StepEvent) -> Json {
    Json::obj(vec![
        ("event", "step".into()),
        ("seq", ev.seq.into()),
        ("delta", ev.text_delta.as_str().into()),
        ("done", ev.done.into()),
    ])
}

pub fn response_json(resp: &super::Response) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("n_requested", resp.n_requested.into()),
        ("batch_size", resp.batch_size.into()),
        ("batch_ms", (resp.batch_secs * 1e3).into()),
        ("queue_ms", (resp.queue_secs * 1e3).into()),
        ("preempted", resp.preempted.into()),
        ("queue_depth", resp.queue_depth.into()),
        ("rebuckets", (resp.rebuckets as usize).into()),
        // Engine-lifetime launch accounting when this reply finalized:
        // what the exec backend actually dispatched vs. the rectangular
        // PAD equivalent — the gap is the packed mode's pad-FLOP saving
        // (see `spec::backend`'s launch accounting).
        ("launch_flops", resp.launch_flops.into()),
        ("padded_launch_flops", resp.padded_launch_flops.into()),
        // Draft economy of this request's own sequences: mean per-row
        // draft length (the adaptive controller's realized γ) and the
        // accepted/proposed draft-token ratio.
        ("draft_len_mean", resp.draft_len_mean.into()),
        ("acceptance_rate", resp.acceptance_rate.into()),
        // Prompt-prefix KV reuse tally (engine-lifetime echo, like
        // launch_flops): cache probes, KV row copies executed, and the
        // prefill FLOPs reuse avoided. hits + misses == lookups.
        ("prefix_cache", Json::obj(vec![
            ("lookups", (resp.prefix.lookups as usize).into()),
            ("hits", (resp.prefix.hits as usize).into()),
            ("misses", (resp.prefix.misses as usize).into()),
            ("evictions", (resp.prefix.evictions as usize).into()),
            ("row_copies", (resp.prefix.row_copies as usize).into()),
            ("saved_flops", resp.prefix.saved_flops.into()),
        ])),
        // Time to first token, `null` when no byte was ever emitted
        // (a time budget expired before the first step).
        ("ttft_ms", match resp.ttft_secs {
            Some(s) => (s * 1e3).into(),
            None => Json::Null,
        }),
        ("seqs", Json::Arr(resp.seqs.iter().map(|s| {
            Json::obj(vec![
                ("text", s.text.as_str().into()),
                ("finished", s.finished.into()),
                ("mean_logp", s.mean_logp.into()),
                ("n_tokens", s.n_tokens.into()),
            ])
        }).collect())),
    ])
}

fn error_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", msg.into())])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_request() {
        let r = parse_request(
            r#"{"prompt": "hi", "n": 4, "max_new_tokens": 8,
               "temperature": 0.7, "top_p": 0.9, "seed": 3,
               "priority": -2, "deadline_ms": 250,
               "stream": true}"#).unwrap();
        assert_eq!(r.prompt, b"hi");
        assert_eq!(r.n_seqs, 4);
        assert_eq!(r.max_new_tokens, Some(8));
        assert!((r.temperature.unwrap() - 0.7).abs() < 1e-6);
        assert_eq!(r.seed, Some(3));
        // Priorities are signed: background work may rank *below* the
        // default class.
        assert_eq!(r.priority, Some(-2));
        assert_eq!(r.deadline_ms, Some(250));
        assert!(r.stream);
    }

    #[test]
    fn parse_minimal_request() {
        let r = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(r.n_seqs, 1);
        assert_eq!(r.max_new_tokens, None);
        assert_eq!(r.seed, None);
        assert_eq!(r.priority, None);
        assert_eq!(r.deadline_ms, None);
        assert!(!r.stream);
    }

    #[test]
    fn parse_rejects_missing_prompt() {
        assert!(parse_request(r#"{"n": 2}"#).is_err());
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn parse_rejects_out_of_range_priority() {
        // 2^32 - 1 would wrap to -1 under `as i32` — from "run me first"
        // to "preempt me first". Out-of-range priorities must fail the
        // request at parse time instead.
        assert!(parse_request(
            r#"{"prompt": "x", "priority": 4294967295}"#).is_err());
        assert!(parse_request(
            r#"{"prompt": "x", "priority": -3000000000}"#).is_err());
        let r = parse_request(
            r#"{"prompt": "x", "priority": -5}"#).unwrap();
        assert_eq!(r.priority, Some(-5));
    }

    #[test]
    fn response_json_reports_requested_fanout() {
        let resp = crate::coordinator::Response {
            seqs: vec![],
            n_requested: 9,
            batch_secs: 0.1,
            batch_size: 4,
            queue_secs: 0.0,
            preempted: 2,
            queue_depth: 3,
            rebuckets: 5,
            launch_flops: 1.5e9,
            padded_launch_flops: 2.0e9,
            prefix: crate::coordinator::PrefixEcho {
                lookups: 4,
                hits: 3,
                misses: 1,
                evictions: 2,
                row_copies: 5,
                saved_flops: 6.5e7,
            },
            ttft_secs: Some(0.0255),
            draft_len_mean: 3.5,
            acceptance_rate: 0.75,
        };
        let j = response_json(&resp);
        // A client compares n_requested to seqs.len() to detect the
        // engine's fan-out clamp.
        assert_eq!(j.get("n_requested").unwrap().as_usize().unwrap(), 9);
        assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
        // Scheduler echoes: how often this request was preempted, the
        // queue depth when it was answered, and the engine's live
        // re-bucket count (grow/shrink of the running PAD bucket).
        assert_eq!(j.get("preempted").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("queue_depth").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("rebuckets").unwrap().as_usize().unwrap(), 5);
        let ttft = j.get("ttft_ms").unwrap().as_f64().unwrap();
        assert!((ttft - 25.5).abs() < 1e-9);
        // Draft economy echoes (per-request, per-row — see Response).
        let dl = j.get("draft_len_mean").unwrap().as_f64().unwrap();
        assert!((dl - 3.5).abs() < 1e-9);
        let ar = j.get("acceptance_rate").unwrap().as_f64().unwrap();
        assert!((ar - 0.75).abs() < 1e-9);
        // Launch accounting rides the wire for the serving report's
        // "flops" section (packed's saving shows as launch < padded).
        let lf = j.get("launch_flops").unwrap().as_f64().unwrap();
        let pf = j.get("padded_launch_flops").unwrap().as_f64().unwrap();
        assert!((lf - 1.5e9).abs() < 1.0 && (pf - 2.0e9).abs() < 1.0);
        // Prefix-reuse echoes ride the wire for the serving report's
        // "prefix_cache" section; the tally stays internally consistent.
        let pc = j.get("prefix_cache").unwrap();
        let v = |k: &str| pc.get(k).unwrap().as_usize().unwrap();
        assert_eq!(v("lookups"), 4);
        assert_eq!(v("hits") + v("misses"), v("lookups"));
        assert_eq!(v("row_copies"), 5);
        let sf = pc.get("saved_flops").unwrap().as_f64().unwrap();
        assert!((sf - 6.5e7).abs() < 1.0);
    }

    #[test]
    fn response_json_ttft_is_null_when_nothing_was_emitted() {
        let resp = crate::coordinator::Response {
            seqs: vec![],
            n_requested: 1,
            batch_secs: 0.0,
            batch_size: 0,
            queue_secs: 0.3,
            preempted: 0,
            queue_depth: 0,
            rebuckets: 0,
            launch_flops: 0.0,
            padded_launch_flops: 0.0,
            prefix: crate::coordinator::PrefixEcho::default(),
            ttft_secs: None,
            draft_len_mean: 0.0,
            acceptance_rate: 0.0,
        };
        let j = response_json(&resp);
        // A budget-expired request never produced a byte: the field is
        // present (schema-stable) but explicitly `null`, not 0.0.
        assert_eq!(j.get("ttft_ms").unwrap(), &Json::Null);
    }

    #[test]
    fn with_id_echoes_the_client_tag_verbatim() {
        let tag = Some(Json::Str("req-7".into()));
        let j = with_id(error_json("boom"), &tag);
        assert_eq!(j.get("id").unwrap().as_str().unwrap(), "req-7");
        // The tag is any JSON value, echoed as-is — numbers included.
        let tag = Some(Json::Num(42.0));
        let j = with_id(event_json(&StepEvent {
            seq: 0,
            text_delta: "x".into(),
            done: false,
        }), &tag);
        assert_eq!(j.get("id").unwrap().as_f64().unwrap(), 42.0);
        // Untagged requests keep untagged replies: a one-at-a-time
        // client sees the pre-pipelining wire format unchanged.
        let j = with_id(error_json("boom"), &None);
        assert!(j.opt("id").is_none());
    }

    #[test]
    fn parse_line_returns_the_tag_even_for_bad_requests() {
        // The id must come back with the *error* line too, or a
        // pipelining client cannot tell which in-flight request died.
        let (id, req) = parse_line(r#"{"id": 3, "n": 2}"#);
        assert_eq!(id, Some(Json::Num(3.0)));
        assert!(req.is_err());
        let (id, req) = parse_line(r#"{"id": "a", "prompt": "hi"}"#);
        assert_eq!(id, Some(Json::Str("a".into())));
        assert_eq!(req.unwrap().prompt, b"hi");
        // Unparseable line: no id recoverable at all.
        let (id, req) = parse_line("not json");
        assert!(id.is_none() && req.is_err());
    }

    #[test]
    fn event_line_shape() {
        let j = event_json(&StepEvent {
            seq: 1,
            text_delta: "ab".into(),
            done: true,
        });
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "step");
        assert_eq!(j.get("seq").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("done").unwrap(), &Json::Bool(true));
    }
}
