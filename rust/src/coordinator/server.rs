//! TCP line-protocol front-end over the [`Coordinator`].
//!
//! One JSON object per line in, one per line out:
//!
//! ```text
//! -> {"prompt": "def add_7(x):\n    return", "n": 4, "max_new_tokens": 32}
//! <- {"ok": true, "seqs": [{"text": " x + 7", "finished": true, ...}],
//!     "batch_size": 4, "batch_ms": 120.5, "queue_ms": 0.8}
//! ```
//!
//! A thread per connection forwards requests to the engine worker; the
//! dynamic batcher co-batches concurrent connections into single
//! speculative batches.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::Result;

use super::{Coordinator, Request};
use crate::runtime::json::Json;

/// Serve until the listener errors (bind to port 0 for an ephemeral port;
/// the bound address is passed to `on_ready`).
pub fn serve(coord: Arc<Coordinator>, addr: &str,
             on_ready: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_ready(listener.local_addr()?);
    for stream in listener.incoming() {
        let stream = stream?;
        let coord = coord.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().ok();
            if let Err(e) = handle_conn(&coord, stream) {
                eprintln!("[server] connection {peer:?} error: {e:#}");
            }
        });
    }
    Ok(())
}

fn handle_conn(coord: &Coordinator, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse_request(&line) {
            Ok(req) => match coord.generate(req) {
                Ok(resp) => response_json(&resp),
                Err(e) => error_json(&format!("{e:#}")),
            },
            Err(e) => error_json(&format!("bad request: {e:#}")),
        };
        writer.write_all(reply.to_string_pretty().replace('\n', " ")
            .as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line)?;
    Ok(Request {
        prompt: crate::tokenizer::encode(j.get("prompt")?.as_str()?),
        n_seqs: j.opt("n").map(|v| v.as_usize()).transpose()?.unwrap_or(1),
        max_new_tokens: j
            .opt("max_new_tokens")
            .map(|v| v.as_usize())
            .transpose()?,
        temperature: j
            .opt("temperature")
            .map(|v| v.as_f64().map(|x| x as f32))
            .transpose()?,
        top_p: j
            .opt("top_p")
            .map(|v| v.as_f64().map(|x| x as f32))
            .transpose()?,
    })
}

pub fn response_json(resp: &super::Response) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("batch_size", resp.batch_size.into()),
        ("batch_ms", (resp.batch_secs * 1e3).into()),
        ("queue_ms", (resp.queue_secs * 1e3).into()),
        ("seqs", Json::Arr(resp.seqs.iter().map(|s| {
            Json::obj(vec![
                ("text", s.text.as_str().into()),
                ("finished", s.finished.into()),
                ("mean_logp", s.mean_logp.into()),
                ("n_tokens", s.n_tokens.into()),
            ])
        }).collect())),
    ])
}

fn error_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", msg.into())])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_request() {
        let r = parse_request(
            r#"{"prompt": "hi", "n": 4, "max_new_tokens": 8,
               "temperature": 0.7, "top_p": 0.9}"#).unwrap();
        assert_eq!(r.prompt, b"hi");
        assert_eq!(r.n_seqs, 4);
        assert_eq!(r.max_new_tokens, Some(8));
        assert!((r.temperature.unwrap() - 0.7).abs() < 1e-6);
    }

    #[test]
    fn parse_minimal_request() {
        let r = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(r.n_seqs, 1);
        assert_eq!(r.max_new_tokens, None);
    }

    #[test]
    fn parse_rejects_missing_prompt() {
        assert!(parse_request(r#"{"n": 2}"#).is_err());
        assert!(parse_request("not json").is_err());
    }
}
