//! Host-side prompt-prefix cache: the coordinator's index of which
//! prefix contexts *may* have a resident KV donor row.
//!
//! The cache is deliberately an **index, not a store**: the KV bytes
//! live (only) on the device, in rows of the running batch — live
//! sequences and the frozen Husk rows that suspension/retirement leave
//! behind in a fused bucket. An entry here records "a row encoding this
//! prefix was resident recently"; whether one *still* is gets
//! re-validated against the live row table (`SpecBatch::donor_row_for`)
//! at lookup time, so the cache can never serve stale KV — the worst a
//! stale entry costs is one failed probe, counted as a miss.
//!
//! Keys are prompt-prefix **bytes truncated to block granularity**
//! ([`PrefixCache::block`] bytes): two prompts share an entry exactly
//! when they agree on every whole block. Block truncation is what makes
//! the index *hash-consed* — the thousand variants of "system prompt +
//! short user suffix" collapse onto one key — while the donor
//! validation step keeps correctness exact: `donor_row_for` matches on
//! the *full* untruncated context, so a block-mate that diverges inside
//! the tail simply misses.
//!
//! Eviction is LRU over a **logical tick** — a counter bumped once per
//! cache operation — never wall-clock time. Identical
//! insertion/lookup streams therefore produce identical eviction
//! sequences on every run and every machine, which is what lets the
//! serving harness pin bit-for-bit counter determinism with the cache
//! enabled (ISSUE 10 acceptance: cache hit/miss must not perturb the
//! deterministic `counters` block; this module keeps even the
//! *advisory* prefix counters replayable).
//!
//! Capacity 0 disables the cache: every lookup misses, inserts are
//! dropped, and nothing is counted — the coordinator skips its prefix
//! bookkeeping entirely so a `--prefix-cache 0` run is byte-identical
//! to one that predates the cache.

use std::collections::HashMap;

/// Deterministic LRU index of recently-resident prompt prefixes.
#[derive(Debug)]
pub struct PrefixCache {
    /// Max entries; 0 disables the cache entirely.
    capacity: usize,
    /// Bytes per key block; keys are contexts truncated to a whole
    /// number of blocks (a context shorter than one block keeps its
    /// exact bytes — otherwise every short prompt would collide on the
    /// empty key).
    block: usize,
    /// key -> last-use logical tick.
    entries: HashMap<Vec<u8>, u64>,
    /// Logical clock: bumped once per lookup/insert. Recency lives
    /// here, not in wall time, so eviction order is a pure function of
    /// the operation stream.
    tick: u64,
}

impl PrefixCache {
    pub fn new(capacity: usize, block: usize) -> PrefixCache {
        PrefixCache {
            capacity,
            block: block.max(1),
            entries: HashMap::new(),
            tick: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Block granularity of the keys (bytes).
    pub fn block(&self) -> usize {
        self.block
    }

    /// The key a context indexes under: truncated to whole blocks,
    /// kept exact when shorter than one block.
    fn key(&self, ctx: &[u8]) -> Vec<u8> {
        if ctx.len() < self.block {
            ctx.to_vec()
        } else {
            ctx[..ctx.len() - ctx.len() % self.block].to_vec()
        }
    }

    /// Probe the index for `ctx`'s block-truncated prefix. A hit
    /// refreshes the entry's recency. The caller still must validate a
    /// live donor row before treating this as a cache *hit* in the
    /// served sense.
    pub fn lookup(&mut self, ctx: &[u8]) -> bool {
        if !self.enabled() || ctx.is_empty() {
            return false;
        }
        self.tick += 1;
        let tick = self.tick;
        match self.entries.get_mut(&self.key(ctx)) {
            Some(last) => {
                *last = tick;
                true
            }
            None => false,
        }
    }

    /// Record that a row encoding `ctx` is (newly) resident. Returns
    /// the number of entries evicted to stay within capacity (0 or 1 —
    /// surfaced so the coordinator can count evictions without this
    /// module owning metrics).
    pub fn insert(&mut self, ctx: &[u8]) -> usize {
        if !self.enabled() || ctx.is_empty() {
            return 0;
        }
        self.tick += 1;
        let tick = self.tick;
        self.entries.insert(self.key(ctx), tick);
        let mut evicted = 0;
        while self.entries.len() > self.capacity {
            // Deterministic LRU victim: the minimum logical tick. Ticks
            // are unique (one per operation), so the victim is unique
            // and independent of HashMap iteration order.
            let victim = self
                .entries
                .iter()
                .min_by_key(|&(_, &t)| t)
                .map(|(k, _)| k.clone())
                .expect("non-empty over capacity");
            self.entries.remove(&victim);
            evicted += 1;
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Replay one operation stream and return (hit pattern, eviction
    /// counts) — the observable behavior determinism must pin.
    fn replay(ops: &[(&str, &[u8])], cap: usize, block: usize)
              -> (Vec<bool>, Vec<usize>) {
        let mut c = PrefixCache::new(cap, block);
        let mut hits = Vec::new();
        let mut evs = Vec::new();
        for &(op, ctx) in ops {
            match op {
                "get" => hits.push(c.lookup(ctx)),
                "put" => evs.push(c.insert(ctx)),
                _ => unreachable!(),
            }
        }
        (hits, evs)
    }

    #[test]
    fn same_stream_same_evictions() {
        // The ISSUE-pinned determinism property: identical
        // insertion/lookup streams produce identical hits AND identical
        // eviction sequences, run after run (no wall-clock, no
        // HashMap-order dependence — the interesting case is capacity
        // pressure with interleaved recency refreshes).
        let ops: Vec<(&str, &[u8])> = vec![
            ("put", b"aaaa"), ("put", b"bbbb"), ("get", b"aaaa"),
            ("put", b"cccc"), // cap 2: evicts bbbb (aaaa refreshed)
            ("get", b"bbbb"), ("get", b"cccc"),
            ("put", b"dddd"), // evicts aaaa
            ("get", b"aaaa"), ("get", b"dddd"),
        ];
        let first = replay(&ops, 2, 4);
        assert_eq!(first.0, vec![true, false, true, false, true]);
        assert_eq!(first.1, vec![0, 0, 1, 1]);
        for _ in 0..10 {
            assert_eq!(replay(&ops, 2, 4), first, "replay diverged");
        }
    }

    #[test]
    fn capacity_bound_respected() {
        let mut c = PrefixCache::new(3, 1);
        let mut evicted = 0;
        for i in 0..50u8 {
            evicted += c.insert(&[i, i, i]);
            assert!(c.len() <= 3, "over capacity after insert {i}");
        }
        assert_eq!(c.len(), 3);
        assert_eq!(evicted, 47, "every overflow evicted exactly one");
        // The survivors are the three most recent inserts.
        assert!(c.lookup(&[49, 49, 49]));
        assert!(c.lookup(&[48, 48, 48]));
        assert!(c.lookup(&[47, 47, 47]));
        assert!(!c.lookup(&[46, 46, 46]));
    }

    #[test]
    fn lookup_refreshes_recency() {
        let mut c = PrefixCache::new(2, 1);
        c.insert(b"old");
        c.insert(b"new");
        assert!(c.lookup(b"old"), "present before pressure");
        // "old" was just touched, so the LRU victim is "new".
        assert_eq!(c.insert(b"x"), 1);
        assert!(c.lookup(b"old"));
        assert!(!c.lookup(b"new"));
    }

    #[test]
    fn block_granularity_hash_conses_shared_prefixes() {
        let mut c = PrefixCache::new(8, 4);
        // 9 bytes -> keyed on the first 8 (two whole blocks): prompts
        // differing only inside the trailing partial block share the
        // entry.
        c.insert(b"syspromptA");
        assert!(c.lookup(b"syspromptB"), "same whole-block prefix");
        assert!(!c.lookup(b"sysPromptB"), "differs inside a block");
        // Shorter than one block: exact-bytes key, no empty-key
        // collision.
        c.insert(b"ab");
        assert!(c.lookup(b"ab"));
        assert!(!c.lookup(b"cd"));
    }

    #[test]
    fn capacity_zero_disables() {
        let mut c = PrefixCache::new(0, 4);
        assert!(!c.enabled());
        assert_eq!(c.insert(b"aaaa"), 0);
        assert!(!c.lookup(b"aaaa"));
        assert!(c.is_empty());
    }

    #[test]
    fn empty_context_never_cached() {
        let mut c = PrefixCache::new(4, 4);
        assert_eq!(c.insert(b""), 0);
        assert!(!c.lookup(b""));
        assert!(c.is_empty());
    }
}
