//! Dynamic batching **policy**: how many rank-ordered queued requests fit
//! the speculative batch's free slots, and whether to admit now or hold
//! the head for co-batchable arrivals — the continuous-batching
//! generalization of the paper's serving scenario (§1, footnote 5), where
//! multiple recommendations for one prompt *and* unrelated prompts ride
//! the same engine batch.
//!
//! Since the preemptive scheduler landed, this module is a pure policy
//! the [`super::scheduler`] consults — it no longer *owns* admission.
//! The scheduler ranks the queue (priority, deadline, FIFO), decides
//! preemptions/resumes, and then calls [`plan_batch`] / [`should_flush`]
//! over the rank-ordered queue with a single `now` per round, so the
//! fan-out-atomicity, oversized-head clamp and co-batch window semantics
//! pinned here apply unchanged — and the window check cannot drift
//! between call sites. `plan_batch` still plans against however many
//! slots are free right now — which, with `--pad-headroom`, includes the
//! PAD bucket's grow-room padding rows, and after a live re-bucket
//! (`SpecBatch::rebucket`) includes the grown bucket's fresh rows: the
//! scheduler plans the grow first, then consults this policy against
//! the enlarged free count, so a burst larger than the old bucket
//! admits in the same round.

use std::time::{Duration, Instant};

/// A queued generation request, pre-expansion.
#[derive(Debug, Clone)]
pub struct Pending {
    pub request_id: u64,
    /// Number of sequences this request fans out to (same prompt, distinct
    /// RNG streams).
    pub n_seqs: usize,
    pub enqueued: Instant,
}

/// Batching limits.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Hard cap on sequences per engine batch (largest exported bucket).
    pub max_batch: usize,
    /// How long the head-of-line request may wait for co-batching.
    pub window: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 16, window: Duration::from_millis(5) }
    }
}

/// Decide how many queued requests to admit into `free_slots` open batch
/// slots. Greedy in arrival order; a request's fan-out is admitted
/// atomically (its sequences must land in the same batch generation so
/// one response can carry them all). The head request is special-cased:
/// if its fan-out exceeds even an *empty* batch (`free_slots ==
/// max_batch`), it is admitted clamped to the cap rather than starving;
/// against a merely *partially full* batch it waits for more slots to
/// drain. Returns (requests to take, total sequences they admit).
pub fn plan_batch(queue: &[Pending], free_slots: usize,
                  cfg: &BatcherConfig) -> (usize, usize) {
    let free = free_slots.min(cfg.max_batch);
    if queue.is_empty() || free == 0 {
        return (0, 0);
    }
    let mut taken = 0usize;
    let mut seqs = 0usize;
    for p in queue {
        let n = p.n_seqs.max(1);
        if taken == 0 && n > free {
            // Oversized head: only an empty batch may clamp-admit it;
            // otherwise keep its slot claim and let the batch drain.
            if free == cfg.max_batch {
                return (1, free);
            }
            return (0, 0);
        }
        if seqs + n > free {
            break;
        }
        seqs += n;
        taken += 1;
        if seqs == free {
            break;
        }
    }
    (taken, seqs)
}

/// Should the coordinator admit now, or keep the free slots open a little
/// longer for co-batchable arrivals? Admit when the queue can already fill
/// every free slot, or once the **oldest** queued request has waited out
/// the window — but never when [`plan_batch`] would take nothing anyway
/// (no free slots, or a head whose fan-out doesn't fit until more of the
/// batch drains): flushing then would only make the coordinator rebuild
/// the pending list and re-plan uselessly at every step boundary. Gated
/// on `plan_batch` itself so the two policies cannot drift.
///
/// The age check deliberately uses the oldest waiter, not the queue
/// head: the scheduler hands this function a **rank-ordered** queue, so
/// a fresh higher-priority arrival becomes the head — measuring the
/// window from it would re-arm the clock on every urgent arrival and
/// starve older lower-priority work behind a sub-window trickle. (Under
/// plain FIFO order the head *is* the oldest, so this is exactly the
/// pre-scheduler semantics.)
pub fn should_flush(queue: &[Pending], free_slots: usize,
                    cfg: &BatcherConfig, now: Instant) -> bool {
    let Some(oldest) = queue.iter().map(|p| p.enqueued).min() else {
        return false;
    };
    if plan_batch(queue, free_slots, cfg).0 == 0 {
        return false;
    }
    let free = free_slots.min(cfg.max_batch);
    let seqs: usize = queue.iter().map(|p| p.n_seqs.max(1)).sum();
    seqs >= free || now.duration_since(oldest) >= cfg.window
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(id: u64, n: usize) -> Pending {
        Pending { request_id: id, n_seqs: n, enqueued: Instant::now() }
    }

    #[test]
    fn admits_while_budget_holds() {
        let cfg = BatcherConfig { max_batch: 8, ..Default::default() };
        let q = [pend(1, 2), pend(2, 4), pend(3, 4)];
        let (taken, seqs) = plan_batch(&q, 8, &cfg);
        assert_eq!(taken, 2);
        assert_eq!(seqs, 6);
    }

    #[test]
    fn plans_against_free_slots_not_the_cap() {
        // Batch half-full (3 of 8 slots free): only what fits is taken.
        let cfg = BatcherConfig { max_batch: 8, ..Default::default() };
        let q = [pend(1, 2), pend(2, 2), pend(3, 1)];
        let (taken, seqs) = plan_batch(&q, 3, &cfg);
        assert_eq!(taken, 1);
        assert_eq!(seqs, 2);
        // A later request never jumps an earlier one that doesn't fit.
        let q2 = [pend(1, 3), pend(2, 1)];
        let (taken, seqs) = plan_batch(&q2, 2, &cfg);
        assert_eq!((taken, seqs), (0, 0));
    }

    #[test]
    fn partial_batch_plus_queued_fanout_fills_exactly() {
        let cfg = BatcherConfig { max_batch: 8, ..Default::default() };
        let q = [pend(1, 2), pend(2, 2), pend(3, 2)];
        let (taken, seqs) = plan_batch(&q, 4, &cfg);
        assert_eq!(taken, 2);
        assert_eq!(seqs, 4);
    }

    #[test]
    fn head_clamped_only_into_an_empty_batch() {
        let cfg = BatcherConfig { max_batch: 4, ..Default::default() };
        // Empty batch: oversized head admits clamped to the cap.
        assert_eq!(plan_batch(&[pend(1, 9)], 4, &cfg), (1, 4));
        // Partially full batch: the oversized head waits for a full drain.
        assert_eq!(plan_batch(&[pend(1, 9)], 3, &cfg), (0, 0));
        assert_eq!(plan_batch(&[pend(1, 9), pend(2, 1)], 3, &cfg), (0, 0));
    }

    #[test]
    fn exact_fill_stops() {
        let cfg = BatcherConfig { max_batch: 4, ..Default::default() };
        let q = [pend(1, 2), pend(2, 2), pend(3, 1)];
        let (taken, seqs) = plan_batch(&q, 4, &cfg);
        assert_eq!(taken, 2);
        assert_eq!(seqs, 4);
    }

    #[test]
    fn no_free_slots_admits_nothing() {
        let cfg = BatcherConfig { max_batch: 4, ..Default::default() };
        assert_eq!(plan_batch(&[pend(1, 1)], 0, &cfg), (0, 0));
    }

    #[test]
    fn flush_on_full_or_timeout() {
        let cfg = BatcherConfig {
            max_batch: 4,
            window: Duration::from_millis(10),
        };
        let now = Instant::now();
        assert!(!should_flush(&[], 4, &cfg, now));
        let young = [pend(1, 1)];
        assert!(!should_flush(&young, 4, &cfg, now));
        assert!(should_flush(&young, 4, &cfg,
                             now + Duration::from_millis(11)));
        let full = [pend(1, 2), pend(2, 2)];
        assert!(should_flush(&full, 4, &cfg, now));
    }

    #[test]
    fn flush_considers_free_slots() {
        let cfg = BatcherConfig {
            max_batch: 8,
            window: Duration::from_millis(10),
        };
        let now = Instant::now();
        // Two queued seqs fill the two free slots: admit immediately.
        assert!(should_flush(&[pend(1, 2)], 2, &cfg, now));
        // Same queue against a fully-busy batch: nothing to do.
        assert!(!should_flush(&[pend(1, 2)], 0, &cfg,
                              now + Duration::from_millis(11)));
    }

    #[test]
    fn oversized_head_never_flushes_a_partial_batch() {
        // Head fan-out exceeds the free slots of a *partially full* batch:
        // plan_batch takes nothing until the batch drains, so should_flush
        // must agree — even long after the window expired — instead of
        // making the coordinator re-plan uselessly at every step boundary.
        let cfg = BatcherConfig {
            max_batch: 4,
            window: Duration::from_millis(10),
        };
        let now = Instant::now();
        let late = now + Duration::from_millis(500);
        let q = [pend(1, 9)];
        assert_eq!(plan_batch(&q, 3, &cfg), (0, 0));
        assert!(!should_flush(&q, 3, &cfg, now));
        assert!(!should_flush(&q, 3, &cfg, late));
        // Queued followers don't change the verdict: the head still blocks.
        let q2 = [pend(1, 9), pend(2, 1)];
        assert_eq!(plan_batch(&q2, 3, &cfg), (0, 0));
        assert!(!should_flush(&q2, 3, &cfg, late));
    }

    #[test]
    fn oversized_head_flushes_once_the_batch_is_empty() {
        // The flip side: against an *empty* batch the head clamp-admits,
        // so should_flush fires (here immediately — 9 queued seqs already
        // cover the 4 free slots).
        let cfg = BatcherConfig {
            max_batch: 4,
            window: Duration::from_millis(10),
        };
        let now = Instant::now();
        let q = [pend(1, 9)];
        assert!(should_flush(&q, 4, &cfg, now));
        assert_eq!(plan_batch(&q, 4, &cfg), (1, 4));
    }

    #[test]
    fn window_measured_from_oldest_not_the_ranked_head() {
        // The scheduler passes a rank-ordered queue: a fresh
        // higher-priority arrival sits at the head. The co-batch window
        // must still expire on the OLDEST waiter's clock — anchoring it
        // to the head would let a trickle of urgent arrivals re-arm the
        // window forever and starve the old request behind them.
        let cfg = BatcherConfig {
            max_batch: 8,
            window: Duration::from_millis(50),
        };
        let t0 = Instant::now();
        let old = Pending { request_id: 1, n_seqs: 1, enqueued: t0 };
        let fresh_head = Pending {
            request_id: 2,
            n_seqs: 1,
            enqueued: t0 + Duration::from_millis(49),
        };
        let q = [fresh_head, old]; // rank order: newcomer first
        assert!(!should_flush(&q, 8, &cfg, t0 + Duration::from_millis(40)));
        assert!(should_flush(&q, 8, &cfg, t0 + Duration::from_millis(51)),
                "oldest waiter's window expired; the fresh head must not \
                 re-arm it");
    }

    #[test]
    fn pad_headroom_rows_plan_like_free_slots() {
        // The --pad-headroom knob rounds a PAD bucket up past the
        // admitted count; the extra Shadow rows surface through
        // `SpecBatch::free_slots` exactly like retired rows. The policy
        // must admit a late arrival into that grow-room immediately
        // (queue covers the free slots -> no window wait, no drain).
        let cfg = BatcherConfig {
            max_batch: 8,
            window: Duration::from_millis(50),
        };
        let now = Instant::now();
        // Bucket of 4 running 2 real sequences: 2 headroom rows free.
        let q = [pend(1, 2)];
        assert!(should_flush(&q, 2, &cfg, now), "headroom admits now");
        assert_eq!(plan_batch(&q, 2, &cfg), (1, 2));
        // Without headroom the same running bucket has 0 free rows and
        // the arrival would have waited for a retirement or the drain.
        assert!(!should_flush(&q, 0, &cfg, now));
        assert_eq!(plan_batch(&q, 0, &cfg), (0, 0));
    }

    #[test]
    fn grown_bucket_rows_plan_like_free_slots() {
        // After a live re-bucket (4 -> 8 rows, 4 live) the scheduler
        // re-consults this policy with the enlarged free count: the
        // burst that triggered the grow admits immediately — covering
        // the free rows skips the window, exactly like headroom rows.
        let cfg = BatcherConfig {
            max_batch: 8,
            window: Duration::from_millis(50),
        };
        let now = Instant::now();
        let q = [pend(1, 2), pend(2, 2)];
        // Before the grow: the bucket is fully live, nothing fits.
        assert_eq!(plan_batch(&q, 0, &cfg), (0, 0));
        assert!(!should_flush(&q, 0, &cfg, now));
        // After: 4 fresh rows — the whole burst admits, no window wait.
        assert!(should_flush(&q, 4, &cfg, now));
        assert_eq!(plan_batch(&q, 4, &cfg), (2, 4));
    }

    #[test]
    fn zero_fanout_counts_as_one() {
        let cfg = BatcherConfig::default();
        let (taken, seqs) = plan_batch(&[pend(1, 0)], 16, &cfg);
        assert_eq!((taken, seqs), (1, 1));
    }
}
