//! Dynamic batching policy: group queued requests into one speculative
//! batch, the way the paper's serving scenario batches multiple
//! recommendations for one prompt *and* unrelated prompts together (§1,
//! footnote 5).

use std::time::{Duration, Instant};

/// A queued generation request, pre-expansion.
#[derive(Debug, Clone)]
pub struct Pending {
    pub request_id: u64,
    /// Number of sequences this request fans out to (same prompt, distinct
    /// RNG streams).
    pub n_seqs: usize,
    pub enqueued: Instant,
}

/// Batching limits.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Hard cap on sequences per engine batch (largest exported bucket).
    pub max_batch: usize,
    /// How long the head-of-line request may wait for co-batching.
    pub window: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 16, window: Duration::from_millis(5) }
    }
}

/// Decide how many queued requests to admit into the next batch.
///
/// Greedy in arrival order: admit requests while the sequence budget
/// holds; always admit at least the head request (clamping its fan-out to
/// the cap). Returns the number of requests to take and the total
/// sequences.
pub fn plan_batch(queue: &[Pending], cfg: &BatcherConfig)
                  -> (usize, usize) {
    if queue.is_empty() {
        return (0, 0);
    }
    let mut taken = 0usize;
    let mut seqs = 0usize;
    for p in queue {
        let n = p.n_seqs.max(1);
        if taken > 0 && seqs + n > cfg.max_batch {
            break;
        }
        seqs += n;
        taken += 1;
        if seqs >= cfg.max_batch {
            break;
        }
    }
    (taken, seqs.min(cfg.max_batch))
}

/// Should the worker run now or keep waiting for co-batchable requests?
pub fn should_flush(queue: &[Pending], cfg: &BatcherConfig,
                    now: Instant) -> bool {
    match queue.first() {
        None => false,
        Some(head) => {
            let seqs: usize = queue.iter().map(|p| p.n_seqs.max(1)).sum();
            seqs >= cfg.max_batch
                || now.duration_since(head.enqueued) >= cfg.window
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(id: u64, n: usize) -> Pending {
        Pending { request_id: id, n_seqs: n, enqueued: Instant::now() }
    }

    #[test]
    fn admits_while_budget_holds() {
        let cfg = BatcherConfig { max_batch: 8, ..Default::default() };
        let q = vec![pend(1, 2), pend(2, 4), pend(3, 4)];
        let (taken, seqs) = plan_batch(&q, &cfg);
        assert_eq!(taken, 2);
        assert_eq!(seqs, 6);
    }

    #[test]
    fn head_always_admitted_even_if_oversized() {
        let cfg = BatcherConfig { max_batch: 4, ..Default::default() };
        let (taken, seqs) = plan_batch(&[pend(1, 9)], &cfg);
        assert_eq!(taken, 1);
        assert_eq!(seqs, 4); // clamped to cap
    }

    #[test]
    fn exact_fill_stops() {
        let cfg = BatcherConfig { max_batch: 4, ..Default::default() };
        let q = vec![pend(1, 2), pend(2, 2), pend(3, 1)];
        let (taken, seqs) = plan_batch(&q, &cfg);
        assert_eq!(taken, 2);
        assert_eq!(seqs, 4);
    }

    #[test]
    fn flush_on_full_or_timeout() {
        let cfg = BatcherConfig {
            max_batch: 4,
            window: Duration::from_millis(10),
        };
        let now = Instant::now();
        assert!(!should_flush(&[], &cfg, now));
        let young = vec![pend(1, 1)];
        assert!(!should_flush(&young, &cfg, now));
        assert!(should_flush(&young, &cfg,
                             now + Duration::from_millis(11)));
        let full = vec![pend(1, 2), pend(2, 2)];
        assert!(should_flush(&full, &cfg, now));
    }

    #[test]
    fn zero_fanout_counts_as_one() {
        let cfg = BatcherConfig::default();
        let (taken, seqs) = plan_batch(&[pend(1, 0)], &cfg);
        assert_eq!((taken, seqs), (1, 1));
    }
}
