//! `bass` — the serving binary.
//!
//! Subcommands:
//! * `selftest`  — load artifacts, run a tiny generation on every path.
//! * `generate`  — one batched generation from a prompt (`--prompt`,
//!   `--n`, `--mode pad|split|packed`, `--precision f32|int8`, ...).
//! * `serve`     — TCP line-protocol server over the continuously-batched,
//!   **preemptively scheduled** coordinator (mid-flight admission in both
//!   `--mode pad` and `--mode split`; wire `"priority"`/`"deadline_ms"`
//!   rank requests and may suspend/resume running work — disable with
//!   `--no-preempt`; running PAD buckets **grow and shrink live** under
//!   bursty load, no drain or artifact rebuild; `--pad-headroom N`
//!   starts PAD buckets with N grow-room rows; requests may set
//!   `"stream": true` for per-step event lines).
//! * `serving`   — open-loop serving load harness: seeded Poisson /
//!   bursty arrivals with mixed priorities, fan-outs, prompt lengths
//!   and budgets drive the coordinator (directly, or over one
//!   pipelined TCP connection with `--tcp`) and emit the schema-stable
//!   `BENCH_serving.json` (TTFT/TPOT/e2e mean/p50/p99, goodput under
//!   `--slo-ms`, preemption/re-bucket overhead, deterministic
//!   counters, per-launch FLOP totals). Defaults to `--mode stub` — the
//!   host-only backend — so it runs on artifact-less machines;
//!   `--deterministic` selects the CI-gate workload whose counters are
//!   timing-independent; `--mode packed --stub-engine` serves the
//!   packed ragged backend's host-only path (same bytes as stub, packed
//!   launch-FLOP accounting) without artifacts; `--trace-out t.json`
//!   exports one Chrome trace per scenario (`t.<scenario>.json`) and
//!   adds a per-scenario `observability` section to the report;
//!   `--stats-every S` (also on `serve`) emits periodic registry
//!   snapshots to stderr; `--prefix-cache N` (also on `serve`, default
//!   64, 0 = all prefix KV reuse off) sizes the host-side
//!   prompt-prefix cache, and `--prefix-pool N` / `--prefix-reuse M‰`
//!   overlay N shared system prompts on the workload so the cache and
//!   fan-out prefill sharing actually fire (reported in the
//!   `prefix_cache` section).
//! * `eval`      — run a task (`--task code|summ`) and report accuracy.
//! * `calibrate` — measure peak FLOP/s (Fig-1 utilization denominator).
//! * `info`      — print the manifest summary.

use std::sync::Arc;

use anyhow::{bail, Result};
use bass::baseline::{RdConfig, RegularDecoder};
use bass::bench_util::artifacts_root;
use bass::cli::Args;
use bass::coordinator::{server, Coordinator, CoordinatorConfig};
use bass::eval::{aggregate, judge, Candidate};
use bass::kv::FinishReason;
use bass::obs::Tracer;
use bass::runtime::json::Json;
use bass::runtime::{Attn, Engine, Precision};
use bass::spec::{ExecMode, Policy, SpecConfig, SpecEngine};
use bass::tokenizer;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn spec_config_from(args: &Args) -> Result<SpecConfig> {
    Ok(SpecConfig {
        main_model: args.flag_or("main-model", "main"),
        draft_model: args.flag_or("draft-model", "draft_a"),
        precision: Precision::parse(&args.flag_or("precision", "f32"))?,
        attn: if args.switch("pallas") { Attn::Pallas } else { Attn::Dense },
        temperature: args.f32_flag("temperature", 0.2)?,
        top_p: args.f32_flag("top-p", 0.95)?,
        max_new_tokens: args.usize_flag("max-new-tokens", 96)?,
        policy: match args.flag("fixed-draft") {
            Some(k) => Policy::Fixed(k.parse()?),
            None => Policy::Heuristic,
        },
        mode: match args.flag_or("mode", "pad").as_str() {
            "pad" => ExecMode::Pad,
            "split" => ExecMode::Split,
            // Packed-segment launches: ragged rows laid back-to-back in
            // one offset-addressed stream, so dense verify FLOPs scale
            // with Σq_i instead of PAD's rectangle. Needs the v4
            // decode_packed/draft_packed artifacts (`make artifacts`) —
            // or `--stub-engine` for the host-only serving path.
            "packed" => ExecMode::Packed,
            // Host-only deterministic backend: no artifacts, no device;
            // the serving load harness and CI perf gate run on it.
            "stub" => ExecMode::Stub,
            m => bail!("unknown mode '{m}'"),
        },
        seed: args.u64_flag("seed", 0)?,
        time_budget_secs: args
            .flag("time-budget")
            .map(|v| v.parse::<f64>())
            .transpose()?,
        pad_headroom: args.usize_flag("pad-headroom", 0)?,
    })
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    let root = artifacts_root();
    match args.subcommand.as_str() {
        "info" => {
            let engine = Engine::load(&root)?;
            let m = &engine.manifest;
            println!("platform : {}", engine.platform());
            println!("vocab    : {} (eos={})", m.vocab, m.eos);
            println!("batches  : {:?}", m.batches);
            println!("k buckets: {:?}", m.draft_k_buckets);
            println!("artifacts: {}", m.artifacts.len());
            for (name, info) in &m.models {
                println!("model {name}: L={} H={} d={} ff={} params={} \
                          precisions={:?}",
                         info.n_layer, info.n_head, info.d_model, info.d_ff,
                         info.param_count,
                         info.weights.keys().collect::<Vec<_>>());
            }
            Ok(())
        }
        "calibrate" => {
            let engine = Engine::load(&root)?;
            let iters = args.usize_flag("iters", 10)?;
            let peak = engine.calibrate_peak_flops(iters)?;
            println!("peak ≈ {:.1} GFLOP/s ({} iters of {}-flop GEMM)",
                     peak / 1e9, iters, engine.manifest.calib_flops);
            Ok(())
        }
        "selftest" => selftest(&args),
        "generate" => generate(&args),
        "eval" => eval_task(&args),
        "serve" => serve_cmd(&args),
        "serving" => serving_cmd(&args),
        other => bail!(
            "unknown subcommand '{other}' \
             (try: info|calibrate|selftest|generate|eval|serve|serving)"),
    }
}

fn selftest(args: &Args) -> Result<()> {
    let engine = Engine::load(&artifacts_root())?;
    println!("[selftest] platform = {}", engine.platform());
    let prompt = tokenizer::encode("def add_7(x):\n    return");
    let max_new = args.usize_flag("max-new-tokens", 24)?;
    for (label, cfg) in [
        ("BASS-PAD f32", SpecConfig::default()),
        ("BASS-SPLIT f32", SpecConfig {
            mode: ExecMode::Split,
            ..SpecConfig::default()
        }),
        ("BASS-PAD int8", SpecConfig {
            precision: Precision::Int8,
            ..SpecConfig::default()
        }),
    ] {
        let cfg = SpecConfig { max_new_tokens: max_new, ..cfg };
        let res = SpecEngine::new(&engine, cfg)
            .generate(&[prompt.clone(), prompt.clone()])?;
        println!("[selftest] {label}: steps={} accept={:.0}% out[0]={:?}",
                 res.steps, res.metrics.acceptance_rate * 100.0,
                 tokenizer::decode(&res.seqs[0].generated));
    }
    let rd = RegularDecoder::new(&engine, RdConfig {
        max_new_tokens: max_new,
        ..RdConfig::default()
    });
    let res = rd.generate(&[prompt.clone()])?;
    println!("[selftest] RD: out={:?}",
             tokenizer::decode(&res.seqs[0].generated));
    println!("[selftest] OK");
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let engine = Engine::load(&artifacts_root())?;
    let prompt_text = args.flag_or("prompt", "def add_7(x):\n    return");
    let n = args.usize_flag("n", 1)?;
    let prompts = vec![tokenizer::encode(&prompt_text); n];
    let cfg = spec_config_from(args)?;
    let use_rd = args.switch("regular");
    let t0 = std::time::Instant::now();
    if use_rd {
        let rd = RegularDecoder::new(&engine, RdConfig {
            model: cfg.main_model.clone(),
            precision: cfg.precision,
            attn: cfg.attn,
            temperature: cfg.temperature,
            top_p: cfg.top_p,
            max_new_tokens: cfg.max_new_tokens,
            seed: cfg.seed,
            time_budget_secs: cfg.time_budget_secs,
        });
        let res = rd.generate(&prompts)?;
        print_seqs(&res.seqs, t0.elapsed().as_secs_f64());
        println!("-- RD: ptl first/mean/last = {:.2}/{:.2}/{:.2} ms",
                 res.metrics.ptl_first * 1e3, res.metrics.ptl_mean * 1e3,
                 res.metrics.ptl_last * 1e3);
    } else {
        let res = SpecEngine::new(&engine, cfg).generate(&prompts)?;
        print_seqs(&res.seqs, t0.elapsed().as_secs_f64());
        println!("-- BASS: steps={} acceptance={:.1}% tokens/step={:.2}",
                 res.steps, res.metrics.acceptance_rate * 100.0,
                 res.metrics.tokens_per_step);
        println!("-- ptl first/mean/last = {:.2}/{:.2}/{:.2} ms",
                 res.metrics.ptl_first * 1e3, res.metrics.ptl_mean * 1e3,
                 res.metrics.ptl_last * 1e3);
    }
    Ok(())
}

fn print_seqs(seqs: &[bass::kv::SeqState], wall: f64) {
    for (i, s) in seqs.iter().enumerate() {
        println!("[{i}] ({:?}, {} tokens) {:?}", s.finish,
                 s.tokens_generated(), tokenizer::decode(&s.generated));
    }
    println!("-- wall {:.1} ms", wall * 1e3);
}

fn eval_task(args: &Args) -> Result<()> {
    let root = artifacts_root();
    let engine = Engine::load(&root)?;
    let cfg = spec_config_from(args)?;
    let task = args.flag_or("task", "code");
    let n_problems = args.usize_flag("problems", 16)?;
    let batch = args.usize_flag("batch", 4)?;
    match task.as_str() {
        "code" => {
            let tasks = bass::eval::load_code_tasks(&root)?;
            let mut outcomes = Vec::new();
            for t in tasks.iter().take(n_problems) {
                let prompts = vec![tokenizer::encode(&t.prompt); batch];
                let res = SpecEngine::new(&engine, cfg.clone())
                    .generate(&prompts)?;
                let cands: Vec<Candidate> = res.seqs.iter().map(|s| {
                    let text = tokenizer::decode(&s.generated);
                    Candidate {
                        passes: t.passes(&text),
                        text,
                        finished: s.finish != FinishReason::Running,
                        mean_logp: s.mean_logp(),
                    }
                }).collect();
                outcomes.push(judge(&cands));
            }
            let r = aggregate(&outcomes);
            println!("code task: n={} Pass@Batch={:.1}% Pass@First={:.1}% \
                      Pass@Finished={:.1}%",
                     r.n, r.pass_batch * 100.0, r.pass_first * 100.0,
                     r.pass_finished * 100.0);
        }
        "summ" => {
            let tasks = bass::eval::load_summ_tasks(&root)?;
            let mut scores = Vec::new();
            for t in tasks.iter().take(n_problems) {
                let prompts = vec![tokenizer::encode(&t.prompt); batch];
                let res = SpecEngine::new(&engine, cfg.clone())
                    .generate(&prompts)?;
                let text = tokenizer::decode(&res.seqs[0].generated);
                scores.push(bass::eval::rouge2_f1(
                    t.extract_summary(&text), &t.reference));
            }
            let mean: f64 =
                scores.iter().sum::<f64>() / scores.len().max(1) as f64;
            println!("summ task: n={} ROUGE-2={:.3}", scores.len(), mean);
        }
        other => bail!("unknown task '{other}'"),
    }
    Ok(())
}

/// The open-loop serving load harness (`serving` subcommand).
fn serving_cmd(args: &Args) -> Result<()> {
    let mut spec = spec_config_from(args)?;
    if args.flag("mode").is_none() {
        // The harness default is the host-only backend: no artifacts,
        // no device, full scheduler stack — what a CI machine has.
        spec.mode = ExecMode::Stub;
    }
    let deterministic = args.switch("deterministic");
    let n = args.usize_flag("requests", 160)?;
    let rate = args.f32_flag("rate", 120.0)? as f64;
    let seed = args.u64_flag("seed", 5)?;
    let slo_ms = args.f32_flag("slo-ms", 250.0)? as f64;
    let arrival = args.flag_or("arrival", "both");
    let out = args.flag_or("out", "BENCH_serving.json");
    let tcp = args.switch("tcp");
    let max_batch = args.usize_flag("max-batch", 8)?;
    let window_ms = args.usize_flag("window-ms", 2)? as u64;
    let driver = if tcp { "tcp" } else { "direct" };
    let mode_name = spec.mode.as_str();
    // `--stub-engine` serves a device mode on the host-only engine —
    // only packed has such a path; the worker rejects other modes.
    let stub_engine = args.switch("stub-engine");
    // `--trace-out t.json` exports one Chrome trace per scenario
    // (`t.<scenario>.json`, Perfetto-loadable). The span ring is
    // advisory: the deterministic counters are byte-identical with it
    // on or off (CI asserts this).
    let trace_out = args.flag("trace-out");
    let stats_every = args
        .flag("stats-every")
        .map(|v| v.parse::<f64>())
        .transpose()?;
    // Host-side prompt-prefix cache capacity; 0 disables every form of
    // prefix KV reuse (cache, fan-out sharing, cheap-resume bias) — CI
    // diffs a 0-run against a default run to pin that reuse is
    // byte-invisible in the deterministic counters.
    let prefix_cache = args.usize_flag("prefix-cache", 64)?;
    // `--prefix-pool N` overlays a shared-prefix population of N system
    // prompts on the chosen workload mix (even the gate mix, whose
    // counters stay deterministic — prompt *content* never affects
    // token counts); `--prefix-reuse M` is the reuse rate in permille.
    let prefix_pool = match args.usize_flag("prefix-pool", 0)? {
        0 => None,
        n_prompts => Some(Some(bass::loadgen::PrefixPool {
            n_prompts,
            prefix_len: 48,
            reuse_permille: args.usize_flag("prefix-reuse", 600)?
                .min(1000) as u32,
        })),
    };

    let scenarios = bass::loadgen::scenarios(&arrival, deterministic, n,
                                             rate, seed, slo_ms,
                                             prefix_pool)?;
    let mut entries = Vec::new();
    for sc in &scenarios {
        // A fresh coordinator per scenario: engine-lifetime counters
        // (rebuckets, queue stats) start at zero, and one scenario's
        // backlog cannot bleed into the next one's latencies.
        let mut cfg = CoordinatorConfig::new(
            artifacts_root(),
            spec.clone(),
            bass::coordinator::batcher::BatcherConfig {
                max_batch,
                window: std::time::Duration::from_millis(window_ms),
            },
        );
        cfg.stub_engine = stub_engine;
        cfg.prefix_cache = prefix_cache;
        let tracer = if trace_out.is_some() {
            Tracer::wall(bass::obs::DEFAULT_RING_CAP)
        } else {
            Tracer::disabled()
        };
        cfg.tracer = tracer.clone();
        cfg.stats_every_secs = stats_every;
        let (outcomes, makespan, stats) = if tcp {
            let coord = Arc::new(Coordinator::start(cfg)?);
            let (addr_tx, addr_rx) = std::sync::mpsc::channel();
            let srv = coord.clone();
            std::thread::spawn(move || {
                let _ = server::serve(srv, "127.0.0.1:0", move |a| {
                    let _ = addr_tx.send(a);
                });
            });
            let addr = addr_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("server failed to bind"))?;
            let (o, m) = bass::loadgen::run_tcp(&addr.to_string(), sc)?;
            let stats = coord.stats().ok();
            (o, m, stats)
        } else {
            let coord = Coordinator::start(cfg)?;
            let (o, m) = bass::loadgen::run_direct(&coord, sc);
            let stats = coord.stats().ok();
            (o, m, stats)
        };
        let mut entry = bass::loadgen::report::scenario_report(
            sc, &outcomes, makespan);
        if tracer.enabled() {
            let path = trace_path(trace_out.as_deref().unwrap(), &sc.name);
            std::fs::write(&path,
                           tracer.chrome_trace().to_string_pretty() + "\n")?;
            println!("[serving] wrote {path}");
            bass::loadgen::report::attach_observability(
                &mut entry,
                Json::obj(vec![
                    ("spans", tracer.summary()),
                    ("trace_file", path.as_str().into()),
                    ("stats", stats.unwrap_or(Json::Null)),
                ]),
            );
        }
        let g = entry.get("goodput")?;
        println!("[serving] {}: {} reqs in {:.2}s — goodput {:.1} rps \
                  ({}/{} within {:.0}ms SLO)",
                 sc.name, outcomes.len(), makespan,
                 g.get("goodput_rps")?.as_f64()?,
                 g.get("within_slo")?.as_usize()?,
                 g.get("served")?.as_usize()?, sc.slo_ms);
        entries.push(entry);
    }
    let doc = bass::loadgen::report::bench_report(
        entries, &format!("bass serving ({driver}/{mode_name})"), driver,
        mode_name);
    std::fs::write(&out, doc.to_string_pretty() + "\n")?;
    println!("[serving] wrote {out}");
    Ok(())
}

/// Per-scenario trace file name: `trace.json` + `poisson-gate` →
/// `trace.poisson-gate.json`. The extension split only looks at the
/// final path component, so dotted directories stay intact.
fn trace_path(base: &str, scenario: &str) -> String {
    let name_at = base.rfind('/').map_or(0, |i| i + 1);
    match base[name_at..].rfind('.') {
        Some(i) => {
            let i = name_at + i;
            format!("{}.{scenario}{}", &base[..i], &base[i..])
        }
        None => format!("{base}.{scenario}"),
    }
}

fn serve_cmd(args: &Args) -> Result<()> {
    let mut cfg = CoordinatorConfig::new(
        artifacts_root(),
        spec_config_from(args)?,
        bass::coordinator::batcher::BatcherConfig {
            max_batch: args.usize_flag("max-batch", 8)?,
            window: std::time::Duration::from_millis(
                args.usize_flag("window-ms", 5)? as u64),
        },
    );
    // Priority preemption (suspend/resume-by-recompute) is on by default;
    // --no-preempt keeps the ranked queue but never suspends running work.
    cfg.preempt = !args.switch("no-preempt");
    cfg.stub_engine = args.switch("stub-engine");
    // Prompt-prefix KV reuse: cache capacity (entries); 0 disables all
    // prefix reuse including fan-out prefill sharing.
    cfg.prefix_cache = args.usize_flag("prefix-cache", 64)?;
    // Periodic stderr registry snapshots; the wire `{"cmd":"stats"}`
    // admin command reads the same registry on demand.
    cfg.stats_every_secs = args
        .flag("stats-every")
        .map(|v| v.parse::<f64>())
        .transpose()?;
    let addr = format!("127.0.0.1:{}", args.usize_flag("port", 4781)?);
    let coord = Arc::new(Coordinator::start(cfg)?);
    println!("[serve] engine ready");
    server::serve(coord, &addr, |a| println!("[serve] listening on {a}"))
}
