//! Optimized auto-regressive **regular decoding** (RD) — the paper's 1×
//! anchor in every table. One ragged decode call (Q = 1) per output token,
//! host-side nucleus sampling, static batching: the same structure as the
//! paper's DeepSpeed baseline.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::flops::FlopCounter;
use crate::kv::SeqState;
use crate::metrics::BatchMetrics;
use crate::runtime::{Attn, Engine, Precision};
use crate::sampling::{logp_of, sample_cdf, warp_top_p, Pcg32};

/// Configuration of a regular-decoding run.
#[derive(Debug, Clone)]
pub struct RdConfig {
    pub model: String,
    pub precision: Precision,
    pub attn: Attn,
    pub temperature: f32,
    pub top_p: f32,
    pub max_new_tokens: usize,
    pub seed: u64,
    pub time_budget_secs: Option<f64>,
}

impl Default for RdConfig {
    fn default() -> Self {
        RdConfig {
            model: "main".into(),
            precision: Precision::F32,
            attn: Attn::Dense,
            temperature: 0.2,
            top_p: 0.95,
            max_new_tokens: 96,
            seed: 0,
            time_budget_secs: None,
        }
    }
}

/// Result of a regular-decoding batch.
#[derive(Debug)]
pub struct RdResult {
    pub seqs: Vec<SeqState>,
    pub metrics: BatchMetrics,
    pub prefill_secs: f64,
    pub flops: FlopCounter,
}

pub struct RegularDecoder<'a> {
    pub engine: &'a Engine,
    pub cfg: RdConfig,
}

impl<'a> RegularDecoder<'a> {
    pub fn new(engine: &'a Engine, cfg: RdConfig) -> RegularDecoder<'a> {
        RegularDecoder { engine, cfg }
    }

    pub fn generate(&self, prompts: &[Vec<u8>]) -> Result<RdResult> {
        let cfg = &self.cfg;
        let eng = self.engine;
        let man = &eng.manifest;
        let b_real = prompts.len();
        if b_real == 0 {
            bail!("empty prompt batch");
        }
        let b = man.bucket_batch(b_real)?;
        let p_cap = man.prefill_p;
        let info = man.model(&cfg.model)?.clone();
        let s_max = info.s_max as i32;
        let vocab = man.vocab;

        let mut tokens = vec![0i32; b * p_cap];
        let mut plens = vec![0i32; b];
        let mut states = Vec::with_capacity(b);
        for i in 0..b {
            let src = &prompts[i.min(b_real - 1)];
            let tail: &[u8] = if src.len() > p_cap {
                &src[src.len() - p_cap..]
            } else {
                src
            };
            if tail.is_empty() {
                bail!("empty prompt");
            }
            for (j, &byte) in tail.iter().enumerate() {
                tokens[i * p_cap + j] = byte as i32;
            }
            plens[i] = tail.len() as i32;
            states.push(SeqState::new(tail.to_vec(), *tail.last().unwrap(),
                                      tail.len() as i32));
        }

        let mut flops = FlopCounter::default();
        let t_prefill = Instant::now();
        let out = eng.prefill(&cfg.model, cfg.precision, cfg.attn, b,
                              &tokens, &plens)?;
        flops.add_prefill(&info, b, p_cap);
        let mut caches = out.caches;
        let prefill_secs = t_prefill.elapsed().as_secs_f64();

        let mut rngs: Vec<Pcg32> = (0..b)
            .map(|i| Pcg32::new(cfg.seed, i as u64))
            .collect();

        let t0 = Instant::now();
        while states[..b_real].iter().any(|s| s.active()) {
            if let Some(budget) = cfg.time_budget_secs {
                if t0.elapsed().as_secs_f64() >= budget {
                    break;
                }
            }
            let step_tokens: Vec<i32> =
                states.iter().map(|s| s.pending_main as i32).collect();
            let lens: Vec<i32> = states.iter().map(|s| s.main_len).collect();
            let out = eng.decode(&cfg.model, cfg.precision, cfg.attn, b, 1,
                                 &step_tokens, &lens, caches)?;
            caches = out.caches;
            let ctx = states.iter().map(|s| s.main_len as usize)
                .sum::<usize>() / b;
            flops.add_step(&info, b, 1, ctx);

            let t_now = t0.elapsed().as_secs_f64();
            for i in 0..b {
                if !states[i].active() {
                    continue;
                }
                let row = &out.logits[i * vocab..(i + 1) * vocab];
                let warped = warp_top_p(row, cfg.temperature, cfg.top_p);
                let tok = sample_cdf(&warped, rngs[i].next_f32());
                let logp = logp_of(&warped, tok) as f64;
                // RD is the k=0 degenerate case of a speculative step.
                let emitted = states[i].apply_step(&[], tok as u8, false, 0,
                                                   1, logp);
                states[i].check_eos(man.eos, emitted, t_now);
                states[i].check_limits(cfg.max_new_tokens, s_max, 2, t_now);
            }
        }

        let wall = t0.elapsed().as_secs_f64();
        states.truncate(b_real);
        let metrics = BatchMetrics::from_seqs(&states, wall);
        Ok(RdResult { seqs: states, metrics, prefill_secs, flops })
    }
}

/// Auto-regressive generation with a **draft** model alone (draft models
/// export `draft` artifacts, not `decode` ones; K=1 drafting with in-graph
/// sampling *is* one RD step). Used for the standalone draft rows of
/// Tables 4/5 (draft per-token latency, draft-alone accuracy).
pub struct DraftOnlyDecoder<'a> {
    pub engine: &'a Engine,
    pub cfg: RdConfig,
}

impl<'a> DraftOnlyDecoder<'a> {
    pub fn new(engine: &'a Engine, cfg: RdConfig) -> DraftOnlyDecoder<'a> {
        DraftOnlyDecoder { engine, cfg }
    }

    pub fn generate(&self, prompts: &[Vec<u8>]) -> Result<RdResult> {
        let cfg = &self.cfg;
        let eng = self.engine;
        let man = &eng.manifest;
        let b_real = prompts.len();
        let b = man.bucket_batch(b_real)?;
        let p_cap = man.prefill_p;
        let info = man.model(&cfg.model)?.clone();
        let s_max = info.s_max as i32;

        let mut tokens = vec![0i32; b * p_cap];
        let mut plens = vec![0i32; b];
        let mut states = Vec::with_capacity(b);
        for i in 0..b {
            let src = &prompts[i.min(b_real - 1)];
            let tail: &[u8] = if src.len() > p_cap {
                &src[src.len() - p_cap..]
            } else {
                src
            };
            for (j, &byte) in tail.iter().enumerate() {
                tokens[i * p_cap + j] = byte as i32;
            }
            plens[i] = tail.len() as i32;
            states.push(SeqState::new(tail.to_vec(), *tail.last().unwrap(),
                                      tail.len() as i32));
        }

        let mut flops = FlopCounter::default();
        let t_prefill = Instant::now();
        let out = eng.prefill(&cfg.model, cfg.precision, cfg.attn, b,
                              &tokens, &plens)?;
        flops.add_prefill(&info, b, p_cap);
        let mut caches = out.caches;
        let prefill_secs = t_prefill.elapsed().as_secs_f64();

        let mut rngs: Vec<Pcg32> = (0..b)
            .map(|i| Pcg32::new(cfg.seed, i as u64))
            .collect();

        // The smallest exported draft bucket for this model (draft_a ships
        // K=1; the Table-4 comparison drafts start at K=2 — all K tokens
        // are emitted per call since there is no verifier to reject them).
        let k = man.k_buckets(&cfg.model)[0];

        let t0 = Instant::now();
        while states[..b_real].iter().any(|s| s.active()) {
            if let Some(budget) = cfg.time_budget_secs {
                if t0.elapsed().as_secs_f64() >= budget {
                    break;
                }
            }
            let mut tokens_in = vec![0i32; b * 2];
            let mut n_in = vec![1i32; b];
            let mut lens = vec![0i32; b];
            let mut uniforms = vec![0f32; b * k];
            for i in 0..b {
                tokens_in[2 * i] = states[i].pending_draft[0] as i32;
                tokens_in[2 * i + 1] = states[i].pending_draft[1] as i32;
                n_in[i] = states[i].n_pending_draft;
                lens[i] = states[i].draft_len;
                for j in 0..k {
                    uniforms[i * k + j] = rngs[i].next_f32();
                }
            }
            let out = eng.draft(&cfg.model, cfg.precision, cfg.attn, b, k,
                                &tokens_in, &n_in, &lens, &uniforms,
                                &vec![cfg.temperature; b],
                                &vec![cfg.top_p; b], caches)?;
            caches = out.caches;
            let ctx = states.iter().map(|s| s.draft_len as usize)
                .sum::<usize>() / b;
            flops.add_step(&info, b, k + 1, ctx);

            let t_now = t0.elapsed().as_secs_f64();
            let vocab = man.vocab;
            for i in 0..b {
                if !states[i].active() {
                    continue;
                }
                let n_in_used = states[i].n_pending_draft;
                let mut last = 0u8;
                for j in 0..k {
                    let tok = out.tokens[i * k + j] as usize;
                    let q = &out.qdists[(i * k + j) * vocab
                                        ..(i * k + j + 1) * vocab];
                    states[i].logp_sum +=
                        crate::sampling::logp_of(q, tok) as f64;
                    states[i].generated.push(tok as u8);
                    last = tok as u8;
                }
                // All k drafts "accepted": the cache holds entries through
                // d_{k-1}; d_k rides as the next resync token.
                states[i].main_len += k as i32;
                states[i].draft_len += n_in_used + k as i32 - 1;
                states[i].pending_draft = [last, 0];
                states[i].n_pending_draft = 1;
                states[i].pending_main = last;
                states[i].check_eos(man.eos, k, t_now);
                states[i].check_limits(cfg.max_new_tokens, s_max,
                                       (k + 2) as i32, t_now);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        states.truncate(b_real);
        let metrics = BatchMetrics::from_seqs(&states, wall);
        Ok(RdResult { seqs: states, metrics, prefill_secs, flops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config() {
        let c = RdConfig::default();
        assert_eq!(c.model, "main");
        assert_eq!(c.max_new_tokens, 96);
    }
}
