//! Per-sequence batch state: the slot/row model every exec backend and
//! the batch orchestrator share.
//!
//! * [`Slot`] — one admitted sequence: its [`SeqState`], private PCG32
//!   streams, and per-sequence sampling params / budget.
//! * [`Row`] — one batch row. `Shadow` rows are PAD padding (they advance
//!   like real sequences, matching the padded artifact rows, but are
//!   never reported); `Husk` rows are released PAD sequences — frozen
//!   state that keeps feeding the fused artifact valid lengths. Both are
//!   mid-flight admission targets: a new sequence scatter-prefills over
//!   the row and turns it back into `Seq`.
//! * [`SuspendedSeq`] — the host-side snapshot preemption and live
//!   re-bucketing are built on: everything needed to rebuild the row
//!   bitwise by recompute.
//! * [`AdmitOpts`] / [`SeqEvent`] / [`StepReport`] — the admission and
//!   step-reporting surface of [`super::SpecBatch`].

use anyhow::{bail, Result};

use crate::kv::{FinishReason, SeqState};
use crate::sampling::Pcg32;

use super::config::SpecConfig;
use super::draft_len::Controller;

/// Identity of one admitted sequence (the admission counter; unique for
/// the lifetime of a [`super::SpecBatch`], never reused across slot
/// turnover).
pub type SeqId = u64;

/// What happened to one live sequence during a [`super::SpecBatch::step`].
#[derive(Debug, Clone)]
pub struct SeqEvent {
    pub id: SeqId,
    /// Draft length this sequence ran at this step (its own bucketized
    /// `k_i` — per-row, not the batch launch width).
    pub draft_len: usize,
    /// Draft tokens accepted this step (0..=draft_len).
    pub accepted: usize,
    /// Bytes appended to the sequence this step, post-EOS truncation.
    pub new_bytes: Vec<u8>,
    /// Sequence finished this step (EOS / length / capacity).
    pub done: bool,
    pub finish: FinishReason,
}

/// Outcome of one [`super::SpecBatch::step`].
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    /// 0-based index of the step just executed.
    pub step: usize,
    /// Launch draft length (bucketized `max_i k_i` over the stepping
    /// rows — what the fused PAD artifact ran at; each row's own length
    /// is in its [`SeqEvent::draft_len`]).
    pub k: usize,
    /// Per-sequence events, in slot order (live sequences only).
    pub events: Vec<SeqEvent>,
    /// Sequences that finished on this step (retire them to free slots).
    pub finished: Vec<SeqId>,
    /// Real sequences still generating after this step.
    pub active: usize,
    /// Real sequences occupying slots (active + finished-but-unretired).
    pub occupied: usize,
}

/// Per-admission overrides for [`super::SpecBatch::admit_opts`]. Every
/// `None` falls back to the batch-wide [`SpecConfig`] value, so
/// `AdmitOpts::default()` reproduces plain [`super::SpecBatch::admit`].
#[derive(Debug, Clone, Default)]
pub struct AdmitOpts {
    /// Per-sequence generation limit.
    pub max_new_tokens: Option<usize>,
    /// Pinned PCG32 stream index (see [`super::SpecBatch::admit_opts`]).
    pub stream: Option<u64>,
    /// Per-sequence sampling temperature — drives both this row of the
    /// fused draft artifact and the verify-side warp.
    pub temperature: Option<f32>,
    /// Per-sequence nucleus threshold (same scope as `temperature`).
    pub top_p: Option<f32>,
}

impl AdmitOpts {
    /// Range-check the sampling overrides; the `Err` names the offending
    /// field. [`super::SpecBatch::admit_opts`] runs this before consuming
    /// a slot, so a bad wire value (`top_p: 0`, NaN, …) fails that one
    /// request up front instead of warping its rows into all-zero/NaN
    /// distributions mid-generation.
    pub fn validate(&self) -> Result<()> {
        if let Some(t) = self.temperature {
            if !t.is_finite() || t < 0.0 {
                bail!("temperature must be finite and >= 0 (got {t})");
            }
        }
        if let Some(p) = self.top_p {
            if !p.is_finite() || p <= 0.0 || p > 1.0 {
                bail!("top_p must be in (0, 1] (got {p})");
            }
        }
        Ok(())
    }
}

/// One occupied slot: sequence state plus its private RNG streams and
/// sampling params.
pub(crate) struct Slot {
    pub(crate) id: SeqId,
    pub(crate) state: SeqState,
    pub(crate) rng_draft: Pcg32,
    pub(crate) rng_accept: Pcg32,
    pub(crate) max_new_tokens: usize,
    /// Per-sequence sampling params (seeded from [`SpecConfig`],
    /// overridden per admission): used for this row of the fused draft
    /// call and the host-side verify warp.
    pub(crate) temperature: f32,
    pub(crate) top_p: f32,
    /// This sequence's own draft-length state (Algorithm 1 per row):
    /// observes only this row's accepted counts, so the sequence's
    /// draft-length trajectory — and therefore its RNG consumption —
    /// is independent of co-batch composition.
    pub(crate) draft_ctrl: Controller,
}

/// A batch row (see the module docs for the `Shadow`/`Husk` lifecycle).
pub(crate) enum Row {
    Free,
    Seq(Slot),
    Shadow(Slot),
    Husk(SeqState),
}

impl Row {
    pub(crate) fn state(&self) -> Option<&SeqState> {
        match self {
            Row::Free => None,
            Row::Seq(s) | Row::Shadow(s) => Some(&s.state),
            Row::Husk(st) => Some(st),
        }
    }

    pub(crate) fn is_free(&self) -> bool {
        matches!(self, Row::Free)
    }
}

/// States of the rows whose compute is *served work* this step: live real
/// sequences only. Husk (released) and Shadow (padding) rows still ride
/// the fused PAD artifact, but they serve no request — FLOP and token
/// accounting must not charge them (`flops_count_live_rows_only`).
/// Test-only since the engine went per-row: step accounting now walks
/// each live row's own (k_i, context) instead of aggregating.
#[cfg(test)]
pub(crate) fn live_row_states(rows: &[Row]) -> Vec<&SeqState> {
    rows.iter()
        .filter_map(|r| match r {
            Row::Seq(s) if s.state.active() => Some(&s.state),
            _ => None,
        })
        .collect()
}

/// A sequence lifted out of the batch by [`super::SpecBatch::suspend`]:
/// the complete host-side identity — prompt, verified output bytes, PCG32
/// stream positions, per-sequence sampling params and generation budget.
/// Device KV is deliberately **not** captured: [`super::SpecBatch::resume`]
/// (and a live re-bucket, which round-trips every carried row through the
/// same primitive) rebuilds it bitwise by recomputing a prefill over
/// `prompt ‖ generated` with the existing artifacts, so a snapshot costs
/// a few hundred host bytes and reinstating costs one prefill — the
/// recompute end of the preemption cost model (cheap to hold, one
/// prompt-length compute to reinstate).
#[derive(Debug, Clone)]
pub struct SuspendedSeq {
    prompt: Vec<u8>,
    generated: Vec<u8>,
    logp_sum: f64,
    rng_draft: Pcg32,
    rng_accept: Pcg32,
    max_new_tokens: usize,
    temperature: f32,
    top_p: f32,
    /// Learned draft-length state: carried through suspend/resume so a
    /// preempted sequence resumes at its adapted length, not at `l0`.
    draft_ctrl: Controller,
}

impl SuspendedSeq {
    /// Build a snapshot "as if" freshly admitted with `admit_opts(prompt,
    /// seed, opts)` and suspended before any step: zero progress, RNG
    /// streams at their start. Lets a scheduler park work host-side
    /// without ever occupying a device slot (and lets host-only tests
    /// construct parked entries). An unpinned `opts.stream` defaults to
    /// stream 0 — callers wanting the batch's admission-counter streams
    /// should admit for real instead.
    pub fn fresh(prompt: &[u8], seed: u64, opts: &AdmitOpts,
                 cfg: &SpecConfig) -> SuspendedSeq {
        let stream = opts.stream.unwrap_or(0);
        SuspendedSeq {
            prompt: prompt.to_vec(),
            generated: Vec::new(),
            logp_sum: 0.0,
            rng_draft: Pcg32::new(seed, 2 * stream),
            rng_accept: Pcg32::new(seed, 2 * stream + 1),
            max_new_tokens: opts
                .max_new_tokens
                .unwrap_or(cfg.max_new_tokens),
            temperature: opts.temperature.unwrap_or(cfg.temperature),
            top_p: opts.top_p.unwrap_or(cfg.top_p),
            draft_ctrl: Controller::for_policy(&cfg.policy),
        }
    }

    /// Snapshot a released slot (the suspend path): the Slot's host
    /// state *is* the sequence's complete identity.
    pub(crate) fn from_slot(slot: Slot) -> SuspendedSeq {
        SuspendedSeq {
            prompt: slot.state.prompt,
            generated: slot.state.generated,
            logp_sum: slot.state.logp_sum,
            rng_draft: slot.rng_draft,
            rng_accept: slot.rng_accept,
            max_new_tokens: slot.max_new_tokens,
            temperature: slot.temperature,
            top_p: slot.top_p,
            draft_ctrl: slot.draft_ctrl,
        }
    }

    /// Rebuild a slot under a fresh [`SeqId`] (the resume path): the
    /// restored RNG streams, params and budget plus a
    /// [`SeqState::resumed`] ragged restart make the continuation
    /// byte-identical to never having been suspended once the device KV
    /// is recomputed.
    pub(crate) fn into_slot(self, id: SeqId) -> Slot {
        Slot {
            id,
            state: SeqState::resumed(self.prompt, self.generated,
                                     self.logp_sum),
            rng_draft: self.rng_draft,
            rng_accept: self.rng_accept,
            max_new_tokens: self.max_new_tokens,
            temperature: self.temperature,
            top_p: self.top_p,
            draft_ctrl: self.draft_ctrl,
        }
    }

    /// Output bytes verified before the suspension.
    pub fn tokens_generated(&self) -> usize {
        self.generated.len()
    }

    /// Length of the verified context (`prompt ‖ generated`) a resume
    /// must recompute; must fit `manifest.prefill_p` to be resumable.
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// The verified context bytes (`prompt ‖ generated`) a resume must
    /// rebuild row KV for — what a prefix-cache lookup keys on.
    pub fn context(&self) -> Vec<u8> {
        let mut ctx = self.prompt.clone();
        ctx.extend_from_slice(&self.generated);
        ctx
    }

    /// Collapse into a plain (still `Running`) sequence state — what a
    /// serving layer reports when it must answer a request whose
    /// sequence is parked (time-budget expiry, shutdown) without
    /// resuming it.
    pub fn into_state(self) -> SeqState {
        SeqState::resumed(self.prompt, self.generated, self.logp_sum)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(id: SeqId, prompt: Vec<u8>) -> Slot {
        let last = *prompt.last().unwrap();
        let len = prompt.len() as i32;
        Slot {
            id,
            state: SeqState::new(prompt, last, len),
            rng_draft: Pcg32::new(0, 2 * id),
            rng_accept: Pcg32::new(0, 2 * id + 1),
            max_new_tokens: 8,
            temperature: 1.0,
            top_p: 1.0,
            draft_ctrl: Controller::for_policy(
                &crate::spec::Policy::Heuristic),
        }
    }

    #[test]
    fn step_report_default_is_idle() {
        let r = StepReport::default();
        assert_eq!(r.active, 0);
        assert!(r.events.is_empty() && r.finished.is_empty());
    }

    #[test]
    fn flops_count_live_rows_only() {
        // Regression for the PAD metrics skew: Husk (released) and Shadow
        // (padding) rows used to accrue draft/verify FLOPs — the fused
        // artifact does compute them, but they serve no request, so
        // charging them inflated PAD throughput/utilization.
        let mut finished = slot(2, vec![4, 5]);
        finished.state.finish_at(FinishReason::Eos, 1.0);
        let rows = [
            Row::Seq(slot(0, vec![1, 2, 3])), // live: the only countable
            Row::Husk(SeqState::new(vec![9, 9], 9, 2)), // retired
            Row::Shadow(slot(1, vec![7, 8])),           // padding
            Row::Seq(finished), // finished-but-unretired: not served work
            Row::Free,
        ];
        let live = live_row_states(&rows);
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].prompt, vec![1, 2, 3]);
    }

    #[test]
    fn suspended_husk_rows_charge_nothing() {
        // A PAD preemption husks the row with a *still-Running* state
        // (unlike a retire husk, which is finished). It serves no request
        // while suspended, so FLOP/token accounting must skip it — the
        // preemption variant of the PAD metrics-skew regression.
        let suspended_husk = SeqState::new(vec![3, 4, 5], 5, 3);
        assert!(suspended_husk.active(), "suspend husks stay Running");
        let rows = [
            Row::Seq(slot(0, vec![1, 2])),
            Row::Husk(suspended_husk),
        ];
        let live = live_row_states(&rows);
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].prompt, vec![1, 2]);
    }

    #[test]
    fn all_padding_batch_counts_zero_live_rows() {
        // A drained-but-unreset PAD bucket (husks + still-running shadows)
        // must charge nothing.
        let rows = [
            Row::Husk(SeqState::new(vec![1], 1, 1)),
            Row::Shadow(slot(0, vec![2, 3])),
        ];
        assert!(live_row_states(&rows).is_empty());
    }

    #[test]
    fn fresh_suspended_seq_round_trips_into_state() {
        // SuspendedSeq::fresh == "admitted then suspended before any
        // step": zero progress, budget/params resolved against the
        // config, and into_state() reconstructs a fresh-admit SeqState.
        let cfg = SpecConfig::default();
        let opts = AdmitOpts {
            max_new_tokens: Some(7),
            temperature: Some(1.5),
            ..AdmitOpts::default()
        };
        let susp = SuspendedSeq::fresh(&[9, 8, 7], 42, &opts, &cfg);
        assert_eq!(susp.tokens_generated(), 0);
        assert_eq!(susp.context_len(), 3);
        assert_eq!(susp.max_new_tokens, 7);
        assert_eq!(susp.temperature, 1.5);
        assert_eq!(susp.top_p, cfg.top_p); // unset -> config default
        let st = susp.into_state();
        let fresh = SeqState::new(vec![9, 8, 7], 7, 3);
        assert_eq!(st.main_len, fresh.main_len);
        assert_eq!(st.pending_main, fresh.pending_main);
        assert!(st.active());
    }

    #[test]
    fn slot_snapshot_round_trip_preserves_identity() {
        // from_slot ∘ into_slot is the suspend/resume (and re-bucket)
        // host identity: bytes, RNG positions, params and budget all
        // survive; only the SeqId and the ragged restart differ.
        let mut s = slot(3, vec![10, 11, 12]);
        s.state.generated = vec![20, 21];
        s.state.logp_sum = -1.5;
        s.rng_draft.next_f32(); // advance the streams off their start
        s.rng_accept.next_f32();
        s.draft_ctrl.observe(0); // learn: shrink off the l0 start
        s.draft_ctrl.observe(0);
        let learned = s.draft_ctrl.current();
        assert_ne!(learned, Controller::for_policy(
            &crate::spec::Policy::Heuristic).current());
        let mut rng_d = s.rng_draft.clone();
        let mut rng_a = s.rng_accept.clone();
        let mut back = SuspendedSeq::from_slot(s).into_slot(9);
        assert_eq!(back.id, 9);
        assert_eq!(back.state.prompt, vec![10, 11, 12]);
        assert_eq!(back.state.generated, vec![20, 21]);
        assert_eq!(back.state.logp_sum, -1.5);
        assert_eq!(back.state.main_len, 4); // context - 1 ragged restart
        assert_eq!(back.max_new_tokens, 8);
        assert_eq!(back.rng_draft.next_u32(), rng_d.next_u32());
        assert_eq!(back.rng_accept.next_u32(), rng_a.next_u32());
        assert_eq!(back.draft_ctrl.current(), learned,
                   "resumes at the learned draft length, not l0");
    }

    #[test]
    fn admit_opts_sampling_overrides_are_range_checked() {
        let ok = |o: AdmitOpts| o.validate().is_ok();
        assert!(ok(AdmitOpts::default()));
        assert!(ok(AdmitOpts { temperature: Some(0.0),
                               ..AdmitOpts::default() })); // warp clamps
        assert!(ok(AdmitOpts { temperature: Some(2.5),
                               top_p: Some(1.0),
                               ..AdmitOpts::default() }));
        for bad in [
            AdmitOpts { top_p: Some(0.0), ..AdmitOpts::default() },
            AdmitOpts { top_p: Some(-0.5), ..AdmitOpts::default() },
            AdmitOpts { top_p: Some(1.5), ..AdmitOpts::default() },
            AdmitOpts { top_p: Some(f32::NAN), ..AdmitOpts::default() },
            AdmitOpts { temperature: Some(-1.0),
                        ..AdmitOpts::default() },
            AdmitOpts { temperature: Some(f32::INFINITY),
                        ..AdmitOpts::default() },
            AdmitOpts { temperature: Some(f32::NAN),
                        ..AdmitOpts::default() },
        ] {
            assert!(bad.validate().is_err(), "accepted: {bad:?}");
        }
    }
}
