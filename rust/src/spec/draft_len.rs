//! Draft-length control: the paper's **Algorithm 1** plus the fixed-length
//! baselines it is ablated against (Table 6).
//!
//! Rationale (paper §3.2): grow the draft when the sequence accepted
//! everything last step; shrink it otherwise, faster when the current
//! draft is long and on consecutive shrinks — but never below the
//! acceptance just observed.
//!
//! # Per-sequence controllers
//!
//! BASS adapts the draft length from *per-sequence* acceptance, so the
//! unit of control here is one sequence: [`Controller`] is the
//! clonable per-row state the engine keeps in every slot (and snapshots
//! into a `SuspendedSeq`, so a preempted sequence resumes at its learned
//! draft length). Each step the engine asks every live row's controller
//! for its own `l_i`, buckets it (`manifest.bucket_k`), drafts that row
//! at `k_i`, and feeds back **only that row's** accepted count — a
//! sequence's draft-length trajectory is a pure function of its own
//! acceptance history, never of co-batch composition.
//!
//! The batch-wide [`DraftLenPolicy`] trait and its [`Heuristic`] /
//! [`Fixed`] impls remain as the literal Algorithm-1 reference (observe
//! the whole batch's accepted counts, one global `l`): benches and
//! ablations that want the paper's original batch-global variant keep
//! using it, and [`Controller`] delegates to the same update rule with a
//! single-row observation.

use super::config::Policy;

/// A policy choosing a batch-global draft length (the paper's original
/// Algorithm-1 formulation; the engine itself now runs one
/// [`Controller`] per sequence).
pub trait DraftLenPolicy {
    /// Draft length to use for the next speculative step.
    fn current(&self) -> usize;
    /// Observe the per-sequence accepted counts of the last step.
    fn observe(&mut self, accepted: &[usize]);
    fn name(&self) -> String;
}

/// Paper Algorithm 1 with its published constants
/// (l0 = 7, l_incre = 2, l_mod = 10, l_limit = 32).
#[derive(Debug, Clone)]
pub struct Heuristic {
    l: usize,
    s: usize,
    pub l0: usize,
    pub l_incre: usize,
    pub l_mod: usize,
    pub l_limit: usize,
}

impl Heuristic {
    pub fn paper() -> Heuristic {
        Heuristic::new(7, 2, 10, 32)
    }

    /// Constants scaled to this testbed's exported bucket range
    /// (l_limit = 16 matches `DRAFT_K_BUCKETS`; see DESIGN.md §2).
    pub fn testbed() -> Heuristic {
        Heuristic::new(7, 2, 10, 16)
    }

    pub fn new(l0: usize, l_incre: usize, l_mod: usize, l_limit: usize)
               -> Heuristic {
        assert!(l0 >= 1 && l_limit >= l0);
        Heuristic { l: l0, s: 0, l0, l_incre, l_mod, l_limit }
    }
}

impl DraftLenPolicy for Heuristic {
    fn current(&self) -> usize {
        self.l
    }

    fn observe(&mut self, accepted: &[usize]) {
        let xmax = accepted.iter().copied().max().unwrap_or(0);
        if xmax == self.l {
            // The whole draft was accepted: grow.
            self.l = (self.l + self.l_incre).min(self.l_limit);
            self.s = 0;
        } else {
            // Shrink: faster when long, faster on consecutive shrinks,
            // but never below the observed acceptance (or 1).
            let dec = self.l.div_ceil(self.l_mod) + self.s;
            let next = self.l as i64 - dec as i64;
            self.l = next.max(1).max(xmax as i64) as usize;
            self.s = 1;
        }
        debug_assert!((1..=self.l_limit).contains(&self.l));
    }

    fn name(&self) -> String {
        format!("heuristic(l0={},inc={},mod={},lim={})", self.l0,
                self.l_incre, self.l_mod, self.l_limit)
    }
}

/// Constant draft length (the "fixed draft size k" rows of Table 6).
#[derive(Debug, Clone)]
pub struct Fixed(pub usize);

impl DraftLenPolicy for Fixed {
    fn current(&self) -> usize {
        self.0
    }

    fn observe(&mut self, _accepted: &[usize]) {}

    fn name(&self) -> String {
        format!("fixed({})", self.0)
    }
}

/// Per-sequence draft-length state: one Algorithm-1 instance (or a
/// fixed length) owned by a single sequence, observing **its own**
/// accepted counts only. Clonable so the engine can snapshot it into a
/// `SuspendedSeq` and carry it through suspend/resume and live
/// re-bucketing — a preempted sequence resumes at its learned length.
#[derive(Debug, Clone)]
pub enum Controller {
    Heuristic(Heuristic),
    Fixed(usize),
}

impl Controller {
    /// The controller a fresh admission under `policy` starts with.
    pub fn for_policy(policy: &Policy) -> Controller {
        match policy {
            Policy::Heuristic => {
                Controller::Heuristic(Heuristic::testbed())
            }
            Policy::Fixed(k) => Controller::Fixed(*k),
        }
    }

    /// This sequence's draft length for the next step (unbucketized —
    /// the engine buckets it against the exported draft artifacts).
    pub fn current(&self) -> usize {
        match self {
            Controller::Heuristic(h) => h.current(),
            Controller::Fixed(k) => *k,
        }
    }

    /// Feed back this sequence's own accepted count from the last step
    /// (Algorithm 1 with a single-row observation).
    pub fn observe(&mut self, accepted: usize) {
        if let Controller::Heuristic(h) = self {
            h.observe(&[accepted]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_on_full_accept() {
        let mut h = Heuristic::paper();
        assert_eq!(h.current(), 7);
        h.observe(&[3, 7]); // one sequence accepted all 7
        assert_eq!(h.current(), 9);
        h.observe(&[9, 2]);
        assert_eq!(h.current(), 11);
    }

    #[test]
    fn caps_at_limit() {
        let mut h = Heuristic::paper();
        for _ in 0..40 {
            let l = h.current();
            h.observe(&[l]);
        }
        assert_eq!(h.current(), 32);
    }

    #[test]
    fn shrinks_and_accelerates() {
        let mut h = Heuristic::new(20, 2, 10, 32);
        h.observe(&[0, 1]); // miss: dec = ceil(20/10) + 0 = 2 -> 18
        assert_eq!(h.current(), 18);
        h.observe(&[0, 0]); // consecutive: dec = ceil(18/10) + 1 = 3 -> 15
        assert_eq!(h.current(), 15);
        h.observe(&[1, 0]); // dec = 2 + 1 = 3 -> 12
        assert_eq!(h.current(), 12);
    }

    #[test]
    fn never_below_max_accepted() {
        let mut h = Heuristic::new(8, 2, 10, 32);
        h.observe(&[6, 2]); // dec = 1, would be 7; max accepted 6 < 7
        assert_eq!(h.current(), 7);
        h.observe(&[6, 6]); // dec = 1 + 1 = 2 -> 5, clamped up to 6
        assert_eq!(h.current(), 6);
    }

    #[test]
    fn never_below_one() {
        let mut h = Heuristic::new(1, 2, 10, 32);
        for _ in 0..10 {
            h.observe(&[0]);
            assert!(h.current() >= 1);
        }
    }

    /// Hand-rolled property sweep: for random acceptance patterns the
    /// invariants of Algorithm 1 hold at every step.
    #[test]
    fn property_invariants_random_walk() {
        use crate::sampling::Pcg32;
        let mut rng = Pcg32::new(11, 4);
        for _ in 0..200 {
            let mut h = Heuristic::testbed();
            for _ in 0..100 {
                let l = h.current();
                let b = 1 + (rng.next_u32() % 8) as usize;
                let accepted: Vec<usize> = (0..b)
                    .map(|_| (rng.next_u32() as usize) % (l + 1))
                    .collect();
                let xmax = *accepted.iter().max().unwrap();
                let prev = h.current();
                h.observe(&accepted);
                let cur = h.current();
                assert!((1..=16).contains(&cur));
                assert!(cur >= xmax.min(16), "dropped below max accepted");
                if xmax == prev {
                    assert!(cur >= prev, "must not shrink on full accept");
                } else {
                    assert!(cur <= prev.max(xmax), "must not grow on miss");
                }
            }
        }
    }

    #[test]
    fn fixed_is_fixed() {
        let mut f = Fixed(6);
        f.observe(&[6, 6]);
        f.observe(&[0]);
        assert_eq!(f.current(), 6);
    }

    // -- per-sequence controllers -----------------------------------------

    #[test]
    fn controller_tracks_policy() {
        let mut c = Controller::for_policy(&Policy::Fixed(5));
        c.observe(5);
        c.observe(0);
        assert_eq!(c.current(), 5, "fixed controller never moves");
        let h = Controller::for_policy(&Policy::Heuristic);
        assert_eq!(h.current(), Heuristic::testbed().current());
    }

    #[test]
    fn controller_matches_single_row_heuristic() {
        // A Controller IS Algorithm 1 observing one row: feeding the
        // same per-step accepted counts to both must trace identically.
        let mut c = Controller::for_policy(&Policy::Heuristic);
        let mut h = Heuristic::testbed();
        for acc in [0usize, 3, 7, 9, 11, 0, 0, 2, 16, 16, 1] {
            let a = acc.min(c.current());
            c.observe(a);
            h.observe(&[a]);
            assert_eq!(c.current(), h.current());
        }
    }

    #[test]
    fn controllers_are_independent_across_sequences() {
        // Two sequences with different acceptance regimes diverge — the
        // whole point of going per-row: a cold row shrinks while a hot
        // one grows, regardless of co-batching.
        let mut hot = Controller::for_policy(&Policy::Heuristic);
        let mut cold = Controller::for_policy(&Policy::Heuristic);
        for _ in 0..6 {
            let l = hot.current();
            hot.observe(l); // always full accept
            cold.observe(0); // never accepts
        }
        assert_eq!(hot.current(), 16, "hot row grows to the limit");
        assert_eq!(cold.current(), 1, "cold row shrinks to 1");
    }

    #[test]
    fn controller_clone_preserves_learned_state() {
        // The suspend/resume carry: a cloned controller resumes exactly
        // where the original stood (same l, same shrink streak).
        let mut c = Controller::for_policy(&Policy::Heuristic);
        c.observe(0);
        c.observe(0);
        let mut snap = c.clone();
        assert_eq!(snap.current(), c.current());
        c.observe(1);
        snap.observe(1);
        assert_eq!(snap.current(), c.current(), "same trajectory after");
    }
}
