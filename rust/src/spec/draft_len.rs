//! Draft-length control: the paper's **Algorithm 1** plus the fixed-length
//! baselines it is ablated against (Table 6).
//!
//! Rationale (paper §3.2): grow the draft when at least one sequence
//! accepted everything last step; shrink it otherwise, faster when the
//! current draft is long and on consecutive shrinks — but never below the
//! best acceptance observed in the batch.

/// A policy choosing the next step's (uniform-across-batch) draft length.
pub trait DraftLenPolicy {
    /// Draft length to use for the next speculative step.
    fn current(&self) -> usize;
    /// Observe the per-sequence accepted counts of the last step.
    fn observe(&mut self, accepted: &[usize]);
    fn name(&self) -> String;
}

/// Paper Algorithm 1 with its published constants
/// (l0 = 7, l_incre = 2, l_mod = 10, l_limit = 32).
#[derive(Debug, Clone)]
pub struct Heuristic {
    l: usize,
    s: usize,
    pub l0: usize,
    pub l_incre: usize,
    pub l_mod: usize,
    pub l_limit: usize,
}

impl Heuristic {
    pub fn paper() -> Heuristic {
        Heuristic::new(7, 2, 10, 32)
    }

    /// Constants scaled to this testbed's exported bucket range
    /// (l_limit = 16 matches `DRAFT_K_BUCKETS`; see DESIGN.md §2).
    pub fn testbed() -> Heuristic {
        Heuristic::new(7, 2, 10, 16)
    }

    pub fn new(l0: usize, l_incre: usize, l_mod: usize, l_limit: usize)
               -> Heuristic {
        assert!(l0 >= 1 && l_limit >= l0);
        Heuristic { l: l0, s: 0, l0, l_incre, l_mod, l_limit }
    }
}

impl DraftLenPolicy for Heuristic {
    fn current(&self) -> usize {
        self.l
    }

    fn observe(&mut self, accepted: &[usize]) {
        let xmax = accepted.iter().copied().max().unwrap_or(0);
        if xmax == self.l {
            // At least one sequence accepted the whole draft: grow.
            self.l = (self.l + self.l_incre).min(self.l_limit);
            self.s = 0;
        } else {
            // Shrink: faster when long, faster on consecutive shrinks,
            // but never below the best acceptance (or 1).
            let dec = self.l.div_ceil(self.l_mod) + self.s;
            let next = self.l as i64 - dec as i64;
            self.l = next.max(1).max(xmax as i64) as usize;
            self.s = 1;
        }
        debug_assert!((1..=self.l_limit).contains(&self.l));
    }

    fn name(&self) -> String {
        format!("heuristic(l0={},inc={},mod={},lim={})", self.l0,
                self.l_incre, self.l_mod, self.l_limit)
    }
}

/// Constant draft length (the "fixed draft size k" rows of Table 6).
#[derive(Debug, Clone)]
pub struct Fixed(pub usize);

impl DraftLenPolicy for Fixed {
    fn current(&self) -> usize {
        self.0
    }

    fn observe(&mut self, _accepted: &[usize]) {}

    fn name(&self) -> String {
        format!("fixed({})", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_on_full_accept() {
        let mut h = Heuristic::paper();
        assert_eq!(h.current(), 7);
        h.observe(&[3, 7]); // one sequence accepted all 7
        assert_eq!(h.current(), 9);
        h.observe(&[9, 2]);
        assert_eq!(h.current(), 11);
    }

    #[test]
    fn caps_at_limit() {
        let mut h = Heuristic::paper();
        for _ in 0..40 {
            let l = h.current();
            h.observe(&[l]);
        }
        assert_eq!(h.current(), 32);
    }

    #[test]
    fn shrinks_and_accelerates() {
        let mut h = Heuristic::new(20, 2, 10, 32);
        h.observe(&[0, 1]); // miss: dec = ceil(20/10) + 0 = 2 -> 18
        assert_eq!(h.current(), 18);
        h.observe(&[0, 0]); // consecutive: dec = ceil(18/10) + 1 = 3 -> 15
        assert_eq!(h.current(), 15);
        h.observe(&[1, 0]); // dec = 2 + 1 = 3 -> 12
        assert_eq!(h.current(), 12);
    }

    #[test]
    fn never_below_max_accepted() {
        let mut h = Heuristic::new(8, 2, 10, 32);
        h.observe(&[6, 2]); // dec = 1, would be 7; max accepted 6 < 7
        assert_eq!(h.current(), 7);
        h.observe(&[6, 6]); // dec = 1 + 1 = 2 -> 5, clamped up to 6
        assert_eq!(h.current(), 6);
    }

    #[test]
    fn never_below_one() {
        let mut h = Heuristic::new(1, 2, 10, 32);
        for _ in 0..10 {
            h.observe(&[0]);
            assert!(h.current() >= 1);
        }
    }

    /// Hand-rolled property sweep: for random acceptance patterns the
    /// invariants of Algorithm 1 hold at every step.
    #[test]
    fn property_invariants_random_walk() {
        use crate::sampling::Pcg32;
        let mut rng = Pcg32::new(11, 4);
        for _ in 0..200 {
            let mut h = Heuristic::testbed();
            for _ in 0..100 {
                let l = h.current();
                let b = 1 + (rng.next_u32() % 8) as usize;
                let accepted: Vec<usize> = (0..b)
                    .map(|_| (rng.next_u32() as usize) % (l + 1))
                    .collect();
                let xmax = *accepted.iter().max().unwrap();
                let prev = h.current();
                h.observe(&accepted);
                let cur = h.current();
                assert!((1..=16).contains(&cur));
                assert!(cur >= xmax.min(16), "dropped below max accepted");
                if xmax == prev {
                    assert!(cur >= prev, "must not shrink on full accept");
                } else {
                    assert!(cur <= prev.max(xmax), "must not grow on miss");
                }
            }
        }
    }

    #[test]
    fn fixed_is_fixed() {
        let mut f = Fixed(6);
        f.observe(&[6, 6]);
        f.observe(&[0]);
        assert_eq!(f.current(), 6);
    }
}
