//! The paper's contribution: batched speculative sampling (§3), layered
//! so the decode loop is written once and the execution modes plug in:
//!
//! * `config` — [`SpecConfig`] / [`ExecMode`] / [`Policy`]: the
//!   batch-wide knobs. The mode is data here; it becomes behavior only
//!   inside `backend`.
//! * `seq` (internal) — the slot/row model: per-sequence state, RNG
//!   streams and sampling params ([`AdmitOpts`] overrides), the
//!   Husk/Shadow row lifecycle, and the [`SuspendedSeq`] host snapshot
//!   that preemption *and* live re-bucketing rebuild rows from.
//! * `backend` (internal) — the **mode-agnostic exec backend
//!   contract**: `PadBackend` (one fused artifact per batch bucket) and
//!   `SplitBackend` (per-sequence B=1 artifacts) own the device caches
//!   and implement admission binding, the lazy start, step execution,
//!   row release and — PAD only — live re-bucketing. No code outside
//!   the backend implementations branches on [`ExecMode`].
//! * [`draft_len`] — Algorithm 1 and fixed-length baselines; the
//!   engine runs one per-sequence [`Controller`] per slot (adaptive γ
//!   per row), so draft lengths track each sequence's own acceptance.
//! * `engine` — the mode-free batch orchestrator: the resumable
//!   [`SpecBatch`] step API (admit / step / retire, suspend / resume by
//!   recompute, and [`SpecBatch::rebucket`] — grow or shrink a running
//!   PAD bucket without a drain, no artifact rebuild).
//! * `oneshot` — the [`SpecEngine`] convenience wrapper (admit a prompt
//!   batch, step to completion, aggregate a [`SpecResult`]).

pub mod draft_len;

mod backend;
mod config;
mod engine;
mod oneshot;
mod seq;

pub use config::{ExecMode, Policy, SpecConfig};
pub use draft_len::{Controller, DraftLenPolicy, Fixed, Heuristic};
pub use engine::{Rebucket, SpecBatch};
pub use oneshot::{SpecEngine, SpecResult};
pub use seq::{AdmitOpts, SeqEvent, SeqId, StepReport, SuspendedSeq};
