//! The paper's contribution: batched speculative sampling (§3).
//!
//! * [`draft_len`] — Algorithm 1 and fixed-length baselines.
//! * [`engine`] — the BASS decode loop with PAD/SPLIT execution.

pub mod draft_len;
mod engine;

pub use draft_len::{DraftLenPolicy, Fixed, Heuristic};
pub use engine::{ExecMode, Policy, SpecConfig, SpecEngine, SpecResult};
