//! The paper's contribution: batched speculative sampling (§3).
//!
//! * [`draft_len`] — Algorithm 1 and fixed-length baselines.
//! * [`engine`] — the BASS decode loop, exposed both as the resumable
//!   [`SpecBatch`] step API (admit / step / retire, plus suspend / resume
//!   by recompute — what the coordinator's continuous batching and
//!   preemptive scheduling drive) and as the one-shot [`SpecEngine`]
//!   convenience wrapper.

pub mod draft_len;
mod engine;

pub use draft_len::{DraftLenPolicy, Fixed, Heuristic};
pub use engine::{AdmitOpts, ExecMode, Policy, SeqEvent, SeqId, SpecBatch,
                 SpecConfig, SpecEngine, SpecResult, StepReport,
                 SuspendedSeq};
