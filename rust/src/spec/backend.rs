//! Mode-agnostic exec backends: everything that used to branch on
//! [`ExecMode`] inside the spec engine lives behind the [`Backend`]
//! trait, so the batch orchestrator ([`super::SpecBatch`]) is written
//! once against the contract below and [`PadBackend`] /
//! [`SplitBackend`] / [`PackedBackend`] own the device caches and the
//! mode-specific row lifecycle.
//!
//! # The backend contract
//!
//! A backend owns the device KV caches and answers five questions for
//! the orchestrator, which owns the [`Row`] table and all host-side
//! sequence state:
//!
//! 1. **Where can work land?** [`Backend::free_slots`] /
//!    [`Backend::admissible_row`]. SPLIT: `Free` rows. PAD before the
//!    lazy start: `Free` rows of the capacity table. A *running* PAD
//!    bucket: reusable `Husk`/`Shadow` rows of the fused bucket.
//! 2. **How does a context get device KV?** [`Backend::bind_row`] binds
//!    `ctx` (a fresh prompt, or a resume's `prompt ‖ generated`) to a
//!    row *before* the orchestrator installs its [`Slot`]. SPLIT runs a
//!    per-slot B=1 prefill; a running PAD bucket scatter-prefills the
//!    row via the v3 `prefill_scatter` artifacts; a not-yet-started PAD
//!    batch defers to [`Backend::start`], which bucketizes (headroom
//!    applied), pads with `Shadow` rows and runs one fused prefill.
//! 3. **How does a step execute?** [`Backend::draft`] /
//!    [`Backend::verify`] take the orchestrator-assembled per-row I/O
//!    ([`DraftIo`] / [`VerifyIo`]) — **ragged**: each row carries its
//!    own draft length `k_i` (`klens`) and verify width `q_i = k_i + 1`
//!    (`qlens`) next to the launch-width `k`/`q`. PAD runs the fused
//!    artifact at the launch width and rows past their own `k_i` are
//!    masked by never being read; SPLIT runs each row's B=1 artifact at
//!    that row's *own* `k_i`/`q_i` bucket, so short rows really skip
//!    the FLOPs; the stub honors the raggedness exactly.
//! 4. **How does a row free?** [`Backend::release`] takes the [`Slot`]
//!    out (retire/suspend): SPLIT drops the slot's caches and leaves
//!    `Free`; a running PAD bucket leaves a `Husk` so the fused
//!    artifact keeps valid length inputs. [`Backend::reset`] drops all
//!    device state on drain (the orchestrator resets rows and clock).
//! 5. **Can the live batch re-shape?** [`Backend::live_bucket`] /
//!    [`Backend::rebucket`]. Only PAD has a fused bucket:
//!    re-bucketing re-encodes every carried `Seq` row's context with
//!    one fused prefill at the new bucket — the same bitwise recompute
//!    primitive as resume, so carried sequences are byte-exact — and
//!    replaces `Husk`/`Shadow` rows with fresh `Shadow` grow-room.
//!    Suspended sequences handed to `rebucket` ride that same fused
//!    prefill as fresh `Seq` rows (no separate scatter prefill per
//!    resume). The old caches are replaced only after the new prefill
//!    succeeds, so a device failure leaves the running bucket intact.
//!    SPLIT declines (`live_bucket` = None): its slots are
//!    per-sequence, there is nothing to re-shape.
//!
//! # The packed contract (`ExecMode::Packed`)
//!
//! [`PackedBackend`] keeps PAD's *row lifecycle* — fused caches, lazy
//! bucketized start, `Husk`/`Shadow` reuse, scatter-prefill binds, live
//! re-bucketing — but swaps the *step ABI*: instead of launching the
//! `[B, q]` rectangle at the launch width, each step packs the ragged
//! rows back-to-back into one offset-addressed token stream:
//!
//! - **Verify**: row i's `q_i` tokens sit at `qoffs[i]..qoffs[i+1]` of
//!   a `[1, C]` stream with `C = B · q'`, `q'` the smallest
//!   `Manifest::bucket_packed_q` ladder member holding `Σq_i` (always
//!   `q' ≤` the launch `q`, so C never exceeds PAD's rectangle).
//!   Dense FLOPs scale with `C ≈ Σq_i` instead of `B · max_i q_i`;
//!   rows with `q_i = 0` (Husks, Shadows past their budget) cost
//!   nothing. Logits come back in the same packed layout and are
//!   unpacked to the launch-width `[B, q, V]` buffer the orchestrator
//!   indexes, so acceptance is bitwise-identical to PAD.
//! - **Draft**: uniforms/outputs use a packed-prefix `[B·k]` layout
//!   addressed by `koffs`; the graph still computes the `[B, k]`
//!   rectangle (the unrolled draft loop masks per-row), so the draft
//!   saving is the launch unification, not FLOPs — the accounting below
//!   stays honest about that.
//!
//! On a host-only (stub) engine the packed backend performs the stub
//! backend's deterministic compute *in the packed layout* and unpacks,
//! so `Packed` serves byte-identically to `Stub` on machines without
//! the PJRT binding while still exercising the offset math end to end.
//!
//! # Launch-FLOP accounting
//!
//! Every backend's `draft`/`verify` also accrues
//! [`FlopCounter::add_launch`]`(launch, padded_launch)`: `launch` is
//! what the backend actually dispatched, `padded_launch` what the PAD
//! rectangle of the same batch would have been. PAD and the stub launch
//! the rectangle (`launch == padded_launch`); SPLIT launches each
//! stepping row at its own bucket; packed verify launches the `C`-token
//! stream (full per-row cost at `q_i` plus dense-only cost for the
//! `C - Σq_i` capacity filler). The gap is the pad-FLOP saving the
//! serving report surfaces (`BENCH_serving.json` `"flops"`).
//!
//! # Row-copy contract
//!
//! [`Backend::copy_row`] gives a destination row the same device KV as
//! a donor row whose [`SeqState`] context is identical — the device
//! primitive behind fan-out prefill sharing and prefix-cache reuse.
//! Because each cache position's KV is a pure function of its token
//! prefix, a copied row is **bitwise identical** to a freshly
//! prefilled one; the Python parity suite pins this per mode.
//!
//! Preconditions (the orchestrator guarantees both): the donor holds a
//! live, *unstepped-or-equal* context covering the destination's full
//! admission context, and the destination row was returned by
//! [`Backend::admissible_row`]. Per mode:
//!
//! - **PAD / Packed (device), running bucket**: one weightless v5
//!   `kv_row_copy` launch per model over the fused store (resolve +
//!   compile first, so a stale artifact set rejects only this copy and
//!   leaves the running batch intact — same containment as
//!   [`scatter_bind`]).
//! - **PAD / Packed / Stub, before the lazy start**: a no-op, exactly
//!   like [`Backend::bind_row`] — the fused start encodes the
//!   destination row's own context, and the rectangle is launched
//!   whether or not rows share a prompt, so there is nothing to save.
//! - **Stub / host-only Packed, started**: no device KV exists (the
//!   host [`SeqState`] *is* the sequence identity), so the copy is
//!   free; the FLOP accounting still charges the device-equivalent
//!   row-copy cost — the same stands-in-for-PAD convention the stub's
//!   launch accounting uses.
//! - **SPLIT**: per-slot B=1 caches have no shared store, so the donor
//!   slot's cache set is cloned buffer-by-buffer through a host
//!   round-trip (`Engine::clone_cache_set`) — bitwise-exact, and still
//!   far cheaper than re-running the prompt.
//!
//! Accounting: a successful copy charges
//! [`FlopCounter::add_row_copy`] for both models (launch == padded —
//! the copy touches one row regardless of bucket width) instead of
//! `add_prefill`, and records a [`SpanKind::RowCopy`] span. The
//! fan-out identity — admitting n siblings costs exactly one prefill
//! plus n−1 row copies — holds in every started mode.
//!
//! The *only* place an [`ExecMode`] becomes concrete is [`make`]; no
//! other code in `spec/` may match on the mode.

use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::PjRtBuffer;

use crate::flops::{step_flops, FlopCounter};
use crate::kv::SeqState;
use crate::obs::{SpanKind, Tracer};
use crate::runtime::{Engine, ModelInfo};
use crate::sampling::Pcg32;

use super::config::{ExecMode, SpecConfig};
use super::draft_len::Controller;
use super::seq::{Row, Slot};

/// What the orchestrator lends a backend for device work: the engine,
/// the batch configuration, and the prefill accounting sinks (draft and
/// verify timing stays orchestrator-side, around the step calls).
pub(super) struct ExecCtx<'a> {
    pub engine: &'a Engine,
    pub cfg: &'a SpecConfig,
    pub main_info: &'a ModelInfo,
    pub draft_info: &'a ModelInfo,
    pub prefill_secs: &'a mut f64,
    pub flops: &'a mut FlopCounter,
    /// Span recorder (a cheap handle clone; disabled = no-op). Backends
    /// record `fused_prefill` / `scatter_bind` spans here; draft and
    /// verify spans stay orchestrator-side, around the step calls.
    pub tracer: Tracer,
}

/// Orchestrator-assembled per-row inputs of one fused draft call
/// (`b = stepping.len()` rows; see `Engine::draft` for the layouts).
pub(super) struct DraftIo<'a> {
    /// Launch draft length: `max_i k_i` over the slot-holding rows.
    /// PAD/stub buffers (`uniforms`, returned tokens/q-dists) are laid
    /// out at this width.
    pub k: usize,
    pub tokens_in: &'a [i32],
    pub n_in: &'a [i32],
    pub dlens: &'a [i32],
    /// Per-row draft lengths `k_i` (0 for Free/Husk rows): each row's
    /// own bucketized adaptive draft length. Only positions `0..k_i` of
    /// a row's uniforms/outputs are meaningful; SPLIT executes the row
    /// at exactly this bucket.
    pub klens: &'a [i32],
    pub uniforms: &'a [f32],
    pub temps: &'a [f32],
    pub tps: &'a [f32],
    /// Rows holding a still-active sequence (SPLIT skips the rest; the
    /// fused PAD call computes every row regardless).
    pub stepping: &'a [bool],
}

/// Per-row inputs of one verify (main-model decode) call.
pub(super) struct VerifyIo<'a> {
    /// Launch verify width: launch `k + 1`; `[B,Q,V]` logits layout.
    pub q: usize,
    pub vtokens: &'a [i32],
    pub mlens: &'a [i32],
    /// Per-row verify widths `q_i = k_i + 1` (0 for Free/Husk rows):
    /// the host reads a row's logits only at `0..q_i`, with the bonus
    /// position at `q_i - 1`; SPLIT decodes the row at exactly `q_i`.
    pub qlens: &'a [i32],
    pub stepping: &'a [bool],
}

/// The exec-backend contract (see the module docs for the narrative).
pub(super) trait Backend {
    /// Device caches exist — the batch has started stepping. SPLIT is
    /// always "started" (slots own their caches); PAD flips at the lazy
    /// fused prefill.
    fn started(&self) -> bool;

    /// Rows a new admission/resume could bind right now.
    fn free_slots(&self, rows: &[Row]) -> usize;

    /// The row the next admission/resume binds to (the error names the
    /// mode-specific reason nothing is available).
    fn admissible_row(&self, rows: &[Row]) -> Result<usize>;

    /// Give `ctx` (a fresh prompt, or a resume's `prompt ‖ generated`)
    /// device KV in `row`, before the caller installs the [`Slot`].
    fn bind_row(&mut self, cx: &mut ExecCtx, rows: &[Row], row: usize,
                ctx: &[u8]) -> Result<()>;

    /// Give row `dst` the same device KV as donor row `src` (identical
    /// context — the orchestrator guarantees it), before the caller
    /// installs the [`Slot`]: the cheap alternative to
    /// [`Backend::bind_row`] behind fan-out prefill sharing and
    /// prefix-cache reuse. See the module-level "Row-copy contract".
    fn copy_row(&mut self, cx: &mut ExecCtx, rows: &[Row], src: usize,
                dst: usize) -> Result<()>;

    /// Lazy start before the first step (PAD: bucketize + shadow-pad +
    /// fused prefill; SPLIT: no-op). Only called while `!started()`.
    fn start(&mut self, cx: &mut ExecCtx, rows: &mut Vec<Row>,
             capacity: usize) -> Result<()>;

    /// One draft call over the batch; returns `([B,K] tokens, [B,K,V]
    /// q-distributions)`.
    fn draft(&mut self, cx: &mut ExecCtx, io: &DraftIo)
             -> Result<(Vec<i32>, Vec<f32>)>;

    /// One verify (main decode) call; returns `[B,Q,V]` logits.
    fn verify(&mut self, cx: &mut ExecCtx, io: &VerifyIo)
              -> Result<Vec<f32>>;

    /// Take the [`Slot`] out of a released (retired/suspended) row,
    /// leaving the mode's placeholder behind and dropping any per-slot
    /// caches.
    fn release(&mut self, rows: &mut [Row], idx: usize) -> Slot;

    /// Drop all device state (drain auto-reset); the orchestrator
    /// resets the row table and clock.
    fn reset(&mut self);

    /// Rows of the live fused bucket — `None` for SPLIT or a PAD batch
    /// that has not started.
    fn live_bucket(&self, rows: &[Row]) -> Option<usize>;

    /// Re-shape the running fused batch to `bucket` rows without a
    /// drain, folding `resumes` (already re-slotted suspended
    /// sequences) into the same fused prefill as fresh `Seq` rows;
    /// returns the number of re-encoded real rows (carried + resumed).
    fn rebucket(&mut self, _cx: &mut ExecCtx, _rows: &mut Vec<Row>,
                _bucket: usize, _resumes: Vec<Slot>) -> Result<usize> {
        bail!("this backend has no fused bucket to re-shape");
    }
}

/// The one place an [`ExecMode`] becomes a concrete backend.
///
/// `host_only` is the engine's `is_stub()`: only the packed backend is
/// dual-engine and branches on it (device artifacts vs. stub-identical
/// host compute in the packed layout). PAD/SPLIT ignore it — their
/// device calls fail fast on a stub engine — and the stub backend never
/// touches a device in the first place.
pub(super) fn make(cfg: &SpecConfig, capacity: usize, host_only: bool)
                   -> Box<dyn Backend> {
    match cfg.mode {
        ExecMode::Pad => Box::new(PadBackend { store: None }),
        ExecMode::Split => Box::new(SplitBackend {
            main: (0..capacity).map(|_| Vec::new()).collect(),
            draft: (0..capacity).map(|_| Vec::new()).collect(),
        }),
        ExecMode::Packed => Box::new(PackedBackend {
            store: None,
            started: false,
            host_only,
        }),
        ExecMode::Stub => Box::new(StubBackend { started: false }),
    }
}

/// Right-pad `ctx` into a prefill token window of `p`, tail-clamped: a
/// context longer than the window keeps its tail. The clamp only ever
/// fires for rows whose outputs are never read again (finished rows
/// carried across a live re-bucket; the shadow padding replicating
/// them) — exact-recompute preconditions reject clamping a live row.
fn encode_window(ctx: &[u8], p: usize) -> (Vec<i32>, i32) {
    let tail = if ctx.len() > p { &ctx[ctx.len() - p..] } else { ctx };
    let mut tokens = vec![0i32; p];
    for (j, &byte) in tail.iter().enumerate() {
        tokens[j] = byte as i32;
    }
    (tokens, tail.len() as i32)
}

/// Commit one bucket (re-)shape of a fused row table: keep `Seq` rows
/// in slot order, append `resumes` (re-slotted suspended sequences
/// riding the same fused prefill) as fresh `Seq` rows after them, drop
/// `Husk`/`Shadow` rows, and pad with fresh `Shadow` rows replicating
/// the last real context (tail-clamped to the `p`-byte prefill
/// window). Shared by the PAD fused prefill — which runs it only after
/// the device calls succeed, so a failure leaves a running bucket
/// intact — and the host-only stub backend, which has no device calls
/// at all. Returns the number of real rows (carried + resumed).
fn commit_bucket(cfg: &SpecConfig, p: usize, rows: &mut Vec<Row>,
                 bucket: usize, resumes: Vec<Slot>) -> Result<usize> {
    let n_real = rows.iter().filter(|r| matches!(r, Row::Seq(_))).count()
        + resumes.len();
    if n_real == 0 {
        bail!("cannot start an empty fused batch");
    }
    if bucket < n_real {
        bail!("bucket {bucket} cannot hold {n_real} occupied rows");
    }
    let mut new_rows: Vec<Row> = std::mem::take(rows)
        .into_iter()
        .filter(|r| matches!(r, Row::Seq(_)))
        .chain(resumes.into_iter().map(Row::Seq))
        .collect();
    let last_ctx = new_rows
        .iter()
        .rev()
        .find_map(|r| match r {
            Row::Seq(s) => Some(s.state.context_tail(p)),
            _ => None,
        })
        .expect("n_real >= 1");
    for i in n_real..bucket {
        let state = SeqState::new(last_ctx.clone(),
                                  *last_ctx.last().expect("non-empty"),
                                  last_ctx.len() as i32);
        new_rows.push(Row::Shadow(Slot {
            id: u64::MAX, // never reported
            state,
            rng_draft: Pcg32::new(cfg.seed, 2 * i as u64),
            rng_accept: Pcg32::new(cfg.seed, 2 * i as u64 + 1),
            max_new_tokens: cfg.max_new_tokens,
            temperature: cfg.temperature,
            top_p: cfg.top_p,
            draft_ctrl: Controller::for_policy(&cfg.policy),
        }));
    }
    *rows = new_rows;
    Ok(n_real)
}

/// Re-encode a fused batch at `bucket` rows: keep `Seq` rows (in slot
/// order), drop `Husk`/`Shadow` rows, pad with fresh `Shadow` rows
/// replicating the last real context, and run the fused prefill for
/// both models over every row's context (tail-clamped only for rows
/// whose outputs are dead — active rows are precondition-checked by
/// the caller). Commits rows and `store` **only on success**, so a
/// failed prefill leaves a running bucket untouched. Returns the
/// number of carried real rows. Shared by [`PadBackend`] and the
/// device path of [`PackedBackend`], whose cache lifecycle is PAD's.
///
/// Rows are encoded from their full `prompt ‖ generated` context, so
/// sequences resumed before the start — and every row carried across
/// a re-bucket — prefill their pre-existing output too: the bitwise
/// recompute that makes both paths byte-exact. Suspended sequences
/// handed in as `resumes` are encoded in this same launch, right
/// after the carried rows — one fused prefill covers the move *and*
/// the resumes, instead of a scatter prefill per resume afterwards.
fn fused_prefill(
    cx: &mut ExecCtx, rows: &mut Vec<Row>, bucket: usize,
    resumes: Vec<Slot>,
    store: &mut Option<(Vec<PjRtBuffer>, Vec<PjRtBuffer>)>,
) -> Result<usize> {
    let cfg = cx.cfg;
    let eng = cx.engine;
    let p = eng.manifest.prefill_p;
    let mut real_ctx: Vec<Vec<u8>> = rows
        .iter()
        .filter_map(|r| match r {
            Row::Seq(s) => Some(s.state.context_tail(p)),
            _ => None,
        })
        .collect();
    real_ctx.extend(resumes.iter().map(|s| s.state.context_tail(p)));
    let n_real = real_ctx.len();
    if n_real == 0 {
        bail!("cannot start an empty fused batch");
    }
    if bucket < n_real {
        bail!("bucket {bucket} cannot hold {n_real} occupied rows");
    }
    let last_ctx = real_ctx.last().expect("n_real >= 1").clone();
    let mut tokens = vec![0i32; bucket * p];
    let mut plens = vec![0i32; bucket];
    for i in 0..bucket {
        let ctx = if i < n_real { &real_ctx[i] } else { &last_ctx };
        let (t, l) = encode_window(ctx, p);
        tokens[i * p..(i + 1) * p].copy_from_slice(&t);
        plens[i] = l;
    }
    let t0 = Instant::now();
    let tr = cx.tracer.begin();
    let m = eng.prefill(&cfg.main_model, cfg.precision, cfg.attn,
                        bucket, &tokens, &plens)?;
    let d = eng.prefill(&cfg.draft_model, cfg.precision, cfg.attn,
                        bucket, &tokens, &plens)?;
    *cx.prefill_secs += t0.elapsed().as_secs_f64();
    cx.tracer.span(SpanKind::FusedPrefill, tr, 0, None,
                   cfg.mode.as_str(),
                   &[("bucket", bucket as f64),
                     ("rows", n_real as f64)]);
    cx.flops.add_prefill(cx.main_info, bucket, p);
    cx.flops.add_prefill(cx.draft_info, bucket, p);
    // Commit: compact Seq rows to the front, resumes after them,
    // fresh Shadow padding last (exactly the padded rows the fused
    // artifact computes anyway) — the same order the contexts were
    // encoded in above.
    let n = commit_bucket(cfg, p, rows, bucket, resumes)?;
    *store = Some((m.caches, d.caches));
    Ok(n)
}

/// Mid-flight scatter-prefill of `ctx` into a reusable row of a running
/// fused bucket (both models); shared by [`PadBackend`] and the device
/// path of [`PackedBackend`]. The row's whole KV slice is replaced, so
/// the previous occupant cannot leak into the new sequence, and no
/// other row is touched. Resolving + compiling the scatter executables
/// first means the likely failures (stale pre-v3 artifact set, bucket
/// not exported) reject only this admission/resume and leave the
/// running batch intact — as do upload failures inside
/// `prefill_into_slot`, which consumes the fused caches only at the
/// execute itself. Only an execute failure (post-donation) is
/// batch-fatal: the next step errors and the serving layer's recovery
/// path rebuilds a fresh batch.
fn scatter_bind(
    cx: &mut ExecCtx, rows: &[Row], row: usize, ctx: &[u8],
    store: &mut (Vec<PjRtBuffer>, Vec<PjRtBuffer>),
) -> Result<()> {
    let cfg = cx.cfg;
    let eng = cx.engine;
    let b = rows.len();
    eng.ensure_prefill_scatter(&cfg.main_model, cfg.precision,
                               cfg.attn, b)?;
    eng.ensure_prefill_scatter(&cfg.draft_model, cfg.precision,
                               cfg.attn, b)?;
    let p = eng.manifest.prefill_p;
    let (tokens, plen) = encode_window(ctx, p);
    let (main, draft) = store;
    let t0 = Instant::now();
    let tr = cx.tracer.begin();
    eng.prefill_into_slot(&cfg.main_model, cfg.precision, cfg.attn, b,
                          row, &tokens, plen, main)
        .context("fused scatter prefill (main model)")?;
    eng.prefill_into_slot(&cfg.draft_model, cfg.precision, cfg.attn, b,
                          row, &tokens, plen, draft)
        .context("fused scatter prefill (draft model)")?;
    *cx.prefill_secs += t0.elapsed().as_secs_f64();
    cx.tracer.span(SpanKind::ScatterBind, tr, 0, None,
                   cfg.mode.as_str(), &[("row", row as f64)]);
    cx.flops.add_prefill(cx.main_info, 1, p);
    cx.flops.add_prefill(cx.draft_info, 1, p);
    Ok(())
}

/// Mid-flight KV row copy inside a running fused bucket (both models;
/// see the module-level "Row-copy contract"); shared by [`PadBackend`]
/// and the device path of [`PackedBackend`]. Resolving + compiling the
/// weightless v5 `kv_row_copy` executables first means the likely
/// failure (stale pre-v5 artifact set) rejects only this copy and
/// leaves the running batch intact; only an execute failure
/// (post-donation) is batch-fatal, exactly like [`scatter_bind`].
fn fused_row_copy(
    cx: &mut ExecCtx, rows: &[Row], src: usize, dst: usize,
    store: &mut (Vec<PjRtBuffer>, Vec<PjRtBuffer>),
) -> Result<()> {
    let cfg = cx.cfg;
    let eng = cx.engine;
    let b = rows.len();
    eng.ensure_kv_row_copy(&cfg.main_model, cfg.precision, cfg.attn, b)?;
    eng.ensure_kv_row_copy(&cfg.draft_model, cfg.precision, cfg.attn, b)?;
    let (main, draft) = store;
    let t0 = Instant::now();
    let tr = cx.tracer.begin();
    eng.kv_row_copy(&cfg.main_model, cfg.precision, cfg.attn, b, src,
                    dst, main)
        .context("fused KV row copy (main model)")?;
    eng.kv_row_copy(&cfg.draft_model, cfg.precision, cfg.attn, b, src,
                    dst, draft)
        .context("fused KV row copy (draft model)")?;
    *cx.prefill_secs += t0.elapsed().as_secs_f64();
    record_row_copy(cx, tr, src, dst);
    Ok(())
}

/// Accounting tail every successful copy shares: the `row_copy` span
/// plus both models' copy-cost accrual ([`FlopCounter::add_row_copy`];
/// launch == padded). Host-only backends call this alone — no device
/// KV moves, but the device-equivalent cost is charged, the same
/// stands-in-for-PAD convention as the stub's launch accounting.
fn record_row_copy(cx: &mut ExecCtx, tr: Option<u64>, src: usize,
                   dst: usize) {
    cx.tracer.span(SpanKind::RowCopy, tr, 0, None, cx.cfg.mode.as_str(),
                   &[("src", src as f64), ("dst", dst as f64)]);
    cx.flops.add_row_copy(cx.main_info);
    cx.flops.add_row_copy(cx.draft_info);
}

/// Σᵢ `step_flops(info, 1, q, lens[i])` — the per-row sum both sides of
/// the launch accounting are built from (PAD's rectangle when `q` is
/// the launch width for every row).
fn rect_launch_flops(info: &ModelInfo, q: usize, lens: &[i32]) -> f64 {
    lens.iter()
        .map(|&l| step_flops(info, 1, q, l as usize))
        .sum()
}

// ---------------------------------------------------------------------
// BASS-PAD: one fused artifact padded to the batch bucket.
// ---------------------------------------------------------------------

/// Fused-bucket backend. `store` holds both models' fused cache buffers
/// once the lazy start ran; the bucket is `rows.len()` from then on.
pub(super) struct PadBackend {
    /// (main caches, draft caches); `None` until the fused prefill.
    store: Option<(Vec<PjRtBuffer>, Vec<PjRtBuffer>)>,
}

impl Backend for PadBackend {
    fn started(&self) -> bool {
        self.store.is_some()
    }

    fn free_slots(&self, rows: &[Row]) -> usize {
        if self.started() {
            // Reusable rows of the running fused bucket: retired/suspended
            // Husks and padding Shadows a mid-flight admission/resume
            // scatter-prefills over. Growing past them takes a re-bucket.
            rows.iter()
                .filter(|r| matches!(r, Row::Husk(_) | Row::Shadow(_)))
                .count()
        } else {
            rows.iter().filter(|r| r.is_free()).count()
        }
    }

    fn admissible_row(&self, rows: &[Row]) -> Result<usize> {
        if self.started() {
            rows.iter()
                .position(|r| matches!(r, Row::Husk(_) | Row::Shadow(_)))
                .ok_or_else(|| {
                    anyhow!("no reusable PAD row (bucket of {} fully \
                             live; wait for a retirement, a re-bucket, \
                             or the drain)",
                            rows.len())
                })
        } else {
            rows.iter().position(Row::is_free).ok_or_else(|| {
                anyhow!("no free slot (capacity {})", rows.len())
            })
        }
    }

    /// Mid-flight scatter-prefill of `ctx` into a reusable row of the
    /// running fused bucket (both models; see [`scatter_bind`] for the
    /// failure containment); a no-op before the lazy start, which
    /// encodes the row itself.
    fn bind_row(&mut self, cx: &mut ExecCtx, rows: &[Row], row: usize,
                ctx: &[u8]) -> Result<()> {
        match self.store.as_mut() {
            None => Ok(()), // lazy start encodes this row's context
            Some(store) => scatter_bind(cx, rows, row, ctx, store),
        }
    }

    /// Running bucket: one `kv_row_copy` launch per model on the fused
    /// store. Pre-start: a no-op like [`Backend::bind_row`] — the lazy
    /// start encodes the destination row itself.
    fn copy_row(&mut self, cx: &mut ExecCtx, rows: &[Row], src: usize,
                dst: usize) -> Result<()> {
        match self.store.as_mut() {
            None => Ok(()),
            Some(store) => fused_row_copy(cx, rows, src, dst, store),
        }
    }

    /// PAD lazy start: bucketize the admitted count (rounded up by
    /// `SpecConfig::pad_headroom` so the running bucket keeps reusable
    /// grow-room rows) and fused-prefill every row.
    fn start(&mut self, cx: &mut ExecCtx, rows: &mut Vec<Row>,
             capacity: usize) -> Result<()> {
        let n_real = rows.iter().filter(|r| !r.is_free()).count();
        if n_real == 0 {
            bail!("cannot start an empty PAD batch");
        }
        let b = cx.engine.manifest.bucket_batch_padded(
            n_real, cx.cfg.pad_headroom, capacity)?;
        fused_prefill(cx, rows, b, Vec::new(), &mut self.store)
            .map(|_| ())
    }

    fn draft(&mut self, cx: &mut ExecCtx, io: &DraftIo)
             -> Result<(Vec<i32>, Vec<f32>)> {
        let Some((_, draft)) = self.store.as_mut() else {
            bail!("PAD store missing");
        };
        let cfg = cx.cfg;
        let b = io.stepping.len();
        // The fused artifact computes every bucket row at the launch k.
        let rect = rect_launch_flops(cx.draft_info, io.k, io.dlens);
        cx.flops.add_launch(rect, rect);
        let caches = std::mem::take(draft);
        let out = cx.engine.draft(&cfg.draft_model, cfg.precision,
                                  cfg.attn, b, io.k, io.tokens_in,
                                  io.n_in, io.dlens, io.uniforms,
                                  io.temps, io.tps, caches)?;
        *draft = out.caches;
        Ok((out.tokens, out.qdists))
    }

    fn verify(&mut self, cx: &mut ExecCtx, io: &VerifyIo)
              -> Result<Vec<f32>> {
        let Some((main, _)) = self.store.as_mut() else {
            bail!("PAD store missing");
        };
        let cfg = cx.cfg;
        let b = io.stepping.len();
        // Every bucket row decodes at the launch q = k + 1.
        let rect = rect_launch_flops(cx.main_info, io.q, io.mlens);
        cx.flops.add_launch(rect, rect);
        let caches = std::mem::take(main);
        let out = cx.engine.decode(&cfg.main_model, cfg.precision,
                                   cfg.attn, b, io.q, io.vtokens,
                                   io.mlens, caches)?;
        *main = out.caches;
        Ok(out.logits)
    }

    fn release(&mut self, rows: &mut [Row], idx: usize) -> Slot {
        let replacement = if self.started() {
            // The fused artifact keeps computing this row; leave a
            // frozen state so its dlens/mlens inputs stay valid.
            match &rows[idx] {
                Row::Seq(s) => Row::Husk(s.state.clone()),
                _ => unreachable!("release of a non-Seq row"),
            }
        } else {
            Row::Free
        };
        let Row::Seq(slot) = std::mem::replace(&mut rows[idx], replacement)
        else {
            unreachable!("release of a non-Seq row");
        };
        slot
    }

    fn reset(&mut self) {
        self.store = None;
    }

    fn live_bucket(&self, rows: &[Row]) -> Option<usize> {
        self.started().then_some(rows.len())
    }

    fn rebucket(&mut self, cx: &mut ExecCtx, rows: &mut Vec<Row>,
                bucket: usize, resumes: Vec<Slot>) -> Result<usize> {
        if self.store.is_none() {
            bail!("PAD batch has not started; nothing to re-bucket");
        }
        fused_prefill(cx, rows, bucket, resumes, &mut self.store)
    }
}

// ---------------------------------------------------------------------
// BASS-SPLIT: per-sequence B=1 artifacts, skipping inactive slots.
// ---------------------------------------------------------------------

/// Per-slot backend: one B=1 cache set per slot for each model; empty
/// vectors mark free slots.
pub(super) struct SplitBackend {
    main: Vec<Vec<PjRtBuffer>>,
    draft: Vec<Vec<PjRtBuffer>>,
}

impl Backend for SplitBackend {
    fn started(&self) -> bool {
        true // every slot owns its caches; there is no fused start
    }

    fn free_slots(&self, rows: &[Row]) -> usize {
        rows.iter().filter(|r| r.is_free()).count()
    }

    fn admissible_row(&self, rows: &[Row]) -> Result<usize> {
        rows.iter().position(Row::is_free).ok_or_else(|| {
            anyhow!("no free slot (capacity {})", rows.len())
        })
    }

    /// Prefill one slot's own B=1 caches (both models) over `ctx`.
    fn bind_row(&mut self, cx: &mut ExecCtx, _rows: &[Row], row: usize,
                ctx: &[u8]) -> Result<()> {
        let cfg = cx.cfg;
        let eng = cx.engine;
        let p = eng.manifest.prefill_p;
        let (tokens, plen) = encode_window(ctx, p);
        let plens = [plen];
        let t0 = Instant::now();
        let tr = cx.tracer.begin();
        let m = eng.prefill(&cfg.main_model, cfg.precision, cfg.attn, 1,
                            &tokens, &plens)?;
        let d = eng.prefill(&cfg.draft_model, cfg.precision, cfg.attn, 1,
                            &tokens, &plens)?;
        *cx.prefill_secs += t0.elapsed().as_secs_f64();
        cx.tracer.span(SpanKind::ScatterBind, tr, 0, None,
                       cfg.mode.as_str(), &[("row", row as f64)]);
        cx.flops.add_prefill(cx.main_info, 1, p);
        cx.flops.add_prefill(cx.draft_info, 1, p);
        self.main[row] = m.caches;
        self.draft[row] = d.caches;
        Ok(())
    }

    /// SPLIT has no shared store to row-copy inside: the donor slot's
    /// B=1 cache sets are cloned buffer-by-buffer through a host
    /// round-trip — bitwise-exact (f32 survives the download/upload
    /// pair) and far cheaper than re-running the prompt. The donor is
    /// only read; a failure leaves both slots untouched.
    fn copy_row(&mut self, cx: &mut ExecCtx, _rows: &[Row], src: usize,
                dst: usize) -> Result<()> {
        if self.main[src].is_empty() || self.draft[src].is_empty() {
            bail!("SPLIT row copy: donor slot {src} holds no caches");
        }
        let cfg = cx.cfg;
        let eng = cx.engine;
        let t0 = Instant::now();
        let tr = cx.tracer.begin();
        let m = eng.clone_cache_set(&cfg.main_model, &self.main[src])
            .context("per-slot cache clone (main model)")?;
        let d = eng.clone_cache_set(&cfg.draft_model, &self.draft[src])
            .context("per-slot cache clone (draft model)")?;
        *cx.prefill_secs += t0.elapsed().as_secs_f64();
        record_row_copy(cx, tr, src, dst);
        self.main[dst] = m;
        self.draft[dst] = d;
        Ok(())
    }

    fn start(&mut self, _cx: &mut ExecCtx, _rows: &mut Vec<Row>,
             _capacity: usize) -> Result<()> {
        Ok(()) // slots prefill at bind time; nothing fused to start
    }

    fn draft(&mut self, cx: &mut ExecCtx, io: &DraftIo)
             -> Result<(Vec<i32>, Vec<f32>)> {
        let cfg = cx.cfg;
        let vocab = cx.engine.manifest.vocab;
        let b = io.stepping.len();
        let k = io.k;
        let mut toks = vec![0i32; b * k];
        let mut qd = vec![0f32; b * k * vocab];
        // SPLIT launches each stepping row at its own k_i bucket; the
        // PAD equivalent would run those rows at the launch k.
        let mut launch = 0.0;
        let mut rect = 0.0;
        for i in 0..b {
            if !io.stepping[i] {
                continue;
            }
            let ctx = io.dlens[i] as usize;
            launch += step_flops(cx.draft_info, 1,
                                 io.klens[i] as usize, ctx);
            rect += step_flops(cx.draft_info, 1, k, ctx);
        }
        cx.flops.add_launch(launch, rect);
        for i in 0..b {
            if !io.stepping[i] {
                continue; // SPLIT skips finished/free slots
            }
            // Each row runs its own k_i bucket: the per-sequence draft
            // length is a real FLOP saving here, not just masking.
            // Outputs land in the launch-width (k) layout the
            // orchestrator indexes; positions k_i..k stay zero and are
            // never read.
            let ki = io.klens[i] as usize;
            let caches = std::mem::take(&mut self.draft[i]);
            let out = cx.engine.draft(
                &cfg.draft_model, cfg.precision, cfg.attn, 1, ki,
                &io.tokens_in[i * 2..i * 2 + 2], &io.n_in[i..=i],
                &io.dlens[i..=i], &io.uniforms[i * k..i * k + ki],
                &io.temps[i..=i], &io.tps[i..=i], caches)?;
            self.draft[i] = out.caches;
            toks[i * k..i * k + ki].copy_from_slice(&out.tokens);
            qd[i * k * vocab..(i * k + ki) * vocab]
                .copy_from_slice(&out.qdists);
        }
        Ok((toks, qd))
    }

    fn verify(&mut self, cx: &mut ExecCtx, io: &VerifyIo)
              -> Result<Vec<f32>> {
        let cfg = cx.cfg;
        let vocab = cx.engine.manifest.vocab;
        let b = io.stepping.len();
        let q = io.q;
        let mut logits = vec![0f32; b * q * vocab];
        let mut launch = 0.0;
        let mut rect = 0.0;
        for i in 0..b {
            if !io.stepping[i] {
                continue;
            }
            let ctx = io.mlens[i] as usize;
            launch += step_flops(cx.main_info, 1,
                                 io.qlens[i] as usize, ctx);
            rect += step_flops(cx.main_info, 1, q, ctx);
        }
        cx.flops.add_launch(launch, rect);
        for i in 0..b {
            if !io.stepping[i] {
                continue;
            }
            // Decode at this row's own q_i = k_i + 1 (the k_i buckets
            // are exported, so the q_i decode program always exists).
            let qi = io.qlens[i] as usize;
            let caches = std::mem::take(&mut self.main[i]);
            let out = cx.engine.decode(
                &cfg.main_model, cfg.precision, cfg.attn, 1, qi,
                &io.vtokens[i * q..i * q + qi], &io.mlens[i..=i],
                caches)?;
            self.main[i] = out.caches;
            logits[i * q * vocab..(i * q + qi) * vocab]
                .copy_from_slice(&out.logits);
        }
        Ok(logits)
    }

    fn release(&mut self, rows: &mut [Row], idx: usize) -> Slot {
        self.main[idx] = Vec::new();
        self.draft[idx] = Vec::new();
        let Row::Seq(slot) = std::mem::replace(&mut rows[idx], Row::Free)
        else {
            unreachable!("release of a non-Seq row");
        };
        slot
    }

    fn reset(&mut self) {
        // Per-slot caches were dropped release by release; clear
        // defensively so a reset never leaks a stale cache set.
        for c in self.main.iter_mut().chain(self.draft.iter_mut()) {
            c.clear();
        }
    }

    fn live_bucket(&self, _rows: &[Row]) -> Option<usize> {
        None // per-sequence slots: no fused bucket to re-shape
    }
}

// ---------------------------------------------------------------------
// BASS-PACKED: one offset-addressed launch over the ragged rows.
// ---------------------------------------------------------------------

/// Packed-segment backend (see the module docs): PAD's fused-bucket
/// row lifecycle with an offset-addressed step ABI. Dual-engine —
/// device artifacts (`decode_packed` / `draft_packed`, manifest v4) on
/// a real engine; stub-identical host compute in the packed layout on
/// a stub one, so serving/CI exercise the pack/unpack math without a
/// device.
pub(super) struct PackedBackend {
    /// (main caches, draft caches) once the fused prefill ran; always
    /// `None` on a host-only engine.
    store: Option<(Vec<PjRtBuffer>, Vec<PjRtBuffer>)>,
    /// Lazy-start flag; on a device engine it tracks `store`, on a
    /// host-only engine it is the whole started state (like the stub).
    started: bool,
    /// Stub engine: no device work, host compute in the packed layout.
    host_only: bool,
}

impl PackedBackend {
    /// Cumulative segment offsets `[0, l_0, l_0+l_1, ...]` (`B + 1`
    /// entries) over per-row lengths — the `qoffs`/`koffs` ABI input.
    fn offsets(lens: &[i32]) -> Vec<i32> {
        let mut offs = Vec::with_capacity(lens.len() + 1);
        let mut acc = 0i32;
        offs.push(0);
        for &l in lens {
            acc += l;
            offs.push(acc);
        }
        offs
    }
}

impl Backend for PackedBackend {
    fn started(&self) -> bool {
        self.started
    }

    fn free_slots(&self, rows: &[Row]) -> usize {
        if self.started {
            rows.iter()
                .filter(|r| matches!(r, Row::Husk(_) | Row::Shadow(_)))
                .count()
        } else {
            rows.iter().filter(|r| r.is_free()).count()
        }
    }

    fn admissible_row(&self, rows: &[Row]) -> Result<usize> {
        if self.started {
            rows.iter()
                .position(|r| matches!(r, Row::Husk(_) | Row::Shadow(_)))
                .ok_or_else(|| {
                    anyhow!("no reusable packed row (bucket of {} fully \
                             live; wait for a retirement, a re-bucket, \
                             or the drain)",
                            rows.len())
                })
        } else {
            rows.iter().position(Row::is_free).ok_or_else(|| {
                anyhow!("no free slot (capacity {})", rows.len())
            })
        }
    }

    /// Device engine: PAD's mid-flight scatter prefill into a reusable
    /// bucket row. Host-only: nothing to build (like the stub). Both
    /// are no-ops before the lazy start, which encodes the row itself.
    fn bind_row(&mut self, cx: &mut ExecCtx, rows: &[Row], row: usize,
                ctx: &[u8]) -> Result<()> {
        match self.store.as_mut() {
            Some(store) => scatter_bind(cx, rows, row, ctx, store),
            None => Ok(()),
        }
    }

    /// Device engine with a running bucket: PAD's fused `kv_row_copy`.
    /// Host-only and started: no device KV exists, so the copy is free
    /// — charge the device-equivalent cost (stub convention). Not yet
    /// started: a no-op like [`Backend::bind_row`].
    fn copy_row(&mut self, cx: &mut ExecCtx, rows: &[Row], src: usize,
                dst: usize) -> Result<()> {
        match self.store.as_mut() {
            Some(store) => fused_row_copy(cx, rows, src, dst, store),
            None if self.started && self.host_only => {
                let tr = cx.tracer.begin();
                record_row_copy(cx, tr, src, dst);
                Ok(())
            }
            None => Ok(()),
        }
    }

    /// Lazy start: bucketize like PAD (headroom applied) and commit the
    /// row table — with the fused prefill on a device engine, without
    /// it on a host-only one.
    fn start(&mut self, cx: &mut ExecCtx, rows: &mut Vec<Row>,
             capacity: usize) -> Result<()> {
        let n_real = rows.iter().filter(|r| !r.is_free()).count();
        if n_real == 0 {
            bail!("cannot start an empty packed batch");
        }
        let b = cx.engine.manifest.bucket_batch_padded(
            n_real, cx.cfg.pad_headroom, capacity)?;
        if self.host_only {
            commit_bucket(cx.cfg, cx.engine.manifest.prefill_p, rows, b,
                          Vec::new())?;
        } else {
            fused_prefill(cx, rows, b, Vec::new(), &mut self.store)?;
        }
        self.started = true;
        Ok(())
    }

    fn draft(&mut self, cx: &mut ExecCtx, io: &DraftIo)
             -> Result<(Vec<i32>, Vec<f32>)> {
        let cfg = cx.cfg;
        let vocab = cx.engine.manifest.vocab;
        let b = io.stepping.len();
        let k = io.k;
        // The packed draft graph still computes the [B, k] rectangle
        // (the unrolled loop masks per row), so the draft launch is
        // PAD's — the packed saving is the verify stream.
        let rect = rect_launch_flops(cx.draft_info, k, io.dlens);
        cx.flops.add_launch(rect, rect);
        // Pack the launch-width uniforms into the packed-prefix [B·k]
        // layout the artifact addresses through koffs.
        let koffs = Self::offsets(io.klens);
        let mut packed_u = vec![0f32; b * k];
        for i in 0..b {
            let ki = io.klens[i] as usize;
            let o = koffs[i] as usize;
            packed_u[o..o + ki]
                .copy_from_slice(&io.uniforms[i * k..i * k + ki]);
        }
        let (ptoks, pqd) = if self.host_only {
            // Stub-identical compute, in the packed layout: token t of
            // the stream draws from the same uniform the launch-width
            // stub would use, so the unpacked outputs match bitwise.
            let mut t = vec![0i32; b * k];
            let mut qdp = vec![0f32; b * k * vocab];
            for x in 0..koffs[b] as usize {
                let tok = stub_token(packed_u[x], vocab);
                t[x] = tok as i32;
                qdp[x * vocab + tok] = 1.0;
            }
            (t, qdp)
        } else {
            let Some((_, draft)) = self.store.as_mut() else {
                bail!("packed store missing");
            };
            let caches = std::mem::take(draft);
            let out = cx.engine.draft_packed(
                &cfg.draft_model, cfg.precision, cfg.attn, b, k,
                io.tokens_in, io.n_in, io.dlens, &koffs, &packed_u,
                io.temps, io.tps, caches)?;
            *draft = out.caches;
            (out.tokens, out.qdists)
        };
        // Unpack to the launch-width layout the orchestrator indexes;
        // positions past a row's k_i stay zero and are never read.
        let mut toks = vec![0i32; b * k];
        let mut qd = vec![0f32; b * k * vocab];
        for i in 0..b {
            let ki = io.klens[i] as usize;
            let o = koffs[i] as usize;
            toks[i * k..i * k + ki].copy_from_slice(&ptoks[o..o + ki]);
            qd[i * k * vocab..(i * k + ki) * vocab]
                .copy_from_slice(&pqd[o * vocab..(o + ki) * vocab]);
        }
        Ok((toks, qd))
    }

    fn verify(&mut self, cx: &mut ExecCtx, io: &VerifyIo)
              -> Result<Vec<f32>> {
        let cfg = cx.cfg;
        let eng = cx.engine;
        let vocab = eng.manifest.vocab;
        let b = io.stepping.len();
        let q = io.q;
        let qoffs = Self::offsets(io.qlens);
        let sum_q = qoffs[b] as usize;
        let q_cap = eng.manifest.bucket_packed_q(b, sum_q)?;
        let c = b * q_cap;
        // Launch accounting: real rows at their own q_i (Husk/Shadow
        // rows past their budget have q_i = 0 and cost nothing); the
        // C - Σq_i capacity filler costs dense GEMMs only (it attends
        // to nothing). The padded side is PAD's bucket rectangle.
        let mut launch = 0.0;
        let mut rect = 0.0;
        for i in 0..b {
            let ctx = io.mlens[i] as usize;
            rect += step_flops(cx.main_info, 1, q, ctx);
            let qi = io.qlens[i] as usize;
            if qi > 0 {
                launch += step_flops(cx.main_info, 1, qi, ctx);
            }
        }
        launch +=
            2.0 * cx.main_info.param_count as f64 * (c - sum_q) as f64;
        cx.flops.add_launch(launch, rect);
        // Pack the launch-width verify tokens into the [1, C] stream.
        let mut ptokens = vec![0i32; c];
        for i in 0..b {
            let qi = io.qlens[i] as usize;
            let o = qoffs[i] as usize;
            ptokens[o..o + qi]
                .copy_from_slice(&io.vtokens[i * q..i * q + qi]);
        }
        let plogits = if self.host_only {
            // Stub-identical compute in the packed layout: position
            // qoffs[i] + j agrees one-hot with draft token j + 1 of
            // its own segment; the bonus sits at the segment's end.
            let mut lg = vec![0f32; c * vocab];
            for i in 0..b {
                let qi = io.qlens[i] as usize;
                if qi == 0 {
                    continue;
                }
                let o = qoffs[i] as usize;
                for j in 0..qi - 1 {
                    let d = (ptokens[o + 1 + j] as usize).min(vocab - 1);
                    lg[(o + j) * vocab + d] = STUB_LOGIT;
                }
                let bonus = 1 + (io.mlens[i] as usize % stub_span(vocab));
                lg[(o + qi - 1) * vocab + bonus] = STUB_LOGIT;
            }
            lg
        } else {
            let Some((main, _)) = self.store.as_mut() else {
                bail!("packed store missing");
            };
            let caches = std::mem::take(main);
            let out = eng.decode_packed(&cfg.main_model, cfg.precision,
                                        cfg.attn, b, q_cap, &ptokens,
                                        &qoffs, io.mlens, caches)?;
            *main = out.caches;
            out.logits
        };
        // Unpack to [B, q, V]; the host reads a row only at 0..q_i, so
        // the zero tail past it is never observed.
        let mut logits = vec![0f32; b * q * vocab];
        for i in 0..b {
            let qi = io.qlens[i] as usize;
            let o = qoffs[i] as usize;
            logits[i * q * vocab..(i * q + qi) * vocab]
                .copy_from_slice(&plogits[o * vocab..(o + qi) * vocab]);
        }
        Ok(logits)
    }

    fn release(&mut self, rows: &mut [Row], idx: usize) -> Slot {
        let replacement = if self.started {
            match &rows[idx] {
                Row::Seq(s) => Row::Husk(s.state.clone()),
                _ => unreachable!("release of a non-Seq row"),
            }
        } else {
            Row::Free
        };
        let Row::Seq(slot) = std::mem::replace(&mut rows[idx], replacement)
        else {
            unreachable!("release of a non-Seq row");
        };
        slot
    }

    fn reset(&mut self) {
        self.store = None;
        self.started = false;
    }

    fn live_bucket(&self, rows: &[Row]) -> Option<usize> {
        self.started.then_some(rows.len())
    }

    fn rebucket(&mut self, cx: &mut ExecCtx, rows: &mut Vec<Row>,
                bucket: usize, resumes: Vec<Slot>) -> Result<usize> {
        if !self.started {
            bail!("packed batch has not started; nothing to re-bucket");
        }
        if self.host_only {
            commit_bucket(cx.cfg, cx.engine.manifest.prefill_p, rows,
                          bucket, resumes)
        } else {
            fused_prefill(cx, rows, bucket, resumes, &mut self.store)
        }
    }
}

// ---------------------------------------------------------------------
// Stub: host-only deterministic backend (no device, no artifacts).
// ---------------------------------------------------------------------

/// The non-eos token a stub draft emits for uniform `u` — the whole
/// "model": a pure function of the per-sequence RNG stream, never the
/// eos byte (0), always `< vocab`.
fn stub_token(u: f32, vocab: usize) -> usize {
    let span = stub_span(vocab);
    1 + ((u * span as f32) as usize).min(span - 1)
}

/// How many distinct non-eos tokens the stub emits (`1..=span`).
fn stub_span(vocab: usize) -> usize {
    vocab.saturating_sub(1).min(250).max(1)
}

/// A one-hot logit this strong survives [`crate::sampling::warp_top_p`]
/// at any temperature/top-p as probability exactly 1.0 in f32 (the
/// competing mass is `255·e^-50 ≈ 5e-20`), which is what makes stub
/// verification accept every draft token with certainty.
const STUB_LOGIT: f32 = 50.0;

/// Host-only deterministic backend: no device, no artifacts, no KV —
/// the host-side [`SeqState`] *is* the whole sequence identity. The
/// draft emits seeded non-eos tokens with exact one-hot q-distributions
/// and verify emits one-hot logits agreeing at those very tokens (it
/// reads them back out of `vtokens`), so every step accepts `k + 1`
/// tokens with probability 1 and no cache-length bookkeeping needs
/// mirroring. Sequences finish by `Length`/`Capacity`/budget only.
///
/// The row lifecycle mirrors BASS-PAD's fused bucket — lazy start
/// bucketizes and `Shadow`-pads, retirement leaves `Husk` rows,
/// mid-flight admission reuses them, live re-bucketing re-commits the
/// row table — so the whole coordinator/scheduler stack (admission,
/// preemption, re-bucketing, budgets) runs unmodified on machines
/// without the PJRT binding. The serving load harness and the CI perf
/// gate drive this backend.
pub(super) struct StubBackend {
    /// Flipped by the lazy start, like PAD's fused prefill (there is
    /// just no device work behind it).
    started: bool,
}

impl Backend for StubBackend {
    fn started(&self) -> bool {
        self.started
    }

    fn free_slots(&self, rows: &[Row]) -> usize {
        if self.started {
            rows.iter()
                .filter(|r| matches!(r, Row::Husk(_) | Row::Shadow(_)))
                .count()
        } else {
            rows.iter().filter(|r| r.is_free()).count()
        }
    }

    fn admissible_row(&self, rows: &[Row]) -> Result<usize> {
        if self.started {
            rows.iter()
                .position(|r| matches!(r, Row::Husk(_) | Row::Shadow(_)))
                .ok_or_else(|| {
                    anyhow!("no reusable stub row (bucket of {} fully \
                             live; wait for a retirement, a re-bucket, \
                             or the drain)",
                            rows.len())
                })
        } else {
            rows.iter().position(Row::is_free).ok_or_else(|| {
                anyhow!("no free slot (capacity {})", rows.len())
            })
        }
    }

    fn bind_row(&mut self, _cx: &mut ExecCtx, _rows: &[Row], _row: usize,
                _ctx: &[u8]) -> Result<()> {
        Ok(()) // no device KV to build; SeqState carries everything
    }

    /// No device KV to move — the copy is free on the host. Once
    /// started, the device-equivalent cost is still charged and the
    /// `row_copy` span recorded, the same stands-in-for-PAD convention
    /// as the stub's launch accounting; pre-start it is a no-op like
    /// [`Backend::bind_row`] (the rectangle start covers every row).
    fn copy_row(&mut self, cx: &mut ExecCtx, _rows: &[Row], src: usize,
                dst: usize) -> Result<()> {
        if self.started {
            let tr = cx.tracer.begin();
            record_row_copy(cx, tr, src, dst);
        }
        Ok(())
    }

    /// Stub lazy start: bucketize like PAD (headroom applied, so the
    /// running bucket keeps reusable `Shadow` grow-room) and commit the
    /// row table — the fused prefill minus the device calls.
    fn start(&mut self, cx: &mut ExecCtx, rows: &mut Vec<Row>,
             capacity: usize) -> Result<()> {
        let n_real = rows.iter().filter(|r| !r.is_free()).count();
        if n_real == 0 {
            bail!("cannot start an empty stub batch");
        }
        let b = cx.engine.manifest.bucket_batch_padded(
            n_real, cx.cfg.pad_headroom, capacity)?;
        commit_bucket(cx.cfg, cx.engine.manifest.prefill_p, rows, b,
                      Vec::new())?;
        self.started = true;
        Ok(())
    }

    fn draft(&mut self, cx: &mut ExecCtx, io: &DraftIo)
             -> Result<(Vec<i32>, Vec<f32>)> {
        let vocab = cx.engine.manifest.vocab;
        let b = io.stepping.len();
        let k = io.k;
        let mut toks = vec![0i32; b * k];
        let mut qd = vec![0f32; b * k * vocab];
        // Accounting mirrors the PAD rectangle the stub stands in for.
        let rect = rect_launch_flops(cx.draft_info, k, io.dlens);
        cx.flops.add_launch(rect, rect);
        // Honor the raggedness exactly: each row emits its own k_i
        // tokens from its own k_i uniforms; launch-width filler
        // positions stay zero (the host never reads them, matching the
        // per-row RNG-consumption contract).
        for i in 0..b {
            for j in 0..io.klens[i] as usize {
                let t = stub_token(io.uniforms[i * k + j], vocab);
                toks[i * k + j] = t as i32;
                qd[(i * k + j) * vocab + t] = 1.0;
            }
        }
        Ok((toks, qd))
    }

    fn verify(&mut self, cx: &mut ExecCtx, io: &VerifyIo)
              -> Result<Vec<f32>> {
        let vocab = cx.engine.manifest.vocab;
        let b = io.stepping.len();
        let q = io.q;
        let mut logits = vec![0f32; b * q * vocab];
        let rect = rect_launch_flops(cx.main_info, q, io.mlens);
        cx.flops.add_launch(rect, rect);
        for i in 0..b {
            // This row's own verify width q_i = k_i + 1; rows without a
            // slot (qlens 0) emit nothing — their outputs are dead.
            let qi = io.qlens[i] as usize;
            if qi == 0 {
                continue;
            }
            // Position j predicts the token after stream position j —
            // which for j < k_i is draft token d_{j+1}, sitting right
            // there in the verify input. Agreeing with it one-hot makes
            // the accept ratio exactly 1.
            for j in 0..qi - 1 {
                let d = (io.vtokens[i * q + 1 + j] as usize)
                    .min(vocab - 1);
                logits[(i * q + j) * vocab + d] = STUB_LOGIT;
            }
            // Bonus position (q_i - 1, this row's own): a deterministic
            // non-eos token that moves with the sequence's cache length,
            // so outputs vary step to step but never depend on
            // wall-clock or co-batch identity.
            let bonus = 1 + (io.mlens[i] as usize % stub_span(vocab));
            logits[(i * q + qi - 1) * vocab + bonus] = STUB_LOGIT;
        }
        Ok(logits)
    }

    fn release(&mut self, rows: &mut [Row], idx: usize) -> Slot {
        let replacement = if self.started {
            match &rows[idx] {
                Row::Seq(s) => Row::Husk(s.state.clone()),
                _ => unreachable!("release of a non-Seq row"),
            }
        } else {
            Row::Free
        };
        let Row::Seq(slot) = std::mem::replace(&mut rows[idx], replacement)
        else {
            unreachable!("release of a non-Seq row");
        };
        slot
    }

    fn reset(&mut self) {
        self.started = false;
    }

    fn live_bucket(&self, rows: &[Row]) -> Option<usize> {
        self.started.then_some(rows.len())
    }

    fn rebucket(&mut self, cx: &mut ExecCtx, rows: &mut Vec<Row>,
                bucket: usize, resumes: Vec<Slot>) -> Result<usize> {
        if !self.started {
            bail!("stub batch has not started; nothing to re-bucket");
        }
        commit_bucket(cx.cfg, cx.engine.manifest.prefill_p, rows, bucket,
                      resumes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::SeqState;

    fn slot(id: u64, prompt: Vec<u8>) -> Slot {
        let last = *prompt.last().unwrap();
        let len = prompt.len() as i32;
        Slot {
            id,
            state: SeqState::new(prompt, last, len),
            rng_draft: Pcg32::new(0, 2 * id),
            rng_accept: Pcg32::new(0, 2 * id + 1),
            max_new_tokens: 8,
            temperature: 1.0,
            top_p: 1.0,
            draft_ctrl: Controller::for_policy(
                &crate::spec::Policy::Heuristic),
        }
    }

    #[test]
    fn encode_window_pads_and_clamps() {
        let (t, l) = encode_window(&[1, 2, 3], 5);
        assert_eq!(t, vec![1, 2, 3, 0, 0]);
        assert_eq!(l, 3);
        // Longer than the window: keep the tail (dead rows only).
        let (t, l) = encode_window(&[1, 2, 3, 4, 5, 6], 4);
        assert_eq!(t, vec![3, 4, 5, 6]);
        assert_eq!(l, 4);
    }

    #[test]
    fn make_builds_the_mode_matching_backend() {
        let pad = make(&SpecConfig::default(), 4, false);
        assert!(!pad.started(), "PAD starts lazily at the fused prefill");
        let split = make(&SpecConfig { mode: ExecMode::Split,
                                       ..SpecConfig::default() }, 4,
                         false);
        assert!(split.started(), "SPLIT slots need no fused start");
        assert!(split.live_bucket(&[]).is_none());
        let packed = make(&SpecConfig { mode: ExecMode::Packed,
                                        ..SpecConfig::default() }, 4,
                          true);
        assert!(!packed.started(), "packed starts lazily like PAD");
    }

    #[test]
    fn pad_prestart_uses_free_rows_and_releases_to_free() {
        let mut be = PadBackend { store: None };
        let mut rows = [Row::Seq(slot(0, vec![1, 2])), Row::Free];
        assert_eq!(be.free_slots(&rows), 1);
        assert_eq!(be.admissible_row(&rows).unwrap(), 1);
        assert!(be.live_bucket(&rows).is_none(), "not started: no bucket");
        // Pre-start release frees the row outright (no husk: no fused
        // artifact is computing it).
        let s = be.release(&mut rows, 0);
        assert_eq!(s.id, 0);
        assert!(rows[0].is_free());
        assert_eq!(be.free_slots(&rows), 2);
    }

    #[test]
    fn running_pad_admits_into_husk_and_shadow_rows_only() {
        let mut be = PadBackend { store: Some((Vec::new(), Vec::new())) };
        let mut rows = [
            Row::Seq(slot(0, vec![1, 2])),
            Row::Husk(SeqState::new(vec![3], 3, 1)),
            Row::Shadow(slot(1, vec![4, 5])),
        ];
        assert_eq!(be.free_slots(&rows), 2);
        assert_eq!(be.admissible_row(&rows).unwrap(), 1);
        assert_eq!(be.live_bucket(&rows), Some(3));
        // Releasing a live row of the running bucket husks it.
        let s = be.release(&mut rows, 0);
        assert_eq!(s.id, 0);
        assert!(matches!(rows[0], Row::Husk(_)));
        assert_eq!(be.free_slots(&rows), 3);
        // A fully-live bucket reports the re-bucket option in its error.
        let full = [Row::Seq(slot(2, vec![9]))];
        let err = be.admissible_row(&full).unwrap_err().to_string();
        assert!(err.contains("re-bucket"), "unhelpful error: {err}");
    }

    #[test]
    fn split_rows_are_per_slot_and_never_bucketed() {
        let cfg = SpecConfig { mode: ExecMode::Split,
                               ..SpecConfig::default() };
        let mut be = make(&cfg, 2, false);
        let mut rows = [Row::Seq(slot(0, vec![1, 2])), Row::Free];
        assert_eq!(be.free_slots(&rows), 1);
        assert_eq!(be.admissible_row(&rows).unwrap(), 1);
        assert!(be.live_bucket(&rows).is_none());
        let s = be.release(&mut rows, 0);
        assert_eq!(s.id, 0);
        assert!(rows[0].is_free());
    }

    // -- stub backend ------------------------------------------------------

    use crate::sampling::warp_top_p;

    #[test]
    fn stub_mirrors_the_pad_row_lifecycle() {
        let cfg = SpecConfig { mode: ExecMode::Stub,
                               ..SpecConfig::default() };
        let mut be = make(&cfg, 4, true);
        assert!(!be.started(), "stub starts lazily like PAD");
        let mut rows = vec![Row::Seq(slot(0, vec![1, 2])), Row::Free];
        assert_eq!(be.free_slots(&rows), 1);
        assert!(be.live_bucket(&rows).is_none());
        // Pre-start release frees the row outright.
        let s = be.release(&mut rows, 0);
        assert_eq!(s.id, 0);
        assert!(rows[0].is_free());
    }

    #[test]
    fn stub_start_commits_a_shadow_padded_bucket() {
        let eng = Engine::stub();
        let cfg = SpecConfig { mode: ExecMode::Stub,
                               ..SpecConfig::default() };
        let main_info = eng.manifest.model("main").unwrap().clone();
        let draft_info = eng.manifest.model("draft_a").unwrap().clone();
        let mut secs = 0.0;
        let mut flops = FlopCounter::default();
        let mut cx = ExecCtx {
            engine: &eng,
            cfg: &cfg,
            main_info: &main_info,
            draft_info: &draft_info,
            prefill_secs: &mut secs,
            flops: &mut flops,
            tracer: Tracer::disabled(),
        };
        let mut be = StubBackend { started: false };
        let mut rows = vec![
            Row::Seq(slot(0, vec![1, 2])),
            Row::Seq(slot(1, vec![3, 4, 5])),
            Row::Free,
            Row::Free,
            Row::Free,
        ];
        be.start(&mut cx, &mut rows, 5).unwrap();
        assert!(be.started());
        // 2 real rows bucketize to 2 (no headroom): Seq rows compacted,
        // no padding needed.
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| matches!(r, Row::Seq(_))));
        assert_eq!(be.live_bucket(&rows), Some(2));
        // Retiring one leaves a reusable Husk, like a running PAD batch.
        let s = be.release(&mut rows, 0);
        assert_eq!(s.id, 0);
        assert!(matches!(rows[0], Row::Husk(_)));
        assert_eq!(be.free_slots(&rows), 1);
        assert_eq!(be.admissible_row(&rows).unwrap(), 0);
        // Re-bucket to 4 drops the Husk and pads with Shadows.
        be.rebucket(&mut cx, &mut rows, 4, Vec::new()).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(
            rows.iter().filter(|r| matches!(r, Row::Seq(_))).count(), 1);
        assert_eq!(
            rows.iter().filter(|r| matches!(r, Row::Shadow(_))).count(),
            3);
        assert_eq!(secs, 0.0, "stub does no timed device work");
    }

    #[test]
    fn stub_draft_and_verify_agree_for_full_acceptance() {
        let eng = Engine::stub();
        let cfg = SpecConfig { mode: ExecMode::Stub,
                               ..SpecConfig::default() };
        let main_info = eng.manifest.model("main").unwrap().clone();
        let draft_info = eng.manifest.model("draft_a").unwrap().clone();
        let mut secs = 0.0;
        let mut flops = FlopCounter::default();
        let mut cx = ExecCtx {
            engine: &eng,
            cfg: &cfg,
            main_info: &main_info,
            draft_info: &draft_info,
            prefill_secs: &mut secs,
            flops: &mut flops,
            tracer: Tracer::disabled(),
        };
        let mut be = StubBackend { started: true };
        let vocab = eng.manifest.vocab;
        let k = 2;
        let uniforms = [0.3f32, 0.9];
        let io = DraftIo {
            k,
            tokens_in: &[5, 0],
            n_in: &[1],
            dlens: &[0],
            klens: &[k as i32],
            uniforms: &uniforms,
            temps: &[0.2],
            tps: &[0.95],
            stepping: &[true],
        };
        let (toks, qd) = be.draft(&mut cx, &io).unwrap();
        let (toks2, _) = be.draft(&mut cx, &io).unwrap();
        assert_eq!(toks, toks2, "same uniforms, same tokens");
        for j in 0..k {
            let t = toks[j] as usize;
            assert!((1..=250).contains(&t), "non-eos byte token: {t}");
            assert_eq!(qd[j * vocab + t], 1.0, "exact one-hot q-dist");
            assert_eq!(
                qd[j * vocab..(j + 1) * vocab].iter().sum::<f32>(), 1.0);
        }
        // Verify sees the draft tokens in vtokens and agrees one-hot:
        // after the per-slot warp each draft token has probability 1.0,
        // so spec_accept takes all of them plus the bonus.
        let q = k + 1;
        let vtokens = [5, toks[0], toks[1]];
        let vio = VerifyIo {
            q,
            vtokens: &vtokens,
            mlens: &[7],
            qlens: &[q as i32],
            stepping: &[true],
        };
        let logits = be.verify(&mut cx, &vio).unwrap();
        for j in 0..k {
            let w = warp_top_p(&logits[j * vocab..(j + 1) * vocab],
                               0.2, 0.95);
            assert_eq!(w[toks[j] as usize], 1.0,
                       "verify must certainly accept draft token {j}");
        }
        let wb = warp_top_p(&logits[k * vocab..(k + 1) * vocab],
                            0.2, 0.95);
        let bonus = wb.iter().position(|&p| p == 1.0).unwrap();
        assert!(bonus >= 1, "bonus is never the eos byte");
    }

    #[test]
    fn stub_honors_ragged_klens_and_qlens() {
        let eng = Engine::stub();
        let cfg = SpecConfig { mode: ExecMode::Stub,
                               ..SpecConfig::default() };
        let main_info = eng.manifest.model("main").unwrap().clone();
        let draft_info = eng.manifest.model("draft_a").unwrap().clone();
        let mut secs = 0.0;
        let mut flops = FlopCounter::default();
        let mut cx = ExecCtx {
            engine: &eng,
            cfg: &cfg,
            main_info: &main_info,
            draft_info: &draft_info,
            prefill_secs: &mut secs,
            flops: &mut flops,
            tracer: Tracer::disabled(),
        };
        let mut be = StubBackend { started: true };
        let vocab = eng.manifest.vocab;
        // Two rows at different own draft lengths under a launch k of 4.
        let k = 4;
        let uniforms: Vec<f32> =
            (0..2 * k).map(|i| 0.05 + (i as f32) / 10.0).collect();
        let io = DraftIo {
            k,
            tokens_in: &[5, 0, 6, 0],
            n_in: &[1, 1],
            dlens: &[0, 0],
            klens: &[2, 4],
            uniforms: &uniforms,
            temps: &[1.0, 1.0],
            tps: &[1.0, 1.0],
            stepping: &[true, true],
        };
        let (toks, qd) = be.draft(&mut cx, &io).unwrap();
        assert!(toks[0] != 0 && toks[1] != 0, "row 0 fills its k_i = 2");
        assert_eq!(&toks[2..4], &[0, 0],
                   "row 0 emits nothing past its own k_i");
        assert!(toks[4..8].iter().all(|&t| t != 0),
                "row 1 fills its k_i = 4");
        assert!(qd[2 * vocab..4 * vocab].iter().all(|&p| p == 0.0),
                "no q-dist mass past row 0's k_i");
        // Verify: each row's bonus lands at its *own* q_i - 1.
        let q = k + 1;
        let mut vtokens = vec![0i32; 2 * q];
        vtokens[0] = 5;
        vtokens[1..3].copy_from_slice(&toks[0..2]);
        vtokens[q] = 6;
        vtokens[q + 1..q + 1 + k].copy_from_slice(&toks[4..8]);
        let vio = VerifyIo {
            q,
            vtokens: &vtokens,
            mlens: &[7, 9],
            qlens: &[3, 5],
            stepping: &[true, true],
        };
        let logits = be.verify(&mut cx, &vio).unwrap();
        let row0 = &logits[..q * vocab];
        assert!(row0[2 * vocab..3 * vocab].contains(&STUB_LOGIT),
                "row 0's bonus sits at its own q_i - 1 = 2");
        assert!(row0[3 * vocab..].iter().all(|&l| l == 0.0),
                "row 0 emits nothing past its own q_i");
        let row1 = &logits[q * vocab..];
        assert!(row1[4 * vocab..5 * vocab].contains(&STUB_LOGIT),
                "row 1's bonus sits at the launch q - 1");
    }

    // -- packed backend ----------------------------------------------------

    #[test]
    fn packed_offsets_are_cumulative() {
        assert_eq!(PackedBackend::offsets(&[2, 0, 3]), vec![0, 2, 2, 5]);
        assert_eq!(PackedBackend::offsets(&[]), vec![0]);
    }

    #[test]
    fn packed_host_mirrors_the_pad_row_lifecycle() {
        let eng = Engine::stub();
        let cfg = SpecConfig { mode: ExecMode::Packed,
                               ..SpecConfig::default() };
        let main_info = eng.manifest.model("main").unwrap().clone();
        let draft_info = eng.manifest.model("draft_a").unwrap().clone();
        let mut secs = 0.0;
        let mut flops = FlopCounter::default();
        let mut cx = ExecCtx {
            engine: &eng,
            cfg: &cfg,
            main_info: &main_info,
            draft_info: &draft_info,
            prefill_secs: &mut secs,
            flops: &mut flops,
            tracer: Tracer::disabled(),
        };
        let mut be = make(&cfg, 4, true);
        let mut rows = vec![
            Row::Seq(slot(0, vec![1, 2])),
            Row::Seq(slot(1, vec![3, 4, 5])),
            Row::Free,
            Row::Free,
        ];
        assert!(!be.started());
        be.start(&mut cx, &mut rows, 4).unwrap();
        assert!(be.started());
        assert_eq!(rows.len(), 2);
        assert_eq!(be.live_bucket(&rows), Some(2));
        // Retiring a live row husks it, like a running PAD bucket.
        let s = be.release(&mut rows, 0);
        assert_eq!(s.id, 0);
        assert!(matches!(rows[0], Row::Husk(_)));
        assert_eq!(be.free_slots(&rows), 1);
        assert_eq!(be.admissible_row(&rows).unwrap(), 0);
        // Host-only bind is stateless, like the stub.
        be.bind_row(&mut cx, &rows, 0, &[7, 8]).unwrap();
        // Re-bucket to 4 drops the Husk and pads with Shadows.
        be.rebucket(&mut cx, &mut rows, 4, Vec::new()).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(
            rows.iter().filter(|r| matches!(r, Row::Shadow(_))).count(),
            3);
        assert_eq!(secs, 0.0, "host-only packed does no device work");
        be.reset();
        assert!(!be.started());
    }

    #[test]
    fn packed_host_step_matches_the_stub_bitwise() {
        let eng = Engine::stub();
        let cfg = SpecConfig { mode: ExecMode::Packed,
                               ..SpecConfig::default() };
        let main_info = eng.manifest.model("main").unwrap().clone();
        let draft_info = eng.manifest.model("draft_a").unwrap().clone();
        let mut secs = 0.0;
        let mut flops = FlopCounter::default();
        let mut cx = ExecCtx {
            engine: &eng,
            cfg: &cfg,
            main_info: &main_info,
            draft_info: &draft_info,
            prefill_secs: &mut secs,
            flops: &mut flops,
            tracer: Tracer::disabled(),
        };
        let mut packed = PackedBackend {
            store: None, started: true, host_only: true,
        };
        let mut stub = StubBackend { started: true };
        // Three rows: ragged k_i, one Husk (k_i = 0) in the middle.
        let k = 4;
        let uniforms: Vec<f32> =
            (0..3 * k).map(|i| 0.03 + (i as f32) / 15.0).collect();
        let io = DraftIo {
            k,
            tokens_in: &[5, 0, 0, 0, 6, 0],
            n_in: &[1, 1, 1],
            dlens: &[9, 7, 12],
            klens: &[2, 0, 4],
            uniforms: &uniforms,
            temps: &[1.0, 1.0, 1.0],
            tps: &[1.0, 1.0, 1.0],
            stepping: &[true, false, true],
        };
        let (pt, pq) = packed.draft(&mut cx, &io).unwrap();
        let (st, sq) = stub.draft(&mut cx, &io).unwrap();
        assert_eq!(pt, st, "packed draft tokens match the stub bitwise");
        assert_eq!(pq, sq, "packed draft q-dists match the stub bitwise");
        // Verify: ragged q_i under launch q = 5, Husk row reads nothing.
        let q = k + 1;
        let mut vtokens = vec![0i32; 3 * q];
        vtokens[0] = 5;
        vtokens[1..3].copy_from_slice(&pt[0..2]);
        vtokens[2 * q] = 6;
        vtokens[2 * q + 1..2 * q + 1 + k].copy_from_slice(&pt[8..12]);
        let vio = VerifyIo {
            q,
            vtokens: &vtokens,
            mlens: &[10, 7, 13],
            qlens: &[3, 0, 5],
            stepping: &[true, false, true],
        };
        let pl = packed.verify(&mut cx, &vio).unwrap();
        let sl = stub.verify(&mut cx, &vio).unwrap();
        assert_eq!(pl, sl, "packed verify logits match the stub bitwise");
    }

    #[test]
    fn packed_verify_launch_beats_the_pad_rectangle() {
        let eng = Engine::stub();
        let cfg = SpecConfig { mode: ExecMode::Packed,
                               ..SpecConfig::default() };
        let main_info = eng.manifest.model("main").unwrap().clone();
        let draft_info = eng.manifest.model("draft_a").unwrap().clone();
        let mut secs = 0.0;
        let mut flops = FlopCounter::default();
        let mut cx = ExecCtx {
            engine: &eng,
            cfg: &cfg,
            main_info: &main_info,
            draft_info: &draft_info,
            prefill_secs: &mut secs,
            flops: &mut flops,
            tracer: Tracer::disabled(),
        };
        let mut be = PackedBackend {
            store: None, started: true, host_only: true,
        };
        // Ragged widths under launch q = 5: Σq_i = 8 rides the q' = 5
        // ladder rung (C = 10), but row 0 only computes q_0 = 3.
        let q = 5;
        let vio = VerifyIo {
            q,
            vtokens: &vec![1i32; 2 * q],
            mlens: &[20, 30],
            qlens: &[3, 5],
            stepping: &[true, true],
        };
        be.verify(&mut cx, &vio).unwrap();
        assert!(flops.launch > 0.0);
        assert!(flops.launch < flops.padded_launch,
                "ragged widths must launch fewer FLOPs than PAD's \
                 rectangle (launch {} vs padded {})",
                flops.launch, flops.padded_launch);
        // A fully rectangular batch packs with no saving beyond the
        // ladder rounding: launch stays ≤ padded.
        let mut flops2 = FlopCounter::default();
        let mut cx2 = ExecCtx {
            engine: &eng,
            cfg: &cfg,
            main_info: &main_info,
            draft_info: &draft_info,
            prefill_secs: &mut secs,
            flops: &mut flops2,
            tracer: Tracer::disabled(),
        };
        let vio_full = VerifyIo {
            q,
            vtokens: &vec![1i32; 2 * q],
            mlens: &[20, 30],
            qlens: &[5, 5],
            stepping: &[true, true],
        };
        be.verify(&mut cx2, &vio_full).unwrap();
        assert!(flops2.launch <= flops2.padded_launch);
    }
}
