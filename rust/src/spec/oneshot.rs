//! The one-shot convenience wrapper over the resumable batch API:
//! [`SpecEngine::generate`] admits a whole prompt batch, steps it to
//! completion (or its time budget) and aggregates the run into a
//! [`SpecResult`] — what the benches, eval harness and CLI drive.

use anyhow::{bail, Result};

use crate::flops::FlopCounter;
use crate::kv::SeqState;
use crate::metrics::BatchMetrics;
use crate::runtime::Engine;

use super::config::SpecConfig;
use super::engine::SpecBatch;

/// Result of one batched speculative generation.
#[derive(Debug)]
pub struct SpecResult {
    /// Final state of every *real* (non-padding) sequence.
    pub seqs: Vec<SeqState>,
    pub metrics: BatchMetrics,
    /// Total draft tokens proposed / accepted (acceptance-rate numerator
    /// counts accepted drafts only, not corrections).
    pub drafted: usize,
    pub accepted: usize,
    pub steps: usize,
    /// Prefill wall time (reported separately; PTL clocks start after
    /// prefill, matching the paper's incremental-decoding focus).
    pub prefill_secs: f64,
    pub draft_secs: f64,
    pub verify_secs: f64,
    pub flops: FlopCounter,
    /// History of (draft length used, accepted counts) per step.
    pub step_log: Vec<(usize, Vec<usize>)>,
}

pub struct SpecEngine<'a> {
    pub engine: &'a Engine,
    pub cfg: SpecConfig,
}

impl<'a> SpecEngine<'a> {
    pub fn new(engine: &'a Engine, cfg: SpecConfig) -> SpecEngine<'a> {
        SpecEngine { engine, cfg }
    }

    /// Generate completions for a batch of prompts (1 ≤ n ≤ largest batch
    /// bucket). Prompts longer than the prefill capacity keep their tail.
    /// This is a thin one-shot loop over the resumable [`SpecBatch`] API:
    /// admit everything, step until done (or the time budget expires),
    /// retire everything.
    pub fn generate(&self, prompts: &[Vec<u8>]) -> Result<SpecResult> {
        let cfg = &self.cfg;
        if prompts.is_empty() {
            bail!("empty prompt batch");
        }
        let mut batch =
            SpecBatch::new(self.engine, cfg.clone(), prompts.len())?;
        let mut ids = Vec::with_capacity(prompts.len());
        for p in prompts {
            ids.push(batch.admit(p, cfg.seed)?);
        }
        while batch.has_active() {
            if let Some(budget) = cfg.time_budget_secs {
                if batch.elapsed_secs() >= budget {
                    break;
                }
            }
            batch.step()?;
        }
        let wall = batch.elapsed_secs();
        let seqs: Vec<SeqState> = ids
            .into_iter()
            .map(|id| batch.retire(id))
            .collect::<Result<_>>()?;
        let mut metrics = BatchMetrics::from_seqs(&seqs, wall);
        metrics.steps = batch.steps;
        metrics.acceptance_rate = if batch.drafted > 0 {
            batch.accepted as f64 / batch.drafted as f64
        } else {
            0.0
        };
        metrics.tokens_per_step = if batch.steps > 0 {
            metrics.total_tokens as f64 / batch.steps as f64
        } else {
            0.0
        };
        Ok(SpecResult {
            seqs,
            metrics,
            drafted: batch.drafted,
            accepted: batch.accepted,
            steps: batch.steps,
            prefill_secs: batch.prefill_secs,
            draft_secs: batch.draft_secs,
            verify_secs: batch.verify_secs,
            flops: batch.flops.clone(),
            step_log: batch.step_log.clone(),
        })
    }
}
