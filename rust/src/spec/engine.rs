//! The BASS speculative decoding loop (paper §3): batched drafting,
//! batched ragged verification, per-sequence acceptance, draft-length
//! control and PAD/SPLIT execution.
//!
//! One step, for a batch where every sequence `i` has its own cache length:
//!
//! ```text
//!   k  = bucket(policy.current())
//!   draft : d_1..d_k per sequence  (one fused draft artifact call)
//!   verify: main decode over [pending, d_1..d_k]  (Q = k+1)
//!   per sequence: stochastic accept/reject (sampling.rs) -> a_i accepted,
//!     corrected/bonus next token; cache lengths advance by 1 + a_i
//!     (raggedly!), draft rolls back to its accepted prefix
//!   policy.observe(a_1..a_b)   (Algorithm 1)
//! ```
//!
//! BASS-PAD runs one batched artifact padded to the bucket size; BASS-SPLIT
//! runs per-sequence B=1 artifacts, skipping finished sequences entirely —
//! the same compute/launch trade the paper's Figure 4 kernels make.

use std::time::Instant;

use anyhow::{bail, Result};
use xla::PjRtBuffer;

use crate::flops::FlopCounter;
use crate::kv::SeqState;
use crate::metrics::BatchMetrics;
use crate::runtime::{Attn, Engine, Precision};
use crate::sampling::{logp_of, spec_accept, warp_top_p, Pcg32};
use crate::spec::draft_len::{DraftLenPolicy, Fixed, Heuristic};

/// How model calls are batched (paper Fig 4b vs 4c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One batched artifact padded to the batch bucket (BASS-PAD).
    Pad,
    /// Per-sequence B=1 artifacts (BASS-SPLIT).
    Split,
}

/// Draft-length policy selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Paper Algorithm 1 (testbed constants, l_limit matching buckets).
    Heuristic,
    /// Constant draft length (Table 6 ablation rows).
    Fixed(usize),
}

/// Configuration of one speculative generation run.
#[derive(Debug, Clone)]
pub struct SpecConfig {
    pub main_model: String,
    pub draft_model: String,
    pub precision: Precision,
    pub attn: Attn,
    pub temperature: f32,
    pub top_p: f32,
    pub max_new_tokens: usize,
    pub policy: Policy,
    pub mode: ExecMode,
    pub seed: u64,
    /// Wall-clock budget from generation start (Fig 5); sequences still
    /// running when it expires are left unfinished.
    pub time_budget_secs: Option<f64>,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig {
            main_model: "main".into(),
            draft_model: "draft_a".into(),
            precision: Precision::F32,
            attn: Attn::Dense,
            temperature: 0.2,
            top_p: 0.95,
            max_new_tokens: 96,
            policy: Policy::Heuristic,
            mode: ExecMode::Pad,
            seed: 0,
            time_budget_secs: None,
        }
    }
}

/// Result of one batched speculative generation.
#[derive(Debug)]
pub struct SpecResult {
    /// Final state of every *real* (non-padding) sequence.
    pub seqs: Vec<SeqState>,
    pub metrics: BatchMetrics,
    /// Total draft tokens proposed / accepted (acceptance-rate numerator
    /// counts accepted drafts only, not corrections).
    pub drafted: usize,
    pub accepted: usize,
    pub steps: usize,
    /// Prefill wall time (reported separately; PTL clocks start after
    /// prefill, matching the paper's incremental-decoding focus).
    pub prefill_secs: f64,
    pub draft_secs: f64,
    pub verify_secs: f64,
    pub flops: FlopCounter,
    /// History of (draft length used, accepted counts) per step.
    pub step_log: Vec<(usize, Vec<usize>)>,
}

/// Device cache handles, PAD (one set) or SPLIT (one set per sequence).
enum CacheStore {
    Pad { main: Vec<PjRtBuffer>, draft: Vec<PjRtBuffer> },
    Split { main: Vec<Vec<PjRtBuffer>>, draft: Vec<Vec<PjRtBuffer>> },
}

pub struct SpecEngine<'a> {
    pub engine: &'a Engine,
    pub cfg: SpecConfig,
}

impl<'a> SpecEngine<'a> {
    pub fn new(engine: &'a Engine, cfg: SpecConfig) -> SpecEngine<'a> {
        SpecEngine { engine, cfg }
    }

    /// Generate completions for a batch of prompts (1 ≤ n ≤ largest batch
    /// bucket). Prompts longer than the prefill capacity keep their tail.
    pub fn generate(&self, prompts: &[Vec<u8>]) -> Result<SpecResult> {
        let cfg = &self.cfg;
        let eng = self.engine;
        let man = &eng.manifest;
        let b_real = prompts.len();
        if b_real == 0 {
            bail!("empty prompt batch");
        }
        let b = match cfg.mode {
            ExecMode::Pad => man.bucket_batch(b_real)?,
            ExecMode::Split => b_real,
        };
        let p_cap = man.prefill_p;
        let main_info = man.model(&cfg.main_model)?.clone();
        let draft_info = man.model(&cfg.draft_model)?.clone();
        let s_max = main_info.s_max as i32;
        let vocab = man.vocab;

        // ---- prompt prep (pad rows replicate row 0) ------------------------
        let mut tokens = vec![0i32; b * p_cap];
        let mut plens = vec![0i32; b];
        let mut states: Vec<SeqState> = Vec::with_capacity(b);
        for i in 0..b {
            let src = &prompts[i.min(b_real - 1)];
            let tail: &[u8] = if src.len() > p_cap {
                &src[src.len() - p_cap..]
            } else {
                src
            };
            if tail.is_empty() {
                bail!("empty prompt");
            }
            for (j, &byte) in tail.iter().enumerate() {
                tokens[i * p_cap + j] = byte as i32;
            }
            plens[i] = tail.len() as i32;
            states.push(SeqState::new(tail.to_vec(), *tail.last().unwrap(),
                                      tail.len() as i32));
        }

        // ---- prefill --------------------------------------------------------
        let t_prefill = Instant::now();
        let mut flops = FlopCounter::default();
        let mut store = self.prefill_all(b, &tokens, &plens, &mut flops,
                                         &main_info, &draft_info)?;
        let prefill_secs = t_prefill.elapsed().as_secs_f64();

        // ---- the speculative loop -------------------------------------------
        let mut policy: Box<dyn DraftLenPolicy> = match cfg.policy {
            Policy::Heuristic => Box::new(Heuristic::testbed()),
            Policy::Fixed(k) => Box::new(Fixed(k)),
        };
        let mut rng_draft: Vec<Pcg32> = (0..b)
            .map(|i| Pcg32::new(cfg.seed, 2 * i as u64))
            .collect();
        let mut rng_accept: Vec<Pcg32> = (0..b)
            .map(|i| Pcg32::new(cfg.seed, 2 * i as u64 + 1))
            .collect();

        let t0 = Instant::now();
        let now = |t: Instant| t.elapsed().as_secs_f64();
        let mut drafted = 0usize;
        let mut accepted_total = 0usize;
        let mut steps = 0usize;
        let mut draft_secs = 0.0f64;
        let mut verify_secs = 0.0f64;
        let mut step_log = Vec::new();

        while states[..b_real].iter().any(|s| s.active()) {
            if let Some(budget) = cfg.time_budget_secs {
                if now(t0) >= budget {
                    break;
                }
            }
            let k = man.bucket_k(&cfg.draft_model, policy.current());

            // -- draft ---------------------------------------------------------
            let mut tokens_in = vec![0i32; b * 2];
            let mut n_in = vec![1i32; b];
            let mut dlens = vec![0i32; b];
            let mut uniforms = vec![0f32; b * k];
            for i in 0..b {
                let s = &states[i];
                tokens_in[i * 2] = s.pending_draft[0] as i32;
                tokens_in[i * 2 + 1] = s.pending_draft[1] as i32;
                n_in[i] = s.n_pending_draft;
                dlens[i] = s.draft_len;
                for j in 0..k {
                    uniforms[i * k + j] = rng_draft[i].next_f32();
                }
            }
            let td = Instant::now();
            let (draft_tokens, qdists) = self.draft_all(
                &mut store, b, k, &tokens_in, &n_in, &dlens, &uniforms,
                &states)?;
            draft_secs += now(td);
            let ctx_d = states.iter().map(|s| s.draft_len as usize)
                .sum::<usize>() / b;
            flops.add_step(&draft_info, self.active_count(&states, b),
                           k + 1, ctx_d);

            // -- verify ----------------------------------------------------------
            let q = k + 1;
            let mut vtokens = vec![0i32; b * q];
            let mut mlens = vec![0i32; b];
            for i in 0..b {
                vtokens[i * q] = states[i].pending_main as i32;
                for j in 0..k {
                    vtokens[i * q + 1 + j] = draft_tokens[i * k + j];
                }
                mlens[i] = states[i].main_len;
            }
            let tv = Instant::now();
            let logits = self.verify_all(&mut store, b, q, &vtokens, &mlens,
                                         &states)?;
            verify_secs += now(tv);
            let ctx_m = states.iter().map(|s| s.main_len as usize)
                .sum::<usize>() / b;
            flops.add_step(&main_info, self.active_count(&states, b), q,
                           ctx_m);

            // -- accept/reject per sequence (host) --------------------------------
            let mut accepted_counts = Vec::new();
            for i in 0..b {
                if !states[i].active() {
                    continue;
                }
                // Warp main distributions for positions 0..=k.
                let warped: Vec<Vec<f32>> = (0..q)
                    .map(|j| {
                        let row = &logits[(i * q + j) * vocab
                                          ..(i * q + j + 1) * vocab];
                        warp_top_p(row, cfg.temperature, cfg.top_p)
                    })
                    .collect();
                let p_refs: Vec<&[f32]> =
                    warped.iter().map(|w| w.as_slice()).collect();
                let d_tokens: Vec<usize> = (0..k)
                    .map(|j| draft_tokens[i * k + j] as usize)
                    .collect();
                let q_refs: Vec<&[f32]> = (0..k)
                    .map(|j| &qdists[(i * k + j) * vocab
                                     ..(i * k + j + 1) * vocab])
                    .collect();
                let out = spec_accept(&p_refs, &d_tokens, &q_refs,
                                      &mut rng_accept[i]);

                let acc_bytes: Vec<u8> = d_tokens[..out.accepted]
                    .iter()
                    .map(|&t| t as u8)
                    .collect();
                let mut logp = logp_of(&warped[out.accepted],
                                       out.next_token) as f64;
                for (j, &d) in d_tokens[..out.accepted].iter().enumerate() {
                    logp += logp_of(&warped[j], d) as f64;
                }
                let n_in_used = states[i].n_pending_draft;
                let emitted = states[i].apply_step(
                    &acc_bytes, out.next_token as u8, out.bonus, k,
                    n_in_used, logp);
                if i < b_real {
                    drafted += k;
                    accepted_total += out.accepted;
                    accepted_counts.push(out.accepted);
                }
                let t_now = now(t0);
                states[i].check_eos(man.eos, emitted, t_now);
                states[i].check_limits(cfg.max_new_tokens, s_max,
                                       (k + 2) as i32, t_now);
                debug_assert!(states[i].check_invariants(s_max).is_ok());
            }
            steps += 1;
            step_log.push((k, accepted_counts.clone()));
            policy.observe(&accepted_counts);
        }

        // ---- wrap up -----------------------------------------------------------
        let wall = now(t0);
        states.truncate(b_real);
        let mut metrics = BatchMetrics::from_seqs(&states, wall);
        metrics.steps = steps;
        metrics.acceptance_rate = if drafted > 0 {
            accepted_total as f64 / drafted as f64
        } else {
            0.0
        };
        metrics.tokens_per_step = if steps > 0 {
            metrics.total_tokens as f64 / steps as f64
        } else {
            0.0
        };
        Ok(SpecResult {
            seqs: states,
            metrics,
            drafted,
            accepted: accepted_total,
            steps,
            prefill_secs,
            draft_secs,
            verify_secs,
            flops,
            step_log,
        })
    }

    fn active_count(&self, states: &[SeqState], b: usize) -> usize {
        match self.cfg.mode {
            // PAD computes every row, active or not.
            ExecMode::Pad => b,
            ExecMode::Split => states.iter().filter(|s| s.active()).count(),
        }
    }

    // -- mode-dispatched model calls ---------------------------------------------

    fn prefill_all(&self, b: usize, tokens: &[i32], plens: &[i32],
                   flops: &mut FlopCounter,
                   main_info: &crate::runtime::ModelInfo,
                   draft_info: &crate::runtime::ModelInfo)
                   -> Result<CacheStore> {
        let cfg = &self.cfg;
        let eng = self.engine;
        let p = eng.manifest.prefill_p;
        flops.add_prefill(main_info, b, p);
        flops.add_prefill(draft_info, b, p);
        match cfg.mode {
            ExecMode::Pad => {
                let m = eng.prefill(&cfg.main_model, cfg.precision, cfg.attn,
                                    b, tokens, plens)?;
                let d = eng.prefill(&cfg.draft_model, cfg.precision,
                                    cfg.attn, b, tokens, plens)?;
                Ok(CacheStore::Pad { main: m.caches, draft: d.caches })
            }
            ExecMode::Split => {
                let mut main = Vec::with_capacity(b);
                let mut draft = Vec::with_capacity(b);
                for i in 0..b {
                    let row = &tokens[i * p..(i + 1) * p];
                    let m = eng.prefill(&cfg.main_model, cfg.precision,
                                        cfg.attn, 1, row, &plens[i..=i])?;
                    let d = eng.prefill(&cfg.draft_model, cfg.precision,
                                        cfg.attn, 1, row, &plens[i..=i])?;
                    main.push(m.caches);
                    draft.push(d.caches);
                }
                Ok(CacheStore::Split { main, draft })
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn draft_all(&self, store: &mut CacheStore, b: usize, k: usize,
                 tokens_in: &[i32], n_in: &[i32], dlens: &[i32],
                 uniforms: &[f32], states: &[SeqState])
                 -> Result<(Vec<i32>, Vec<f32>)> {
        let cfg = &self.cfg;
        let eng = self.engine;
        let vocab = eng.manifest.vocab;
        match store {
            CacheStore::Pad { draft, .. } => {
                let caches = std::mem::take(draft);
                let out = eng.draft(&cfg.draft_model, cfg.precision,
                                    cfg.attn, b, k, tokens_in, n_in, dlens,
                                    uniforms, cfg.temperature, cfg.top_p,
                                    caches)?;
                *draft = out.caches;
                Ok((out.tokens, out.qdists))
            }
            CacheStore::Split { draft, .. } => {
                let mut toks = vec![0i32; b * k];
                let mut qd = vec![0f32; b * k * vocab];
                for i in 0..b {
                    if !states[i].active() {
                        continue; // SPLIT skips finished sequences
                    }
                    let caches = std::mem::take(&mut draft[i]);
                    let out = eng.draft(
                        &cfg.draft_model, cfg.precision, cfg.attn, 1, k,
                        &tokens_in[i * 2..i * 2 + 2], &n_in[i..=i],
                        &dlens[i..=i], &uniforms[i * k..(i + 1) * k],
                        cfg.temperature, cfg.top_p, caches)?;
                    draft[i] = out.caches;
                    toks[i * k..(i + 1) * k].copy_from_slice(&out.tokens);
                    qd[i * k * vocab..(i + 1) * k * vocab]
                        .copy_from_slice(&out.qdists);
                }
                Ok((toks, qd))
            }
        }
    }

    fn verify_all(&self, store: &mut CacheStore, b: usize, q: usize,
                  vtokens: &[i32], mlens: &[i32], states: &[SeqState])
                  -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let eng = self.engine;
        let vocab = eng.manifest.vocab;
        match store {
            CacheStore::Pad { main, .. } => {
                let caches = std::mem::take(main);
                let out = eng.decode(&cfg.main_model, cfg.precision,
                                     cfg.attn, b, q, vtokens, mlens,
                                     caches)?;
                *main = out.caches;
                Ok(out.logits)
            }
            CacheStore::Split { main, .. } => {
                let mut logits = vec![0f32; b * q * vocab];
                for i in 0..b {
                    if !states[i].active() {
                        continue;
                    }
                    let caches = std::mem::take(&mut main[i]);
                    let out = eng.decode(
                        &cfg.main_model, cfg.precision, cfg.attn, 1, q,
                        &vtokens[i * q..(i + 1) * q], &mlens[i..=i],
                        caches)?;
                    main[i] = out.caches;
                    logits[i * q * vocab..(i + 1) * q * vocab]
                        .copy_from_slice(&out.logits);
                }
                Ok(logits)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_is_sane() {
        let c = SpecConfig::default();
        assert_eq!(c.main_model, "main");
        assert_eq!(c.mode, ExecMode::Pad);
        assert!(matches!(c.policy, Policy::Heuristic));
    }
}
