//! The BASS speculative decoding loop (paper §3), decomposed into a
//! **resumable step API** so a serving layer can do continuous batching.
//!
//! [`SpecBatch`] owns the device caches and per-slot sequence state and
//! exposes three operations the coordinator drives at step boundaries:
//!
//! * [`SpecBatch::admit`] — place a prompt into a free slot, **in either
//!   mode at any step boundary**. SPLIT prefills the slot's own B=1
//!   caches; PAD admission into a running batch scatter-prefills the new
//!   sequence into a freed row (a retired Husk or padding Shadow) of the
//!   fused cache via the per-row `prefill_scatter` artifact
//!   ([`Engine::prefill_into_slot`]), so the batch never has to drain.
//!   [`AdmitOpts`] carries per-sequence overrides — `max_new_tokens`, a
//!   pinned RNG stream, and **per-sequence sampling params**:
//!   `temperature` / `top_p` live in the slot and flow as `[B]` rows into
//!   the fused draft artifact and into the host-side verify warp, so
//!   co-batched requests never have to agree on sampling knobs.
//! * [`SpecBatch::step`] — one draft + verify + accept round over the
//!   currently-active slots:
//!
//!   ```text
//!     k  = bucket(policy.current())
//!     draft : d_1..d_k per sequence  (one fused draft artifact call)
//!     verify: main decode over [pending, d_1..d_k]  (Q = k+1)
//!     per sequence: stochastic accept/reject (sampling.rs) -> a_i accepted,
//!       corrected/bonus next token; cache lengths advance by 1 + a_i
//!       (raggedly!), draft rolls back to its accepted prefix
//!     policy.observe(a_1..a_b)   (Algorithm 1)
//!   ```
//!
//! * [`SpecBatch::retire`] — take a sequence's final state out of the
//!   batch, freeing its slot. In SPLIT mode the slot's caches are dropped
//!   and the slot is immediately reusable by the next `admit`; in PAD mode
//!   the row freezes into a Husk placeholder that the next admission
//!   scatter-prefills over (the batch still auto-resets to full capacity
//!   when the last real sequence leaves, so an idle engine re-buckets).
//! * [`SpecBatch::suspend`] / [`SpecBatch::resume`] — **preemption**.
//!   Suspend lifts a still-running sequence out of the batch as a
//!   host-side [`SuspendedSeq`] (verified bytes, PCG32 stream positions,
//!   per-sequence sampling params and budget) and frees its slot exactly
//!   like `retire`; the device KV is deliberately dropped. Resume rebuilds
//!   the KV row by **recompute**: a fresh prefill over
//!   `prompt ‖ generated` — per-slot (SPLIT) or scatter (running PAD) —
//!   using the *existing* v3 artifacts, no new ABI. Because the ragged
//!   attention masks per query position with exact-zero pad probability
//!   and each position's KV is a pure function of its token prefix, the
//!   recomputed row is **bitwise identical** to the incrementally built
//!   one (pinned host-side by `test_parity.py::test_resume_recompute_*`
//!   and end-to-end by `rust/tests/step_equivalence.rs` /
//!   `admission_interleaving.rs`), so a preempted-then-resumed sequence
//!   reproduces its uninterrupted run byte-for-byte under
//!   [`Policy::Fixed`]. The suspended set lives on the host, so a serving
//!   layer can hold more admitted work than there are device slots —
//!   suspend-to-host is the recompute analog of paging KV out. The one
//!   bound: `prompt ‖ generated` must still fit the prefill capacity
//!   (`manifest.prefill_p`) or the resume could not be exact —
//!   [`SpecBatch::can_suspend`] checks; longer sequences are pinned to
//!   their slot and schedulers must pick another victim.
//!
//! Each admitted sequence gets its own pair of PCG32 streams keyed by a
//! monotonically increasing admission counter, so given the same per-step
//! draft lengths a sequence's output is a function of (prompt, seed,
//! admission index) only — *not* of what else is or was in the batch.
//! Draft lengths are exactly reproducible under [`Policy::Fixed`]; under
//! the adaptive heuristic they are batch-global Algorithm-1 state fed by
//! every co-batched sequence (by design). That is what makes stepwise
//! driving with mid-flight admission — in both modes — reproduce one-shot
//! [`SpecEngine::generate`] byte-for-byte
//! (`rust/tests/step_equivalence.rs`, and under randomized
//! admit/step/retire schedules, `rust/tests/admission_interleaving.rs`).
//!
//! BASS-PAD runs one batched artifact padded to the batch bucket; BASS-SPLIT
//! runs per-sequence B=1 artifacts, skipping finished sequences entirely —
//! the same compute/launch trade the paper's Figure 4 kernels make.

use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::PjRtBuffer;

use crate::flops::FlopCounter;
use crate::kv::{FinishReason, SeqState};
use crate::metrics::BatchMetrics;
use crate::runtime::{Attn, Engine, ModelInfo, Precision};
use crate::sampling::{logp_of, spec_accept, warp_top_p, Pcg32};
use crate::spec::draft_len::{DraftLenPolicy, Fixed, Heuristic};

/// How model calls are batched (paper Fig 4b vs 4c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One batched artifact padded to the batch bucket (BASS-PAD).
    Pad,
    /// Per-sequence B=1 artifacts (BASS-SPLIT).
    Split,
}

/// Draft-length policy selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Paper Algorithm 1 (testbed constants, l_limit matching buckets).
    Heuristic,
    /// Constant draft length (Table 6 ablation rows).
    Fixed(usize),
}

/// Configuration of one speculative generation run.
#[derive(Debug, Clone)]
pub struct SpecConfig {
    pub main_model: String,
    pub draft_model: String,
    pub precision: Precision,
    pub attn: Attn,
    /// Default sampling temperature; sequences admitted with an
    /// [`AdmitOpts`] override keep their own (per-row everywhere).
    pub temperature: f32,
    /// Default nucleus threshold (same override scope as `temperature`).
    pub top_p: f32,
    pub max_new_tokens: usize,
    pub policy: Policy,
    pub mode: ExecMode,
    pub seed: u64,
    /// Wall-clock budget from generation start (Fig 5); sequences still
    /// running when it expires are left unfinished.
    pub time_budget_secs: Option<f64>,
    /// PAD grow-room: pad the initial bucket up to this many rows above
    /// the admitted count (clamped to the serving capacity and the
    /// largest exported bucket), so a running fused batch keeps reusable
    /// padding rows for mid-flight admissions instead of making a burst
    /// wait for the drain-and-re-bucket. 0 (the default) reproduces the
    /// tight bucket. SPLIT ignores it (slots are always per-sequence).
    pub pad_headroom: usize,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig {
            main_model: "main".into(),
            draft_model: "draft_a".into(),
            precision: Precision::F32,
            attn: Attn::Dense,
            temperature: 0.2,
            top_p: 0.95,
            max_new_tokens: 96,
            policy: Policy::Heuristic,
            mode: ExecMode::Pad,
            seed: 0,
            time_budget_secs: None,
            pad_headroom: 0,
        }
    }
}

/// Result of one batched speculative generation.
#[derive(Debug)]
pub struct SpecResult {
    /// Final state of every *real* (non-padding) sequence.
    pub seqs: Vec<SeqState>,
    pub metrics: BatchMetrics,
    /// Total draft tokens proposed / accepted (acceptance-rate numerator
    /// counts accepted drafts only, not corrections).
    pub drafted: usize,
    pub accepted: usize,
    pub steps: usize,
    /// Prefill wall time (reported separately; PTL clocks start after
    /// prefill, matching the paper's incremental-decoding focus).
    pub prefill_secs: f64,
    pub draft_secs: f64,
    pub verify_secs: f64,
    pub flops: FlopCounter,
    /// History of (draft length used, accepted counts) per step.
    pub step_log: Vec<(usize, Vec<usize>)>,
}

/// Identity of one admitted sequence (the admission counter; unique for
/// the lifetime of a [`SpecBatch`], never reused across slot turnover).
pub type SeqId = u64;

/// What happened to one live sequence during a [`SpecBatch::step`].
#[derive(Debug, Clone)]
pub struct SeqEvent {
    pub id: SeqId,
    /// Draft tokens accepted this step (0..=k).
    pub accepted: usize,
    /// Bytes appended to the sequence this step, post-EOS truncation.
    pub new_bytes: Vec<u8>,
    /// Sequence finished this step (EOS / length / capacity).
    pub done: bool,
    pub finish: FinishReason,
}

/// Outcome of one [`SpecBatch::step`].
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    /// 0-based index of the step just executed.
    pub step: usize,
    /// Draft length used (bucketized).
    pub k: usize,
    /// Per-sequence events, in slot order (live sequences only).
    pub events: Vec<SeqEvent>,
    /// Sequences that finished on this step (retire them to free slots).
    pub finished: Vec<SeqId>,
    /// Real sequences still generating after this step.
    pub active: usize,
    /// Real sequences occupying slots (active + finished-but-unretired).
    pub occupied: usize,
}

/// Device cache handles, PAD (one fused set) or SPLIT (one set per slot;
/// empty vectors mark free slots).
enum CacheStore {
    Pad { main: Vec<PjRtBuffer>, draft: Vec<PjRtBuffer> },
    Split { main: Vec<Vec<PjRtBuffer>>, draft: Vec<Vec<PjRtBuffer>> },
}

/// Per-admission overrides for [`SpecBatch::admit_opts`]. Every `None`
/// falls back to the batch-wide [`SpecConfig`] value, so
/// `AdmitOpts::default()` reproduces plain [`SpecBatch::admit`].
#[derive(Debug, Clone, Default)]
pub struct AdmitOpts {
    /// Per-sequence generation limit.
    pub max_new_tokens: Option<usize>,
    /// Pinned PCG32 stream index (see [`SpecBatch::admit_opts`]).
    pub stream: Option<u64>,
    /// Per-sequence sampling temperature — drives both this row of the
    /// fused draft artifact and the verify-side warp.
    pub temperature: Option<f32>,
    /// Per-sequence nucleus threshold (same scope as `temperature`).
    pub top_p: Option<f32>,
}

impl AdmitOpts {
    /// Range-check the sampling overrides; the `Err` names the offending
    /// field. [`SpecBatch::admit_opts`] runs this before consuming a slot,
    /// so a bad wire value (`top_p: 0`, NaN, …) fails that one request
    /// up front instead of warping its rows into all-zero/NaN
    /// distributions mid-generation.
    pub fn validate(&self) -> Result<()> {
        if let Some(t) = self.temperature {
            if !t.is_finite() || t < 0.0 {
                bail!("temperature must be finite and >= 0 (got {t})");
            }
        }
        if let Some(p) = self.top_p {
            if !p.is_finite() || p <= 0.0 || p > 1.0 {
                bail!("top_p must be in (0, 1] (got {p})");
            }
        }
        Ok(())
    }
}

/// A sequence lifted out of the batch by [`SpecBatch::suspend`]: the
/// complete host-side identity — prompt, verified output bytes, PCG32
/// stream positions, per-sequence sampling params and generation budget.
/// Device KV is deliberately **not** captured: [`SpecBatch::resume`]
/// rebuilds it bitwise by recomputing a prefill over
/// `prompt ‖ generated` with the existing artifacts, so a snapshot costs
/// a few hundred host bytes and resuming costs one prefill — the
/// recompute end of the preemption cost model (cheap to hold, one
/// prompt-length compute to reinstate).
#[derive(Debug, Clone)]
pub struct SuspendedSeq {
    prompt: Vec<u8>,
    generated: Vec<u8>,
    logp_sum: f64,
    rng_draft: Pcg32,
    rng_accept: Pcg32,
    max_new_tokens: usize,
    temperature: f32,
    top_p: f32,
}

impl SuspendedSeq {
    /// Build a snapshot "as if" freshly admitted with `admit_opts(prompt,
    /// seed, opts)` and suspended before any step: zero progress, RNG
    /// streams at their start. Lets a scheduler park work host-side
    /// without ever occupying a device slot (and lets host-only tests
    /// construct parked entries). An unpinned `opts.stream` defaults to
    /// stream 0 — callers wanting the batch's admission-counter streams
    /// should admit for real instead.
    pub fn fresh(prompt: &[u8], seed: u64, opts: &AdmitOpts,
                 cfg: &SpecConfig) -> SuspendedSeq {
        let stream = opts.stream.unwrap_or(0);
        SuspendedSeq {
            prompt: prompt.to_vec(),
            generated: Vec::new(),
            logp_sum: 0.0,
            rng_draft: Pcg32::new(seed, 2 * stream),
            rng_accept: Pcg32::new(seed, 2 * stream + 1),
            max_new_tokens: opts
                .max_new_tokens
                .unwrap_or(cfg.max_new_tokens),
            temperature: opts.temperature.unwrap_or(cfg.temperature),
            top_p: opts.top_p.unwrap_or(cfg.top_p),
        }
    }

    /// Output bytes verified before the suspension.
    pub fn tokens_generated(&self) -> usize {
        self.generated.len()
    }

    /// Length of the verified context (`prompt ‖ generated`) a resume
    /// must recompute; must fit `manifest.prefill_p` to be resumable.
    pub fn context_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    /// Collapse into a plain (still `Running`) sequence state — what a
    /// serving layer reports when it must answer a request whose
    /// sequence is parked (time-budget expiry, shutdown) without
    /// resuming it.
    pub fn into_state(self) -> SeqState {
        SeqState::resumed(self.prompt, self.generated, self.logp_sum)
    }
}

/// One occupied slot: sequence state plus its private RNG streams and
/// sampling params.
struct Slot {
    id: SeqId,
    state: SeqState,
    rng_draft: Pcg32,
    rng_accept: Pcg32,
    max_new_tokens: usize,
    /// Per-sequence sampling params (seeded from [`SpecConfig`], overridden
    /// per admission): used for this row of the fused draft call and the
    /// host-side verify warp.
    temperature: f32,
    top_p: f32,
}

/// A batch row. `Shadow` rows are PAD padding (they advance like real
/// sequences, matching the padded artifact rows, but are never reported);
/// `Husk` rows are retired PAD sequences — frozen state that keeps feeding
/// the fused artifact valid lengths. Both are mid-flight admission
/// targets: a new sequence scatter-prefills over the row and turns it
/// back into `Seq`.
enum Row {
    Free,
    Seq(Slot),
    Shadow(Slot),
    Husk(SeqState),
}

impl Row {
    fn state(&self) -> Option<&SeqState> {
        match self {
            Row::Free => None,
            Row::Seq(s) | Row::Shadow(s) => Some(&s.state),
            Row::Husk(st) => Some(st),
        }
    }

    fn is_free(&self) -> bool {
        matches!(self, Row::Free)
    }
}

/// A resumable speculative batch over up to `capacity` concurrent
/// sequences. See the module docs for the admit / step / retire contract.
pub struct SpecBatch<'a> {
    engine: &'a Engine,
    cfg: SpecConfig,
    capacity: usize,
    rows: Vec<Row>,
    store: Option<CacheStore>,
    policy: Box<dyn DraftLenPolicy>,
    /// Admission counter; doubles as the SeqId and the PCG32 stream index.
    next_stream: u64,
    t0: Option<Instant>,
    main_info: ModelInfo,
    draft_info: ModelInfo,
    s_max: i32,
    // -- aggregates across the batch lifetime ------------------------------
    pub steps: usize,
    pub drafted: usize,
    pub accepted: usize,
    pub prefill_secs: f64,
    pub draft_secs: f64,
    pub verify_secs: f64,
    pub flops: FlopCounter,
    pub step_log: Vec<(usize, Vec<usize>)>,
}

impl<'a> SpecBatch<'a> {
    /// Create an empty batch with room for `capacity` concurrent
    /// sequences. In PAD mode the actual device batch is the smallest
    /// exported bucket covering the admitted count at start time.
    pub fn new(engine: &'a Engine, cfg: SpecConfig, capacity: usize)
               -> Result<SpecBatch<'a>> {
        if capacity == 0 {
            bail!("batch capacity must be >= 1");
        }
        let main_info = engine.manifest.model(&cfg.main_model)?.clone();
        let draft_info = engine.manifest.model(&cfg.draft_model)?.clone();
        let s_max = main_info.s_max as i32;
        let policy = fresh_policy(&cfg);
        let store = match cfg.mode {
            ExecMode::Pad => None, // fused prefill happens at first step
            ExecMode::Split => Some(CacheStore::Split {
                main: (0..capacity).map(|_| Vec::new()).collect(),
                draft: (0..capacity).map(|_| Vec::new()).collect(),
            }),
        };
        Ok(SpecBatch {
            engine,
            cfg,
            capacity,
            rows: (0..capacity).map(|_| Row::Free).collect(),
            store,
            policy,
            next_stream: 0,
            t0: None,
            main_info,
            draft_info,
            s_max,
            steps: 0,
            drafted: 0,
            accepted: 0,
            prefill_secs: 0.0,
            draft_secs: 0.0,
            verify_secs: 0.0,
            flops: FlopCounter::default(),
            step_log: Vec::new(),
        })
    }

    // -- introspection ----------------------------------------------------

    /// The batch-wide speculative configuration (mode, policy, sampling
    /// defaults — individual sequences may carry [`AdmitOpts`] overrides).
    pub fn config(&self) -> &SpecConfig {
        &self.cfg
    }

    /// Slots a new sequence could occupy right now. In a *running* PAD
    /// batch these are the reusable rows of the fused bucket — retired
    /// (Husk) and padding (Shadow) rows that mid-flight admission
    /// scatter-prefills over; the bucket itself cannot grow until the
    /// batch drains and re-buckets.
    pub fn free_slots(&self) -> usize {
        if self.cfg.mode == ExecMode::Pad && self.store.is_some() {
            return self
                .rows
                .iter()
                .filter(|r| matches!(r, Row::Husk(_) | Row::Shadow(_)))
                .count();
        }
        self.rows.iter().filter(|r| r.is_free()).count()
    }

    /// True when `admit` would succeed for a 1-sequence request.
    pub fn can_admit(&self) -> bool {
        self.free_slots() > 0
    }

    /// Real sequences occupying slots (active or finished-but-unretired).
    pub fn occupied(&self) -> usize {
        self.rows.iter().filter(|r| matches!(r, Row::Seq(_))).count()
    }

    /// Real sequences still generating.
    pub fn active(&self) -> usize {
        self.rows
            .iter()
            .filter(|r| matches!(r, Row::Seq(s) if s.state.active()))
            .count()
    }

    pub fn has_active(&self) -> bool {
        self.active() > 0
    }

    /// Seconds since the first step began (0 before the batch starts);
    /// the clock `SeqState::finish_secs` and time budgets are measured on.
    pub fn elapsed_secs(&self) -> f64 {
        self.t0.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    // -- admit ------------------------------------------------------------

    /// Admit a prompt into a free slot and return its [`SeqId`]. `seed` is
    /// the RNG seed for this sequence; its PCG32 streams derive from the
    /// batch-lifetime admission counter, so re-admitting the same
    /// prompt+seed into a reused slot still gets fresh randomness. SPLIT
    /// mode prefills the slot's caches immediately; PAD mode defers to the
    /// fused prefill at first step for a not-yet-started batch and
    /// scatter-prefills into a freed row (Husk/Shadow) of a running one.
    pub fn admit(&mut self, prompt: &[u8], seed: u64) -> Result<SeqId> {
        self.admit_opts(prompt, seed, AdmitOpts::default())
    }

    /// [`SpecBatch::admit`] with per-sequence overrides ([`AdmitOpts`]):
    /// a `max_new_tokens` limit, sampling params (`temperature` /
    /// `top_p` — per-row through the draft artifact and the verify-side
    /// warp, so co-batched requests keep their own knobs), and an optional
    /// pinned `stream` index. Pinning the stream makes the randomness a
    /// pure function of (seed, stream) — independent of how many
    /// admissions preceded it — which is what per-request seeds need for
    /// reproducibility under serving traffic (exact for the full output
    /// only when per-step draft lengths also match, i.e.
    /// [`Policy::Fixed`]). Callers pinning streams own the (seed, stream)
    /// uniqueness trade-off; the unpinned default (the admission counter)
    /// never collides within a batch lifetime.
    pub fn admit_opts(&mut self, prompt: &[u8], seed: u64, opts: AdmitOpts)
                      -> Result<SeqId> {
        opts.validate()?;
        let p_cap = self.engine.manifest.prefill_p;
        let tail: &[u8] = if prompt.len() > p_cap {
            &prompt[prompt.len() - p_cap..]
        } else {
            prompt
        };
        if tail.is_empty() {
            bail!("empty prompt");
        }
        if self.cfg.mode == ExecMode::Pad && self.store.is_some() {
            return self.admit_pad_midflight(tail, seed, opts);
        }
        let Some(row) = self.rows.iter().position(Row::is_free) else {
            bail!("no free slot (capacity {})", self.capacity);
        };
        let slot = self.make_slot(tail, seed, opts);
        if self.cfg.mode == ExecMode::Split {
            self.prefill_split_slot(row, &slot.state.prompt)?;
        }
        let id = slot.id;
        self.rows[row] = Row::Seq(slot);
        Ok(id)
    }

    /// Build an occupied-slot record, consuming the next admission index
    /// (the [`SeqId`] and, unless pinned, the PCG32 stream index).
    fn make_slot(&mut self, tail: &[u8], seed: u64, opts: AdmitOpts)
                 -> Slot {
        let id = self.next_stream;
        self.next_stream += 1;
        let stream = opts.stream.unwrap_or(id);
        let state = SeqState::new(tail.to_vec(), *tail.last().unwrap(),
                                  tail.len() as i32);
        Slot {
            id,
            state,
            rng_draft: Pcg32::new(seed, 2 * stream),
            rng_accept: Pcg32::new(seed, 2 * stream + 1),
            max_new_tokens: opts
                .max_new_tokens
                .unwrap_or(self.cfg.max_new_tokens),
            temperature: opts.temperature.unwrap_or(self.cfg.temperature),
            top_p: opts.top_p.unwrap_or(self.cfg.top_p),
        }
    }

    /// Mid-flight PAD admission: scatter-prefill the new sequence into a
    /// reusable row (retired Husk or padding Shadow) of the running fused
    /// batch. The row's whole KV slice is replaced, its slot gets fresh
    /// per-sequence state — sampling params, PCG32 streams, ragged
    /// lengths at `prompt_len - 1` — so the previous occupant cannot leak
    /// into the new sequence, and no other row is touched.
    fn admit_pad_midflight(&mut self, tail: &[u8], seed: u64,
                           opts: AdmitOpts) -> Result<SeqId> {
        let row = self.reusable_pad_row()?;
        self.ensure_scatter_ready()?;
        let slot = self.make_slot(tail, seed, opts);
        self.prefill_pad_row(row, &slot.state.prompt)?;
        let id = slot.id;
        self.rows[row] = Row::Seq(slot);
        Ok(id)
    }

    /// First reusable row of the running fused bucket — a retired Husk or
    /// padding Shadow a mid-flight admission/resume may scatter over.
    fn reusable_pad_row(&self) -> Result<usize> {
        self.rows
            .iter()
            .position(|r| matches!(r, Row::Husk(_) | Row::Shadow(_)))
            .ok_or_else(|| {
                anyhow!("no reusable PAD row (bucket of {} fully live; \
                         wait for a retirement or the drain)",
                        self.rows.len())
            })
    }

    /// Resolve + compile both models' scatter executables up front: the
    /// likely failures (stale pre-v3 artifact set, bucket not exported)
    /// reject only this admission/resume and leave the running batch
    /// intact — as do upload failures inside `prefill_into_slot`, which
    /// consumes the fused caches only at the execute itself. Only an
    /// execute failure (post-donation) is batch-fatal: the next `step`
    /// errors and the serving layer's recovery path fails the in-flight
    /// requests and rebuilds a fresh batch (see `coordinator::worker`).
    fn ensure_scatter_ready(&self) -> Result<()> {
        let b = self.rows.len();
        let cfg = &self.cfg;
        self.engine.ensure_prefill_scatter(&cfg.main_model, cfg.precision,
                                           cfg.attn, b)?;
        self.engine.ensure_prefill_scatter(&cfg.draft_model, cfg.precision,
                                           cfg.attn, b)?;
        Ok(())
    }

    /// Scatter-prefill one context (`ctx` — a fresh admission's prompt,
    /// or a resume's `prompt ‖ generated`) into row `row` of the running
    /// PAD batch's fused caches (both models). Pre-execute failures
    /// leave the caches untouched (see [`Engine::prefill_into_slot`]);
    /// an execute failure leaves that model's cache vector empty — the
    /// batch is poisoned and the next `step` fails, which the
    /// coordinator turns into a full-batch error + rebuild.
    fn prefill_pad_row(&mut self, row: usize, ctx: &[u8]) -> Result<()> {
        let cfg = self.cfg.clone();
        let eng = self.engine;
        let b = self.rows.len();
        let p = eng.manifest.prefill_p;
        let mut tokens = vec![0i32; p];
        for (j, &byte) in ctx.iter().enumerate() {
            tokens[j] = byte as i32;
        }
        let plen = ctx.len() as i32;
        let t0 = Instant::now();
        let Some(CacheStore::Pad { main, draft }) = self.store.as_mut()
        else {
            bail!("PAD store missing");
        };
        eng.prefill_into_slot(&cfg.main_model, cfg.precision, cfg.attn, b,
                              row, &tokens, plen, main)
            .context("PAD scatter prefill (main model)")?;
        eng.prefill_into_slot(&cfg.draft_model, cfg.precision, cfg.attn, b,
                              row, &tokens, plen, draft)
            .context("PAD scatter prefill (draft model)")?;
        self.prefill_secs += t0.elapsed().as_secs_f64();
        self.flops.add_prefill(&self.main_info, 1, p);
        self.flops.add_prefill(&self.draft_info, 1, p);
        Ok(())
    }

    /// Prefill one SPLIT slot (B=1 artifacts for both models) over `ctx`
    /// — a fresh admission's prompt, or a resume's `prompt ‖ generated`.
    fn prefill_split_slot(&mut self, row: usize, ctx: &[u8]) -> Result<()> {
        let cfg = &self.cfg;
        let eng = self.engine;
        let p = eng.manifest.prefill_p;
        let mut tokens = vec![0i32; p];
        for (j, &byte) in ctx.iter().enumerate() {
            tokens[j] = byte as i32;
        }
        let plens = [ctx.len() as i32];
        let t0 = Instant::now();
        let m = eng.prefill(&cfg.main_model, cfg.precision, cfg.attn, 1,
                            &tokens, &plens)?;
        let d = eng.prefill(&cfg.draft_model, cfg.precision, cfg.attn, 1,
                            &tokens, &plens)?;
        self.prefill_secs += t0.elapsed().as_secs_f64();
        self.flops.add_prefill(&self.main_info, 1, p);
        self.flops.add_prefill(&self.draft_info, 1, p);
        match self.store.as_mut() {
            Some(CacheStore::Split { main, draft }) => {
                main[row] = m.caches;
                draft[row] = d.caches;
                Ok(())
            }
            _ => bail!("SPLIT store missing"),
        }
    }

    /// PAD lazy start: bucketize the admitted count (rounded up by
    /// [`SpecConfig::pad_headroom`] so the running bucket keeps reusable
    /// grow-room rows), pad the row vector with shadow sequences
    /// replicating the last real context (exactly the padded rows the
    /// fused artifact computes anyway) and run the fused prefill for both
    /// models. Rows are encoded from their full context
    /// (`prompt ‖ generated`) so resumed sequences placed before the
    /// start prefill their pre-suspend output too.
    fn start_pad(&mut self) -> Result<()> {
        let cfg = self.cfg.clone();
        let eng = self.engine;
        let p = eng.manifest.prefill_p;
        // Compact real slots to the front (pre-start retires leave holes).
        let mut real: Vec<Row> = Vec::new();
        for r in std::mem::take(&mut self.rows) {
            if !r.is_free() {
                real.push(r);
            }
        }
        let n_real = real.len();
        if n_real == 0 {
            bail!("cannot start an empty PAD batch");
        }
        let b = eng.manifest.bucket_batch_padded(n_real, cfg.pad_headroom,
                                                 self.capacity)?;
        let last_ctx = real
            .last()
            .and_then(|r| r.state())
            .map(|s| s.context())
            .expect("real rows have state");
        self.rows = real;
        for i in n_real..b {
            let state = SeqState::new(last_ctx.clone(),
                                      *last_ctx.last().unwrap(),
                                      last_ctx.len() as i32);
            self.rows.push(Row::Shadow(Slot {
                id: u64::MAX, // never reported
                state,
                rng_draft: Pcg32::new(cfg.seed, 2 * i as u64),
                rng_accept: Pcg32::new(cfg.seed, 2 * i as u64 + 1),
                max_new_tokens: cfg.max_new_tokens,
                temperature: cfg.temperature,
                top_p: cfg.top_p,
            }));
        }
        let mut tokens = vec![0i32; b * p];
        let mut plens = vec![0i32; b];
        for (i, row) in self.rows.iter().enumerate() {
            let st = row.state().expect("all PAD rows live at start");
            let ctx = st.context();
            for (j, &byte) in ctx.iter().enumerate() {
                tokens[i * p + j] = byte as i32;
            }
            plens[i] = ctx.len() as i32;
        }
        let t0 = Instant::now();
        let m = eng.prefill(&cfg.main_model, cfg.precision, cfg.attn, b,
                            &tokens, &plens)?;
        let d = eng.prefill(&cfg.draft_model, cfg.precision, cfg.attn, b,
                            &tokens, &plens)?;
        self.prefill_secs += t0.elapsed().as_secs_f64();
        self.flops.add_prefill(&self.main_info, b, p);
        self.flops.add_prefill(&self.draft_info, b, p);
        self.store = Some(CacheStore::Pad { main: m.caches, draft: d.caches });
        Ok(())
    }

    // -- step --------------------------------------------------------------

    /// Run one draft + verify + accept round over the active sequences.
    /// A batch with nothing active is a no-op returning an empty report.
    pub fn step(&mut self) -> Result<StepReport> {
        if !self.has_active() {
            return Ok(StepReport {
                step: self.steps,
                occupied: self.occupied(),
                ..StepReport::default()
            });
        }
        if self.store.is_none() {
            self.start_pad()?;
        }
        if self.t0.is_none() {
            self.t0 = Some(Instant::now());
        }
        let mut store = self.store.take().expect("store present");
        let res = self.step_inner(&mut store);
        self.store = Some(store);
        res
    }

    fn step_inner(&mut self, store: &mut CacheStore) -> Result<StepReport> {
        let cfg = self.cfg.clone();
        let eng = self.engine;
        let man = &eng.manifest;
        let vocab = man.vocab;
        let b = self.rows.len();
        let t0 = self.t0.expect("clock started");
        let now = |t: Instant| t.elapsed().as_secs_f64();
        let k = man.bucket_k(&cfg.draft_model, self.policy.current());

        // -- draft ---------------------------------------------------------
        let mut tokens_in = vec![0i32; b * 2];
        let mut n_in = vec![1i32; b];
        let mut dlens = vec![0i32; b];
        let mut uniforms = vec![0f32; b * k];
        // Per-row sampling params for the fused draft call. Free and Husk
        // rows carry the batch defaults — their outputs are never read, the
        // artifact just needs a valid value per row.
        let mut temps = vec![cfg.temperature; b];
        let mut tps = vec![cfg.top_p; b];
        for (i, row) in self.rows.iter_mut().enumerate() {
            if let Some(s) = row.state() {
                tokens_in[i * 2] = s.pending_draft[0] as i32;
                tokens_in[i * 2 + 1] = s.pending_draft[1] as i32;
                n_in[i] = s.n_pending_draft;
                dlens[i] = s.draft_len;
            }
            // Every slot-holding row consumes its draft stream each step
            // (finished-but-unretired included), so a sequence's randomness
            // depends only on its own step count — never on co-batch
            // composition.
            if let Row::Seq(slot) | Row::Shadow(slot) = row {
                for j in 0..k {
                    uniforms[i * k + j] = slot.rng_draft.next_f32();
                }
                temps[i] = slot.temperature;
                tps[i] = slot.top_p;
            }
        }
        let stepping: Vec<bool> = self
            .rows
            .iter()
            .map(|r| {
                matches!(r, Row::Seq(s) | Row::Shadow(s) if s.state.active())
            })
            .collect();
        let td = Instant::now();
        let (draft_tokens, qdists) = self.draft_all(
            store, b, k, &tokens_in, &n_in, &dlens, &uniforms, &temps,
            &tps, &stepping)?;
        self.draft_secs += now(td);
        // FLOP/throughput accounting charges *live* rows only. The fused
        // PAD artifact still computes Husk (retired) and Shadow (padding)
        // rows, but that is overhead, not served work — counting it
        // inflated PAD throughput/utilization numbers.
        let live = live_row_states(&self.rows);
        let n_compute = live.len();
        let ctx_d = live.iter().map(|s| s.draft_len as usize).sum::<usize>()
            / live.len().max(1);
        self.flops.add_step(&self.draft_info, n_compute, k + 1, ctx_d);

        // -- verify --------------------------------------------------------
        let q = k + 1;
        let mut vtokens = vec![0i32; b * q];
        let mut mlens = vec![0i32; b];
        for (i, row) in self.rows.iter().enumerate() {
            if let Some(s) = row.state() {
                vtokens[i * q] = s.pending_main as i32;
                for j in 0..k {
                    vtokens[i * q + 1 + j] = draft_tokens[i * k + j];
                }
                mlens[i] = s.main_len;
            }
        }
        let tv = Instant::now();
        let logits =
            self.verify_all(store, b, q, &vtokens, &mlens, &stepping)?;
        self.verify_secs += now(tv);
        let ctx_m = live.iter().map(|s| s.main_len as usize).sum::<usize>()
            / live.len().max(1);
        self.flops.add_step(&self.main_info, n_compute, q, ctx_m);

        // -- accept/reject per sequence (host) -----------------------------
        let mut events = Vec::new();
        let mut finished = Vec::new();
        let mut accepted_counts = Vec::new();
        let s_max = self.s_max;
        let mut drafted_add = 0usize;
        let mut accepted_add = 0usize;
        for (i, row) in self.rows.iter_mut().enumerate() {
            let (slot, real) = match row {
                Row::Seq(s) => (s, true),
                Row::Shadow(s) => (s, false),
                _ => continue,
            };
            if !slot.state.active() {
                continue;
            }
            // Warp main distributions for positions 0..=k with this
            // slot's own sampling params (per-request, not batch-wide).
            let warped: Vec<Vec<f32>> = (0..q)
                .map(|j| {
                    let r = &logits[(i * q + j) * vocab
                                    ..(i * q + j + 1) * vocab];
                    warp_top_p(r, slot.temperature, slot.top_p)
                })
                .collect();
            let p_refs: Vec<&[f32]> =
                warped.iter().map(|w| w.as_slice()).collect();
            let d_tokens: Vec<usize> = (0..k)
                .map(|j| draft_tokens[i * k + j] as usize)
                .collect();
            let q_refs: Vec<&[f32]> = (0..k)
                .map(|j| &qdists[(i * k + j) * vocab
                                 ..(i * k + j + 1) * vocab])
                .collect();
            let out = spec_accept(&p_refs, &d_tokens, &q_refs,
                                  &mut slot.rng_accept);

            let acc_bytes: Vec<u8> = d_tokens[..out.accepted]
                .iter()
                .map(|&t| t as u8)
                .collect();
            let mut logp =
                logp_of(&warped[out.accepted], out.next_token) as f64;
            for (j, &d) in d_tokens[..out.accepted].iter().enumerate() {
                logp += logp_of(&warped[j], d) as f64;
            }
            let n_in_used = slot.state.n_pending_draft;
            let gen_before = slot.state.generated.len();
            let emitted = slot.state.apply_step(
                &acc_bytes, out.next_token as u8, out.bonus, k, n_in_used,
                logp);
            if real {
                drafted_add += k;
                accepted_add += out.accepted;
                accepted_counts.push(out.accepted);
            }
            let t_now = now(t0);
            slot.state.check_eos(man.eos, emitted, t_now);
            slot.state.check_limits(slot.max_new_tokens, s_max,
                                    (k + 2) as i32, t_now);
            debug_assert!(slot.state.check_invariants(s_max).is_ok());
            if real {
                let done = !slot.state.active();
                if done {
                    finished.push(slot.id);
                }
                let cut = gen_before.min(slot.state.generated.len());
                events.push(SeqEvent {
                    id: slot.id,
                    accepted: out.accepted,
                    new_bytes: slot.state.generated[cut..].to_vec(),
                    done,
                    finish: slot.state.finish,
                });
            }
        }
        let step = self.steps;
        self.steps += 1;
        self.drafted += drafted_add;
        self.accepted += accepted_add;
        self.step_log.push((k, accepted_counts.clone()));
        self.policy.observe(&accepted_counts);
        Ok(StepReport {
            step,
            k,
            events,
            finished,
            active: self.active(),
            occupied: self.occupied(),
        })
    }

    // -- retire ------------------------------------------------------------

    /// Take a sequence out of the batch, returning its final state. The
    /// slot becomes reusable immediately: SPLIT drops the slot's caches
    /// and frees the row; a running PAD batch freezes the row into a
    /// Husk placeholder that the next mid-flight admission
    /// scatter-prefills over (the batch still auto-resets to full
    /// capacity when the last real sequence leaves, so an idle engine
    /// re-buckets). Retiring a still-active sequence abandons it
    /// (cancel).
    pub fn retire(&mut self, id: SeqId) -> Result<SeqState> {
        let Some(idx) = self.rows.iter().position(
            |r| matches!(r, Row::Seq(s) if s.id == id))
        else {
            bail!("no live sequence {id} in batch");
        };
        Ok(self.release_row(idx).state)
    }

    /// Free one occupied row (shared tail of `retire` and `suspend`):
    /// SPLIT drops the slot's caches and frees the row; a running PAD
    /// batch freezes the row into a Husk so the fused artifact keeps
    /// valid dlens/mlens inputs. Draining the last real sequence resets
    /// the batch (fresh clock, fresh policy; PAD drops its bucket).
    fn release_row(&mut self, idx: usize) -> Slot {
        let pad_running = self.cfg.mode == ExecMode::Pad
            && self.store.is_some();
        let replacement = if pad_running {
            // The fused artifact keeps computing this row; leave a frozen
            // state so dlens/mlens inputs stay valid.
            match &self.rows[idx] {
                Row::Seq(s) => Row::Husk(s.state.clone()),
                _ => unreachable!(),
            }
        } else {
            Row::Free
        };
        let Row::Seq(slot) = std::mem::replace(&mut self.rows[idx],
                                               replacement)
        else {
            unreachable!();
        };
        if let Some(CacheStore::Split { main, draft }) = self.store.as_mut()
        {
            main[idx] = Vec::new();
            draft[idx] = Vec::new();
        }
        if pad_running && self.occupied() == 0 {
            self.reset_pad();
        } else if self.occupied() == 0 {
            // Batch drained: the next busy period gets a fresh clock and
            // a fresh draft-length policy, same as a PAD reset — so a
            // request hitting an idle server behaves identically in both
            // modes regardless of earlier traffic.
            self.t0 = None;
            self.policy = fresh_policy(&self.cfg);
        }
        slot
    }

    // -- suspend / resume (preemption) -------------------------------------

    /// True when [`SpecBatch::suspend`] would succeed for `id`: the
    /// sequence is live, still generating, and its verified context
    /// (`prompt ‖ generated`) fits the prefill capacity so a resume can
    /// recompute the KV row *exactly*. Sequences grown past
    /// `manifest.prefill_p` are pinned to their slot — preempting them
    /// would truncate context — so a scheduler must pick another victim.
    pub fn can_suspend(&self, id: SeqId) -> bool {
        let p_cap = self.engine.manifest.prefill_p;
        self.rows.iter().any(|r| matches!(r, Row::Seq(s)
            if s.id == id
                && s.state.active()
                && s.state.prompt.len() + s.state.generated.len() <= p_cap))
    }

    /// Preempt a still-running sequence: lift its complete host-side
    /// identity out of the batch as a [`SuspendedSeq`] and free its slot
    /// exactly like [`SpecBatch::retire`] (SPLIT frees the row; a running
    /// PAD batch husks it; draining the last real sequence resets the
    /// batch). The device KV is dropped — [`SpecBatch::resume`] rebuilds
    /// it bitwise by recompute, so the pair is invisible to the
    /// sequence's output under [`Policy::Fixed`].
    pub fn suspend(&mut self, id: SeqId) -> Result<SuspendedSeq> {
        let Some(idx) = self.rows.iter().position(
            |r| matches!(r, Row::Seq(s) if s.id == id))
        else {
            bail!("no live sequence {id} in batch");
        };
        let Row::Seq(slot) = &self.rows[idx] else { unreachable!() };
        if !slot.state.active() {
            bail!("sequence {id} already finished; retire it instead");
        }
        let ctx = slot.state.prompt.len() + slot.state.generated.len();
        let p_cap = self.engine.manifest.prefill_p;
        if ctx > p_cap {
            bail!("sequence {id} context ({ctx} bytes) exceeds the prefill \
                   capacity ({p_cap}); a resume could not recompute it \
                   exactly");
        }
        let slot = self.release_row(idx);
        Ok(SuspendedSeq {
            prompt: slot.state.prompt,
            generated: slot.state.generated,
            logp_sum: slot.state.logp_sum,
            rng_draft: slot.rng_draft,
            rng_accept: slot.rng_accept,
            max_new_tokens: slot.max_new_tokens,
            temperature: slot.temperature,
            top_p: slot.top_p,
        })
    }

    /// Re-admit a suspended sequence by **recompute**: prefill
    /// `prompt ‖ generated` into a free slot (SPLIT / not-yet-started
    /// PAD) or scatter it over a reusable row of the running fused
    /// bucket (PAD) — the existing artifacts rebuild the KV row bitwise,
    /// and the restored RNG streams, sampling params and budget make the
    /// continuation byte-identical to never having been preempted (under
    /// [`Policy::Fixed`]; see the module docs). Returns a **new**
    /// [`SeqId`] — ids are never reused, so callers remap their handle.
    /// Fails like `admit` when no slot/row is free. The snapshot is
    /// consumed either way: a failed resume cannot be retried, so a
    /// serving layer must fail the owning request loudly rather than
    /// silently dropping its output (a *running* PAD batch still gets
    /// the pre-donation safety of mid-flight admission — compile/upload
    /// failures reject the resume without poisoning co-resident rows).
    pub fn resume(&mut self, susp: SuspendedSeq) -> Result<SeqId> {
        let p_cap = self.engine.manifest.prefill_p;
        let ctx_len = susp.context_len();
        if ctx_len == 0 {
            bail!("suspended sequence has an empty context");
        }
        if ctx_len > p_cap {
            bail!("suspended context ({ctx_len} bytes) exceeds the \
                   prefill capacity ({p_cap})");
        }
        let id = self.next_stream;
        self.next_stream += 1;
        let slot = Slot {
            id,
            state: SeqState::resumed(susp.prompt, susp.generated,
                                     susp.logp_sum),
            rng_draft: susp.rng_draft,
            rng_accept: susp.rng_accept,
            max_new_tokens: susp.max_new_tokens,
            temperature: susp.temperature,
            top_p: susp.top_p,
        };
        let ctx = slot.state.context();
        if self.cfg.mode == ExecMode::Pad && self.store.is_some() {
            let row = self.reusable_pad_row()?;
            self.ensure_scatter_ready()?;
            self.prefill_pad_row(row, &ctx)?;
            self.rows[row] = Row::Seq(slot);
            return Ok(id);
        }
        let Some(row) = self.rows.iter().position(Row::is_free) else {
            bail!("no free slot (capacity {})", self.capacity);
        };
        if self.cfg.mode == ExecMode::Split {
            self.prefill_split_slot(row, &ctx)?;
        }
        self.rows[row] = Row::Seq(slot);
        Ok(id)
    }

    /// Drop the drained PAD batch so new admissions start a fresh bucket.
    fn reset_pad(&mut self) {
        self.store = None;
        self.rows = (0..self.capacity).map(|_| Row::Free).collect();
        self.t0 = None;
        self.policy = fresh_policy(&self.cfg);
    }

    // -- mode-dispatched model calls ---------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn draft_all(&self, store: &mut CacheStore, b: usize, k: usize,
                 tokens_in: &[i32], n_in: &[i32], dlens: &[i32],
                 uniforms: &[f32], temps: &[f32], tps: &[f32],
                 stepping: &[bool])
                 -> Result<(Vec<i32>, Vec<f32>)> {
        let cfg = &self.cfg;
        let eng = self.engine;
        let vocab = eng.manifest.vocab;
        match store {
            CacheStore::Pad { draft, .. } => {
                let caches = std::mem::take(draft);
                let out = eng.draft(&cfg.draft_model, cfg.precision,
                                    cfg.attn, b, k, tokens_in, n_in, dlens,
                                    uniforms, temps, tps, caches)?;
                *draft = out.caches;
                Ok((out.tokens, out.qdists))
            }
            CacheStore::Split { draft, .. } => {
                let mut toks = vec![0i32; b * k];
                let mut qd = vec![0f32; b * k * vocab];
                for i in 0..b {
                    if !stepping[i] {
                        continue; // SPLIT skips finished/free slots
                    }
                    let caches = std::mem::take(&mut draft[i]);
                    let out = eng.draft(
                        &cfg.draft_model, cfg.precision, cfg.attn, 1, k,
                        &tokens_in[i * 2..i * 2 + 2], &n_in[i..=i],
                        &dlens[i..=i], &uniforms[i * k..(i + 1) * k],
                        &temps[i..=i], &tps[i..=i], caches)?;
                    draft[i] = out.caches;
                    toks[i * k..(i + 1) * k].copy_from_slice(&out.tokens);
                    qd[i * k * vocab..(i + 1) * k * vocab]
                        .copy_from_slice(&out.qdists);
                }
                Ok((toks, qd))
            }
        }
    }

    fn verify_all(&self, store: &mut CacheStore, b: usize, q: usize,
                  vtokens: &[i32], mlens: &[i32], stepping: &[bool])
                  -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let eng = self.engine;
        let vocab = eng.manifest.vocab;
        match store {
            CacheStore::Pad { main, .. } => {
                let caches = std::mem::take(main);
                let out = eng.decode(&cfg.main_model, cfg.precision,
                                     cfg.attn, b, q, vtokens, mlens,
                                     caches)?;
                *main = out.caches;
                Ok(out.logits)
            }
            CacheStore::Split { main, .. } => {
                let mut logits = vec![0f32; b * q * vocab];
                for i in 0..b {
                    if !stepping[i] {
                        continue;
                    }
                    let caches = std::mem::take(&mut main[i]);
                    let out = eng.decode(
                        &cfg.main_model, cfg.precision, cfg.attn, 1, q,
                        &vtokens[i * q..(i + 1) * q], &mlens[i..=i],
                        caches)?;
                    main[i] = out.caches;
                    logits[i * q * vocab..(i + 1) * q * vocab]
                        .copy_from_slice(&out.logits);
                }
                Ok(logits)
            }
        }
    }
}

/// States of the rows whose compute is *served work* this step: live real
/// sequences only. Husk (retired) and Shadow (padding) rows still ride
/// the fused PAD artifact, but they serve no request — FLOP and token
/// accounting must not charge them (`flops_count_live_rows_only`).
fn live_row_states(rows: &[Row]) -> Vec<&SeqState> {
    rows.iter()
        .filter_map(|r| match r {
            Row::Seq(s) if s.state.active() => Some(&s.state),
            _ => None,
        })
        .collect()
}

fn fresh_policy(cfg: &SpecConfig) -> Box<dyn DraftLenPolicy> {
    match cfg.policy {
        Policy::Heuristic => Box::new(Heuristic::testbed()),
        Policy::Fixed(k) => Box::new(Fixed(k)),
    }
}

pub struct SpecEngine<'a> {
    pub engine: &'a Engine,
    pub cfg: SpecConfig,
}

impl<'a> SpecEngine<'a> {
    pub fn new(engine: &'a Engine, cfg: SpecConfig) -> SpecEngine<'a> {
        SpecEngine { engine, cfg }
    }

    /// Generate completions for a batch of prompts (1 ≤ n ≤ largest batch
    /// bucket). Prompts longer than the prefill capacity keep their tail.
    /// This is a thin one-shot loop over the resumable [`SpecBatch`] API:
    /// admit everything, step until done (or the time budget expires),
    /// retire everything.
    pub fn generate(&self, prompts: &[Vec<u8>]) -> Result<SpecResult> {
        let cfg = &self.cfg;
        if prompts.is_empty() {
            bail!("empty prompt batch");
        }
        let mut batch =
            SpecBatch::new(self.engine, cfg.clone(), prompts.len())?;
        let mut ids = Vec::with_capacity(prompts.len());
        for p in prompts {
            ids.push(batch.admit(p, cfg.seed)?);
        }
        while batch.has_active() {
            if let Some(budget) = cfg.time_budget_secs {
                if batch.elapsed_secs() >= budget {
                    break;
                }
            }
            batch.step()?;
        }
        let wall = batch.elapsed_secs();
        let seqs: Vec<SeqState> = ids
            .into_iter()
            .map(|id| batch.retire(id))
            .collect::<Result<_>>()?;
        let mut metrics = BatchMetrics::from_seqs(&seqs, wall);
        metrics.steps = batch.steps;
        metrics.acceptance_rate = if batch.drafted > 0 {
            batch.accepted as f64 / batch.drafted as f64
        } else {
            0.0
        };
        metrics.tokens_per_step = if batch.steps > 0 {
            metrics.total_tokens as f64 / batch.steps as f64
        } else {
            0.0
        };
        Ok(SpecResult {
            seqs,
            metrics,
            drafted: batch.drafted,
            accepted: batch.accepted,
            steps: batch.steps,
            prefill_secs: batch.prefill_secs,
            draft_secs: batch.draft_secs,
            verify_secs: batch.verify_secs,
            flops: batch.flops.clone(),
            step_log: batch.step_log.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_is_sane() {
        let c = SpecConfig::default();
        assert_eq!(c.main_model, "main");
        assert_eq!(c.mode, ExecMode::Pad);
        assert!(matches!(c.policy, Policy::Heuristic));
    }

    #[test]
    fn step_report_default_is_idle() {
        let r = StepReport::default();
        assert_eq!(r.active, 0);
        assert!(r.events.is_empty() && r.finished.is_empty());
    }

    fn slot(id: SeqId, prompt: Vec<u8>) -> Slot {
        let last = *prompt.last().unwrap();
        let len = prompt.len() as i32;
        Slot {
            id,
            state: SeqState::new(prompt, last, len),
            rng_draft: Pcg32::new(0, 2 * id),
            rng_accept: Pcg32::new(0, 2 * id + 1),
            max_new_tokens: 8,
            temperature: 1.0,
            top_p: 1.0,
        }
    }

    #[test]
    fn flops_count_live_rows_only() {
        // Regression for the PAD metrics skew: Husk (retired) and Shadow
        // (padding) rows used to accrue draft/verify FLOPs — the fused
        // artifact does compute them, but they serve no request, so
        // charging them inflated PAD throughput/utilization.
        let mut finished = slot(2, vec![4, 5]);
        finished.state.finish_at(FinishReason::Eos, 1.0);
        let rows = vec![
            Row::Seq(slot(0, vec![1, 2, 3])), // live: the only countable
            Row::Husk(SeqState::new(vec![9, 9], 9, 2)), // retired
            Row::Shadow(slot(1, vec![7, 8])),           // padding
            Row::Seq(finished), // finished-but-unretired: not served work
            Row::Free,
        ];
        let live = live_row_states(&rows);
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].prompt, vec![1, 2, 3]);
    }

    #[test]
    fn suspended_husk_rows_charge_nothing() {
        // A PAD preemption husks the row with a *still-Running* state
        // (unlike a retire husk, which is finished). It serves no request
        // while suspended, so FLOP/token accounting must skip it — the
        // preemption variant of the PAD metrics-skew regression.
        let suspended_husk = SeqState::new(vec![3, 4, 5], 5, 3);
        assert!(suspended_husk.active(), "suspend husks stay Running");
        let rows = vec![
            Row::Seq(slot(0, vec![1, 2])),
            Row::Husk(suspended_husk),
        ];
        let live = live_row_states(&rows);
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].prompt, vec![1, 2]);
    }

    #[test]
    fn fresh_suspended_seq_round_trips_into_state() {
        // SuspendedSeq::fresh == "admitted then suspended before any
        // step": zero progress, budget/params resolved against the
        // config, and into_state() reconstructs a fresh-admit SeqState.
        let cfg = SpecConfig::default();
        let opts = AdmitOpts {
            max_new_tokens: Some(7),
            temperature: Some(1.5),
            ..AdmitOpts::default()
        };
        let susp = SuspendedSeq::fresh(&[9, 8, 7], 42, &opts, &cfg);
        assert_eq!(susp.tokens_generated(), 0);
        assert_eq!(susp.context_len(), 3);
        assert_eq!(susp.max_new_tokens, 7);
        assert_eq!(susp.temperature, 1.5);
        assert_eq!(susp.top_p, cfg.top_p); // unset -> config default
        let st = susp.into_state();
        let fresh = SeqState::new(vec![9, 8, 7], 7, 3);
        assert_eq!(st.main_len, fresh.main_len);
        assert_eq!(st.pending_main, fresh.pending_main);
        assert!(st.active());
    }

    #[test]
    fn all_padding_batch_counts_zero_live_rows() {
        // A drained-but-unreset PAD bucket (husks + still-running shadows)
        // must charge nothing.
        let rows = vec![
            Row::Husk(SeqState::new(vec![1], 1, 1)),
            Row::Shadow(slot(0, vec![2, 3])),
        ];
        assert!(live_row_states(&rows).is_empty());
    }

    #[test]
    fn admit_opts_sampling_overrides_are_range_checked() {
        let ok = |o: AdmitOpts| o.validate().is_ok();
        assert!(ok(AdmitOpts::default()));
        assert!(ok(AdmitOpts { temperature: Some(0.0),
                               ..AdmitOpts::default() })); // warp clamps
        assert!(ok(AdmitOpts { temperature: Some(2.5),
                               top_p: Some(1.0),
                               ..AdmitOpts::default() }));
        for bad in [
            AdmitOpts { top_p: Some(0.0), ..AdmitOpts::default() },
            AdmitOpts { top_p: Some(-0.5), ..AdmitOpts::default() },
            AdmitOpts { top_p: Some(1.5), ..AdmitOpts::default() },
            AdmitOpts { top_p: Some(f32::NAN), ..AdmitOpts::default() },
            AdmitOpts { temperature: Some(-1.0),
                        ..AdmitOpts::default() },
            AdmitOpts { temperature: Some(f32::INFINITY),
                        ..AdmitOpts::default() },
            AdmitOpts { temperature: Some(f32::NAN),
                        ..AdmitOpts::default() },
        ] {
            assert!(bad.validate().is_err(), "accepted: {bad:?}");
        }
    }
}
