//! The BASS speculative decoding loop (paper §3) as a **mode-agnostic
//! batch orchestrator**: [`SpecBatch`] owns the host-side row table,
//! per-slot sequence state, RNG streams and one per-sequence
//! draft-length controller per slot, and drives an exec
//! [`Backend`](super::backend::Backend) (BASS-PAD fused bucket /
//! BASS-SPLIT per-slot artifacts) through the contract in
//! [`super::backend`]. Nothing here matches on the execution mode.
//!
//! The coordinator drives five operations at step boundaries:
//!
//! * [`SpecBatch::admit`] — place a prompt into a free slot, **in either
//!   mode at any step boundary**. SPLIT prefills the slot's own B=1
//!   caches; PAD admission into a running batch scatter-prefills the new
//!   sequence into a freed row (a retired Husk or padding Shadow) of the
//!   fused cache via the per-row `prefill_scatter` artifact, so the
//!   batch never has to drain. [`AdmitOpts`] carries per-sequence
//!   overrides — `max_new_tokens`, a pinned RNG stream, and
//!   **per-sequence sampling params** flowing as `[B]` rows into the
//!   fused draft artifact and into the host-side verify warp.
//! * [`SpecBatch::step`] — one draft + verify + accept round over the
//!   currently-active slots:
//!
//!   ```text
//!     per row i: k_i = bucket(controller_i.current())  (own history)
//!     k = max_i k_i                   (fused PAD launch width)
//!     draft : d_1..d_{k_i} per sequence  (PAD: one fused call at k,
//!             rows masked via klens; SPLIT: each row at its own k_i
//!             bucket — the short rows' FLOPs are really saved)
//!     verify: main decode over [pending, d_1..d_{k_i}]
//!             (PAD: Q = k+1; SPLIT: Q_i = k_i+1)
//!     per sequence: stochastic accept/reject (sampling.rs) -> a_i accepted,
//!       corrected/bonus next token; cache lengths advance by 1 + a_i
//!       (raggedly!), draft rolls back to its accepted prefix
//!     controller_i.observe(a_i)   (Algorithm 1, per-sequence)
//!   ```
//!
//! * [`SpecBatch::retire`] — take a sequence's final state out of the
//!   batch, freeing its slot (SPLIT frees the row; a running PAD batch
//!   husks it; draining the last real sequence resets the batch).
//! * [`SpecBatch::suspend`] / [`SpecBatch::resume`] — **preemption**.
//!   Suspend lifts a still-running sequence out as a host-side
//!   [`SuspendedSeq`]; resume rebuilds the KV row by **recompute**: a
//!   fresh prefill over `prompt ‖ generated` using the *existing* v3
//!   artifacts. Because the ragged attention masks per query position
//!   with exact-zero pad probability and each position's KV is a pure
//!   function of its token prefix, the recomputed row is **bitwise
//!   identical** to the incrementally built one (pinned host-side by
//!   `test_parity.py::test_resume_recompute_*` and end-to-end by
//!   `rust/tests/step_equivalence.rs` / `admission_interleaving.rs`), so
//!   a preempted-then-resumed sequence reproduces its uninterrupted run
//!   byte-for-byte under [`Policy::Fixed`]. The one bound:
//!   `prompt ‖ generated` must still fit `manifest.prefill_p`
//!   ([`SpecBatch::can_suspend`]).
//! * [`SpecBatch::rebucket`] — **live re-bucketing**. A running PAD
//!   bucket grows (burst larger than its reusable rows) or shrinks
//!   (occupancy fell below a smaller bucket) **without draining**: every
//!   carried row rides the same bitwise recompute primitive as resume —
//!   one fused prefill at the new bucket re-encodes each row's
//!   `prompt ‖ generated` — while SeqIds, RNG stream positions, sampling
//!   params, the batch clock and each row's draft-length controller all
//!   carry over, so outputs are byte-identical under [`Policy::Fixed`]
//!   and **no artifact rebuild or manifest bump is needed** (the
//!   per-bucket `prefill` programs in the v3 grid already cover every
//!   target). Suspended sequences can ride the same fused prefill
//!   ([`SpecBatch::rebucket_resume`]) instead of paying one scatter
//!   prefill each after the move.
//!   Cost model: one fused prefill at the new bucket `b'` (≈ `b'`
//!   row-prefills over `prefill_p`) buys rows *now* for queued work that
//!   would otherwise wait unboundedly for a retirement or the drain
//!   (grow), or removes `b - b'` dead rows from every subsequent fused
//!   step (shrink). [`SpecConfig::pad_headroom`] is re-applied at every
//!   re-bucket, so the new bucket keeps the same grow-room policy.
//!
//! Each admitted sequence gets its own pair of PCG32 streams keyed by a
//! monotonically increasing admission counter, and **consumes exactly
//! `k_i` uniforms per step** — `k_i` being its own controller's
//! bucketized draft length, itself a pure function of the sequence's
//! own acceptance history. Launch-width filler positions (`k_i..k` in a
//! fused PAD call) are zero-filled, *not* drawn from any stream: the
//! in-graph draft sampling is autoregressive per row, so a row's first
//! `k_i` positions never read them, and the host never reads tokens
//! past `k_i`. A sequence's output is therefore a function of (prompt,
//! seed, admission index) only — *not* of what else is or was in the
//! batch — under [`Policy::Fixed`] **and** under the adaptive
//! heuristic (per-sequence controllers made the adaptive policy
//! co-batch-independent for the first time). That is what makes
//! stepwise driving with mid-flight admission, preemption and live
//! re-bucketing reproduce one-shot [`super::SpecEngine::generate`]
//! byte-for-byte (`rust/tests/step_equivalence.rs`, including its
//! `heuristic_cobatch_equals_solo` pins, and under randomized
//! admit/step/suspend/resume/re-bucket/retire schedules,
//! `rust/tests/admission_interleaving.rs`).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::flops::FlopCounter;
use crate::kv::SeqState;
use crate::obs::{SpanKind, Tracer};
use crate::runtime::{Engine, ModelInfo};
use crate::sampling::{logp_of, spec_accept, warp_top_p, Pcg32};
use crate::spec::draft_len::Controller;

use super::backend::{self, Backend, DraftIo, ExecCtx, VerifyIo};
use super::config::SpecConfig;
use super::seq::{AdmitOpts, Row, SeqEvent, SeqId, Slot, StepReport,
                 SuspendedSeq};

/// One executed live re-bucket (see [`SpecBatch::rebucket`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rebucket {
    /// Bucket rows before.
    pub from: usize,
    /// Bucket rows after.
    pub to: usize,
    /// Real (Seq) rows re-encoded across the move.
    pub migrated: usize,
}

/// A resumable speculative batch over up to `capacity` concurrent
/// sequences. See the module docs for the admit / step / retire /
/// suspend / resume / rebucket contract.
pub struct SpecBatch<'a> {
    engine: &'a Engine,
    cfg: SpecConfig,
    capacity: usize,
    rows: Vec<Row>,
    backend: Box<dyn Backend>,
    /// Admission counter; doubles as the SeqId and the PCG32 stream index.
    next_stream: u64,
    t0: Option<Instant>,
    main_info: ModelInfo,
    draft_info: ModelInfo,
    s_max: i32,
    /// Span recorder ([`crate::obs`]); disabled by default — every
    /// record call is then a no-op (the disabled-is-free contract).
    tracer: Tracer,
    // -- aggregates across the batch lifetime ------------------------------
    pub steps: usize,
    pub drafted: usize,
    pub accepted: usize,
    pub prefill_secs: f64,
    pub draft_secs: f64,
    pub verify_secs: f64,
    pub flops: FlopCounter,
    pub step_log: Vec<(usize, Vec<usize>)>,
}

impl<'a> SpecBatch<'a> {
    /// Create an empty batch with room for `capacity` concurrent
    /// sequences. In PAD mode the actual device batch is the smallest
    /// exported bucket covering the admitted count at start time.
    pub fn new(engine: &'a Engine, cfg: SpecConfig, capacity: usize)
               -> Result<SpecBatch<'a>> {
        if capacity == 0 {
            bail!("batch capacity must be >= 1");
        }
        let main_info = engine.manifest.model(&cfg.main_model)?.clone();
        let draft_info = engine.manifest.model(&cfg.draft_model)?.clone();
        let s_max = main_info.s_max as i32;
        let backend = backend::make(&cfg, capacity, engine.is_stub());
        Ok(SpecBatch {
            engine,
            cfg,
            capacity,
            rows: (0..capacity).map(|_| Row::Free).collect(),
            backend,
            next_stream: 0,
            t0: None,
            main_info,
            draft_info,
            s_max,
            tracer: Tracer::disabled(),
            steps: 0,
            drafted: 0,
            accepted: 0,
            prefill_secs: 0.0,
            draft_secs: 0.0,
            verify_secs: 0.0,
            flops: FlopCounter::default(),
            step_log: Vec::new(),
        })
    }

    /// Split the batch into its backend, the execution context the
    /// backend borrows, and the row table — disjoint fields, so the
    /// three can be used together without aliasing.
    fn backend_cx(&mut self)
                  -> (&mut dyn Backend, ExecCtx<'_>, &mut Vec<Row>) {
        (
            self.backend.as_mut(),
            ExecCtx {
                engine: self.engine,
                cfg: &self.cfg,
                main_info: &self.main_info,
                draft_info: &self.draft_info,
                prefill_secs: &mut self.prefill_secs,
                flops: &mut self.flops,
                tracer: self.tracer.clone(),
            },
            &mut self.rows,
        )
    }

    /// Attach a span recorder ([`crate::obs::Tracer`]). The default is
    /// the disabled no-op tracer; tracing never changes what the batch
    /// computes (clock-injection rule — see the `obs` module doc).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    // -- introspection ----------------------------------------------------

    /// The batch-wide speculative configuration (mode, policy, sampling
    /// defaults — individual sequences may carry [`AdmitOpts`] overrides).
    pub fn config(&self) -> &SpecConfig {
        &self.cfg
    }

    /// Slots a new sequence could occupy right now. In a *running* PAD
    /// batch these are the reusable rows of the fused bucket — retired
    /// (Husk) and padding (Shadow) rows that mid-flight admission
    /// scatter-prefills over; growing past them takes a live
    /// [`SpecBatch::rebucket`].
    pub fn free_slots(&self) -> usize {
        self.backend.free_slots(&self.rows)
    }

    /// True when `admit` would succeed for a 1-sequence request.
    pub fn can_admit(&self) -> bool {
        self.free_slots() > 0
    }

    /// Real sequences occupying slots (active or finished-but-unretired).
    pub fn occupied(&self) -> usize {
        self.rows.iter().filter(|r| matches!(r, Row::Seq(_))).count()
    }

    /// Real sequences still generating.
    pub fn active(&self) -> usize {
        self.rows
            .iter()
            .filter(|r| matches!(r, Row::Seq(s) if s.state.active()))
            .count()
    }

    pub fn has_active(&self) -> bool {
        self.active() > 0
    }

    /// Rows of the live fused bucket — `None` for SPLIT, or for a PAD
    /// batch that has not started stepping yet.
    pub fn bucket_rows(&self) -> Option<usize> {
        self.backend.live_bucket(&self.rows)
    }

    /// Seconds since the first step began (0 before the batch starts);
    /// the clock `SeqState::finish_secs` and time budgets are measured on.
    pub fn elapsed_secs(&self) -> f64 {
        self.t0.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    // -- admit ------------------------------------------------------------

    /// Admit a prompt into a free slot and return its [`SeqId`]. `seed` is
    /// the RNG seed for this sequence; its PCG32 streams derive from the
    /// batch-lifetime admission counter, so re-admitting the same
    /// prompt+seed into a reused slot still gets fresh randomness. SPLIT
    /// mode prefills the slot's caches immediately; PAD mode defers to the
    /// fused prefill at first step for a not-yet-started batch and
    /// scatter-prefills into a freed row (Husk/Shadow) of a running one.
    pub fn admit(&mut self, prompt: &[u8], seed: u64) -> Result<SeqId> {
        self.admit_opts(prompt, seed, AdmitOpts::default())
    }

    /// [`SpecBatch::admit`] with per-sequence overrides ([`AdmitOpts`]):
    /// a `max_new_tokens` limit, sampling params (`temperature` /
    /// `top_p` — per-row through the draft artifact and the verify-side
    /// warp, so co-batched requests keep their own knobs), and an optional
    /// pinned `stream` index. Pinning the stream makes the randomness a
    /// pure function of (seed, stream) — independent of how many
    /// admissions preceded it — which is what per-request seeds need for
    /// reproducibility under serving traffic (exact for the full output
    /// only when per-step draft lengths also match, i.e.
    /// [`Policy::Fixed`]). Callers pinning streams own the (seed, stream)
    /// uniqueness trade-off; the unpinned default (the admission counter)
    /// never collides within a batch lifetime.
    pub fn admit_opts(&mut self, prompt: &[u8], seed: u64, opts: AdmitOpts)
                      -> Result<SeqId> {
        opts.validate()?;
        let p_cap = self.engine.manifest.prefill_p;
        let tail: &[u8] = if prompt.len() > p_cap {
            &prompt[prompt.len() - p_cap..]
        } else {
            prompt
        };
        if tail.is_empty() {
            bail!("empty prompt");
        }
        let row = self.backend.admissible_row(&self.rows)?;
        let slot = self.make_slot(tail, seed, opts);
        let id = slot.id;
        {
            let (be, mut cx, rows) = self.backend_cx();
            be.bind_row(&mut cx, rows, row, &slot.state.prompt)?;
        }
        self.rows[row] = Row::Seq(slot);
        Ok(id)
    }

    /// Build an occupied-slot record, consuming the next admission index
    /// (the [`SeqId`] and, unless pinned, the PCG32 stream index).
    fn make_slot(&mut self, tail: &[u8], seed: u64, opts: AdmitOpts)
                 -> Slot {
        let id = self.next_stream;
        self.next_stream += 1;
        let stream = opts.stream.unwrap_or(id);
        let state = SeqState::new(tail.to_vec(), *tail.last().unwrap(),
                                  tail.len() as i32);
        Slot {
            id,
            state,
            rng_draft: Pcg32::new(seed, 2 * stream),
            rng_accept: Pcg32::new(seed, 2 * stream + 1),
            max_new_tokens: opts
                .max_new_tokens
                .unwrap_or(self.cfg.max_new_tokens),
            temperature: opts.temperature.unwrap_or(self.cfg.temperature),
            top_p: opts.top_p.unwrap_or(self.cfg.top_p),
            draft_ctrl: Controller::for_policy(&self.cfg.policy),
        }
    }

    // -- shared admission (fan-out sharing / prefix-cache reuse) -----------

    /// True when `row`'s device KV covers `ctx`: the row's encoded
    /// verified context has `ctx` as a byte prefix and its cache extent
    /// (`main_len`) reaches `ctx`'s restart position, so a row copy
    /// seeds a new sequence at `main_len = ctx.len() - 1` with KV
    /// bitwise equal to a fresh prefill of `ctx` (causal purity for the
    /// covered positions; the exact-zero ragged mask for the donor's
    /// tail beyond them, which the copied-into sequence overwrites as
    /// it decodes — the same masking contract every co-batched ragged
    /// step already relies on).
    fn row_covers(row: &Row, ctx: &[u8]) -> bool {
        let state = match row {
            Row::Seq(s) => &s.state,
            // A released row of a running fused bucket: its KV is
            // frozen at suspension/retirement, still encoding the
            // husk's context — the residency a prefix cache trades on.
            Row::Husk(state) => state,
            // Free rows hold nothing; Shadow replicas are never donors
            // (their content is an artifact of bucket padding).
            _ => return false,
        };
        state.main_len as usize + 1 >= ctx.len()
            && state.context().starts_with(ctx)
    }

    /// A resident row whose device KV could seed a new sequence with
    /// verified context `ctx` via the backend's row copy — a live
    /// sequence sharing the prefix (fan-out sibling) or a still-intact
    /// husk (a preempted/retired sequence whose row was not reused).
    /// `None` before the batch started stepping: there is no device KV
    /// yet, and the fused start encodes every row from its own context
    /// anyway (sharing is vacuous pre-start).
    /// Contexts longer than the prefill window are matched on their
    /// `prefill_p`-byte tail — the same clamp
    /// [`SpecBatch::admit_shared_opts`] binds with, so a probe and the
    /// bind it gates can never disagree.
    pub fn donor_row_for(&self, ctx: &[u8]) -> Option<usize> {
        let p_cap = self.engine.manifest.prefill_p;
        let ctx = if ctx.len() > p_cap {
            &ctx[ctx.len() - p_cap..]
        } else {
            ctx
        };
        if ctx.is_empty() || !self.backend.started() {
            return None;
        }
        self.rows.iter().position(|r| Self::row_covers(r, ctx))
    }

    /// The formula-based device-equivalent prefill cost a successful
    /// shared bind avoids: one single-row prefill per model over the
    /// `prefill_p` window — what [`SpecBatch::admit_opts`] /
    /// [`SpecBatch::resume`] would have charged the launch accounting.
    /// Serving layers report it as `prefix_cache.saved_flops`
    /// regardless of backend (on the stub nothing physical is saved,
    /// but the stub stands in for PAD by convention).
    pub fn shared_bind_saving(&self) -> f64 {
        let p = self.engine.manifest.prefill_p;
        crate::flops::prefill_flops(&self.main_info, 1, p)
            + crate::flops::prefill_flops(&self.draft_info, 1, p)
    }

    /// [`SpecBatch::admit_opts`], but the new row's KV is **row-copied**
    /// from `donor_row` (a row [`SpecBatch::donor_row_for`] returned
    /// for this prompt) instead of prefilled — fan-out prefill sharing
    /// and prefix-cache admission hits. The donor is re-validated
    /// against the prompt; everything else (SeqId, RNG streams,
    /// sampling params) is exactly `admit_opts`, so the admitted
    /// sequence's output is byte-identical to the prefilled path.
    pub fn admit_shared_opts(&mut self, donor_row: usize, prompt: &[u8],
                             seed: u64, opts: AdmitOpts) -> Result<SeqId> {
        opts.validate()?;
        let p_cap = self.engine.manifest.prefill_p;
        let tail: &[u8] = if prompt.len() > p_cap {
            &prompt[prompt.len() - p_cap..]
        } else {
            prompt
        };
        if tail.is_empty() {
            bail!("empty prompt");
        }
        self.check_donor(donor_row, tail)?;
        let row = self.backend.admissible_row(&self.rows)?;
        let slot = self.make_slot(tail, seed, opts);
        let id = slot.id;
        {
            let (be, mut cx, rows) = self.backend_cx();
            be.copy_row(&mut cx, rows, donor_row, row)?;
        }
        self.rows[row] = Row::Seq(slot);
        Ok(id)
    }

    /// [`SpecBatch::resume`], but the row KV is **row-copied** from
    /// `donor_row` instead of recomputed by prefill — the prefix-cache
    /// resume hit (typically the sequence's own still-intact husk). The
    /// continuation is byte-identical to the recompute path; like
    /// `resume`, the snapshot is consumed, so on `Err` the owning
    /// request must be failed loudly.
    pub fn resume_shared(&mut self, donor_row: usize, susp: SuspendedSeq)
                         -> Result<SeqId> {
        let p_cap = self.engine.manifest.prefill_p;
        let ctx_len = susp.context_len();
        if ctx_len == 0 {
            bail!("suspended sequence has an empty context");
        }
        if ctx_len > p_cap {
            bail!("suspended context ({ctx_len} bytes) exceeds the \
                   prefill capacity ({p_cap})");
        }
        self.check_donor(donor_row, &susp.context())?;
        let row = self.backend.admissible_row(&self.rows)?;
        let id = self.next_stream;
        self.next_stream += 1;
        let slot = susp.into_slot(id);
        {
            let (be, mut cx, rows) = self.backend_cx();
            be.copy_row(&mut cx, rows, donor_row, row)?;
        }
        self.rows[row] = Row::Seq(slot);
        Ok(id)
    }

    /// Re-validate a donor row right before the copy (the row table may
    /// have changed since [`SpecBatch::donor_row_for`]).
    fn check_donor(&self, donor_row: usize, ctx: &[u8]) -> Result<()> {
        if !self.backend.started() {
            bail!("no device KV to copy from: the batch has not started \
                   stepping (admit normally; the fused start encodes \
                   every row)");
        }
        let ok = self
            .rows
            .get(donor_row)
            .is_some_and(|r| Self::row_covers(r, ctx));
        if !ok {
            bail!("row {donor_row} is not a valid KV donor for a \
                   {}-byte context", ctx.len());
        }
        Ok(())
    }

    // -- step --------------------------------------------------------------

    /// Run one draft + verify + accept round over the active sequences.
    /// A batch with nothing active is a no-op returning an empty report.
    pub fn step(&mut self) -> Result<StepReport> {
        if !self.has_active() {
            return Ok(StepReport {
                step: self.steps,
                occupied: self.occupied(),
                ..StepReport::default()
            });
        }
        if !self.backend.started() {
            let capacity = self.capacity;
            let (be, mut cx, rows) = self.backend_cx();
            be.start(&mut cx, rows, capacity)?;
        }
        if self.t0.is_none() {
            self.t0 = Some(Instant::now());
        }
        self.step_inner()
    }

    fn step_inner(&mut self) -> Result<StepReport> {
        let eng = self.engine;
        let man = &eng.manifest;
        let vocab = man.vocab;
        let b = self.rows.len();
        let t0 = self.t0.expect("clock started");
        let now = |t: Instant| t.elapsed().as_secs_f64();
        let (def_temp, def_tp) = (self.cfg.temperature, self.cfg.top_p);

        // Per-row draft lengths: every slot-holding row runs at its own
        // controller's bucketized k_i; the fused launch width is their
        // max. Free/Husk rows carry k_i = 0 — their outputs are never
        // read, the artifact just needs valid inputs per row.
        let mut k_rows = vec![0usize; b];
        for (i, row) in self.rows.iter().enumerate() {
            if let Row::Seq(slot) | Row::Shadow(slot) = row {
                k_rows[i] = man.bucket_k(&self.cfg.draft_model,
                                         slot.draft_ctrl.current());
            }
        }
        let k = k_rows.iter().copied().max().unwrap_or(0).max(1);

        // -- draft ---------------------------------------------------------
        let mut tokens_in = vec![0i32; b * 2];
        let mut n_in = vec![1i32; b];
        let mut dlens = vec![0i32; b];
        let mut klens = vec![0i32; b];
        let mut uniforms = vec![0f32; b * k];
        // Per-row sampling params for the fused draft call. Free and Husk
        // rows carry the batch defaults — their outputs are never read, the
        // artifact just needs a valid value per row.
        let mut temps = vec![def_temp; b];
        let mut tps = vec![def_tp; b];
        for (i, row) in self.rows.iter_mut().enumerate() {
            if let Some(s) = row.state() {
                tokens_in[i * 2] = s.pending_draft[0] as i32;
                tokens_in[i * 2 + 1] = s.pending_draft[1] as i32;
                n_in[i] = s.n_pending_draft;
                dlens[i] = s.draft_len;
            }
            // RNG contract: every slot-holding row (finished-but-unretired
            // included) consumes **exactly k_i** uniforms from its own
            // draft stream each step — a function of its own acceptance
            // history only, never of co-batch composition. Launch-width
            // filler positions (k_i..k) stay zero and are NOT drawn from
            // the stream: in-graph draft sampling is autoregressive per
            // row, so position j reads only that row's uniforms < j, and
            // the filler feeds tokens the host never reads back.
            if let Row::Seq(slot) | Row::Shadow(slot) = row {
                let ki = k_rows[i];
                klens[i] = ki as i32;
                for j in 0..ki {
                    uniforms[i * k + j] = slot.rng_draft.next_f32();
                }
                temps[i] = slot.temperature;
                tps[i] = slot.top_p;
            }
        }
        let stepping: Vec<bool> = self
            .rows
            .iter()
            .map(|r| {
                matches!(r, Row::Seq(s) | Row::Shadow(s) if s.state.active())
            })
            .collect();
        let n_step = stepping.iter().filter(|&&s| s).count();
        let tr_d = self.tracer.begin();
        let (fl0, fp0) = (self.flops.launch, self.flops.padded_launch);
        let td = Instant::now();
        let io = DraftIo {
            k,
            tokens_in: &tokens_in,
            n_in: &n_in,
            dlens: &dlens,
            klens: &klens,
            uniforms: &uniforms,
            temps: &temps,
            tps: &tps,
            stepping: &stepping,
        };
        let (draft_tokens, qdists) = {
            let (be, mut cx, _) = self.backend_cx();
            be.draft(&mut cx, &io)?
        };
        self.draft_secs += now(td);
        self.tracer.span(
            SpanKind::Draft,
            tr_d,
            0,
            None,
            self.cfg.mode.as_str(),
            &[
                ("k", k as f64),
                ("rows", n_step as f64),
                ("launch_flops", self.flops.launch - fl0),
                ("padded_launch_flops", self.flops.padded_launch - fp0),
            ],
        );
        // FLOP/throughput accounting charges *live* rows only, each at
        // its own k_i and its own exact context length — no per-step
        // batch averaging (the old integer mean both truncated and
        // smeared context across rows), and no k_max smearing (a row
        // drafting 2 is charged 2+1 tokens, not k_max+1). The fused PAD
        // artifact still computes Husk (retired) and Shadow (padding)
        // rows, but that is overhead, not served work. (Context lengths
        // are read here, before accept moves them: they do not change
        // between the draft and verify calls.)
        let live_kc: Vec<(usize, usize, usize)> = self
            .rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| match r {
                Row::Seq(s) if s.state.active() => Some((
                    k_rows[i],
                    s.state.draft_len as usize,
                    s.state.main_len as usize,
                )),
                _ => None,
            })
            .collect();
        for &(ki, ctx_d, _) in &live_kc {
            self.flops.add_step(&self.draft_info, 1, ki + 1, ctx_d);
        }

        // -- verify --------------------------------------------------------
        let q = k + 1;
        let mut vtokens = vec![0i32; b * q];
        let mut mlens = vec![0i32; b];
        let mut qlens = vec![0i32; b];
        for (i, row) in self.rows.iter().enumerate() {
            if let Some(s) = row.state() {
                vtokens[i * q] = s.pending_main as i32;
                for j in 0..k {
                    vtokens[i * q + 1 + j] = draft_tokens[i * k + j];
                }
                mlens[i] = s.main_len;
            }
            if matches!(row, Row::Seq(_) | Row::Shadow(_)) {
                qlens[i] = k_rows[i] as i32 + 1;
            }
        }
        let tr_v = self.tracer.begin();
        let (fl1, fp1) = (self.flops.launch, self.flops.padded_launch);
        let tv = Instant::now();
        let vio = VerifyIo {
            q,
            vtokens: &vtokens,
            mlens: &mlens,
            qlens: &qlens,
            stepping: &stepping,
        };
        let logits = {
            let (be, mut cx, _) = self.backend_cx();
            be.verify(&mut cx, &vio)?
        };
        self.verify_secs += now(tv);
        self.tracer.span(
            SpanKind::Verify,
            tr_v,
            0,
            None,
            self.cfg.mode.as_str(),
            &[
                ("q", q as f64),
                ("rows", n_step as f64),
                ("launch_flops", self.flops.launch - fl1),
                ("padded_launch_flops", self.flops.padded_launch - fp1),
            ],
        );
        for &(ki, _, ctx_m) in &live_kc {
            self.flops.add_step(&self.main_info, 1, ki + 1, ctx_m);
        }

        // -- accept/reject per sequence (host) -----------------------------
        let mut events = Vec::new();
        let mut finished = Vec::new();
        let mut accepted_counts = Vec::new();
        let s_max = self.s_max;
        let mut drafted_add = 0usize;
        let mut accepted_add = 0usize;
        for (i, row) in self.rows.iter_mut().enumerate() {
            let (slot, real) = match row {
                Row::Seq(s) => (s, true),
                Row::Shadow(s) => (s, false),
                _ => continue,
            };
            if !slot.state.active() {
                continue;
            }
            // This row's own draft length: only positions 0..k_i (and
            // the bonus at k_i) of the launch-width buffers are real.
            let ki = k_rows[i];
            // Warp main distributions for positions 0..=k_i with this
            // slot's own sampling params (per-request, not batch-wide).
            let warped: Vec<Vec<f32>> = (0..=ki)
                .map(|j| {
                    let r = &logits[(i * q + j) * vocab
                                    ..(i * q + j + 1) * vocab];
                    warp_top_p(r, slot.temperature, slot.top_p)
                })
                .collect();
            let p_refs: Vec<&[f32]> =
                warped.iter().map(|w| w.as_slice()).collect();
            let d_tokens: Vec<usize> = (0..ki)
                .map(|j| draft_tokens[i * k + j] as usize)
                .collect();
            let q_refs: Vec<&[f32]> = (0..ki)
                .map(|j| &qdists[(i * k + j) * vocab
                                 ..(i * k + j + 1) * vocab])
                .collect();
            let out = spec_accept(&p_refs, &d_tokens, &q_refs,
                                  &mut slot.rng_accept);

            let acc_bytes: Vec<u8> = d_tokens[..out.accepted]
                .iter()
                .map(|&t| t as u8)
                .collect();
            let mut logp =
                logp_of(&warped[out.accepted], out.next_token) as f64;
            for (j, &d) in d_tokens[..out.accepted].iter().enumerate() {
                logp += logp_of(&warped[j], d) as f64;
            }
            let n_in_used = slot.state.n_pending_draft;
            let gen_before = slot.state.generated.len();
            let emitted = slot.state.apply_step(
                &acc_bytes, out.next_token as u8, out.bonus, ki, n_in_used,
                logp);
            if real {
                drafted_add += ki;
                accepted_add += out.accepted;
                accepted_counts.push(out.accepted);
            }
            // Algorithm 1, per sequence: the controller sees only this
            // row's accepted count (Shadow rows too — they must trace
            // the same trajectory as the real run they mirror).
            slot.draft_ctrl.observe(out.accepted);
            // Guard the cache limit against *next* step's draft length —
            // the controller may have just grown it.
            let k_next = man.bucket_k(&self.cfg.draft_model,
                                      slot.draft_ctrl.current());
            let t_now = now(t0);
            slot.state.check_eos(man.eos, emitted, t_now);
            slot.state.check_limits(slot.max_new_tokens, s_max,
                                    (k_next + 2) as i32, t_now);
            debug_assert!(slot.state.check_invariants(s_max).is_ok());
            if real {
                let done = !slot.state.active();
                if done {
                    finished.push(slot.id);
                }
                let cut = gen_before.min(slot.state.generated.len());
                events.push(SeqEvent {
                    id: slot.id,
                    draft_len: ki,
                    accepted: out.accepted,
                    new_bytes: slot.state.generated[cut..].to_vec(),
                    done,
                    finish: slot.state.finish,
                });
            }
        }
        let step = self.steps;
        self.steps += 1;
        self.drafted += drafted_add;
        self.accepted += accepted_add;
        self.step_log.push((k, accepted_counts));
        Ok(StepReport {
            step,
            k,
            events,
            finished,
            active: self.active(),
            occupied: self.occupied(),
        })
    }

    // -- retire ------------------------------------------------------------

    /// Take a sequence out of the batch, returning its final state. The
    /// slot becomes reusable immediately: SPLIT drops the slot's caches
    /// and frees the row; a running PAD batch freezes the row into a
    /// Husk placeholder that the next mid-flight admission
    /// scatter-prefills over (the batch still auto-resets to full
    /// capacity when the last real sequence leaves, so an idle engine
    /// re-buckets). Retiring a still-active sequence abandons it
    /// (cancel).
    pub fn retire(&mut self, id: SeqId) -> Result<SeqState> {
        let Some(idx) = self.rows.iter().position(
            |r| matches!(r, Row::Seq(s) if s.id == id))
        else {
            bail!("no live sequence {id} in batch");
        };
        Ok(self.release_row(idx).state)
    }

    /// Free one occupied row (shared tail of `retire` and `suspend`):
    /// the backend leaves its placeholder (SPLIT: Free; running PAD: a
    /// Husk so the fused artifact keeps valid dlens/mlens inputs).
    /// Draining the last real sequence resets the batch — fresh clock,
    /// device state dropped (draft-length state needs no reset: each
    /// controller lives and dies with its slot) — so a request hitting
    /// an idle server behaves identically in both modes regardless of
    /// earlier traffic.
    fn release_row(&mut self, idx: usize) -> Slot {
        let slot = self.backend.release(&mut self.rows, idx);
        if self.occupied() == 0 {
            self.backend.reset();
            self.rows = (0..self.capacity).map(|_| Row::Free).collect();
            self.t0 = None;
        }
        slot
    }

    // -- suspend / resume (preemption) -------------------------------------

    /// True when [`SpecBatch::suspend`] would succeed for `id`: the
    /// sequence is live, still generating, and its verified context
    /// (`prompt ‖ generated`) fits the prefill capacity so a resume can
    /// recompute the KV row *exactly*. Sequences grown past
    /// `manifest.prefill_p` are pinned to their slot — preempting them
    /// would truncate context — so a scheduler must pick another victim.
    pub fn can_suspend(&self, id: SeqId) -> bool {
        let p_cap = self.engine.manifest.prefill_p;
        self.rows.iter().any(|r| matches!(r, Row::Seq(s)
            if s.id == id
                && s.state.active()
                && s.state.context_len() <= p_cap))
    }

    /// Preempt a still-running sequence: lift its complete host-side
    /// identity out of the batch as a [`SuspendedSeq`] and free its slot
    /// exactly like [`SpecBatch::retire`]. The device KV is dropped —
    /// [`SpecBatch::resume`] rebuilds it bitwise by recompute, so the
    /// pair is invisible to the sequence's output under
    /// [`Policy::Fixed`].
    pub fn suspend(&mut self, id: SeqId) -> Result<SuspendedSeq> {
        let Some(idx) = self.rows.iter().position(
            |r| matches!(r, Row::Seq(s) if s.id == id))
        else {
            bail!("no live sequence {id} in batch");
        };
        let Row::Seq(slot) = &self.rows[idx] else { unreachable!() };
        if !slot.state.active() {
            bail!("sequence {id} already finished; retire it instead");
        }
        let ctx = slot.state.context_len();
        let p_cap = self.engine.manifest.prefill_p;
        if ctx > p_cap {
            bail!("sequence {id} context ({ctx} bytes) exceeds the prefill \
                   capacity ({p_cap}); a resume could not recompute it \
                   exactly");
        }
        Ok(SuspendedSeq::from_slot(self.release_row(idx)))
    }

    /// Re-admit a suspended sequence by **recompute**: prefill
    /// `prompt ‖ generated` into a free slot (SPLIT / not-yet-started
    /// PAD) or scatter it over a reusable row of the running fused
    /// bucket (PAD) — the existing artifacts rebuild the KV row bitwise,
    /// and the restored RNG streams, sampling params and budget make the
    /// continuation byte-identical to never having been preempted (under
    /// [`Policy::Fixed`]; see the module docs). Returns a **new**
    /// [`SeqId`] — ids are never reused, so callers remap their handle.
    /// Fails like `admit` when no slot/row is free. The snapshot is
    /// consumed either way: a failed resume cannot be retried, so a
    /// serving layer must fail the owning request loudly rather than
    /// silently dropping its output (a *running* PAD batch still gets
    /// the pre-donation safety of mid-flight admission — compile/upload
    /// failures reject the resume without poisoning co-resident rows).
    pub fn resume(&mut self, susp: SuspendedSeq) -> Result<SeqId> {
        let p_cap = self.engine.manifest.prefill_p;
        let ctx_len = susp.context_len();
        if ctx_len == 0 {
            bail!("suspended sequence has an empty context");
        }
        if ctx_len > p_cap {
            bail!("suspended context ({ctx_len} bytes) exceeds the \
                   prefill capacity ({p_cap})");
        }
        let row = self.backend.admissible_row(&self.rows)?;
        let id = self.next_stream;
        self.next_stream += 1;
        let slot = susp.into_slot(id);
        let ctx = slot.state.context();
        {
            let (be, mut cx, rows) = self.backend_cx();
            be.bind_row(&mut cx, rows, row, &ctx)?;
        }
        self.rows[row] = Row::Seq(slot);
        Ok(id)
    }

    // -- live re-bucketing -------------------------------------------------

    /// The bucket a live re-bucket toward `desired_rows` total rows
    /// would land on — [`SpecConfig::pad_headroom`] re-applied, clamped
    /// to the serving capacity and the largest exported bucket, never
    /// below the occupied rows — or `None` when re-bucketing is
    /// impossible or pointless: SPLIT (no fused bucket), a PAD batch
    /// that has not started (the lazy start buckets by itself), an
    /// empty batch (the drain auto-reset re-buckets for free), a live
    /// row whose context outgrew `manifest.prefill_p` (its KV could not
    /// be recomputed *exactly*), or a target that resolves to the
    /// current bucket. This is the single validation path
    /// [`SpecBatch::rebucket`] trusts, so a scheduler probing it cannot
    /// drift from what the batch will actually do.
    pub fn rebucket_target(&self, desired_rows: usize) -> Option<usize> {
        self.rebucket_target_with(desired_rows, 0)
    }

    /// [`SpecBatch::rebucket_target`] with `resume_rows` suspended
    /// sequences that would ride the same fused prefill
    /// ([`SpecBatch::rebucket_resume`]): the target bucket must hold
    /// the occupied rows *plus* the resumes. `None` keeps the same
    /// meaning — and when the resolved bucket equals the current one,
    /// the current bucket by construction has at least `resume_rows`
    /// reusable rows, so the caller can always fall back to plain
    /// per-row scatter resumes.
    pub fn rebucket_target_with(&self, desired_rows: usize,
                                resume_rows: usize) -> Option<usize> {
        let cur = self.backend.live_bucket(&self.rows)?;
        let occupied = self.occupied();
        if occupied == 0 {
            return None;
        }
        let p_cap = self.engine.manifest.prefill_p;
        let movable = self.rows.iter().all(|r| match r {
            // Only still-active rows carry a live KV contract; finished
            // rows are reported from host state and may be re-encoded
            // clamped, husks and shadows are dropped.
            Row::Seq(s) if s.state.active() => s.state.context_len()
                <= p_cap,
            _ => true,
        });
        if !movable {
            return None;
        }
        let floor = occupied + resume_rows;
        let largest = self.engine.manifest.largest_batch();
        let ceil = largest.min(self.capacity).max(floor);
        let want = desired_rows.clamp(floor, ceil);
        let b = self
            .engine
            .manifest
            .bucket_batch_padded(want, self.cfg.pad_headroom,
                                 self.capacity)
            .ok()?;
        (b != cur).then_some(b)
    }

    /// Re-shape the running fused bucket to cover `desired_rows` total
    /// rows **without draining** — grow for a burst larger than the
    /// reusable rows, shrink when occupancy fell below a smaller bucket.
    /// Every carried row rides the same bitwise recompute primitive as
    /// [`SpecBatch::resume`]: one fused prefill at the new bucket
    /// re-encodes each row's `prompt ‖ generated`, while SeqIds, RNG
    /// stream positions, sampling params, the batch clock and the
    /// draft-length policy carry over — so carried sequences are
    /// byte-identical to never having been re-bucketed under
    /// [`Policy::Fixed`], and no artifact rebuild or manifest bump is
    /// needed. Returns `Ok(None)` when no re-bucket is possible or
    /// needed ([`SpecBatch::rebucket_target`]). On a device failure the
    /// previous bucket stays intact (the old caches are replaced only
    /// after the new prefill succeeds), so the caller may simply keep
    /// driving the batch.
    pub fn rebucket(&mut self, desired_rows: usize)
                    -> Result<Option<Rebucket>> {
        let Some(bucket) = self.rebucket_target(desired_rows) else {
            return Ok(None);
        };
        let from = self.rows.len();
        let tr = self.tracer.begin();
        let migrated = {
            let (be, mut cx, rows) = self.backend_cx();
            be.rebucket(&mut cx, rows, bucket, Vec::new())?
        };
        self.tracer.span(
            SpanKind::Rebucket,
            tr,
            0,
            None,
            self.cfg.mode.as_str(),
            &[
                ("from", from as f64),
                ("to", bucket as f64),
                ("migrated", migrated as f64),
            ],
        );
        Ok(Some(Rebucket { from, to: bucket, migrated }))
    }

    /// [`SpecBatch::rebucket`] with suspended sequences folded into the
    /// same fused prefill. A re-bucket re-encodes every carried row's
    /// context in one launch anyway, so resuming *through* it encodes
    /// the resumed contexts in that same call instead of paying one
    /// scatter prefill per resume afterwards (the PR-5 double-prefill
    /// debt). Returns the re-bucket report plus the **new** [`SeqId`]s
    /// in input order. Call only after
    /// [`SpecBatch::rebucket_target_with`] returned a bucket — like
    /// [`SpecBatch::resume`] the snapshots are consumed, so on `Err`
    /// the owning requests must be failed loudly. The previous bucket
    /// itself survives a device failure (old caches are replaced only
    /// after the new fused prefill succeeds).
    pub fn rebucket_resume(&mut self, desired_rows: usize,
                           resumes: Vec<SuspendedSeq>)
                           -> Result<(Rebucket, Vec<SeqId>)> {
        let p_cap = self.engine.manifest.prefill_p;
        for s in &resumes {
            let ctx = s.context_len();
            if ctx == 0 {
                bail!("suspended sequence has an empty context");
            }
            if ctx > p_cap {
                bail!("suspended context ({ctx} bytes) exceeds the \
                       prefill capacity ({p_cap})");
            }
        }
        let Some(bucket) =
            self.rebucket_target_with(desired_rows, resumes.len())
        else {
            bail!("no re-bucket target covering {} resumes (probe \
                   rebucket_target_with first; scatter resumes still \
                   work)", resumes.len());
        };
        let slots: Vec<Slot> = resumes
            .into_iter()
            .map(|s| {
                let id = self.next_stream;
                self.next_stream += 1;
                s.into_slot(id)
            })
            .collect();
        let ids: Vec<SeqId> = slots.iter().map(|s| s.id).collect();
        let from = self.rows.len();
        let tr = self.tracer.begin();
        let migrated = {
            let (be, mut cx, rows) = self.backend_cx();
            be.rebucket(&mut cx, rows, bucket, slots)?
        };
        self.tracer.span(
            SpanKind::Rebucket,
            tr,
            0,
            None,
            self.cfg.mode.as_str(),
            &[
                ("from", from as f64),
                ("to", bucket as f64),
                ("migrated", migrated as f64),
                ("resumed", ids.len() as f64),
            ],
        );
        Ok((Rebucket { from, to: bucket, migrated }, ids))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rebucket_report_orients_grow_and_shrink() {
        let grow = Rebucket { from: 2, to: 4, migrated: 2 };
        assert!(grow.to > grow.from);
        let shrink = Rebucket { from: 8, to: 2, migrated: 1 };
        assert!(shrink.to < shrink.from);
        assert_eq!(shrink.migrated, 1);
    }

    #[test]
    fn stub_batch_runs_the_full_spec_loop_deterministically() {
        use crate::spec::{ExecMode, Policy};
        let eng = Engine::stub();
        let cfg = SpecConfig {
            mode: ExecMode::Stub,
            policy: Policy::Fixed(4),
            max_new_tokens: 13,
            ..SpecConfig::default()
        };
        let run = || {
            let mut batch = SpecBatch::new(&eng, cfg.clone(), 4).unwrap();
            let a = batch.admit(b"hello", 7).unwrap();
            let b = batch.admit(b"world!", 7).unwrap();
            let mut steps = 0usize;
            while batch.has_active() {
                batch.step().unwrap();
                steps += 1;
                assert!(steps < 64, "stub batch failed to converge");
            }
            assert_eq!(batch.accepted, batch.drafted,
                       "stub verify accepts every draft token");
            let sa = batch.retire(a).unwrap();
            let sb = batch.retire(b).unwrap();
            (steps, sa.generated, sb.generated)
        };
        let (steps, ga, gb) = run();
        // Fixed k=4 with certain acceptance emits 5 tokens/step:
        // 13 new tokens land in ceil(13/5) = 3 steps, truncated exactly.
        assert_eq!(steps, 3);
        assert_eq!(ga.len(), 13);
        assert_eq!(gb.len(), 13);
        assert!(ga.iter().all(|&t| t != 0), "never the eos byte");
        assert_ne!(ga, gb, "per-sequence RNG streams differ");
        let again = run();
        assert_eq!(again, (steps, ga, gb), "bit-deterministic replay");
    }

    /// The integer-truncation regression: the old accounting charged
    /// each fused step at the batch's *integer-mean* context
    /// (`ctx = (Σ ctx_i) / b`), so a [1, 2]-byte-prompt batch was
    /// billed attention at ctx 1 — identical to a [1, 1] batch — and
    /// the bias compounded every step. Per-row charging makes
    /// co-batched FLOPs exactly the sum of the solo runs (the stub
    /// backend charges no prefill, so step charges are the whole
    /// total, and charges depend on context lengths, not token
    /// values).
    #[test]
    fn per_row_flop_charging_has_no_truncation_bias() {
        use crate::spec::{ExecMode, Policy};
        let eng = Engine::stub();
        let cfg = SpecConfig {
            mode: ExecMode::Stub,
            policy: Policy::Fixed(4),
            max_new_tokens: 13,
            ..SpecConfig::default()
        };
        let total = |prompts: &[&[u8]]| -> f64 {
            let mut batch = SpecBatch::new(&eng, cfg.clone(), 4).unwrap();
            let ids: Vec<_> = prompts
                .iter()
                .map(|p| batch.admit(p, 7).unwrap())
                .collect();
            let mut steps = 0usize;
            while batch.has_active() {
                batch.step().unwrap();
                steps += 1;
                assert!(steps < 64, "stub batch failed to converge");
            }
            for id in ids {
                batch.retire(id).unwrap();
            }
            batch.flops.total
        };
        let solo_short = total(&[b"a"]);
        let solo_long = total(&[b"bc"]);
        let co = total(&[b"a", b"bc"]);
        assert!(solo_long > solo_short, "longer context bills more");
        let sum = solo_short + solo_long;
        assert!((co - sum).abs() <= 1e-9 * co,
                "co-batched FLOPs {co} != solo sum {sum}");
        // And the headline bias: [1,2]-length contexts must out-bill
        // [1,1] — under the truncated mean both charged ctx 1.
        let co_same = total(&[b"a", b"b"]);
        assert!(co > co_same,
                "[1,2]-ctx batch ({co}) must out-bill [1,1] ({co_same})");
    }

    /// The resume-fold path on the host-only stub: a suspended sequence
    /// rides [`SpecBatch::rebucket_resume`] into a grow's single
    /// re-shape (keeping its snapshotted RNG streams and budget) and
    /// both sequences finish byte-identical to an uninterrupted run.
    /// `step_equivalence.rs` pins the device modes bitwise; this keeps
    /// the fold covered when `artifacts/` is absent (CI's default).
    #[test]
    fn stub_rebucket_resume_folds_rider_deterministically() {
        use crate::spec::{ExecMode, Policy};
        let eng = Engine::stub();
        let cfg = SpecConfig {
            mode: ExecMode::Stub,
            policy: Policy::Fixed(4),
            max_new_tokens: 13,
            ..SpecConfig::default()
        };
        // Reference: both sequences co-resident, uninterrupted.
        let mut refb = SpecBatch::new(&eng, cfg.clone(), 4).unwrap();
        let a = refb.admit(b"hello", 7).unwrap();
        let b = refb.admit(b"world!", 7).unwrap();
        while refb.has_active() {
            refb.step().unwrap();
        }
        let want_a = refb.retire(a).unwrap().generated;
        let want_b = refb.retire(b).unwrap().generated;

        // Interrupted: suspend the rider after one step, run on, then
        // fold it back through a grow's fused re-shape.
        let mut batch = SpecBatch::new(&eng, cfg.clone(), 4).unwrap();
        let a = batch.admit(b"hello", 7).unwrap();
        let b = batch.admit(b"world!", 7).unwrap();
        batch.step().unwrap();
        let snap = batch.suspend(b).unwrap();
        batch.step().unwrap();
        assert!(batch.has_active(), "carried row must still be live");
        assert!(batch.rebucket_target_with(3, 1).is_some(),
                "a larger bucket must exist for the fold");
        let (r, ids) = batch.rebucket_resume(3, vec![snap]).unwrap();
        assert!(r.to >= 3, "bucket must cover the demand (got {})", r.to);
        assert_eq!(r.migrated, 2, "carried + folded rows re-encode");
        let b = ids[0];
        let mut steps = 0usize;
        while batch.has_active() {
            batch.step().unwrap();
            steps += 1;
            assert!(steps < 64, "folded stub batch failed to converge");
        }
        assert_eq!(batch.retire(a).unwrap().generated, want_a,
                   "carried bytes diverge from the uninterrupted run");
        assert_eq!(batch.retire(b).unwrap().generated, want_b,
                   "folded-rider bytes diverge from the uninterrupted \
                    run");
    }
}
