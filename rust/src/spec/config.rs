//! Batch-wide speculative configuration: execution mode, draft-length
//! policy selection and sampling defaults. Per-sequence overrides ride
//! [`super::AdmitOpts`]; the *mode* becomes concrete only when
//! [`super::backend::make`] builds the matching exec backend.

use crate::runtime::{Attn, Precision};

/// How model calls are batched (paper Fig 4b vs 4c).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One batched artifact padded to the batch bucket (BASS-PAD).
    Pad,
    /// Per-sequence B=1 artifacts (BASS-SPLIT).
    Split,
    /// One packed-segment launch: the batch's ragged rows are laid
    /// back-to-back in a single offset-addressed token stream, so dense
    /// FLOPs scale with Σq_i instead of PAD's b·max(q_i) rectangle and
    /// SPLIT's launch count. Follows PAD's fused-bucket row lifecycle
    /// (Husk/Shadow rows, live re-bucketing); on a stub engine it
    /// computes host-side in the packed layout, byte-identical to
    /// `Stub`.
    Packed,
    /// Host-only deterministic backend: no device, no artifacts — the
    /// draft emits seeded byte tokens with one-hot q-distributions and
    /// verify agrees exactly, so every step accepts k+1 tokens. Mirrors
    /// PAD's fused-bucket row lifecycle (Husk/Shadow rows, live
    /// re-bucketing), which makes the whole coordinator/scheduler stack
    /// — admission, preemption, re-bucketing, budgets — exercisable on
    /// machines without the PJRT binding. This is what the serving load
    /// harness and the CI perf gate run against.
    Stub,
}

impl ExecMode {
    /// Stable lowercase tag (CLI `--mode` vocabulary; also the trace
    /// event `"mode"` tag).
    pub fn as_str(self) -> &'static str {
        match self {
            ExecMode::Pad => "pad",
            ExecMode::Split => "split",
            ExecMode::Packed => "packed",
            ExecMode::Stub => "stub",
        }
    }
}

/// Draft-length policy selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Paper Algorithm 1 (testbed constants, l_limit matching buckets).
    Heuristic,
    /// Constant draft length (Table 6 ablation rows).
    Fixed(usize),
}

/// Configuration of one speculative generation run.
#[derive(Debug, Clone)]
pub struct SpecConfig {
    pub main_model: String,
    pub draft_model: String,
    pub precision: Precision,
    pub attn: Attn,
    /// Default sampling temperature; sequences admitted with an
    /// [`super::AdmitOpts`] override keep their own (per-row everywhere).
    pub temperature: f32,
    /// Default nucleus threshold (same override scope as `temperature`).
    pub top_p: f32,
    pub max_new_tokens: usize,
    pub policy: Policy,
    pub mode: ExecMode,
    pub seed: u64,
    /// Wall-clock budget from generation start (Fig 5); sequences still
    /// running when it expires are left unfinished.
    pub time_budget_secs: Option<f64>,
    /// PAD grow-room: pad the bucket up to this many rows above the
    /// admitted count (clamped to the serving capacity and the largest
    /// exported bucket), so a running fused batch keeps reusable padding
    /// rows for mid-flight admissions. Re-applied on every live
    /// re-bucket ([`super::SpecBatch::rebucket`]), so a grown or shrunk
    /// bucket keeps the same grow-room policy. 0 (the default)
    /// reproduces the tight bucket. SPLIT ignores it (slots are always
    /// per-sequence).
    pub pad_headroom: usize,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig {
            main_model: "main".into(),
            draft_model: "draft_a".into(),
            precision: Precision::F32,
            attn: Attn::Dense,
            temperature: 0.2,
            top_p: 0.95,
            max_new_tokens: 96,
            policy: Policy::Heuristic,
            mode: ExecMode::Pad,
            seed: 0,
            time_budget_secs: None,
            pad_headroom: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_default_is_sane() {
        let c = SpecConfig::default();
        assert_eq!(c.main_model, "main");
        assert_eq!(c.mode, ExecMode::Pad);
        assert!(matches!(c.policy, Policy::Heuristic));
        assert_eq!(c.pad_headroom, 0);
    }
}
