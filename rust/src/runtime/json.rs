//! Minimal JSON parser/serializer.
//!
//! serde is not available in this offline image (only the `xla` crate's
//! dependency closure is vendored), and the runtime only needs to read the
//! artifact manifest / task files and emit result records — a few hundred
//! lines of hand-rolled JSON is the whole requirement.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Object keys keep insertion order irrelevant; we use
/// a BTreeMap for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_f64()?;
        if v < 0.0 {
            bail!("negative where usize expected");
        }
        Ok(v as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    // -- construction / serialization ---------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |n: usize| "  ".repeat(n);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                out.push_str(if *b { "true" } else { "false" })
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i,
                  self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("{e}: '{s}'"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-parse multi-byte UTF-8 sequences in one go.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let chunk =
                            std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = vec![];
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected , or ] at byte {}, got {}", self.i,
                           c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected , or }} at byte {}, got {}", self.i,
                           c as char),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0], Json::Num(1.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1]
                .get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x": [1, 2.5, "séq", {"y": null}], "z": true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"caf\u{e9} \u{2603}\"").unwrap();
        assert_eq!(v, Json::Str("café ☃".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }
}
