//! The PJRT execution engine: loads AOT artifacts, owns device-resident
//! weights and KV-cache buffers, and exposes typed `prefill` / `decode` /
//! `draft` calls to the Layer-3 coordinator.
//!
//! Design points (DESIGN.md §7):
//! * **Lazy compilation** — HLO text is parsed and compiled on first use of
//!   an [`ArtifactKey`], then cached for the process lifetime.
//! * **Weights uploaded once** per (model, precision) and shared by every
//!   call; they are never donated.
//! * **KV caches stay on device**: each step consumes the previous step's
//!   cache buffers (donated to the executable via `input_output_alias`) and
//!   returns fresh handles. Only logits / draft tokens cross to the host.
//! * Single-threaded by construction (PJRT wrapper types are not `Send`);
//!   the coordinator runs the engine on a dedicated worker thread.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{ElementType, HloModuleProto, PjRtBuffer, PjRtClient,
          PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactKey, Attn, Manifest, Phase, Precision};
use super::weights::{read_bwt, DType};

/// Per-phase call accounting (drives the utilization + overhead metrics).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// phase name -> (calls, total seconds inside PJRT execute).
    pub exec: HashMap<String, (u64, f64)>,
    pub compiles: u64,
    pub compile_secs: f64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

impl EngineStats {
    fn record(&mut self, phase: &str, secs: f64) {
        let e = self.exec.entry(phase.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += secs;
    }

    pub fn total_exec_secs(&self) -> f64 {
        self.exec.values().map(|(_, s)| s).sum()
    }
}

/// Output of a prefill / decode step.
pub struct StepOut {
    /// Row-major logits; `[B, V]` after prefill, `[B, Q, V]` after decode.
    pub logits: Vec<f32>,
    pub caches: Vec<PjRtBuffer>,
}

/// Output of one fused draft call.
pub struct DraftOut {
    /// `[B, K]` drafted tokens.
    pub tokens: Vec<i32>,
    /// `[B, K, V]` warped draft distributions (the q(x) of the
    /// accept/reject rule).
    pub qdists: Vec<f32>,
    pub caches: Vec<PjRtBuffer>,
}

pub struct Engine {
    /// `None` for a host-only stub engine ([`Engine::stub`]): every
    /// device path errors through [`Engine::client`], and the stub exec
    /// backend never calls one.
    client: Option<PjRtClient>,
    pub manifest: Manifest,
    executables: RefCell<HashMap<ArtifactKey, Rc<PjRtLoadedExecutable>>>,
    weights: RefCell<HashMap<(String, Precision), Rc<Vec<PjRtBuffer>>>>,
    pub stats: RefCell<EngineStats>,
}

impl Engine {
    /// Create a CPU-PJRT engine over an artifact directory.
    pub fn load(root: &std::path::Path) -> Result<Engine> {
        let manifest = Manifest::load(root)?;
        let client = PjRtClient::cpu()?;
        Ok(Engine {
            client: Some(client),
            manifest,
            executables: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    /// Create a host-only engine: no PJRT client, no artifact directory —
    /// a synthetic [`Manifest::stub`] supplies the model geometry and
    /// bucket ladders the batching/scheduling layers consult. Only the
    /// `ExecMode::Stub` backend can execute against it; any device phase
    /// call fails through [`Engine::client`].
    pub fn stub() -> Engine {
        Engine {
            client: None,
            manifest: Manifest::stub(),
            executables: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        }
    }

    /// True when this engine was built by [`Engine::stub`].
    pub fn is_stub(&self) -> bool {
        self.client.is_none()
    }

    fn client(&self) -> Result<&PjRtClient> {
        self.client.as_ref().context(
            "host-only stub engine: no PJRT client (device phase calls \
             are only valid on Engine::load engines)")
    }

    pub fn platform(&self) -> String {
        match &self.client {
            Some(c) => c.platform_name(),
            None => "host-stub".to_string(),
        }
    }

    // -- artifact / weight caches -------------------------------------------

    /// Compile (or fetch the cached) executable for a key.
    pub fn executable(&self, key: &ArtifactKey)
                      -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.borrow().get(key) {
            return Ok(e.clone());
        }
        let path = self.manifest.artifact_path(key)?;
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?)?;
        let exe = self
            .client()?
            .compile(&XlaComputation::from_proto(&proto))
            .with_context(|| format!("compiling {key}"))?;
        let exe = Rc::new(exe);
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.executables.borrow_mut().insert(key.clone(), exe.clone());
        Ok(exe)
    }

    /// Upload (or fetch) the device-resident weight buffers of a model.
    pub fn weights(&self, model: &str, precision: Precision)
                   -> Result<Rc<Vec<PjRtBuffer>>> {
        let cache_key = (model.to_string(), precision);
        if let Some(w) = self.weights.borrow().get(&cache_key) {
            return Ok(w.clone());
        }
        let info = self.manifest.model(model)?;
        let rel = info.weights.get(&precision).with_context(|| {
            format!("model {model} has no {precision} weights")
        })?;
        let tensors = read_bwt(&self.manifest.root.join(rel))?;
        let mut bufs = Vec::with_capacity(tensors.len());
        let mut bytes = 0u64;
        for t in &tensors {
            let ty = match t.dtype {
                DType::F32 => ElementType::F32,
                DType::I8 => ElementType::S8,
                DType::I32 => ElementType::S32,
            };
            bytes += t.data.len() as u64;
            bufs.push(self.client()?.buffer_from_host_raw_bytes(
                ty, &t.data, &t.dims, None)?);
        }
        self.stats.borrow_mut().h2d_bytes += bytes;
        let rc = Rc::new(bufs);
        self.weights.borrow_mut().insert(cache_key, rc.clone());
        Ok(rc)
    }

    // -- host<->device helpers ------------------------------------------------

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.stats.borrow_mut().h2d_bytes += 4 * data.len() as u64;
        Ok(self.client()?.buffer_from_host_buffer(data, dims, None)?)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.stats.borrow_mut().h2d_bytes += 4 * data.len() as u64;
        Ok(self.client()?.buffer_from_host_buffer(data, dims, None)?)
    }

    fn download_f32(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let v = buf.to_literal_sync()?.to_vec::<f32>()?;
        self.stats.borrow_mut().d2h_bytes += 4 * v.len() as u64;
        Ok(v)
    }

    fn download_i32(&self, buf: &PjRtBuffer) -> Result<Vec<i32>> {
        let v = buf.to_literal_sync()?.to_vec::<i32>()?;
        self.stats.borrow_mut().d2h_bytes += 4 * v.len() as u64;
        Ok(v)
    }

    fn run(&self, key: &ArtifactKey, inputs: &[&PjRtBuffer], phase: &str)
           -> Result<Vec<PjRtBuffer>> {
        let exe = self.executable(key)?;
        let t0 = Instant::now();
        let mut outs = exe.execute_b(inputs)?;
        self.stats.borrow_mut().record(phase, t0.elapsed().as_secs_f64());
        if outs.is_empty() || outs[0].is_empty() {
            bail!("{key}: empty execution result");
        }
        Ok(outs.swap_remove(0))
    }

    // -- typed phase calls ------------------------------------------------------

    /// Context-encode a prompt batch. `tokens` is row-major `[B, P]`
    /// (P = `manifest.prefill_p`), `prompt_lens` per-sequence true lengths.
    /// Returns last-token logits `[B, V]` and fresh cache buffers.
    pub fn prefill(&self, model: &str, precision: Precision, attn: Attn,
                   batch: usize, tokens: &[i32], prompt_lens: &[i32])
                   -> Result<StepOut> {
        let p = self.manifest.prefill_p;
        if tokens.len() != batch * p || prompt_lens.len() != batch {
            bail!("prefill shape mismatch: {} tokens for B={batch} P={p}",
                  tokens.len());
        }
        let key = ArtifactKey {
            model: model.into(), precision, phase: Phase::Prefill,
            batch, q: p, attn,
        };
        let w = self.weights(model, precision)?;
        let t = self.upload_i32(tokens, &[batch, p])?;
        let l = self.upload_i32(prompt_lens, &[batch])?;
        let mut inputs: Vec<&PjRtBuffer> = w.iter().collect();
        inputs.push(&t);
        inputs.push(&l);
        let mut outs = self.run(&key, &inputs, "prefill")?;
        let n_cache = self.manifest.model(model)?.n_cache_bufs();
        if outs.len() != 1 + n_cache {
            bail!("prefill: expected {} outputs, got {}", 1 + n_cache,
                  outs.len());
        }
        let caches = outs.split_off(1);
        let logits = self.download_f32(&outs[0])?;
        Ok(StepOut { logits, caches })
    }

    /// Prefill ONE sequence and scatter its KV into row `row` of an
    /// existing fused cache of batch `batch`, leaving every other row
    /// untouched — the per-row prefill PAD-mode continuous batching
    /// needs: a freed (retired or padding) row of a *running* fused
    /// batch is re-primed with a new prompt, no drain required. `tokens`
    /// is the new prompt alone, `[P]` right-padded
    /// (P = `manifest.prefill_p`). `caches` are the fused batch's cache
    /// buffers, replaced in place with the successor buffers on success.
    ///
    /// Unlike `decode`/`draft` (which own a whole step and may treat any
    /// failure as step-fatal), this runs *inside* a live batch another
    /// request depends on, so `caches` is `&mut` and is consumed only at
    /// the execute itself: a failure before then (weight upload, host
    /// tensor upload, lazy compile) leaves the fused caches untouched
    /// and only rejects this admission. An execute failure donates the
    /// buffers and leaves `caches` empty — batch-fatal; the next step
    /// errors and the serving layer rebuilds. Returns the new
    /// sequence's last-token logits `[V]`.
    #[allow(clippy::too_many_arguments)]
    pub fn prefill_into_slot(&self, model: &str, precision: Precision,
                             attn: Attn, batch: usize, row: usize,
                             tokens: &[i32], prompt_len: i32,
                             caches: &mut Vec<PjRtBuffer>)
                             -> Result<Vec<f32>> {
        let p = self.manifest.prefill_p;
        if tokens.len() != p {
            bail!("prefill_into_slot shape mismatch: {} tokens, P={p}",
                  tokens.len());
        }
        if row >= batch {
            bail!("prefill_into_slot: row {row} out of range for batch \
                   {batch}");
        }
        let key = ArtifactKey {
            model: model.into(), precision, phase: Phase::PrefillScatter,
            batch, q: p, attn,
        };
        let n_cache = self.manifest.model(model)?.n_cache_bufs();
        if caches.len() != n_cache {
            bail!("prefill_into_slot: {} cache buffers, expected \
                   {n_cache}", caches.len());
        }
        let w = self.weights(model, precision)?;
        let t = self.upload_i32(tokens, &[1, p])?;
        let l = self.upload_i32(&[prompt_len], &[1])?;
        let r = self.upload_i32(&[row as i32], &[1])?;
        let owned = std::mem::take(caches);
        let mut inputs: Vec<&PjRtBuffer> = w.iter().collect();
        inputs.extend([&t, &l, &r]);
        inputs.extend(owned.iter());
        let run_res = self.run(&key, &inputs, "prefill_scatter");
        drop(owned); // donated: handles must not be reused
        let mut outs = run_res?;
        if outs.len() != 1 + n_cache {
            bail!("prefill_scatter: expected {} outputs, got {}",
                  1 + n_cache, outs.len());
        }
        *caches = outs.split_off(1);
        self.download_f32(&outs[0])
    }

    /// Resolve and compile the prefill-scatter executable for a bucket
    /// without touching any cache buffer. Callers use this to fail fast
    /// (stale artifact set, unknown bucket) *before* donating a running
    /// batch's fused caches to [`Engine::prefill_into_slot`].
    pub fn ensure_prefill_scatter(&self, model: &str, precision: Precision,
                                  attn: Attn, batch: usize) -> Result<()> {
        let key = ArtifactKey {
            model: model.into(), precision, phase: Phase::PrefillScatter,
            batch, q: self.manifest.prefill_p, attn,
        };
        self.executable(&key).map(|_| ())
    }

    /// Copy row `src`'s full `[H, S, Dh]` KV slab onto row `dst` of the
    /// same fused cache, leaving every other row untouched — the device
    /// primitive behind fan-out prefill sharing and prefix-cache reuse.
    /// Strictly simpler than [`Engine::prefill_into_slot`]: the v5
    /// `kv_row_copy` artifact is weightless (two `s32[1]` row indices
    /// plus the donated caches), so no weight upload can fail here.
    ///
    /// Same failure discipline as `prefill_into_slot`: `caches` is
    /// consumed only at the execute itself — a failure before then
    /// (host upload, lazy compile) leaves the fused caches untouched
    /// and only rejects this copy; an execute failure donates the
    /// buffers and leaves `caches` empty (batch-fatal).
    pub fn kv_row_copy(&self, model: &str, precision: Precision,
                       attn: Attn, batch: usize, src: usize, dst: usize,
                       caches: &mut Vec<PjRtBuffer>) -> Result<()> {
        if src >= batch || dst >= batch {
            bail!("kv_row_copy: rows {src}->{dst} out of range for batch \
                   {batch}");
        }
        let key = ArtifactKey {
            model: model.into(), precision, phase: Phase::KvRowCopy,
            batch, q: 0, attn,
        };
        let n_cache = self.manifest.model(model)?.n_cache_bufs();
        if caches.len() != n_cache {
            bail!("kv_row_copy: {} cache buffers, expected {n_cache}",
                  caches.len());
        }
        let s = self.upload_i32(&[src as i32], &[1])?;
        let d = self.upload_i32(&[dst as i32], &[1])?;
        let owned = std::mem::take(caches);
        let mut inputs: Vec<&PjRtBuffer> = vec![&s, &d];
        inputs.extend(owned.iter());
        let run_res = self.run(&key, &inputs, "kv_row_copy");
        drop(owned); // donated: handles must not be reused
        let outs = run_res?;
        if outs.len() != n_cache {
            bail!("kv_row_copy: expected {n_cache} outputs, got {}",
                  outs.len());
        }
        *caches = outs;
        Ok(())
    }

    /// Resolve and compile the row-copy executable for a bucket without
    /// touching any cache buffer — fail fast (stale artifact set,
    /// unknown bucket) *before* donating a running batch's fused caches
    /// to [`Engine::kv_row_copy`].
    pub fn ensure_kv_row_copy(&self, model: &str, precision: Precision,
                              attn: Attn, batch: usize) -> Result<()> {
        let key = ArtifactKey {
            model: model.into(), precision, phase: Phase::KvRowCopy,
            batch, q: 0, attn,
        };
        self.executable(&key).map(|_| ())
    }

    /// Duplicate a per-slot (B=1) cache set buffer-by-buffer via a host
    /// round-trip — SPLIT-mode fan-out sharing, where each slot owns its
    /// own caches and the fused `kv_row_copy` artifact (b>1, one store)
    /// does not apply. f32 values round-trip bitwise through the
    /// download/upload pair, so the clone is byte-identical to the
    /// donor. The donor buffers are only read; a failure leaves both
    /// the donor and the destination slot untouched.
    pub fn clone_cache_set(&self, model: &str, caches: &[PjRtBuffer])
                           -> Result<Vec<PjRtBuffer>> {
        let info = self.manifest.model(model)?;
        let n_cache = info.n_cache_bufs();
        if caches.len() != n_cache {
            bail!("clone_cache_set: {} cache buffers, expected {n_cache}",
                  caches.len());
        }
        let dims = [1usize, info.n_head, info.s_max, info.d_head];
        let n_elems: usize = dims.iter().product();
        let mut out = Vec::with_capacity(caches.len());
        for c in caches {
            let host = self.download_f32(c)?;
            if host.len() != n_elems {
                bail!("clone_cache_set: buffer holds {} elements, \
                       expected {n_elems} (B=1 slot cache)", host.len());
            }
            out.push(self.upload_f32(&host, &dims)?);
        }
        Ok(out)
    }

    /// Ragged decode/verify step. `tokens` `[B, Q]`, `seq_lens` `[B]`;
    /// consumes `caches` (donated) and returns logits `[B, Q, V]` plus the
    /// successor cache buffers.
    pub fn decode(&self, model: &str, precision: Precision, attn: Attn,
                  batch: usize, q: usize, tokens: &[i32], seq_lens: &[i32],
                  caches: Vec<PjRtBuffer>) -> Result<StepOut> {
        if tokens.len() != batch * q || seq_lens.len() != batch {
            bail!("decode shape mismatch");
        }
        let key = ArtifactKey {
            model: model.into(), precision, phase: Phase::Decode,
            batch, q, attn,
        };
        let w = self.weights(model, precision)?;
        let t = self.upload_i32(tokens, &[batch, q])?;
        let l = self.upload_i32(seq_lens, &[batch])?;
        let mut inputs: Vec<&PjRtBuffer> = w.iter().collect();
        inputs.push(&t);
        inputs.push(&l);
        inputs.extend(caches.iter());
        let mut outs = self.run(&key, &inputs, "decode")?;
        drop(caches); // donated: handles must not be reused
        let n_cache = self.manifest.model(model)?.n_cache_bufs();
        if outs.len() != 1 + n_cache {
            bail!("decode: expected {} outputs, got {}", 1 + n_cache,
                  outs.len());
        }
        let new_caches = outs.split_off(1);
        let logits = self.download_f32(&outs[0])?;
        Ok(StepOut { logits, caches: new_caches })
    }

    /// Packed-segment decode/verify step (`ExecMode::Packed`): the
    /// batch's ragged rows laid back-to-back in one `[1, C]` token
    /// stream (`C = batch * q_cap`, `q_cap` a `bucket_packed_q` ladder
    /// member), addressed by `qoffs` `[B+1]` cumulative offsets.
    /// Consumes `caches` (donated, `[B]`-fused like `decode`) and
    /// returns logits `[1, C, V]` — position `qoffs[i] + j` holds row
    /// i's logits for its token j — plus the successor cache buffers.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_packed(&self, model: &str, precision: Precision,
                         attn: Attn, batch: usize, q_cap: usize,
                         tokens: &[i32], qoffs: &[i32], seq_lens: &[i32],
                         caches: Vec<PjRtBuffer>) -> Result<StepOut> {
        let c_tok = batch * q_cap;
        if tokens.len() != c_tok || qoffs.len() != batch + 1
            || seq_lens.len() != batch
        {
            bail!("decode_packed shape mismatch");
        }
        let key = ArtifactKey {
            model: model.into(), precision, phase: Phase::DecodePacked,
            batch, q: q_cap, attn,
        };
        let w = self.weights(model, precision)?;
        let t = self.upload_i32(tokens, &[1, c_tok])?;
        let o = self.upload_i32(qoffs, &[batch + 1])?;
        let l = self.upload_i32(seq_lens, &[batch])?;
        let mut inputs: Vec<&PjRtBuffer> = w.iter().collect();
        inputs.extend([&t, &o, &l]);
        inputs.extend(caches.iter());
        let mut outs = self.run(&key, &inputs, "decode_packed")?;
        drop(caches); // donated: handles must not be reused
        let n_cache = self.manifest.model(model)?.n_cache_bufs();
        if outs.len() != 1 + n_cache {
            bail!("decode_packed: expected {} outputs, got {}",
                  1 + n_cache, outs.len());
        }
        let new_caches = outs.split_off(1);
        let logits = self.download_f32(&outs[0])?;
        Ok(StepOut { logits, caches: new_caches })
    }

    /// One fused draft call: ingest 1–2 catch-up tokens per sequence, then
    /// draft `k` tokens with in-graph nucleus sampling. `uniforms` `[B, K]`
    /// supplies the randomness (host-controlled, reproducible);
    /// `temperature` / `top_p` are `[B]` per-row sampling params — each
    /// co-batched sequence keeps its own request's knobs inside the fused
    /// call.
    #[allow(clippy::too_many_arguments)]
    pub fn draft(&self, model: &str, precision: Precision, attn: Attn,
                 batch: usize, k: usize, tokens_in: &[i32], n_in: &[i32],
                 seq_lens: &[i32], uniforms: &[f32], temperature: &[f32],
                 top_p: &[f32], caches: Vec<PjRtBuffer>) -> Result<DraftOut> {
        if tokens_in.len() != batch * 2 || uniforms.len() != batch * k
            || temperature.len() != batch || top_p.len() != batch
        {
            bail!("draft shape mismatch");
        }
        let key = ArtifactKey {
            model: model.into(), precision, phase: Phase::Draft,
            batch, q: k, attn,
        };
        let w = self.weights(model, precision)?;
        let t = self.upload_i32(tokens_in, &[batch, 2])?;
        let n = self.upload_i32(n_in, &[batch])?;
        let l = self.upload_i32(seq_lens, &[batch])?;
        let u = self.upload_f32(uniforms, &[batch, k])?;
        let temp = self.upload_f32(temperature, &[batch])?;
        let tp = self.upload_f32(top_p, &[batch])?;
        let mut inputs: Vec<&PjRtBuffer> = w.iter().collect();
        inputs.extend([&t, &n, &l, &u, &temp, &tp]);
        inputs.extend(caches.iter());
        let mut outs = self.run(&key, &inputs, "draft")?;
        drop(caches);
        let n_cache = self.manifest.model(model)?.n_cache_bufs();
        if outs.len() != 2 + n_cache {
            bail!("draft: expected {} outputs, got {}", 2 + n_cache,
                  outs.len());
        }
        let new_caches = outs.split_off(2);
        let tokens = self.download_i32(&outs[0])?;
        let qdists = self.download_f32(&outs[1])?;
        Ok(DraftOut { tokens, qdists, caches: new_caches })
    }

    /// Offset-addressed fused draft call (`ExecMode::Packed`): same
    /// resync + K-step loop as [`Engine::draft`], but `uniforms` is a
    /// flat packed-prefix `[B*K]` buffer addressed by `koffs` `[B+1]`
    /// (row i's `k_i = koffs[i+1] - koffs[i]` uniforms at
    /// `koffs[i]..koffs[i+1]`), and the returned tokens `[B*K]` /
    /// qdists `[B*K, V]` use the same packed-prefix layout.
    #[allow(clippy::too_many_arguments)]
    pub fn draft_packed(&self, model: &str, precision: Precision,
                        attn: Attn, batch: usize, k: usize,
                        tokens_in: &[i32], n_in: &[i32], seq_lens: &[i32],
                        koffs: &[i32], uniforms: &[f32],
                        temperature: &[f32], top_p: &[f32],
                        caches: Vec<PjRtBuffer>) -> Result<DraftOut> {
        if tokens_in.len() != batch * 2 || koffs.len() != batch + 1
            || uniforms.len() != batch * k || temperature.len() != batch
            || top_p.len() != batch
        {
            bail!("draft_packed shape mismatch");
        }
        let key = ArtifactKey {
            model: model.into(), precision, phase: Phase::DraftPacked,
            batch, q: k, attn,
        };
        let w = self.weights(model, precision)?;
        let t = self.upload_i32(tokens_in, &[batch, 2])?;
        let n = self.upload_i32(n_in, &[batch])?;
        let l = self.upload_i32(seq_lens, &[batch])?;
        let o = self.upload_i32(koffs, &[batch + 1])?;
        let u = self.upload_f32(uniforms, &[batch * k])?;
        let temp = self.upload_f32(temperature, &[batch])?;
        let tp = self.upload_f32(top_p, &[batch])?;
        let mut inputs: Vec<&PjRtBuffer> = w.iter().collect();
        inputs.extend([&t, &n, &l, &o, &u, &temp, &tp]);
        inputs.extend(caches.iter());
        let mut outs = self.run(&key, &inputs, "draft_packed")?;
        drop(caches);
        let n_cache = self.manifest.model(model)?.n_cache_bufs();
        if outs.len() != 2 + n_cache {
            bail!("draft_packed: expected {} outputs, got {}",
                  2 + n_cache, outs.len());
        }
        let new_caches = outs.split_off(2);
        let tokens = self.download_i32(&outs[0])?;
        let qdists = self.download_f32(&outs[1])?;
        Ok(DraftOut { tokens, qdists, caches: new_caches })
    }

    /// Compile every artifact of a model at one (precision, batch) ahead
    /// of time, so serving latency never pays lazy-compile costs. Returns
    /// the number of executables compiled (cached ones are free).
    pub fn prewarm(&self, model: &str, precision: Precision,
                   batch: usize) -> Result<usize> {
        let keys: Vec<ArtifactKey> = self
            .manifest
            .artifacts
            .keys()
            .filter(|k| k.model == model && k.precision == precision
                    && k.batch == batch && k.attn == Attn::Dense)
            .cloned()
            .collect();
        let before = self.stats.borrow().compiles;
        self.weights(model, precision)?;
        for k in &keys {
            self.executable(k)?;
        }
        Ok((self.stats.borrow().compiles - before) as usize)
    }

    // -- calibration -------------------------------------------------------------

    /// Measure sustained peak FLOP/s with the exported GEMM artifact; this
    /// is the denominator of the Fig-1 utilization metric (the testbed
    /// stand-in for the A100 datasheet number).
    pub fn calibrate_peak_flops(&self, iters: usize) -> Result<f64> {
        let path = self.manifest.root.join(&self.manifest.calib_file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?)?;
        let exe = self.client()?.compile(&XlaComputation::from_proto(&proto))?;
        let n = (self.manifest.calib_flops as f64 / 2.0).cbrt() as usize;
        let host = vec![1.0f32; n * n];
        let a = self.upload_f32(&host, &[n, n])?;
        let b = self.upload_f32(&host, &[n, n])?;
        // Warm up, then time.
        let out = exe.execute_b(&[&a, &b])?;
        drop(out);
        let t0 = Instant::now();
        for _ in 0..iters {
            let out = exe.execute_b(&[&a, &b])?;
            // Force completion by touching the result.
            let _ = out[0][0].to_literal_sync()?;
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        Ok(self.manifest.calib_flops as f64 / dt)
    }
}
