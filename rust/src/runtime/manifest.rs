//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `artifacts/manifest.json` describes the trained models, their weight
//! files per precision, and the full grid of AOT-compiled HLO programs
//! (prefill / decode / draft × batch × Q-bucket × precision). The engine
//! resolves [`ArtifactKey`]s against this index and lazily compiles the
//! HLO text on first use.

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::json::Json;

/// Manifest (= artifact ABI) version this runtime speaks. v5: the grid
/// exports a weightless `kv_row_copy` program per (model, precision,
/// b>1 bucket) — copies one row's `[H,S,Dh]` KV slab onto another row
/// of the same fused store (fan-out prefill sharing and the coordinator
/// prefix cache ride on it); v4 added the packed-segment
/// `decode_packed` / `draft_packed` programs (`ExecMode::Packed` packs
/// the batch's ragged rows into one offset-addressed token stream); v3
/// added a per-row `prefill_scatter` artifact per batch bucket (PAD
/// mid-flight admission scatter-prefills a new sequence into a freed
/// row of the running fused cache); v2 made the draft artifact take
/// `[B]` per-row temperature/top_p vectors instead of scalars. Checked
/// at load so an artifact/binary mismatch fails with a "rebuild"
/// message instead of an opaque device shape error mid-request.
pub const MANIFEST_VERSION: usize = 5;

/// Numeric precision of a model's weights (paper Tables 1–3 axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    F32,
    Int8,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Precision::F32,
            "int8" => Precision::Int8,
            _ => bail!("unknown precision '{s}'"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which AOT program an artifact holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Context encoding of the prompt batch; `q` = padded prompt capacity.
    Prefill,
    /// Context-encode ONE prompt and scatter its KV into a given row of
    /// an existing fused cache (PAD mid-flight admission); `q` = padded
    /// prompt capacity, `batch` = the fused cache's bucket.
    PrefillScatter,
    /// Ragged verification step of the main model; `q` = tokens per seq.
    Decode,
    /// Fused draft loop (resync + K auto-regressive steps); `q` = K.
    Draft,
    /// Packed-segment verification (`ExecMode::Packed`): one `[1, C]`
    /// token stream holding the batch's ragged rows back-to-back,
    /// addressed by `[B+1]` cumulative offsets; `q` = per-row capacity
    /// bucket, so C = `batch * q`.
    DecodePacked,
    /// Offset-addressed fused draft loop: uniforms and outputs live in a
    /// packed-prefix `[B*K]` layout indexed by `[B+1]` koffs; `q` = K.
    DraftPacked,
    /// Copy one row's full `[H,S,Dh]` KV slab onto another row of the
    /// same fused cache (weightless; fan-out prefill sharing + prefix-
    /// cache reuse); `q` is unused (0), `batch` = the fused bucket.
    KvRowCopy,
}

impl Phase {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "prefill" => Phase::Prefill,
            "prefill_scatter" => Phase::PrefillScatter,
            "decode" => Phase::Decode,
            "draft" => Phase::Draft,
            "decode_packed" => Phase::DecodePacked,
            "draft_packed" => Phase::DraftPacked,
            "kv_row_copy" => Phase::KvRowCopy,
            _ => bail!("unknown phase '{s}'"),
        })
    }
}

/// Attention realization inside the artifact (both are BASS-PAD; see
/// DESIGN.md §6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Attn {
    /// XLA-fused pad+mask attention (default production path).
    Dense,
    /// Explicitly-tiled Pallas kernel lowered in interpret mode (parity
    /// subset proving the L1 path composes through PJRT).
    Pallas,
}

/// Unique identity of one AOT program.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub model: String,
    pub precision: Precision,
    pub phase: Phase,
    pub batch: usize,
    pub q: usize,
    pub attn: Attn,
}

impl fmt::Display for ArtifactKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}_{}_{:?}{}_b{}{}", self.model, self.precision,
               self.phase, self.q, self.batch,
               if self.attn == Attn::Pallas { "_pallas" } else { "" })
    }
}

/// Architecture + weight index of one model.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub s_max: usize,
    pub d_head: usize,
    pub param_count: usize,
    /// precision -> weights file (relative to the artifact root).
    pub weights: HashMap<Precision, String>,
}

impl ModelInfo {
    /// Shape of each per-layer KV cache buffer at a given batch size.
    pub fn cache_dims(&self, batch: usize) -> [usize; 4] {
        [batch, self.n_head, self.s_max, self.d_head]
    }

    /// Number of per-layer cache buffers (K and V per layer).
    pub fn n_cache_bufs(&self) -> usize {
        2 * self.n_layer
    }
}

/// Parsed `manifest.json`.
#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub vocab: usize,
    pub eos: u8,
    pub prefill_p: usize,
    pub batches: Vec<usize>,
    pub draft_k_buckets: Vec<usize>,
    pub small_k_buckets: Vec<usize>,
    pub models: HashMap<String, ModelInfo>,
    pub artifacts: HashMap<ArtifactKey, String>,
    pub calib_file: String,
    pub calib_flops: u64,
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(root, &text)
    }

    pub fn parse(root: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("version")?.as_usize()?;
        if version != MANIFEST_VERSION {
            bail!("artifact manifest is version {version}, this runtime \
                   needs {MANIFEST_VERSION} (v5 added the per-bucket \
                   kv_row_copy programs fan-out prefill sharing and the \
                   prefix cache use; v4 added the packed-segment \
                   decode_packed/draft_packed programs ExecMode::Packed \
                   launches; v3 added the per-row prefill_scatter \
                   artifacts PAD mid-flight admission uses; v2 changed \
                   the draft ABI to per-row temperature/top_p vectors) — \
                   rebuild with `make artifacts`");
        }
        let usize_arr = |v: &Json| -> Result<Vec<usize>> {
            v.as_arr()?.iter().map(|x| x.as_usize()).collect()
        };

        let mut models = HashMap::new();
        for (name, m) in j.get("models")?.as_obj()? {
            let mut weights = HashMap::new();
            for (prec, file) in m.get("weights")?.as_obj()? {
                weights.insert(Precision::parse(prec)?,
                               file.as_str()?.to_string());
            }
            models.insert(name.clone(), ModelInfo {
                name: name.clone(),
                n_layer: m.get("n_layer")?.as_usize()?,
                n_head: m.get("n_head")?.as_usize()?,
                d_model: m.get("d_model")?.as_usize()?,
                d_ff: m.get("d_ff")?.as_usize()?,
                s_max: m.get("s_max")?.as_usize()?,
                d_head: m.get("d_head")?.as_usize()?,
                param_count: m.get("param_count")?.as_usize()?,
                weights,
            });
        }

        let mut artifacts = HashMap::new();
        for a in j.get("artifacts")?.as_arr()? {
            let key = ArtifactKey {
                model: a.get("model")?.as_str()?.to_string(),
                precision: Precision::parse(a.get("precision")?.as_str()?)?,
                phase: Phase::parse(a.get("phase")?.as_str()?)?,
                batch: a.get("batch")?.as_usize()?,
                q: a.get("q")?.as_usize()?,
                attn: match a.get("attn")?.as_str()? {
                    "pallas" => Attn::Pallas,
                    _ => Attn::Dense,
                },
            };
            artifacts.insert(key, a.get("file")?.as_str()?.to_string());
        }

        let calib = j.get("calib")?;
        Ok(Manifest {
            root: root.to_path_buf(),
            vocab: j.get("vocab")?.as_usize()?,
            eos: j.get("eos")?.as_usize()? as u8,
            prefill_p: j.get("prefill_p")?.as_usize()?,
            batches: usize_arr(j.get("batches")?)?,
            draft_k_buckets: usize_arr(j.get("draft_k_buckets")?)?,
            small_k_buckets: usize_arr(j.get("small_k_buckets")?)?,
            models,
            artifacts,
            calib_file: calib.get("file")?.as_str()?.to_string(),
            calib_flops: calib.get("flops")?.as_f64()? as u64,
        })
    }

    /// Synthetic manifest for the host-only stub engine
    /// ([`super::Engine::stub`]): the real testbed geometry (byte vocab,
    /// eos 0, the exported batch/k ladders) with NO artifact or weight
    /// files — the stub exec backend computes everything on the host, so
    /// only the fields the batching/scheduling layers consult matter
    /// (bucket ladders, `prefill_p`, model `s_max`).
    pub fn stub() -> Manifest {
        let model = |name: &str| ModelInfo {
            name: name.to_string(),
            n_layer: 4,
            n_head: 8,
            d_model: 256,
            d_ff: 1024,
            s_max: 4096,
            d_head: 32,
            param_count: 3_290_624,
            weights: HashMap::new(),
        };
        let mut models = HashMap::new();
        models.insert("main".to_string(), model("main"));
        models.insert("draft_a".to_string(), model("draft_a"));
        Manifest {
            root: PathBuf::from("<stub>"),
            vocab: 256,
            eos: 0,
            prefill_p: 64,
            batches: vec![1, 2, 4, 8, 16],
            draft_k_buckets: vec![1, 2, 4, 8],
            small_k_buckets: vec![2, 4],
            models,
            artifacts: HashMap::new(),
            calib_file: String::new(),
            calib_flops: 0,
        }
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}'"))
    }

    pub fn artifact_path(&self, key: &ArtifactKey) -> Result<PathBuf> {
        let rel = self
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!("no artifact for {key} in manifest"))?;
        Ok(self.root.join(rel))
    }

    /// Draft-length buckets available for a model (draft_a has the full
    /// Algorithm-1 range; the Table-4 comparison drafts ship a subset).
    pub fn k_buckets(&self, model: &str) -> &[usize] {
        if model == "draft_a" {
            &self.draft_k_buckets
        } else {
            &self.small_k_buckets
        }
    }

    /// Round a requested draft length down to the nearest exported bucket
    /// (never below the smallest bucket).
    pub fn bucket_k(&self, model: &str, k: usize) -> usize {
        let buckets = self.k_buckets(model);
        let mut best = buckets[0];
        for &b in buckets {
            if b <= k && b > best {
                best = b;
            }
        }
        best.max(buckets[0])
    }

    /// Smallest packed per-row capacity bucket `q'` whose stream
    /// `C = batch * q'` fits `sum_q` packed tokens. The ladder is
    /// `{k + 1}` over the full draft-bucket range, so the rectangular
    /// launch width `max_i q_i` is always a member: a packed launch
    /// never carries more tokens than PAD's `batch * q_launch`
    /// rectangle (Σq_i ≤ b·q_launch rounds to `q' ≤ q_launch`).
    pub fn bucket_packed_q(&self, batch: usize, sum_q: usize)
                           -> Result<usize> {
        self.draft_k_buckets
            .iter()
            .map(|&k| k + 1)
            .filter(|&q| q * batch >= sum_q)
            .min()
            .ok_or_else(|| {
                anyhow!("{sum_q} packed tokens exceed the largest \
                         decode_packed capacity at batch {batch}")
            })
    }

    /// Largest exported batch bucket (0 when none are exported) — the
    /// ceiling a live PAD re-bucket may grow to.
    pub fn largest_batch(&self) -> usize {
        self.batches.iter().copied().max().unwrap_or(0)
    }

    /// Smallest exported batch bucket that fits `n` sequences.
    pub fn bucket_batch(&self, n: usize) -> Result<usize> {
        self.batches
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| anyhow!("batch {n} exceeds largest bucket"))
    }

    /// Smallest exported batch bucket covering `n` sequences **plus** up
    /// to `headroom` grow-room rows, clamped to `cap` (the serving
    /// capacity) and to the largest exported bucket. The headroom is
    /// best-effort: it never raises an error plain `bucket_batch(n)`
    /// would not, it only rounds the bucket up so a running PAD batch
    /// starts with reusable padding rows for mid-flight admissions
    /// instead of making a burst wait for the drain-and-re-bucket
    /// (`SpecConfig::pad_headroom`).
    pub fn bucket_batch_padded(&self, n: usize, headroom: usize,
                               cap: usize) -> Result<usize> {
        let want = (n + headroom).min(cap).min(self.largest_batch()).max(n);
        self.bucket_batch(want)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 5, "vocab": 256, "eos": 0, "prefill_p": 64,
      "batches": [1, 2, 4], "draft_k_buckets": [1, 2, 4, 8],
      "small_k_buckets": [2, 4],
      "models": {"main": {"n_layer": 4, "n_head": 8, "d_model": 256,
        "d_ff": 1024, "s_max": 256, "d_head": 32, "param_count": 3290624,
        "weights": {"f32": "weights/main_f32.bwt"}}},
      "artifacts": [{"file": "hlo/main_f32_decode1_b1.hlo.txt",
        "model": "main", "precision": "f32", "phase": "decode",
        "batch": 1, "q": 1, "attn": "dense"},
        {"file": "hlo/main_f32_prefill_scatter64_b4.hlo.txt",
        "model": "main", "precision": "f32", "phase": "prefill_scatter",
        "batch": 4, "q": 64, "attn": "dense"},
        {"file": "hlo/main_f32_decode_packed3_b2.hlo.txt",
        "model": "main", "precision": "f32", "phase": "decode_packed",
        "batch": 2, "q": 3, "attn": "dense"},
        {"file": "hlo/draft_a_f32_draft_packed4_b2.hlo.txt",
        "model": "draft_a", "precision": "f32", "phase": "draft_packed",
        "batch": 2, "q": 4, "attn": "dense"},
        {"file": "hlo/main_f32_kv_row_copy0_b4.hlo.txt",
        "model": "main", "precision": "f32", "phase": "kv_row_copy",
        "batch": 4, "q": 0, "attn": "dense"}],
      "calib": {"file": "hlo/gemm_calib.hlo.txt", "n": 768,
        "flops": 905969664}
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.vocab, 256);
        let mi = m.model("main").unwrap();
        assert_eq!(mi.n_layer, 4);
        assert_eq!(mi.cache_dims(2), [2, 8, 256, 32]);
        let key = ArtifactKey {
            model: "main".into(),
            precision: Precision::F32,
            phase: Phase::Decode,
            batch: 1,
            q: 1,
            attn: Attn::Dense,
        };
        assert!(m.artifact_path(&key).is_ok());
        assert!(m.model("nope").is_err());
        // The per-row scatter phase round-trips through the manifest.
        let scatter = ArtifactKey {
            model: "main".into(),
            precision: Precision::F32,
            phase: Phase::PrefillScatter,
            batch: 4,
            q: 64,
            attn: Attn::Dense,
        };
        assert!(m.artifact_path(&scatter).is_ok());
        // ...and so do the v4 packed-segment phases.
        let packed = ArtifactKey {
            model: "main".into(),
            precision: Precision::F32,
            phase: Phase::DecodePacked,
            batch: 2,
            q: 3,
            attn: Attn::Dense,
        };
        assert!(m.artifact_path(&packed).is_ok());
        let dpacked = ArtifactKey {
            model: "draft_a".into(),
            precision: Precision::F32,
            phase: Phase::DraftPacked,
            batch: 2,
            q: 4,
            attn: Attn::Dense,
        };
        assert!(m.artifact_path(&dpacked).is_ok());
        // ...and the v5 row-copy phase.
        let copy = ArtifactKey {
            model: "main".into(),
            precision: Precision::F32,
            phase: Phase::KvRowCopy,
            batch: 4,
            q: 0,
            attn: Attn::Dense,
        };
        assert!(m.artifact_path(&copy).is_ok());
    }

    #[test]
    fn stale_manifest_version_is_rejected_with_rebuild_hint() {
        // Pre-v5 artifacts lack the kv_row_copy programs (pre-v4 the
        // packed-segment ones, pre-v3 the per-row prefill_scatter ones,
        // pre-v2 export scalar draft temp/top_p): loading them with this
        // runtime must fail up front, not at execute time, and the error
        // must name both the missing programs and the rebuild command.
        for stale in ["\"version\": 1", "\"version\": 2", "\"version\": 3",
                      "\"version\": 4"] {
            let old = SAMPLE.replace("\"version\": 5", stale);
            let err = Manifest::parse(Path::new("/tmp/x"), &old)
                .expect_err("stale manifest must be rejected");
            let msg = format!("{err:#}");
            assert!(msg.contains("make artifacts"),
                    "unhelpful error: {msg}");
            assert!(msg.contains("kv_row_copy"),
                    "error does not name the missing programs: {msg}");
            assert!(msg.contains("decode_packed"),
                    "error does not name the missing programs: {msg}");
        }
    }

    #[test]
    fn bucket_logic() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.bucket_k("draft_a", 5), 4);
        assert_eq!(m.bucket_k("draft_a", 1), 1);
        assert_eq!(m.bucket_k("draft_a", 100), 8);
        assert_eq!(m.bucket_k("draft_b", 3), 2);
        assert_eq!(m.bucket_batch(3).unwrap(), 4);
        assert_eq!(m.bucket_batch(1).unwrap(), 1);
        assert!(m.bucket_batch(5).is_err());
        assert_eq!(m.largest_batch(), 4);
    }

    #[test]
    fn packed_capacity_never_exceeds_the_pad_rectangle() {
        // Ladder from SAMPLE: draft_k [1,2,4,8] -> q' ∈ {2,3,5,9}.
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.bucket_packed_q(4, 8).unwrap(), 2);
        assert_eq!(m.bucket_packed_q(4, 9).unwrap(), 3);
        assert_eq!(m.bucket_packed_q(2, 10).unwrap(), 5);
        assert_eq!(m.bucket_packed_q(1, 9).unwrap(), 9);
        assert!(m.bucket_packed_q(1, 10).is_err());
        // The invariant the ladder encodes: for any ragged q_i drawn
        // from the exported buckets, the packed capacity C = b·q' stays
        // within PAD's rectangle b·max_i(q_i).
        for &k_hi in &m.draft_k_buckets {
            let (b, q_launch) = (4, k_hi + 1);
            let sum: usize = (0..b).map(|_| q_launch).sum();
            let qp = m.bucket_packed_q(b, sum).unwrap();
            assert!(qp <= q_launch, "C grew past the PAD rectangle");
        }
    }

    #[test]
    fn stub_manifest_serves_the_batching_layers() {
        let m = Manifest::stub();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.eos, 0);
        assert!(m.model("main").is_ok() && m.model("draft_a").is_ok());
        assert_eq!(m.bucket_batch(3).unwrap(), 4);
        assert_eq!(m.largest_batch(), 16);
        assert_eq!(m.bucket_k("draft_a", 5), 4);
        assert!(m.artifacts.is_empty(), "stub exports no device programs");
        // Generation room: a prefill-capacity context plus a full budget
        // must fit s_max (SpecBatch admission checks this bound).
        assert!(m.model("main").unwrap().s_max > m.prefill_p + 1024);
    }

    #[test]
    fn padded_bucket_rounds_up_for_headroom() {
        // Buckets are [1, 2, 4] in SAMPLE.
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        // Zero headroom degrades to plain bucket_batch.
        assert_eq!(m.bucket_batch_padded(1, 0, 8).unwrap(), 1);
        assert_eq!(m.bucket_batch_padded(3, 0, 8).unwrap(), 4);
        // Headroom rounds the bucket up past the admitted count...
        assert_eq!(m.bucket_batch_padded(1, 1, 8).unwrap(), 2);
        assert_eq!(m.bucket_batch_padded(2, 1, 8).unwrap(), 4);
        // ...but is clamped to the serving capacity...
        assert_eq!(m.bucket_batch_padded(2, 4, 2).unwrap(), 2);
        // ...and to the largest exported bucket (best-effort, no error).
        assert_eq!(m.bucket_batch_padded(1, 99, 16).unwrap(), 4);
        // An unsatisfiable admitted count still errors exactly like
        // bucket_batch, headroom or not.
        assert!(m.bucket_batch_padded(5, 2, 16).is_err());
    }
}
