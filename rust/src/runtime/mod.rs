//! Runtime layer: PJRT client, artifact registry, weight upload, JSON.
//!
//! Adapts the `/opt/xla-example/load_hlo` pattern: HLO **text** artifacts
//! (AOT-lowered by `python/compile/aot.py`) are parsed with
//! `HloModuleProto::from_text_file`, compiled on the PJRT CPU client and
//! executed with device-resident buffers. The crate-local patched `xla`
//! crate (`third_party/xla-rs`) sets `untuple_result`, so multi-output
//! programs return one buffer per output — the property that lets KV caches
//! live on device across steps.

mod engine;
pub mod json;
mod manifest;
pub mod weights;

pub use engine::{DraftOut, Engine, EngineStats, StepOut};
pub use manifest::{ArtifactKey, Attn, Manifest, ModelInfo, Phase, Precision};
