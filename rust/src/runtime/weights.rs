//! `.bwt` weight loader — the Rust half of `python/compile/bwt.py`.
//!
//! Weights are uploaded to the PJRT device **once** per (model, precision)
//! and the resulting buffers are reused by every executable call; they are
//! never donated, so the same handles stay valid for the process lifetime.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Element type of a weight tensor (subset the models use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
}

impl DType {
    fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::I32,
            t => bail!("unknown dtype tag {t}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

/// One host-side weight tensor as read from a `.bwt` file.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    /// Raw little-endian row-major bytes.
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("{}: not f32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Read all tensors from a `.bwt` file, preserving on-disk (= artifact
/// input) order.
pub fn read_bwt(path: &Path) -> Result<Vec<HostTensor>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse_bwt(&buf).with_context(|| format!("parse {}", path.display()))
}

fn parse_bwt(buf: &[u8]) -> Result<Vec<HostTensor>> {
    let mut r = Cursor { b: buf, i: 0 };
    if r.take(4)? != b"BWT1" {
        bail!("bad magic");
    }
    let count = r.u32()? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = r.u16()? as usize;
        let name = String::from_utf8(r.take(nlen)?.to_vec())?;
        let dtype = DType::from_tag(r.u8()?)?;
        let ndim = r.u8()? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(r.u32()? as usize);
        }
        let nbytes =
            dims.iter().product::<usize>().max(1) * dtype.size();
        let data = r.take(nbytes)?.to_vec();
        out.push(HostTensor { name, dtype, dims, data });
    }
    if r.i != buf.len() {
        bail!("trailing bytes: {} of {}", buf.len() - r.i, buf.len());
    }
    Ok(out)
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated file at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bwt() -> Vec<u8> {
        // Two tensors: "a" f32[2,2], "b" i8[3].
        let mut v = b"BWT1".to_vec();
        v.extend(2u32.to_le_bytes());
        v.extend(1u16.to_le_bytes());
        v.extend(b"a");
        v.push(0); // f32
        v.push(2);
        v.extend(2u32.to_le_bytes());
        v.extend(2u32.to_le_bytes());
        for x in [1.0f32, 2.0, 3.0, 4.0] {
            v.extend(x.to_le_bytes());
        }
        v.extend(1u16.to_le_bytes());
        v.extend(b"b");
        v.push(1); // i8
        v.push(1);
        v.extend(3u32.to_le_bytes());
        v.extend_from_slice(&[250, 0, 7]);
        v
    }

    #[test]
    fn parse_sample() {
        let ts = parse_bwt(&sample_bwt()).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "a");
        assert_eq!(ts[0].dims, vec![2, 2]);
        assert_eq!(ts[0].f32_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ts[1].dtype, DType::I8);
        assert_eq!(ts[1].data, vec![250, 0, 7]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut v = sample_bwt();
        v[0] = b'X';
        assert!(parse_bwt(&v).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let v = sample_bwt();
        assert!(parse_bwt(&v[..v.len() - 1]).is_err());
        assert!(parse_bwt(&v[..10]).is_err());
    }

    #[test]
    fn rejects_trailing() {
        let mut v = sample_bwt();
        v.push(0);
        assert!(parse_bwt(&v).is_err());
    }
}
