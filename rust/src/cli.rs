//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `bass <subcommand> [--flag value]... [--switch]...`

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()`-style input (element 0 is the binary).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().skip(1).peekable();
        match it.next() {
            Some(s) if !s.starts_with('-') => {
                args.subcommand = s.clone();
            }
            Some(s) => bail!("expected subcommand, got '{s}'"),
            None => bail!("missing subcommand"),
        }
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    args.flags.insert(name.to_string(),
                                      (*v).clone());
                    it.next();
                }
                _ => args.switches.push(name.to_string()),
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn f32_flag(&self, name: &str, default: f32) -> Result<f32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: bad float '{v}'")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Result<Args> {
        let v: Vec<String> =
            std::iter::once("bass").chain(s.iter().copied())
                .map(String::from)
                .collect();
        Args::parse(&v)
    }

    #[test]
    fn full_grammar() {
        let a = parse(&["serve", "--port", "8000", "--verbose",
                        "--batch", "8"]).unwrap();
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.flag("port"), Some("8000"));
        assert_eq!(a.usize_flag("batch", 1).unwrap(), 8);
        assert_eq!(a.u64_flag("port", 1).unwrap(), 8000);
        assert_eq!(a.u64_flag("missing", 9).unwrap(), 9);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        assert_eq!(a.usize_flag("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse(&[]).is_err());
        assert!(parse(&["--x"]).is_err());
        assert!(parse(&["run", "stray"]).is_err());
        assert!(parse(&["run", "--n", "abc"]).unwrap()
                .usize_flag("n", 0).is_err());
        assert!(parse(&["run", "--seed", "-3"]).unwrap()
                .u64_flag("seed", 0).is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["eval", "--fast"]).unwrap();
        assert!(a.switch("fast"));
    }
}
