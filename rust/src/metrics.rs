//! Latency/throughput metrics matching the paper's reporting (§4.1):
//! per-token latency (PTL) of the **first** finished sequence, the
//! **last**, and the **mean** across the batch — latency is *not* divided
//! by batch size (footnote 6).

use crate::kv::SeqState;

/// Per-batch generation metrics.
#[derive(Debug, Clone, Default)]
pub struct BatchMetrics {
    /// Per-token latency (seconds) of each sequence: finish_time / tokens.
    pub ptl: Vec<f64>,
    /// PTL of the first sequence to finish.
    pub ptl_first: f64,
    /// PTL of the last sequence to finish.
    pub ptl_last: f64,
    /// Mean PTL across the batch.
    pub ptl_mean: f64,
    /// Wall-clock seconds for the whole batch.
    pub wall_secs: f64,
    /// Total generated tokens across the batch.
    pub total_tokens: usize,
    /// Aggregate throughput, tokens per second.
    pub tokens_per_sec: f64,
    /// Draft-token acceptance rate (speculative runs only).
    pub acceptance_rate: f64,
    /// Mean tokens emitted per speculative step (accepted + 1).
    pub tokens_per_step: f64,
    /// Speculative steps taken (0 for regular decoding).
    pub steps: usize,
    /// Achieved FLOP/s over calibrated peak (Fig-1 utilization).
    pub utilization: f64,
}

impl BatchMetrics {
    /// Compute PTL metrics from finished sequence states. Sequences that
    /// generated zero tokens are skipped (they carry no latency signal).
    pub fn from_seqs(seqs: &[SeqState], wall_secs: f64) -> BatchMetrics {
        let mut ptl = Vec::new();
        let mut total_tokens = 0usize;
        for s in seqs {
            let n = s.tokens_generated();
            total_tokens += n;
            if n > 0 {
                let t = if s.finish_secs > 0.0 { s.finish_secs } else {
                    wall_secs
                };
                ptl.push(t / n as f64);
            }
        }
        let (first, last, mean) = if ptl.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            let mut sorted = ptl.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (sorted[0], *sorted.last().unwrap(),
             ptl.iter().sum::<f64>() / ptl.len() as f64)
        };
        BatchMetrics {
            ptl,
            ptl_first: first,
            ptl_last: last,
            ptl_mean: mean,
            wall_secs,
            total_tokens,
            tokens_per_sec: if wall_secs > 0.0 {
                total_tokens as f64 / wall_secs
            } else {
                0.0
            },
            ..Default::default()
        }
    }
}

/// Simple streaming statistics for benchmark harnesses.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::FinishReason;

    fn seq_with(tokens: usize, finish_secs: f64) -> SeqState {
        let mut s = SeqState::new(vec![1, 2], 2, 2);
        for _ in 0..tokens {
            s.generated.push(7);
        }
        s.finish_at(FinishReason::Eos, finish_secs);
        s
    }

    #[test]
    fn ptl_first_last_mean() {
        let seqs = vec![seq_with(10, 1.0), seq_with(10, 2.0),
                        seq_with(5, 1.5)];
        let m = BatchMetrics::from_seqs(&seqs, 2.0);
        assert!((m.ptl_first - 0.1).abs() < 1e-9);
        assert!((m.ptl_last - 0.3).abs() < 1e-9);
        assert!((m.ptl_mean - (0.1 + 0.2 + 0.3) / 3.0).abs() < 1e-9);
        assert_eq!(m.total_tokens, 25);
        assert!((m.tokens_per_sec - 12.5).abs() < 1e-9);
    }

    #[test]
    fn unfinished_uses_wall_clock() {
        let mut s = seq_with(4, 0.0);
        s.finish = FinishReason::Running;
        s.finish_secs = 0.0;
        let m = BatchMetrics::from_seqs(&[s], 2.0);
        assert!((m.ptl_first - 0.5).abs() < 1e-9);
    }

    #[test]
    fn zero_token_seqs_skipped() {
        let seqs = vec![seq_with(0, 1.0), seq_with(10, 1.0)];
        let m = BatchMetrics::from_seqs(&seqs, 1.0);
        assert_eq!(m.ptl.len(), 1);
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::default();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(1.0), 100.0);
        assert_eq!(s.percentile(0.5), 51.0); // round(49.5) = 50 -> s[50]
        assert_eq!(s.min(), 1.0);
    }
}
