//! Latency/throughput metrics matching the paper's reporting (§4.1):
//! per-token latency (PTL) of the **first** finished sequence, the
//! **last**, and the **mean** across the batch — latency is *not* divided
//! by batch size (footnote 6) — plus the serving-side scheduler counters
//! ([`SchedStats`]: preemptions, resumes, queue depth, per-priority
//! queue wait) the coordinator's preemptive scheduler maintains.

use std::collections::BTreeMap;

use crate::kv::SeqState;
use crate::obs::Series;
use crate::runtime::json::Json;

/// Per-batch generation metrics.
#[derive(Debug, Clone, Default)]
pub struct BatchMetrics {
    /// Per-token latency (seconds) of each sequence: finish_time / tokens.
    pub ptl: Vec<f64>,
    /// PTL of the first sequence to finish.
    pub ptl_first: f64,
    /// PTL of the last sequence to finish.
    pub ptl_last: f64,
    /// Mean PTL across the batch.
    pub ptl_mean: f64,
    /// Wall-clock seconds for the whole batch.
    pub wall_secs: f64,
    /// Total generated tokens across the batch.
    pub total_tokens: usize,
    /// Aggregate throughput, tokens per second.
    pub tokens_per_sec: f64,
    /// Draft-token acceptance rate (speculative runs only).
    pub acceptance_rate: f64,
    /// Mean tokens emitted per speculative step (accepted + 1).
    pub tokens_per_step: f64,
    /// Speculative steps taken (0 for regular decoding).
    pub steps: usize,
    /// Achieved FLOP/s over calibrated peak (Fig-1 utilization).
    pub utilization: f64,
}

impl BatchMetrics {
    /// Compute PTL metrics from finished sequence states. Sequences that
    /// generated zero tokens are skipped (they carry no latency signal).
    pub fn from_seqs(seqs: &[SeqState], wall_secs: f64) -> BatchMetrics {
        let mut ptl = Vec::new();
        let mut total_tokens = 0usize;
        for s in seqs {
            let n = s.tokens_generated();
            total_tokens += n;
            if n > 0 {
                let t = if s.finish_secs > 0.0 { s.finish_secs } else {
                    wall_secs
                };
                ptl.push(t / n as f64);
            }
        }
        let (first, last, mean) = if ptl.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            let mut sorted = ptl.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (sorted[0], *sorted.last().unwrap(),
             ptl.iter().sum::<f64>() / ptl.len() as f64)
        };
        BatchMetrics {
            ptl,
            ptl_first: first,
            ptl_last: last,
            ptl_mean: mean,
            wall_secs,
            total_tokens,
            tokens_per_sec: if wall_secs > 0.0 {
                total_tokens as f64 / wall_secs
            } else {
                0.0
            },
            ..Default::default()
        }
    }
}

/// Counters for the coordinator's preemptive scheduler: how often running
/// work was suspended/resumed and what the queue looked like, per
/// priority class. Preemptions and resumes are counted on **successful
/// execution** (after `SpecBatch::suspend` parked a snapshot / after
/// `SpecBatch::resume` re-entered the batch), never at plan time — a
/// planned action can still fail or be dropped, and the counters must
/// not drift from what actually ran.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    /// Running sequences suspended to host memory to make room for
    /// higher-priority work (recompute-resume preemptions).
    pub preemptions: u64,
    /// Suspended sequences re-admitted by recompute.
    pub resumes: u64,
    /// Live PAD re-buckets that **grew** the running fused bucket (a
    /// burst larger than its reusable rows, served without a drain).
    pub rebuckets_grow: u64,
    /// Live PAD re-buckets that **shrank** the bucket (idle occupancy
    /// covered by a smaller bucket; cuts dead rows from every fused
    /// step).
    pub rebuckets_shrink: u64,
    /// Real rows re-encoded across all re-buckets; divide by
    /// [`SchedStats::rebuckets`] for the per-re-bucket migrated-row
    /// count.
    pub rebucket_migrated: u64,
    /// Requests waiting in the scheduler queue right now (gauge,
    /// refreshed at every planning boundary).
    pub queue_depth: usize,
    /// High-water mark of `queue_depth`.
    pub max_queue_depth: usize,
    /// Bucket-occupancy gauge: live real rows vs the fused bucket's
    /// rows, refreshed at every planning boundary ((0, 0) for SPLIT or
    /// an idle engine). Sustained low occupancy is the shrink signal;
    /// occupancy pinned at 1.0 with queued work is the grow signal.
    pub bucket_live: usize,
    pub bucket_rows: usize,
    /// Lifetime aggregate of the gauge over rounds where a fused bucket
    /// was running — `mean_bucket_occupancy` in the worker-exit summary
    /// is the `--pad-headroom` / `shrink_delay` tuning signal.
    pub occupancy_sum: f64,
    pub occupancy_rounds: u64,
    /// priority -> aggregated admission waits (queue time before the
    /// request first entered the engine batch).
    pub queue_wait: BTreeMap<i32, QueueWait>,
    /// Draft-token economy over every (sequence, step) the engine
    /// executed: each live row contributes its **own** per-row draft
    /// length `k_i` (the adaptive controller's bucketized choice, not
    /// the batch launch width) and its own accepted count.
    pub draft_steps: u64,
    pub draft_len_sum: u64,
    pub draft_accepted_sum: u64,
    /// Queue-depth-over-time: every `note_depth` refresh sampled into
    /// a bounded deterministic series ([`Series`] decimates, never
    /// randomizes), so the report can show the shape of the backlog,
    /// not just its high-water mark. Advisory: the *number* of
    /// refreshes depends on arrival timing, so the series never rides
    /// the deterministic `counters` contract.
    pub depth_series: Series,
    /// Bucket-occupancy-over-time, one sample per `note_bucket`
    /// refresh (0.0 while no fused bucket runs).
    pub occupancy_series: Series,
    /// Prompt-prefix cache lookups that found a usable resident donor
    /// row (admission/resume served by `row_copy` instead of a full
    /// prompt prefill).
    pub prefix_hits: u64,
    /// Prefix-cache lookups that found nothing (full prefill ran).
    /// `hits + misses` is the report's `lookups` — the invariant
    /// `diff_bench_serving.py` hard-checks.
    pub prefix_misses: u64,
    /// Cache entries deterministically evicted (LRU capacity bound —
    /// logical ticks, never wall clock).
    pub prefix_evictions: u64,
    /// Device-equivalent prefill FLOPs the cache + fan-out sharing
    /// avoided: each reuse credits `prefill_flops(main) +
    /// prefill_flops(draft)` for the prompt it did NOT re-encode
    /// (formula-based, so the stub backend reports the same savings
    /// the device backends realize — the same convention as its
    /// launch-FLOP accounting).
    pub prefix_saved_flops: f64,
    /// KV row copies actually executed (fan-out sibling shares + cache
    /// hits), counted like preemptions/resumes: on success, never at
    /// plan time.
    pub row_copies: u64,
}

/// Aggregated queue-wait observations of one priority class.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueueWait {
    pub requests: u64,
    pub total_secs: f64,
}

impl SchedStats {
    /// Refresh the queue-depth gauge (and its high-water mark, and
    /// the bounded over-time series).
    pub fn note_depth(&mut self, depth: usize) {
        self.queue_depth = depth;
        self.max_queue_depth = self.max_queue_depth.max(depth);
        self.depth_series.push(depth as f64);
    }

    /// Count one **executed** live re-bucket (after `SpecBatch::rebucket`
    /// returned an outcome — never at plan time, mirroring
    /// preemption/resume counting).
    pub fn note_rebucket(&mut self, grow: bool, migrated: usize) {
        if grow {
            self.rebuckets_grow += 1;
        } else {
            self.rebuckets_shrink += 1;
        }
        self.rebucket_migrated += migrated as u64;
    }

    /// Total live re-buckets (grow + shrink) — what the response JSON
    /// echoes as `"rebuckets"`.
    pub fn rebuckets(&self) -> u64 {
        self.rebuckets_grow + self.rebuckets_shrink
    }

    /// Refresh the bucket-occupancy gauge (and, while a bucket is
    /// actually running, fold it into the lifetime mean).
    pub fn note_bucket(&mut self, live: usize, rows: usize) {
        self.bucket_live = live;
        self.bucket_rows = rows;
        if rows > 0 {
            self.occupancy_rounds += 1;
            self.occupancy_sum += live as f64 / rows as f64;
        }
        self.occupancy_series.push(self.bucket_occupancy());
    }

    /// Live rows over bucket rows (0 when no fused bucket is running).
    pub fn bucket_occupancy(&self) -> f64 {
        if self.bucket_rows == 0 {
            0.0
        } else {
            self.bucket_live as f64 / self.bucket_rows as f64
        }
    }

    /// Mean occupancy across bucket-running rounds (0 when none ran).
    pub fn mean_bucket_occupancy(&self) -> f64 {
        if self.occupancy_rounds == 0 {
            0.0
        } else {
            self.occupancy_sum / self.occupancy_rounds as f64
        }
    }

    /// Record one (sequence, step) draft observation: the row's own
    /// draft length and how many of those tokens were accepted.
    pub fn observe_draft(&mut self, draft_len: usize, accepted: usize) {
        self.draft_steps += 1;
        self.draft_len_sum += draft_len as u64;
        self.draft_accepted_sum += accepted as u64;
    }

    /// Mean per-row draft length across all observed (sequence, step)
    /// pairs (0 when no speculative step ran).
    pub fn mean_draft_len(&self) -> f64 {
        if self.draft_steps == 0 {
            0.0
        } else {
            self.draft_len_sum as f64 / self.draft_steps as f64
        }
    }

    /// Accepted draft tokens over proposed draft tokens (0 when nothing
    /// was drafted).
    pub fn draft_acceptance(&self) -> f64 {
        if self.draft_len_sum == 0 {
            0.0
        } else {
            self.draft_accepted_sum as f64 / self.draft_len_sum as f64
        }
    }

    /// Record one prefix-cache lookup outcome. Savings are credited by
    /// [`SchedStats::note_row_copy`] when the reuse actually executes,
    /// never at lookup time — a hit whose copy later fails must not
    /// claim FLOPs it did not save.
    pub fn note_prefix_lookup(&mut self, hit: bool) {
        if hit {
            self.prefix_hits += 1;
        } else {
            self.prefix_misses += 1;
        }
    }

    /// Total prefix-cache lookups (`hits + misses` by construction).
    pub fn prefix_lookups(&self) -> u64 {
        self.prefix_hits + self.prefix_misses
    }

    /// Count one **executed** KV row copy (fan-out sibling share or
    /// cache-hit resume); a sharing copy also credits the sibling
    /// prefill it replaced.
    pub fn note_row_copy(&mut self, saved_flops: f64) {
        self.row_copies += 1;
        self.prefix_saved_flops += saved_flops;
    }

    /// Record one request's admission wait under its priority class.
    pub fn observe_wait(&mut self, priority: i32, secs: f64) {
        let w = self.queue_wait.entry(priority).or_default();
        w.requests += 1;
        w.total_secs += secs;
    }

    /// Mean queue wait of a priority class, seconds (0 when unobserved).
    pub fn mean_wait_secs(&self, priority: i32) -> f64 {
        match self.queue_wait.get(&priority) {
            Some(w) if w.requests > 0 => w.total_secs / w.requests as f64,
            _ => 0.0,
        }
    }

    /// The registry snapshot: every counter/gauge/series this struct
    /// tracks, as JSON. This is the **single source of truth** behind
    /// every exposition path — the TCP `{"cmd":"stats"}` admin reply,
    /// the periodic stderr snapshot, the report's `observability`
    /// section — while [`SchedStats::summary_line`] renders the same
    /// numbers as the worker-exit text, so the views cannot drift.
    pub fn snapshot(&self) -> Json {
        let mut waits = BTreeMap::new();
        for (p, w) in &self.queue_wait {
            waits.insert(format!("{p}"), Json::obj(vec![
                ("requests", (w.requests as f64).into()),
                ("mean_wait_ms", (self.mean_wait_secs(*p) * 1e3).into()),
            ]));
        }
        Json::obj(vec![
            ("preemptions", (self.preemptions as f64).into()),
            ("resumes", (self.resumes as f64).into()),
            ("rebuckets", (self.rebuckets() as f64).into()),
            ("rebuckets_grow", (self.rebuckets_grow as f64).into()),
            ("rebuckets_shrink", (self.rebuckets_shrink as f64).into()),
            ("rebucket_migrated", (self.rebucket_migrated as f64).into()),
            ("queue_depth", self.queue_depth.into()),
            ("max_queue_depth", self.max_queue_depth.into()),
            ("bucket_occupancy", self.bucket_occupancy().into()),
            ("mean_bucket_occupancy",
             self.mean_bucket_occupancy().into()),
            ("draft_len_mean", self.mean_draft_len().into()),
            ("acceptance_rate", self.draft_acceptance().into()),
            ("queue_wait", Json::Obj(waits)),
            ("prefix_cache", Json::obj(vec![
                ("lookups", (self.prefix_lookups() as f64).into()),
                ("hits", (self.prefix_hits as f64).into()),
                ("misses", (self.prefix_misses as f64).into()),
                ("evictions", (self.prefix_evictions as f64).into()),
                ("row_copies", (self.row_copies as f64).into()),
                ("saved_flops", self.prefix_saved_flops.into()),
            ])),
            ("queue_depth_series", self.depth_series.to_json()),
            ("bucket_occupancy_series",
             self.occupancy_series.to_json()),
        ])
    }

    /// The worker-exit stderr line, as a formatted view of the
    /// registry ([`SchedStats::snapshot`] carries the same numbers).
    /// `None` when the scheduler never did anything worth a line.
    pub fn summary_line(&self) -> Option<String> {
        if self.preemptions == 0 && self.resumes == 0
            && self.max_queue_depth == 0 && self.rebuckets() == 0
            && self.prefix_lookups() == 0 && self.row_copies == 0
        {
            return None;
        }
        let waits: Vec<String> = self
            .queue_wait
            .iter()
            .map(|(p, w)| {
                format!("p{p}:{:.1}ms×{}",
                        self.mean_wait_secs(*p) * 1e3, w.requests)
            })
            .collect();
        Some(format!(
            "preemptions={} resumes={} rebuckets={} (grow {} / shrink \
             {}, {} rows migrated) bucket_occ≈{:.0}% draft_len≈{:.1} \
             accept≈{:.0}% prefix[{}/{} hit, {} evicted, {} copies, \
             {:.3e} FLOPs saved] max_queue_depth={} queue_wait[{}]",
            self.preemptions, self.resumes, self.rebuckets(),
            self.rebuckets_grow, self.rebuckets_shrink,
            self.rebucket_migrated,
            self.mean_bucket_occupancy() * 100.0,
            self.mean_draft_len(),
            self.draft_acceptance() * 100.0,
            self.prefix_hits, self.prefix_lookups(),
            self.prefix_evictions, self.row_copies,
            self.prefix_saved_flops,
            self.max_queue_depth, waits.join(" ")))
    }
}

/// Simple streaming statistics for benchmark harnesses.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn add(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Linear-interpolation percentile (the "linear" / type-7 estimator):
    /// rank `(n-1)·p` interpolated between its neighbors. The old
    /// nearest-rank `round()` collapsed p99 to the max (or under-reported
    /// by a whole rank) for small sample counts — the serving harness
    /// reports p99 over a few hundred requests, where that bias is the
    /// difference between "met the SLO" and "missed it".
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (s.len() - 1) as f64 * p.clamp(0.0, 1.0);
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        s[lo] + (s[hi] - s[lo]) * frac
    }

    /// Smallest sample — 0.0 when empty, like `mean`/`percentile`
    /// (the old `f64::INFINITY` identity leaked a non-finite value
    /// into JSON reports when a scenario produced no samples).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::FinishReason;

    fn seq_with(tokens: usize, finish_secs: f64) -> SeqState {
        let mut s = SeqState::new(vec![1, 2], 2, 2);
        for _ in 0..tokens {
            s.generated.push(7);
        }
        s.finish_at(FinishReason::Eos, finish_secs);
        s
    }

    #[test]
    fn ptl_first_last_mean() {
        let seqs = [seq_with(10, 1.0), seq_with(10, 2.0),
                    seq_with(5, 1.5)];
        let m = BatchMetrics::from_seqs(&seqs, 2.0);
        assert!((m.ptl_first - 0.1).abs() < 1e-9);
        assert!((m.ptl_last - 0.3).abs() < 1e-9);
        assert!((m.ptl_mean - (0.1 + 0.2 + 0.3) / 3.0).abs() < 1e-9);
        assert_eq!(m.total_tokens, 25);
        assert!((m.tokens_per_sec - 12.5).abs() < 1e-9);
    }

    #[test]
    fn unfinished_uses_wall_clock() {
        let mut s = seq_with(4, 0.0);
        s.finish = FinishReason::Running;
        s.finish_secs = 0.0;
        let m = BatchMetrics::from_seqs(&[s], 2.0);
        assert!((m.ptl_first - 0.5).abs() < 1e-9);
    }

    #[test]
    fn zero_token_seqs_skipped() {
        let seqs = [seq_with(0, 1.0), seq_with(10, 1.0)];
        let m = BatchMetrics::from_seqs(&seqs, 1.0);
        assert_eq!(m.ptl.len(), 1);
    }

    #[test]
    fn resumed_sequence_counts_tokens_once() {
        // A preempted-then-resumed sequence carries its pre-suspend bytes
        // in `generated` and its context in `prompt ‖ generated`; PTL and
        // throughput must count each emitted token exactly once — the
        // context re-encoded by the resume prefill is not served output.
        let mut s = SeqState::resumed(vec![1, 2, 3], vec![7; 5], -1.0);
        for _ in 0..5 {
            s.generated.push(8); // post-resume output
        }
        s.finish_at(FinishReason::Eos, 2.0);
        let m = BatchMetrics::from_seqs(&[s], 2.0);
        assert_eq!(m.total_tokens, 10);
        assert!((m.ptl_first - 0.2).abs() < 1e-9);
        assert!((m.tokens_per_sec - 5.0).abs() < 1e-9);
    }

    #[test]
    fn sched_stats_track_depth_waits_and_counts() {
        let mut s = SchedStats::default();
        s.note_depth(3);
        s.note_depth(1);
        assert_eq!(s.queue_depth, 1);
        assert_eq!(s.max_queue_depth, 3);
        s.observe_wait(0, 0.4);
        s.observe_wait(0, 0.6);
        s.observe_wait(5, 0.1);
        assert!((s.mean_wait_secs(0) - 0.5).abs() < 1e-12);
        assert!((s.mean_wait_secs(5) - 0.1).abs() < 1e-12);
        assert_eq!(s.mean_wait_secs(-3), 0.0);
        s.preemptions += 1;
        s.resumes += 1;
        assert_eq!((s.preemptions, s.resumes), (1, 1));
    }

    #[test]
    fn sched_stats_track_draft_economy() {
        let mut s = SchedStats::default();
        assert_eq!(s.mean_draft_len(), 0.0);
        assert_eq!(s.draft_acceptance(), 0.0);
        s.observe_draft(4, 4); // hot row: full accept
        s.observe_draft(8, 2); // long draft, poor acceptance
        s.observe_draft(0, 0); // zero-length rows still count a step
        assert_eq!(s.draft_steps, 3);
        assert!((s.mean_draft_len() - 4.0).abs() < 1e-12);
        assert!((s.draft_acceptance() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sched_stats_track_rebuckets_and_occupancy() {
        let mut s = SchedStats::default();
        assert_eq!(s.rebuckets(), 0);
        assert_eq!(s.bucket_occupancy(), 0.0, "no bucket: occupancy 0");
        s.note_rebucket(true, 3); // grow carrying 3 rows
        s.note_rebucket(true, 1);
        s.note_rebucket(false, 2); // shrink carrying 2 rows
        assert_eq!(s.rebuckets_grow, 2);
        assert_eq!(s.rebuckets_shrink, 1);
        assert_eq!(s.rebuckets(), 3);
        assert_eq!(s.rebucket_migrated, 6);
        s.note_bucket(3, 4);
        assert!((s.bucket_occupancy() - 0.75).abs() < 1e-12);
        s.note_bucket(1, 4);
        s.note_bucket(0, 0); // idle / SPLIT: gauge zero, mean unaffected
        assert_eq!(s.bucket_occupancy(), 0.0);
        assert_eq!(s.occupancy_rounds, 2);
        assert!((s.mean_bucket_occupancy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sched_stats_snapshot_mirrors_the_summary_line() {
        let mut s = SchedStats::default();
        assert!(s.summary_line().is_none(), "idle scheduler: no line");
        s.preemptions = 2;
        s.resumes = 2;
        s.note_rebucket(true, 3);
        s.note_depth(4);
        s.note_depth(1);
        s.note_bucket(3, 4);
        s.observe_draft(4, 2);
        s.observe_wait(0, 0.25);
        let j = s.snapshot();
        assert_eq!(j.get("preemptions").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("rebuckets").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("max_queue_depth").unwrap().as_usize().unwrap(),
                   4);
        assert_eq!(j.get("queue_depth").unwrap().as_usize().unwrap(), 1);
        let w = j.get("queue_wait").unwrap().get("0").unwrap();
        assert_eq!(w.get("requests").unwrap().as_usize().unwrap(), 1);
        assert!((w.get("mean_wait_ms").unwrap().as_f64().unwrap()
                 - 250.0).abs() < 1e-9);
        // The gauge series saw exactly the note_* refreshes.
        let d = j.get("queue_depth_series").unwrap();
        assert_eq!(d.get("seen").unwrap().as_usize().unwrap(), 2);
        assert_eq!(d.get("values").unwrap().as_arr().unwrap().len(), 2);
        let o = j.get("bucket_occupancy_series").unwrap();
        assert_eq!(o.get("seen").unwrap().as_usize().unwrap(), 1);
        // The exit line is a view of the same registry numbers.
        let line = s.summary_line().expect("active scheduler: a line");
        assert!(line.contains("preemptions=2"));
        assert!(line.contains("rebuckets=1"));
        assert!(line.contains("max_queue_depth=4"));
        assert!(line.contains("p0:250.0ms×1"));
        // And the snapshot serializes to valid JSON (no NaN tokens).
        let text = j.to_string_pretty();
        Json::parse(&text).expect("snapshot round-trips");
    }

    #[test]
    fn sched_stats_track_prefix_cache_economy() {
        let mut s = SchedStats::default();
        assert_eq!(s.prefix_lookups(), 0);
        assert!(s.summary_line().is_none(), "untouched cache: no line");
        s.note_prefix_lookup(false);
        s.note_prefix_lookup(true);
        s.note_prefix_lookup(true);
        s.prefix_evictions += 1;
        // Savings accrue on the executed copy, not at lookup time.
        assert_eq!(s.prefix_saved_flops, 0.0);
        s.note_row_copy(1000.0);
        s.note_row_copy(500.0);
        assert_eq!(s.prefix_hits, 2);
        assert_eq!(s.prefix_misses, 1);
        assert_eq!(s.prefix_lookups(), 3);
        assert_eq!(s.row_copies, 2);
        assert!((s.prefix_saved_flops - 1500.0).abs() < 1e-9);
        let j = s.snapshot();
        let pc = j.get("prefix_cache").unwrap();
        assert_eq!(pc.get("lookups").unwrap().as_usize().unwrap(), 3);
        assert_eq!(pc.get("hits").unwrap().as_usize().unwrap(), 2);
        assert_eq!(pc.get("misses").unwrap().as_usize().unwrap(), 1);
        assert_eq!(pc.get("evictions").unwrap().as_usize().unwrap(), 1);
        assert_eq!(pc.get("row_copies").unwrap().as_usize().unwrap(), 2);
        assert!((pc.get("saved_flops").unwrap().as_f64().unwrap()
                 - 1500.0).abs() < 1e-9);
        let line = s.summary_line().expect("active cache: a line");
        assert!(line.contains("prefix[2/3 hit"), "line: {line}");
    }

    #[test]
    fn summary_min_is_finite_on_empty() {
        assert_eq!(Summary::default().min(), 0.0);
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::default();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(1.0), 100.0);
        // Linear interpolation: rank 99·0.5 = 49.5 -> (50 + 51)/2.
        assert!((s.percentile(0.5) - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.99) - 99.01).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
    }

    #[test]
    fn percentile_interpolates_small_samples() {
        // The regression the harness hit: nearest-rank `round()` returned
        // the MAX as p99 for any sample count below ~50, making every
        // small-run p99 a worst-case outlier report. With interpolation,
        // p99 of {10, 20, 30, 40} sits just below the max, p50 between
        // the middle ranks — and a singleton is every percentile.
        let mut s = Summary::default();
        for v in [10.0, 20.0, 30.0, 40.0] {
            s.add(v);
        }
        assert!((s.percentile(0.5) - 25.0).abs() < 1e-9);
        let p99 = s.percentile(0.99);
        assert!(p99 < 40.0 && p99 > 39.0, "p99 {p99} must interpolate");
        let mut one = Summary::default();
        one.add(7.0);
        assert_eq!(one.percentile(0.99), 7.0);
        assert_eq!(Summary::default().percentile(0.5), 0.0);
    }
}
