//! Host-side sampling: temperature + nucleus warping, categorical sampling,
//! and the speculative accept/reject rule (Leviathan et al. 2023 / Chen et
//! al. 2023) the paper builds on (§2.2).
//!
//! The warp **must** match the in-graph draft sampler
//! (`python/compile/model.py::sample_top_p`) bit-for-bit in structure:
//! softmax at `logits/max(T, 1e-4)`, descending sort, keep tokens while
//! `cum - p_i < top_p`, renormalize, then CDF inversion *in original token
//! order*. Only then does the composed speculative distribution equal
//! direct sampling from the warped main distribution — the property the
//! `spec_accept_matches_direct_sampling` property test checks.

/// Deterministic PCG32 RNG (O'Neill 2014). One independent stream per
/// sequence keeps batched generation reproducible regardless of batch
/// composition.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Pcg32 {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }
}

/// Numerically-stable in-place softmax.
pub fn softmax(x: &mut [f32]) {
    let m = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// Temperature + nucleus (top-p) warp of raw logits into a renormalized
/// probability vector. Mirrors the jax in-graph sampler exactly: token i is
/// kept iff the mass of *strictly more probable* tokens is < top_p (ties
/// all kept; top-1 always kept).
pub fn warp_top_p(logits: &[f32], temperature: f32, top_p: f32) -> Vec<f32> {
    let t = temperature.max(1e-4);
    // A non-finite logit (one poisoned artifact output) is treated as
    // -inf: it gets zero mass instead of panicking the engine worker
    // thread (NaN) or poisoning softmax into an all-NaN row that would
    // silently auto-accept every draft token (+inf, since NaN p makes
    // `(p / q).min(1.0)` evaluate to 1.0). An all-poisoned row degrades
    // to uniform so downstream CDF inversion stays well-defined.
    let mut probs: Vec<f32> = logits
        .iter()
        .map(|&l| if l.is_finite() { l / t } else { f32::NEG_INFINITY })
        .collect();
    if !probs.is_empty() && probs.iter().all(|&v| v == f32::NEG_INFINITY) {
        let n = probs.len();
        return vec![1.0 / n as f32; n];
    }
    softmax(&mut probs);
    // Sort descending once; prefix[j] = mass of the j largest values,
    // accumulated in descending order. mass_before(p) is then the prefix
    // at the count of strictly-greater values (binary search): O(V log V)
    // total where the old per-token scan of the sorted prefix was O(V²) —
    // and this runs on the verify hot path, B×(k+1) times per step.
    // Summation order matches the old scan exactly, so results are
    // bit-identical (`warp_prefix_sum_matches_reference_scan`).
    let mut sorted: Vec<f32> = probs.clone();
    sorted.sort_by(|a, b| b.total_cmp(a));
    let mut prefix = vec![0.0f32; sorted.len() + 1];
    for (j, &s) in sorted.iter().enumerate() {
        prefix[j + 1] = prefix[j] + s;
    }
    let mut keep = vec![false; probs.len()];
    for (i, &p) in probs.iter().enumerate() {
        let n_greater = sorted.partition_point(|&s| s > p);
        keep[i] = prefix[n_greater] < top_p;
    }
    let mass: f32 = probs
        .iter()
        .zip(&keep)
        .map(|(&p, &k)| if k { p } else { 0.0 })
        .sum();
    let inv = 1.0 / mass;
    probs
        .iter()
        .zip(&keep)
        .map(|(&p, &k)| if k { p * inv } else { 0.0 })
        .collect()
}

/// Sample by CDF inversion in original index order — the same convention as
/// the in-graph sampler (`argmax(cdf > u)`).
pub fn sample_cdf(probs: &[f32], u: f32) -> usize {
    let u = u * (1.0 - 1e-6);
    let mut cdf = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        cdf += p;
        if cdf > u {
            return i;
        }
    }
    // Float underflow tail: return the last token with non-zero mass.
    probs
        .iter()
        .rposition(|&p| p > 0.0)
        .unwrap_or(probs.len() - 1)
}

/// Outcome of verifying one sequence's draft tokens against the main model.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecOutcome {
    /// How many draft tokens were accepted (0..=k).
    pub accepted: usize,
    /// The next stream token: the corrected token on rejection, or the
    /// bonus token when all k drafts were accepted.
    pub next_token: usize,
    /// True iff all k drafts were accepted (`next_token` is the bonus).
    pub bonus: bool,
}

/// The stochastic speculative sampling rule over *warped* distributions.
///
/// * `p_main[j]` — warped main-model distribution after consuming stream
///   token j (j = 0..k inclusive; index k is the bonus distribution).
/// * `draft_tokens[j]` — draft token d_{j+1}.
/// * `q_draft[j]` — warped draft distribution d_{j+1} was sampled from.
///
/// Token d is accepted with probability `min(1, p(d)/q(d))`; on rejection
/// the corrected token is sampled from `norm(max(0, p - q))`. This composes
/// to exact sampling from `p` (Leviathan et al. 2023, Thm 1).
pub fn spec_accept(
    p_main: &[&[f32]],
    draft_tokens: &[usize],
    q_draft: &[&[f32]],
    rng: &mut Pcg32,
) -> SpecOutcome {
    let k = draft_tokens.len();
    debug_assert_eq!(p_main.len(), k + 1);
    debug_assert_eq!(q_draft.len(), k);
    for j in 0..k {
        let d = draft_tokens[j];
        let p = p_main[j][d];
        let q = q_draft[j][d];
        // One uniform is consumed per draft position unconditionally, so
        // the stream position is a function of j alone.
        let r = rng.next_f32();
        // d was sampled from q, so q(d) > 0 in exact arithmetic; treat an
        // fp-zero as a reject to stay conservative.
        if q > 0.0 && r < (p / q).min(1.0) {
            continue;
        }
        // Reject: sample from the residual distribution.
        let mut residual: Vec<f32> = p_main[j]
            .iter()
            .zip(q_draft[j])
            .map(|(&p, &q)| (p - q).max(0.0))
            .collect();
        let mass: f32 = residual.iter().sum();
        if mass > 1e-12 {
            let inv = 1.0 / mass;
            for v in residual.iter_mut() {
                *v *= inv;
            }
        } else {
            // p == q exactly: resampling from p is distribution-correct.
            residual = p_main[j].to_vec();
        }
        let c = sample_cdf(&residual, rng.next_f32());
        return SpecOutcome { accepted: j, next_token: c, bonus: false };
    }
    let bonus = sample_cdf(p_main[k], rng.next_f32());
    SpecOutcome { accepted: k, next_token: bonus, bonus: true }
}

/// Log-probability of `token` under the warped distribution (used by the
/// Fig-5 mean-logP ranking).
pub fn logp_of(warped: &[f32], token: usize) -> f32 {
    warped[token].max(1e-30).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn pcg_is_deterministic_and_uniform() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        let mut c = Pcg32::new(42, 2);
        let xs: Vec<f32> = (0..1000).map(|_| a.next_f32()).collect();
        let ys: Vec<f32> = (0..1000).map(|_| b.next_f32()).collect();
        assert_eq!(xs, ys);
        let zs: Vec<f32> = (0..1000).map(|_| c.next_f32()).collect();
        assert_ne!(xs, zs);
        let mean = xs.iter().sum::<f32>() / 1000.0;
        assert_close(mean, 0.5, 0.05);
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn softmax_normalizes() {
        let mut x = vec![1.0, 2.0, 3.0, -1000.0];
        softmax(&mut x);
        assert_close(x.iter().sum::<f32>(), 1.0, 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
        assert!(x[3] < 1e-20);
    }

    #[test]
    fn warp_keeps_top1_even_with_tiny_top_p() {
        let logits = vec![0.0, 5.0, 1.0];
        let w = warp_top_p(&logits, 1.0, 0.01);
        assert_close(w[1], 1.0, 1e-6);
        assert_eq!(w[0], 0.0);
    }

    #[test]
    fn warp_matches_python() {
        // Pinned case shared with python/tests/test_parity.py.
        let w = warp_top_p(&[0.0, 1.0, 2.0, -1.0], 1.0, 0.8);
        assert_close(w[2], 0.6439 / 0.8808, 2e-3);
        assert_close(w[1], 0.2369 / 0.8808, 2e-3);
        assert_eq!(w[0], 0.0);
        assert_eq!(w[3], 0.0);
    }

    /// The pre-optimization warp: per-token scan of the sorted prefix
    /// (O(V²)). Kept as the reference the prefix-sum rewrite must match
    /// bit-for-bit (same descending summation order).
    fn warp_reference_scan(logits: &[f32], temperature: f32, top_p: f32)
                           -> Vec<f32> {
        let t = temperature.max(1e-4);
        let mut probs: Vec<f32> = logits.iter().map(|&l| l / t).collect();
        softmax(&mut probs);
        let mut sorted: Vec<f32> = probs.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut keep = vec![false; probs.len()];
        for (i, &p) in probs.iter().enumerate() {
            let mut mass_before = 0.0f32;
            for &s in &sorted {
                if s > p {
                    mass_before += s;
                } else {
                    break;
                }
            }
            keep[i] = mass_before < top_p;
        }
        let mass: f32 = probs
            .iter()
            .zip(&keep)
            .map(|(&p, &k)| if k { p } else { 0.0 })
            .sum();
        let inv = 1.0 / mass;
        probs
            .iter()
            .zip(&keep)
            .map(|(&p, &k)| if k { p * inv } else { 0.0 })
            .collect()
    }

    #[test]
    fn warp_prefix_sum_matches_reference_scan() {
        // Random logits over a spread of (T, top_p), including ties from
        // repeated values: the fast path must be bit-identical.
        let mut rng = Pcg32::new(2024, 17);
        for case in 0usize..40 {
            let v = 2 + (case % 63);
            let mut logits: Vec<f32> =
                (0..v).map(|_| (rng.next_f32() - 0.5) * 12.0).collect();
            if case % 3 == 0 {
                logits[v / 2] = logits[0]; // force a tie
            }
            let t = 0.05 + rng.next_f32() * 2.0;
            let p = 0.05 + rng.next_f32() * 0.95;
            let fast = warp_top_p(&logits, t, p);
            let slow = warp_reference_scan(&logits, t, p);
            assert_eq!(fast, slow, "case {case}: T={t} top_p={p}");
        }
    }

    #[test]
    fn warp_nonfinite_logit_is_neg_inf_not_a_panic() {
        // One poisoned artifact output must not panic the worker thread
        // (NaN) or NaN-poison the whole row (+inf): non-finite values get
        // zero mass, everything else warps as if they were -inf.
        let with_ninf =
            warp_top_p(&[1.0, f32::NEG_INFINITY, 0.5, -0.3], 1.0, 0.9);
        for poison in [f32::NAN, f32::INFINITY] {
            let w = warp_top_p(&[1.0, poison, 0.5, -0.3], 1.0, 0.9);
            assert_eq!(w, with_ninf, "poison {poison}");
            assert_eq!(w[1], 0.0);
            assert!(w.iter().all(|v| v.is_finite()));
            assert_close(w.iter().sum::<f32>(), 1.0, 1e-6);
        }
    }

    #[test]
    fn warp_all_poisoned_degrades_to_uniform() {
        for row in [[f32::NAN; 4], [f32::INFINITY; 4],
                    [f32::NEG_INFINITY; 4]] {
            let w = warp_top_p(&row, 0.7, 0.9);
            assert_eq!(w, vec![0.25; 4]);
            // CDF inversion over the degraded row still returns a token.
            assert_eq!(sample_cdf(&w, 0.9), 3);
        }
    }

    #[test]
    fn warp_per_row_params_matches_python() {
        // Pinned per-row case shared with python/tests/test_parity.py::
        // test_per_row_params_directed: one logits row warped under two
        // different (T, top_p) pairs — the per-slot verify-side warp.
        let logits = [0.0f32, 1.0, 2.0, -1.0];
        let row0 = warp_top_p(&logits, 1.0, 0.8);
        assert_close(row0[2], 0.6439 / 0.8808, 2e-3);
        assert_close(row0[1], 0.2369 / 0.8808, 2e-3);
        assert_eq!(row0[0], 0.0);
        assert_eq!(row0[3], 0.0);
        let row1 = warp_top_p(&logits, 0.5, 1.0);
        assert_close(row1[2], 0.86495, 2e-3);
        assert_close(row1[1], 0.11706, 2e-3);
        assert_close(row1[0], 0.01584, 2e-3);
        assert!(row1[3] > 0.0, "top_p = 1 keeps everything");
    }

    #[test]
    fn warp_top_p_1_is_plain_softmax() {
        let logits = vec![0.3, -0.2, 1.7, 0.0];
        let w = warp_top_p(&logits, 1.0, 1.0);
        let mut s = logits.clone();
        softmax(&mut s);
        for (a, b) in w.iter().zip(&s) {
            assert_close(*a, *b, 1e-6);
        }
    }

    #[test]
    fn warp_low_temperature_concentrates() {
        let logits = vec![0.0, 0.5, 0.4];
        let w = warp_top_p(&logits, 0.01, 1.0);
        assert!(w[1] > 0.999);
    }

    #[test]
    fn sample_cdf_inverts() {
        let probs = vec![0.0, 0.25, 0.0, 0.75];
        assert_eq!(sample_cdf(&probs, 0.1), 1);
        assert_eq!(sample_cdf(&probs, 0.24), 1);
        assert_eq!(sample_cdf(&probs, 0.26), 3);
        assert_eq!(sample_cdf(&probs, 0.999999), 3);
    }

    #[test]
    fn sample_cdf_empirical_distribution() {
        let probs = vec![0.1, 0.0, 0.6, 0.3];
        let mut rng = Pcg32::new(7, 0);
        let mut counts = [0usize; 4];
        let n = 40_000;
        for _ in 0..n {
            counts[sample_cdf(&probs, rng.next_f32())] += 1;
        }
        for i in 0..4 {
            assert_close(counts[i] as f32 / n as f32, probs[i], 0.01);
        }
    }

    /// THE core correctness property of speculative sampling: composing
    /// draft sampling + accept/reject + residual/bonus sampling must equal
    /// direct sampling from the main distribution.
    #[test]
    fn spec_accept_matches_direct_sampling() {
        // Hand-rolled property test (proptest is unavailable offline):
        // sweep several random (p, q) pairs on a small vocab and compare
        // empirical next-token frequencies at draft position 0.
        let vocab = 6;
        for case in 0..8u64 {
            let mut setup = Pcg32::new(100 + case, 3);
            let mk_dist = |rng: &mut Pcg32| {
                let mut v: Vec<f32> =
                    (0..vocab).map(|_| rng.next_f32() + 0.01).collect();
                let s: f32 = v.iter().sum();
                v.iter_mut().for_each(|x| *x /= s);
                v
            };
            let p0 = mk_dist(&mut setup);
            let p1 = mk_dist(&mut setup);
            let q0 = mk_dist(&mut setup);

            let n = 60_000;
            let mut freq = vec![0f32; vocab];
            let mut rng = Pcg32::new(case, 9);
            for _ in 0..n {
                // Draft one token from q0, then run the rule.
                let d = sample_cdf(&q0, rng.next_f32());
                let out = spec_accept(
                    &[&p0, &p1],
                    &[d],
                    &[&q0],
                    &mut rng,
                );
                // The first emitted stream token: accepted draft or
                // correction.
                let first = if out.accepted >= 1 { d } else { out.next_token };
                freq[first] += 1.0;
            }
            for f in freq.iter_mut() {
                *f /= n as f32;
            }
            for i in 0..vocab {
                assert_close(freq[i], p0[i], 0.015);
            }
        }
    }

    #[test]
    fn spec_accept_identical_dists_accepts_everything() {
        let p = vec![0.25f32, 0.25, 0.25, 0.25];
        let pr: &[f32] = &p;
        let mut rng = Pcg32::new(1, 1);
        let mut bonus_count = 0;
        for _ in 0..200 {
            let d = sample_cdf(&p, rng.next_f32());
            let out = spec_accept(&[pr, pr, pr], &[d, d], &[pr, pr], &mut rng);
            assert_eq!(out.accepted, 2);
            if out.bonus {
                bonus_count += 1;
            }
        }
        assert_eq!(bonus_count, 200);
    }

    #[test]
    fn spec_accept_disjoint_dists_rejects_immediately() {
        // q puts all mass on token 0, p on token 1: always reject at 0 and
        // correct to token 1.
        let p = vec![0.0f32, 1.0, 0.0];
        let q = vec![1.0f32, 0.0, 0.0];
        let mut rng = Pcg32::new(2, 2);
        for _ in 0..100 {
            let out = spec_accept(&[&p, &p], &[0], &[&q], &mut rng);
            assert_eq!(out, SpecOutcome {
                accepted: 0,
                next_token: 1,
                bonus: false
            });
        }
    }

    #[test]
    fn logp_of_is_safe_on_zero() {
        assert!(logp_of(&[0.0, 1.0], 0).is_finite());
        assert_close(logp_of(&[0.5, 0.5], 1), 0.5f32.ln(), 1e-6);
    }
}
