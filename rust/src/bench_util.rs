//! Benchmark harness shared by `rust/benches/*` (criterion is unavailable
//! in this offline image; this is a small measured-run harness with
//! warmup, repetitions and table/JSON output).

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::metrics::Summary;
use crate::runtime::json::Json;

/// Resolve the artifacts directory (env override for CI layouts).
pub fn artifacts_root() -> PathBuf {
    std::env::var("BASS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True when artifacts exist; benches/integration tests bail politely
/// otherwise.
pub fn artifacts_available() -> bool {
    artifacts_root().join("manifest.json").exists()
}

/// Time `f` with warmup; returns per-iteration seconds summary.
pub fn measure<F: FnMut() -> Result<()>>(warmup: usize, iters: usize,
                                         mut f: F) -> Result<Summary> {
    for _ in 0..warmup {
        f()?;
    }
    let mut s = Summary::default();
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f()?;
        s.add(t0.elapsed().as_secs_f64());
    }
    Ok(s)
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        for (i, c) in cells.iter().enumerate() {
            if i < self.widths.len() {
                self.widths[i] = self.widths[i].max(c.len());
            }
        }
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:>w$}  ", w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers, &self.widths);
        println!("{}", "-".repeat(
            self.widths.iter().sum::<usize>() + 2 * self.widths.len()));
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Append a machine-readable result record under `artifacts/results/`.
pub fn save_result(name: &str, record: Json) -> Result<()> {
    let dir = artifacts_root().join("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, record.to_string_pretty())?;
    println!("[saved {}]", path.display());
    Ok(())
}

/// Load prompts for benchmarking from a task file, cycling if needed.
pub fn bench_prompts(root: &Path, task: &str, n: usize)
                     -> Result<Vec<Vec<u8>>> {
    let prompts: Vec<Vec<u8>> = match task {
        "code" => crate::eval::load_code_tasks(root)?
            .into_iter()
            .map(|t| crate::tokenizer::encode(&t.prompt))
            .collect(),
        "summ" => crate::eval::load_summ_tasks(root)?
            .into_iter()
            .map(|t| crate::tokenizer::encode(&t.prompt))
            .collect(),
        _ => anyhow::bail!("unknown task '{task}'"),
    };
    Ok((0..n).map(|i| prompts[i % prompts.len()].clone()).collect())
}

/// Format milliseconds with a sensible precision.
pub fn ms(secs: f64) -> String {
    format!("{:.2}", secs * 1e3)
}

/// Format a speedup ratio like the paper ("2.16x").
pub fn speedup(base: f64, ours: f64) -> String {
    if ours <= 0.0 {
        return "-".into();
    }
    format!("{:.2}x", base / ours)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut n = 0;
        let s = measure(2, 5, || {
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 7);
        assert_eq!(s.n(), 5);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // smoke: must not panic
    }

    #[test]
    fn speedup_formats() {
        assert_eq!(speedup(2.0, 1.0), "2.00x");
        assert_eq!(speedup(1.0, 0.0), "-");
    }
}
