//! Pass@Batch / Pass@First / Pass@Finished metrics (Tables 2–3, Fig 5).
//!
//! * **Pass@Batch** — fraction of problems where at least one sequence in
//!   the generated batch passes the checker (paper §4.3).
//! * **Pass@First** — the top-ranked (mean-logP) *finished* sequence
//!   passes (paper §4.5: "the first displayed recommendation").
//! * **Pass@Finished** — at least one *finished* sequence passes within
//!   the time budget.

/// One generated candidate with its ranking inputs.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub text: String,
    pub finished: bool,
    /// Mean log-probability under the warped main distribution.
    pub mean_logp: f64,
    pub passes: bool,
}

/// Per-problem outcome under the three metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PassOutcome {
    pub pass_batch: bool,
    pub pass_first: bool,
    pub pass_finished: bool,
    pub n_finished: usize,
}

pub fn judge(cands: &[Candidate]) -> PassOutcome {
    let pass_batch = cands.iter().any(|c| c.passes);
    let finished: Vec<&Candidate> =
        cands.iter().filter(|c| c.finished).collect();
    let pass_finished = finished.iter().any(|c| c.passes);
    let first = finished.iter().max_by(|a, b| {
        a.mean_logp.partial_cmp(&b.mean_logp).unwrap()
    });
    PassOutcome {
        pass_batch,
        pass_first: first.map(|c| c.passes).unwrap_or(false),
        pass_finished,
        n_finished: finished.len(),
    }
}

/// Aggregate outcomes across problems into percentage rates.
#[derive(Debug, Default, Clone, Copy)]
pub struct PassRates {
    pub n: usize,
    pub pass_batch: f64,
    pub pass_first: f64,
    pub pass_finished: f64,
}

pub fn aggregate(outcomes: &[PassOutcome]) -> PassRates {
    let n = outcomes.len();
    if n == 0 {
        return PassRates::default();
    }
    let frac = |f: fn(&PassOutcome) -> bool| {
        outcomes.iter().filter(|o| f(o)).count() as f64 / n as f64
    };
    PassRates {
        n,
        pass_batch: frac(|o| o.pass_batch),
        pass_first: frac(|o| o.pass_first),
        pass_finished: frac(|o| o.pass_finished),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(passes: bool, finished: bool, logp: f64) -> Candidate {
        Candidate { text: String::new(), finished, mean_logp: logp, passes }
    }

    #[test]
    fn ranking_picks_highest_logp_finished() {
        let cands = vec![
            cand(false, true, -0.1), // ranked first, fails
            cand(true, true, -0.5),
            cand(true, false, -0.01), // unfinished: ignored by ranking
        ];
        let o = judge(&cands);
        assert!(o.pass_batch);
        assert!(o.pass_finished);
        assert!(!o.pass_first);
        assert_eq!(o.n_finished, 2);
    }

    #[test]
    fn no_finished_candidates() {
        let o = judge(&[cand(true, false, -0.1)]);
        assert!(o.pass_batch);
        assert!(!o.pass_finished);
        assert!(!o.pass_first);
    }

    #[test]
    fn aggregate_rates() {
        let outcomes = vec![
            PassOutcome { pass_batch: true, pass_first: true,
                          pass_finished: true, n_finished: 1 },
            PassOutcome { pass_batch: true, pass_first: false,
                          pass_finished: false, n_finished: 0 },
        ];
        let r = aggregate(&outcomes);
        assert_eq!(r.n, 2);
        assert!((r.pass_batch - 1.0).abs() < 1e-9);
        assert!((r.pass_first - 0.5).abs() < 1e-9);
        assert!((r.pass_finished - 0.5).abs() < 1e-9);
        assert_eq!(aggregate(&[]).n, 0);
    }
}
