//! Evaluation harnesses for the paper's accuracy metrics.

pub mod passk;
pub mod rouge2;
pub mod tasks;

pub use passk::{aggregate, judge, Candidate, PassOutcome, PassRates};
pub use rouge2::rouge2_f1;
pub use tasks::{load_code_tasks, load_summ_tasks, CodeTask, SummTask};
