//! ROUGE-2 F1 (Lin 2004) — the XSum accuracy metric of Table 1.

use std::collections::HashMap;

fn bigrams(text: &str) -> HashMap<(String, String), usize> {
    let words: Vec<String> = text
        .split_whitespace()
        .map(|w| {
            w.chars()
                .filter(|c| c.is_alphanumeric())
                .collect::<String>()
                .to_lowercase()
        })
        .filter(|w| !w.is_empty())
        .collect();
    let mut map = HashMap::new();
    for pair in words.windows(2) {
        *map.entry((pair[0].clone(), pair[1].clone())).or_insert(0) += 1;
    }
    map
}

/// ROUGE-2 F1 between a candidate and a reference.
pub fn rouge2_f1(candidate: &str, reference: &str) -> f64 {
    let c = bigrams(candidate);
    let r = bigrams(reference);
    let c_total: usize = c.values().sum();
    let r_total: usize = r.values().sum();
    if c_total == 0 || r_total == 0 {
        return 0.0;
    }
    let overlap: usize = c
        .iter()
        .map(|(k, &v)| v.min(*r.get(k).unwrap_or(&0)))
        .sum();
    let p = overlap as f64 / c_total as f64;
    let rec = overlap as f64 / r_total as f64;
    if p + rec == 0.0 {
        0.0
    } else {
        2.0 * p * rec / (p + rec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_one() {
        let s = "alice maps the rivers of paris";
        assert!((rouge2_f1(s, s) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_is_zero() {
        assert_eq!(rouge2_f1("a b c", "x y z"), 0.0);
    }

    #[test]
    fn partial_overlap() {
        // cand bigrams: (a,b),(b,c); ref bigrams: (a,b),(b,d)
        let f1 = rouge2_f1("a b c", "a b d");
        // p = 1/2, r = 1/2 -> f1 = 1/2
        assert!((f1 - 0.5).abs() < 1e-9);
    }

    #[test]
    fn case_and_punct_normalized() {
        assert!((rouge2_f1("Alice maps, the rivers!",
                           "alice maps the rivers") - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(rouge2_f1("", "a b"), 0.0);
        assert_eq!(rouge2_f1("a b", ""), 0.0);
        assert_eq!(rouge2_f1("one", "one"), 0.0); // no bigrams
    }
}
