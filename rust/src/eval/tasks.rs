//! Task loaders for the paper's two evaluation workloads (testbed analogs):
//! `synth_humaneval` (code completion with programmatic checkers, Tables
//! 2/3, Fig 5) and `synth_xsum` (summarization with ROUGE-2, Table 1).
//! Files are emitted by `python/compile/corpus.py` at `make artifacts`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::json::Json;

/// A code-completion problem with its checker.
#[derive(Debug, Clone)]
pub struct CodeTask {
    pub task_id: String,
    pub prompt: String,
    /// Expected canonical completion (first generated line must equal it).
    pub expected: String,
}

impl CodeTask {
    /// The HumanEval-style pass check: the first non-empty generated line
    /// must equal the canonical body expression.
    pub fn passes(&self, generated: &str) -> bool {
        generated
            .lines()
            .map(str::trim)
            .find(|l| !l.is_empty())
            .map(|l| l == self.expected)
            .unwrap_or(false)
    }
}

/// A summarization example.
#[derive(Debug, Clone)]
pub struct SummTask {
    pub task_id: String,
    pub prompt: String,
    pub reference: String,
}

impl SummTask {
    /// The generated summary: everything up to the first newline.
    pub fn extract_summary<'a>(&self, generated: &'a str) -> &'a str {
        generated.split('\n').next().unwrap_or("").trim()
    }
}

pub fn load_code_tasks(root: &Path) -> Result<Vec<CodeTask>> {
    let path = root.join("tasks/synth_humaneval.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text)?;
    let mut out = Vec::new();
    for t in j.as_arr()? {
        let checker = t.get("checker")?;
        if checker.get("type")?.as_str()? != "line_equals" {
            bail!("unsupported checker type");
        }
        out.push(CodeTask {
            task_id: t.get("task_id")?.as_str()?.to_string(),
            prompt: t.get("prompt")?.as_str()?.to_string(),
            expected: checker.get("expected")?.as_str()?.to_string(),
        });
    }
    Ok(out)
}

pub fn load_summ_tasks(root: &Path) -> Result<Vec<SummTask>> {
    let path = root.join("tasks/synth_xsum.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text)?;
    let mut out = Vec::new();
    for t in j.as_arr()? {
        out.push(SummTask {
            task_id: t.get("task_id")?.as_str()?.to_string(),
            prompt: t.get("prompt")?.as_str()?.to_string(),
            reference: t.get("reference")?.as_str()?.to_string(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_checker_first_line() {
        let t = CodeTask {
            task_id: "add_7".into(),
            prompt: "def add_7(x):\n    return".into(),
            expected: "x + 7".into(),
        };
        assert!(t.passes(" x + 7\n"));
        assert!(t.passes("\n  x + 7 \ndef next()"));
        assert!(!t.passes(" x + 8\n"));
        assert!(!t.passes(""));
    }

    #[test]
    fn summary_extraction() {
        let t = SummTask {
            task_id: "s".into(),
            prompt: "p".into(),
            reference: "r".into(),
        };
        assert_eq!(t.extract_summary(" alice maps paris.\nextra"),
                   "alice maps paris.");
        assert_eq!(t.extract_summary(""), "");
    }
}
