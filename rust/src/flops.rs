//! Analytic FLOP accounting for the utilization metric (Fig 1).
//!
//! The paper reports "GPU utilization" as achieved FLOP/s over peak; here
//! the peak is calibrated at runtime with a large GEMM artifact
//! (`Engine::calibrate_peak_flops`) and the achieved side is counted
//! analytically from the transformer dimensions — the same accounting the
//! paper's 0.4% / 4.8% / 15.8% numbers use.

use crate::runtime::ModelInfo;

/// FLOPs for one forward pass over `q` new tokens per sequence in a batch
/// of `b`, with an average live context of `ctx` tokens.
///
/// Dense GEMMs dominate: 2·params per token; attention adds
/// 2 · 2 · H · q · ctx · Dh per sequence per layer (QKᵀ and PV).
pub fn step_flops(info: &ModelInfo, b: usize, q: usize, ctx: usize) -> f64 {
    let dense = 2.0 * info.param_count as f64 * (b * q) as f64;
    let attn = 4.0
        * (info.n_layer * info.n_head * b * q * ctx * info.d_head) as f64;
    dense + attn
}

/// FLOPs to prefill a batch of prompts of true length `p` each.
pub fn prefill_flops(info: &ModelInfo, b: usize, p: usize) -> f64 {
    // Causal attention: average context p/2.
    step_flops(info, b, p, p / 2)
}

/// Cost of one `kv_row_copy` launch: the elements moved (2·L cache
/// buffers of `[H, S, Dh]` each — K and V per layer). A copy is pure
/// memory traffic, so one element-move is charged as one FLOP; the
/// launch touches exactly one row regardless of the bucket width, so
/// the launched and PAD-padded costs coincide.
pub fn row_copy_flops(info: &ModelInfo) -> f64 {
    (2 * info.n_layer * info.n_head * info.s_max * info.d_head) as f64
}

/// Running FLOP counter a decode loop updates step by step.
///
/// `total` counts *useful* per-row work (each row at its own `q_i`/`k_i`
/// and context — the utilization numerator). `launch` / `padded_launch`
/// count what the exec backend actually launches vs. what a rectangular
/// PAD launch of the same batch would: PAD/stub launch the rectangle
/// (`launch == padded_launch`), packed launches the Σq_i token stream
/// plus its capacity filler, SPLIT launches each row at its own bucket.
/// The gap `padded_launch - launch` is the pad-FLOP saving the serving
/// report surfaces (`BENCH_serving.json` "flops").
#[derive(Debug, Default, Clone)]
pub struct FlopCounter {
    pub total: f64,
    pub launch: f64,
    pub padded_launch: f64,
}

impl FlopCounter {
    pub fn add_step(&mut self, info: &ModelInfo, b: usize, q: usize,
                    ctx: usize) {
        self.total += step_flops(info, b, q, ctx);
    }

    pub fn add_prefill(&mut self, info: &ModelInfo, b: usize, p: usize) {
        self.total += prefill_flops(info, b, p);
    }

    /// Accrue one launch's FLOPs: `launch` as actually dispatched,
    /// `padded` as the rectangular PAD equivalent would have been.
    pub fn add_launch(&mut self, launch: f64, padded: f64) {
        self.launch += launch;
        self.padded_launch += padded;
    }

    /// Accrue one KV row copy. Fan-out siblings and prefix-cache hits
    /// go through here instead of [`FlopCounter::add_prefill`]: the
    /// useful work is the element move, not a re-run of the prompt.
    /// Copy launches are row-shaped on every backend, so launch and
    /// padded cost are the same.
    pub fn add_row_copy(&mut self, info: &ModelInfo) {
        let f = row_copy_flops(info);
        self.total += f;
        self.add_launch(f, f);
    }

    /// Utilization fraction given elapsed seconds and a calibrated peak.
    pub fn utilization(&self, wall_secs: f64, peak_flops: f64) -> f64 {
        if wall_secs <= 0.0 || peak_flops <= 0.0 {
            return 0.0;
        }
        self.total / wall_secs / peak_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn model() -> ModelInfo {
        ModelInfo {
            name: "m".into(),
            n_layer: 4,
            n_head: 8,
            d_model: 256,
            d_ff: 1024,
            s_max: 256,
            d_head: 32,
            param_count: 3_290_624,
            weights: HashMap::new(),
        }
    }

    #[test]
    fn dense_term_scales_linearly() {
        let m = model();
        let f1 = step_flops(&m, 1, 1, 0);
        assert_eq!(f1, 2.0 * 3_290_624.0);
        assert_eq!(step_flops(&m, 8, 1, 0), 8.0 * f1);
        assert_eq!(step_flops(&m, 8, 4, 0), 32.0 * f1);
    }

    #[test]
    fn attention_term_grows_with_context() {
        let m = model();
        let short = step_flops(&m, 1, 1, 10);
        let long = step_flops(&m, 1, 1, 200);
        assert!(long > short);
        let attn_delta = long - short;
        assert_eq!(attn_delta, 4.0 * (4 * 8 * 190 * 32) as f64);
    }

    #[test]
    fn launch_accounting_tracks_the_pad_gap() {
        let mut c = FlopCounter::default();
        c.add_launch(10.0, 12.0);
        c.add_launch(5.0, 5.0);
        assert_eq!(c.launch, 15.0);
        assert_eq!(c.padded_launch, 17.0);
        assert!(c.launch <= c.padded_launch);
        // add_launch never touches the utilization numerator.
        assert_eq!(c.total, 0.0);
    }

    /// Satellite-pinned regression: a fan-out-n admission charges
    /// exactly one prefill plus (n-1) row copies — not n prefills —
    /// on both the useful-work and launch/padded axes.
    #[test]
    fn fanout_charges_one_prefill_plus_copies() {
        let m = model();
        let n = 4;
        let p = 48;

        let mut shared = FlopCounter::default();
        shared.add_prefill(&m, 1, p);
        let pf = prefill_flops(&m, 1, p);
        shared.add_launch(pf, pf);
        for _ in 1..n {
            shared.add_row_copy(&m);
        }

        let copy = row_copy_flops(&m);
        assert_eq!(copy, (2 * 4 * 8 * 256 * 32) as f64);
        let expect = pf + (n - 1) as f64 * copy;
        assert_eq!(shared.total, expect);
        assert_eq!(shared.launch, expect);
        assert_eq!(shared.padded_launch, expect);

        // The naive per-sibling accounting is strictly more expensive.
        let mut naive = FlopCounter::default();
        naive.add_prefill(&m, n, p);
        assert!(naive.total > shared.total);
        // And a copy is far cheaper than the prefill it replaces.
        assert!(copy < pf);
    }

    #[test]
    fn utilization_math() {
        let mut c = FlopCounter::default();
        let m = model();
        c.add_step(&m, 1, 1, 0);
        let u = c.utilization(1.0, 2.0 * 3_290_624.0 * 10.0);
        assert!((u - 0.1).abs() < 1e-9);
        assert_eq!(c.utilization(0.0, 1.0), 0.0);
    }
}
