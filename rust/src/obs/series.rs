//! Deterministic bounded time series for gauge sampling.

use crate::runtime::json::Json;

/// A decimating sample buffer: the first `cap` samples are kept
/// verbatim; on overflow every other retained sample is dropped and
/// the keep-stride doubles, so the buffer always covers the full push
/// history at bounded resolution. The retained set is a **pure
/// function of the pushed sequence** — no RNG, no clock — so a
/// deterministic push stream yields a byte-deterministic series
/// (which is why this is used instead of a random reservoir).
///
/// Invariant: retained value `j` is the sample pushed at index
/// `j * stride` — the capacity is rounded up to even so the retained
/// indices stay contiguous multiples of the stride across every
/// compaction.
#[derive(Debug, Clone)]
pub struct Series {
    cap: usize,
    stride: u64,
    seen: u64,
    values: Vec<f64>,
}

impl Series {
    pub fn new(cap: usize) -> Series {
        let cap = cap.max(2);
        let cap = cap + (cap & 1); // even, for contiguous decimation
        Series { cap, stride: 1, seen: 0, values: Vec::new() }
    }

    /// Offer one sample. O(1) amortized; compaction is O(cap) and
    /// happens once per stride doubling.
    pub fn push(&mut self, v: f64) {
        let i = self.seen;
        self.seen += 1;
        if i % self.stride != 0 {
            return;
        }
        if self.values.len() == self.cap {
            let kept: Vec<f64> =
                self.values.iter().copied().step_by(2).collect();
            self.values = kept;
            self.stride *= 2;
            if i % self.stride != 0 {
                return;
            }
        }
        self.values.push(v);
    }

    /// Samples offered over the series' lifetime.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Push-index distance between retained values.
    pub fn stride(&self) -> u64 {
        self.stride
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// `{"seen", "stride", "values"}` — value `j` was sampled at push
    /// index `j * stride`. Non-finite samples degrade to `null`.
    pub fn to_json(&self) -> Json {
        let vals = self
            .values
            .iter()
            .map(|&v| {
                if v.is_finite() { Json::Num(v) } else { Json::Null }
            })
            .collect();
        Json::obj(vec![
            ("seen", (self.seen as f64).into()),
            ("stride", (self.stride as f64).into()),
            ("values", Json::Arr(vals)),
        ])
    }
}

impl Default for Series {
    fn default() -> Series {
        Series::new(256)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_everything_until_capacity() {
        let mut s = Series::new(4);
        for i in 0..4 {
            s.push(i as f64);
        }
        assert_eq!(s.stride(), 1);
        assert_eq!(s.values(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn decimates_deterministically_and_stays_bounded() {
        let mut a = Series::new(4);
        let mut b = Series::new(4);
        for i in 0..1000 {
            a.push(i as f64);
            b.push(i as f64);
        }
        assert!(a.len() <= 4, "bounded (got {})", a.len());
        assert_eq!(a.seen(), 1000);
        assert_eq!(a.values(), b.values(), "pure function of the pushes");
        assert_eq!(a.stride(), b.stride());
        // Retained value j is the sample pushed at index j*stride.
        for (j, &v) in a.values().iter().enumerate() {
            assert_eq!(v, (j as u64 * a.stride()) as f64);
        }
    }

    #[test]
    fn json_shape_is_stable() {
        let mut s = Series::new(4);
        s.push(2.0);
        s.push(f64::NAN);
        let j = s.to_json();
        assert_eq!(j.get("seen").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("stride").unwrap().as_usize().unwrap(), 1);
        let vals = j.get("values").unwrap().as_arr().unwrap();
        assert_eq!(vals.len(), 2);
        assert!(matches!(vals[1], Json::Null), "NaN degrades to null");
    }
}
